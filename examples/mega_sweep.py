"""The ISSUE 6 acceptance demo: a 10^5-case Study on one box.

Declares a systems x models x plans x workloads grid whose workload axis is
densely sampled (batch x input length x output length), runs it cold, then
reruns it warm from the persistent CaseResult cache and spot-checks
bit-identity against a fully uncached evaluation of a sample of cases.

    PYTHONPATH=src python examples/mega_sweep.py                 # 10^5 cases
    PYTHONPATH=src python examples/mega_sweep.py --cases 2000    # smoke

The cold run streams every unique (device, GEMM shape) pair of the whole
grid through one stacked mapper search; the warm rerun re-prices nothing.
Point REPRO_CACHE_DIR somewhere fast if ~/.cache is networked.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import hardware as hw
from repro.core import result_cache
from repro.core.graph import Plan
from repro.core.mapper import clear_matmul_cache
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config


def build_cases(n_target: int):
    """systems x models x plans x (batch x in_len x out_len) ≈ n_target."""
    systems = [hw.make_system(hw.compute_design(d), 4, 600, "fc")
               for d in "ABCDE"]
    models = [get_config("qwen2-0.5b"), get_config("qwen3-1.7b")]
    plans = [Plan(tp=1, dp=4), Plan(tp=4)]
    fixed = len(systems) * len(models) * len(plans)

    batches = (1, 2, 4, 8, 16, 32)
    outs = (16, 64, 256)
    n_inputs = max(1, n_target // (fixed * len(batches) * len(outs)))
    in_lens = [64 + 32 * i for i in range(n_inputs)]

    cases = [Case(s, m, p, Workload(b, i, o),
                  label=f"{s.device.name}/{m.name}/b{b}i{i}o{o}")
             for s in systems for m in models for p in plans
             for b in batches for i in in_lens for o in outs]
    return cases


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cases", type=int, default=100_000)
    ap.add_argument("--verify-sample", type=int, default=8,
                    help="cases re-evaluated uncached for bit-identity")
    args = ap.parse_args(argv)

    cases = build_cases(args.cases)
    print(f"grid: {len(cases)} cases")

    clear_matmul_cache()
    t0 = time.perf_counter()
    cold = Study(cases=cases, enforce_fits=False).run()
    dt_cold = time.perf_counter() - t0
    print(f"cold: {dt_cold:.1f}s "
          f"({1e3 * dt_cold / len(cases):.2f} ms/case)  "
          f"[{cold.stats.summary()}]")

    clear_matmul_cache()                    # warm rerun = a fresh process
    t0 = time.perf_counter()
    warm = Study(cases=cases, enforce_fits=False).run()
    dt_warm = time.perf_counter() - t0
    print(f"warm: {dt_warm:.2f}s — {dt_cold / max(dt_warm, 1e-9):.0f}x "
          f"(case hits: {warm.stats.case_cache_hits})")

    assert all(c.latency == w.latency and c.throughput == w.throughput
               for c, w in zip(cold, warm)), "warm rerun diverged"

    # bit-identity vs the uncached path on an evenly-spaced sample
    step = max(1, len(cases) // args.verify_sample)
    sample = cases[::step][:args.verify_sample]
    clear_matmul_cache()
    with result_cache.disabled():
        ref = Study(cases=sample, enforce_fits=False).run()
    ok = all(a.latency == b.latency for a, b in zip(ref, cold[::step]))
    print(f"uncached spot-check ({len(sample)} cases): "
          f"{'bit-identical' if ok else 'MISMATCH'}")
    assert ok


if __name__ == "__main__":
    main()
