"""Quickstart: the three layers of LLMCompass-JAX in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. LLMCompass simulator (the paper): evaluate a hardware design in ms.
2. Planner: pick the parallelism plan for an assigned arch on a v5e slice.
3. JAX runtime: run a real (reduced) model end to end.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core import area, cost, planner
from repro.core.graph import Plan
from repro.configs import get_config, smoke_config
from repro import models

# ---------------------------------------------------------------- 1) paper
print("== 1. LLMCompass: GPT-3 175B on a 4xA100 node (paper Sec. IV) ==")
node = hw.dgx_a100(4)
gpt3 = get_config("gpt3-175b")
pf = im.prefill(node, gpt3, Plan(tp=4), batch=8, seq=2048)
dc = im.decode_step(node, gpt3, Plan(tp=4), batch=8, kv_len=3072)
print(f"prefill (b8, s2048): {pf.latency:.3f} s   dominant={pf.dominant}")
print(f"decode  /token     : {dc.latency * 1e3:.1f} ms  dominant={dc.dominant}")

a100 = hw.nvidia_a100()
rep = area.device_area(a100, 600)
c = cost.device_cost(a100, rep.total_mm2)
print(f"A100 die: {rep.total_mm2:.0f} mm^2, device cost ~${c.total_usd:.0f}")

# -------------------------------------------------------------- 2) planner
print("\n== 2. Planner: qwen3-1.7b on 16x TPU v5e ==")
v5e = hw.tpu_v5e_pod(16)
best = planner.best_plan(v5e, get_config("qwen3-1.7b"), batch=8,
                         in_len=2048, out_len=256)
print(f"best plan: tp={best.plan.tp} pp={best.plan.pp} dp={best.plan.dp}  "
      f"latency={best.latency * 1e3:.0f} ms  "
      f"throughput={best.throughput:.0f} tok/s")

# -------------------------------------------------------------- 3) runtime
print("\n== 3. JAX runtime: reduced qwen3, forward + generate ==")
cfg = smoke_config(get_config("qwen3-1.7b"))
params = models.init_params(cfg, jax.random.PRNGKey(0))
tokens = jnp.array([[1, 2, 3, 4, 5]])
cache = models.init_cache(cfg, 1, 64)
logits, cache = models.prefill(cfg, params, tokens, cache)
out = [int(jnp.argmax(logits[0]))]
for _ in range(7):
    logits, cache = models.decode_step(cfg, params,
                                       jnp.asarray([out[-1]]), cache)
    out.append(int(jnp.argmax(logits[0])))
print(f"prompt {tokens.tolist()[0]} -> generated {out}")
print("done.")
