"""Offline batched serving with continuous batching — end-to-end driver.

    PYTHONPATH=src python examples/serve_offline.py [--arch recurrentgemma-2b]

Serves a reduced config of the chosen architecture with the production
engine (prefill waves + per-slot decode + refill), printing throughput.
"""
import argparse
import sys
import time
sys.path.insert(0, "src")

import jax

from repro.configs import get_config, smoke_config
from repro import models
from repro.serving import Engine, Request, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_size=args.batch, max_len=128)
    reqs = [Request(uid=i,
                    prompt=[(3 * i + j) % cfg.vocab_size
                            for j in range(4 + (i % 5))],
                    max_new_tokens=args.max_new,
                    sampling=SamplingParams(temperature=0.7, top_k=20))
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    for r in done[:3]:
        print(f"req {r.uid}: {len(r.prompt)} prompt -> {r.output}")
    print(f"{len(done)} requests, {eng.stats['tokens_out']} new tokens, "
          f"{dt:.2f}s wall, {eng.stats['tokens_out'] / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
