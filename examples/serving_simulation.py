"""Predict serving metrics analytically: static vs continuous batching
under increasing Poisson load, without touching real hardware.

    PYTHONPATH=src python examples/serving_simulation.py [--model qwen3-1.7b]

For each arrival rate the same trace is replayed through both scheduling
policies (the REAL engine's slot scheduler, priced by the analytical
Evaluator stack) and the request-level metrics are printed — the questions
a fixed-shape `generate()` call cannot answer: p99 TTFT under load, goodput,
slot occupancy.
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.core import hardware as hw
from repro.core.graph import Plan
from repro.core.study import Case, Study
from repro.core.workload import Trace, TrafficWorkload
from repro.configs import get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="qwen3-1.7b")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=48)
    args = ap.parse_args()

    system = hw.make_system(hw.nvidia_a100(), 1)
    cfg = get_config(args.model)
    cases = []
    for rate in (2.0, 8.0, 24.0):
        trace = Trace.poisson(args.requests, rate=rate, in_len=(128, 512),
                              out_len=(32, 128), seed=7)
        for policy in ("static", "continuous"):
            w = TrafficWorkload.from_trace(trace, slots=args.slots,
                                           policy=policy)
            cases.append(Case(system, cfg, Plan(), w, stage="serve",
                              label=f"rate{rate:g}/{policy}"))
    res = Study(cases=cases).run()

    print(f"{args.model} on 1x A100, {args.slots} slots, "
          f"{args.requests} Poisson requests per trace")
    print(f"{'case':<18}{'goodput':>9}{'ttft p50':>10}{'ttft p99':>10}"
          f"{'tpot p50':>10}{'occupancy':>10}")
    for r in res:
        s = r.sim
        print(f"{r.case.label:<18}{s.goodput:>9.1f}{s.ttft(50):>10.4f}"
              f"{s.ttft(99):>10.4f}{s.tpot(50):>10.5f}"
              f"{s.mean_occupancy:>10.0%}")
    print("\n" + res.stats.summary())


if __name__ == "__main__":
    main()
