"""End-to-end training driver example.

Default: a tiny model for a quick CPU check. With --preset m100 it trains
a ~100M-parameter model (deliverable (b): "train ~100M model for a few
hundred steps" — run with --steps 300 on real hardware).

    PYTHONPATH=src python examples/train_small.py --steps 30
    PYTHONPATH=src python examples/train_small.py --preset m100 --steps 300
"""
import sys
sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    main()
