"""Design-space exploration with LLMCompass — reproduces the paper's Sec. V
workflow and goes beyond it: sweep compute/memory configurations, evaluate
perf and perf/$ for BOTH the paper's GPT-3 setting and one of our assigned
architectures (qwen3-1.7b serving).

    PYTHONPATH=src python examples/design_space_exploration.py
"""
import sys
sys.path.insert(0, "src")

from dataclasses import replace

from repro.core import area, cost, hardware as hw
from repro.core import inference_model as im
from repro.core.graph import Plan
from repro.configs import get_config

gpt3 = get_config("gpt3-175b")
qwen = get_config("qwen3-1.7b")

print("design, die_mm2, cost_usd, gpt3_gen_s, qwen_tok_s, perf_per_usd")
designs = {
    "ga100 (baseline)": hw.nvidia_ga100(),
    "latency-oriented (paper)": hw.latency_oriented(),
    "throughput-oriented (paper)": hw.throughput_oriented(),
    # beyond-paper what-ifs:
    "half-HBM latency design": replace(
        hw.latency_oriented(), name="half-hbm",
        main_memory=hw.MainMemory(1.0e12, 80 * hw.GB, "HBM2e")),
    "double-MXU ga100": replace(
        hw.nvidia_ga100(), name="2xmxu",
        core=hw._gpu_core(lanes=4, vec_width=32, sa=32, local_kb=384)),
}

base_perf = None
for name, dev in designs.items():
    rep = area.device_area(dev, 600)
    c = cost.device_cost(dev, rep.total_mm2)
    node = hw.make_system(dev, 4, 600, "fc")
    g = im.generate(node, replace(gpt3, n_layers=48), Plan(tp=4),
                    batch=16, in_len=1024, out_len=1024)
    # assigned-arch serving throughput on the same node
    tq = im.throughput(node, qwen, Plan(tp=1, dp=4), batch=16,
                       in_len=2048, out_len=256)
    perf = 1.0 / g.latency
    if base_perf is None:
        base_perf = perf
        base_cost = c.total_usd
    rel_ppd = (perf / base_perf) / (c.total_usd / base_cost)
    print(f"{name:28s} {rep.total_mm2:7.0f} {c.total_usd:8.0f} "
          f"{g.latency:10.2f} {tq:10.0f} {rel_ppd:8.2f}")

print("\npaper claims: latency design ~0.95x perf at 0.58x area (1.06x "
      "perf/$); throughput design 1.42x throughput, 3.41x perf/$ "
      "(reproduced in benchmarks/table4_designs.py)")
