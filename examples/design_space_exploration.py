"""Design-space exploration with LLMCompass — reproduces the paper's Sec. V
workflow and goes beyond it: declare one Study grid over five device
designs and two models (the paper's GPT-3 setting and our assigned
qwen3-1.7b serving workload), and let the engine share evaluators, solve
every design's GEMM shapes in one device-axis stacked mapper search, and
price each die exactly once.

    PYTHONPATH=src python examples/design_space_exploration.py
"""
import sys
sys.path.insert(0, "src")

from dataclasses import replace

from repro.core import hardware as hw
from repro.core.graph import Plan
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config

gpt3_48 = replace(get_config("gpt3-175b"), n_layers=48)
qwen = get_config("qwen3-1.7b")

designs = {
    "ga100 (baseline)": hw.nvidia_ga100(),
    "latency-oriented (paper)": hw.latency_oriented(),
    "throughput-oriented (paper)": hw.throughput_oriented(),
    # beyond-paper what-ifs (public constructors only):
    "half-HBM latency design": replace(
        hw.latency_oriented(), name="half-hbm",
        main_memory=hw.MainMemory(1.0e12, 80 * hw.GB, "HBM2e")),
    "double-MXU ga100": replace(
        hw.nvidia_ga100(), name="2xmxu",
        core=hw.make_core(lanes=4, vec_width=32, sa_rows=32, local_kb=384)),
}

# the grid, declared: per design, GPT-3 generation latency (paper Fig. 10
# shape) and qwen serving throughput on the same 4-device node
cases = []
for name, dev in designs.items():
    node = hw.make_system(dev, 4, 600, "fc")
    cases.append(Case(node, gpt3_48, Plan(tp=4), Workload(16, 1024, 1024),
                      label=f"{name}|gpt3"))
    cases.append(Case(node, qwen, Plan(tp=1, dp=4), Workload(16, 2048, 256),
                      label=f"{name}|qwen"))

res = Study(cases=cases, enforce_fits=False).run()

print("design, die_mm2, cost_usd, gpt3_gen_s, qwen_tok_s, perf_per_usd")
base_perf = base_cost = None
for name in designs:
    g = res.get(label=f"{name}|gpt3")
    q = res.get(label=f"{name}|qwen")
    perf = 1.0 / g.latency
    if base_perf is None:
        base_perf, base_cost = perf, g.device_cost_usd
    rel_ppd = (perf / base_perf) / (g.device_cost_usd / base_cost)
    print(f"{name:28s} {g.area_mm2:7.0f} {g.device_cost_usd:8.0f} "
          f"{g.latency:10.2f} {q.throughput:10.0f} {rel_ppd:8.2f}")

print(f"\n[study] {res.stats.summary()}")
print("\npaper claims: latency design ~0.95x perf at 0.58x area (1.06x "
      "perf/$); throughput design 1.42x throughput, 3.41x perf/$ "
      "(reproduced in benchmarks/table4_designs.py)")
