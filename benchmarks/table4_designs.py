"""Paper Table IV + Figs. 10-12: the two proposed cost-effective designs.

Claims:
  C6 latency-oriented (half compute/SRAM, same HBM): ~95.3% of GA100
     performance, 42.1% smaller die, ~1.06x perf/$ (Fig. 10, 11);
  C7 throughput-oriented (512GB DDR @1TB/s, 4x systolic, half cores):
     ~1.42x throughput, ~3.41x perf/$, ~9x worse latency (Fig. 12).

Settings follow the paper: Fig. 10 = batch 16, 4-way TP, 48 GPT-3 layers;
Fig. 12 = largest batch within memory, 8-way pipeline (12 layers/device).
"""
from __future__ import annotations

from dataclasses import replace

from repro.core import area, cost, hardware as hw
from repro.core import inference_model as im
from repro.core.graph import Plan
from repro.configs import get_config

from .common import emit


def _half_gpt3(cfg):
    return replace(cfg, n_layers=48)


def _eighth_gpt3(cfg):
    return replace(cfg, n_layers=12)


def run() -> dict:
    cfg = get_config("gpt3-175b")
    ga = hw.nvidia_ga100()
    lat = hw.latency_oriented()
    thr = hw.throughput_oriented()
    checks = {}

    # ---- Fig. 10/11: latency-oriented vs GA100 (48 layers, batch 16, TP4)
    cfg48 = _half_gpt3(cfg)
    plan = Plan(tp=4)
    ratios = []
    for in_len, out_len in ((256, 256), (512, 1024), (1024, 1024),
                            (2048, 256), (256, 2048), (2048, 2048)):
        t_ga = im.generate(hw.make_system(ga, 4, 600, "fc"), cfg48, plan,
                           16, in_len, out_len).latency
        t_lat = im.generate(hw.make_system(lat, 4, 600, "fc"), cfg48, plan,
                            16, in_len, out_len).latency
        ratio = t_ga / t_lat          # normalized performance (>=: better)
        ratios.append(ratio)
        emit(f"fig10/in{in_len}_out{out_len}", t_lat * 1e6,
             f"norm_perf={ratio:.3f}")
    avg_perf = sum(ratios) / len(ratios)
    checks["latency_design_norm_perf"] = round(avg_perf, 3)   # paper 0.953
    checks["latency_perf_ok"] = 0.85 <= avg_perf <= 1.0
    # worst case should be long-input/short-output (prefill-heavy)
    checks["worst_is_prefill_heavy"] = min(ratios) == ratios[3]

    # die area + cost
    a_ga = area.device_area(ga, 600).total_mm2
    a_lat = area.device_area(lat, 600).total_mm2
    a_thr = area.device_area(thr, 600).total_mm2
    c_ga = cost.device_cost(ga, a_ga)
    c_lat = cost.device_cost(lat, a_lat)
    c_thr = cost.device_cost(thr, a_thr)
    emit("table4/area_mm2", 0.0,
         f"lat={a_lat:.0f};ga={a_ga:.0f};thr={a_thr:.0f};paper=478/826/787")
    emit("table4/cost_usd", 0.0,
         f"lat={c_lat.total_usd:.0f};ga={c_ga.total_usd:.0f};"
         f"thr={c_thr.total_usd:.0f};paper=640/711/296")
    checks["area_reduction"] = round(1 - a_lat / a_ga, 3)     # paper 0.421
    perf_cost_lat = avg_perf * c_ga.total_usd / c_lat.total_usd
    checks["latency_perf_per_cost"] = round(perf_cost_lat, 2)  # paper 1.06

    # ---- Fig. 12: throughput-oriented vs 8-GA100, PP=8, 12 layers each
    cfg12 = _eighth_gpt3(cfg)
    plan_pp = Plan(tp=1, pp=8)
    tps = {}
    lats = {}
    for dev, tag in ((ga, "ga100"), (thr, "throughput")):
        node = hw.make_system(dev, 8, 600, "fc")
        # largest batch within memory (paper: "largest batch size within
        # memory capacity"); full GPT-3 = 8 stages x 12 layers
        full_plan = Plan(tp=1, pp=8)
        b = im.max_batch(node, cfg, full_plan, 2048 + 2048)
        b = max(1, min(b, 512))
        g = im.generate(node, cfg, full_plan, b, 2048, 2048)
        tp_tok = b * 2048 / g.latency
        tps[tag] = tp_tok
        lats[tag] = g.latency / 1.0
        emit(f"fig12/{tag}", g.latency * 1e6,
             f"batch={b};tokens_per_s={tp_tok:.0f}")
    thr_x = tps["throughput"] / tps["ga100"]
    lat_x = lats["throughput"] / lats["ga100"]
    checks["throughput_gain_x"] = round(thr_x, 2)            # paper 1.42
    checks["throughput_latency_x"] = round(lat_x, 2)         # paper 9.21
    perf_cost_thr = thr_x * c_ga.total_usd / c_thr.total_usd
    checks["throughput_perf_per_cost"] = round(perf_cost_thr, 2)  # 3.41
    checks["throughput_ok"] = 1.1 <= thr_x <= 2.2
    checks["perf_cost_ok"] = 2.0 <= perf_cost_thr <= 5.0
    emit("table4/claims", 0.0,
         f"lat_norm_perf={avg_perf:.3f}(paper0.953);"
         f"lat_perf_cost={perf_cost_lat:.2f}(paper1.06);"
         f"thr_x={thr_x:.2f}(paper1.42);"
         f"thr_perf_cost={perf_cost_thr:.2f}(paper3.41)")
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
