"""Paper Table IV + Figs. 10-12: the two proposed cost-effective designs.

Claims:
  C6 latency-oriented (half compute/SRAM, same HBM): ~95.3% of GA100
     performance, 42.1% smaller die, ~1.06x perf/$ (Fig. 10, 11);
  C7 throughput-oriented (512GB DDR @1TB/s, 4x systolic, half cores):
     ~1.42x throughput, ~3.41x perf/$, ~9x worse latency (Fig. 12).

Settings follow the paper: Fig. 10 = batch 16, 4-way TP, 48 GPT-3 layers
over the paper's six in/out shapes — declared as one 2-system x 6-workload
Study grid; Fig. 12 = largest batch within memory, 8-way pipeline. Die
area/cost come from the Study's per-device pricing, and throughput goes
through the shared `throughput_from_generate` helper (pipeline-full pp
multiplier included — the seed hand-rolled `b * 2048 / latency` here and
silently dropped it).
"""
from __future__ import annotations

from dataclasses import replace

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core.graph import Plan
from repro.core.study import Case, Study
from repro.core.workload import Workload, paper_workloads
from repro.configs import get_config

from .common import emit


def _half_gpt3(cfg):
    return replace(cfg, n_layers=48)


def run() -> dict:
    cfg = get_config("gpt3-175b")
    ga = hw.nvidia_ga100()
    lat = hw.latency_oriented()
    thr = hw.throughput_oriented()
    checks = {}

    # ---- Fig. 10/11: latency-oriented vs GA100 (48 layers, batch 16, TP4)
    wls = paper_workloads(batch=16)    # the six (in, out) shapes, Fig.10 order
    res10 = Study(systems=[hw.make_system(ga, 4, 600, "fc"),
                           hw.make_system(lat, 4, 600, "fc")],
                  configs=[_half_gpt3(cfg)], plans=[Plan(tp=4)],
                  workloads=wls, enforce_fits=False).run()
    ratios = []
    for name, w in wls.items():
        t_ga = res10.get(device="nvidia-ga100", label=name).latency
        t_lat = res10.get(device="latency-oriented", label=name).latency
        ratio = t_ga / t_lat          # normalized performance (>=: better)
        ratios.append(ratio)
        emit(f"fig10/in{w.in_len}_out{w.out_len}", t_lat * 1e6,
             f"norm_perf={ratio:.3f}")
    avg_perf = sum(ratios) / len(ratios)
    checks["latency_design_norm_perf"] = round(avg_perf, 3)   # paper 0.953
    checks["latency_perf_ok"] = 0.85 <= avg_perf <= 1.0
    # worst case should be long-input/short-output (prefill-heavy)
    checks["worst_is_prefill_heavy"] = min(ratios) == ratios[3]

    # ---- Fig. 12: throughput-oriented vs 8-GA100, PP=8, 12 layers each
    plan_pp = Plan(tp=1, pp=8)
    cases12 = []
    for dev, tag in ((ga, "ga100"), (thr, "throughput")):
        node = hw.make_system(dev, 8, 600, "fc")
        # largest batch within memory (paper: "largest batch size within
        # memory capacity"); full GPT-3 = 8 stages x 12 layers
        b = im.max_batch(node, cfg, plan_pp, 2048 + 2048)
        b = max(1, min(b, 512))
        cases12.append(Case(node, cfg, plan_pp, Workload(b, 2048, 2048),
                            label=tag))
    res12 = Study(cases=cases12, enforce_fits=False).run()
    tps, lats = {}, {}
    for r in res12:
        tag = r.case.label
        tps[tag] = r.throughput        # shared helper: includes pp multiplier
        lats[tag] = r.latency
        emit(f"fig12/{tag}", r.latency * 1e6,
             f"batch={r.case.workload.batch};tokens_per_s={r.throughput:.0f}")

    # die area + cost: the Study priced each distinct device exactly once
    r_ga = res10.get(device="nvidia-ga100", label="in256_out256")
    r_lat = res10.get(device="latency-oriented", label="in256_out256")
    r_thr = res12.get(label="throughput")
    a_ga, c_ga = r_ga.area_mm2, r_ga.device_cost_usd
    a_lat, c_lat = r_lat.area_mm2, r_lat.device_cost_usd
    a_thr, c_thr = r_thr.area_mm2, r_thr.device_cost_usd
    emit("table4/area_mm2", 0.0,
         f"lat={a_lat:.0f};ga={a_ga:.0f};thr={a_thr:.0f};paper=478/826/787")
    emit("table4/cost_usd", 0.0,
         f"lat={c_lat:.0f};ga={c_ga:.0f};thr={c_thr:.0f};paper=640/711/296")
    checks["area_reduction"] = round(1 - a_lat / a_ga, 3)     # paper 0.421
    perf_cost_lat = avg_perf * c_ga / c_lat
    checks["latency_perf_per_cost"] = round(perf_cost_lat, 2)  # paper 1.06

    thr_x = tps["throughput"] / tps["ga100"]
    lat_x = lats["throughput"] / lats["ga100"]
    checks["throughput_gain_x"] = round(thr_x, 2)            # paper 1.42
    checks["throughput_latency_x"] = round(lat_x, 2)         # paper 9.21
    perf_cost_thr = thr_x * c_ga / c_thr
    checks["throughput_perf_per_cost"] = round(perf_cost_thr, 2)  # 3.41
    checks["throughput_ok"] = 1.1 <= thr_x <= 2.2
    checks["perf_cost_ok"] = 2.0 <= perf_cost_thr <= 5.0
    emit("table4/claims", 0.0,
         f"lat_norm_perf={avg_perf:.3f}(paper0.953);"
         f"lat_perf_cost={perf_cost_lat:.2f}(paper1.06);"
         f"thr_x={thr_x:.2f}(paper1.42);"
         f"thr_perf_cost={perf_cost_thr:.2f}(paper3.41)")
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
