"""ISSUE 7 acceptance benchmark: the static verifier over the shipped
matrix, plus its runtime overhead on a cold Study.

Two claims are checked:

  * zero error-severity diagnostics across every shipped
    config/plan/policy/fusion combination (`repro.verify.lint_all` — the
    same matrix `python -m repro.verify --all-configs` gates CI on); the
    counts land in the --json bench report next to the other checks;
  * verification overhead < 5% of a cold study run. Measured directly
    rather than by A/B wall-clock: the lint work the verify wiring adds to
    a cold Study (plan/policy rules per unique grid point + graph rules per
    unique graph) is timed on its own and divided by the cold study's
    uncached wall-clock, so the check is deterministic instead of riding
    run-to-run mapper-search noise.
"""
from __future__ import annotations

import time

from repro.core import result_cache
from repro.core import verify as verify_core
from repro.core.mapper import clear_matmul_cache
from repro.core.study import Study
from repro.verify import lint_all

from .common import emit
from .study_speed import _cases


def run(quick: bool = False) -> dict:
    # ---- shipped-matrix lint: the CI gate's numbers ----------------------
    t0 = time.perf_counter()
    report = lint_all(all_configs=True)
    dt_lint = time.perf_counter() - t0
    counts = {"error": 0, "warn": 0, "info": 0}
    for row in report:
        counts[row["severity"]] += 1
    emit("verify/shipped_matrix", dt_lint * 1e6,
         f"errors={counts['error']};warns={counts['warn']};"
         f"infos={counts['info']}")

    # ---- overhead on a cold study (study_speed's grid) -------------------
    cases = _cases(quick=True)
    with result_cache.disabled():
        clear_matmul_cache()
        t0 = time.perf_counter()
        Study(cases=cases, enforce_fits=False, verify="off").run()
        dt_study = time.perf_counter() - t0
        clear_matmul_cache()

    # the exact lint work the wiring adds to that run: plan+policy rules
    # once per unique grid point, graph rules once per unique graph
    points, graphs = set(), {}
    for case in cases:
        w = case.workload
        points.add((case.system, case.cfg, case.plan, case.policy,
                    w.batch, w.total_len))
        for g in Study._graphs(case):
            graphs.setdefault(case.system.device, set()).add(g)
    by_point = {p: c for c, p in zip(
        cases, ((c.system, c.cfg, c.plan, c.policy, c.workload.batch,
                 c.workload.total_len) for c in cases))}
    t0 = time.perf_counter()
    n_diags = 0
    for point in points:
        case = by_point[point]
        w = case.workload
        n_diags += len(verify_core.plan_diagnostics(
            case.system, case.cfg, case.plan, policy=case.policy,
            batch=w.batch, max_len=w.total_len, check_memory=False))
        n_diags += len(verify_core.policy_diagnostics(case.policy,
                                                      case.system.device))
    for dev, gs in sorted(graphs.items(), key=lambda kv: kv[0].name):
        for g in gs:
            n_diags += len(verify_core.graph_diagnostics(g, dev))
    dt_verify = time.perf_counter() - t0

    overhead = dt_verify / max(dt_study, 1e-9)
    emit("verify/study_overhead", dt_verify * 1e6,
         f"study_s={dt_study:.2f};verify_s={dt_verify:.4f};"
         f"overhead={overhead:.2%};graphs={sum(len(g) for g in graphs.values())};"
         f"points={len(points)};diags={n_diags}")

    return {
        "matrix_errors": counts["error"],
        "matrix_warns": counts["warn"],
        "matrix_infos": counts["info"],
        "zero_errors": counts["error"] == 0,
        "zero_warns": counts["warn"] == 0,
        "lint_seconds": round(dt_lint, 2),
        "study_seconds": round(dt_study, 2),
        "verify_seconds": round(dt_verify, 4),
        "overhead_ratio": round(overhead, 4),
        "overhead_under_5pct": overhead < 0.05,
    }


if __name__ == "__main__":
    print("CHECKS:", run())
