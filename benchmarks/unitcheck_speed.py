"""Dimensional-analysis pass over the shipped tree: correctness + speed.

Three claims are checked:

  * `python -m repro.unitcheck src/repro/core` (the CI gate) reports zero
    diagnostics on the shipped pricing core;
  * every registered rule fires on its built-in sample mutant
    (`registry_selfcheck` — the same proof the mutant test suite runs);
  * a full-tree pass (src/repro/core + benchmarks + examples parsed
    together) stays under 10 seconds, so the gate never becomes the slow
    step of CI. The whole-tree figure is the honest upper bound: the
    checker's two-pass design re-reads every file per invocation, there is
    no incremental mode to hide behind.
"""
from __future__ import annotations

import pathlib
import time

from repro.core import unitcheck

from .common import emit

_ROOT = pathlib.Path(__file__).resolve().parents[1]
_CORE = _ROOT / "src" / "repro" / "core"


def run(quick: bool = False) -> dict:
    # ---- the CI gate: shipped core is clean ------------------------------
    t0 = time.perf_counter()
    diags = unitcheck.check_paths([str(_CORE)])
    dt_core = time.perf_counter() - t0
    errors = [d for d in diags if d.severity == "error"]
    emit("unitcheck/core", dt_core * 1e6,
         f"diags={len(diags)};errors={len(errors)};rules={len(unitcheck.RULES)}")

    # ---- every rule proves itself on its sample mutant -------------------
    t0 = time.perf_counter()
    per_rule = unitcheck.registry_diagnostics()
    dt_self = time.perf_counter() - t0
    uncaught = sorted(r for r, ds in per_rule.items() if not ds)
    emit("unitcheck/selfcheck", dt_self * 1e6,
         f"rules={len(per_rule)};uncaught={len(uncaught)}")

    # ---- full-tree speed: core + benchmarks + examples in one table ------
    targets = [str(_CORE), str(_ROOT / "src" / "repro"),
               str(_ROOT / "benchmarks"), str(_ROOT / "examples")]
    t0 = time.perf_counter()
    tree_diags = unitcheck.check_paths(targets)
    dt_tree = time.perf_counter() - t0
    tree_errors = [d for d in tree_diags if d.severity == "error"]
    emit("unitcheck/full_tree", dt_tree * 1e6,
         f"seconds={dt_tree:.3f};diags={len(tree_diags)};"
         f"errors={len(tree_errors)}")

    return {
        "core_diags": len(diags),
        "core_clean": not errors,
        "rules_total": len(unitcheck.RULES),
        "all_rules_fire": not uncaught,
        "tree_errors": len(tree_errors),
        "tree_clean": not tree_errors,
        "tree_seconds": round(dt_tree, 3),
        "tree_under_10s": dt_tree < 10.0,
    }


if __name__ == "__main__":
    print("CHECKS:", run())
