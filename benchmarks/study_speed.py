"""ISSUE 2 acceptance benchmark: the declarative Study front-door vs the
loops it replaces.

A 5-design x 2-model x 3-workload grid (30 generate cases) is run three ways:

  study     — ONE Study: shared Evaluator per System, every unique
              (device, GEMM shape) pair pre-solved in a single device-axis
              stacked mapper search;
  loop      — the pre-Study hand-rolled per-System loop: a cold default
              Evaluator per `im.generate` call (what the benchmarks actually
              did before this API), sharing only the global matmul memo;
  seed path — the same loop with per-shape dense-search Evaluators
              (use_reference_mapper=True, no batching, no memo): the seed
              commit's cost, and the ISSUE 2 acceptance baseline.

Every CaseResult latency must match both baselines bit-for-bit; the
wall-clock ratios and cache statistics are the acceptance numbers.

ISSUE 6 adds the warm-rerun measurement: the same grid run again against the
persistent CaseResult cache (private temp dir) must be >= 10x faster than the
cold run (>= 5x in --quick's shrunken grid, where fixed overhead dominates)
and bit-identical — the regression threshold is a hard claim check, so a
cache-layer slowdown fails CI.

ISSUE 10 adds the cold-path measurement: the same grid fully uncached, run
(a) with pruning off, serial — the exhaustive baseline; (b) with pruning
on, serial; (c) with pruning on across `workers=` process shards. Rows
must be identical across all three (CI-asserted), the pruned search must
evaluate strictly fewer candidate rows than the exhaustive one
(CI-asserted), and `cold_speedup_x` = (a)/(c) carries the >= 3x acceptance
claim — gated on hosts with >= 4 cores, where the parallel win exists.
"""
from __future__ import annotations

import os
import tempfile
import time

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core import obs
from repro.core import result_cache
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan
from repro.core.mapper import (clear_matmul_cache, get_mapper_prune,
                               set_mapper_prune)
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config

from .common import emit

DESIGNS = "ABCDE"                       # paper Table III compute designs
MODELS = ("qwen2-0.5b", "qwen3-1.7b")
WORKLOADS = {
    "chat": Workload(8, 2048, 256),
    "short": Workload(16, 256, 256),
    "longgen": Workload(4, 512, 1024),
}
PLAN = Plan(tp=1, dp=4)


def _cases(quick: bool = False):
    designs = DESIGNS[:2] if quick else DESIGNS
    models = MODELS[:1] if quick else MODELS
    wl = dict(list(WORKLOADS.items())[:2]) if quick else WORKLOADS
    return [Case(hw.make_system(hw.compute_design(d), 4, 600, "fc"),
                 get_config(m), PLAN, w, label=f"{d}/{m}/{name}")
            for d in designs for m in models
            for name, w in sorted(wl.items())]


def _generate(case, evaluator):
    w = case.workload
    return im.generate(case.system, case.cfg, case.plan, w.batch, w.in_len,
                       w.out_len, samples=w.samples, evaluator=evaluator)


def run(quick: bool = False) -> dict:
    cases = _cases(quick)

    with result_cache.disabled():       # three honest uncached timings
        # ---- Study path: one declarative grid -----------------------------
        clear_matmul_cache()
        t0 = time.perf_counter()
        res = Study(cases=cases, enforce_fits=False).run()
        dt_study = time.perf_counter() - t0

        # ---- pre-Study loop: cold default Evaluator per call, warm memo ---
        clear_matmul_cache()
        t0 = time.perf_counter()
        loop = [_generate(c, Evaluator(c.system)) for c in cases]
        dt_loop = time.perf_counter() - t0

        # ---- seed path: per-shape dense-search Evaluator per case ---------
        t0 = time.perf_counter()
        seed = [_generate(c, Evaluator(c.system, use_reference_mapper=True))
                for c in cases]
        dt_seed = time.perf_counter() - t0
        clear_matmul_cache()

    # ---- persistent layer: cold grid, then warm rerun (ISSUE 6) -----------
    with tempfile.TemporaryDirectory(prefix="repro-bench-cache-") as tmp:
        with result_cache.overridden(root=tmp, enabled=True):
            clear_matmul_cache()
            t0 = time.perf_counter()
            cold = Study(cases=cases, enforce_fits=False).run()
            dt_cold = time.perf_counter() - t0
            clear_matmul_cache()        # warm rerun = a fresh process
            t0 = time.perf_counter()
            warm = Study(cases=cases, enforce_fits=False).run()
            dt_warm = time.perf_counter() - t0
            clear_matmul_cache(disk=True)
    warm_speedup = dt_cold / max(dt_warm, 1e-9)
    warm_exact = all(c.latency == w.latency and c.throughput == w.throughput
                     for c, w in zip(cold, warm))
    # quick's shrunken grid carries relatively more fixed overhead — the
    # asserted floor drops to 5x there; the acceptance claim is the full 10x
    warm_floor = 5.0 if quick else 10.0

    # ---- ISSUE 10 cold path: pruned search + parallel shards --------------
    reg = obs.metrics()
    workers = 2 if quick else (os.cpu_count() or 1)
    prev_prune = get_mapper_prune()

    def _cold_run(prune, n_workers):
        set_mapper_prune(prune)
        clear_matmul_cache()
        base = reg.counter("mapper.rows_evaluated")
        t0 = time.perf_counter()
        r = Study(cases=cases, enforce_fits=False).run(workers=n_workers)
        dt = time.perf_counter() - t0
        rows = reg.counter("mapper.rows_evaluated") - base
        return r, dt, rows

    with result_cache.disabled():
        try:
            res_off, dt_cold_off, rows_off = _cold_run("off", None)
            res_on, dt_cold_on, rows_on = _cold_run("on", None)
            res_par, dt_cold_par, _ = _cold_run("on", workers)
        finally:
            set_mapper_prune(prev_prune)
            clear_matmul_cache()
    prune_rows_identical = res_on.to_rows() == res_off.to_rows()
    parallel_rows_identical = res_par.to_rows() == res_off.to_rows()
    prune_speedup = dt_cold_off / max(dt_cold_on, 1e-9)
    cold_speedup = dt_cold_off / max(dt_cold_par, 1e-9)
    emit("study_speed/cold_path", dt_cold_par * 1e6,
         f"off_s={dt_cold_off:.2f};prune_s={dt_cold_on:.2f};"
         f"par_s={dt_cold_par:.2f};workers={workers};"
         f"prune={prune_speedup:.2f}x;cold={cold_speedup:.2f}x;"
         f"rows={rows_on:.0f}/{rows_off:.0f}")

    exact = all(r.latency == a.latency == b.latency == c.latency
                for r, a, b, c in zip(res, loop, seed, cold))
    speedup_loop = dt_loop / max(dt_study, 1e-9)
    speedup_seed = dt_seed / max(dt_study, 1e-9)
    emit("study_speed/grid", dt_study * 1e6,
         f"cases={len(cases)};study_s={dt_study:.2f};loop_s={dt_loop:.2f};"
         f"seed_s={dt_seed:.2f};vs_loop={speedup_loop:.1f}x;"
         f"vs_seed={speedup_seed:.1f}x")
    emit("study_speed/warm_rerun", dt_warm * 1e6,
         f"cold_s={dt_cold:.2f};warm_s={dt_warm:.4f};"
         f"speedup={warm_speedup:.0f}x;"
         f"case_hits={warm.stats.case_cache_hits}")
    emit("study_speed/study_stats", 0.0,
         res.stats.summary().replace(" ", ";"))
    for system, ev in res.evaluators.items():
        emit(f"study_speed/evaluator_{system.device.name}", 0.0,
             ev.stats.summary().replace(" ", ";"))
    return {
        "cases": len(cases),
        "study_seconds": round(dt_study, 2),
        "loop_seconds": round(dt_loop, 2),
        "seed_loop_seconds": round(dt_seed, 2),
        "speedup_vs_loop_x": round(speedup_loop, 2),
        "speedup_vs_seed_x": round(speedup_seed, 2),
        "unique_matmul_pairs": res.stats.matmul_pairs_presolved,
        "bitwise_equal_to_both_baselines": exact,
        "faster_than_seed_loop": dt_seed > dt_study,
        "warm_rerun_speedup_x": round(warm_speedup, 1),
        "warm_rerun_bitwise_equal": warm_exact,
        "warm_rerun_fast_enough": warm_speedup >= warm_floor,
        # ISSUE 10 cold path (all CI-asserted except the host-gated target)
        "cold_workers": workers,
        "prune_candidates_unpruned": int(rows_off),
        "prune_candidates_evaluated": int(rows_on),
        "prune_rows_identical": prune_rows_identical,
        "parallel_rows_identical": parallel_rows_identical,
        "prune_speedup_x": round(prune_speedup, 2),
        "cold_speedup_x": round(cold_speedup, 2),
        # the >= 3x acceptance claim needs real cores to shard across; on
        # small hosts the identity checks above still gate correctness
        "cold_speedup_target_ok": cold_speedup >= 3.0
        or (os.cpu_count() or 1) < 4,
    }


if __name__ == "__main__":
    print("CHECKS:", run())
