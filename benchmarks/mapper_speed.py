"""Paper Sec. I / C8: simulation speed.

Paper: simulating a 4-A100 node running GPT-3 175B inference takes 15-16
minutes on one Xeon core, including 26,400 mapper search rounds. Our
evaluator deduplicates specs across the whole workload and solves every
unique GEMM shape in one stacked, infeasible-candidate-compressed broadcast
(mapper.matmul_perf_batch) — this benchmark measures the same workload
end-to-end cold, reports the speedup versus the paper AND versus the seed
path (per-shape dense broadcast search, matmul_perf_reference)."""
from __future__ import annotations

import time

from repro.core import hardware as hw
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan, build_model
from repro.core.mapper import clear_matmul_cache

from .common import emit


def _workload(cfg, plan):
    """Full GPT-3 inference sim: prefill + decode at several KV depths
    (the paper's workload: batch 8, input 2048, generating 1024 tokens)."""
    return [build_model(cfg, plan, batch=8, seq=2048, kv_len=2048)] + \
        [build_model(cfg, plan, batch=8, seq=1, kv_len=2048 + k)
         for k in (1, 256, 512, 768, 1024)]


def run() -> dict:
    from repro.configs import get_config
    cfg = get_config("gpt3-175b")
    node = hw.dgx_a100(4)
    plan = Plan(tp=4)
    graphs = _workload(cfg, plan)

    # ---- new path: one shared evaluator, one batched mapper search -------
    clear_matmul_cache()
    ev = Evaluator(node)
    t0 = time.perf_counter()
    costs = ev.evaluate_many(graphs)
    dt = time.perf_counter() - t0

    # ---- seed path: per-shape dense search, eager walk --------------------
    clear_matmul_cache()
    seed_ev = Evaluator(node, use_reference_mapper=True)
    t0 = time.perf_counter()
    seed_costs = seed_ev.evaluate_many(graphs)
    dt_seed = time.perf_counter() - t0
    clear_matmul_cache()

    exact = all(abs(a.latency - b.latency) <= 1e-12 * abs(b.latency)
                for a, b in zip(costs, seed_costs))

    emit("mapper/gpt3_4xA100_full_sim", dt * 1e6,
         f"seconds={dt:.2f};paper_seconds=930;"
         f"speedup_vs_paper={930 / max(dt, 1e-9):.0f}x;"
         f"seed_path_seconds={dt_seed:.2f};"
         f"speedup_vs_seed={dt_seed / max(dt, 1e-9):.1f}x;"
         f"unique_matmuls={ev.stats.matmul_searches}")
    emit("mapper/evaluator_stats", 0.0, ev.stats.summary().replace(" ", ";"))
    pf, dcs = costs[0], costs[1:]
    # graphs are whole-model (all 96 layers via node repeats) — no extra x96
    dec_ms = sum(d.latency for d in dcs) / len(dcs) * 1e3
    emit("mapper/gpt3_predictions", 0.0,
         f"prefill_s={pf.latency:.3f};decode_ms_per_tok={dec_ms:.1f}")
    return {"sim_seconds": round(dt, 2),
            "speedup_vs_paper": round(930 / max(dt, 1e-9)),
            "speedup_vs_seed_path": round(dt_seed / max(dt, 1e-9), 1),
            "matches_seed_path": exact,
            "faster_than_paper": dt < 930,
            "faster_than_seed_path": dt < dt_seed}


if __name__ == "__main__":
    print("CHECKS:", run())
