"""Paper Sec. I / C8: simulation speed.

Paper: simulating a 4-A100 node running GPT-3 175B inference takes 15-16
minutes on one Xeon core, including 26,400 mapper search rounds. Our
evaluator deduplicates specs across the whole workload and solves every
unique GEMM shape in one stacked, infeasible-candidate-compressed broadcast
(mapper.matmul_perf_batch) — this benchmark measures the same workload
end-to-end cold, reports the speedup versus the paper AND versus the seed
path (per-shape dense broadcast search, matmul_perf_reference).

ISSUE 6 additions: the same workload is also timed on the JAX chunk backend
(one jitted XLA kernel per padding bucket, numerically gated against the
numpy path), and through the persistent disk layer — cold populate versus a
warm process-restart replay (in-memory memo dropped, disk entries hit) in a
private temp directory so the user's real cache is never touched.

ISSUE 10 addition: the same presolve with pruning off vs on — the
lower-bound cutoff must cut the evaluated candidate rows by >= 50% on this
workload (acceptance claim) with bit-identical latencies.
"""
from __future__ import annotations

import tempfile
import time

from repro.core import hardware as hw
from repro.core import obs
from repro.core import result_cache
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan, build_model
from repro.core.mapper import (clear_matmul_cache, get_mapper_prune,
                               matmul_cache_stats, reset_matmul_cache_stats,
                               set_mapper_backend, set_mapper_prune)

from .common import emit


def _workload(cfg, plan):
    """Full GPT-3 inference sim: prefill + decode at several KV depths
    (the paper's workload: batch 8, input 2048, generating 1024 tokens)."""
    return [build_model(cfg, plan, batch=8, seq=2048, kv_len=2048)] + \
        [build_model(cfg, plan, batch=8, seq=1, kv_len=2048 + k)
         for k in (1, 256, 512, 768, 1024)]


def _timed_eval(node, graphs):
    clear_matmul_cache()
    ev = Evaluator(node)
    t0 = time.perf_counter()
    costs = ev.evaluate_many(graphs)
    return time.perf_counter() - t0, costs, ev


def run() -> dict:
    from repro.configs import get_config
    cfg = get_config("gpt3-175b")
    node = hw.dgx_a100(4)
    plan = Plan(tp=4)
    graphs = _workload(cfg, plan)
    checks = {}

    with result_cache.disabled():       # honest cold timings, always
        # ---- new path: one shared evaluator, one batched mapper search ---
        dt, costs, ev = _timed_eval(node, graphs)

        # ---- seed path: per-shape dense search, eager walk ---------------
        clear_matmul_cache()
        seed_ev = Evaluator(node, use_reference_mapper=True)
        t0 = time.perf_counter()
        seed_costs = seed_ev.evaluate_many(graphs)
        dt_seed = time.perf_counter() - t0

        exact = all(abs(a.latency - b.latency) <= 1e-12 * abs(b.latency)
                    for a, b in zip(costs, seed_costs))

        # ---- ISSUE 10: candidate pruning off vs on on this presolve ------
        reg = obs.metrics()
        prev_prune = get_mapper_prune()
        try:
            set_mapper_prune("off")
            base = reg.counter("mapper.rows_evaluated")
            dt_off, off_costs, _ = _timed_eval(node, graphs)
            rows_off = reg.counter("mapper.rows_evaluated") - base
            set_mapper_prune("on")
            base = reg.counter("mapper.rows_evaluated")
            dt_on, on_costs, _ = _timed_eval(node, graphs)
            rows_on = reg.counter("mapper.rows_evaluated") - base
        finally:
            set_mapper_prune(prev_prune)
        prune_exact = all(a.latency == b.latency
                          for a, b in zip(on_costs, off_costs))
        prune_cut_pct = 100.0 * (1.0 - rows_on / max(rows_off, 1.0))

        # ---- JAX chunk backend: trace-included cold, then warm-trace -----
        try:
            set_mapper_backend("jax")
        except ImportError:
            jax_ok = None
            dt_jax_cold = dt_jax = float("nan")
        else:
            try:
                dt_jax_cold, jax_costs, _ = _timed_eval(node, graphs)
                dt_jax, jax_costs, _ = _timed_eval(node, graphs)
                # no reductions in the table math: only FMA contraction can
                # move a latency, and only by its last ulp
                jax_ok = all(
                    abs(a.latency - b.latency) <= 1e-9 * abs(b.latency)
                    for a, b in zip(jax_costs, costs))
            finally:
                set_mapper_backend("numpy")

    # ---- persistent layer: cold populate vs process-restart replay -------
    with tempfile.TemporaryDirectory(prefix="repro-bench-cache-") as tmp:
        with result_cache.overridden(root=tmp, enabled=True):
            clear_matmul_cache(disk=True)
            reset_matmul_cache_stats()
            dt_cold, cold_costs, _ = _timed_eval(node, graphs)
            # clear_matmul_cache() drops only the in-memory memo — the next
            # run replays a "new process" against the same disk entries
            dt_disk, disk_costs, _ = _timed_eval(node, graphs)
            ms = matmul_cache_stats()
            disk_exact = all(a.latency == b.latency
                             for a, b in zip(disk_costs, cold_costs))
            clear_matmul_cache(disk=True)
    disk_speedup = dt_cold / max(dt_disk, 1e-9)

    emit("mapper/gpt3_4xA100_full_sim", dt * 1e6,
         f"seconds={dt:.2f};paper_seconds=930;"
         f"speedup_vs_paper={930 / max(dt, 1e-9):.0f}x;"
         f"seed_path_seconds={dt_seed:.2f};"
         f"speedup_vs_seed={dt_seed / max(dt, 1e-9):.1f}x;"
         f"unique_matmuls={ev.stats.matmul_searches}")
    emit("mapper/evaluator_stats", 0.0, ev.stats.summary().replace(" ", ";"))
    emit("mapper/jax_backend", dt_jax * 1e6,
         f"numpy_s={dt:.2f};jax_cold_s={dt_jax_cold:.2f};"
         f"jax_warm_trace_s={dt_jax:.2f};"
         f"jax_vs_numpy={dt / max(dt_jax, 1e-9):.1f}x")
    emit("mapper/disk_cache", dt_disk * 1e6,
         f"cold_s={dt_cold:.3f};warm_disk_s={dt_disk:.4f};"
         f"speedup={disk_speedup:.0f}x;disk_hits={ms.disk_hits}")
    emit("mapper/prune", dt_on * 1e6,
         f"off_s={dt_off:.2f};on_s={dt_on:.2f};"
         f"speedup={dt_off / max(dt_on, 1e-9):.2f}x;"
         f"rows={rows_on:.0f}/{rows_off:.0f};cut={prune_cut_pct:.1f}%")
    pf, dcs = costs[0], costs[1:]
    # graphs are whole-model (all 96 layers via node repeats) — no extra x96
    dec_ms = sum(d.latency for d in dcs) / len(dcs) * 1e3
    emit("mapper/gpt3_predictions", 0.0,
         f"prefill_s={pf.latency:.3f};decode_ms_per_tok={dec_ms:.1f}")
    checks.update({
        "sim_seconds": round(dt, 2),
        "speedup_vs_paper": round(930 / max(dt, 1e-9)),
        "speedup_vs_seed_path": round(dt_seed / max(dt, 1e-9), 1),
        "matches_seed_path": exact,
        "faster_than_paper": dt < 930,
        "faster_than_seed_path": dt < dt_seed,
        "jax_matches_numpy": jax_ok,
        "jax_warm_trace_seconds": round(dt_jax, 2),
        "disk_warm_speedup_x": round(disk_speedup, 1),
        "disk_warm_bitwise_equal": disk_exact,
        "disk_warm_faster_10x": disk_speedup >= 10,
        # ISSUE 10 acceptance: pruning alone cuts >= 50% of the candidate
        # rows on the GPT-3 presolve, bit-identically
        "prune_candidates_unpruned": int(rows_off),
        "prune_candidates_evaluated": int(rows_on),
        "prune_candidates_reduction_pct": round(prune_cut_pct, 1),
        "prune_cut_at_least_half": prune_cut_pct >= 50.0,
        "prune_bitwise_equal": prune_exact,
        "prune_speedup_x": round(dt_off / max(dt_on, 1e-9), 2),
    })
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
