"""Paper Sec. I / C8: simulation speed.

Paper: simulating a 4-A100 node running GPT-3 175B inference takes 15-16
minutes on one Xeon core, including 26,400 mapper search rounds. Our
mapper evaluates the whole candidate space as one numpy broadcast — this
benchmark measures the same workload end-to-end and reports the speedup
(a beyond-paper improvement recorded in EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import time

from repro.core import hardware as hw
from repro.core.graph import Plan, model_ops
from repro.core.mapper import matmul_perf
from repro.configs import get_config

from .common import emit


def run() -> dict:
    matmul_perf.cache_clear()
    cfg = get_config("gpt3-175b")
    node = hw.dgx_a100(4)
    plan = Plan(tp=4)
    t0 = time.perf_counter()
    # full GPT-3 inference sim: prefill + decode at several KV depths
    # (the paper's workload: batch 8, input 2048, generating 1024 tokens)
    pf = model_ops(cfg, node, plan, batch=8, seq=2048, kv_len=2048)
    dcs = [model_ops(cfg, node, plan, batch=8, seq=1, kv_len=2048 + k)
           for k in (1, 256, 512, 768, 1024)]
    dt = time.perf_counter() - t0
    ci = matmul_perf.cache_info()
    emit("mapper/gpt3_4xA100_full_sim", dt * 1e6,
         f"seconds={dt:.1f};paper_seconds=930;speedup={930 / max(dt, 1e-9):.0f}x;"
         f"unique_matmuls={ci.misses}")
    dec_ms = sum(d.latency for d in dcs) / len(dcs) * 96 * 1e3
    emit("mapper/gpt3_predictions", 0.0,
         f"prefill_s={pf.latency * 96 / 96:.3f}x96layers;"
         f"decode_ms_per_tok={dec_ms:.1f}")
    return {"sim_seconds": round(dt, 2),
            "speedup_vs_paper": round(930 / max(dt, 1e-9)),
            "faster_than_paper": dt < 930}


if __name__ == "__main__":
    print("CHECKS:", run())
