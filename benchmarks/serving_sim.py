"""ISSUE 3 acceptance benchmark: the trace-driven serving simulator.

Two claims on the A100 system:

  consistency — a constant-arrival, uniform-length trace with one admission
                wave (continuous batching has nothing to refill) must
                reproduce the closed-form `inference_model.generate` /
                `throughput` numbers within 1%, from ONE shared stacked
                mapper search (no per-step re-search);
  scheduling  — on a bursty Poisson trace, continuous batching must beat
                static batching on p99 TTFT and goodput; the benchmark
                prints TTFT/TPOT p50/p99 + goodput for both policies.
"""
from __future__ import annotations

import time

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan
from repro.core.mapper import clear_matmul_cache
from repro.core.simulator import simulate
from repro.core.workload import Trace, TrafficWorkload

from repro.configs import get_config

from .common import emit

MODEL = "qwen3-1.7b"


def _emit_sim(name: str, r) -> None:
    emit(f"serving_sim/{name}", r.makespan * 1e6,
         f"goodput={r.goodput:.1f};ttft_p50={r.ttft(50):.5f};"
         f"ttft_p99={r.ttft(99):.5f};tpot_p50={r.tpot(50):.6f};"
         f"tpot_p99={r.tpot(99):.6f};occ={r.mean_occupancy:.2f};"
         f"waves={r.waves};rounds={r.rounds}")


def run(quick: bool = False) -> dict:
    cfg = get_config(MODEL)
    system = hw.make_system(hw.nvidia_a100(), 1)
    plan = Plan()
    slots = 4 if quick else 8
    in_len, out_len = (128, 32) if quick else (512, 128)

    # ONE Evaluator for everything below: the uniform-trace replay, the
    # generate()/throughput() oracle AND both policy replays share its spec
    # cache, so each distinct traffic shape costs one stacked search total
    clear_matmul_cache()
    ev = Evaluator(system)

    # ---- consistency: one uniform wave vs generate()/throughput() --------
    uniform = TrafficWorkload.from_trace(
        Trace.constant(slots, 0.0, in_len, out_len), slots=slots)
    t0 = time.perf_counter()
    r_uni = simulate(system, cfg, plan, uniform, evaluator=ev)
    dt_sim = time.perf_counter() - t0
    searches_uniform = ev.stats.batched_searches
    g = im.generate(system, cfg, plan, slots, in_len, out_len, evaluator=ev)
    thr = im.throughput(system, cfg, plan, slots, in_len, out_len,
                        evaluator=ev)
    e2e_err = abs(r_uni.e2e(50) - g.latency) / g.latency
    thr_err = abs(r_uni.goodput - thr) / thr
    _emit_sim("uniform_wave", r_uni)
    emit("serving_sim/consistency", dt_sim * 1e6,
         f"gen_s={g.latency:.4f};sim_e2e_s={r_uni.e2e(50):.4f};"
         f"e2e_rel_err={e2e_err:.2e};thr_rel_err={thr_err:.2e};"
         f"stacked_searches={searches_uniform}")

    # ---- scheduling: static vs continuous on a Poisson trace -------------
    n_req = 24 if quick else 64
    rate = 20.0 if quick else 16.0      # past saturation: scheduling matters
    trace = Trace.poisson(n_req, rate=rate, in_len=(in_len // 4, in_len),
                          out_len=(out_len // 4, out_len), seed=7)
    results = {}
    for policy in ("static", "continuous"):
        w = TrafficWorkload.from_trace(trace, slots=slots, policy=policy)
        results[policy] = simulate(system, cfg, plan, w, evaluator=ev)
        _emit_sim(f"poisson_{policy}", results[policy])
    st, ct = results["static"], results["continuous"]
    emit("serving_sim/continuous_vs_static", 0.0,
         f"goodput_gain={ct.goodput / st.goodput:.2f}x;"
         f"ttft_p99_gain={st.ttft(99) / ct.ttft(99):.2f}x;"
         f"stacked_searches_total={ev.stats.batched_searches}")
    clear_matmul_cache()

    conserved = (r_uni.tokens_out == slots * out_len
                 and st.tokens_out == ct.tokens_out == trace.tokens_out)
    return {
        "e2e_rel_err": round(e2e_err, 6),
        "thr_rel_err": round(thr_err, 6),
        "consistency_within_1pct": e2e_err < 0.01 and thr_err < 0.01,
        # uniform replay = 1 stacked search; generate() reuses it (0 more);
        # the Poisson trace adds 1; its second policy reuses that (0 more)
        "single_stacked_search": searches_uniform == 1,
        "one_search_per_traffic_shape": ev.stats.batched_searches == 2,
        "tokens_conserved": conserved,
        "continuous_beats_static_goodput": ct.goodput >= st.goodput,
        "continuous_beats_static_ttft_p99": ct.ttft(99) <= st.ttft(99),
    }


if __name__ == "__main__":
    print("CHECKS:", run())
