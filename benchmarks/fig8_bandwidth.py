"""Paper Fig. 8: memory-bandwidth sweep 400 -> 3200 GB/s on the A100-like
base design.

Claims (C4): prefill gains ~14.3% from 800->2000 GB/s then flattens
(+3.5% to 3200); decode speeds up 1.88x from 800->2000 and +26% more to
3200; implication (3): decoding is much more bandwidth-sensitive.

One Study over the eight bandwidth variants (layer stage): every variant's
GEMM shapes go through one device-axis stacked mapper search."""
from __future__ import annotations

from dataclasses import replace

from repro.core import hardware as hw
from repro.core.graph import Plan
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config

from .common import emit

BANDWIDTHS_GBPS = (400, 800, 1200, 1600, 2000, 2400, 2800, 3200)


def run() -> dict:
    cfg = get_config("gpt3-175b")
    plan = Plan(tp=4)
    wl = Workload(8, 2048, 1024)    # prefill@2048, decode@kv 3072
    base = hw.nvidia_a100()
    study = Study(cases=[
        Case(hw.make_system(
            replace(base, main_memory=replace(base.main_memory,
                                              bandwidth_bytes=bw * 1e9)),
            4, 600, "fc"), cfg, plan, wl, stage="layer", label=str(bw))
        for bw in BANDWIDTHS_GBPS], enforce_fits=False)
    lat = {}
    for r in study.run():
        bw = int(r.case.label)
        lat[bw] = (r.prefill_latency, r.decode_latency)
        emit(f"fig8/bw{bw}_prefill", r.prefill_latency * 1e6,
             f"ms={r.prefill_latency * 1e3:.2f}")
        emit(f"fig8/bw{bw}_decode", r.decode_latency * 1e6,
             f"ms={r.decode_latency * 1e3:.4f}")
    pf_gain = lat[800][0] / lat[2000][0]
    pf_tail = lat[2000][0] / lat[3200][0]
    dc_gain = lat[800][1] / lat[2000][1]
    dc_tail = lat[2000][1] / lat[3200][1]
    checks = {
        "prefill_800_2000_x": round(pf_gain, 3),       # paper: 1.167
        "prefill_2000_3200_x": round(pf_tail, 3),      # paper: 1.035
        "decode_800_2000_x": round(dc_gain, 3),        # paper: 1.88
        "decode_2000_3200_x": round(dc_tail, 3),       # paper: 1.26
        "decode_more_sensitive": dc_gain > pf_gain * 1.3,
        "prefill_flattens": pf_tail < 1.12,
    }
    emit("fig8/claims", 0.0,
         f"pf_800to2000={pf_gain:.2f}x(paper1.17);"
         f"dc_800to2000={dc_gain:.2f}x(paper1.88)")
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
