"""Paper Fig. 5: operator-level performance model across input sizes on
A100 / MI210 / TPUv3, plus GPT-3-layer prefill/decode on the 4-A100 node.

Without lab hardware we validate the paper's *qualitative* claims that a
roofline model cannot reproduce:
  (a) matmul throughput ramps with M and saturates below peak (Fig. 5a);
  (b) LayerNorm throughput DROPS at extreme reduction dims (Fig. 5d);
  (c) predicted latencies sit between the roofline bound and 3x of it for
      large compute-bound shapes (interpretability without fudge factors);
  (d) prefill/decode per-layer latencies land in the measured range of
      Fig. 5h/5i (tens of ms / sub-ms).
"""
from __future__ import annotations

from repro.core import hardware as hw
from repro.core import operators as ops
from repro.core import interconnect as net
from repro.core import roofline
from repro.core.graph import Plan
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config

from .common import emit


def run() -> dict:
    a100 = hw.nvidia_a100()
    mi210 = hw.amd_mi210()
    tpu = hw.google_tpu_v3()
    out = {}

    # (a) Matmul MxKxN, K=N=12288 (GPT-3 d_model), sweep M  [Fig. 5a]
    tflops = []
    for m in (16, 64, 256, 1024, 4096, 16384):
        r = ops.matmul(a100, m, 12288, 12288)
        tf = r.flops / r.latency / 1e12
        rf = roofline.matmul_roofline(a100, m, 12288, 12288)
        emit(f"fig5a/matmul_m{m}_a100", r.latency * 1e6,
             f"TFLOPS={tf:.1f};roofline_s={rf.latency:.2e};bound={r.bound}")
        tflops.append(tf)
    out["matmul_monotonic"] = all(b >= a * 0.7 for a, b in
                                  zip(tflops, tflops[1:]))
    out["matmul_below_peak"] = tflops[-1] <= a100.peak_matmul_flops / 1e12
    out["matmul_saturates"] = tflops[-1] > 0.5 * a100.peak_matmul_flops / 1e12

    # (b) Softmax (M x N, softmax over N)  [Fig. 5b]
    for n in (512, 2048, 8192, 32768):
        r = ops.softmax(a100, 32768, n)
        emit(f"fig5b/softmax_n{n}_a100", r.latency * 1e6,
             f"GBps={r.main_memory_bytes / r.latency / 1e9:.0f};bound={r.bound}")

    # (d) LayerNorm: throughput dropping at extreme reduction dim [Fig. 5d]
    thr = []
    for n in (1024, 8192, 65536, 524288, 4 * 1024 * 1024):
        rows = max(8, (1 << 25) // n)
        r = ops.layernorm(a100, rows, n)
        gbps = rows * n * 4 / r.latency / 1e9
        thr.append(gbps)
        emit(f"fig5d/layernorm_n{n}_a100", r.latency * 1e6,
             f"GBps={gbps:.0f};bound={r.bound}")
    out["layernorm_drops"] = thr[-1] < max(thr) * 0.9

    # (e) GELU  [Fig. 5e]
    for n in (1 << 20, 1 << 24):
        r = ops.gelu(a100, n)
        emit(f"fig5e/gelu_{n}_a100", r.latency * 1e6, f"bound={r.bound}")

    # (f) all-reduce on the 4-A100 node [Fig. 5f]
    node = hw.dgx_a100(4)
    for mb in (1, 16, 256):
        r = net.all_reduce(node, mb * 2 ** 20)
        emit(f"fig5f/allreduce_{mb}MB_4xA100", r.latency * 1e6,
             f"busbw_GBps={2 * (4 - 1) / 4 * mb * 2 ** 20 / r.latency / 1e9:.0f}")

    # (g) cross-device comparison: same matmul on MI210 / TPUv3
    for dev, tag in ((mi210, "mi210"), (tpu, "tpuv3")):
        r = ops.matmul(dev, 4096, 12288, 12288)
        emit(f"fig5g/matmul_4096_{tag}", r.latency * 1e6,
             f"TFLOPS={r.flops / r.latency / 1e12:.1f}")

    # (h, i) GPT-3 layer prefill & decode on 4xA100 TP  [Fig. 5h/5i]
    # one declarative layer-stage case: prefill@2048, decode@kv 3072
    cfg = get_config("gpt3-175b")
    r = Study(cases=[Case(node, cfg, Plan(tp=4), Workload(8, 2048, 1024),
                          stage="layer")], enforce_fits=False).run()[0]
    emit("fig5h/gpt3_prefill_layer_4xA100", r.prefill_latency * 1e6,
         f"paper_range_ms=30-80;ours_ms={r.prefill_latency * 1e3:.1f}")
    emit("fig5i/gpt3_decode_layer_4xA100", r.decode_latency * 1e6,
         f"paper_range_ms=0.3-1.5;ours_ms={r.decode_latency * 1e3:.3f}")
    out["prefill_in_range"] = 0.020 <= r.prefill_latency <= 0.110
    out["decode_in_range"] = 0.0003 <= r.decode_latency <= 0.0015
    out["prefill_compute_bound"] = r.dominant == "compute"
    out["decode_memory_bound"] = r.decode_dominant in ("memory", "overhead")
    return out


if __name__ == "__main__":
    checks = run()
    print("CHECKS:", checks)
