"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) followed by
a CHECKS summary per benchmark. Exit code 1 if any reproduction claim
check fails.

``--quick`` runs a reduced smoke subset (fast modules + a shrunken
study_speed grid) so sweep regressions fail in CI rather than only in full
paper reproductions.

``--json out.json`` additionally writes a machine-readable report (per-bench
wall-clock seconds + every CHECKS key/ratio) so the perf trajectory is
tracked across PRs — CI emits BENCH_quick.json from the smoke run. Every
report is stamped with the git SHA and the analytical MODEL_VERSION, and
each entry carries the framework's own wall-clock phase spans
(presolve/search/schedule/verify/evaluate, core/obs.py) so self-time is
tracked next to the modeled numbers.

``--trace-dir DIR`` drops the smoke Perfetto traces (trace_smoke) into
DIR — CI uploads them as artifacts.

``--baseline PATH`` points at the previous run's BENCH_*.json artifact;
when it exists (default: whatever already sits at the ``--json`` path,
i.e. the artifact the previous PR's CI run left behind) the report gains a
``bench_cold_vs_warm`` delta section and the console a ``#
BENCH_cold_vs_warm`` block, so CI surfaces per-benchmark speedups and
regressions between PRs instead of only absolute numbers.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def _load_baseline(path):
    """The previous BENCH_*.json report at `path`, or None when absent or
    unreadable (first run, corrupt artifact) — deltas are best-effort and
    must never fail the suite."""
    if not path:
        return None
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    return doc if isinstance(doc, dict) and "benchmarks" in doc else None


def delta_vs_previous(prev, timings):
    """`bench_cold_vs_warm` section: per-benchmark wall-clock vs the
    previous report. `speedup` > 1 means this run was faster. Benchmarks
    present on only one side are skipped — suite composition changes
    (quick vs full, new modules) must not fabricate deltas."""
    bench = {}
    for name in sorted(timings):
        doc = prev["benchmarks"].get(name)
        if not isinstance(doc, dict) or "seconds" not in doc:
            continue
        prev_s = float(doc["seconds"])
        cur_s = float(timings[name])
        bench[name] = {
            "seconds_prev": round(prev_s, 4),
            "seconds": round(cur_s, 4),
            "speedup": round(prev_s / cur_s, 4) if cur_s > 0 else 0.0,
        }
    return {
        "previous_git_sha": prev.get("git_sha", "unknown"),
        "previous_suite": prev.get("suite", "unknown"),
        "benchmarks": bench,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced CI smoke subset")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write a machine-readable BENCH_*.json report "
                         "(per-bench seconds + checks) to PATH")
    ap.add_argument("--trace-dir", metavar="DIR", default=None,
                    help="write the smoke Perfetto traces to DIR "
                         "(uploaded as CI artifacts)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="previous BENCH_*.json to diff against (default: "
                         "the existing file at --json PATH, if any)")
    args = ap.parse_args(argv)
    baseline_path = args.baseline
    if baseline_path is None and args.json and os.path.exists(args.json):
        baseline_path = args.json
    baseline = _load_baseline(baseline_path)

    from repro.core import obs
    from repro.core.result_cache import MODEL_VERSION

    from . import (fig5_operators, fig6_area, table3_compute_designs,
                   fig8_bandwidth, fig9_buffers, table4_designs,
                   mapper_speed, planner_archs, precision_sweep,
                   schedule_overlap, serving_sim, study_speed,
                   trace_smoke, unitcheck_speed, verify_lint)

    if args.quick:
        modules = [
            ("fig6_area", fig6_area, {}),
            ("table3_compute_designs", table3_compute_designs, {}),
            ("fig8_bandwidth", fig8_bandwidth, {}),
            ("fig9_buffers", fig9_buffers, {}),
            ("study_speed", study_speed, {"quick": True}),
            ("serving_sim", serving_sim, {"quick": True}),
            ("precision_sweep", precision_sweep, {"quick": True}),
            ("schedule_overlap", schedule_overlap, {"quick": True}),
            ("verify_lint", verify_lint, {"quick": True}),
            ("unitcheck_speed", unitcheck_speed, {"quick": True}),
            ("trace_smoke", trace_smoke,
             {"quick": True, "trace_dir": args.trace_dir}),
        ]
    else:
        modules = [
            ("fig5_operators", fig5_operators, {}),
            ("fig6_area", fig6_area, {}),
            ("table3_compute_designs", table3_compute_designs, {}),
            ("fig8_bandwidth", fig8_bandwidth, {}),
            ("fig9_buffers", fig9_buffers, {}),
            ("table4_designs", table4_designs, {}),
            ("mapper_speed", mapper_speed, {}),
            ("planner_archs", planner_archs, {}),
            ("study_speed", study_speed, {}),
            ("serving_sim", serving_sim, {}),
            ("precision_sweep", precision_sweep, {}),
            ("schedule_overlap", schedule_overlap, {}),
            ("verify_lint", verify_lint, {}),
            ("unitcheck_speed", unitcheck_speed, {}),
            ("trace_smoke", trace_smoke, {"trace_dir": args.trace_dir}),
        ]

    print("name,us_per_call,derived")
    reg = obs.metrics()
    reg.set_enabled(True)       # framework self-profiling (phase spans)
    failed = []
    all_checks = {}
    timings = {}
    phases = {}
    for name, mod, kw in modules:
        snap0 = reg.snapshot()
        t0 = time.perf_counter()
        checks = mod.run(**kw)
        dt = time.perf_counter() - t0
        snap1 = reg.snapshot()
        phases[name] = {
            k[len("phase."):-len(".seconds")]:
                round(v - snap0.get(k, 0.0), 4)
            for k, v in sorted(snap1.items())
            if k.startswith("phase.") and k.endswith(".seconds")
            and v - snap0.get(k, 0.0) > 0.0}
        all_checks[name] = checks
        timings[name] = dt
        bad = [k for k, v in checks.items()
               if isinstance(v, bool) and not v]
        status = "PASS" if not bad else f"FAIL({','.join(bad)})"
        print(f"# {name}: {status}  [{dt:.1f}s]")
        if bad:
            failed.append((name, bad))
    print("#")
    print("# ==== claim-check summary ====")
    for name, checks in all_checks.items():
        for k, v in checks.items():
            print(f"# {name}.{k} = {v}")
    delta = None
    if baseline is not None:
        delta = delta_vs_previous(baseline, timings)
        print("#")
        print(f"# ==== BENCH_cold_vs_warm (vs "
              f"{delta['previous_git_sha'][:12]} "
              f"[{delta['previous_suite']}]) ====")
        for name, d in delta["benchmarks"].items():
            print(f"# BENCH_cold_vs_warm.{name}: {d['seconds_prev']}s -> "
                  f"{d['seconds']}s  ({d['speedup']}x)")
    if args.json:
        sha = _git_sha()
        report = {
            "suite": "quick" if args.quick else "full",
            "python": platform.python_version(),
            "platform": platform.platform(),
            "git_sha": sha,
            "model_version": MODEL_VERSION,
            "passed": not failed,
            "benchmarks": {
                name: {"seconds": round(timings[name], 4),
                       "checks": all_checks[name],
                       "git_sha": sha,
                       "model_version": MODEL_VERSION,
                       "phases": phases[name]}
                for name in timings
            },
        }
        if delta is not None:
            report["bench_cold_vs_warm"] = delta
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all reproduction claim checks passed")


if __name__ == "__main__":
    main()
