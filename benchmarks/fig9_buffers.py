"""Paper Fig. 9 + Sec. IV-D: local / global buffer size sweeps.

Claims (C5): local 64->192KB improves prefill ~18%, 192->1024KB adds only
~0.2%; decode insensitive (<0.5%). Global 10->40MB ~11.8% prefill, 40->80MB
~0.01%. Implications (4)(5): buffers big enough to keep the systolic arrays
busy; beyond that, nothing.

Both sweeps are declared as ONE Study (nine device variants, layer stage):
one device-axis stacked mapper search covers the whole grid."""
from __future__ import annotations

from dataclasses import replace

from repro.core import hardware as hw
from repro.core.graph import Plan
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config

from .common import emit

KB = 1024
MB = 1024 * KB

LOCAL_KB = (64, 128, 192, 512, 1024)
GLOBAL_MB = (10, 20, 40, 80)


def run() -> dict:
    cfg = get_config("gpt3-175b")
    plan = Plan(tp=4)
    wl = Workload(8, 2048, 1024)    # prefill@2048, decode@kv 3072
    base = hw.nvidia_a100()
    cases = [
        Case(hw.make_system(
            replace(base, core=replace(base.core,
                                       local_buffer_bytes=kb * KB)),
            4, 600, "fc"), cfg, plan, wl, stage="layer", label=f"local{kb}")
        for kb in LOCAL_KB]
    cases += [
        Case(hw.make_system(replace(base, global_buffer_bytes=mb * MB),
                            4, 600, "fc"),
             cfg, plan, wl, stage="layer", label=f"global{mb}")
        for mb in GLOBAL_MB]
    res = Study(cases=cases, enforce_fits=False).run()

    pf_l, dc_l, pf_g = {}, {}, {}
    for kb in LOCAL_KB:
        r = res.get(label=f"local{kb}")
        pf_l[kb], dc_l[kb] = r.prefill_latency, r.decode_latency
        emit(f"fig9/local{kb}KB_prefill", r.prefill_latency * 1e6,
             f"ms={r.prefill_latency * 1e3:.2f}")
        emit(f"fig9/local{kb}KB_decode", r.decode_latency * 1e6, "")
    for mb in GLOBAL_MB:
        r = res.get(label=f"global{mb}")
        pf_g[mb] = r.prefill_latency
        emit(f"fig9/global{mb}MB_prefill", r.prefill_latency * 1e6,
             f"ms={r.prefill_latency * 1e3:.2f}")
    checks = {
        "local_64_192_gain": round(pf_l[64] / pf_l[192], 3),   # paper 1.18
        "local_192_1024_gain": round(pf_l[192] / pf_l[1024], 3),  # ~1.002
        "local_decode_insensitive":
            abs(dc_l[64] / dc_l[1024] - 1.0) < 0.05,
        "global_10_40_gain": round(pf_g[10] / pf_g[40], 3),    # paper 1.118
        "global_40_80_flat": pf_g[40] / pf_g[80] < 1.03,
        "local_helps_prefill": pf_l[64] / pf_l[192] > 1.03,
        "local_saturates": pf_l[192] / pf_l[1024] < 1.08,
    }
    emit("fig9/claims", 0.0,
         f"local64to192={checks['local_64_192_gain']}x(paper1.18);"
         f"global10to40={checks['global_10_40_gain']}x(paper1.12)")
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
