"""Paper Fig. 9 + Sec. IV-D: local / global buffer size sweeps.

Claims (C5): local 64->192KB improves prefill ~18%, 192->1024KB adds only
~0.2%; decode insensitive (<0.5%). Global 10->40MB ~11.8% prefill, 40->80MB
~0.01%. Implications (4)(5): buffers big enough to keep the systolic arrays
busy; beyond that, nothing."""
from __future__ import annotations

from dataclasses import replace

from repro.core import hardware as hw
from repro.core.graph import Plan, layer_ops
from repro.configs import get_config

from .common import emit

KB = 1024
MB = 1024 * KB


def run() -> dict:
    cfg = get_config("gpt3-175b")
    plan = Plan(tp=4)
    base = hw.nvidia_a100()
    pf_l, dc_l = {}, {}
    for kb in (64, 128, 192, 512, 1024):
        dev = replace(base, core=replace(base.core,
                                         local_buffer_bytes=kb * KB))
        node = hw.make_system(dev, 4, 600, "fc")
        pf = layer_ops(cfg, node, plan, 0, batch=8, seq=2048, kv_len=2048)
        dc = layer_ops(cfg, node, plan, 0, batch=8, seq=1, kv_len=3072)
        pf_l[kb], dc_l[kb] = pf.latency, dc.latency
        emit(f"fig9/local{kb}KB_prefill", pf.latency * 1e6,
             f"ms={pf.latency * 1e3:.2f}")
        emit(f"fig9/local{kb}KB_decode", dc.latency * 1e6, "")
    pf_g = {}
    for mb in (10, 20, 40, 80):
        dev = replace(base, global_buffer_bytes=mb * MB)
        node = hw.make_system(dev, 4, 600, "fc")
        pf = layer_ops(cfg, node, plan, 0, batch=8, seq=2048, kv_len=2048)
        pf_g[mb] = pf.latency
        emit(f"fig9/global{mb}MB_prefill", pf.latency * 1e6,
             f"ms={pf.latency * 1e3:.2f}")
    checks = {
        "local_64_192_gain": round(pf_l[64] / pf_l[192], 3),   # paper 1.18
        "local_192_1024_gain": round(pf_l[192] / pf_l[1024], 3),  # ~1.002
        "local_decode_insensitive":
            abs(dc_l[64] / dc_l[1024] - 1.0) < 0.05,
        "global_10_40_gain": round(pf_g[10] / pf_g[40], 3),    # paper 1.118
        "global_40_80_flat": pf_g[40] / pf_g[80] < 1.03,
        "local_helps_prefill": pf_l[64] / pf_l[192] > 1.03,
        "local_saturates": pf_l[192] / pf_l[1024] < 1.08,
    }
    emit("fig9/claims", 0.0,
         f"local64to192={checks['local_64_192_gain']}x(paper1.18);"
         f"global10to40={checks['global_10_40_gain']}x(paper1.12)")
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
