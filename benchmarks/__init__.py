"""Paper-reproduction benchmark package; run `python -m benchmarks.run`."""
