"""Paper Table III + Fig. 7: five compute-system designs A-E.

Claims (C3): A (1/4 compute) ~3.25x slower prefill than B but ~equal
decode; E (few huge cores) degrades both; implication (1): compute helps
prefill, barely helps decode; implication (2): large systolic arrays are
less efficient at decode.

Declared as ONE Study over the five designs (layer stage = the paper's
single-layer prefill/decode microbenchmark): all five devices' GEMM shapes
are solved in a single device-axis stacked mapper search."""
from __future__ import annotations

from repro.core import hardware as hw
from repro.core.graph import Plan
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config

from .common import emit


def run() -> dict:
    cfg = get_config("gpt3-175b")
    plan = Plan(tp=4)
    # layer stage: prefill at seq=2048, decode at kv = 2048 + 1024 = 3072
    wl = Workload(8, 2048, 1024)
    study = Study(cases=[
        Case(hw.make_system(hw.compute_design(w), 4, 600, "fc"),
             cfg, plan, wl, stage="layer", label=w)
        for w in "ABCDE"], enforce_fits=False)
    res = {}
    for r in study.run():
        w = r.case.label
        res[w] = (r.prefill_latency, r.decode_latency)
        emit(f"table3/design_{w}_prefill", r.prefill_latency * 1e6,
             f"ms={r.prefill_latency * 1e3:.2f}")
        emit(f"table3/design_{w}_decode", r.decode_latency * 1e6,
             f"ms={r.decode_latency * 1e3:.4f}")
    a_pf, a_dc = res["A"]
    b_pf, b_dc = res["B"]
    e_pf, e_dc = res["E"]
    checks = {
        # paper: 3.25x prefill gap, ~0.1% decode gap
        "A_vs_B_prefill_ratio": round(a_pf / b_pf, 2),
        "A_vs_B_decode_ratio": round(a_dc / b_dc, 3),
        "prefill_gap_large": a_pf / b_pf > 2.0,
        "decode_gap_small": a_dc / b_dc < 1.15,
        # paper: E is 12.4% worse prefill, 30.8% worse decode than B
        "E_worse_decode": e_dc > b_dc * 1.05,
    }
    emit("table3/claim_A_vs_B", 0.0,
         f"prefill_x={checks['A_vs_B_prefill_ratio']};"
         f"decode_x={checks['A_vs_B_decode_ratio']};paper=3.25x/1.001x")
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
