"""ISSUE 9 acceptance benchmark: Perfetto trace export smoke.

Three claims are checked:

  * schema validity + span fidelity — one prefill Schedule trace (GPT-3
    175B, 4x A100, FULL fusion) and one serving-replay trace validate
    against the Chrome trace_event contract (required keys, known phases,
    matched same-name B/E pairs, monotonic timestamps per lane) and their
    total span equals the modeled makespan bit-for-bit;
  * determinism — exporting the same Schedule / simulation twice yields
    byte-identical JSON (virtual timestamps, canonical serialization);
  * zero-overhead-when-off — the instrumentation the observability layer
    adds to hot paths (disabled phase() spans + registry counter adds) is
    timed directly, scaled by a generous count of call sites a cold quick
    study executes, and divided by that study's wall-clock: the ratio must
    stay under 2%. Measured deterministically (like verify_lint) instead
    of A/B wall-clocks that ride mapper-search noise.

With --trace-dir (via benchmarks.run) both traces are written out so CI
can upload them as artifacts.
"""
from __future__ import annotations

import os
import time
from typing import Optional

from repro.configs import get_config
from repro.core import fusion as fu
from repro.core import hardware as hw
from repro.core import obs, result_cache
from repro.core.evaluator import Evaluator
from repro.core.fusion import fuse
from repro.core.graph import Plan, build_model
from repro.core.mapper import clear_matmul_cache
from repro.core.simulator import simulate
from repro.core.study import Study
from repro.core.trace_export import (_ts, schedule_trace_events,
                                     simulation_trace_events,
                                     to_perfetto_json, total_span_us,
                                     validate_trace_events, write_trace)
from repro.core.workload import Trace, TrafficWorkload

from .common import emit
from .study_speed import _cases


def run(quick: bool = False, trace_dir: Optional[str] = None) -> dict:
    checks: dict = {}

    # ---- prefill Schedule trace: schema + span + determinism -------------
    cfg = get_config("gpt3-175b")
    system = hw.dgx_a100(4)
    ev = Evaluator(system, verify="off")
    g = fuse(build_model(cfg, Plan(tp=4), 2, 256, kv_len=256), fu.FULL)
    t0 = time.perf_counter()
    cost = ev.evaluate(g, overlap=True)
    events = schedule_trace_events(cost.schedule, g, process_name="prefill")
    text = to_perfetto_json(events)
    dt_export = time.perf_counter() - t0
    errors = validate_trace_events(events)
    span = total_span_us(events)
    again = to_perfetto_json(schedule_trace_events(
        ev.evaluate(g, overlap=True).schedule, g, process_name="prefill"))
    checks["prefill_schema_valid"] = not errors
    checks["prefill_span_equals_makespan"] = \
        span == _ts(cost.schedule.makespan)
    checks["prefill_deterministic"] = text == again
    emit("trace/prefill_export", dt_export * 1e6,
         f"events={len(events)};span_us={span:.3f};errors={len(errors)}")

    # ---- serving replay trace --------------------------------------------
    scfg = get_config("qwen2-0.5b")
    ssys = hw.dgx_a100(2)
    traffic = TrafficWorkload.from_trace(
        Trace.poisson(8, 16.0, 128, 8, seed=0), slots=4)
    sim = simulate(ssys, scfg, Plan(tp=2), traffic,
                   evaluator=Evaluator(ssys, verify="off"), verify="off")
    sevents = simulation_trace_events(sim)
    serrors = validate_trace_events(sevents)
    checks["serve_schema_valid"] = not serrors
    checks["serve_span_equals_makespan"] = \
        total_span_us(sevents) == _ts(sim.makespan)
    checks["serve_deterministic"] = \
        to_perfetto_json(sevents) == to_perfetto_json(
            simulation_trace_events(sim))
    emit("trace/serve_export", 0.0,
         f"events={len(sevents)};reqs={len(sim.requests)};"
         f"errors={len(serrors)}")

    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        write_trace(os.path.join(trace_dir, "gpt3-175b_prefill"
                                 ".perfetto.json"), events)
        write_trace(os.path.join(trace_dir, "qwen2-0.5b_serve"
                                 ".perfetto.json"), sevents)

    # ---- instrumentation-off overhead on the cold study ------------------
    reg = obs.metrics()
    prev = reg.set_enabled(False)
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with reg.phase("probe"):
            pass
    per_span = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    for _ in range(n):
        reg.inc("probe")
    per_inc = (time.perf_counter() - t0) / n
    reg.set_enabled(prev)

    cases = _cases(quick=True)
    with result_cache.disabled():
        clear_matmul_cache()
        t0 = time.perf_counter()
        Study(cases=cases, enforce_fits=False, verify="off").run()
        dt_study = time.perf_counter() - t0
        clear_matmul_cache()
    # generous call-site count for that run: per case, evaluate_many enters
    # <= 3 disabled spans and a couple of counter adds; the Study adds the
    # presolve/evaluate spans and per-case cache counters on top
    k = 8 * len(cases) + 16
    overhead = k * (per_span + per_inc) / max(dt_study, 1e-9)
    checks["overhead_ratio"] = round(overhead, 6)
    checks["overhead_under_2pct"] = overhead < 0.02
    checks["study_seconds"] = round(dt_study, 2)
    emit("trace/off_overhead", per_span * 1e6,
         f"per_span_ns={per_span * 1e9:.0f};per_inc_ns={per_inc * 1e9:.0f};"
         f"sites={k};study_s={dt_study:.2f};overhead={overhead:.4%}")
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
