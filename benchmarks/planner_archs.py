"""Beyond-paper benchmark: the LLMCompass-based parallelism planner applied
to the 10 assigned architectures on a TPU v5e pod slice — the simulator
used the way launch/serve.py uses it (DESIGN.md Sec. 4).

One Evaluator is shared across ALL archs and plans: rank_plans is a thin
Study per arch (DESIGN.md §6), so each arch's whole plan enumeration is
pre-solved in one stacked mapper search and every plan after the first pays
only for GEMM shapes it hasn't seen. The same sweep is then re-run in
seed-replica mode (fresh-per-sweep dense per-shape search, no batching) to
report the wall-clock speedup of the IR/evaluator path — the ISSUE 1
acceptance number."""
from __future__ import annotations

import time

from repro.core import hardware as hw
from repro.core import planner
from repro.core import result_cache
from repro.core.evaluator import Evaluator
from repro.core.mapper import clear_matmul_cache
from repro.configs import ARCHS

from .common import emit


def _sweep(node, evaluator, quiet: bool = False) -> dict:
    out = {}
    for arch, cfg in ARCHS.items():
        try:
            best = planner.best_plan(node, cfg, batch=8, in_len=2048,
                                     out_len=256, objective="latency",
                                     evaluator=evaluator)
            p = best.plan
            if not quiet:
                emit(f"planner/{arch}", best.latency * 1e6,
                     f"tp={p.tp};pp={p.pp};dp={p.dp};ep={p.ep};"
                     f"mem_GiB={best.memory_per_device / 2 ** 30:.2f};"
                     f"tok_s={best.throughput:.0f}")
            out[arch] = {"tp": p.tp, "pp": p.pp, "dp": p.dp,
                         "fits": best.fits}
        except ValueError as e:
            if not quiet:
                emit(f"planner/{arch}", 0.0, f"does_not_fit:{e}")
            out[arch] = {"fits": False}
    return out


def run() -> dict:
    node = hw.tpu_v5e_pod(16)      # 4x4 v5e slice for planning demo

    with result_cache.disabled():   # honest engine-vs-seed timing, no disk
        # ---- new path: shared dedup evaluator + batched mapper -----------
        clear_matmul_cache()
        ev = Evaluator(node)
        t0 = time.perf_counter()
        out = _sweep(node, ev)
        dt = time.perf_counter() - t0

        # ---- seed path: dense per-shape search, no batching --------------
        clear_matmul_cache()
        t0 = time.perf_counter()
        _sweep(node, Evaluator(node, use_reference_mapper=True), quiet=True)
        dt_seed = time.perf_counter() - t0
        clear_matmul_cache()

    emit("planner/sweep_wallclock", dt * 1e6,
         f"seconds={dt:.1f};seed_path_seconds={dt_seed:.1f};"
         f"speedup={dt_seed / max(dt, 1e-9):.1f}x")
    emit("planner/evaluator_stats", 0.0, ev.stats.summary().replace(" ", ";"))

    # grok-314B should need heavy model parallelism; small models DP-heavy
    ok_small = all(out[a]["tp"] <= 4 for a in ("qwen1.5-0.5b", "qwen2-0.5b")
                   if out[a].get("fits"))
    out["small_models_dp_heavy"] = ok_small
    out["sweep_seconds"] = round(dt, 1)
    out["seed_path_seconds"] = round(dt_seed, 1)
    out["speedup_vs_seed_path"] = round(dt_seed / max(dt, 1e-9), 1)
    out["at_least_2x_faster"] = dt_seed >= 2 * dt
    return out


if __name__ == "__main__":
    print("CHECKS:", run())
