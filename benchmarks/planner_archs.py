"""Beyond-paper benchmark: the LLMCompass-based parallelism planner applied
to the 10 assigned architectures on a TPU v5e pod slice — the simulator
used the way launch/serve.py uses it (DESIGN.md Sec. 4)."""
from __future__ import annotations

from repro.core import hardware as hw
from repro.core import planner
from repro.configs import ARCHS

from .common import emit


def run() -> dict:
    node = hw.tpu_v5e_pod(16)      # 4x4 v5e slice for planning demo
    out = {}
    for arch, cfg in ARCHS.items():
        try:
            best = planner.best_plan(node, cfg, batch=8, in_len=2048,
                                     out_len=256, objective="latency")
            p = best.plan
            emit(f"planner/{arch}", best.latency * 1e6,
                 f"tp={p.tp};pp={p.pp};dp={p.dp};ep={p.ep};"
                 f"mem_GiB={best.memory_per_device / 2 ** 30:.2f};"
                 f"tok_s={best.throughput:.0f}")
            out[arch] = {"tp": p.tp, "pp": p.pp, "dp": p.dp,
                         "fits": best.fits}
        except ValueError as e:
            emit(f"planner/{arch}", 0.0, f"does_not_fit:{e}")
            out[arch] = {"fits": False}
    # grok-314B should need heavy model parallelism; small models DP-heavy
    ok_small = all(out[a]["tp"] <= 4 for a in ("qwen1.5-0.5b", "qwen2-0.5b")
                   if out[a].get("fits"))
    out["small_models_dp_heavy"] = ok_small
    return out


if __name__ == "__main__":
    print("CHECKS:", run())
