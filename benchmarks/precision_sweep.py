"""ISSUE 4 acceptance benchmark: the precision axis on an A100 system.

Three claims:

  no-op default — the fp16-everywhere PrecisionPolicy reproduces the frozen
                  seed generate() numbers bit-for-bit (quick mode checks the
                  explicit policy against the implicit default instead);
  quantization  — int8 weights strictly cut the latency of a memory-bound
                  decode step (weight streaming halves) and int8 KV raises
                  the serving slot budget; w8a8 speeds up compute-bound
                  prefill via the 2x issue rate;
  die area      — an int8-native systolic datapath prices below the fp16
                  one per MAC (area.MAC_AREA), so a matched design point
                  improves perf/$.

One Study grid per model prices every policy through ONE device-axis
stacked mapper search; per-policy perf/$ rows are emitted for the Pareto
view (GPT-3 rows use enforce_fits=False: fp16 GPT-3 does not fit 4xA100 —
which is itself the quantization story the planner check tells).
"""
from __future__ import annotations

import json
import os
import time

from repro.configs import get_config
from repro.core import area, cost, hardware as hw
from repro.core import inference_model as im
from repro.core.graph import Plan
from repro.core.mapper import clear_matmul_cache
from repro.core.precision import get_policy
from repro.core.study import Study
from repro.core.workload import Workload

from .common import emit

_REF_PATH = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                         "seed_reference.json")

#: the sweep: deployment-relevant quantization points, fp16 first
SWEEP = ("fp16", "int8-weights", "int8-kv", "w8kv8", "w8a8")


def _sweep_study(system, cfg, plan, workload, enforce_fits=True):
    return Study(systems=[system], configs=[cfg], plans=[plan],
                 workloads={"w": workload},
                 policies={n: get_policy(n) for n in SWEEP},
                 enforce_fits=enforce_fits).run()


def run(quick: bool = False) -> dict:
    checks: dict = {}
    clear_matmul_cache()

    # ---- small config: qwen3-1.7b on 1xA100 ------------------------------
    cfg = get_config("qwen3-1.7b")
    sys1 = hw.make_system(hw.nvidia_a100(), 1)
    wl = Workload(8, 256, 64, samples=4) if quick \
        else Workload(8, 2048, 256, samples=8)
    t0 = time.perf_counter()
    res = _sweep_study(sys1, cfg, Plan(), wl)
    dt = time.perf_counter() - t0
    by = {policy: res.filter(policy=policy)[0] for policy in SWEEP}
    for name, r in by.items():
        emit(f"precision_sweep/{cfg.name}/{name}", r.latency * 1e6,
             f"thr={r.throughput:.0f};perf_per_usd={r.perf_per_dollar:.3f};"
             f"mem_gib={r.memory_per_device / 2**30:.2f}")
    emit("precision_sweep/grid", dt * 1e6,
         f"cases={len(res)};presolved={res.stats.matmul_pairs_presolved}")

    # fp16 row == the no-axis default row, bit-for-bit
    base = Study(systems=[sys1], configs=[cfg], plans=[Plan()],
                 workloads={"w": wl}).run()[0]
    checks["fp16_policy_is_noop"] = by["fp16"].latency == base.latency

    # ---- memory-bound decode: int8 weights strictly faster ---------------
    dec_cfg, dec_sys, dec_plan, dec_b, dec_kv = \
        (cfg, sys1, Plan(), 8, 2048) if quick \
        else (get_config("gpt3-175b"), hw.dgx_a100(4), Plan(tp=4), 8, 3072)
    d16 = im.decode_step(dec_sys, dec_cfg, dec_plan, dec_b, dec_kv)
    d8 = im.decode_step(dec_sys, dec_cfg, dec_plan, dec_b, dec_kv,
                        policy=get_policy("int8-weights"))
    emit(f"precision_sweep/decode_{dec_cfg.name}", d16.latency * 1e6,
         f"fp16_ms={d16.latency * 1e3:.3f};w8_ms={d8.latency * 1e3:.3f};"
         f"speedup={d16.latency / d8.latency:.2f}x;"
         f"dominant={d16.dominant}")
    checks["decode_memory_bound"] = d16.dominant == "memory"
    checks["int8_weights_decode_faster"] = d8.latency < d16.latency
    checks["int8_weights_traffic_lower"] = d8.bytes < d16.bytes

    # ---- compute-bound prefill: w8a8 uses the 2x issue rate --------------
    p16 = im.prefill(dec_sys, dec_cfg, dec_plan, dec_b, 2048)
    p8 = im.prefill(dec_sys, dec_cfg, dec_plan, dec_b, 2048,
                    policy=get_policy("w8a8"))
    emit(f"precision_sweep/prefill_{dec_cfg.name}", p16.latency * 1e6,
         f"fp16_s={p16.latency:.4f};w8a8_s={p8.latency:.4f};"
         f"speedup={p16.latency / p8.latency:.2f}x")
    checks["w8a8_prefill_faster"] = p8.latency < p16.latency

    # ---- quantized-KV slot budget ----------------------------------------
    b16 = im.max_batch(sys1, cfg, Plan(), 16384)
    b8 = im.max_batch(sys1, cfg, Plan(), 16384, get_policy("int8-kv"))
    emit("precision_sweep/slot_budget_16k", 0.0,
         f"fp16_slots={b16};int8kv_slots={b8};gain={b8 / max(b16, 1):.2f}x")
    checks["int8_kv_more_slots"] = b8 > b16

    # ---- die area: narrow datapath ---------------------------------------
    a100 = hw.nvidia_a100()
    i8 = hw.with_mac_dtype(a100, "int8")
    ar16 = area.device_area(a100, 600)
    ar8 = area.device_area(i8, 600)
    c16 = cost.device_cost(a100, ar16.total_mm2).total_usd
    c8 = cost.device_cost(i8, ar8.total_mm2).total_usd
    emit("precision_sweep/die_area", 0.0,
         f"fp16_mm2={ar16.total_mm2:.0f};int8_mm2={ar8.total_mm2:.0f};"
         f"fp16_usd={c16:.0f};int8_usd={c8:.0f}")
    checks["int8_mac_smaller_die"] = ar8.total_mm2 < ar16.total_mm2
    checks["int8_mac_cheaper_device"] = c8 < c16

    # matched design point: int8 array + w8a8 policy — the Pareto frontier
    # entry narrow datapaths buy (throughput up via 2x rate, cost down)
    sys8 = hw.make_system(i8, 1)
    r8 = Study(systems=[sys8], configs=[cfg], plans=[Plan()],
               workloads={"w": wl},
               policies={"w8a8": get_policy("w8a8")}).run()[0]
    emit("precision_sweep/int8_design_point", r8.latency * 1e6,
         f"thr={r8.throughput:.0f};perf_per_usd={r8.perf_per_dollar:.3f};"
         f"vs_fp16={r8.perf_per_dollar / by['fp16'].perf_per_dollar:.2f}x")
    checks["int8_design_better_perf_per_usd"] = \
        r8.perf_per_dollar > by["fp16"].perf_per_dollar

    # ---- GPT-3 across policies (full mode: the paper-scale grid) ---------
    if not quick:
        gpt3 = get_config("gpt3-175b")
        node = hw.dgx_a100(4)
        gres = _sweep_study(node, gpt3, Plan(tp=4),
                            Workload(4, 512, 64, samples=8),
                            enforce_fits=False)
        for name in SWEEP:
            r = gres.filter(policy=name)[0]
            emit(f"precision_sweep/gpt3/{name}", r.latency * 1e6,
                 f"thr={r.throughput:.1f};fits={r.fits};"
                 f"perf_per_usd={r.perf_per_dollar:.4f}")
        ref = json.load(open(_REF_PATH))["gpt3-175b/dgx_a100_4"]
        g16 = gres.filter(policy="fp16")[0]
        checks["gpt3_fp16_matches_frozen_seed"] = \
            abs(g16.latency - ref["generate"]) <= 1e-9 * ref["generate"]
        # fp16 GPT-3 does not fit 4xA100; w8kv8 does — the planner story
        checks["gpt3_fits_only_quantized"] = \
            (not g16.fits) and gres.filter(policy="w8kv8")[0].fits

    clear_matmul_cache()
    return checks


if __name__ == "__main__":
    print("CHECKS:", run())
