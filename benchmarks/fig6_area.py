"""Paper Fig. 6: area model validation — GA100 / Aldebaran die estimates
and the per-core breakdown. Paper: 5.1% / 8.1% error on accounted
components; our calibration (core/area.py) reproduces the Table IV triple
(826 / 478 / 787 mm^2)."""
from __future__ import annotations

from repro.core import area, hardware as hw

from .common import emit

PAPER = {"ga100": 826.0, "mi210": 724.0,
         "latency-oriented": 478.0, "throughput-oriented": 787.0}


def run() -> dict:
    out = {}
    for name, target in PAPER.items():
        dev = hw.get_device(name)
        rep = area.device_area(dev, 600)
        err = (rep.total_mm2 - target) / target
        emit(f"fig6a/area_{name}", 0.0,
             f"mm2={rep.total_mm2:.1f};paper={target};err_pct={err * 100:+.1f}")
        out[f"{name}_err"] = err
    # per-core (SM) breakdown  [Fig. 6b]
    ga = hw.nvidia_ga100()
    rep = area.device_area(ga, 600)
    core = area.core_area(ga)
    emit("fig6b/ga100_core_mm2", 0.0,
         f"core_mm2={core:.2f};die_photo_SM~3-5mm2")
    for k, v in rep.breakdown.items():
        emit(f"fig6b/ga100_{k}", 0.0, f"mm2={v:.1f}")
    out["ga100_ok"] = abs(out["ga100_err"]) < 0.05
    out["designs_ok"] = (abs(out["latency-oriented_err"]) < 0.05
                         and abs(out["throughput-oriented_err"]) < 0.08)
    return out


if __name__ == "__main__":
    print("CHECKS:", run())
