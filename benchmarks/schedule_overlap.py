"""ISSUE 5 acceptance benchmark: dataflow scheduling + kernel fusion.

GPT-3 175B on 4xA100 under TP=4 — the paper's flagship system — priced at
the four execution-model points (serial / fused / overlap / full):

  bit-for-bit — the serial, unfused configuration must reproduce the frozen
                seed-commit prefill/decode/generate numbers exactly (the DAG
                refactor cannot move the baseline);
  overlap+fusion — the FULL model (fused epilogues + flash streaming +
                comm/compute overlap) must show >= 1.05x modeled prefill
                speedup from hidden all-reduces and fused epilogues, with
                per-resource timeline breakdowns and the critical path in
                the report;
  soundness  — scheduled makespans never beat the per-resource busy-time
               bound, and fusion moves work between resources without
               changing the math (flops preserved).

Also reported: the decode-step win (launch-overhead elision + hidden
collectives dominate at seq=1), the sequence-parallel sibling whose RS+AG
hide behind the adjacent GEMMs, and the fusion pass's elided HBM traffic.
"""
from __future__ import annotations

import json
import os

from repro.core import fusion as fu
from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core.evaluator import Evaluator
from repro.core.fusion import elided_bytes, fuse
from repro.core.graph import Plan, build_model
from repro.core.mapper import clear_matmul_cache

from repro.configs import get_config

from .common import emit

_REF_PATH = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                         "seed_reference.json")

MODELS = {"serial": fu.SERIAL, "fused": fu.FUSED, "overlap": fu.OVERLAP,
          "full": fu.FULL}


def _busy_str(rep) -> str:
    if rep.schedule is None:
        return ""
    busy = rep.schedule.busy
    return ";".join(f"busy_{r}={busy.get(r, 0.0) * 1e3:.2f}ms"
                    for r in ("compute", "vector", "link"))


def _stage(name: str, reports: dict) -> None:
    base = reports["serial"].latency
    for tag, rep in reports.items():
        extra = f"speedup={base / rep.latency:.3f}x"
        busy = _busy_str(rep)
        if busy:
            extra += ";" + busy
        emit(f"schedule_overlap/{name}/{tag}", rep.latency * 1e6, extra)
    sch = reports["full"].schedule
    if sch is not None:
        top = sorted(sch.critical_breakdown().items(),
                     key=lambda kv: -kv[1])[:4]
        emit(f"schedule_overlap/{name}/critical_path", sch.makespan * 1e6,
             ";".join(f"{k}={v * 1e3:.2f}ms" for k, v in top))


def run(quick: bool = False) -> dict:
    cfg = get_config("gpt3-175b")
    system = hw.dgx_a100(4)
    plan = Plan(tp=4)
    batch, seq = (4, 1024) if quick else (8, 2048)

    clear_matmul_cache()
    ev = Evaluator(system)
    checks: dict = {}

    # ---- guard: serial/unfused == frozen seed numbers, bit-for-bit -------
    ref = json.load(open(_REF_PATH))["gpt3-175b/dgx_a100_4"]
    pf0 = im.prefill(system, cfg, plan, 4, 512, evaluator=ev)
    dc0 = im.decode_step(system, cfg, plan, 4, 768, evaluator=ev)
    gn0 = im.generate(system, cfg, plan, 4, 512, 64, evaluator=ev)
    checks["serial_matches_seed_bitforbit"] = (
        pf0.latency == ref["prefill"] and dc0.latency == ref["decode"]
        and gn0.latency == ref["generate"])

    # ---- prefill at the acceptance workload ------------------------------
    pf = {tag: im.prefill(system, cfg, plan, batch, seq, evaluator=ev,
                          fusion=f) for tag, f in MODELS.items()}
    _stage(f"prefill_b{batch}_s{seq}", pf)
    speedup = pf["serial"].latency / pf["full"].latency
    checks["prefill_speedup"] = round(speedup, 3)
    checks["prefill_speedup_ge_1.05"] = speedup >= 1.05
    checks["overlap_only_speedup"] = round(
        pf["serial"].latency / pf["overlap"].latency, 3)
    checks["fused_only_speedup"] = round(
        pf["serial"].latency / pf["fused"].latency, 3)

    # soundness: makespan within [max resource busy, serial sum]
    sch = pf["full"].schedule
    checks["makespan_ge_busy_bound"] = \
        sch.makespan >= max(sch.busy.values()) - 1e-15
    checks["flops_preserved_by_fusion"] = \
        abs(pf["full"].flops - pf["serial"].flops) < 1e-6 * pf["serial"].flops

    # ---- decode step (launch-overhead elision + hidden collectives) ------
    dec = {tag: im.decode_step(system, cfg, plan, batch, seq, evaluator=ev,
                               fusion=f) for tag, f in MODELS.items()}
    _stage(f"decode_b{batch}_kv{seq}", dec)
    dec_speedup = dec["serial"].latency / dec["full"].latency
    checks["decode_speedup"] = round(dec_speedup, 3)
    checks["decode_speedup_gt_1"] = dec_speedup > 1.0

    # ---- sequence-parallel sibling: RS+AG hidden behind adjacent GEMMs ---
    sp = Plan(tp=4, sequence_parallel=True)
    sp_serial = im.prefill(system, cfg, sp, batch, seq, evaluator=ev)
    sp_full = im.prefill(system, cfg, sp, batch, seq, evaluator=ev,
                         fusion=fu.FULL)
    emit("schedule_overlap/prefill_sp/serial", sp_serial.latency * 1e6, "")
    emit("schedule_overlap/prefill_sp/full", sp_full.latency * 1e6,
         f"speedup={sp_serial.latency / sp_full.latency:.3f}x;"
         + _busy_str(sp_full))
    checks["sp_overlap_hides_rs_ag"] = sp_full.latency < sp_serial.latency

    # ---- fusion traffic elision ------------------------------------------
    g = build_model(cfg, plan, batch, seq, kv_len=seq)
    gf = fuse(g, fu.FUSED)
    est = elided_bytes(g, gf)
    actual = pf["serial"].bytes - pf["fused"].bytes
    emit("schedule_overlap/elided_traffic", 0.0,
         f"estimate_GB={est / 1e9:.2f};actual_GB={actual / 1e9:.2f};"
         f"fused_nodes={len(g) - len(gf)}")
    checks["traffic_elided_GB"] = round(actual / 1e9, 2)
    checks["fusion_elides_traffic"] = actual >= est * 0.999 > 0

    emit("schedule_overlap/evaluator_stats", 0.0,
         ev.stats.summary().replace(" ", ";"))
    checks["sched_vs_serial_ratio"] = round(ev.stats.schedule_ratio, 3)
    return checks


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for k, v in run().items():
        print(f"# {k} = {v}")
