from .optimizer import AdamW, AdamWState, cosine_schedule, constant_schedule
from .train_step import (TrainState, make_train_step, init_state,
                         compress_grads, compress_int8, decompress_int8)

__all__ = ["AdamW", "AdamWState", "cosine_schedule", "constant_schedule",
           "TrainState", "make_train_step", "init_state", "compress_grads",
           "compress_int8", "decompress_int8"]
