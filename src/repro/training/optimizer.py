"""AdamW + LR schedules, from scratch (no optax in this environment).

Mixed precision: bf16 params in the model, fp32 master copy + moments in
the optimizer state (ZeRO-shardable over the data axis, see
distributed/sharding.opt_state_shardings).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    master: dict        # fp32 params
    m: dict
    v: dict


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        f32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        zeros = jax.tree.map(jnp.zeros_like, f32)
        return AdamWState(jnp.zeros((), jnp.int32), f32, zeros,
                          jax.tree.map(jnp.zeros_like, f32))

    def update(self, grads, state: AdamWState, params):
        """params: current model-dtype params (for the cast back).
        Returns (new model-dtype params, new state, stats)."""
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(g32)) + 1e-20)
        scale = jnp.minimum(1.0, self.clip_norm / gnorm)
        g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        lr = self.lr(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            p = p - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                          + self.weight_decay * p * (p.ndim >= 2))
            return p, m, v

        flat_p, tdef = jax.tree.flatten(state.master)
        flat_g = jax.tree.leaves(g32)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        cast = jax.tree.map(lambda p, old: p.astype(old.dtype), new_p, params)
        new_state = AdamWState(step, new_p, new_m, new_v)
        return cast, new_state, {"grad_norm": gnorm, "lr": lr}


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def constant_schedule(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)
