"""Training step: loss -> grad -> AdamW, with microbatch gradient
accumulation and optional int8 error-feedback gradient compression for the
cross-pod (DCN) all-reduce — a distributed-optimization trick beyond the
paper (EXPERIMENTS.md §Perf).

The remat policy is the scan-over-units checkpoint in models/lm.py; the
step itself is pure and jit/pjit-friendly (all sharding comes from the
in/out shardings the launcher attaches).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .. import models
from .optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def compress_int8(g):
    """Per-tensor int8 quantization (symmetric)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error_feedback=None):
    """int8 + error feedback; returns (compressed pytree, new residuals)."""
    if error_feedback is None:
        error_feedback = jax.tree.map(jnp.zeros_like, grads)
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), gf - deq
    flat = jax.tree.map(one, grads, error_feedback)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    return deq, res


def _shard_microbatch(a):
    """Constrain (n_mb, mb, ...) xs: mb dim over the DP axes (guarded)."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except AttributeError:
        return a
    if am is None or not am.shape:
        return a
    from jax.sharding import PartitionSpec as P
    shape = dict(am.shape)
    for axes in ((("pod", "data") if "pod" in shape else ("data",)),
                 ("data",)):
        axes = tuple(x for x in axes if x in shape)
        if not axes:
            continue
        n = 1
        for x in axes:
            n *= shape[x]
        if a.shape[1] % n == 0 and a.shape[1] >= n:
            spec = [None, axes if len(axes) > 1 else axes[0]] \
                + [None] * (a.ndim - 2)
            return jax.lax.with_sharding_constraint(a, P(*spec))
    return a


def make_train_step(cfg: ModelConfig, opt: AdamW, microbatches: int = 1,
                    has_frontend: bool = False):
    """Returns step(state, batch) -> (state, metrics). batch:
    {tokens, targets, mask[, frontend]} with global-batch leading dim."""

    def loss(params, tokens, targets, mask, frontend):
        return models.loss_fn(cfg, params, tokens, targets, mask=mask,
                              frontend=frontend)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step(state: TrainState, batch):
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(targets, jnp.float32)
        frontend = batch.get("frontend") if has_frontend else None

        if microbatches > 1:
            # reshape to a leading microbatch axis and scan over it as xs —
            # NEVER dynamic-slice along the sharded batch axis (GSPMD lowers
            # that to collective-permute halo storms; §Perf iteration 4)
            def to_mb(a):
                if a is None:
                    return None
                a = a.reshape(microbatches, -1, *a.shape[1:])
                return _shard_microbatch(a)

            xs = tuple(to_mb(a) for a in (tokens, targets, mask, frontend))

            def body(carry, mb):
                acc, tot_loss = carry
                t, tg, mk, fe = mb
                (lv, met), g = grad_fn(state.params, t, tg, mk, fe)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                   acc, g)
                return (acc, tot_loss + lv), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gacc, tot), _ = jax.lax.scan(body, (zeros, 0.0), xs)
            grads = jax.tree.map(lambda g: g / microbatches, gacc)
            lv = tot / microbatches
            met = {}
        else:
            (lv, met), grads = grad_fn(state.params, tokens, targets, mask,
                                       frontend)

        new_params, new_opt, stats = opt.update(grads, state.opt,
                                                state.params)
        metrics = {"loss": lv, **stats}
        metrics.update({k: v for k, v in met.items()})
        return TrainState(new_params, new_opt), metrics

    return step


def init_state(cfg: ModelConfig, opt: AdamW, key) -> TrainState:
    params = models.init_params(cfg, key)
    return TrainState(params, opt.init(params))
