"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision] — image
cross-attention every 5th decoder layer; vision tower is a STUB
(input_specs provides precomputed patch embeddings)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=128256,
    cross_attn_layers=(3, 8, 13, 18, 23, 28, 33, 38),
    n_frontend_tokens=1601,
    qkv_bias=False, mlp_gated=True, activation="silu", norm="rmsnorm",
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
