"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B] — dense, MHA (GQA kv=16), QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=2816, vocab_size=151936,
    qkv_bias=True, mlp_gated=True, activation="silu", norm="rmsnorm",
    rope_theta=1_000_000.0, tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
