"""Shared architecture + input-shape configuration.

One ModelConfig drives three consumers that must stay consistent:
  * core/graph.py      — the LLMCompass operator graph (simulator)
  * models/            — the executable JAX definition
  * launch/dryrun.py   — input_specs + sharding for the multi-pod dry-run
tests/test_config_consistency.py asserts the simulator's parameter count
matches the instantiated JAX parameters.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free families
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    # --- attention ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0      # partial-rotary (stablelm: 0.25)
    attn_window: int = 0            # 0 = full causal; >0 = local window
    attn_logit_softcap: float = 0.0
    # --- mlp ---
    mlp_gated: bool = True          # SwiGLU/GeGLU (3 mats) vs plain (2 mats)
    activation: str = "silu"        # silu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    # --- hybrid (recurrentgemma): per-layer block cycle ---
    block_pattern: Tuple[str, ...] = ()     # e.g. ("rglru","rglru","attn")
    rglru_conv_width: int = 4
    # --- ssm (rwkv6) ---
    rwkv_head_dim: int = 64
    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    cross_attention: bool = False
    # --- vlm ---
    cross_attn_layers: Tuple[int, ...] = ()  # decoder layers w/ image x-attn
    n_frontend_tokens: int = 0      # stubbed modality tokens (vision/audio)
    # --- bookkeeping ---
    max_seq_len: int = 1 << 20
    dtype: str = "bfloat16"
    source: str = ""                # provenance tag from the assignment table

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def group_size(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1) if self.n_heads else 0

    def block_kind(self, layer: int) -> str:
        """dense attention / rglru / rwkv per layer index."""
        if self.family == "ssm":
            return "rwkv"
        if self.block_pattern:
            return self.block_pattern[layer % len(self.block_pattern)]
        return "attn"

    # --- parameter accounting (must match models/, tested) -------------
    def attn_params(self) -> int:
        d, dh = self.d_model, self.d_head
        q = d * self.n_heads * dh
        kv = 2 * d * self.n_kv_heads * dh
        o = self.n_heads * dh * d
        bias = (self.n_heads + 2 * self.n_kv_heads) * dh if self.qkv_bias else 0
        qknorm = 2 * dh if self.qk_norm else 0
        return q + kv + o + bias + qknorm

    def mlp_params(self) -> int:
        mats = 3 if self.mlp_gated else 2
        return mats * self.d_model * self.d_ff

    def rwkv_params(self) -> int:
        """RWKV6 time-mix (r,k,v,g,o + decay LoRA) + channel-mix."""
        d = self.d_model
        tm = 5 * d * d + 6 * 32 * d + 2 * (d * 64 + 64 * d)   # lora_rank 64
        cm = d * int(3.5 * d) + int(3.5 * d) * d
        return tm + cm

    def rglru_params(self) -> int:
        """Griffin recurrent block: in/out proj (2 branches) + conv1d + gates."""
        d = self.d_model
        return 2 * d * d + d * d + self.rglru_conv_width * d + 2 * d * d

    def layer_params(self, layer: int) -> int:
        d = self.d_model
        kind = self.block_kind(layer)
        norms = 2 * d * (2 if self.norm == "layernorm" else 1)
        if kind == "rwkv":
            return self.rwkv_params() + norms
        if kind == "rglru":
            return self.rglru_params() + self.mlp_params() + norms
        p = self.attn_params() + norms
        if self.n_experts:
            p += self.n_experts * self.mlp_params() + d * self.n_experts
        else:
            p += self.mlp_params()
        if self.cross_attention:
            # enc-dec decoder layer: self-attn + cross-attn
            p += self.attn_params() + d * (2 if self.norm == "layernorm" else 1)
        # vision cross-attn layers REPLACE self-attn (gated xattn + mlp),
        # same parameter count + 1 gate scalar
        if layer in self.cross_attn_layers:
            p += 1
        return p

    def param_count(self) -> int:
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else emb
        total = emb + head + self.d_model  # final norm
        total += sum(self.layer_params(i) for i in range(self.n_layers))
        # encoder stack (whisper): same block sans cross-attn, non-causal
        enc_cfg_layers = self.n_encoder_layers
        if enc_cfg_layers:
            enc_layer = self.attn_params() + self.mlp_params() + \
                2 * self.d_model * (2 if self.norm == "layernorm" else 1)
            total += enc_cfg_layers * enc_layer + self.d_model
        return total

    def active_param_count(self) -> int:
        """MoE: only top_k experts fire per token."""
        if not self.n_experts:
            return self.param_count()
        dense = self.param_count() - self.n_layers * self.n_experts * self.mlp_params()
        return dense + self.n_layers * self.top_k * self.mlp_params()

    def kv_bytes_per_token(self, bytes_per: float = 2) -> float:
        """KV-cache bytes per token across all (attention) layers, at the
        given element width (core/precision.py policies pass theirs;
        fractional for sub-byte types)."""
        per_layer = 2 * self.n_kv_heads * self.d_head * bytes_per
        n_attn = sum(1 for i in range(self.n_layers)
                     if self.block_kind(i) == "attn")
        return per_layer * n_attn


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rules: long_500k only for sub-quadratic archs (SSM/hybrid
    with bounded-window attention). See DESIGN.md Sec. 5."""
    if shape.name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2 * max(1, len(cfg.block_pattern) or 1)),
        d_model=128,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_head=32 if cfg.n_heads else 0,
        d_ff=256,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        cross_attn_layers=(1,) if cfg.cross_attn_layers else (),
        n_frontend_tokens=16 if cfg.n_frontend_tokens else 0,
        attn_window=min(cfg.attn_window, 64) if cfg.attn_window else 0,
        max_seq_len=4096,
    )
