"""GPT-3 175B [arXiv:2005.14165] — the paper's own evaluation model
(96 layers, d_model 12288, 96 heads). Used by the simulator benchmarks."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gpt3-175b", family="dense",
    n_layers=96, d_model=12288, n_heads=96, n_kv_heads=96, d_head=128,
    d_ff=49152, vocab_size=50257,
    qkv_bias=True, mlp_gated=False, activation="gelu", norm="layernorm",
    rope_fraction=0.0,
    source="arXiv:2005.14165 (paper Sec. IV setup)",
)
