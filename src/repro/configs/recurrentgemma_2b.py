"""recurrentgemma-2b (Griffin) [arXiv:2402.19427] — RG-LRU + local attention,
2 recurrent blocks per 1 local-attention block (1:2), window 2048, MQA kv=1."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"), attn_window=2048,
    rglru_conv_width=4,
    mlp_gated=True, activation="gelu", norm="rmsnorm", tie_embeddings=True,
    source="arXiv:2402.19427; hf",
)
