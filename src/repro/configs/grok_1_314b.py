"""grok-1-314b [hf:xai-org/grok-1] — MoE 8 experts top-2, GQA kv=8."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, top_k=2,
    qkv_bias=False, mlp_gated=True, activation="gelu", norm="rmsnorm",
    attn_logit_softcap=30.0,
    source="hf:xai-org/grok-1; unverified",
)
