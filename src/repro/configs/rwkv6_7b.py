"""rwkv6-7b (Finch) [arXiv:2404.05892] — attention-free, data-dependent
decay, head dim 64."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=14336, vocab_size=65536,
    rwkv_head_dim=64, mlp_gated=False, activation="relu", norm="layernorm",
    source="arXiv:2404.05892; hf",
)
