"""whisper-tiny [arXiv:2212.04356] — enc-dec transformer backbone; conv audio
frontend is a STUB (input_specs provides precomputed frame embeddings)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_head=64,
    d_ff=1536, vocab_size=51865,
    n_encoder_layers=4, cross_attention=True,
    qkv_bias=True, mlp_gated=False, activation="gelu", norm="layernorm",
    rope_fraction=0.0,            # learned positions; backbone uses none here
    n_frontend_tokens=1500,
    source="arXiv:2212.04356; unverified",
)
