"""Architecture registry: ``--arch <id>`` ids -> ModelConfig."""
from .base import ModelConfig, ShapeConfig, SHAPES, shape_applicable, smoke_config

from .qwen1_5_0_5b import CONFIG as _qwen15
from .qwen2_0_5b import CONFIG as _qwen2
from .stablelm_1_6b import CONFIG as _stablelm
from .qwen3_1_7b import CONFIG as _qwen3
from .granite_moe_3b_a800m import CONFIG as _granite
from .grok_1_314b import CONFIG as _grok
from .rwkv6_7b import CONFIG as _rwkv6
from .whisper_tiny import CONFIG as _whisper
from .recurrentgemma_2b import CONFIG as _rgemma
from .llama_3_2_vision_11b import CONFIG as _llamav
from .gpt3_175b import CONFIG as _gpt3

ARCHS = {
    "qwen1.5-0.5b": _qwen15,
    "qwen2-0.5b": _qwen2,
    "stablelm-1.6b": _stablelm,
    "qwen3-1.7b": _qwen3,
    "granite-moe-3b-a800m": _granite,
    "grok-1-314b": _grok,
    "rwkv6-7b": _rwkv6,
    "whisper-tiny": _whisper,
    "recurrentgemma-2b": _rgemma,
    "llama-3.2-vision-11b": _llamav,
}

# the paper's own model — selectable but not part of the assigned 10
EXTRA_ARCHS = {"gpt3-175b": _gpt3}


def get_config(arch: str) -> ModelConfig:
    cfg = ARCHS.get(arch) or EXTRA_ARCHS.get(arch)
    if cfg is None:
        raise KeyError(f"unknown arch '{arch}'; have {sorted(ARCHS)}")
    return cfg


def dryrun_cells():
    """All (arch, shape) pairs subject to applicability rules (DESIGN.md §5)."""
    cells = []
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            if shape_applicable(cfg, shape):
                cells.append((arch, shape.name))
    return cells


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "EXTRA_ARCHS",
           "get_config", "shape_applicable", "smoke_config", "dryrun_cells"]
