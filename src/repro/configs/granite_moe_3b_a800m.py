"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0 MoE family] —
40 experts, top-8, per-expert d_ff=512, GQA kv=8."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8,
    qkv_bias=False, mlp_gated=True, activation="silu", norm="rmsnorm",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
