"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b] — dense MHA, LayerNorm,
partial rotary (25%)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=5632, vocab_size=100352,
    qkv_bias=False, mlp_gated=True, activation="silu", norm="layernorm",
    rope_fraction=0.25, rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
