"""qwen2-0.5b [arXiv:2407.10671] — dense, GQA kv=2, QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, mlp_gated=True, activation="silu", norm="rmsnorm",
    rope_theta=1_000_000.0, tie_embeddings=True,
    source="arXiv:2407.10671; hf",
)
