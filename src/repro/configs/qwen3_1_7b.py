"""qwen3-1.7b [hf:Qwen/Qwen3 family] — dense, GQA kv=8, qk-norm, d_head=128."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=6144, vocab_size=151936,
    qkv_bias=False, qk_norm=True, mlp_gated=True, activation="silu",
    norm="rmsnorm", rope_theta=1_000_000.0, tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf",
)
