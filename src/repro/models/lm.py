"""Decoder-only (and enc-dec) LM skeleton.

Layer stacks are grouped into repeating *units* (smallest period of the
layer-kind sequence) and scanned with stacked parameters — one unit of HLO
regardless of depth (compile time + HLO size stay constant as layers grow,
which is what makes the 512-device dry-run tractable). Non-uniform archs:

    dense/moe/rwkv      unit = 1 layer
    recurrentgemma      unit = (rglru, rglru, attn), 8 units + 2 remainder
    llama-3.2-vision    unit = (attn, attn, attn, xattn, attn), 8 units
    whisper             encoder scan + decoder scan (self+cross per layer)

Public entry points (all pure functions of (cfg, params, ...)):
    init_params, forward (teacher-forced logits), loss,
    init_cache, prefill, decode_step
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from . import layers as L
from . import recurrent as R

Params = dict

TRAIN_CHUNK_Q = 512
TRAIN_CHUNK_K = 1024
VOCAB_PAD = 256      # embeddings padded so the vocab axis shards under TP


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD


def _mask_pad_logits(cfg: ModelConfig, logits):
    """Padded vocab entries must never win: -inf them (sharding-friendly
    iota-compare on the vocab axis)."""
    if logits.shape[-1] == cfg.vocab_size:
        return logits
    idx = jnp.arange(logits.shape[-1])
    return jnp.where(idx < cfg.vocab_size, logits,
                     jnp.asarray(-1e30, logits.dtype))


def _activation_spec(x):
    """Sharding constraint for scan-carry residuals: batch over (pod,data),
    d_model over model — keeps the remat-saved unit boundaries sharded
    instead of replicated (a beyond-paper optimization, EXPERIMENTS §Perf).
    Applies only under an active mesh whose axes divide the dims."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except AttributeError:      # older jax
        return None
    if am is None or not am.shape:
        return None
    from jax.sharding import PartitionSpec as P
    shape = dict(am.shape)
    batch_axes = tuple(a for a in ("pod", "data") if a in shape)
    bsz = 1
    for a in batch_axes:
        bsz *= shape[a]
    b_ok = batch_axes and x.shape[0] % bsz == 0 and x.shape[0] >= bsz
    tp_ok = "model" in shape and x.shape[-1] % shape["model"] == 0
    if not (b_ok or tp_ok):
        return None
    return P(batch_axes if b_ok else None, None,
             "model" if tp_ok else None)


ACTIVATION_SHARDING = False   # opt-in: forcing d-sharded scan carries makes
#                               XLA reshard around every matmul (measured
#                               regression, EXPERIMENTS.md §Perf iteration 2)


def _shard_activations(x):
    if not ACTIVATION_SHARDING:
        return x
    spec = _activation_spec(x)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# layer kinds / unit structure
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> list:
    kinds = []
    for i in range(cfg.n_layers):
        k = cfg.block_kind(i)
        if k == "attn":
            if cfg.cross_attention:
                k = "encdec"                   # whisper decoder layer
            elif i in cfg.cross_attn_layers:
                k = "xattn"                    # vision cross-attn layer
        kinds.append(k)
    return kinds


def unit_structure(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(unit kinds, n_units, remainder kinds)."""
    kinds = layer_kinds(cfg)
    n = len(kinds)
    for p in range(1, n + 1):
        reps = n // p
        if reps == 0:
            continue
        if all(kinds[i] == kinds[i % p] for i in range(reps * p)):
            return tuple(kinds[:p]), reps, tuple(kinds[reps * p:])
    return tuple(kinds), 1, ()


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, kind: str, key) -> Params:
    ks = jax.random.split(key, 6)
    if kind == "rwkv":
        return {"ln1": L.norm_init(cfg), "tmix": R.rwkv_tmix_init(cfg, ks[0]),
                "ln2": L.norm_init(cfg), "cmix": R.rwkv_cmix_init(cfg, ks[1])}
    if kind == "rglru":
        return {"ln1": L.norm_init(cfg), "rec": R.rglru_init(cfg, ks[0]),
                "ln2": L.norm_init(cfg), "mlp": L.mlp_init(cfg, ks[1])}
    p = {"ln1": L.norm_init(cfg), "ln2": L.norm_init(cfg)}
    if kind == "xattn":
        p["xattn"] = L.attn_init(cfg, ks[0])
        p["xgate"] = jnp.zeros((1,), jnp.float32)
        p["mlp"] = L.mlp_init(cfg, ks[1])
        return p
    p["attn"] = L.attn_init(cfg, ks[0])
    if kind == "encdec":
        p["lnx"] = L.norm_init(cfg)
        p["xattn"] = L.attn_init(cfg, ks[2])
    if cfg.n_experts:
        p["moe"] = L.moe_init(cfg, ks[1])
    else:
        p["mlp"] = L.mlp_init(cfg, ks[1])
    return p


def _mlp_or_moe(cfg: ModelConfig, p: Params, h, aux):
    if cfg.n_experts:
        y, a = L.moe_apply(cfg, p["moe"], h)
        return y, aux + a
    return L.mlp_apply(cfg, p["mlp"], h), aux


def _apply_layer_full(cfg: ModelConfig, kind: str, p: Params, x, *,
                      positions, enc_out=None, frontend=None, aux=0.0,
                      static_attn: bool = True):
    """Full-sequence (training / prefill-without-cache) layer application."""
    if kind == "rwkv":
        B = x.shape[0]
        H, N = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        prev = jnp.zeros((B, cfg.d_model), x.dtype)
        st0 = jnp.zeros((B, H, N, N), jnp.float32)
        h = L.apply_norm(cfg, p["ln1"], x)
        y, _ = R.rwkv_tmix_apply(cfg, p["tmix"], h, prev, st0)
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        y, _ = R.rwkv_cmix_apply(cfg, p["cmix"], h, prev)
        return x + y, aux
    if kind == "rglru":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, _ = R.rglru_apply(cfg, p["rec"], h)
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        y, aux = _mlp_or_moe(cfg, p, h, aux)
        return x + y, aux

    if kind == "xattn":   # vision cross-attention layer (gated)
        h = L.apply_norm(cfg, p["ln1"], x)
        q, k, v = L.attn_qkv(cfg, p["xattn"], h, kv_src=frontend)
        o = L.flash_attention(q, k, v, causal=False, static=static_attn,
                              chunk_q=TRAIN_CHUNK_Q, chunk_k=TRAIN_CHUNK_K)
        x = x + (jnp.tanh(p["xgate"])
                 * L.attn_out(p["xattn"], o)).astype(x.dtype)
        h = L.apply_norm(cfg, p["ln2"], x)
        y, aux = _mlp_or_moe(cfg, p, h, aux)
        return x + y, aux

    # self-attention (+ optional enc-dec cross attention)
    h = L.apply_norm(cfg, p["ln1"], x)
    q, k, v = L.attn_qkv(cfg, p["attn"], h, positions=positions)
    o = L.flash_attention(q, k, v, causal=True, window=cfg.attn_window,
                          logit_softcap=cfg.attn_logit_softcap,
                          static=static_attn,
                          chunk_q=TRAIN_CHUNK_Q, chunk_k=TRAIN_CHUNK_K)
    x = x + L.attn_out(p["attn"], o)
    if kind == "encdec":
        h = L.apply_norm(cfg, p["lnx"], x)
        q, k, v = L.attn_qkv(cfg, p["xattn"], h, kv_src=enc_out)
        o = L.flash_attention(q, k, v, causal=False, static=static_attn,
                              chunk_q=TRAIN_CHUNK_Q, chunk_k=TRAIN_CHUNK_K)
        x = x + L.attn_out(p["xattn"], o)
    h = L.apply_norm(cfg, p["ln2"], x)
    y, aux = _mlp_or_moe(cfg, p, h, aux)
    return x + y, aux


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    unit, n_units, rem = unit_structure(cfg)
    keys = jax.random.split(key, 8)
    vpad = padded_vocab(cfg)
    params: Params = {
        "embed": L._init(keys[0], (vpad, cfg.d_model)),
        "final_norm": L.norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = L._init(keys[1], (cfg.d_model, vpad))

    def stack_init(kind, key, n):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: _layer_init(cfg, kind, k))(ks)

    unit_keys = jax.random.split(keys[2], len(unit))
    params["units"] = {f"u{j}": stack_init(kind, unit_keys[j], n_units)
                       for j, kind in enumerate(unit)}
    rem_keys = jax.random.split(keys[3], max(len(rem), 1))
    params["rem"] = {f"r{j}": _layer_init(cfg, kind, rem_keys[j])
                     for j, kind in enumerate(rem)}
    if cfg.n_encoder_layers:
        ek = jax.random.split(keys[4], cfg.n_encoder_layers + 1)
        params["enc"] = {
            "layers": jax.vmap(lambda k: _layer_init(cfg, "attn", k))(
                jax.random.split(ek[0], cfg.n_encoder_layers)),
            "final_norm": L.norm_init(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# forward (teacher-forced, training / eval)
# ---------------------------------------------------------------------------

def _encode(cfg: ModelConfig, params: Params, frontend):
    """Whisper encoder over stubbed frame embeddings (B, Nf, d)."""
    x = frontend + L.sinusoidal_positions(frontend.shape[1],
                                          cfg.d_model).astype(frontend.dtype)

    @jax.checkpoint
    def enc_layer(x, p):
        h = L.apply_norm(cfg, p["ln1"], x)
        q, k, v = L.attn_qkv(cfg, p["attn"], h)
        o = L.flash_attention(q, k, v, causal=False, static=True)
        x = x + L.attn_out(p["attn"], o)
        h = L.apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp_apply(cfg, p["mlp"], h)
        return _shard_activations(x), None

    x, _ = lax.scan(enc_layer, _shard_activations(x), params["enc"]["layers"])
    return L.apply_norm(cfg, params["enc"]["final_norm"], x)


def forward(cfg: ModelConfig, params: Params, tokens, frontend=None,
            remat: bool = True):
    """tokens: (B, S) -> logits (B, S, V). frontend: stub modality embeds."""
    unit, n_units, rem = unit_structure(cfg)
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.rope_fraction == 0.0 and not cfg.attention_free:
        x = x + L.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    enc_out = _encode(cfg, params, frontend) if cfg.n_encoder_layers else None
    xattn_src = frontend if cfg.cross_attn_layers else None

    def unit_fn(carry, unit_params):
        x, aux = carry
        for j, kind in enumerate(unit):
            x, aux = _apply_layer_full(
                cfg, kind, unit_params[f"u{j}"], x, positions=positions,
                enc_out=enc_out, frontend=xattn_src, aux=aux)
        # remat saves the carry at unit boundaries: keep it sharded
        return (_shard_activations(x), aux), None

    scan_fn = jax.checkpoint(unit_fn) if remat else unit_fn
    (x, aux), _ = lax.scan(scan_fn, (_shard_activations(x), 0.0),
                           params["units"])
    for j, kind in enumerate(rem):
        x, aux = _apply_layer_full(cfg, kind, params["rem"][f"r{j}"], x,
                                   positions=positions, enc_out=enc_out,
                                   frontend=xattn_src, aux=aux)
    x = L.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = _mask_pad_logits(cfg, x @ head)
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Params, tokens, targets, mask=None,
            frontend=None, aux_weight: float = 0.01, z_weight: float = 1e-4):
    logits, aux = forward(cfg, params, tokens, frontend=frontend)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0] - logz
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    zl = z_weight * ((logz ** 2) * mask).sum() / denom
    total = ce + zl + aux_weight * aux
    return total, {"ce": ce, "z_loss": zl, "aux": aux,
                   "tokens": denom}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    dh, hkv = cfg.d_head, cfg.n_kv_heads
    if kind == "rwkv":
        H, N = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        return {"state": jnp.zeros((batch, H, N, N), jnp.float32),
                "sx_t": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
                "sx_c": jnp.zeros((batch, cfg.d_model), jnp.bfloat16)}
    if kind == "rglru":
        return {"h": jnp.zeros((batch, cfg.d_model), jnp.float32),
                "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1,
                                   cfg.d_model), jnp.bfloat16)}
    # KV caches are stored FUSED (B, T, Hkv*dh): the fused layout matches
    # the natural sharding of the kv projection output, so the cache
    # scatter/gather needs no resharding under TP (the per-head reshape at
    # the attend site factorizes the same tiling)
    if kind == "xattn":
        nf = max(cfg.n_frontend_tokens, 1)
        return {"xk": jnp.zeros((batch, nf, hkv * dh), jnp.bfloat16),
                "xv": jnp.zeros((batch, nf, hkv * dh), jnp.bfloat16)}
    kv_len = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    c = {"k": jnp.zeros((batch, kv_len, hkv * dh), jnp.bfloat16),
         "v": jnp.zeros((batch, kv_len, hkv * dh), jnp.bfloat16)}
    if kind == "encdec":
        nf = max(cfg.n_frontend_tokens, 1)
        c["xk"] = jnp.zeros((batch, nf, hkv * dh), jnp.bfloat16)
        c["xv"] = jnp.zeros((batch, nf, hkv * dh), jnp.bfloat16)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    unit, n_units, rem = unit_structure(cfg)

    def stack(kind):
        one = _layer_cache(cfg, kind, batch, max_len)
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a[None], (n_units,) + a.shape), one)

    cache = {"units": {f"u{j}": stack(kind) for j, kind in enumerate(unit)},
             "rem": {f"r{j}": _layer_cache(cfg, kind, batch, max_len)
                     for j, kind in enumerate(rem)},
             # per-sequence decode positions (continuous batching)
             "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.n_encoder_layers:
        cache["enc_out"] = jnp.zeros(
            (batch, max(cfg.n_frontend_tokens, 1), cfg.d_model), jnp.bfloat16)
    return cache


def _cache_pos(cfg: ModelConfig, pos, max_len: int):
    """Ring-buffer write position for windowed caches."""
    if cfg.attn_window:
        return pos % min(cfg.attn_window, max_len)
    return pos


def _apply_layer_cached(cfg: ModelConfig, kind: str, p: Params, x, cache,
                        pos, *, enc_out=None, frontend=None,
                        static_attn: bool = False):
    """Sequence chunk (prefill, pos scalar 0) or single step (decode,
    pos: (B,) per-sequence positions — continuous batching) w/ cache update.

    x: (B, S, d).
    """
    B, S, d = x.shape
    if kind == "rwkv":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, (sx, st) = R.rwkv_tmix_apply(cfg, p["tmix"], h, cache["sx_t"],
                                        cache["state"])
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        prev_c = cache["sx_c"]
        y, sxc = R.rwkv_cmix_apply(cfg, p["cmix"], h, prev_c)
        cache = {"state": st, "sx_t": sx.astype(jnp.bfloat16),
                 "sx_c": sxc.astype(jnp.bfloat16)}
        return x + y, cache
    if kind == "rglru":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, (hst, conv) = R.rglru_apply(cfg, p["rec"], h, h0=cache["h"],
                                       conv_carry=cache["conv"])
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp_apply(cfg, p["mlp"], h)
        return x, {"h": hst, "conv": conv.astype(jnp.bfloat16)}
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    if kind == "xattn":
        h = L.apply_norm(cfg, p["ln1"], x)
        if frontend is not None:   # prefill: compute cross KV once
            _, xk, xv = L.attn_qkv(cfg, p["xattn"], h, kv_src=frontend)
            cache = {"xk": xk.reshape(B, -1, hkv * dh).astype(jnp.bfloat16),
                     "xv": xv.reshape(B, -1, hkv * dh).astype(jnp.bfloat16)}
        q = h @ p["xattn"]["wq"]
        if cfg.qkv_bias:
            q = q + p["xattn"]["bq"]
        q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
        if cfg.qk_norm:
            q = L.rms_norm(q, p["xattn"]["q_norm"])
        nf = cache["xk"].shape[1]
        o = L.flash_attention(q, cache["xk"].reshape(B, nf, hkv, dh),
                              cache["xv"].reshape(B, nf, hkv, dh),
                              causal=False, static=static_attn)
        x = x + (jnp.tanh(p["xgate"])
                 * L.attn_out(p["xattn"], o)).astype(x.dtype)
        h = L.apply_norm(cfg, p["ln2"], x)
        y, _ = _mlp_or_moe(cfg, p, h, 0.0)
        return x + y, cache

    # self-attention with KV cache (+ optional enc-dec cross)
    h = L.apply_norm(cfg, p["ln1"], x)
    if S > 1:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    else:
        positions = jnp.reshape(pos, (B, 1))
    q, k, v = L.attn_qkv(cfg, p["attn"], h, positions=positions)
    max_len = cache["k"].shape[1]
    win = max_len
    new_cache = dict(cache)
    kf = k.reshape(B, S, hkv * dh).astype(jnp.bfloat16)
    vf = v.reshape(B, S, hkv * dh).astype(jnp.bfloat16)
    if S > 1:
        # prefill from position 0 (right-padded prompts; pads are after the
        # valid tokens and get overwritten as decode advances per sequence)
        if cfg.attn_window and S > win:
            slots = (jnp.arange(S - win, S)) % win
            ck = cache["k"].at[:, slots].set(kf[:, -win:])
            cv = cache["v"].at[:, slots].set(vf[:, -win:])
        else:
            ck = lax.dynamic_update_slice(cache["k"], kf, (0, 0, 0))
            cv = lax.dynamic_update_slice(cache["v"], vf, (0, 0, 0))
        new_cache["k"], new_cache["v"] = ck, cv
        o = L.flash_attention(q, k, v, causal=True, window=cfg.attn_window,
                              logit_softcap=cfg.attn_logit_softcap,
                              static=static_attn)
    else:
        wpos = _cache_pos(cfg, pos, max_len)           # (B,)
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, wpos].set(kf[:, 0])
        cv = cache["v"].at[bidx, wpos].set(vf[:, 0])
        new_cache["k"], new_cache["v"] = ck, cv
        valid = jnp.minimum(pos + 1, max_len)          # (B,)
        o = _decode_attend(cfg, q, ck.reshape(B, max_len, hkv, dh),
                           cv.reshape(B, max_len, hkv, dh), pos, valid)
    x = x + L.attn_out(p["attn"], o)
    if kind == "encdec":
        h = L.apply_norm(cfg, p["lnx"], x)
        if enc_out is not None and frontend is not None:
            _, xk, xv = L.attn_qkv(cfg, p["xattn"], h, kv_src=enc_out)
            new_cache["xk"] = xk.reshape(B, -1, hkv * dh).astype(jnp.bfloat16)
            new_cache["xv"] = xv.reshape(B, -1, hkv * dh).astype(jnp.bfloat16)
        q = h @ p["xattn"]["wq"]
        if cfg.qkv_bias:
            q = q + p["xattn"]["bq"]
        q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
        nf = new_cache["xk"].shape[1]
        o = L.flash_attention(q, new_cache["xk"].reshape(B, nf, hkv, dh),
                              new_cache["xv"].reshape(B, nf, hkv, dh),
                              causal=False, static=static_attn)
        x = x + L.attn_out(p["xattn"], o)
    h = L.apply_norm(cfg, p["ln2"], x)
    y, _ = _mlp_or_moe(cfg, p, h, 0.0)
    return x + y, new_cache


def _decode_attend(cfg: ModelConfig, q, ck, cv, pos, valid_len):
    """Single-token attention over the cache, GQA-grouped (KV read once).

    q: (B,1,Hq,dh); ck/cv: (B,T,Hkv,dh). Cache slot order may be a ring
    rotation — softmax is permutation invariant and RoPE was applied at
    write time, so ordering is irrelevant.
    """
    B, _, Hq, dh = q.shape
    Hkv = cfg.n_kv_heads
    G = max(1, Hq // Hkv)
    qg = q.reshape(B, 1, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck,
                   preferred_element_type=jnp.float32) / math.sqrt(dh)
    if cfg.attn_logit_softcap:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    k_idx = jnp.arange(ck.shape[1])
    mask = k_idx[None, :] < jnp.reshape(valid_len, (-1, 1))   # (B, T)
    s = jnp.where(mask[:, None, None, None, :], s, L.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cv.dtype), cv)
    return o.reshape(B, 1, Hq, dh)


def prefill(cfg: ModelConfig, params: Params, tokens, cache, frontend=None,
            prompt_lens=None):
    """Process right-padded prompts from position 0.

    prompt_lens: (B,) true prompt lengths (defaults to S). Returns
    (logits at each sequence's last real token, cache)."""
    unit, n_units, rem = unit_structure(cfg)
    B, S = tokens.shape
    if prompt_lens is None:
        prompt_lens = jnp.full((B,), S, jnp.int32)
    x = params["embed"][tokens]
    if cfg.rope_fraction == 0.0 and not cfg.attention_free:
        x = x + L.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = _encode(cfg, params, frontend)
        cache = dict(cache)
        cache["enc_out"] = enc_out.astype(jnp.bfloat16)
    xsrc = frontend if cfg.cross_attn_layers else None
    pos = jnp.zeros((), jnp.int32)

    def unit_fn(x, pc):
        unit_params, ucache = pc
        new_uc = {}
        for j, kind in enumerate(unit):
            x, new_uc[f"u{j}"] = _apply_layer_cached(
                cfg, kind, unit_params[f"u{j}"], x, ucache[f"u{j}"], pos,
                enc_out=enc_out, frontend=xsrc if xsrc is not None else frontend)
        return _shard_activations(x), new_uc

    x, new_units = lax.scan(unit_fn, x, (params["units"], cache["units"]))
    new_cache = dict(cache)
    new_cache["units"] = new_units
    new_rem = {}
    for j, kind in enumerate(rem):
        x, new_rem[f"r{j}"] = _apply_layer_cached(
            cfg, kind, params["rem"][f"r{j}"], x, cache["rem"][f"r{j}"], pos,
            enc_out=enc_out, frontend=xsrc if xsrc is not None else frontend)
    new_cache["rem"] = new_rem
    new_cache["pos"] = prompt_lens.astype(jnp.int32)
    # logits at each sequence's last real token
    last = jnp.clip(prompt_lens - 1, 0, S - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None].astype(jnp.int32)
                                 .repeat(x.shape[-1], -1), axis=1)
    x_last = L.apply_norm(cfg, params["final_norm"], x_last)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return _mask_pad_logits(cfg, (x_last @ head)[:, 0]), new_cache


def decode_step(cfg: ModelConfig, params: Params, token, cache):
    """token: (B,) int32. Returns (logits (B,V), cache). Per-sequence
    positions in cache["pos"] (continuous batching)."""
    unit, n_units, rem = unit_structure(cfg)
    x = params["embed"][token][:, None, :]
    pos = cache["pos"]                                   # (B,)
    if cfg.rope_fraction == 0.0 and not cfg.attention_free:
        # sinusoidal position of each sequence's current step
        d = cfg.d_model
        ang = pos[:, None].astype(jnp.float32) / jnp.power(
            10000.0, jnp.arange(0, d, 2, jnp.float32) / d)[None, :]
        pe = jnp.zeros((pos.shape[0], d), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(
            jnp.cos(ang[:, : (d - d // 2)]))
        x = x + pe[:, None, :].astype(x.dtype)
    enc_out = cache.get("enc_out")

    def unit_fn(x, pc):
        unit_params, ucache = pc
        new_uc = {}
        for j, kind in enumerate(unit):
            x, new_uc[f"u{j}"] = _apply_layer_cached(
                cfg, kind, unit_params[f"u{j}"], x, ucache[f"u{j}"], pos,
                enc_out=None, frontend=None)
        return x, new_uc

    x, new_units = lax.scan(unit_fn, x, (params["units"], cache["units"]))
    new_cache = dict(cache)
    new_cache["units"] = new_units
    new_rem = {}
    for j, kind in enumerate(rem):
        x, new_rem[f"r{j}"] = _apply_layer_cached(
            cfg, kind, params["rem"][f"r{j}"], x, cache["rem"][f"r{j}"], pos)
    new_cache["rem"] = new_rem
    new_cache["pos"] = pos + 1
    x = L.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return _mask_pad_logits(cfg, (x @ head)[:, 0]), new_cache
