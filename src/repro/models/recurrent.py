"""Recurrent blocks: RWKV6 (Finch) time/channel mix and Griffin RG-LRU.

Design notes (TPU adaptation, DESIGN.md Sec. 5):
  * WKV is a matrix-state linear recurrence. We run an outer scan over
    chunks (boundary states are the only stored residuals) with a
    checkpointed inner scan over steps — O(T/L) memory for training without
    the exp-ratio overflow issues of the fully-parallel chunked form.
  * RG-LRU is a diagonal linear recurrence -> jax.lax.associative_scan
    (O(log T) depth, differentiable).
Both have single-step forms for serving decode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .layers import Params, _init

# ---------------------------------------------------------------------------
# RWKV6 time mix
# ---------------------------------------------------------------------------

RWKV_LORA = 64


def rwkv_tmix_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    nh = d // cfg.rwkv_head_dim
    return {
        "mu": jnp.full((5, d), 0.5, jnp.bfloat16),       # r,k,v,g,w shift mix
        "wr": _init(ks[0], (d, d)),
        "wk": _init(ks[1], (d, d)),
        "wv": _init(ks[2], (d, d)),
        "wg": _init(ks[3], (d, d)),
        "wo": _init(ks[4], (d, d), scale=0.02 / math.sqrt(2 * cfg.n_layers)),
        "w0": jnp.full((d,), -6.0, jnp.float32),          # base decay (log-log)
        "w_lora_a": _init(ks[5], (d, RWKV_LORA), dtype=jnp.float32),
        "w_lora_b": _init(ks[6], (RWKV_LORA, d), dtype=jnp.float32),
        "u": _init(ks[7], (d,), scale=0.3, dtype=jnp.float32),   # bonus
        "ln_x": jnp.ones((d,), jnp.float32),              # per-head groupnorm
    }


def _token_shift(x, prev):
    """shift(x)_t = x_{t-1}; prev = last token of the previous segment."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _rwkv_inputs(cfg: ModelConfig, p: Params, x, prev):
    xs = _token_shift(x, prev)
    mixed = [x + (xs - x) * p["mu"][i] for i in range(5)]
    xr, xk, xv, xg, xw = mixed
    r = xr @ p["wr"]
    k = xk @ p["wk"]
    v = xv @ p["wv"]
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (the "Finch" contribution): w in (0,1) per channel
    dd = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(p["w0"] + dd))                   # (B,T,d), fp32
    return r, k, v, g, w


def _wkv_step(state, rkvw):
    """state: (B,H,N,N); r,k,v: (B,H,N); w: (B,H,N); u: (H,N) closure-free."""
    r, k, v, w, u = rkvw
    kv = k[..., :, None] * v[..., None, :]                # (B,H,N,N)
    out = jnp.einsum("bhn,bhnm->bhm", r, state + u[..., :, None] * kv)
    state = state * w[..., :, None] + kv
    return state, out


def wkv_scan(r, k, v, w, u, state0, chunk: int = 64):
    """Chunked, checkpointed WKV recurrence.

    r,k,v,w: (B, T, H, N) fp32; u: (H, N); state0: (B, H, N, N).
    Returns out (B, T, H, N), state_T.
    """
    B, T, H, N = r.shape
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    nC = (T + pad) // L
    # (B, nC, L, H, N) -> (nC, L, B, H, N)
    resh = lambda a: jnp.moveaxis(a.reshape(B, nC, L, H, N), 0, 2)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)

    @jax.checkpoint
    def chunk_fn(state, xs):
        rs, ks, vs, ws = xs        # (L, B, H, N)
        def step(s, t):
            return _wkv_step(s, (rs[t], ks[t], vs[t], ws[t], u))
        state, outs = lax.scan(step, state, jnp.arange(L))
        return state, outs

    state, outs = lax.scan(chunk_fn, state0, (rc, kc, vc, wc))
    # (nC, L, B, H, N) -> (B, T, H, N)
    out = jnp.moveaxis(outs.reshape(nC * L, B, H, N), 1, 0)[:, :T]
    return out, state


def rwkv_tmix_apply(cfg: ModelConfig, p: Params, x, prev_x, state0):
    """x: (B,T,d). Returns (y, (last_x, state_T))."""
    B, T, d = x.shape
    H, N = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    r, k, v, g, w = _rwkv_inputs(cfg, p, x, prev_x)
    shp = lambda a: a.astype(jnp.float32).reshape(B, T, H, N)
    u = p["u"].reshape(H, N)
    out, state = wkv_scan(shp(r), shp(k), shp(v), w.reshape(B, T, H, N),
                          u, state0)
    out = out.reshape(B, T, d)
    # per-head group norm, then gate
    out = out.reshape(B, T, H, N)
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu) * lax.rsqrt(var + 64e-5)
    out = out.reshape(B, T, d) * p["ln_x"]
    y = (out.astype(x.dtype) * g) @ p["wo"]
    return y, (x[:, -1, :], state)


def rwkv_cmix_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    ff = int(3.5 * d)
    k1, k2 = jax.random.split(key)
    return {"mu": jnp.full((2, d), 0.5, jnp.bfloat16),
            "w_up": _init(k1, (d, ff)),
            "w_down": _init(k2, (ff, d),
                            scale=0.02 / math.sqrt(2 * cfg.n_layers))}


def rwkv_cmix_apply(cfg: ModelConfig, p: Params, x, prev_x):
    xs = _token_shift(x, prev_x)
    xk = x + (xs - x) * p["mu"][0]
    h = jnp.square(jax.nn.relu(xk @ p["w_up"]))
    return h @ p["w_down"], x[:, -1, :]


# ---------------------------------------------------------------------------
# Griffin RG-LRU block
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def rglru_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_gate": _init(ks[0], (d, d)),           # gelu branch
        "w_in": _init(ks[1], (d, d)),             # recurrent branch
        "conv_w": _init(ks[2], (cfg.rglru_conv_width, d), scale=0.1),
        "conv_b": jnp.zeros((d,), jnp.bfloat16),
        "w_a": _init(ks[3], (d, d), dtype=jnp.float32),   # recurrence gate
        "w_x": _init(ks[4], (d, d), dtype=jnp.float32),   # input gate
        "lam": jnp.full((d,), 3.0, jnp.float32),          # a = sigmoid(lam)
        "w_out": _init(ks[5], (d, d), scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _causal_conv1d(u, w, b, carry=None):
    """u: (B,T,d); w: (W,d) depthwise. carry: (B,W-1,d) previous inputs."""
    W = w.shape[0]
    if carry is None:
        carry = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([carry, u], axis=1)
    out = sum(up[:, i:i + u.shape[1], :] * w[i] for i in range(W)) + b
    return out, up[:, -(W - 1):, :]


def _rglru_gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"])
    i = jax.nn.sigmoid(uf @ p["w_x"])
    log_a = -RGLRU_C * r * jax.nn.softplus(p["lam"])      # log a_t  (<= 0)
    a = jnp.exp(log_a)
    gated = i * uf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def rglru_apply(cfg: ModelConfig, p: Params, x, h0=None, conv_carry=None):
    """Full-sequence Griffin recurrent block. Returns (y, (h_T, conv_carry))."""
    B, T, d = x.shape
    gate = jax.nn.gelu((x @ p["w_gate"]), approximate=True)
    u = x @ p["w_in"]
    u, conv_carry = _causal_conv1d(u, p["conv_w"], p["conv_b"], conv_carry)
    a, b = _rglru_gates(p, u)
    if h0 is not None:
        # fold h0 into the first step: h_1 = a_1 h0 + b_1
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    aa, hh = lax.associative_scan(combine, (a, b), axis=1)
    y = (gate.astype(jnp.float32) * hh) @ p["w_out"].astype(jnp.float32)
    return y.astype(x.dtype), (hh[:, -1, :], conv_carry)


def rglru_decode_step(cfg: ModelConfig, p: Params, x, h, conv_carry):
    """x: (B,1,d). Returns (y, (h', conv_carry'))."""
    gate = jax.nn.gelu((x @ p["w_gate"]), approximate=True)
    u = x @ p["w_in"]
    u, conv_carry = _causal_conv1d(u, p["conv_w"], p["conv_b"], conv_carry)
    a, b = _rglru_gates(p, u)
    h = a[:, 0] * h + b[:, 0]
    y = (gate[:, 0].astype(jnp.float32) * h) @ p["w_out"].astype(jnp.float32)
    return y[:, None, :].astype(x.dtype), (h, conv_carry)
