"""Model-zoo primitives, pure JAX (no flax).

Conventions:
  * params are nested dicts of jnp arrays; init fns mirror apply fns.
  * activations bf16, reductions/normalizers fp32 (mixed precision).
  * attention uses the memory-efficient chunked online-softmax form
    (flash-attention algorithm) in pure jnp — this is both the production
    path the dry-run lowers (no materialized S x S scores) and the oracle
    the Pallas kernels are checked against.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig

Params = dict


def _init(key, shape, scale=0.02, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * scale).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * lax.rsqrt(var + eps) * scale + bias).astype(dt)


def norm_init(cfg: ModelConfig, dtype=jnp.float32) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(cfg: ModelConfig, p: Params, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------------------
# rotary position embedding (partial-fraction aware)
# ---------------------------------------------------------------------------

def rope_frequencies(cfg: ModelConfig) -> jnp.ndarray:
    rot = int(cfg.d_head * cfg.rope_fraction) // 2 * 2
    if rot == 0:
        return jnp.zeros((0,), jnp.float32)
    return 1.0 / (cfg.rope_theta
                  ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(cfg: ModelConfig, x, positions):
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    inv = rope_frequencies(cfg)
    rot = inv.shape[0] * 2
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv   # (.., seq, rot/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]    # broadcast over heads
    cos = cos[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    xr = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(seq: int, d: int, offset: int = 0) -> jnp.ndarray:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    out = jnp.zeros((seq, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang[:, : (d - d // 2)]))
    return out


# ---------------------------------------------------------------------------
# chunked flash attention (pure jnp oracle / production dry-run path)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, chunk_q: int = 1024, chunk_k: int = 1024,
                    logit_softcap: float = 0.0, kv_valid_len=None,
                    static: bool = False):
    """Memory-efficient attention (flash algorithm, pure jnp).

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); GQA via Hq = G * Hkv.
    q_offset: absolute position of q[0] (decode / chunked prefill).
    window: >0 limits attention to the last `window` keys (local attention).
    kv_valid_len: optional (B,) in-cache valid lengths (serving).
    static=True unrolls the (q_chunk x kv_chunk) block loop in Python —
      only visited blocks appear in the HLO (no masked-block waste) and the
      result is reverse-mode differentiable (training path, small nq).
    static=False streams kv chunks with a while_loop + block skipping
      (serving path: arbitrary lengths, not differentiable).
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    chunk_q = min(chunk_q, Sq)
    chunk_k = min(chunk_k, Sk)
    nq = -(-Sq // chunk_q)
    nk = -(-Sk // chunk_k)
    pad_q = nq * chunk_q - Sq
    pad_k = nk * chunk_k - Sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    qp = qp.reshape(B, nq, chunk_q, Hkv, G, D)
    kp = kp.reshape(B, nk, chunk_k, Hkv, D)
    vp = vp.reshape(B, nk, chunk_k, Hkv, D)

    def kv_block(carry, q_blk, q_pos, k_blk, v_blk, k_pos):
        acc, m, l = carry
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        if logit_softcap > 0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = jnp.ones((chunk_q, chunk_k), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        if kv_valid_len is not None:
            bmask = k_pos[None, :] < kv_valid_len[:, None]   # (B, chunk_k)
            s = jnp.where(bmask[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked blocks: exp(s - m) -> 0, not 1
        p = jnp.exp(s - m_new[..., None]) * (s > NEG_INF / 2)
        corr = jnp.exp(jnp.minimum(m - m_new, 0.0))
        l_new = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    def init_carry():
        return (jnp.zeros((B, Hkv, G, chunk_q, D), jnp.float32),
                jnp.full((B, Hkv, G, chunk_q), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, chunk_q), jnp.float32))

    def finish(carry):
        acc, _, l = carry
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.transpose(out, (0, 3, 1, 2, 4))   # (B, cq, Hkv, G, D)

    if static:
        # ---- python-unrolled visited blocks (differentiable) ----
        outs = []
        for qi in range(nq):
            q_blk = qp[:, qi]
            q_pos = q_offset + qi * chunk_q + jnp.arange(chunk_q)
            q_lo = q_offset + qi * chunk_q
            q_hi = q_offset + (qi + 1) * chunk_q - 1
            carry = init_carry()
            for ki in range(nk):
                k_lo, k_hi = ki * chunk_k, (ki + 1) * chunk_k - 1
                if causal and k_lo > q_hi:
                    continue                        # above the diagonal
                if window and k_hi <= q_lo - window:
                    continue                        # left of the window
                k_pos = k_lo + jnp.arange(chunk_k)
                carry = kv_block(carry, q_blk, q_pos, kp[:, ki], vp[:, ki],
                                 k_pos)
            outs.append(finish(carry))
        out = jnp.concatenate(outs, axis=1).reshape(B, nq * chunk_q, Hq, D)
        return out[:, :Sq].astype(q.dtype)

    # ---- streaming while_loop with block skip (serving) ----
    q_base = jnp.asarray(q_offset)

    def one_q_chunk(qi):
        q_blk = qp[:, qi]
        q_pos = q_base + qi * chunk_q + jnp.arange(chunk_q)
        if causal:
            last_k = jnp.minimum((q_base + (qi + 1) * chunk_q - 1)
                                 // chunk_k + 1, nk)
        else:
            last_k = jnp.asarray(nk)
        if window:
            first_k = jnp.maximum((q_base + qi * chunk_q - window + 1)
                                  // chunk_k, 0)
        else:
            first_k = jnp.asarray(0)

        def body(state):
            carry, ki = state
            k_blk = lax.dynamic_index_in_dim(kp, ki, 1, keepdims=False)
            v_blk = lax.dynamic_index_in_dim(vp, ki, 1, keepdims=False)
            k_pos = ki * chunk_k + jnp.arange(chunk_k)
            return kv_block(carry, q_blk, q_pos, k_blk, v_blk, k_pos), ki + 1

        state = (init_carry(), first_k.astype(jnp.int32))
        state = lax.while_loop(lambda s: s[1] < last_k, body, state)
        return finish(state[0])

    outs = lax.map(one_q_chunk, jnp.arange(nq))
    out = jnp.transpose(outs, (1, 0, 2, 3, 4, 5)).reshape(B, nq * chunk_q, Hq, D)
    return out[:, :Sq].astype(q.dtype)


def attention_reference(q, k, v, *, causal=True, window=0, q_offset=0,
                        logit_softcap: float = 0.0, kv_valid_len=None):
    """Naive full-score attention (small shapes / test oracle)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if logit_softcap > 0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_valid_len is not None:
        bmask = k_pos[None, :] < kv_valid_len[:, None]
        s = jnp.where(bmask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, D)


# ---------------------------------------------------------------------------
# attention block (self or cross), GQA + qk-norm + rope + bias
# ---------------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key) -> Params:
    """Separate q/k/v projections: a fused QKV matmul would have to be
    SPLIT along the TP-sharded output axis, which GSPMD lowers to
    collective-permute redistribution every layer (§Perf iteration 5)."""
    d, dh = cfg.d_model, cfg.d_head
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _init(k1, (d, cfg.n_heads * dh)),
        "wk": _init(k2, (d, cfg.n_kv_heads * dh)),
        "wv": _init(k4, (d, cfg.n_kv_heads * dh)),
        "wo": _init(k3, (cfg.n_heads * dh, d), scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), jnp.bfloat16)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.bfloat16)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.bfloat16)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def attn_qkv(cfg: ModelConfig, p: Params, x, kv_src=None, positions=None):
    """Compute rope'd q, k, v. kv_src=None -> self-attention."""
    B, S, _ = x.shape
    dh = cfg.d_head
    src = x if kv_src is None else kv_src
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, dh)
    k = k.reshape(B, src.shape[1], cfg.n_kv_heads, dh)
    v = v.reshape(B, src.shape[1], cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is not None and kv_src is None and cfg.rope_fraction > 0:
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    return q, k, v


def attn_out(p: Params, o):
    B, S = o.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# MLP (gated / plain) and MoE
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    """Gate/up projections kept separate: a fused (d, 2*ff) matmul must be
    SPLIT along the TP-sharded axis -> collective-permute per layer."""
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": _init(k1, (cfg.d_model, d_ff)),
         "w_down": _init(k2, (d_ff, cfg.d_model),
                         scale=0.02 / math.sqrt(2 * cfg.n_layers))}
    if cfg.mlp_gated:
        p["w_gate"] = _init(k3, (cfg.d_model, d_ff))
    return p


def _act(cfg: ModelConfig, x):
    if cfg.activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if cfg.activation == "relu":
        return jnp.square(jax.nn.relu(x))          # rwkv channel-mix relu^2
    return jax.nn.silu(x)


def mlp_apply(cfg: ModelConfig, p: Params, x):
    if cfg.mlp_gated:
        h = _act(cfg, x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = _act(cfg, x @ p["w_up"])
    return h @ p["w_down"]


def _moe_pad_experts(E: int) -> int:
    """Pad the expert count to a multiple of the data axis so the expert
    buffers/weights shard (EP) instead of replicating — granite's 40
    experts on a 16-wide data axis become 48 (§Perf iteration: +20% MoE
    flops on zero rows buys proper all-to-all dispatch). Runtime-only: the
    router and parameters keep the true E."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except AttributeError:
        return E
    if am is None or not am.shape:
        return E
    data = dict(am.shape).get("data", 1)
    if data <= 1 or E % data == 0:
        return E
    return -(-E // data) * data


def _moe_shard(buf):
    """Constrain the (E, capacity, d/f) expert buffer to the EP layout when
    a mesh is active: experts over the data axis (classic EP — the dispatch
    scatter lowers to an all-to-all), or, when n_experts doesn't divide it,
    the capacity axis over (pod,)data so the buffer still never
    materializes replicated."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except AttributeError:
        return buf
    if am is None or not am.shape:
        return buf
    shape = dict(am.shape)
    from jax.sharding import PartitionSpec as P
    if "data" in shape and buf.shape[0] % shape["data"] == 0 \
            and buf.shape[0] >= shape["data"]:
        return lax.with_sharding_constraint(buf, P("data", None, None))
    dp = tuple(a for a in ("pod", "data") if a in shape)
    if dp:
        n = 1
        for a in dp:
            n *= shape[a]
        if buf.shape[1] % n == 0 and buf.shape[1] >= n:
            return lax.with_sharding_constraint(
                buf, P(None, dp if len(dp) > 1 else dp[0], None))
    return buf


def moe_init(cfg: ModelConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": _init(k1, (cfg.d_model, cfg.n_experts), dtype=jnp.float32),
        "w_up": _init(k2, (cfg.n_experts, cfg.d_model, cfg.d_ff)),
        "w_down": _init(k3, (cfg.n_experts, cfg.d_ff, cfg.d_model),
                        scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.mlp_gated:
        p["w_gate"] = _init(k4, (cfg.n_experts, cfg.d_model, cfg.d_ff))
    return p


def moe_apply(cfg: ModelConfig, p: Params, x, capacity_factor: float = 1.25):
    """Top-k routed MoE with capacity + drop (Switch/GShard style).

    Sort-free scatter dispatch: tokens are gathered per expert into an
    (E, capacity, d) buffer — under expert parallelism the E axis shards and
    XLA lowers the gather/scatter to all-to-all. Returns (y, aux_loss).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, k)                    # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * T * k / E))
    # position of each (token, choice) within its expert's buffer
    flat_idx = idx.reshape(-1)                          # (T*k,)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
    pos_in_expert = jnp.cumsum(onehot, axis=0) * onehot - 1
    pos = pos_in_expert.max(-1)                         # (T*k,)
    keep = pos < capacity
    tok_rep = jnp.repeat(jnp.arange(T), k)

    # 2D scatter into the (E, capacity, d) buffer: keeps the expert axis
    # intact so it shards over the EP (data) axis; over-capacity tokens
    # fall off via mode="drop"
    E_pad = _moe_pad_experts(E)
    w_up, w_down = p["w_up"], p["w_down"]
    w_gate = p.get("w_gate")
    if E_pad != E:
        zpad = ((0, E_pad - E), (0, 0), (0, 0))
        w_up = jnp.pad(w_up, zpad)
        w_down = jnp.pad(w_down, zpad)
        if w_gate is not None:
            w_gate = jnp.pad(w_gate, zpad)
    buf = jnp.zeros((E_pad, capacity, d), xt.dtype)
    hidden = buf.at[flat_idx, jnp.where(keep, pos, capacity)].set(
        xt[tok_rep], mode="drop")
    hidden = _moe_shard(hidden)

    up = jnp.einsum("ecd,edf->ecf", hidden, w_up)
    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", hidden, w_gate)
        h = _act(cfg, g) * up
    else:
        h = _act(cfg, up)
    out = jnp.einsum("ecf,efd->ecd", h, w_down)
    out = _moe_shard(out)
    out_tok = out[flat_idx, jnp.clip(pos, 0, capacity - 1)]
    out_tok = jnp.where(keep[:, None], out_tok, 0.0)
    y = jnp.zeros((T, d), x.dtype).at[tok_rep].add(
        (out_tok * gate.reshape(-1)[:, None]).astype(x.dtype))

    # load-balancing aux loss (Switch): E * sum(frac_tokens * frac_probs)
    me = probs.mean(0)
    ce = jnp.bincount(flat_idx, length=E) / (T * k)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux
