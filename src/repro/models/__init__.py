"""Model zoo facade.

All 10 assigned architectures route through the same skeleton (lm.py) —
the layer-kind sequence derived from the ModelConfig selects dense / MoE /
RWKV / RG-LRU / cross-attention / enc-dec structure.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import layers, recurrent, lm
from .lm import (init_params, forward, loss_fn, init_cache, prefill,
                 decode_step, unit_structure, layer_kinds)


def needs_frontend(cfg: ModelConfig) -> bool:
    return cfg.family in ("audio", "vlm")


def abstract_params(cfg: ModelConfig):
    """Parameter shapes without allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def param_count(params) -> int:
    return sum(int(jnp.size(p)) if hasattr(p, "size") else 0
               for p in jax.tree.leaves(params))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> {tokens, targets, mask (+frontend)}
    prefill-> {tokens (+frontend)}
    decode -> {token} (cache comes from abstract_cache)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        spec = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
    elif shape.kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:   # decode: one new token against a seq_len-deep cache
        spec = {"token": jax.ShapeDtypeStruct((B,), i32)}
    if needs_frontend(cfg) and shape.kind != "decode":
        nf = max(cfg.n_frontend_tokens, 1)
        spec["frontend"] = jax.ShapeDtypeStruct((B, nf, cfg.d_model),
                                                jnp.bfloat16)
    return spec


__all__ = ["init_params", "forward", "loss_fn", "init_cache", "prefill",
           "decode_step", "unit_structure", "layer_kinds", "abstract_params",
           "abstract_cache", "input_specs", "needs_frontend", "param_count",
           "layers", "recurrent", "lm"]
