"""CLI for the dimensional-analysis pass: ``python -m repro.unitcheck``.

Lints the pricing core's unit annotations (core/units.py vocabulary,
core/unitcheck.py engine) and exits nonzero on error-severity diagnostics —
the CI gate. Mirrors ``python -m repro.verify``.

    PYTHONPATH=src python -m repro.unitcheck src/repro/core
    PYTHONPATH=src python -m repro.unitcheck --json report.json src/repro/core
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.core.unitcheck import RULES, check_paths, registry_selfcheck

MODES = ("error", "warn", "off")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.unitcheck",
        description="static unit/dimension checker for the pricing core")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint "
                         "(default: src/repro/core)")
    ap.add_argument("--mode", choices=MODES, default="error",
                    help="error: exit 1 on diagnostics (CI gate); "
                         "warn: report but exit 0; off: do nothing")
    ap.add_argument("--json", metavar="PATH",
                    help="write the diagnostic report as JSON")
    ap.add_argument("--selfcheck", action="store_true",
                    help="also prove every rule fires on its sample mutant")
    args = ap.parse_args(argv)

    if args.mode == "off":
        print("unitcheck: mode=off, nothing checked")
        return 0

    if args.selfcheck:
        registry_selfcheck()

    paths = args.paths or ["src/repro/core"]
    diags = check_paths(paths)

    if args.json:
        report = {
            "rules": sorted(RULES),
            "count": len(diags),
            "diagnostics": [
                {"rule": d.rule, "severity": d.severity,
                 "location": d.location, "message": d.message,
                 "hint": d.hint}
                for d in diags
            ],
        }
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")

    for d in diags:
        print(f"{d.severity}[{d.rule}] {d.location}: {d.message}"
              + (f" (hint: {d.hint})" if d.hint else ""))
    print(f"unitcheck: {len(diags)} diagnostic(s) across "
          f"{len(RULES)} rules ({', '.join(paths)})")
    if args.mode == "error" and any(d.severity == "error" for d in diags):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
