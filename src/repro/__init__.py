"""repro: LLMCompass-JAX — hardware evaluation framework for LLM inference
+ a multi-pod JAX training/serving stack planned by it. See DESIGN.md."""
__version__ = "1.0.0"
