"""End-to-end LLM inference performance model (paper Sec. III-B / IV / V).

prefill latency, per-token decode latency, end-to-end generation latency,
max batch under memory capacity, and throughput — for a System + ModelConfig
+ Plan. Pipeline parallelism follows the paper's description (sequential
stage partitions; throughput multiplies by stages once the pipeline is full,
latency gains nothing).

All entry points build symbolic IR (graph.build_model) and evaluate it with
an Evaluator; pass a shared `evaluator` to amortize the cost model across
calls (the planner does this across its whole plan sweep). `generate`
evaluates the prefill graph and every decode-KV trapezoid sample in ONE
batched evaluation — the unique GEMM shapes of all sample points go through
a single stacked mapper search.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from ..configs.base import ModelConfig
from .evaluator import Evaluator
from .fusion import SERIAL, FusionPolicy, fuse
from .hardware import System
from .graph import LayerCost, Plan, build_model
from .precision import DEFAULT, PrecisionPolicy
from .units import Bytes, Flops, PerSecond, Seconds
from . import interconnect as net


@dataclass
class PerfReport:
    latency: Seconds
    flops: Flops
    bytes: Bytes
    breakdown: Dict[str, float] = field(default_factory=dict)
    bound: Dict[str, float] = field(default_factory=dict)
    serial_latency: Seconds = 0.0   # no-overlap sum (== latency when serial)
    schedule: object = None         # per-op timeline (overlap mode, 1 graph)

    @property
    def dominant(self) -> str:
        return max(self.bound, key=self.bound.get) if self.bound else "n/a"


def _report(cost: LayerCost) -> PerfReport:
    return PerfReport(latency=cost.latency, flops=cost.flops,
                      bytes=cost.bytes, breakdown=cost.breakdown(),
                      bound=cost.by_bound(),
                      serial_latency=cost.serial_latency,
                      schedule=cost.schedule)


def _evaluator(system: System, evaluator: Optional[Evaluator],
               verify: Optional[str] = None) -> Evaluator:
    if evaluator is None:
        return Evaluator(system, verify=verify)
    if evaluator.system != system:
        raise ValueError(
            f"evaluator was built for {evaluator.system.device.name} x"
            f"{evaluator.system.device_count} but this call targets "
            f"{system.device.name} x{system.device_count}; memoized results "
            f"would price the wrong hardware")
    return evaluator


def pp_fill(system: System, plan: Plan, tokens: int, d_model: int,
            policy: PrecisionPolicy = DEFAULT) -> Seconds:
    """Pipeline fill: (pp-1) p2p activation hand-offs for the first batch.

    Public (ISSUE 3): the serving simulator prices its prefill waves and
    decode rounds with the same fill term generate() uses. Hand-offs move
    activations, so the policy's activation width prices them.
    """
    if plan.pp <= 1:
        return 0.0
    return net.p2p(system, tokens * d_model
                   * policy.activations.bytes).latency * (plan.pp - 1)


def prefill(system: System, cfg: ModelConfig, plan: Plan, batch: int,
            seq: int, evaluator: Optional[Evaluator] = None,
            policy: PrecisionPolicy = DEFAULT,
            fusion: FusionPolicy = SERIAL) -> PerfReport:
    ev = _evaluator(system, evaluator)
    cost = ev.evaluate(fuse(build_model(cfg, plan, batch, seq, kv_len=seq,
                                        policy=policy), fusion),
                       overlap=fusion.overlap)
    rep = _report(cost)
    fill: Seconds = pp_fill(system, plan, batch * seq, cfg.d_model, policy)
    rep.latency += fill
    rep.serial_latency += fill
    return rep


def decode_step(system: System, cfg: ModelConfig, plan: Plan, batch: int,
                kv_len: int, evaluator: Optional[Evaluator] = None,
                policy: PrecisionPolicy = DEFAULT,
                fusion: FusionPolicy = SERIAL) -> PerfReport:
    ev = _evaluator(system, evaluator)
    cost = ev.evaluate(fuse(build_model(cfg, plan, batch, seq=1,
                                        kv_len=kv_len, policy=policy),
                            fusion),
                       overlap=fusion.overlap)
    rep = _report(cost)
    fill: Seconds = pp_fill(system, plan, batch, cfg.d_model, policy)
    rep.latency += fill
    rep.serial_latency += fill
    return rep


def generate_graphs(cfg: ModelConfig, plan: Plan, batch: int, in_len: int,
                    out_len: int, samples: int = 8,
                    policy: PrecisionPolicy = DEFAULT,
                    fusion: FusionPolicy = SERIAL):
    """The exact symbolic graphs `generate` evaluates: the prefill graph plus
    one decode graph per KV trapezoid sample point. Exposed so study.Study
    can pre-collect every GEMM shape of a whole grid into one device-axis
    stacked mapper search before any case is priced. Returns (graphs, pts)
    where pts are the sampled KV lengths (graphs[1:] align with pts).
    Graphs come back already rewritten under `fusion`'s kernel-fusion
    rules."""
    pts = [in_len + round(i * (out_len - 1) / max(samples - 1, 1))
           for i in range(samples)]
    graphs = [build_model(cfg, plan, batch, in_len, kv_len=in_len,
                          policy=policy)] + \
        [build_model(cfg, plan, batch, seq=1, kv_len=kv, policy=policy)
         for kv in pts]
    return [fuse(g, fusion) for g in graphs], pts


def generate(system: System, cfg: ModelConfig, plan: Plan, batch: int,
             in_len: int, out_len: int, samples: int = 8,
             evaluator: Optional[Evaluator] = None,
             policy: PrecisionPolicy = DEFAULT,
             fusion: FusionPolicy = SERIAL) -> PerfReport:
    """prefill + out_len decode steps; decode latency integrated over the
    growing KV with `samples` trapezoid points (exact enough, hugely faster).

    The prefill graph and all `samples` decode graphs are evaluated in one
    batched call: their unique GEMM shapes share a single mapper search.
    `fusion` selects the execution model: kernel-fusion rewrites and/or
    overlap-scheduled (critical-path) latencies per graph.
    """
    ev = _evaluator(system, evaluator)
    graphs, pts = generate_graphs(cfg, plan, batch, in_len, out_len, samples,
                                  policy, fusion)
    costs = ev.evaluate_many(graphs, overlap=fusion.overlap)

    pf = _report(costs[0])
    pf_fill: Seconds = pp_fill(system, plan, batch * in_len, cfg.d_model,
                               policy)
    pf.latency += pf_fill
    pf.serial_latency += pf_fill
    dec_fill: Seconds = pp_fill(system, plan, batch, cfg.d_model, policy)
    lats = [c.latency + dec_fill for c in costs[1:]]
    # the no-overlap pricing of the same graphs, integrated identically so
    # PerfReport.serial_latency stays meaningful for the whole generation
    # (and bit-for-bit equal to `latency` in serial mode)
    ser_lats = [c.serial_latency + dec_fill for c in costs[1:]]

    total = pf.latency
    serial_total = pf.serial_latency
    dec = ser_dec = 0.0
    # per-sample trapezoid weights: sample i carries wts[i] of the out_len-1
    # integrated decode steps, +1 at pts[0] for the first token
    wts = [0.0] * samples
    for i in range(samples - 1):
        w = pts[i + 1] - pts[i] if i < samples - 2 \
            else out_len - 1 - (pts[i] - in_len)
        dec += (lats[i] + lats[i + 1]) / 2 * max(w, 0)
        ser_dec += (ser_lats[i] + ser_lats[i + 1]) / 2 * max(w, 0)
        wts[i] += max(w, 0) / 2
        wts[i + 1] += max(w, 0) / 2
    if out_len == 1:
        dec = ser_dec = 0.0
        wts = [0.0] * samples
    wts[0] += 1.0               # +1 first token
    total += dec + lats[0]
    serial_total += ser_dec + ser_lats[0]
    # aggregate flops/bytes/bound over prefill + the integrated decode steps
    # (the decode graphs carry the same weights their latencies were
    # integrated with), so PerfReport.dominant reflects the whole generation
    # instead of just the prefill pass
    flops, bytes_ = pf.flops, pf.bytes
    bound = dict(pf.bound)
    if pf_fill > 0:
        bound["link"] = bound.get("link", 0.0) + pf_fill
    for w, c in zip(wts, costs[1:]):
        if w <= 0:
            continue
        flops += c.flops * w
        bytes_ += c.bytes * w
        for k, v in c.by_bound().items():
            bound[k] = bound.get(k, 0.0) + v * w
        if dec_fill > 0:
            bound["link"] = bound.get("link", 0.0) + dec_fill * w
    rep = PerfReport(latency=total, flops=flops, bytes=bytes_,
                     breakdown={"prefill": pf.latency,
                                "decode": dec + lats[0]},
                     bound=bound, serial_latency=serial_total)
    return rep


# ------------------------- memory accounting ------------------------------

def memory_per_device(cfg: ModelConfig, plan: Plan, batch: int,
                      max_len: int,
                      policy: PrecisionPolicy = DEFAULT) -> Bytes:
    """Resident bytes per device under the planner memory model.

    The precision policy is the single source of truth for byte widths
    (ISSUE 4): weights at `policy.weights`, the KV cache at
    `policy.kv_cache` (this is the quantized-KV capacity lever: int8 KV
    doubles the slot budget), activations at `policy.activations`.
    Recurrent state stays fp32, matching the kernels.
    """
    wb = policy.weights.bytes
    kvb = policy.kv_cache.bytes
    param_n = cfg.param_count()
    if cfg.n_experts and plan.ep > 1:
        # expert FFN weights are sharded ep-ways: each device in the expert
        # group holds n_experts/ep experts (graph.build_mlp's e_local), so
        # only 1/ep of the expert weight bytes are resident per device
        expert_n = cfg.n_layers * cfg.n_experts * cfg.mlp_params()
        param_n = param_n - expert_n * (plan.ep - 1) / plan.ep
    params = param_n * wb / (plan.tp * plan.pp)
    # KV shards at most n_kv_heads ways: past that, tp ranks hold replicas
    # (each rank computes a distinct query-head group against a KV head that
    # also lives elsewhere — graph.build_attention's hkv = max(1, kv//tp)).
    # Dividing by tp would under-count the replicated copies; the verifier
    # notes such plans as plan.tp-kv-heads (ISSUE 7).
    kv_ways = min(plan.tp, cfg.n_kv_heads) if cfg.n_kv_heads else plan.tp
    kv = batch * max_len * cfg.kv_bytes_per_token(kvb) / (kv_ways * plan.pp)
    if cfg.attn_window:   # local attention caps the resident KV window
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.block_kind(i) == "attn")
        if n_attn:
            per_layer = cfg.kv_bytes_per_token(kvb) / n_attn
            kv = batch * min(max_len, cfg.attn_window) * per_layer * n_attn \
                / (kv_ways * plan.pp)
    # recurrent state (rwkv/rglru)
    state = 0.0
    for i in range(cfg.n_layers):
        k = cfg.block_kind(i)
        if k == "rwkv":
            state += batch * cfg.d_model * cfg.rwkv_head_dim * 4
        elif k == "rglru":
            state += batch * cfg.d_model * 4
    state /= (plan.tp * plan.pp)
    act = batch * max(1, max_len if max_len < 8192 else 8192) \
        * cfg.d_model * policy.activations.bytes * 4 / plan.tp
    return params + kv + state + act


def max_batch(system: System, cfg: ModelConfig, plan: Plan,
              max_len: int, policy: PrecisionPolicy = DEFAULT) -> int:
    """Largest batch (or serving slot count) that fits device memory —
    quantized-KV policies raise this budget."""
    cap = system.device.memory_capacity
    lo, hi = 0, 1 << 20
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if memory_per_device(cfg, plan, mid, max_len, policy) <= cap:
            lo = mid
        else:
            hi = mid - 1
    return lo


def throughput(system: System, cfg: ModelConfig, plan: Plan, batch: int,
               in_len: int, out_len: int,
               evaluator: Optional[Evaluator] = None,
               policy: PrecisionPolicy = DEFAULT,
               fusion: FusionPolicy = SERIAL) -> PerSecond:
    """Output tokens / second for the whole system (pipeline-full steady
    state: pp stages each process different microbatches concurrently)."""
    g = generate(system, cfg, plan, batch, in_len, out_len,
                 evaluator=evaluator, policy=policy, fusion=fusion)
    return throughput_from_generate(g, plan, batch, out_len)


def throughput_from_generate(g: PerfReport, plan: Plan, batch: int,
                             out_len: int) -> PerSecond:
    """Derive steady-state throughput from an existing generate() report
    (saves the planner a second full-model walk per plan)."""
    toks = batch * out_len * plan.dp
    return toks * plan.pp / g.latency if g.latency > 0 else 0.0
