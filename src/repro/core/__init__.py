"""LLMCompass core: the papers contribution as a composable library."""
from . import hardware, systolic, mapper, operators, interconnect
from . import ir, evaluator, workload
from . import area, cost, graph, inference_model, study, planner, roofline

__all__ = ["hardware", "systolic", "mapper", "operators", "interconnect",
           "ir", "evaluator", "workload",
           "area", "cost", "graph", "inference_model", "study", "planner",
           "roofline"]
