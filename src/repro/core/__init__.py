"""LLMCompass core: the papers contribution as a composable library."""
from . import hardware, systolic, mapper, operators, interconnect
from . import ir, evaluator
from . import area, cost, graph, inference_model, planner, roofline

__all__ = ["hardware", "systolic", "mapper", "operators", "interconnect",
           "ir", "evaluator",
           "area", "cost", "graph", "inference_model", "planner", "roofline"]
