"""LLMCompass core: the papers contribution as a composable library."""
from . import hardware, systolic, mapper, operators, interconnect
from . import ir, evaluator, workload, scheduler, precision
from . import area, cost, graph, inference_model, simulator, study, planner
from . import roofline, verify

__all__ = ["hardware", "systolic", "mapper", "operators", "interconnect",
           "ir", "evaluator", "workload", "scheduler", "precision",
           "area", "cost", "graph", "inference_model", "simulator", "study",
           "planner", "roofline", "verify"]
