"""Parallelism planner — the paper's model used the way Sec. IV/V uses it:
enumerate plans, keep the ones that fit memory, rank by predicted latency or
throughput. launch/serve.py and launch/train.py call this to pick TP/PP/DP.

The whole sweep shares ONE Evaluator: every candidate plan's graphs are
deduplicated against everything already evaluated, so plan #2 onward pays
only for GEMM shapes and operator extents it hasn't seen (plans that differ
only in dp re-use the entire cost model of their tp/pp siblings). Pass your
own Evaluator to inspect cache statistics afterwards.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from ..configs.base import ModelConfig
from .evaluator import Evaluator
from .hardware import System
from .graph import Plan
from . import inference_model as im


@dataclass(frozen=True)
class RankedPlan:
    plan: Plan
    latency: float          # generate latency for the probe workload
    throughput: float       # tokens/s
    memory_per_device: float
    fits: bool


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def enumerate_plans(system: System, cfg: ModelConfig,
                    max_tp: Optional[int] = None) -> List[Plan]:
    n = system.device_count
    plans = []
    for tp in _divisors(n):
        if max_tp and tp > max_tp:
            continue
        if cfg.n_heads and cfg.n_kv_heads and tp > cfg.n_kv_heads * cfg.group_size:
            continue
        for pp in _divisors(n // tp):
            dp = n // (tp * pp)
            ep = 1
            if cfg.n_experts:
                ep = math.gcd(cfg.n_experts, dp) or 1
            plans.append(Plan(tp=tp, pp=pp, dp=dp, ep=ep))
    return plans


def rank_plans(system: System, cfg: ModelConfig, batch: int, in_len: int,
               out_len: int, objective: str = "latency",
               max_tp: Optional[int] = None,
               evaluator: Optional[Evaluator] = None) -> List[RankedPlan]:
    ev = im._evaluator(system, evaluator)
    out = []
    for plan in enumerate_plans(system, cfg, max_tp=max_tp):
        b_local = max(1, batch // plan.dp)
        mem = im.memory_per_device(cfg, plan, b_local, in_len + out_len)
        fits = mem <= system.device.memory_capacity
        if not fits:
            out.append(RankedPlan(plan, math.inf, 0.0, mem, False))
            continue
        g = im.generate(system, cfg, plan, b_local, in_len, out_len,
                        evaluator=ev)
        tp_ = im.throughput_from_generate(g, plan, b_local, out_len)
        out.append(RankedPlan(plan, g.latency, tp_, mem, True))
    key = (lambda r: r.latency) if objective == "latency" \
        else (lambda r: -r.throughput)
    return sorted(out, key=key)


def best_plan(system: System, cfg: ModelConfig, batch: int, in_len: int,
              out_len: int, objective: str = "latency",
              evaluator: Optional[Evaluator] = None) -> RankedPlan:
    ranked = rank_plans(system, cfg, batch, in_len, out_len, objective,
                        evaluator=evaluator)
    fitting = [r for r in ranked if r.fits]
    if not fitting:
        raise ValueError(
            f"{cfg.name} does not fit on {system.device_count}x"
            f"{system.device.name} under any plan")
    return fitting[0]
