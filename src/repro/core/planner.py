"""Parallelism planner — the paper's model used the way Sec. IV/V uses it:
enumerate plans, keep the ones that fit memory, rank by predicted latency or
throughput. launch/serve.py and launch/train.py call this to pick TP/PP/DP.

`rank_plans` is a thin Study over the plan enumeration (ISSUE 2): one
declarative case per candidate plan, sharing ONE Evaluator across the whole
sweep, with every unique GEMM shape pre-solved in a single stacked mapper
search. Plans that differ only in dp re-use the entire cost model of their
tp/pp siblings. Pass your own Evaluator to inspect cache statistics.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional

from ..configs.base import ModelConfig
from .evaluator import Evaluator
from .fusion import SERIAL, FusionPolicy
from .hardware import System
from .graph import Plan
from .precision import DEFAULT, PrecisionPolicy
from .study import Case, Study
from .workload import Workload


@dataclass(frozen=True)
class RankedPlan:
    plan: Plan
    latency: float          # generate latency for the probe workload
    throughput: float       # tokens/s
    memory_per_device: float
    fits: bool


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def enumerate_plans(system: System, cfg: ModelConfig,
                    max_tp: Optional[int] = None) -> List[Plan]:
    """Every tp/pp/dp/ep factorization of the system, plus a
    sequence-parallel sibling for each tp>1 plan (RS+AG instead of AR, norms
    on the token shard) — SP gives the overlap scheduler a pair of
    collectives to hide behind the adjacent row-parallel GEMMs, and the
    ranking prices it like any other candidate."""
    n = system.device_count
    plans = []
    for tp in _divisors(n):
        if max_tp and tp > max_tp:
            continue
        if cfg.n_heads and cfg.n_kv_heads and tp > cfg.n_kv_heads * cfg.group_size:
            continue
        if cfg.n_heads and tp > 1 and cfg.n_heads % tp:
            # the builder shards heads as floor(n_heads/tp) per device, so a
            # non-dividing tp silently drops attention work — the verifier
            # flags such plans as plan.tp-heads errors (ISSUE 7); qwen2's 14
            # heads at tp=4 modeled only 12 before this gate
            continue
        for pp in _divisors(n // tp):
            if pp > 1 and pp > cfg.n_layers:
                # more stages than layers: ceil-sized stages would price
                # phantom layers (verifier rule plan.pp-layers)
                continue
            dp = n // (tp * pp)
            ep = 1
            if cfg.n_experts:
                ep = math.gcd(cfg.n_experts, dp) or 1
            plan = Plan(tp=tp, pp=pp, dp=dp, ep=ep)
            plans.append(plan)
            if tp > 1 and _supports_sp(cfg):
                plans.append(replace(plan, sequence_parallel=True))
    return plans


def _supports_sp(cfg: ModelConfig) -> bool:
    """Sequence parallelism is modeled for blocks that route their TP sync
    through _add_tp_collective (attention / mlp / rglru); rwkv blocks
    hardcode an all-reduce, so an SP sibling would be a mislabeled
    duplicate of its AR twin."""
    return any(cfg.block_kind(i) != "rwkv" for i in range(cfg.n_layers))


def rank_plans(system: System, cfg: ModelConfig, batch: int, in_len: int,
               out_len: int, objective: str = "latency",
               max_tp: Optional[int] = None,
               evaluator: Optional[Evaluator] = None,
               policy: PrecisionPolicy = DEFAULT,
               fusion: FusionPolicy = SERIAL) -> List[RankedPlan]:
    """Rank every candidate plan: a Study with one case per plan, splitting
    the global batch over each plan's dp replicas. `policy` prices the whole
    sweep at a quantization point — the memory-fit gate sees the quantized
    weight/KV footprint, so int8-weights plans that would not fit at fp16
    stay in the ranking. `fusion` prices it at an execution-model point:
    under FULL, sequence-parallel siblings are ranked with their RS+AG
    hidden behind the adjacent GEMMs."""
    cases = [Case(system, cfg, plan,
                  Workload(max(1, batch // plan.dp), in_len, out_len),
                  policy=policy, fusion=fusion)
             for plan in enumerate_plans(system, cfg, max_tp=max_tp)]
    res = Study(cases=cases,
                evaluators={system: evaluator} if evaluator else None).run()
    out = [RankedPlan(r.case.plan, r.latency, r.throughput,
                      r.memory_per_device, r.fits) for r in res]
    key = (lambda r: r.latency) if objective == "latency" \
        else (lambda r: -r.throughput)
    return sorted(out, key=key)


def best_plan(system: System, cfg: ModelConfig, batch: int, in_len: int,
              out_len: int, objective: str = "latency",
              evaluator: Optional[Evaluator] = None,
              policy: PrecisionPolicy = DEFAULT,
              fusion: FusionPolicy = SERIAL) -> RankedPlan:
    ranked = rank_plans(system, cfg, batch, in_len, out_len, objective,
                        evaluator=evaluator, policy=policy, fusion=fusion)
    fitting = [r for r in ranked if r.fits]
    if not fitting:
        raise ValueError(
            f"{cfg.name} does not fit on {system.device_count}x"
            f"{system.device.name} under any plan")
    return fitting[0]
