"""Mapper: performance-optimal tiling + scheduling search (paper Sec. III-B1).

Simulates C[M,N] = A[M,K] @ B[K,N] (+C) on the hardware template, recursively:

  level 2: main memory -> global buffer      (tiles Tm x Tk x Tn)
  level 1: global buffer -> cores            (subtiles Sm x Sk x Sn, wave
           schedule over cores; scheme 1 = cores own distinct C subtiles with
           merged A/B reads; scheme 2 = cores split K of one C subtile and
           reduce)
  level 0: local buffer -> lanes -> systolic array (closed-form SCALE-Sim
           cycles, see systolic.py)

Double buffering (software pipeline) is a search option at levels 2 and 1: it
overlaps load with compute (latency = max instead of sum) but halves the
usable buffer capacity (paper: "the maximal tile size will be reduced").

The search is *vectorized*: every (tile, subtile, scheme, pipeline) candidate
is evaluated in one numpy broadcast instead of the paper's per-candidate
Python loop. Same search space, orders of magnitude faster (measured in
benchmarks/mapper_speed.py).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .hardware import Device
from .systolic import gemm_cycles_array


@dataclass(frozen=True)
class Mapping:
    """Best mapping found by the search — also the Pallas BlockSpec hint."""
    tile_m: int
    tile_k: int
    tile_n: int
    subtile_m: int
    subtile_k: int
    subtile_n: int
    scheme: int                  # 1: output-parallel, 2: k-split + reduce
    double_buffer_l2: bool
    double_buffer_l1: bool
    compute_time: float
    memory_time: float

    @property
    def bound(self) -> str:
        return "compute" if self.compute_time >= self.memory_time else "memory"


@dataclass(frozen=True)
class MatmulResult:
    latency: float               # seconds, excluding kernel launch overhead
    flops: int
    main_memory_bytes: int
    mapping: Mapping
    candidates_searched: int


def _tile_candidates(dim: int, align: int, max_tiles: int = 12) -> np.ndarray:
    """Power-of-two-ish candidate tile sizes for one dimension."""
    cands = {dim}
    t = align
    while t < dim:
        cands.add(t)
        t *= 2
    # multiples of align near dim for better edge packing
    if dim > align:
        cands.add((dim + align - 1) // align * align)
    out = np.array(sorted(c for c in cands if c > 0), dtype=np.int64)
    if len(out) > max_tiles:           # keep the largest (most reuse) ones
        out = out[-max_tiles:]
    return out


@functools.lru_cache(maxsize=1 << 16)
def matmul_perf(device: Device, m: int, k: int, n: int,
                batch: int = 1, bytes_in: int = 2, bytes_out: int = 2,
                b_shared: bool = False) -> MatmulResult:
    """Search the mapping space and return the best predicted latency.

    batch: independent GEMM instances (e.g. B*H for attention score GEMMs).
      The batch dimension folds into M for scheduling (subtiles never span
      batch elements) and multiplies B-operand traffic unless b_shared.
    b_shared: all batch elements share one B operand (weight matmul with the
      activation batch folded into M should instead pass batch=1, m=B*M).
    """
    dev = device
    sa = dev.core.lane.systolic_array
    lanes = dev.core.lanes
    freq = dev.frequency_hz

    # ---------------- candidate axes ----------------
    tm = _tile_candidates(m, min(sa.rows, m))
    tk = _tile_candidates(k, min(128, k))
    tn = _tile_candidates(n, min(sa.cols, n))
    sm = _tile_candidates(m, min(sa.rows, m))
    sk = _tile_candidates(k, min(64, k))
    sn = _tile_candidates(n, min(sa.cols, n))

    # level-2 tile grid  [i2]
    TM, TK, TN = np.meshgrid(tm, tk, tn, indexing="ij")
    TM, TK, TN = TM.ravel(), TK.ravel(), TN.ravel()
    # level-1 subtile grid  [i1]
    SM, SK, SN = np.meshgrid(sm, sk, sn, indexing="ij")
    SM, SK, SN = SM.ravel(), SK.ravel(), SN.ravel()

    # pipeline options: (db2, db1) in {0,1}^2  [p]
    DB = np.array([(0, 0), (0, 1), (1, 0), (1, 1)], dtype=np.int64)

    # broadcast to [i2, i1, p]
    TM_, TK_, TN_ = (x[:, None, None] for x in (TM, TK, TN))
    SM_, SK_, SN_ = (x[None, :, None] for x in (SM, SK, SN))
    DB2 = DB[None, None, :, 0]
    DB1 = DB[None, None, :, 1]

    # ---------------- validity masks ----------------
    gb_need = (TM_ * TK_ + TK_ * TN_ + TM_ * TN_) * bytes_in * (1 + DB2)
    lb_need = (SM_ * SK_ + SK_ * SN_ + SM_ * SN_) * bytes_in * (1 + DB1)
    valid = (gb_need <= dev.global_buffer_bytes) \
        & (lb_need <= dev.core.local_buffer_bytes) \
        & (SM_ <= TM_) & (SK_ <= TK_) & (SN_ <= TN_)
    if batch > 1:
        # subtiles/tiles must not span batch elements
        valid = valid & (SM_ <= m) & (TM_ <= m)

    # ---------------- level 0: core compute time for one subtile ----------
    # subtile split across lanes on the N dimension
    sn_lane = -(-SN_ // lanes)           # ceil
    lane_cyc = gemm_cycles_array(SM_, SK_, sn_lane, sa.rows, sa.cols)
    subtile_cyc = lane_cyc               # lanes run in parallel

    # ---------------- level 1: schedule subtiles across cores -------------
    n_sub_m = -(-TM_ // SM_)
    n_sub_n = -(-TN_ // SN_)
    n_sub_k = -(-TK_ // SK_)
    cores = dev.core_count
    gb_bw_cyc = dev.global_buffer_bw_per_cycle

    # -- scheme 1: distinct C subtiles per core, k-loop inside core --------
    out_subtiles = n_sub_m * n_sub_n
    waves = -(-out_subtiles // cores)
    # per wave, ~w cores arranged over (gm x gn) subtile grid; unique A/B
    # panel reads are merged (paper: "memory access merging ... automatically
    # identified"). Use the balanced arrangement gm = min(n_sub_m, sqrt(w)).
    w = np.minimum(out_subtiles, cores)
    gm = np.minimum(n_sub_m, np.maximum(1, np.round(np.sqrt(w))).astype(np.int64))
    gn = np.minimum(n_sub_n, np.maximum(1, -(-w // gm)))
    # traffic per wave (bytes through the global buffer port):
    wave_traffic = (gm * SM_ * TK_ + gn * TK_ * SN_) * bytes_in \
        + gm * gn * SM_ * SN_ * bytes_out
    wave_mem_cyc = -(-wave_traffic // gb_bw_cyc)
    wave_cmp_cyc = n_sub_k * subtile_cyc
    s1_cyc = np.where(DB1 == 1,
                      waves * np.maximum(wave_mem_cyc, wave_cmp_cyc)
                      + np.minimum(wave_mem_cyc, wave_cmp_cyc),
                      waves * (wave_mem_cyc + wave_cmp_cyc))

    # -- scheme 2: split K of each C subtile across spare cores ------------
    ck = np.maximum(1, np.minimum(cores // np.maximum(out_subtiles, 1), n_sub_k))
    k_per_core = -(-n_sub_k // ck)
    s2_cmp_cyc = k_per_core * subtile_cyc
    # reduction: partials written + read through GB, summed on vector units
    vec_tp = dev.core.lanes * dev.core.lane.vector_unit.width
    red_traffic = (2 * (ck - 1)) * SM_ * SN_ * bytes_out
    red_cyc = -(-red_traffic // gb_bw_cyc) + \
        -(-((ck - 1) * SM_ * SN_) // np.maximum(vec_tp * cores, 1))
    s2_waves = -(-(out_subtiles * ck) // cores)
    s2_traffic = (SM_ * TK_ + TK_ * SN_) * bytes_in      # per subtile group
    s2_mem_cyc = -(-(s2_traffic * out_subtiles // np.maximum(s2_waves, 1)) // gb_bw_cyc)
    s2_cyc = np.where(DB1 == 1,
                      s2_waves * np.maximum(s2_mem_cyc, s2_cmp_cyc),
                      s2_waves * (s2_mem_cyc + s2_cmp_cyc)) + red_cyc

    use_s2 = s2_cyc < s1_cyc
    tile_cyc = np.where(use_s2, s2_cyc, s1_cyc)
    tile_time = tile_cyc / freq

    # ---------------- level 2: main memory <-> global buffer --------------
    n_t_m = -(-m // np.minimum(TM_, m))
    n_t_n = -(-n // np.minimum(TN_, n))
    n_t_k = -(-k // np.minimum(TK_, k))
    steps = batch * n_t_m * n_t_n * n_t_k
    # IO per step: A tile + B tile; C written once per (m,n) tile
    a_bytes_step = TM_ * TK_ * bytes_in
    b_bytes_step = TK_ * TN_ * bytes_in
    c_bytes_tile = TM_ * TN_ * bytes_out
    mem_bw = dev.memory_bandwidth
    step_mem_t = (a_bytes_step + b_bytes_step) / mem_bw
    c_mem_t = c_bytes_tile / mem_bw
    if b_shared and batch > 1:
        # B re-read only once per k-sweep regardless of batch
        step_mem_t = (a_bytes_step + b_bytes_step / batch) / mem_bw

    step_t = np.where(DB2 == 1,
                      np.maximum(step_mem_t, tile_time),
                      step_mem_t + tile_time)
    total_t = steps * step_t + batch * n_t_m * n_t_n * c_mem_t \
        + np.where(DB2 == 1, np.minimum(step_mem_t, tile_time), 0.0)

    total_t = np.where(valid, total_t, np.inf)

    # ---------------- pick the winner ----------------
    flat = int(np.argmin(total_t))
    i2, i1, p = np.unravel_index(flat, total_t.shape)
    best_t = float(total_t[i2, i1, p])
    if not np.isfinite(best_t):
        raise ValueError(
            f"no valid mapping for matmul {m}x{k}x{n} on {dev.name} "
            f"(buffers too small?)")

    flops = 2 * batch * m * k * n
    # actual main-memory traffic of the chosen mapping
    mm_bytes = int(batch * (n_t_m * n_t_n * n_t_k)[i2, 0, 0]
                   * (TM[i2] * TK[i2] + TK[i2] * TN[i2]) * bytes_in
                   + batch * (n_t_m * n_t_n)[i2, 0, 0] * TM[i2] * TN[i2] * bytes_out)

    mapping = Mapping(
        tile_m=int(TM[i2]), tile_k=int(TK[i2]), tile_n=int(TN[i2]),
        subtile_m=int(SM[i1]), subtile_k=int(SK[i1]), subtile_n=int(SN[i1]),
        scheme=2 if bool(use_s2[i2, i1, p]) else 1,
        double_buffer_l2=bool(DB2[0, 0, p]), double_buffer_l1=bool(DB1[0, 0, p]),
        compute_time=float((steps * tile_time)[i2, i1, p]),
        memory_time=float((steps * step_mem_t)[i2, 0, 0]
                          + (batch * n_t_m * n_t_n * c_mem_t)[i2, 0, 0]),
    )
    n_cand = int(total_t.size)
    return MatmulResult(latency=best_t, flops=flops,
                        main_memory_bytes=mm_bytes, mapping=mapping,
                        candidates_searched=n_cand)
