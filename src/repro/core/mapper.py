"""Mapper: performance-optimal tiling + scheduling search (paper Sec. III-B1).

Simulates C[M,N] = A[M,K] @ B[K,N] (+C) on the hardware template, recursively:

  level 2: main memory -> global buffer      (tiles Tm x Tk x Tn)
  level 1: global buffer -> cores            (subtiles Sm x Sk x Sn, wave
           schedule over cores; scheme 1 = cores own distinct C subtiles with
           merged A/B reads; scheme 2 = cores split K of one C subtile and
           reduce)
  level 0: local buffer -> lanes -> systolic array (closed-form SCALE-Sim
           cycles, see systolic.py)

Double buffering (software pipeline) is a search option at levels 2 and 1: it
overlaps load with compute (latency = max instead of sum) but halves the
usable buffer capacity (paper: "the maximal tile size will be reduced").

The search is *vectorized* and *batched*: every (tile, subtile, scheme,
pipeline) candidate of every requested GEMM shape is evaluated in one numpy
broadcast with a stacked shapes axis (`matmul_perf_batch`). Candidates that
violate a buffer or shape constraint are compressed away *before* the
arithmetic instead of being masked to inf afterwards, so the engine only pays
for feasible mappings — same search space, same winners, bit-identical
latencies (equivalence-tested against `matmul_perf_reference`, the paper-
faithful dense search), measured in benchmarks/mapper_speed.py.

The stacked axis also carries a *device* dimension (`matmul_perf_batch_multi`,
ISSUE 2): every hardware scalar the cost model reads (array geometry, core
count, frequency, buffer port widths, memory bandwidth) is gathered per
candidate row exactly like the shape scalars, so one broadcast solves
(device, shape) pairs across a whole design-space Study. Per-device results
are bit-identical to the single-device path (tests/test_study.py).

Backends (ISSUE 6): the chunk evaluation is split into a gather step
(`_gather_chunk`), a candidate-table computation, and a winner pick
(`_pick_winners`). The table computation has two interchangeable backends —
the default numpy broadcast (`_chunk_tables_numpy`) and a jitted JAX kernel
(`core/mapper_jax.py`) that pads chunks into power-of-two buckets so a
handful of traces serve every chunk shape. Select with
`set_mapper_backend("jax")` or REPRO_MAPPER_BACKEND=jax; winners are
backend-equivalent (tests/test_mapper_jax.py), latencies agree to float64
round-off (XLA may contract a*b+c to FMA).

Results persist (ISSUE 6): the in-memory (device, shape) memo is a bounded
LRU backed by a content-hashed on-disk cache (core/result_cache.py) keyed by
sha256(model-version salt, backend, Device, MatmulShape) — a new process
re-reads previous sessions' searches instead of re-solving them.
"""
from __future__ import annotations

import os
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, ClassVar, Dict, List, Optional, Sequence, Tuple, cast

import numpy as np

from .hardware import Device
from .obs import metrics
from .result_cache import MODEL_VERSION, DiskCache, content_key
from .systolic import gemm_cycles_array
from .units import Bytes, Flops, Seconds


@dataclass(frozen=True)
class Mapping:
    """Best mapping found by the search — also the Pallas BlockSpec hint."""
    tile_m: int
    tile_k: int
    tile_n: int
    subtile_m: int
    subtile_k: int
    subtile_n: int
    scheme: int                  # 1: output-parallel, 2: k-split + reduce
    double_buffer_l2: bool
    double_buffer_l1: bool
    compute_time: Seconds
    memory_time: Seconds

    @property
    def bound(self) -> str:
        return "compute" if self.compute_time >= self.memory_time else "memory"


@dataclass(frozen=True)
class MatmulResult:
    latency: Seconds             # excluding kernel launch overhead
    flops: Flops
    main_memory_bytes: Bytes
    mapping: Mapping
    candidates_searched: int


# GEMM shape tuple accepted by matmul_perf_batch (ISSUE 4: per-operand byte
# widths + narrow-datatype compute rate):
#   (m, k, n, batch, bytes_a, bytes_b, bytes_out, bytes_acc, b_shared,
#    mac_scale)
# bytes_a prices the A (activation) stream, bytes_b the B (weight / KV)
# stream, bytes_out the written C, bytes_acc the on-chip staging of C tiles
# and k-split partials. mac_scale divides systolic cycle counts (power of
# two: exact). All-2 widths with mac_scale 1.0 reproduce the seed search
# bit-for-bit.
MatmulShape = Tuple[int, int, int, int, float, float, float, float, bool,
                    float]


def _tile_candidates(dim: int, align: int, max_tiles: int = 12) -> np.ndarray:
    """Power-of-two-ish candidate tile sizes for one dimension.

    The set always contains the full dimension (max reuse) and, for every
    dim/align ratio within the `max_tiles` doubling budget (< ~2^11 —
    everything the framework's model graphs generate below ~50k-token LM
    heads), the hardware-native alignment tile (one systolic-array pass /
    the k-blocking granularity). Beyond the budget the LARGEST tiles are
    kept, which drops the native tile: that truncation is pinned by the
    frozen fp16 seed references (tests/data/seed_reference.json) — forcing
    the native tile back in finds slightly better mappings for huge
    embedding/LM-head GEMMs and would change frozen winners, so it must
    ride a model-version bump, not a perf PR. Coverage is asserted in
    tests/test_mapper_prune.py."""
    cands = {dim}
    t = align
    while t < dim:
        cands.add(t)
        t *= 2
    # multiples of align near dim for better edge packing
    if dim > align:
        cands.add((dim + align - 1) // align * align)
    out = np.array(sorted(c for c in cands if c > 0), dtype=np.int64)
    if len(out) > max_tiles:           # keep the largest (most reuse) ones
        out = out[-max_tiles:]
    return out


# pipeline options p = (db2, db1), in the dense search's axis order
_DB_OPTIONS = ((0, 0), (0, 1), (1, 0), (1, 1))


def _candidate_rows(dev: Device, shape: MatmulShape
                    ) -> Tuple[Tuple[Any, ...], Any, int]:
    """Feasible (tile, subtile) pairs for one GEMM shape, in dense-search
    order (level-2 index major, level-1 minor). Returns the gathered flat
    candidate arrays plus per-pipeline validity columns."""
    m, k, n, batch, bytes_a, bytes_b, bytes_out, bytes_acc, _, _ = shape
    sa = dev.core.lane.systolic_array

    tm = _tile_candidates(m, min(sa.rows, m))
    tk = _tile_candidates(k, min(128, k))
    tn = _tile_candidates(n, min(sa.cols, n))
    sm = _tile_candidates(m, min(sa.rows, m))
    sk = _tile_candidates(k, min(64, k))
    sn = _tile_candidates(n, min(sa.cols, n))

    TM, TK, TN = np.meshgrid(tm, tk, tn, indexing="ij")
    TM, TK, TN = TM.ravel(), TK.ravel(), TN.ravel()
    SM, SK, SN = np.meshgrid(sm, sk, sn, indexing="ij")
    SM, SK, SN = SM.ravel(), SK.ravel(), SN.ravel()

    # buffer residency: A/B tiles at their stream widths, C tiles at the
    # accumulator width they are staged at
    gb_need = TM * TK * bytes_a + TK * TN * bytes_b + TM * TN * bytes_acc
    lb_need = SM * SK * bytes_a + SK * SN * bytes_b + SM * SN * bytes_acc
    gb_ok = (gb_need[:, None] * (1 + np.array([0, 1], dtype=np.int64))
             <= dev.global_buffer_bytes)            # [i2, db2]
    lb_ok = (lb_need[:, None] * (1 + np.array([0, 1], dtype=np.int64))
             <= dev.core.local_buffer_bytes)        # [i1, db1]

    pair_ok = (SM[None, :] <= TM[:, None]) & (SK[None, :] <= TK[:, None]) \
        & (SN[None, :] <= TN[:, None])
    if batch > 1:
        # subtiles/tiles must not span batch elements
        pair_ok = pair_ok & (SM[None, :] <= m) & (TM[:, None] <= m)
    pair_ok = pair_ok & gb_ok.any(axis=1)[:, None] & lb_ok.any(axis=1)[None, :]

    i2, i1 = np.nonzero(pair_ok)
    n_dense = TM.size * SM.size * len(_DB_OPTIONS)
    cols = (TM[i2], TK[i2], TN[i2], SM[i1], SK[i1], SN[i1])
    p_ok = np.stack([gb_ok[i2, db2] & lb_ok[i1, db1]
                     for db2, db1 in _DB_OPTIONS], axis=1)   # [rows, p]
    return cols, p_ok, n_dense


def _gather_chunk(devs: Sequence[Device], shapes: Sequence[MatmulShape],
                  rows: Sequence[Any], p_oks: Sequence[Any]
                  ) -> Dict[str, Any]:
    """Concatenate the feasible candidates of several (device, shape) pairs
    into flat per-row arrays — the backend-independent input of the chunk
    evaluation. Device and shape scalars are gathered per candidate row;
    uniform device scalars collapse to python scalars so the single-device
    path stays cheap (bit-identical either way: numpy broadcasting of an
    equal-valued array)."""
    counts = [r[0].size for r in rows]
    offs = np.concatenate([[0], np.cumsum(counts)])

    def dscal(vals: Sequence[Any], dtype: Any = np.int64) -> Any:
        if len(set(vals)) == 1:
            return vals[0]
        return np.concatenate([np.full(c, v, dtype=dtype)
                               for c, v in zip(counts, vals)])

    # per-row gathered shape scalars (byte widths promote to float64 only
    # when a sub-byte width appears, keeping the default path on exact int64)
    def scal(idx: int, dtype: Any = np.int64) -> Any:
        vals = [s[idx] for s in shapes]
        if dtype is np.int64 and any(v != int(v) for v in vals):
            dtype = np.float64
        return np.concatenate([np.full(c, v, dtype=dtype)
                               for c, v in zip(counts, vals)])

    tm, tk, tn, sm, sk, sn = (np.concatenate([r[j] for r in rows])
                              for j in range(6))
    return {
        "counts": counts, "offs": offs,
        "tm": tm, "tk": tk, "tn": tn, "sm": sm, "sk": sk, "sn": sn,
        "p_ok": (np.concatenate(p_oks, axis=0) if p_oks
                 else np.zeros((0, 4), bool)),
        "sa_rows": dscal([d.core.lane.systolic_array.rows for d in devs]),
        "sa_cols": dscal([d.core.lane.systolic_array.cols for d in devs]),
        "lanes": dscal([d.core.lanes for d in devs]),
        "freq": dscal([d.frequency_hz for d in devs], dtype=np.float64),
        "cores": dscal([d.core_count for d in devs]),
        "gb_bw_cyc": dscal([d.global_buffer_bw_per_cycle for d in devs]),
        "mem_bw": dscal([d.memory_bandwidth for d in devs],
                        dtype=np.float64),
        "vec_tp": dscal([d.core.lanes * d.core.lane.vector_unit.width
                         for d in devs]),
        "m": scal(0), "k": scal(1), "n": scal(2), "batch": scal(3),
        "bytes_a": scal(4), "bytes_b": scal(5),
        "bytes_out": scal(6), "bytes_acc": scal(7),
        "b_shared": scal(8, dtype=bool),
        "mac_scale": scal(9, dtype=np.float64),
    }


def _chunk_tables_numpy(g: Dict[str, Any]) -> Dict[str, Any]:
    """The numpy backend: evaluate every candidate row of a gathered chunk.

    Returns the per-row tables the winner pick reads: `totals` [rows, p]
    (np.inf where the pipeline option is infeasible), `use_s2` / `tile_time`
    [rows, db1], and the level-2 step/traffic columns. core/mapper_jax.py
    computes the same tables with one jitted XLA kernel.
    """
    TM_, TK_, TN_ = g["tm"], g["tk"], g["tn"]
    SM_, SK_, SN_ = g["sm"], g["sk"], g["sn"]
    P_OK = g["p_ok"]
    sa_rows, sa_cols, lanes = g["sa_rows"], g["sa_cols"], g["lanes"]
    freq, cores, gb_bw_cyc = g["freq"], g["cores"], g["gb_bw_cyc"]
    mem_bw, vec_tp = g["mem_bw"], g["vec_tp"]
    m_v, k_v, n_v, batch_v = g["m"], g["k"], g["n"], g["batch"]
    bytes_a_v, bytes_b_v = g["bytes_a"], g["bytes_b"]
    bytes_out_v, bytes_acc_v = g["bytes_out"], g["bytes_acc"]
    bshared_v, mac_scale_v = g["b_shared"], g["mac_scale"]

    # ---------------- level 0: core compute time for one subtile ----------
    sn_lane = -(-SN_ // lanes)           # ceil: subtile split across lanes
    subtile_cyc = gemm_cycles_array(SM_, SK_, sn_lane, sa_rows, sa_cols)
    # narrow-datatype issue rate (power-of-two scale: division is exact)
    subtile_cyc = np.ceil(subtile_cyc / mac_scale_v).astype(np.int64)

    # ---------------- level 1: schedule subtiles across cores -------------
    n_sub_m = -(-TM_ // SM_)
    n_sub_n = -(-TN_ // SN_)
    n_sub_k = -(-TK_ // SK_)

    # -- scheme 1: distinct C subtiles per core, k-loop inside core --------
    out_subtiles = n_sub_m * n_sub_n
    waves = -(-out_subtiles // cores)
    w = np.minimum(out_subtiles, cores)
    gm = np.minimum(n_sub_m,
                    np.maximum(1, np.round(np.sqrt(w))).astype(np.int64))
    gn = np.minimum(n_sub_n, np.maximum(1, -(-w // gm)))
    wave_traffic = gm * SM_ * TK_ * bytes_a_v + gn * TK_ * SN_ * bytes_b_v \
        + gm * gn * SM_ * SN_ * bytes_out_v
    wave_mem_cyc = -(-wave_traffic // gb_bw_cyc)
    wave_cmp_cyc = n_sub_k * subtile_cyc
    s1_db0 = waves * (wave_mem_cyc + wave_cmp_cyc)
    s1_db1 = waves * np.maximum(wave_mem_cyc, wave_cmp_cyc) \
        + np.minimum(wave_mem_cyc, wave_cmp_cyc)

    # -- scheme 2: split K of each C subtile across spare cores ------------
    ck = np.maximum(1, np.minimum(cores // np.maximum(out_subtiles, 1),
                                  n_sub_k))
    k_per_core = -(-n_sub_k // ck)
    s2_cmp_cyc = k_per_core * subtile_cyc
    red_traffic = (2 * (ck - 1)) * SM_ * SN_ * bytes_acc_v
    red_cyc = -(-red_traffic // gb_bw_cyc) + \
        -(-((ck - 1) * SM_ * SN_) // np.maximum(vec_tp * cores, 1))
    s2_waves = -(-(out_subtiles * ck) // cores)
    s2_traffic = SM_ * TK_ * bytes_a_v + TK_ * SN_ * bytes_b_v
    s2_mem_cyc = -(-(s2_traffic * out_subtiles
                     // np.maximum(s2_waves, 1)) // gb_bw_cyc)
    s2_db0 = s2_waves * (s2_mem_cyc + s2_cmp_cyc) + red_cyc
    s2_db1 = s2_waves * np.maximum(s2_mem_cyc, s2_cmp_cyc) + red_cyc

    use_s2 = (s2_db0 < s1_db0, s2_db1 < s1_db1)
    tile_time = (np.where(use_s2[0], s2_db0, s1_db0) / freq,
                 np.where(use_s2[1], s2_db1, s1_db1) / freq)

    # ---------------- level 2: main memory <-> global buffer --------------
    n_t_m = -(-m_v // np.minimum(TM_, m_v))
    n_t_n = -(-n_v // np.minimum(TN_, n_v))
    n_t_k = -(-k_v // np.minimum(TK_, k_v))
    steps = batch_v * n_t_m * n_t_n * n_t_k
    a_bytes_step = TM_ * TK_ * bytes_a_v
    b_bytes_step = TK_ * TN_ * bytes_b_v
    c_bytes_tile = TM_ * TN_ * bytes_out_v
    # B re-read only once per k-sweep regardless of batch when b_shared
    step_mem_t = np.where(bshared_v & (batch_v > 1),
                          (a_bytes_step + b_bytes_step / batch_v) / mem_bw,
                          (a_bytes_step + b_bytes_step) / mem_bw)
    c_mem_t = c_bytes_tile / mem_bw
    c_total_t = batch_v * n_t_m * n_t_n * c_mem_t

    totals = np.empty((TM_.size, len(_DB_OPTIONS)))
    for p, (db2, db1) in enumerate(_DB_OPTIONS):
        tt = tile_time[db1]
        if db2:
            tot = steps * np.maximum(step_mem_t, tt) + c_total_t \
                + np.minimum(step_mem_t, tt)
        else:
            tot = steps * (step_mem_t + tt) + c_total_t
        totals[:, p] = np.where(P_OK[:, p], tot, np.inf)

    return {"totals": totals,
            "use_s2": np.stack(use_s2, axis=1),
            "tile_time": np.stack(tile_time, axis=1),
            "steps": steps, "step_mem_t": step_mem_t,
            "c_total_t": c_total_t,
            "n_t_m": n_t_m, "n_t_n": n_t_n, "n_t_k": n_t_k}


def _pick_winners(g: Dict[str, Any], t: Dict[str, Any],
                  devs: Sequence[Device],
                  shapes: Sequence[MatmulShape]) -> List[Tuple[Any, ...]]:
    """Select each pair's best candidate from the chunk tables (backend-
    independent: pure numpy over the returned tables)."""
    offs = g["offs"]
    TM_, TK_, TN_ = g["tm"], g["tk"], g["tn"]
    SM_, SK_, SN_ = g["sm"], g["sk"], g["sn"]
    totals, use_s2, tile_time = t["totals"], t["use_s2"], t["tile_time"]
    steps, step_mem_t, c_total_t = t["steps"], t["step_mem_t"], t["c_total_t"]
    n_t_m, n_t_n, n_t_k = t["n_t_m"], t["n_t_n"], t["n_t_k"]

    out: List[Tuple[Any, ...]] = []
    for s, shape in enumerate(shapes):
        lo, hi = int(offs[s]), int(offs[s + 1])
        seg = totals[lo:hi]
        if seg.size == 0 or not np.isfinite(seg).any():
            m, k, n = shape[0], shape[1], shape[2]
            raise ValueError(
                f"no valid mapping for matmul {m}x{k}x{n} on {devs[s].name} "
                f"(buffers too small?)")
        flat = int(np.argmin(seg))
        row, p = lo + flat // seg.shape[1], flat % seg.shape[1]
        db2, db1 = _DB_OPTIONS[p]
        m, k, n, batch, bytes_a, bytes_b, bytes_out, _, _, _ = shape
        mm_bytes = int(batch * int(n_t_m[row] * n_t_n[row] * n_t_k[row])
                       * (int(TM_[row] * TK_[row]) * bytes_a
                          + int(TK_[row] * TN_[row]) * bytes_b)
                       + batch * int(n_t_m[row] * n_t_n[row])
                       * int(TM_[row] * TN_[row]) * bytes_out)
        mapping = Mapping(
            tile_m=int(TM_[row]), tile_k=int(TK_[row]), tile_n=int(TN_[row]),
            subtile_m=int(SM_[row]), subtile_k=int(SK_[row]),
            subtile_n=int(SN_[row]),
            scheme=2 if bool(use_s2[row, db1]) else 1,
            double_buffer_l2=bool(db2), double_buffer_l1=bool(db1),
            compute_time=float(steps[row] * tile_time[row, db1]),
            memory_time=float(steps[row] * step_mem_t[row] + c_total_t[row]),
        )
        out.append((float(totals[row, p]), 2 * batch * m * k * n, mm_bytes,
                    mapping))
    return out


def _chunk_tables(g: Dict[str, Any]) -> Dict[str, Any]:
    """Candidate tables of one gathered chunk via the active backend.
    Every evaluated row is counted (`mapper.rows_evaluated`) — the pruning
    benchmarks compare this against `mapper.rows_feasible` to report how
    much of the dense-equivalent search was actually paid for."""
    _REG.inc("mapper.rows_evaluated", float(g["tm"].size))
    if _BACKEND == "jax":
        return _jax_tables(g)
    return _chunk_tables_numpy(g)


def _pair_sig(dev: Device, shape: MatmulShape) -> Tuple[Any, ...]:
    """Everything the candidate generation + cost tables read from a
    (device, shape) pair. Two pairs with equal signatures have identical
    candidate rows and identical per-row tables — e.g. devices differing
    only in name, memory capacity, or launch overhead — so one is solved
    and the winner reused (`_solve_chunk` dedupe)."""
    sa = dev.core.lane.systolic_array
    return (shape, sa.rows, sa.cols, dev.core.lanes, dev.frequency_hz,
            dev.core_count, dev.global_buffer_bw_per_cycle,
            dev.memory_bandwidth, dev.core.lane.vector_unit.width,
            dev.global_buffer_bytes, dev.core.local_buffer_bytes)


def _solve_chunk(devs: Sequence[Device], shapes: Sequence[MatmulShape],
                 rows: Sequence[Any], p_oks: Sequence[Any]
                 ) -> List[Tuple[Any, ...]]:
    """Evaluate the concatenated feasible candidates of several (device,
    shape) pairs in one broadcast and pick each pair's winner. Returns
    per-pair winner tuples. `devs[i]` is the device of `shapes[i]`.

    Pairs whose cost signatures coincide (`_pair_sig`) contribute their
    candidate rows once; duplicates reuse the solved winner (exact — the
    tables are a pure per-row function of the signature). Dedupe is part
    of the pruning layer and is bypassed when the prune knob is "off"."""
    uniq: Dict[Tuple[Any, ...], int] = {}
    owner: List[int] = []
    first: List[int] = []
    if _PRUNE != "off" and len(shapes) > 1:
        for j in range(len(shapes)):
            sig = _pair_sig(devs[j], shapes[j])
            at = uniq.get(sig)
            if at is None:
                uniq[sig] = len(first)
                owner.append(len(first))
                first.append(j)
            else:
                owner.append(at)
                _REG.inc("mapper.rows_deduped", float(rows[j][0].size))
    else:
        first = list(range(len(shapes)))
        owner = first
    g = _gather_chunk([devs[j] for j in first], [shapes[j] for j in first],
                      [rows[j] for j in first], [p_oks[j] for j in first])
    tables = _chunk_tables(g)
    _REG.inc(f"mapper.chunks_{_BACKEND}")
    won = _pick_winners(g, tables, [devs[j] for j in first],
                        [shapes[j] for j in first])
    return [won[o] for o in owner]


def _jax_tables(g: Dict[str, Any]) -> Dict[str, Any]:
    """Dispatch to the JAX backend, falling back to numpy (once, loudly)
    when jax is unavailable in this environment."""
    global _BACKEND
    try:
        from . import mapper_jax
    except Exception as e:        # jax missing or broken: degrade, keep going
        warnings.warn(f"mapper backend 'jax' unavailable ({e}); "
                      f"falling back to numpy", RuntimeWarning,
                      stacklevel=3)
        _BACKEND = "numpy"
        return _chunk_tables_numpy(g)
    return mapper_jax.chunk_tables(g)


# candidate-row budget per broadcast chunk (~25 work arrays x 8B x rows).
# 64k rows keeps the chunk working set ~10-15MB — cache-resident, measured
# ~2.7x faster than multi-hundred-MB chunks on grid-sized presolves
# (benchmarks/study_speed.py); winners are chunk-composition-independent,
# so this only moves wall-clock, never results.
_CHUNK_ROWS = 1 << 16


# ---------------------------------------------------------------------------
# backend selection (ISSUE 6)
# ---------------------------------------------------------------------------

_BACKENDS = ("numpy", "jax")
_BACKEND = os.environ.get("REPRO_MAPPER_BACKEND", "numpy").strip().lower()
if _BACKEND not in _BACKENDS:
    _BACKEND = "numpy"


def get_mapper_backend() -> str:
    """The active chunk-evaluation backend ("numpy" | "jax")."""
    return _BACKEND


def set_mapper_backend(backend: str) -> str:
    """Select the chunk-evaluation backend; returns the previous one.

    "numpy" is the default (bit-for-bit the frozen seed reference); "jax"
    pads chunks into power-of-two buckets and evaluates them with one jitted
    XLA kernel per bucket shape (core/mapper_jax.py) — winner-equivalent,
    latencies agree to float64 round-off. Raises ImportError immediately if
    jax is requested but not importable."""
    global _BACKEND
    if backend not in _BACKENDS:
        raise ValueError(f"unknown mapper backend {backend!r}; "
                         f"have {_BACKENDS}")
    if backend == "jax":
        from . import mapper_jax        # noqa: F401  (fail fast, not mid-run)
    prev = _BACKEND
    _BACKEND = backend
    return prev


# ---------------------------------------------------------------------------
# candidate pruning (ISSUE 10)
# ---------------------------------------------------------------------------
#
# The batched search evaluates every feasible candidate row. Most rows can
# be discarded without pricing them: a per-row analytic LOWER BOUND on the
# total latency — the level-2 memory time (identical formulas to the
# tables, which every pipeline option only adds to) combined with the
# device's compute roofline (a row-independent floor: the systolic array
# cannot retire more than rows*cols MACs per cycle per lane) — compared
# against an incumbent obtained by exactly pricing a handful of seed rows.
# A row whose lower bound exceeds the incumbent can neither win nor tie,
# so dropping it preserves the first-argmin winner bit-for-bit, including
# tie-breaks. `MatmulResult.candidates_searched` stays the dense-equivalent
# count either way (it describes the search SPACE, not the work done);
# the work actually paid for is reported via the registry counters
# `mapper.rows_feasible` / `mapper.rows_evaluated` / `mapper.rows_pruned`
# / `mapper.rows_deduped`.
#
# Modes: "on" (default) prunes; "off" restores the exhaustive path;
# "oracle" prunes AND re-solves the full row set, asserting the winners
# are identical (the same guarantee discipline as matmul_perf_reference).

_PRUNE_MODES = ("on", "off", "oracle")
_PRUNE = os.environ.get("REPRO_MAPPER_PRUNE", "on").strip().lower()
if _PRUNE not in _PRUNE_MODES:
    _PRUNE = "on"

#: relative slack on the lower-bound cutoff. With the numpy backend the
#: bound is exactly (monotone FP) below every total, so any positive slack
#: is safe; 2^-40 also absorbs the JAX backend's possible 1-ulp FMA
#: contraction downward of the incumbent total.
_PRUNE_EPS = 2.0 ** -40

#: seed rows exactly priced per pair to establish the incumbent
_PRUNE_SEEDS = 4


def get_mapper_prune() -> str:
    """The active pruning mode ("on" | "off" | "oracle")."""
    return _PRUNE


def set_mapper_prune(mode: str) -> str:
    """Select the candidate-pruning mode; returns the previous one.

    "on" (default; or REPRO_MAPPER_PRUNE) applies the lower-bound cutoff
    and cross-pair row dedupe, "off" restores the exhaustive evaluation,
    "oracle" runs both and raises if any winner differs — winners are
    bit-for-bit identical in all three modes."""
    global _PRUNE
    if mode not in _PRUNE_MODES:
        raise ValueError(f"unknown mapper prune mode {mode!r}; "
                         f"have {_PRUNE_MODES}")
    prev = _PRUNE
    _PRUNE = mode
    return prev


def _row_lower_bounds(dev: Device, shape: MatmulShape,
                      cols: Tuple[Any, ...]) -> Any:
    """Per-candidate-row lower bound (Seconds) on the total latency of one
    (device, shape) pair's rows.

    Memory floor: the level-2 step/write-back time, computed with the SAME
    expressions (and operand values) as `_chunk_tables_numpy` — every
    pipeline option adds non-negative compute/overlap terms to it, and FP
    monotonicity keeps the computed tables >= this computed bound.
    Compute floor: per-row subtile pass structure without the full
    `gemm_cycles_array` — a subtile's systolic cycles are at least
    `passes * (SK + 1)` (each pass pays its K-loop plus >= 1 fill/drain
    cycle) and at least its MAC count over the array's peak rate; both
    schemes schedule at least `n_sub_m * n_sub_n * n_sub_k` subtile
    computations over `cores` cores (every ceil in the tables only rounds
    up from these ratios), and every pipeline option's total is >= steps *
    tile compute time. The global roofline MACs / peak keeps the floor
    exact-shape-aware. Both floors under-estimate the true totals in exact
    arithmetic; `_PRUNE_EPS` absorbs the FP divergence."""
    TM_, TK_, TN_ = cols[0], cols[1], cols[2]
    SM_, SK_, SN_ = cols[3], cols[4], cols[5]
    m, k, n, batch, bytes_a, bytes_b, bytes_out, _, b_shared, mac_scale \
        = shape
    n_t_m = -(-m // np.minimum(TM_, m))
    n_t_n = -(-n // np.minimum(TN_, n))
    n_t_k = -(-k // np.minimum(TK_, k))
    steps = batch * n_t_m * n_t_n * n_t_k
    a_bytes_step = TM_ * TK_ * bytes_a
    b_bytes_step = TK_ * TN_ * bytes_b
    c_bytes_tile = TM_ * TN_ * bytes_out
    mem_bw = dev.memory_bandwidth
    if b_shared and batch > 1:
        step_mem_t = (a_bytes_step + b_bytes_step / batch) / mem_bw
    else:
        step_mem_t = (a_bytes_step + b_bytes_step) / mem_bw
    c_mem_t = c_bytes_tile / mem_bw
    c_total_t = batch * n_t_m * n_t_n * c_mem_t
    lb_mem = steps * step_mem_t + c_total_t

    sa = dev.core.lane.systolic_array
    lanes = dev.core.lanes
    cores = dev.core_count
    freq = dev.frequency_hz
    n_sub = (-(-TM_ // SM_)) * (-(-TN_ // SN_)) * (-(-TK_ // SK_))
    sn_lane = -(-SN_ // lanes)
    passes = (-(-SM_ // sa.rows)) * (-(-sn_lane // sa.cols))
    sub_cyc = np.maximum(passes * (SK_ + 1),
                         SM_ * SK_ * sn_lane / (sa.rows * sa.cols))
    lb_cmp_row = steps * (n_sub * sub_cyc / (mac_scale * cores * freq))
    peak_macs = float(cores) * lanes * sa.rows * sa.cols * mac_scale * freq
    lb_cmp = batch * m * k * n / peak_macs
    return np.maximum(lb_mem, np.maximum(lb_cmp_row, lb_cmp))


def _seed_rows(lb: Any) -> Any:
    """Indices of the rows exactly priced to establish the incumbent: the
    _PRUNE_SEEDS smallest lower bounds (most promising) plus the last row
    (largest tiles on every axis — the usual compute-bound winner)."""
    n = int(lb.size)
    picks = set(np.argsort(lb, kind="stable")[:min(_PRUNE_SEEDS, n)].tolist())
    picks.add(n - 1)
    return np.array(sorted(picks), dtype=np.int64)


def _prune_pairs(devs: Sequence[Device], shapes: Sequence[MatmulShape],
                 rows: Sequence[Any], p_oks: Sequence[Any]
                 ) -> Tuple[List[Tuple[Any, ...]], List[Any], int]:
    """Lower-bound cutoff over a pending chunk: exactly price each pair's
    seed rows (one batched backend call for the whole chunk), then keep
    only rows whose bound does not exceed that incumbent. Returns the
    per-pair kept rows/validity columns and the number of rows pruned.
    Winner-preserving: the winning row's bound never exceeds its own total,
    which never exceeds the incumbent; relative row order is kept, so the
    first-argmin tie-break is unchanged."""
    lbs = [_row_lower_bounds(d, s, r)
           for d, s, r in zip(devs, shapes, rows)]
    seeds = [_seed_rows(lb) for lb in lbs]
    seed_rows = [tuple(c[ix] for c in r) for r, ix in zip(rows, seeds)]
    seed_poks = [p[ix] for p, ix in zip(p_oks, seeds)]
    g = _gather_chunk(devs, shapes, seed_rows, seed_poks)
    totals = _chunk_tables(g)["totals"]
    offs = g["offs"]
    kept_rows: List[Tuple[Any, ...]] = []
    kept_poks: List[Any] = []
    n_pruned = 0
    for j, (r, p, lb) in enumerate(zip(rows, p_oks, lbs)):
        inc = float(np.min(totals[int(offs[j]):int(offs[j + 1])]))
        keep = lb <= inc * (1.0 + _PRUNE_EPS)
        n_pruned += int(r[0].size - np.count_nonzero(keep))
        kept_rows.append(tuple(c[keep] for c in r))
        kept_poks.append(p[keep])
    return kept_rows, kept_poks, n_pruned


# ---------------------------------------------------------------------------
# result memo: bounded in-memory LRU backed by the persistent disk layer
# ---------------------------------------------------------------------------

_REG = metrics()


class MapperCacheStats:
    """Accounting for the two memo layers (evaluator snapshots the deltas
    into EvalStats; benchmarks read it directly).

    Since the observability PR this is a *window* over the process-wide
    `MetricsRegistry` ``mapper.*`` counters (core/obs.py), which are the
    single source of truth: each instance reports counts accumulated since
    its own construction, so `reset_matmul_cache_stats()` (which installs a
    fresh window) behaves exactly like the old zeroed dataclass while the
    registry itself stays monotone for whole-process reporting."""

    _KEYS: ClassVar[Tuple[str, ...]] = ("memo_hits", "disk_hits", "misses",
                                        "evictions")

    def __init__(self) -> None:
        self._base: Dict[str, float] = {
            k: _REG.counter(f"mapper.{k}") for k in self._KEYS}

    def _window(self, k: str) -> int:
        return int(_REG.counter(f"mapper.{k}") - self._base[k])

    @property
    def memo_hits(self) -> int:     # served from the in-memory LRU
        return self._window("memo_hits")

    @property
    def disk_hits(self) -> int:     # served from the persistent layer
        return self._window("disk_hits")

    @property
    def misses(self) -> int:        # actually searched
        return self._window("misses")

    @property
    def evictions(self) -> int:     # LRU entries dropped at capacity
        return self._window("evictions")

    def summary(self) -> str:
        return (f"memo_hits={self.memo_hits} disk_hits={self.disk_hits} "
                f"misses={self.misses} evictions={self.evictions}")


_STATS = MapperCacheStats()

# global (device, shape) -> MatmulResult memo shared by the single-shape and
# batched entry points, so independent Evaluators never re-search a shape.
# Bounded LRU: at capacity the least-recently-used entry is evicted (the
# seed's dict silently stopped inserting instead — every later shape missed).
_MM_CACHE: "OrderedDict[Tuple[Any, ...], MatmulResult]" = OrderedDict()
_MM_CACHE_MAX = 1 << 17

_DISK: Optional[DiskCache] = None


def _disk_cache() -> DiskCache:
    """The mapper's persistent namespace (lazy; follows result_cache's
    global root/enabled switches at every access)."""
    global _DISK
    if _DISK is None:
        _DISK = DiskCache("mapper")
    return _DISK


def matmul_cache_stats() -> MapperCacheStats:
    """Live hit/miss/eviction counters of the global matmul memo."""
    return _STATS


def reset_matmul_cache_stats() -> None:
    global _STATS
    _STATS = MapperCacheStats()


def _mm_cache_put(key: Tuple[Any, ...], r: MatmulResult) -> None:
    if key in _MM_CACHE:
        _MM_CACHE.move_to_end(key)
        _MM_CACHE[key] = r
        return
    while len(_MM_CACHE) >= _MM_CACHE_MAX:
        _MM_CACHE.popitem(last=False)
        _REG.inc("mapper.evictions")
    _MM_CACHE[key] = r


# canonical Device hash fragments are stable per process — memoize by the
# (hashable, frozen) Device itself
_DEVICE_KEYS: Dict[Device, str] = {}


def _pair_key(device: Device, shape: MatmulShape) -> str:
    """Content hash of one (device, shape) search under the current model
    version and backend. The backend is part of the key: JAX latencies may
    differ from numpy in the last float64 ulp (FMA contraction), and warm
    reruns must be bit-identical to their own cold path."""
    dk = _DEVICE_KEYS.get(device)
    if dk is None:
        dk = content_key(device, salt=MODEL_VERSION)
        _DEVICE_KEYS[device] = dk
    return content_key(dk, list(shape),
                       salt=f"{MODEL_VERSION}/mapper/{_BACKEND}")


def _result_to_doc(r: MatmulResult) -> Dict[str, Any]:
    mp = r.mapping
    return {"latency": r.latency, "flops": r.flops,
            "bytes": r.main_memory_bytes, "cands": r.candidates_searched,
            "mapping": [mp.tile_m, mp.tile_k, mp.tile_n, mp.subtile_m,
                        mp.subtile_k, mp.subtile_n, mp.scheme,
                        int(mp.double_buffer_l2), int(mp.double_buffer_l1),
                        mp.compute_time, mp.memory_time]}


def _result_from_doc(doc: Dict[str, Any]) -> Optional[MatmulResult]:
    try:
        tm, tk, tn, sm, sk, sn, scheme, db2, db1, ct, mt = doc["mapping"]
        return MatmulResult(
            latency=float(doc["latency"]), flops=int(doc["flops"]),
            main_memory_bytes=int(doc["bytes"]),
            mapping=Mapping(int(tm), int(tk), int(tn), int(sm), int(sk),
                            int(sn), int(scheme), bool(db2), bool(db1),
                            float(ct), float(mt)),
            candidates_searched=int(doc["cands"]))
    except (KeyError, TypeError, ValueError):
        return None                     # malformed entry: treat as a miss


def clear_matmul_cache(disk: bool = False) -> None:
    """Drop all memoized mapper results (cold-start benchmarking).

    By default only the in-memory LRU is cleared — the persistent layer
    keeps serving across-session warmth. Pass `disk=True` to also wipe the
    on-disk mapper namespace (honest cold-start measurement)."""
    _MM_CACHE.clear()
    if disk:
        _disk_cache().clear()


def is_memoized(device: Device, shape: MatmulShape) -> bool:
    """True if this (device, shape) pair is already in the in-memory memo."""
    return (device, shape) in _MM_CACHE


def matmul_perf_batch_multi(
        pairs: Sequence[Tuple[Device, MatmulShape]]) -> List[MatmulResult]:
    """Search the mapping space of many (device, shape) GEMM pairs in stacked
    broadcasts — the device-axis generalization of `matmul_perf_batch`.

    All un-memoized pairs' feasible candidates are concatenated along one
    flat pairs x candidates axis — device scalars gathered per row exactly
    like shape scalars — and evaluated together (chunked to bound peak
    memory). A whole design-space Study (many Systems x models x workloads)
    pays the numpy dispatch overhead once per chunk instead of once per
    device per shape. Results are identical to calling matmul_perf per pair.

    Lookup order per pair: in-memory LRU, then the content-hashed disk layer
    (previous sessions' searches), then the stacked search; fresh results
    are written through to both layers.
    """
    results: List[Optional[MatmulResult]] = [None] * len(pairs)
    pend_idx: List[int] = []
    pend_rows: List[Tuple[Any, ...]] = []
    pend_poks: List[Any] = []
    pend_dense: List[int] = []
    pend_keys: List[Optional[str]] = []
    budget = 0
    disk = _disk_cache()

    def flush() -> None:
        nonlocal budget
        if not pend_idx:
            return
        devs = [pairs[i][0] for i in pend_idx]
        shapes = [pairs[i][1] for i in pend_idx]
        _REG.inc("mapper.rows_feasible",
                 float(sum(r[0].size for r in pend_rows)))
        if _PRUNE == "off":
            use_rows: Sequence[Any] = pend_rows
            use_poks: Sequence[Any] = pend_poks
        else:
            use_rows, use_poks, n_pruned = _prune_pairs(
                devs, shapes, pend_rows, pend_poks)
            _REG.inc("mapper.rows_pruned", float(n_pruned))
        solved = _solve_chunk(devs, shapes, use_rows, use_poks)
        if _PRUNE == "oracle":
            full = _solve_chunk(devs, shapes, pend_rows, pend_poks)
            for (a, b), dev, shape in zip(zip(solved, full), devs, shapes):
                if a != b:
                    raise RuntimeError(
                        f"pruning oracle mismatch for matmul "
                        f"{shape[0]}x{shape[1]}x{shape[2]} on {dev.name}: "
                        f"pruned {a[0]!r}/{a[3]!r} != full {b[0]!r}/{b[3]!r}")
            solved = full
        for i, nd, key, (lat, flops, mm_bytes, mapping) in zip(
                pend_idx, pend_dense, pend_keys, solved):
            r = MatmulResult(latency=lat, flops=flops,
                             main_memory_bytes=mm_bytes,
                             mapping=mapping, candidates_searched=nd)
            results[i] = r
            _mm_cache_put(pairs[i], r)
            if key is not None:
                disk.put(key, _result_to_doc(r))
        pend_idx.clear()
        pend_rows.clear()
        pend_poks.clear()
        pend_dense.clear()
        pend_keys.clear()
        budget = 0

    for i, (device, shape) in enumerate(pairs):
        hit = _MM_CACHE.get((device, shape))
        if hit is not None:
            _MM_CACHE.move_to_end((device, shape))
            _REG.inc("mapper.memo_hits")
            results[i] = hit
            continue
        key: Optional[str] = None
        if disk.enabled:
            key = _pair_key(device, shape)
            doc = disk.get(key)
            r = _result_from_doc(doc) if doc is not None else None
            if r is not None:
                _REG.inc("mapper.disk_hits")
                _mm_cache_put((device, shape), r)
                results[i] = r
                continue
        _REG.inc("mapper.misses")
        cols, p_ok, n_dense = _candidate_rows(device, shape)
        pend_idx.append(i)
        pend_rows.append(cols)
        pend_poks.append(p_ok)
        pend_dense.append(n_dense)
        pend_keys.append(key)
        budget += cols[0].size
        if budget >= _CHUNK_ROWS:
            flush()
    flush()
    return cast(List[MatmulResult], results)


def matmul_perf_batch(device: Device,
                      shapes: Sequence[MatmulShape]) -> List[MatmulResult]:
    """Search the mapping space of many GEMM shapes of one device in stacked
    broadcasts (the single-device view of `matmul_perf_batch_multi`)."""
    return matmul_perf_batch_multi([(device, s) for s in shapes])


def matmul_perf(device: Device, m: int, k: int, n: int,
                batch: int = 1, bytes_a: float = 2, bytes_b: float = 2,
                bytes_out: float = 2, bytes_acc: float = 2,
                b_shared: bool = False,
                mac_scale: float = 1.0) -> MatmulResult:
    """Search the mapping space and return the best predicted latency.
    Memoized through the shared (device, shape) cache in matmul_perf_batch.

    batch: independent GEMM instances (e.g. B*H for attention score GEMMs).
      The batch dimension folds into M for scheduling (subtiles never span
      batch elements) and multiplies B-operand traffic unless b_shared.
    b_shared: all batch elements share one B operand (weight matmul with the
      activation batch folded into M should instead pass batch=1, m=B*M).
    bytes_a/bytes_b/bytes_out/bytes_acc, mac_scale: per-operand widths and
      narrow-datatype issue rate (ISSUE 4) — see MatmulShape.
    """
    return matmul_perf_batch(
        device, [(m, k, n, batch, bytes_a, bytes_b, bytes_out, bytes_acc,
                  b_shared, mac_scale)])[0]


def matmul_perf_reference(device: Device, m: int, k: int, n: int,
                          batch: int = 1, bytes_a: float = 2,
                          bytes_b: float = 2, bytes_out: float = 2,
                          bytes_acc: float = 2, b_shared: bool = False,
                          mac_scale: float = 1.0) -> MatmulResult:
    """The original dense broadcast search, kept as the equivalence oracle
    for the compressed/batched engine (tests/test_ir_evaluator.py) — it
    evolves in lock-step with the engine (per-operand widths + mac_scale in
    ISSUE 4) but keeps the seed's evaluate-everything structure: every
    candidate including infeasible ones is priced (masked to inf)."""
    dev = device
    sa = dev.core.lane.systolic_array
    lanes = dev.core.lanes
    freq = dev.frequency_hz

    # ---------------- candidate axes ----------------
    tm = _tile_candidates(m, min(sa.rows, m))
    tk = _tile_candidates(k, min(128, k))
    tn = _tile_candidates(n, min(sa.cols, n))
    sm = _tile_candidates(m, min(sa.rows, m))
    sk = _tile_candidates(k, min(64, k))
    sn = _tile_candidates(n, min(sa.cols, n))

    # level-2 tile grid  [i2]
    TM, TK, TN = np.meshgrid(tm, tk, tn, indexing="ij")
    TM, TK, TN = TM.ravel(), TK.ravel(), TN.ravel()
    # level-1 subtile grid  [i1]
    SM, SK, SN = np.meshgrid(sm, sk, sn, indexing="ij")
    SM, SK, SN = SM.ravel(), SK.ravel(), SN.ravel()

    # pipeline options: (db2, db1) in {0,1}^2  [p]
    DB = np.array(_DB_OPTIONS, dtype=np.int64)

    # broadcast to [i2, i1, p]
    TM_, TK_, TN_ = (x[:, None, None] for x in (TM, TK, TN))
    SM_, SK_, SN_ = (x[None, :, None] for x in (SM, SK, SN))
    DB2 = DB[None, None, :, 0]
    DB1 = DB[None, None, :, 1]

    # ---------------- validity masks ----------------
    gb_need = (TM_ * TK_ * bytes_a + TK_ * TN_ * bytes_b
               + TM_ * TN_ * bytes_acc) * (1 + DB2)
    lb_need = (SM_ * SK_ * bytes_a + SK_ * SN_ * bytes_b
               + SM_ * SN_ * bytes_acc) * (1 + DB1)
    valid = (gb_need <= dev.global_buffer_bytes) \
        & (lb_need <= dev.core.local_buffer_bytes) \
        & (SM_ <= TM_) & (SK_ <= TK_) & (SN_ <= TN_)
    if batch > 1:
        # subtiles/tiles must not span batch elements
        valid = valid & (SM_ <= m) & (TM_ <= m)

    # ---------------- level 0: core compute time for one subtile ----------
    # subtile split across lanes on the N dimension
    sn_lane = -(-SN_ // lanes)           # ceil
    lane_cyc = gemm_cycles_array(SM_, SK_, sn_lane, sa.rows, sa.cols)
    # narrow-datatype issue rate (power-of-two scale: division is exact)
    lane_cyc = np.ceil(lane_cyc / mac_scale).astype(np.int64)
    subtile_cyc = lane_cyc               # lanes run in parallel

    # ---------------- level 1: schedule subtiles across cores -------------
    n_sub_m = -(-TM_ // SM_)
    n_sub_n = -(-TN_ // SN_)
    n_sub_k = -(-TK_ // SK_)
    cores = dev.core_count
    gb_bw_cyc = dev.global_buffer_bw_per_cycle

    # -- scheme 1: distinct C subtiles per core, k-loop inside core --------
    out_subtiles = n_sub_m * n_sub_n
    waves = -(-out_subtiles // cores)
    w = np.minimum(out_subtiles, cores)
    gm = np.minimum(n_sub_m,
                    np.maximum(1, np.round(np.sqrt(w))).astype(np.int64))
    gn = np.minimum(n_sub_n, np.maximum(1, -(-w // gm)))
    wave_traffic = gm * SM_ * TK_ * bytes_a + gn * TK_ * SN_ * bytes_b \
        + gm * gn * SM_ * SN_ * bytes_out
    wave_mem_cyc = -(-wave_traffic // gb_bw_cyc)
    wave_cmp_cyc = n_sub_k * subtile_cyc
    s1_cyc = np.where(DB1 == 1,
                      waves * np.maximum(wave_mem_cyc, wave_cmp_cyc)
                      + np.minimum(wave_mem_cyc, wave_cmp_cyc),
                      waves * (wave_mem_cyc + wave_cmp_cyc))

    # -- scheme 2: split K of each C subtile across spare cores ------------
    ck = np.maximum(1, np.minimum(cores // np.maximum(out_subtiles, 1),
                                  n_sub_k))
    k_per_core = -(-n_sub_k // ck)
    s2_cmp_cyc = k_per_core * subtile_cyc
    # reduction: partials written + read through GB, summed on vector units
    vec_tp = dev.core.lanes * dev.core.lane.vector_unit.width
    red_traffic = (2 * (ck - 1)) * SM_ * SN_ * bytes_acc
    red_cyc = -(-red_traffic // gb_bw_cyc) + \
        -(-((ck - 1) * SM_ * SN_) // np.maximum(vec_tp * cores, 1))
    s2_waves = -(-(out_subtiles * ck) // cores)
    s2_traffic = SM_ * TK_ * bytes_a + TK_ * SN_ * bytes_b  # per subtile grp
    s2_mem_cyc = -(-(s2_traffic * out_subtiles
                     // np.maximum(s2_waves, 1)) // gb_bw_cyc)
    s2_cyc = np.where(DB1 == 1,
                      s2_waves * np.maximum(s2_mem_cyc, s2_cmp_cyc),
                      s2_waves * (s2_mem_cyc + s2_cmp_cyc)) + red_cyc

    use_s2 = s2_cyc < s1_cyc
    tile_cyc = np.where(use_s2, s2_cyc, s1_cyc)
    tile_time = tile_cyc / freq

    # ---------------- level 2: main memory <-> global buffer --------------
    n_t_m = -(-m // np.minimum(TM_, m))
    n_t_n = -(-n // np.minimum(TN_, n))
    n_t_k = -(-k // np.minimum(TK_, k))
    steps = batch * n_t_m * n_t_n * n_t_k
    # IO per step: A tile + B tile; C written once per (m,n) tile
    a_bytes_step = TM_ * TK_ * bytes_a
    b_bytes_step = TK_ * TN_ * bytes_b
    c_bytes_tile = TM_ * TN_ * bytes_out
    mem_bw = dev.memory_bandwidth
    step_mem_t = (a_bytes_step + b_bytes_step) / mem_bw
    c_mem_t = c_bytes_tile / mem_bw
    if b_shared and batch > 1:
        # B re-read only once per k-sweep regardless of batch
        step_mem_t = (a_bytes_step + b_bytes_step / batch) / mem_bw

    step_t = np.where(DB2 == 1,
                      np.maximum(step_mem_t, tile_time),
                      step_mem_t + tile_time)
    total_t = steps * step_t + batch * n_t_m * n_t_n * c_mem_t \
        + np.where(DB2 == 1, np.minimum(step_mem_t, tile_time), 0.0)

    total_t = np.where(valid, total_t, np.inf)

    # ---------------- pick the winner ----------------
    flat = int(np.argmin(total_t))
    i2, i1, p = np.unravel_index(flat, total_t.shape)
    best_t = float(total_t[i2, i1, p])
    if not np.isfinite(best_t):
        raise ValueError(
            f"no valid mapping for matmul {m}x{k}x{n} on {dev.name} "
            f"(buffers too small?)")

    flops = 2 * batch * m * k * n
    # actual main-memory traffic of the chosen mapping
    mm_bytes = int(batch * (n_t_m * n_t_n * n_t_k)[i2, 0, 0]
                   * (TM[i2] * TK[i2] * bytes_a + TK[i2] * TN[i2] * bytes_b)
                   + batch * (n_t_m * n_t_n)[i2, 0, 0] * TM[i2] * TN[i2]
                   * bytes_out)

    mapping = Mapping(
        tile_m=int(TM[i2]), tile_k=int(TK[i2]), tile_n=int(TN[i2]),
        subtile_m=int(SM[i1]), subtile_k=int(SK[i1]), subtile_n=int(SN[i1]),
        scheme=2 if bool(use_s2[i2, i1, p]) else 1,
        double_buffer_l2=bool(DB2[0, 0, p]),
        double_buffer_l1=bool(DB1[0, 0, p]),
        compute_time=float((steps * tile_time)[i2, i1, p]),
        memory_time=float((steps * step_mem_t)[i2, 0, 0]
                          + (batch * n_t_m * n_t_n * c_mem_t)[i2, 0, 0]),
    )
    n_cand = int(total_t.size)
    return MatmulResult(latency=best_t, flops=flops,
                        main_memory_bytes=mm_bytes, mapping=mapping,
                        candidates_searched=n_cand)
