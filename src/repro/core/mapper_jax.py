"""JAX backend for the mapper's chunk evaluation (ISSUE 6).

The compressed candidate search is embarrassingly data-parallel: every
feasible (tile, subtile, pipeline) row of a chunk is priced independently by
~30 elementwise int64/float64 ops. This module evaluates those rows with one
`jax.jit`-compiled XLA kernel instead of a numpy broadcast chain, which fuses
the whole table computation into a single pass over the rows (numpy
materializes ~25 intermediate arrays per chunk).

Padding buckets: jit recompiles per input shape, and chunk row counts vary
with every (device, shape) mix. Chunks are therefore padded up to the next
power-of-two bucket (min 4096 rows) with infeasible filler rows (`p_ok` all
False — they price to inf and belong to no pair's segment), so a handful of
traces serve every chunk the engine will ever build. The ISSUE 10 pruning
layer (mapper._prune_pairs) needs nothing special here: its seed-row
chunks and cutoff-filtered chunks are ordinary row sets that land in the
same buckets, and because no table op reduces across rows, dropping rows
cannot change any surviving row's total. Dtype mix (int64 byte
widths vs float64 sub-byte widths) keys its own trace, exactly mirroring the
numpy path's dtype promotion rule.

Numerics: the kernel runs under `jax.experimental.enable_x64` so every
intermediate matches the numpy path's dtype (int64 ceil-divisions are exact;
float64 elementwise ops are IEEE). There are no reductions anywhere in the
table computation, so XLA cannot reassociate sums; the one documented
divergence is FMA contraction of `a*b + c` patterns, which can move a
latency by its last ulp. Winners are therefore compared exactly and
latencies to 1e-12 relative in the equivalence gate
(tests/test_mapper_jax.py / benchmarks/mapper_speed.py); warm-cache reruns
are bit-identical to their own backend's cold path because the persistent
layer keys on the backend (mapper._pair_key).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from .systolic import gemm_cycles_array

#: smallest padding bucket — below this, trace count would grow while the
#: per-call win over numpy is already negligible
_MIN_BUCKET = 1 << 12

# pipeline options (db2, db1) — must match mapper._DB_OPTIONS order
_DB_OPTIONS = ((0, 0), (0, 1), (1, 0), (1, 1))

#: the gathered per-row columns the kernel consumes, in a fixed order
_INT_COLS = ("tm", "tk", "tn", "sm", "sk", "sn", "sa_rows", "sa_cols",
             "lanes", "cores", "gb_bw_cyc", "vec_tp", "m", "k", "n", "batch")
_FLT_COLS = ("freq", "mem_bw", "mac_scale")
_DYN_COLS = ("bytes_a", "bytes_b", "bytes_out", "bytes_acc")  # int OR float


@jax.jit
def _tables_kernel(g: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """One fused pass over a padded bucket of candidate rows. Mirrors
    mapper._chunk_tables_numpy statement for statement."""
    TM_, TK_, TN_ = g["tm"], g["tk"], g["tn"]
    SM_, SK_, SN_ = g["sm"], g["sk"], g["sn"]
    P_OK = g["p_ok"]
    sa_rows, sa_cols, lanes = g["sa_rows"], g["sa_cols"], g["lanes"]
    freq, cores, gb_bw_cyc = g["freq"], g["cores"], g["gb_bw_cyc"]
    mem_bw, vec_tp = g["mem_bw"], g["vec_tp"]
    m_v, k_v, n_v, batch_v = g["m"], g["k"], g["n"], g["batch"]
    bytes_a_v, bytes_b_v = g["bytes_a"], g["bytes_b"]
    bytes_out_v, bytes_acc_v = g["bytes_out"], g["bytes_acc"]
    bshared_v, mac_scale_v = g["b_shared"], g["mac_scale"]

    # ---------------- level 0: core compute time for one subtile ----------
    sn_lane = -(-SN_ // lanes)
    subtile_cyc = gemm_cycles_array(SM_, SK_, sn_lane, sa_rows, sa_cols,
                                    xp=jnp)
    subtile_cyc = jnp.ceil(subtile_cyc / mac_scale_v).astype(jnp.int64)

    # ---------------- level 1: schedule subtiles across cores -------------
    n_sub_m = -(-TM_ // SM_)
    n_sub_n = -(-TN_ // SN_)
    n_sub_k = -(-TK_ // SK_)

    out_subtiles = n_sub_m * n_sub_n
    waves = -(-out_subtiles // cores)
    w = jnp.minimum(out_subtiles, cores)
    gm = jnp.minimum(n_sub_m,
                     jnp.maximum(1, jnp.round(jnp.sqrt(w))).astype(jnp.int64))
    gn = jnp.minimum(n_sub_n, jnp.maximum(1, -(-w // gm)))
    wave_traffic = gm * SM_ * TK_ * bytes_a_v + gn * TK_ * SN_ * bytes_b_v \
        + gm * gn * SM_ * SN_ * bytes_out_v
    wave_mem_cyc = -(-wave_traffic // gb_bw_cyc)
    wave_cmp_cyc = n_sub_k * subtile_cyc
    s1_db0 = waves * (wave_mem_cyc + wave_cmp_cyc)
    s1_db1 = waves * jnp.maximum(wave_mem_cyc, wave_cmp_cyc) \
        + jnp.minimum(wave_mem_cyc, wave_cmp_cyc)

    ck = jnp.maximum(1, jnp.minimum(cores // jnp.maximum(out_subtiles, 1),
                                    n_sub_k))
    k_per_core = -(-n_sub_k // ck)
    s2_cmp_cyc = k_per_core * subtile_cyc
    red_traffic = (2 * (ck - 1)) * SM_ * SN_ * bytes_acc_v
    red_cyc = -(-red_traffic // gb_bw_cyc) + \
        -(-((ck - 1) * SM_ * SN_) // jnp.maximum(vec_tp * cores, 1))
    s2_waves = -(-(out_subtiles * ck) // cores)
    s2_traffic = SM_ * TK_ * bytes_a_v + TK_ * SN_ * bytes_b_v
    s2_mem_cyc = -(-(s2_traffic * out_subtiles
                     // jnp.maximum(s2_waves, 1)) // gb_bw_cyc)
    s2_db0 = s2_waves * (s2_mem_cyc + s2_cmp_cyc) + red_cyc
    s2_db1 = s2_waves * jnp.maximum(s2_mem_cyc, s2_cmp_cyc) + red_cyc

    use_s2 = (s2_db0 < s1_db0, s2_db1 < s1_db1)
    tile_time = (jnp.where(use_s2[0], s2_db0, s1_db0) / freq,
                 jnp.where(use_s2[1], s2_db1, s1_db1) / freq)

    # ---------------- level 2: main memory <-> global buffer --------------
    n_t_m = -(-m_v // jnp.minimum(TM_, m_v))
    n_t_n = -(-n_v // jnp.minimum(TN_, n_v))
    n_t_k = -(-k_v // jnp.minimum(TK_, k_v))
    steps = batch_v * n_t_m * n_t_n * n_t_k
    a_bytes_step = TM_ * TK_ * bytes_a_v
    b_bytes_step = TK_ * TN_ * bytes_b_v
    c_bytes_tile = TM_ * TN_ * bytes_out_v
    step_mem_t = jnp.where(bshared_v & (batch_v > 1),
                           (a_bytes_step + b_bytes_step / batch_v) / mem_bw,
                           (a_bytes_step + b_bytes_step) / mem_bw)
    c_mem_t = c_bytes_tile / mem_bw
    c_total_t = batch_v * n_t_m * n_t_n * c_mem_t

    cols = []
    for p, (db2, db1) in enumerate(_DB_OPTIONS):
        tt = tile_time[db1]
        if db2:
            tot = steps * jnp.maximum(step_mem_t, tt) + c_total_t \
                + jnp.minimum(step_mem_t, tt)
        else:
            tot = steps * (step_mem_t + tt) + c_total_t
        cols.append(jnp.where(P_OK[:, p], tot, jnp.inf))

    return {"totals": jnp.stack(cols, axis=1),
            "use_s2": jnp.stack(use_s2, axis=1),
            "tile_time": jnp.stack(tile_time, axis=1),
            "steps": steps, "step_mem_t": step_mem_t,
            "c_total_t": c_total_t,
            "n_t_m": n_t_m, "n_t_n": n_t_n, "n_t_k": n_t_k}


def _bucket(n: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b <<= 1
    return b


def _pad_col(val, n: int, b: int, dtype, fill) -> np.ndarray:
    """Densify a (possibly scalar-collapsed) column to the bucket length."""
    out = np.full(b, fill, dtype=dtype)
    out[:n] = val
    return out


def chunk_tables(g: Dict) -> Dict:
    """Evaluate one gathered chunk's candidate tables on the JAX backend.

    Input/output contract is mapper._chunk_tables_numpy's: numpy arrays in,
    numpy arrays out. Filler rows above the real row count are infeasible
    (p_ok False) and sliced off before returning.
    """
    n = int(g["tm"].size)
    if n == 0:
        return _empty_tables()
    b = _bucket(n)

    padded = {}
    for c in _INT_COLS:
        padded[c] = _pad_col(g[c], n, b, np.int64, 1)
    for c in _FLT_COLS:
        padded[c] = _pad_col(g[c], n, b, np.float64, 1.0)
    for c in _DYN_COLS:
        # mirror the numpy path's promotion rule: int64 unless sub-byte
        # widths appeared in this chunk (the dtype keys the jit trace)
        v = np.asarray(g[c])
        dt = np.float64 if v.dtype == np.float64 else np.int64
        padded[c] = _pad_col(g[c], n, b, dt, 1)
    padded["b_shared"] = _pad_col(g["b_shared"], n, b, bool, False)
    p_ok = np.zeros((b, 4), dtype=bool)
    p_ok[:n] = g["p_ok"]
    padded["p_ok"] = p_ok

    with enable_x64():
        out = jax.device_get(_tables_kernel(padded))
    return {k: v[:n] for k, v in out.items()}


def _empty_tables() -> Dict:
    z = np.zeros(0)
    zi = np.zeros(0, dtype=np.int64)
    return {"totals": np.zeros((0, 4)),
            "use_s2": np.zeros((0, 2), bool),
            "tile_time": np.zeros((0, 2)),
            "steps": zi, "step_mem_t": z, "c_total_t": z,
            "n_t_m": zi, "n_t_n": zi, "n_t_k": zi}
