"""LLM computational graph -> symbolic op-IR (paper Fig. 2 + Sec. III-B).

Builds the per-layer operator graph for any ModelConfig at a given stage
(prefill: seq=S; decode: seq=1 with KV length), already divided by the
parallelism plan (tp / ep), including the Megatron-style collectives the
paper models (two all-reduce per transformer layer under TP) plus the
all-to-all that MoE expert parallelism adds (our extension, DESIGN.md §5).

The builders (`build_layer`, `build_model`) are *symbolic*: they emit
ir.Graph values of hashable OpSpec nodes and never touch a Device, so one
build can be evaluated on any hardware description — and the evaluator can
deduplicate identical specs across a whole design-space sweep. Identical
transformer layers become one node x `repeat` instead of n_layers nodes.
`layer_ops` / `model_ops` remain as eager conveniences: build + evaluate.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import List

from ..configs.base import ModelConfig
from .hardware import System
from . import operators as ops
from .ir import (CollectiveSpec, ElementwiseSpec, Graph, GraphBuilder,
                 MatmulSpec, NormSpec, ScanSpec, SoftmaxSpec, TrafficSpec)


@dataclass(frozen=True)
class Plan:
    """Parallelism plan for the analytical model."""
    tp: int = 1
    pp: int = 1
    dp: int = 1
    ep: int = 1          # expert parallel degree (within tp group or dp)
    sequence_parallel: bool = False   # RS+AG instead of AR (beyond-paper opt)

    @property
    def devices(self) -> int:
        return self.tp * self.pp * self.dp


@dataclass
class LayerCost:
    ops: List[ops.OpResult] = field(default_factory=list)

    def add(self, r: ops.OpResult):
        self.ops.append(r)

    @property
    def latency(self) -> float:
        return sum(o.latency for o in self.ops)

    @property
    def flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def bytes(self) -> float:
        return sum(o.main_memory_bytes for o in self.ops)

    def by_bound(self) -> dict:
        out: dict = {}
        for o in self.ops:
            out[o.bound] = out.get(o.bound, 0.0) + o.latency
        return out

    def breakdown(self) -> dict:
        out: dict = {}
        for o in self.ops:
            out[o.name] = out.get(o.name, 0.0) + o.latency
        return out


# ---------------------------------------------------------------------------
# symbolic builders
# ---------------------------------------------------------------------------

def _norm_spec(cfg: ModelConfig, rows: int) -> NormSpec:
    kind = "layernorm" if cfg.norm == "layernorm" else "rmsnorm"
    return NormSpec(kind, rows, cfg.d_model)


def _add_tp_collective(g: GraphBuilder, cfg: ModelConfig, plan: Plan,
                       tokens: int, name: str) -> None:
    """Per-layer activation synchronization under tensor parallelism."""
    if plan.tp <= 1:
        return
    bytes_ = tokens * cfg.d_model * 2
    if plan.sequence_parallel:
        g.add(CollectiveSpec("reduce_scatter", bytes_, plan.tp), name + "_rs")
        g.add(CollectiveSpec("all_gather", bytes_, plan.tp), name + "_ag")
        return
    g.add(CollectiveSpec("all_reduce", bytes_, plan.tp), name)


def build_attention(cfg: ModelConfig, plan: Plan, batch: int, seq: int,
                    kv_len: int, cross_len: int = 0,
                    prefix: str = "") -> Graph:
    """Self- (or cross-) attention block. seq = query length (1 for decode)."""
    d, dh = cfg.d_model, cfg.d_head
    hq = max(1, cfg.n_heads // plan.tp)
    hkv = max(1, cfg.n_kv_heads // plan.tp)
    g_ = hq // hkv
    toks = batch * seq
    ctx = cross_len if cross_len else kv_len
    win = cfg.attn_window
    kv_eff = min(ctx, win) if (win and not cross_len) else ctx

    g = GraphBuilder()
    g.add(_norm_spec(cfg, toks), prefix + "ln_attn")
    g.add(MatmulSpec(toks, d, (hq + 2 * hkv) * dh), prefix + "qkv_proj")
    if cfg.qk_norm:
        g.add(NormSpec("rmsnorm", toks * (hq + hkv), dh), prefix + "qk_norm")
    if cfg.rope_fraction > 0:
        g.add(ElementwiseSpec("generic", toks * (hq + hkv) * dh, 6.0),
              prefix + "rope")
    if seq == 1:   # decode: append one token of KV
        g.add(TrafficSpec(batch * 2 * hkv * dh * 2), prefix + "kv_append")
    g.add(MatmulSpec(g_ * seq, dh, kv_eff, batch=batch * hkv),
          prefix + "qk_t")
    g.add(SoftmaxSpec(batch * hq * seq, kv_eff), prefix + "softmax")
    g.add(MatmulSpec(g_ * seq, kv_eff, dh, batch=batch * hkv),
          prefix + "a_mul_v")
    g.add(MatmulSpec(toks, hq * dh, d), prefix + "o_proj")
    _add_tp_collective(g, cfg, plan, toks, prefix + "allreduce_attn")
    return g.build()


def build_mlp(cfg: ModelConfig, plan: Plan, batch: int, seq: int) -> Graph:
    d = cfg.d_model
    toks = batch * seq
    g = GraphBuilder()
    g.add(_norm_spec(cfg, toks), "ln_mlp")

    if cfg.n_experts:
        e_local = max(1, cfg.n_experts // plan.ep)
        g.add(MatmulSpec(toks, d, cfg.n_experts), "router")
        if plan.ep > 1:
            a2a = toks * cfg.top_k * d * 2
            g.add(CollectiveSpec("all_to_all", a2a, plan.ep), "moe_dispatch")
        toks_e = math.ceil(toks * cfg.top_k / cfg.n_experts)
        ff = max(1, cfg.d_ff // plan.tp)
        n_up = 2 * ff if cfg.mlp_gated else ff
        g.add(MatmulSpec(toks_e, d, n_up, batch=e_local), "expert_up")
        act = "silu_mul" if cfg.mlp_gated else "gelu"
        g.add(ElementwiseSpec(act, toks_e * e_local * ff), "expert_act")
        g.add(MatmulSpec(toks_e, ff, d, batch=e_local), "expert_down")
        if plan.ep > 1:
            g.add(CollectiveSpec("all_to_all", toks * cfg.top_k * d * 2,
                                 plan.ep), "moe_combine")
        g.add(ElementwiseSpec("generic", toks * d, 2 * cfg.top_k), "moe_mix")
    else:
        ff = max(1, cfg.d_ff // plan.tp)
        if cfg.mlp_gated:
            g.add(MatmulSpec(toks, d, 2 * ff), "w1_gate_proj")
            g.add(ElementwiseSpec("silu_mul", toks * ff), "act_mul")
        else:
            g.add(MatmulSpec(toks, d, ff), "w1_proj")
            g.add(ElementwiseSpec("gelu", toks * ff), "gelu")
        g.add(MatmulSpec(toks, ff, d), "w2_proj")
    _add_tp_collective(g, cfg, plan, toks, "allreduce_mlp")
    return g.build()


def build_rwkv(cfg: ModelConfig, plan: Plan, batch: int, seq: int) -> Graph:
    """RWKV6 time-mix + channel-mix (extension op: ScanSpec)."""
    d = cfg.d_model
    d_tp = max(1, d // plan.tp)
    dh = cfg.rwkv_head_dim
    toks = batch * seq
    g = GraphBuilder()
    g.add(NormSpec("layernorm", toks, d), "ln_tmix")
    for nm in ("r", "k", "v", "g", "w_lora"):
        n = d_tp if nm != "w_lora" else 64
        g.add(MatmulSpec(toks, d, n), f"tmix_{nm}")
    g.add(ScanSpec(seq, batch, d_state=d_tp * dh,
                   flops_per_step=6.0 * d_tp * dh,
                   bytes_io=6 * toks * d_tp * 2), "wkv_scan")
    g.add(MatmulSpec(toks, d_tp, d), "tmix_out")
    if plan.tp > 1:
        g.add(CollectiveSpec("all_reduce", toks * d * 2, plan.tp),
              "allreduce_tmix")
    # channel mix
    ff = int(3.5 * d) // plan.tp
    g.add(NormSpec("layernorm", toks, d), "ln_cmix")
    g.add(MatmulSpec(toks, d, ff), "cmix_up")
    g.add(ElementwiseSpec("generic", toks * ff, 3.0), "relu_sq")
    g.add(MatmulSpec(toks, ff, d), "cmix_down")
    if plan.tp > 1:
        g.add(CollectiveSpec("all_reduce", toks * d * 2, plan.tp),
              "allreduce_cmix")
    return g.build()


def build_rglru(cfg: ModelConfig, plan: Plan, batch: int, seq: int) -> Graph:
    """Griffin recurrent block: dual in-proj, short conv, RG-LRU scan."""
    d = cfg.d_model
    d_tp = max(1, d // plan.tp)
    toks = batch * seq
    g = GraphBuilder()
    g.add(_norm_spec(cfg, toks), "ln_rec")
    g.add(MatmulSpec(toks, d, 2 * d_tp), "rec_in_proj")
    g.add(ElementwiseSpec("generic", toks * d_tp,
                          2.0 * cfg.rglru_conv_width), "conv1d")
    g.add(ScanSpec(seq, batch, d_state=d_tp, flops_per_step=12.0 * d_tp,
                   bytes_io=4 * toks * d_tp * 2), "rg_lru")
    g.add(ElementwiseSpec("generic", toks * d_tp, 4.0), "gate_mul")
    g.add(MatmulSpec(toks, d_tp, d), "rec_out_proj")
    _add_tp_collective(g, cfg, plan, toks, "allreduce_rec")
    return g.build()


def build_layer(cfg: ModelConfig, plan: Plan, layer: int, batch: int,
                seq: int, kv_len: int) -> Graph:
    kind = cfg.block_kind(layer)
    if kind == "rwkv":
        return build_rwkv(cfg, plan, batch, seq)
    if kind == "rglru":
        return build_rglru(cfg, plan, batch, seq) \
            + build_mlp(cfg, plan, batch, seq)
    g = build_attention(cfg, plan, batch, seq, kv_len)
    if cfg.cross_attention or layer in cfg.cross_attn_layers:
        g = g + build_attention(cfg, plan, batch, seq, kv_len,
                                cross_len=max(cfg.n_frontend_tokens, 1),
                                prefix="x_")
    return g + build_mlp(cfg, plan, batch, seq)


@functools.lru_cache(maxsize=4096)
def build_model(cfg: ModelConfig, plan: Plan, batch: int, seq: int,
                kv_len: int, include_head: bool = True) -> Graph:
    """Whole-model graph: distinct layer kinds built once with repeat counts.

    Layers of the same kind have identical cost — each kind becomes one set
    of nodes x `repeat` (this is what makes simulating GPT-3's 96 layers as
    cheap as one layer). The build is symbolic and cached: no operator model
    runs until an Evaluator sees the graph.
    """
    kinds: dict = {}
    for i in range(cfg.n_layers):
        key = (cfg.block_kind(i),
               cfg.cross_attention or i in cfg.cross_attn_layers)
        kinds[key] = kinds.get(key, 0) + 1
    layers_per_stage = {k: math.ceil(v / plan.pp) for k, v in kinds.items()}
    rep_layer = {}
    for i in range(cfg.n_layers):
        key = (cfg.block_kind(i),
               cfg.cross_attention or i in cfg.cross_attn_layers)
        if key not in rep_layer:
            rep_layer[key] = build_layer(cfg, plan, i, batch, seq, kv_len)
    g = GraphBuilder()
    for key, cnt in layers_per_stage.items():
        g.extend(rep_layer[key].scaled(cnt))
    # encoder stack (whisper): runs once per request at prefill
    if cfg.n_encoder_layers and seq > 1:
        enc_len = max(cfg.n_frontend_tokens, 1)
        enc = build_attention(cfg, plan, batch, enc_len, enc_len) \
            + build_mlp(cfg, plan, batch, enc_len)
        g.extend(enc.scaled(cfg.n_encoder_layers, prefix="enc_"))
    if include_head:
        toks = batch * (seq if seq > 1 else 1)
        g.add(TrafficSpec(toks * cfg.d_model * 2), "embed")
        g.add(_norm_spec(cfg, toks), "ln_final")
        g.add(MatmulSpec(toks, cfg.d_model,
                         max(1, cfg.vocab_size // plan.tp)), "lm_head")
    return g.build()


# ---------------------------------------------------------------------------
# eager conveniences: build + evaluate (seed-compatible API)
# ---------------------------------------------------------------------------

def layer_ops(cfg: ModelConfig, system: System, plan: Plan, layer: int,
              batch: int, seq: int, kv_len: int,
              evaluator=None) -> LayerCost:
    from .evaluator import Evaluator
    ev = evaluator if evaluator is not None else Evaluator(system)
    return ev.evaluate(build_layer(cfg, plan, layer, batch, seq, kv_len))


def model_ops(cfg: ModelConfig, system: System, plan: Plan, batch: int,
              seq: int, kv_len: int, include_head: bool = True,
              evaluator=None) -> LayerCost:
    """Whole-model cost: build the symbolic graph and evaluate it."""
    from .evaluator import Evaluator
    ev = evaluator if evaluator is not None else Evaluator(system)
    return ev.evaluate(build_model(cfg, plan, batch, seq, kv_len,
                                   include_head))
