"""LLM computational graph -> symbolic op-IR (paper Fig. 2 + Sec. III-B).

Builds the per-layer operator graph for any ModelConfig at a given stage
(prefill: seq=S; decode: seq=1 with KV length), already divided by the
parallelism plan (tp / ep), including the Megatron-style collectives the
paper models (two all-reduce per transformer layer under TP) plus the
all-to-all that MoE expert parallelism adds (our extension, DESIGN.md §5).

The builders (`build_layer`, `build_model`) are *symbolic*: they emit
ir.Graph values of hashable OpSpec nodes and never touch a Device, so one
build can be evaluated on any hardware description — and the evaluator can
deduplicate identical specs across a whole design-space sweep. Identical
transformer layers become one node x `repeat` instead of n_layers nodes.
`layer_ops` / `model_ops` remain as eager conveniences: build + evaluate.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import List

from ..configs.base import ModelConfig
from .hardware import System
from . import operators as ops
from .ir import (CollectiveSpec, ElementwiseSpec, Graph, GraphBuilder,
                 MatmulSpec, NormSpec, ScanSpec, SoftmaxSpec, TrafficSpec)
from .precision import DEFAULT, PrecisionPolicy


@dataclass(frozen=True)
class Plan:
    """Parallelism plan for the analytical model."""
    tp: int = 1
    pp: int = 1
    dp: int = 1
    ep: int = 1          # expert parallel degree (within tp group or dp)
    sequence_parallel: bool = False   # RS+AG instead of AR (beyond-paper opt)

    @property
    def devices(self) -> int:
        return self.tp * self.pp * self.dp


@dataclass
class LayerCost:
    """Evaluated graph cost. With `schedule` set (overlap-mode evaluation,
    core/schedule.py) `latency` is the resource-timeline makespan and the
    per-op start/end times are exposed; otherwise it is the seed's serial
    sum in node order, bit-for-bit."""
    ops: List[ops.OpResult] = field(default_factory=list)
    schedule: object = None         # Optional[schedule.Schedule]

    def add(self, r: ops.OpResult):
        self.ops.append(r)

    @property
    def latency(self) -> float:
        if self.schedule is not None:
            return self.schedule.makespan
        return self.serial_latency

    @property
    def serial_latency(self) -> float:
        """Serial (no-overlap) latency: the left-to-right sum."""
        return sum(o.latency for o in self.ops)

    @property
    def flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def bytes(self) -> float:
        return sum(o.main_memory_bytes for o in self.ops)

    def by_bound(self) -> dict:
        out: dict = {}
        for o in self.ops:
            out[o.bound] = out.get(o.bound, 0.0) + o.latency
        return out

    def breakdown(self) -> dict:
        """Additive per-name busy time (resource occupancy, not wall-clock
        when scheduled — see critical_breakdown for path attribution)."""
        out: dict = {}
        for o in self.ops:
            out[o.name] = out.get(o.name, 0.0) + o.latency
        return out

    def by_resource(self) -> dict:
        """Per-resource busy seconds (compute / vector / link)."""
        if self.schedule is not None:
            return dict(self.schedule.busy)
        out: dict = {}
        for o, node_res in zip(self.ops, self._resources or ()):
            out[node_res] = out.get(node_res, 0.0) + o.latency
        return out

    _resources: tuple = ()          # set by the evaluator (spec resources)

    def critical_breakdown(self) -> dict:
        """Critical-path (not additive) attribution: which ops the scheduled
        makespan is actually waiting on. Falls back to the additive
        breakdown when the graph was priced serially."""
        if self.schedule is not None:
            return self.schedule.critical_breakdown()
        return self.breakdown()


# ---------------------------------------------------------------------------
# symbolic builders
# ---------------------------------------------------------------------------

def _norm_spec(cfg: ModelConfig, rows: int,
               policy: PrecisionPolicy = DEFAULT,
               plan: Plan = None) -> NormSpec:
    kind = "layernorm" if cfg.norm == "layernorm" else "rmsnorm"
    ab = policy.activations.bytes
    if plan is not None and plan.sequence_parallel and plan.tp > 1:
        # Megatron-style sequence parallelism: the norm (and the rest of the
        # RS..AG region) runs on the token shard, 1/tp of the rows
        rows = math.ceil(rows / plan.tp)
    return NormSpec(kind, rows, cfg.d_model, bytes_in=ab, bytes_out=ab)


def _add_tp_collective(g: GraphBuilder, cfg: ModelConfig, plan: Plan,
                       tokens: int, name: str,
                       policy: PrecisionPolicy = DEFAULT) -> None:
    """Per-layer activation synchronization under tensor parallelism.

    Chain deps are the true edges here: the collective consumes the output
    of the node added just before it (the row-parallel GEMM), and the next
    node consumes the synchronized activations."""
    if plan.tp <= 1:
        return
    ab = policy.activations.bytes
    bytes_ = tokens * cfg.d_model * ab
    if plan.sequence_parallel:
        g.add(CollectiveSpec("reduce_scatter", bytes_, plan.tp, ab),
              name + "_rs")
        g.add(CollectiveSpec("all_gather", bytes_, plan.tp, ab), name + "_ag")
        return
    g.add(CollectiveSpec("all_reduce", bytes_, plan.tp, ab), name)


def build_attention(cfg: ModelConfig, plan: Plan, batch: int, seq: int,
                    kv_len: int, cross_len: int = 0, prefix: str = "",
                    policy: PrecisionPolicy = DEFAULT) -> Graph:
    """Self- (or cross-) attention block. seq = query length (1 for decode).

    Precision: the projections are activation x weight GEMMs; the score and
    value GEMMs stream their B operand from the (possibly quantized) KV
    cache, as does the one-token KV append at decode."""
    d, dh = cfg.d_model, cfg.d_head
    hq = max(1, cfg.n_heads // plan.tp)
    hkv = max(1, cfg.n_kv_heads // plan.tp)
    g_ = hq // hkv
    toks = batch * seq
    ctx = cross_len if cross_len else kv_len
    win = cfg.attn_window
    kv_eff = min(ctx, win) if (win and not cross_len) else ctx
    ab = policy.activations.bytes
    w_mm, kv_mm = policy.weight_gemm(), policy.attn_gemm()

    g = GraphBuilder()
    g.add(_norm_spec(cfg, toks, policy, plan), prefix + "ln_attn")
    i_qkv = g.add(MatmulSpec(toks, d, (hq + 2 * hkv) * dh, **w_mm),
                  prefix + "qkv_proj")
    i_qk = i_qkv                   # most recent producer of the q/k tensors
    if cfg.qk_norm:
        i_qk = g.add(NormSpec("rmsnorm", toks * (hq + hkv), dh, bytes_in=ab,
                              bytes_out=ab), prefix + "qk_norm")
    if cfg.rope_fraction > 0:
        i_qk = g.add(ElementwiseSpec("generic", toks * (hq + hkv) * dh, 6.0,
                                     bytes_elt=ab), prefix + "rope")
    i_app = None
    if seq == 1:   # decode: append one token of KV at cache precision
        i_app = g.add(TrafficSpec(batch * 2 * hkv * dh
                                  * policy.kv_cache.bytes),
                      prefix + "kv_append", deps=(i_qk,))
    qk_deps = (i_qk,) if i_app is None else (i_qk, i_app)
    i_sc = g.add(MatmulSpec(g_ * seq, dh, kv_eff, batch=batch * hkv, **kv_mm),
                 prefix + "qk_t", deps=qk_deps)
    i_sm = g.add(SoftmaxSpec(batch * hq * seq, kv_eff, bytes_in=ab,
                             bytes_out=ab), prefix + "softmax", deps=(i_sc,))
    # a_mul_v reads the probabilities AND the V projection (via i_qk /
    # kv_append) — a real two-producer join in the dataflow DAG
    i_av = g.add(MatmulSpec(g_ * seq, kv_eff, dh, batch=batch * hkv, **kv_mm),
                 prefix + "a_mul_v", deps=tuple(sorted({i_sm} | set(qk_deps))))
    g.add(MatmulSpec(toks, hq * dh, d, **w_mm), prefix + "o_proj",
          deps=(i_av,))
    _add_tp_collective(g, cfg, plan, toks, prefix + "allreduce_attn", policy)
    return g.build()


def build_mlp(cfg: ModelConfig, plan: Plan, batch: int, seq: int,
              policy: PrecisionPolicy = DEFAULT) -> Graph:
    d = cfg.d_model
    toks = batch * seq
    ab = policy.activations.bytes
    w_mm = policy.weight_gemm()
    g = GraphBuilder()
    g.add(_norm_spec(cfg, toks, policy, plan), "ln_mlp")

    if cfg.n_experts:
        e_local = max(1, cfg.n_experts // plan.ep)
        g.add(MatmulSpec(toks, d, cfg.n_experts, **w_mm), "router")
        if plan.ep > 1:
            a2a = toks * cfg.top_k * d * ab
            g.add(CollectiveSpec("all_to_all", a2a, plan.ep, ab),
                  "moe_dispatch")
        toks_e = math.ceil(toks * cfg.top_k / cfg.n_experts)
        ff = max(1, cfg.d_ff // plan.tp)
        n_up = 2 * ff if cfg.mlp_gated else ff
        g.add(MatmulSpec(toks_e, d, n_up, batch=e_local, **w_mm), "expert_up")
        act = "silu_mul" if cfg.mlp_gated else "gelu"
        g.add(ElementwiseSpec(act, toks_e * e_local * ff, bytes_elt=ab),
              "expert_act")
        g.add(MatmulSpec(toks_e, ff, d, batch=e_local, **w_mm), "expert_down")
        if plan.ep > 1:
            g.add(CollectiveSpec("all_to_all", toks * cfg.top_k * d * ab,
                                 plan.ep, ab), "moe_combine")
        g.add(ElementwiseSpec("generic", toks * d, 2 * cfg.top_k,
                              bytes_elt=ab), "moe_mix")
    else:
        ff = max(1, cfg.d_ff // plan.tp)
        if cfg.mlp_gated:
            g.add(MatmulSpec(toks, d, 2 * ff, **w_mm), "w1_gate_proj")
            g.add(ElementwiseSpec("silu_mul", toks * ff, bytes_elt=ab),
                  "act_mul")
        else:
            g.add(MatmulSpec(toks, d, ff, **w_mm), "w1_proj")
            g.add(ElementwiseSpec("gelu", toks * ff, bytes_elt=ab), "gelu")
        g.add(MatmulSpec(toks, ff, d, **w_mm), "w2_proj")
    _add_tp_collective(g, cfg, plan, toks, "allreduce_mlp", policy)
    return g.build()


def build_rwkv(cfg: ModelConfig, plan: Plan, batch: int, seq: int,
               policy: PrecisionPolicy = DEFAULT) -> Graph:
    """RWKV6 time-mix + channel-mix (extension op: ScanSpec)."""
    d = cfg.d_model
    d_tp = max(1, d // plan.tp)
    dh = cfg.rwkv_head_dim
    toks = batch * seq
    ab = policy.activations.bytes
    w_mm = policy.weight_gemm()
    g = GraphBuilder()
    g.add(NormSpec("layernorm", toks, d, bytes_in=ab, bytes_out=ab),
          "ln_tmix")
    for nm in ("r", "k", "v", "g", "w_lora"):
        n = d_tp if nm != "w_lora" else 64
        g.add(MatmulSpec(toks, d, n, **w_mm), f"tmix_{nm}")
    g.add(ScanSpec(seq, batch, d_state=d_tp * dh,
                   flops_per_step=6.0 * d_tp * dh,
                   bytes_io=6 * toks * d_tp * ab), "wkv_scan")
    g.add(MatmulSpec(toks, d_tp, d, **w_mm), "tmix_out")
    if plan.tp > 1:
        g.add(CollectiveSpec("all_reduce", toks * d * ab, plan.tp, ab),
              "allreduce_tmix")
    # channel mix
    ff = int(3.5 * d) // plan.tp
    g.add(NormSpec("layernorm", toks, d, bytes_in=ab, bytes_out=ab),
          "ln_cmix")
    g.add(MatmulSpec(toks, d, ff, **w_mm), "cmix_up")
    g.add(ElementwiseSpec("generic", toks * ff, 3.0, bytes_elt=ab), "relu_sq")
    g.add(MatmulSpec(toks, ff, d, **w_mm), "cmix_down")
    if plan.tp > 1:
        g.add(CollectiveSpec("all_reduce", toks * d * ab, plan.tp, ab),
              "allreduce_cmix")
    return g.build()


def build_rglru(cfg: ModelConfig, plan: Plan, batch: int, seq: int,
                policy: PrecisionPolicy = DEFAULT) -> Graph:
    """Griffin recurrent block: dual in-proj, short conv, RG-LRU scan."""
    d = cfg.d_model
    d_tp = max(1, d // plan.tp)
    toks = batch * seq
    ab = policy.activations.bytes
    w_mm = policy.weight_gemm()
    g = GraphBuilder()
    g.add(_norm_spec(cfg, toks, policy, plan), "ln_rec")
    g.add(MatmulSpec(toks, d, 2 * d_tp, **w_mm), "rec_in_proj")
    g.add(ElementwiseSpec("generic", toks * d_tp,
                          2.0 * cfg.rglru_conv_width, bytes_elt=ab), "conv1d")
    g.add(ScanSpec(seq, batch, d_state=d_tp, flops_per_step=12.0 * d_tp,
                   bytes_io=4 * toks * d_tp * ab), "rg_lru")
    g.add(ElementwiseSpec("generic", toks * d_tp, 4.0, bytes_elt=ab),
          "gate_mul")
    g.add(MatmulSpec(toks, d_tp, d, **w_mm), "rec_out_proj")
    _add_tp_collective(g, cfg, plan, toks, "allreduce_rec", policy)
    return g.build()


def build_layer(cfg: ModelConfig, plan: Plan, layer: int, batch: int,
                seq: int, kv_len: int,
                policy: PrecisionPolicy = DEFAULT) -> Graph:
    kind = cfg.block_kind(layer)
    if kind == "rwkv":
        return build_rwkv(cfg, plan, batch, seq, policy)
    if kind == "rglru":
        return build_rglru(cfg, plan, batch, seq, policy) \
            + build_mlp(cfg, plan, batch, seq, policy)
    g = build_attention(cfg, plan, batch, seq, kv_len, policy=policy)
    if cfg.cross_attention or layer in cfg.cross_attn_layers:
        g = g + build_attention(cfg, plan, batch, seq, kv_len,
                                cross_len=max(cfg.n_frontend_tokens, 1),
                                prefix="x_", policy=policy)
    return g + build_mlp(cfg, plan, batch, seq, policy)


@functools.lru_cache(maxsize=4096)
def build_model(cfg: ModelConfig, plan: Plan, batch: int, seq: int,
                kv_len: int, include_head: bool = True,
                policy: PrecisionPolicy = DEFAULT) -> Graph:
    """Whole-model graph: distinct layer kinds built once with repeat counts.

    Layers of the same kind have identical cost — each kind becomes one set
    of nodes x `repeat` (this is what makes simulating GPT-3's 96 layers as
    cheap as one layer). The build is symbolic and cached: no operator model
    runs until an Evaluator sees the graph. `policy` stamps per-operand byte
    widths + compute rates on every spec (DESIGN.md §8); the default
    reproduces the implicit-fp16 seed graph exactly.
    """
    kinds: dict = {}
    for i in range(cfg.n_layers):
        key = (cfg.block_kind(i),
               cfg.cross_attention or i in cfg.cross_attn_layers)
        kinds[key] = kinds.get(key, 0) + 1
    layers_per_stage = {k: math.ceil(v / plan.pp) for k, v in kinds.items()}
    rep_layer = {}
    for i in range(cfg.n_layers):
        key = (cfg.block_kind(i),
               cfg.cross_attention or i in cfg.cross_attn_layers)
        if key not in rep_layer:
            rep_layer[key] = build_layer(cfg, plan, i, batch, seq, kv_len,
                                         policy)
    g = GraphBuilder()
    for key, cnt in layers_per_stage.items():
        g.extend(rep_layer[key].scaled(cnt))
    # encoder stack (whisper): runs once per request at prefill
    if cfg.n_encoder_layers and seq > 1:
        enc_len = max(cfg.n_frontend_tokens, 1)
        enc = build_attention(cfg, plan, batch, enc_len, enc_len,
                              policy=policy) \
            + build_mlp(cfg, plan, batch, enc_len, policy)
        g.extend(enc.scaled(cfg.n_encoder_layers, prefix="enc_"))
    if include_head:
        toks = batch * (seq if seq > 1 else 1)
        i_last = len(g) - 1
        # embedding gather reads weight-precision rows. Physically it runs
        # BEFORE layer 0 consumes its output; since the head block is
        # appended after the folded stack (seed ordering), keep it chained
        # rather than a free source so the scheduler never hides traffic
        # that sits on the serial prefix of the critical path.
        i_emb = g.add(TrafficSpec(toks * cfg.d_model * policy.weights.bytes),
                      "embed")
        head_deps = (i_emb,) if i_last < 0 else (i_last, i_emb)
        i_ln = g.add(_norm_spec(cfg, toks, policy), "ln_final",
                     deps=head_deps)
        g.add(MatmulSpec(toks, cfg.d_model,
                         max(1, cfg.vocab_size // plan.tp),
                         **policy.weight_gemm()), "lm_head", deps=(i_ln,))
    return g.build()


# ---------------------------------------------------------------------------
# eager conveniences: build + evaluate (seed-compatible API)
# ---------------------------------------------------------------------------

def layer_ops(cfg: ModelConfig, system: System, plan: Plan, layer: int,
              batch: int, seq: int, kv_len: int, evaluator=None,
              policy: PrecisionPolicy = DEFAULT) -> LayerCost:
    from .evaluator import Evaluator
    ev = evaluator if evaluator is not None else Evaluator(system)
    return ev.evaluate(build_layer(cfg, plan, layer, batch, seq, kv_len,
                                   policy))


def model_ops(cfg: ModelConfig, system: System, plan: Plan, batch: int,
              seq: int, kv_len: int, include_head: bool = True,
              evaluator=None, policy: PrecisionPolicy = DEFAULT) -> LayerCost:
    """Whole-model cost: build the symbolic graph and evaluate it."""
    from .evaluator import Evaluator
    ev = evaluator if evaluator is not None else Evaluator(system)
    return ev.evaluate(build_model(cfg, plan, batch, seq, kv_len,
                                   include_head, policy))
