"""LLM computational graph -> operator calls (paper Fig. 2 + Sec. III-B).

Builds the per-layer operator list for any ModelConfig at a given stage
(prefill: seq=S; decode: seq=1 with KV length), already divided by the
parallelism plan (tp / ep), including the Megatron-style collectives the
paper models (two all-reduce per transformer layer under TP) plus the
all-to-all that MoE expert parallelism adds (our extension, DESIGN.md §5).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

from ..configs.base import ModelConfig
from .hardware import Device, System
from . import operators as ops
from . import interconnect as net


@dataclass(frozen=True)
class Plan:
    """Parallelism plan for the analytical model."""
    tp: int = 1
    pp: int = 1
    dp: int = 1
    ep: int = 1          # expert parallel degree (within tp group or dp)
    sequence_parallel: bool = False   # RS+AG instead of AR (beyond-paper opt)

    @property
    def devices(self) -> int:
        return self.tp * self.pp * self.dp


@dataclass
class LayerCost:
    ops: List[ops.OpResult] = field(default_factory=list)

    def add(self, r: ops.OpResult):
        self.ops.append(r)

    @property
    def latency(self) -> float:
        return sum(o.latency for o in self.ops)

    @property
    def flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def bytes(self) -> float:
        return sum(o.main_memory_bytes for o in self.ops)

    def by_bound(self) -> dict:
        out: dict = {}
        for o in self.ops:
            out[o.bound] = out.get(o.bound, 0.0) + o.latency
        return out

    def breakdown(self) -> dict:
        out: dict = {}
        for o in self.ops:
            out[o.name] = out.get(o.name, 0.0) + o.latency
        return out


def _norm(cfg: ModelConfig, dev: Device, rows: int, name: str) -> ops.OpResult:
    fn = ops.layernorm if cfg.norm == "layernorm" else ops.rmsnorm
    return fn(dev, rows, cfg.d_model, name=name)


def _tp_collective(cfg: ModelConfig, system: System, plan: Plan,
                   tokens: int, name: str) -> ops.OpResult:
    """Per-layer activation synchronization under tensor parallelism."""
    if plan.tp <= 1:
        return ops.ZERO
    bytes_ = tokens * cfg.d_model * 2
    if plan.sequence_parallel:
        rs = net.reduce_scatter(system, bytes_, plan.tp, name=name + "_rs")
        ag = net.all_gather(system, bytes_, plan.tp, name=name + "_ag")
        return rs + ag
    return net.all_reduce(system, bytes_, plan.tp, name=name)


def attention_ops(cfg: ModelConfig, system: System, plan: Plan, batch: int,
                  seq: int, kv_len: int, cross_len: int = 0,
                  prefix: str = "") -> List[ops.OpResult]:
    """Self- (or cross-) attention block. seq = query length (1 for decode)."""
    dev = system.device
    d, dh = cfg.d_model, cfg.d_head
    hq = max(1, cfg.n_heads // plan.tp)
    hkv = max(1, cfg.n_kv_heads // plan.tp)
    g = hq // hkv
    toks = batch * seq
    out: List[ops.OpResult] = []
    ctx = cross_len if cross_len else kv_len
    win = cfg.attn_window
    kv_eff = min(ctx, win) if (win and not cross_len) else ctx

    out.append(_norm(cfg, dev, toks, prefix + "ln_attn"))
    out.append(ops.matmul(dev, toks, d, (hq + 2 * hkv) * dh,
                          name=prefix + "qkv_proj"))
    if cfg.qk_norm:
        out.append(ops.rmsnorm(dev, toks * (hq + hkv), dh, name=prefix + "qk_norm"))
    if cfg.rope_fraction > 0:
        out.append(ops.elementwise(dev, toks * (hq + hkv) * dh, 6.0,
                                   name=prefix + "rope"))
    if seq == 1:   # decode: append one token of KV
        out.append(ops.memory_traffic(dev, batch * 2 * hkv * dh * 2,
                                      name=prefix + "kv_append"))
    out.append(ops.matmul(dev, g * seq, dh, kv_eff, batch=batch * hkv,
                          name=prefix + "qk_t"))
    out.append(ops.softmax(dev, batch * hq * seq, kv_eff, name=prefix + "softmax"))
    out.append(ops.matmul(dev, g * seq, kv_eff, dh, batch=batch * hkv,
                          name=prefix + "a_mul_v"))
    out.append(ops.matmul(dev, toks, hq * dh, d, name=prefix + "o_proj"))
    out.append(_tp_collective(cfg, system, plan, toks, prefix + "allreduce_attn"))
    return out


def mlp_ops(cfg: ModelConfig, system: System, plan: Plan, batch: int,
            seq: int) -> List[ops.OpResult]:
    dev = system.device
    d = cfg.d_model
    toks = batch * seq
    out: List[ops.OpResult] = []
    out.append(_norm(cfg, dev, toks, "ln_mlp"))

    if cfg.n_experts:
        e_local = max(1, cfg.n_experts // plan.ep)
        out.append(ops.matmul(dev, toks, d, cfg.n_experts, name="router"))
        if plan.ep > 1:
            a2a = toks * cfg.top_k * d * 2
            out.append(net.all_to_all(system, a2a, plan.ep, name="moe_dispatch"))
        toks_e = math.ceil(toks * cfg.top_k / cfg.n_experts)
        ff = max(1, cfg.d_ff // plan.tp)
        n_up = 2 * ff if cfg.mlp_gated else ff
        out.append(ops.matmul(dev, toks_e, d, n_up, batch=e_local,
                              name="expert_up"))
        act = ops.silu_mul if cfg.mlp_gated else ops.gelu
        out.append(act(dev, toks_e * e_local * ff, name="expert_act"))
        out.append(ops.matmul(dev, toks_e, ff, d, batch=e_local,
                              name="expert_down"))
        if plan.ep > 1:
            out.append(net.all_to_all(system, toks * cfg.top_k * d * 2,
                                      plan.ep, name="moe_combine"))
        out.append(ops.elementwise(dev, toks * d, 2 * cfg.top_k, name="moe_mix"))
    else:
        ff = max(1, cfg.d_ff // plan.tp)
        if cfg.mlp_gated:
            out.append(ops.matmul(dev, toks, d, 2 * ff, name="w1_gate_proj"))
            out.append(ops.silu_mul(dev, toks * ff, name="act_mul"))
        else:
            out.append(ops.matmul(dev, toks, d, ff, name="w1_proj"))
            out.append(ops.gelu(dev, toks * ff, name="gelu"))
        out.append(ops.matmul(dev, toks, ff, d, name="w2_proj"))
    out.append(_tp_collective(cfg, system, plan, toks, "allreduce_mlp"))
    return out


def rwkv_ops(cfg: ModelConfig, system: System, plan: Plan, batch: int,
             seq: int) -> List[ops.OpResult]:
    """RWKV6 time-mix + channel-mix (extension op: recurrent_scan)."""
    dev = system.device
    d = cfg.d_model
    d_tp = max(1, d // plan.tp)
    dh = cfg.rwkv_head_dim
    toks = batch * seq
    out = [ops.layernorm(dev, toks, d, name="ln_tmix")]
    for nm in ("r", "k", "v", "g", "w_lora"):
        n = d_tp if nm != "w_lora" else 64
        out.append(ops.matmul(dev, toks, d, n, name=f"tmix_{nm}"))
    out.append(ops.recurrent_scan(
        dev, seq, batch, d_state=d_tp * dh,
        flops_per_step=6.0 * d_tp * dh,
        bytes_io=6 * toks * d_tp * 2, name="wkv_scan"))
    out.append(ops.matmul(dev, toks, d_tp, d, name="tmix_out"))
    if plan.tp > 1:
        out.append(net.all_reduce(system, toks * d * 2, plan.tp,
                                  name="allreduce_tmix"))
    # channel mix
    ff = int(3.5 * d) // plan.tp
    out.append(ops.layernorm(dev, toks, d, name="ln_cmix"))
    out.append(ops.matmul(dev, toks, d, ff, name="cmix_up"))
    out.append(ops.elementwise(dev, toks * ff, 3.0, name="relu_sq"))
    out.append(ops.matmul(dev, toks, ff, d, name="cmix_down"))
    if plan.tp > 1:
        out.append(net.all_reduce(system, toks * d * 2, plan.tp,
                                  name="allreduce_cmix"))
    return out


def rglru_ops(cfg: ModelConfig, system: System, plan: Plan, batch: int,
              seq: int) -> List[ops.OpResult]:
    """Griffin recurrent block: dual in-proj, short conv, RG-LRU scan."""
    dev = system.device
    d = cfg.d_model
    d_tp = max(1, d // plan.tp)
    toks = batch * seq
    out = [_norm(cfg, dev, toks, "ln_rec")]
    out.append(ops.matmul(dev, toks, d, 2 * d_tp, name="rec_in_proj"))
    out.append(ops.elementwise(dev, toks * d_tp, 2.0 * cfg.rglru_conv_width,
                               name="conv1d"))
    out.append(ops.recurrent_scan(
        dev, seq, batch, d_state=d_tp,
        flops_per_step=12.0 * d_tp,
        bytes_io=4 * toks * d_tp * 2, name="rg_lru"))
    out.append(ops.elementwise(dev, toks * d_tp, 4.0, name="gate_mul"))
    out.append(ops.matmul(dev, toks, d_tp, d, name="rec_out_proj"))
    out.append(_tp_collective(cfg, system, plan, toks, "allreduce_rec"))
    return out


def layer_ops(cfg: ModelConfig, system: System, plan: Plan, layer: int,
              batch: int, seq: int, kv_len: int) -> LayerCost:
    kind = cfg.block_kind(layer)
    cost = LayerCost()
    if kind == "rwkv":
        for r in rwkv_ops(cfg, system, plan, batch, seq):
            cost.add(r)
        return cost
    if kind == "rglru":
        for r in rglru_ops(cfg, system, plan, batch, seq):
            cost.add(r)
        for r in mlp_ops(cfg, system, plan, batch, seq):
            cost.add(r)
        return cost
    for r in attention_ops(cfg, system, plan, batch, seq, kv_len):
        cost.add(r)
    if cfg.cross_attention or layer in cfg.cross_attn_layers:
        for r in attention_ops(cfg, system, plan, batch, seq, kv_len,
                               cross_len=max(cfg.n_frontend_tokens, 1),
                               prefix="x_"):
            cost.add(r)
    for r in mlp_ops(cfg, system, plan, batch, seq):
        cost.add(r)
    return cost


def model_ops(cfg: ModelConfig, system: System, plan: Plan, batch: int,
              seq: int, kv_len: int, include_head: bool = True) -> LayerCost:
    """Whole-model cost: distinct layer kinds evaluated once and multiplied.

    Layers of the same kind have identical cost — evaluate each kind once
    (this is what makes simulating GPT-3 96 layers as cheap as one layer).
    """
    dev = system.device
    total = LayerCost()
    kinds: dict = {}
    for i in range(cfg.n_layers):
        key = (cfg.block_kind(i),
               cfg.cross_attention or i in cfg.cross_attn_layers)
        kinds[key] = kinds.get(key, 0) + 1
    layers_per_stage = {k: math.ceil(v / plan.pp) for k, v in kinds.items()}
    rep_layer = {}
    for i in range(cfg.n_layers):
        key = (cfg.block_kind(i),
               cfg.cross_attention or i in cfg.cross_attn_layers)
        if key not in rep_layer:
            rep_layer[key] = layer_ops(cfg, system, plan, i, batch, seq, kv_len)
    for key, cnt in layers_per_stage.items():
        lc = rep_layer[key]
        for o in lc.ops:
            total.add(ops.OpResult(o.name, o.latency * cnt, o.flops * cnt,
                                   o.main_memory_bytes * cnt, o.bound,
                                   o.mapping))
    # encoder stack (whisper): runs once per request at prefill
    if cfg.n_encoder_layers and seq > 1:
        enc_len = max(cfg.n_frontend_tokens, 1)
        enc = LayerCost()
        for r in attention_ops(cfg, system, plan, batch, enc_len, enc_len):
            enc.add(r)
        for r in mlp_ops(cfg, system, plan, batch, enc_len):
            enc.add(r)
        for o in enc.ops:
            total.add(ops.OpResult("enc_" + o.name,
                                   o.latency * cfg.n_encoder_layers,
                                   o.flops * cfg.n_encoder_layers,
                                   o.main_memory_bytes * cfg.n_encoder_layers,
                                   o.bound))
    if include_head:
        toks = batch * (seq if seq > 1 else 1)
        total.add(ops.memory_traffic(dev, toks * cfg.d_model * 2, name="embed"))
        total.add(_norm(cfg, dev, toks, "ln_final"))
        total.add(ops.matmul(dev, toks, cfg.d_model,
                             max(1, cfg.vocab_size // plan.tp), name="lm_head"))
    return total
