"""Declarative Study API: the one front-door for design-space sweeps (ISSUE 2).

The paper's headline workflow is the systems x models x workloads grid
(Sec. V-VII). A `Study` makes that grid the first-class object: declare
cross-products of Systems, ModelConfigs, Plans and Workloads (or an explicit
`Case` list) and `run()` them as one unit. Under the hood the Study

  * owns ONE shared Evaluator per System (spec-level dedup across every case
    that targets it),
  * pre-collects every un-memoized (device, GEMM-shape) pair across the WHOLE
    grid and solves them in one device-axis stacked mapper search
    (`mapper.matmul_perf_batch_multi`) before any case is priced — the
    cross-System analog of the per-call shapes axis,
  * prices die area and cost once per distinct device (area.py / cost.py),
  * applies the planner's memory-fit check before paying for evaluation
    (`enforce_fits=False` to reproduce paper microbenchmarks regardless),
  * serves previously-priced cases from the persistent content-hashed
    CaseResult cache (ISSUE 6, core/result_cache.py): a rerun of an
    overlapping grid — same process or a later session — re-prices only the
    new cases, bit-identically to the uncached path. serve-stage cases are
    not cached (their SimResult carries full latency distributions); disable
    per Study with `result_cache=False` or globally via REPRO_DISK_CACHE=0.

Every case's numbers are bit-for-bit identical to the single-case seed path
(`inference_model.generate` et al. with a cold Evaluator) — tested against
frozen seed-commit numbers in tests/test_study.py.
"""
from __future__ import annotations

import csv
import io
import math
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from typing import (Any, Dict, Iterable, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Union, cast)

from ..configs.base import ModelConfig
from . import area as area_mod
from . import cost as cost_mod
from . import inference_model as im
from .evaluator import Evaluator
from .fusion import FusionPolicy, fuse, fusion_tag
from .fusion import SERIAL as SERIAL_FUSION
from .graph import Plan, build_layer, build_model
from .hardware import Device, System
from .ir import FusedMatmulSpec, Graph, MatmulSpec
from .mapper import is_memoized, matmul_perf_batch_multi
from . import obs
from .precision import DEFAULT, PrecisionPolicy, policy_tag
from . import result_cache as result_cache_mod
from .result_cache import MODEL_VERSION, DiskCache, content_key
from . import simulator as sim_mod
from . import verify as verify_mod
from .workload import TrafficWorkload, Workload

#: evaluation stages a Case can request
#:   generate — prefill + decode trapezoid (the end-to-end request metric)
#:   prefill  — one full-model prefill pass at in_len
#:   decode   — one full-model decode step at kv = in_len + out_len
#:   layer    — single-layer prefill AND decode microbenchmark (paper
#:              Table III / Fig. 8 / Fig. 9 convention: prefill at seq=in_len,
#:              decode at kv = in_len + out_len, no lm head, no pipeline fill)
#:   serve    — trace-driven continuous-batching replay (core/simulator.py);
#:              requires a TrafficWorkload (slots + trace + policy)
STAGES = ("generate", "prefill", "decode", "layer", "serve")


@dataclass(frozen=True)
class Case:
    """One point of the evaluation grid — frozen, hashable, declarative.

    `policy` is the precision axis (ISSUE 4): it stamps per-operand byte
    widths and compute rates on every graph this case builds, and prices the
    memory-fit gate at quantized weight/KV footprints. (Not to be confused
    with TrafficWorkload.policy, the scheduler policy string.)
    `fusion` is the execution-model axis (ISSUE 5): which kernel-fusion
    rewrites apply and whether latency is the overlap-scheduled makespan or
    the serial sum. `policy_label` / `fusion_label` name the grid-axis
    points in result rows (default to the preset name / structural tag)."""
    system: System
    cfg: ModelConfig
    plan: Plan
    workload: Workload
    stage: str = "generate"
    label: str = ""
    policy: PrecisionPolicy = DEFAULT
    policy_label: str = ""
    fusion: FusionPolicy = SERIAL_FUSION
    fusion_label: str = ""

    def __post_init__(self):
        if self.stage not in STAGES:
            raise ValueError(f"unknown stage {self.stage!r}; have {STAGES}")
        if not isinstance(self.policy, PrecisionPolicy):
            raise TypeError(
                f"Case.policy must be a precision.PrecisionPolicy, got "
                f"{self.policy!r} — the scheduler policy string "
                f"('continuous'/'static') belongs on the TrafficWorkload")
        if not isinstance(self.fusion, FusionPolicy):
            raise TypeError(f"Case.fusion must be a fusion.FusionPolicy, "
                            f"got {self.fusion!r}")
        if self.stage == "serve" and not isinstance(self.workload,
                                                    TrafficWorkload):
            raise ValueError("stage='serve' needs a TrafficWorkload "
                             "(slots + trace + policy)")

    @property
    def policy_tag(self) -> str:
        """Row name of this case's precision point: the grid-axis label when
        one was given, else the preset name / structural tag."""
        return self.policy_label or policy_tag(self.policy)

    @property
    def fusion_tag(self) -> str:
        """Row name of this case's execution-model point."""
        return self.fusion_label or fusion_tag(self.fusion)


@dataclass(frozen=True)
class CaseResult:
    """Structured result row for one Case (latency in seconds)."""
    case: Case
    latency: float              # stage metric: generate/prefill/decode lat.
    throughput: float           # output tok/s (pipeline-full steady state)
    memory_per_device: float    # bytes, planner memory model
    fits: bool
    dominant: str               # binding resource of the (prefill) breakdown
    decode_dominant: str        # binding resource of the decode step ("layer")
    flops: float
    bytes: float
    prefill_latency: float
    decode_latency: float
    area_mm2: float             # die area of ONE device
    device_cost_usd: float      # manufacturing cost of ONE device
    system_cost_usd: float      # device cost x device_count
    perf_per_dollar: float      # throughput / system_cost_usd
    sim: Optional[sim_mod.SimResult] = None   # serve stage: the full replay
    #: per-op attribution of this case's evaluated graph(s) (core/obs.py);
    #: None for serve-stage cases (the SimResult carries the replay)
    attribution: Optional[obs.Attribution] = None
    #: the primary graph's schedule.critical_breakdown(), largest first:
    #: ((op name | "(stall)", seconds), ...) — queryable straight from CSV
    critical: Tuple[Tuple[str, float], ...] = ()

    def to_row(self) -> dict:
        c = self.case
        w = c.workload
        s = self.sim
        return {
            "label": c.label, "stage": c.stage,
            "device": c.system.device.name,
            "n_devices": c.system.device_count,
            "model": c.cfg.name,
            "policy": c.policy_tag,
            "fusion": c.fusion_tag,
            "tp": c.plan.tp, "pp": c.plan.pp, "dp": c.plan.dp,
            "ep": c.plan.ep, "sp": c.plan.sequence_parallel,
            "batch": w.batch, "in_len": w.in_len, "out_len": w.out_len,
            "latency_s": self.latency,
            "throughput_tok_s": self.throughput,
            "memory_per_device_gib": self.memory_per_device / 2 ** 30,
            "fits": self.fits,
            "dominant_bound": self.dominant,
            "prefill_s": self.prefill_latency,
            "decode_s": self.decode_latency,
            "area_mm2": self.area_mm2,
            "system_cost_usd": self.system_cost_usd,
            "perf_per_usd": self.perf_per_dollar,
            "ttft_p50_s": s.ttft(50) if s else "",
            "ttft_p99_s": s.ttft(99) if s else "",
            "tpot_p50_s": s.tpot(50) if s else "",
            "goodput_tok_s": s.goodput if s else "",
            "elided_bytes": self.attribution.elided
            if self.attribution is not None else "",
            "critical_breakdown": "|".join(
                f"{k}={v:.6g}" for k, v in self.critical),
        }


@dataclass
class StudyStats:
    """Grid-level accounting: what one run() shared and pre-solved."""
    cases: int = 0
    evaluated: int = 0
    skipped_unfit: int = 0
    systems: int = 0
    devices: int = 0
    matmul_pairs_presolved: int = 0   # unique un-memoized (device, shape)
    case_cache_hits: int = 0          # CaseResults served from disk (ISSUE 6)
    case_cache_misses: int = 0        # cacheable cases actually evaluated
    presolve_seconds: float = 0.0
    total_seconds: float = 0.0

    def summary(self) -> str:
        return (f"cases={self.cases} evaluated={self.evaluated} "
                f"skipped_unfit={self.skipped_unfit} "
                f"systems={self.systems} devices={self.devices} "
                f"matmul_pairs_presolved={self.matmul_pairs_presolved} "
                f"case_cache_hits={self.case_cache_hits} "
                f"case_cache_misses={self.case_cache_misses} "
                f"presolve_s={self.presolve_seconds:.2f} "
                f"total_s={self.total_seconds:.2f}")


_OBJECTIVES = {
    "latency": (lambda r: r.latency, False),
    "throughput": (lambda r: r.throughput, True),
    "perf_per_dollar": (lambda r: r.perf_per_dollar, True),
}


class StudyResult:
    """Ordered CaseResult rows + grid stats + the shared evaluators."""

    def __init__(self, results: List[CaseResult], stats: StudyStats,
                 evaluators: Dict[System, Evaluator]) -> None:
        self.results = results
        self.stats = stats
        self.evaluators = evaluators

    def __iter__(self) -> Iterator[CaseResult]:
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i) -> CaseResult:
        return self.results[i]

    # -- structured access -------------------------------------------------
    def to_rows(self) -> List[dict]:
        return [r.to_row() for r in self.results]

    def to_csv(self, path: Optional[str] = None) -> str:
        rows = self.to_rows()
        buf = io.StringIO()
        if rows:
            w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def filter(self, **kw) -> List[CaseResult]:
        """Select rows by case attributes: device (name), model (cfg name),
        system, plan, workload, stage, label, policy (a PrecisionPolicy, or
        a string matching the row's policy tag — the grid-axis key / preset
        name / structural tag shown in to_rows()), fusion (a FusionPolicy
        or its tag string), batch, in_len, out_len."""
        def matches(r: CaseResult, key: str, v) -> bool:
            c = r.case
            if key == "policy":
                if isinstance(v, str):
                    return v in (c.policy_tag, policy_tag(c.policy))
                return c.policy == v
            if key == "fusion":
                if isinstance(v, str):
                    return v in (c.fusion_tag, fusion_tag(c.fusion))
                return c.fusion == v
            try:
                return v == {
                    "device": c.system.device.name,
                    "model": c.cfg.name,
                    "system": c.system,
                    "plan": c.plan,
                    "workload": c.workload,
                    "stage": c.stage,
                    "label": c.label,
                    "batch": c.workload.batch,
                    "in_len": c.workload.in_len,
                    "out_len": c.workload.out_len,
                }[key]
            except KeyError:
                raise KeyError(f"unknown filter key {key!r}")

        return [r for r in self.results
                if all(matches(r, k, v) for k, v in kw.items())]

    def get(self, **kw) -> CaseResult:
        hits = self.filter(**kw)
        if len(hits) != 1:
            raise KeyError(f"filter {kw} matched {len(hits)} rows, need 1")
        return hits[0]

    def best(self, objective: str = "latency") -> CaseResult:
        """Best FITTING row under the objective (latency | throughput |
        perf_per_dollar)."""
        try:
            key, maximize = _OBJECTIVES[objective]
        except KeyError:
            raise ValueError(f"unknown objective {objective!r}; "
                             f"have {sorted(_OBJECTIVES)}")
        fitting = [r for r in self.results if r.fits]
        if not fitting:
            raise ValueError("no case fits device memory under any plan")
        return (max if maximize else min)(fitting, key=key)


PlanAxis = Union[str, Sequence[Plan], None]


class Study:
    """Declarative sweep: systems x configs x plans x workloads, or explicit
    cases. Construct, then `run()` once; rerunning reuses the evaluators."""

    def __init__(self,
                 systems: Optional[Sequence[System]] = None,
                 configs: Optional[Sequence[ModelConfig]] = None,
                 plans: PlanAxis = None,
                 workloads: Union[Mapping[str, Workload],
                                  Sequence[Workload], None] = None,
                 policies: Union[Mapping[str, PrecisionPolicy],
                                 Sequence[PrecisionPolicy], None] = None,
                 fusions: Union[Mapping[str, FusionPolicy],
                                Sequence[FusionPolicy], None] = None,
                 cases: Optional[Iterable[Case]] = None,
                 stage: str = "generate",
                 enforce_fits: bool = True,
                 evaluators: Optional[Mapping[System, Evaluator]] = None,
                 result_cache: Optional[bool] = None,
                 verify: Optional[str] = None
                 ) -> None:
        if cases is not None:
            if any(x is not None for x in (systems, configs, workloads,
                                           policies, fusions)) \
                    or plans is not None:
                raise ValueError("pass either an explicit case list OR grid "
                                 "axes, not both")
            self.cases = list(cases)
        else:
            if not systems or not configs or not workloads:
                raise ValueError("a grid Study needs systems, configs and "
                                 "workloads (plans default to [Plan()], "
                                 "policies to [precision.DEFAULT], fusions "
                                 "to [fusion.SERIAL])")
            self.cases = self._expand(systems, configs, plans, workloads,
                                      policies, fusions, stage)
        self.enforce_fits = enforce_fits
        self._evaluators: Dict[System, Evaluator] = \
            dict(evaluators) if evaluators else {}
        self._prices: Dict[tuple, tuple] = {}   # (device, link_bw) -> price
        # persistent CaseResult layer (ISSUE 6): re-running an overlapping
        # grid re-prices only new cases. result_cache=None follows the
        # global disk switch (result_cache.configure / REPRO_DISK_CACHE),
        # True forces the layer on for this Study, False opts out.
        self._case_cache = None if result_cache is False \
            else DiskCache("cases", enabled=result_cache)
        # the caller's tri-state (None=follow global / True / False), so
        # run(workers=N) shard processes rebuild the same cache policy
        self._result_cache_opt = result_cache
        # static verification mode (ISSUE 7): plan/policy rules run once per
        # unique grid point before any evaluation; graphs are linted by the
        # shared Evaluators as cases price. enforce_fits owns the memory
        # decision, so verify_case skips the capacity rule here.
        self.verify_mode = verify_mod.resolve_mode(verify)

    @staticmethod
    def _expand(systems, configs, plans, workloads, policies, fusions,
                stage) -> List[Case]:
        if isinstance(workloads, Mapping):
            wl_items = list(workloads.items())
        else:
            wl_items = [(w.tag, w) for w in workloads]
        if policies is None:
            pol_items = [("", DEFAULT)]
        elif isinstance(policies, Mapping):
            pol_items = list(policies.items())    # keys name the row points
        else:
            pol_items = [("", p) for p in policies]
        if fusions is None:
            fus_items = [("", SERIAL_FUSION)]
        elif isinstance(fusions, Mapping):
            fus_items = list(fusions.items())
        else:
            fus_items = [("", f) for f in fusions]
        if plans is None:
            plans = [Plan()]
        elif plans != "auto":
            plans = list(plans)    # once: survive one-shot iterables
        out = []
        for system in systems:
            for cfg in configs:
                if plans == "auto":
                    from .planner import enumerate_plans   # avoid cycle
                    plan_list = enumerate_plans(system, cfg)
                else:
                    plan_list = plans
                for plan in plan_list:
                    for pname, pol in pol_items:
                        for fname, fus in fus_items:
                            for label, w in wl_items:
                                out.append(Case(system, cfg, plan, w,
                                                stage=stage, label=label,
                                                policy=pol,
                                                policy_label=pname,
                                                fusion=fus,
                                                fusion_label=fname))
        return out

    # ------------------------------------------------------------------
    def _evaluator(self, system: System) -> Evaluator:
        """One Evaluator per System for the Study's lifetime: provided ones
        are validated, created ones are kept so rerunning run() reuses them."""
        ev = im._evaluator(system, self._evaluators.get(system),
                           verify=self.verify_mode)
        self._evaluators[system] = ev
        return ev

    @staticmethod
    def _graphs(case: Case) -> List[Graph]:
        """The symbolic graphs this case will evaluate (for shape pre-pass
        AND, for the layer stage, the evaluation itself), already rewritten
        under the case's fusion policy so the pre-pass collects the fused
        GEMM shapes the evaluation will actually solve."""
        w, cfg, plan, pol = case.workload, case.cfg, case.plan, case.policy
        fus = case.fusion
        if case.stage == "generate":
            graphs, _ = im.generate_graphs(cfg, plan, w.batch, w.in_len,
                                           w.out_len, w.samples, pol, fus)
            return graphs
        if case.stage == "prefill":
            return [fuse(build_model(cfg, plan, w.batch, w.in_len,
                                     kv_len=w.in_len, policy=pol), fus)]
        if case.stage == "decode":
            return [fuse(build_model(cfg, plan, w.batch, seq=1,
                                     kv_len=w.total_len, policy=pol), fus)]
        if case.stage == "serve":
            return sim_mod.trace_graphs(cfg, plan, w, pol, fus)
        # layer: single-layer prefill + decode microbenchmark graphs
        return [fuse(build_layer(cfg, plan, 0, w.batch, w.in_len, w.in_len,
                                 pol), fus),
                fuse(build_layer(cfg, plan, 0, w.batch, 1, w.total_len,
                                 pol), fus)]

    def _price(self, system: System) -> tuple:
        """(area_mm2, device_cost_usd) — computed once per distinct device
        (and link bandwidth, which sets the SerDes PHY area share)."""
        dev: Device = system.device
        link_gbps = system.link.bandwidth_bytes / 1e9
        key = (dev, link_gbps)
        if key not in self._prices:
            a = area_mod.device_area(dev, link_gbps).total_mm2
            c = cost_mod.device_cost(dev, a).total_usd
            self._prices[key] = (a, c)
        return self._prices[key]

    # ---- persistent CaseResult layer (ISSUE 6) -----------------------
    _CASE_DOC_FIELDS = ("latency", "throughput", "dominant",
                        "decode_dominant", "flops", "bytes", "prefill",
                        "decode", "critical", "attribution")

    @staticmethod
    def _case_key(case: Case) -> str:
        """Content hash of everything that determines a case's numbers:
        the full System/config/plan/workload/policy/fusion value tree, the
        stage, the model-version salt, and the active mapper backend (JAX
        latencies may differ from numpy in the last ulp — a warm rerun must
        be bit-identical to its own backend's cold path). Display labels are
        deliberately excluded: relabeling a grid point reuses its numbers."""
        from .mapper import get_mapper_backend   # avoid import cycle at top
        return content_key(
            case.system, case.cfg, case.plan, case.workload, case.policy,
            case.fusion, case.stage,
            salt=f"{MODEL_VERSION}/case/{get_mapper_backend()}")

    def _case_to_doc(self, r: CaseResult) -> dict:
        return {"latency": r.latency, "throughput": r.throughput,
                "dominant": r.dominant, "decode_dominant": r.decode_dominant,
                "flops": r.flops, "bytes": r.bytes,
                "prefill": r.prefill_latency, "decode": r.decode_latency,
                "critical": [[k, v] for k, v in r.critical],
                "attribution": r.attribution.to_doc()
                if r.attribution is not None else None}

    def _case_from_doc(self, doc: dict, case: Case, mem: float,
                       fits: bool) -> Optional[CaseResult]:
        if not all(f in doc for f in self._CASE_DOC_FIELDS):
            return None                     # malformed/older entry: miss
        try:
            att = None
            if doc["attribution"] is not None:
                att = obs.Attribution.from_doc(doc["attribution"])
                if att is None:
                    return None             # malformed attribution: miss
            crit = tuple((str(k), float(v)) for k, v in doc["critical"])
            price_a, price_c = self._price(case.system)
            sys_cost = price_c * case.system.device_count
            thr = float(doc["throughput"])
            return CaseResult(
                case, float(doc["latency"]), thr, mem, fits,
                str(doc["dominant"]), str(doc["decode_dominant"]),
                float(doc["flops"]), float(doc["bytes"]),
                float(doc["prefill"]), float(doc["decode"]),
                price_a, price_c, sys_cost,
                thr / sys_cost if sys_cost > 0 else 0.0,
                attribution=att, critical=crit)
        except (TypeError, ValueError):
            return None

    # ------------------------------------------------------------------
    def run(self, workers: Optional[int] = None) -> StudyResult:
        """Evaluate the grid. `workers=N` (N >= 2) shards the cases across
        a ProcessPoolExecutor — deterministic round-robin by case index, so
        `StudyResult` rows come back byte-identical to the serial path (the
        paper's core invariant: case numbers depend only on case content).
        Each worker runs an ordinary serial Study over its shard with its
        own Evaluators, sharing warmth through the content-hashed disk
        caches (atomic per-entry writes make concurrent same-key puts
        safe); stats, EvalStats and MetricsRegistry counters merge at join
        (`MetricsRegistry.merge_delta`). `workers=None`/0/1 is the
        unchanged serial path."""
        n = 1 if workers is None else int(workers)
        if n < 0:
            raise ValueError(f"workers must be >= 0, got {workers}")
        if n <= 1 or len(self.cases) < 2:
            return self._run_serial()
        return self._run_parallel(min(n, len(self.cases)))

    def _run_parallel(self, workers: int) -> StudyResult:
        t0 = time.perf_counter()
        reg = obs.metrics()
        from .mapper import get_mapper_backend, get_mapper_prune
        common = (self.enforce_fits, self._result_cache_opt,
                  self.verify_mode, get_mapper_backend(), get_mapper_prune(),
                  str(result_cache_mod.cache_root()),
                  result_cache_mod.cache_enabled(), reg.enabled)
        idx_shards = [list(range(w, len(self.cases), workers))
                      for w in range(workers)]
        payloads = [([self.cases[i] for i in sh],) + common
                    for sh in idx_shards]
        with ProcessPoolExecutor(max_workers=workers) as pool:
            outs = list(pool.map(_study_worker, payloads))

        results: List[Optional[CaseResult]] = [None] * len(self.cases)
        stats = StudyStats(cases=len(self.cases))
        evaluators: Dict[System, Evaluator] = {}
        for case in self.cases:
            if case.system not in evaluators:
                evaluators[case.system] = self._evaluator(case.system)
        stats.systems = len(evaluators)
        stats.devices = len({s.device for s in evaluators})
        for sh, (shard_results, wstats, ev_docs, delta) in zip(idx_shards,
                                                               outs):
            for i, r in zip(sh, shard_results):
                results[i] = r
            stats.evaluated += wstats.evaluated
            stats.skipped_unfit += wstats.skipped_unfit
            stats.matmul_pairs_presolved += wstats.matmul_pairs_presolved
            stats.case_cache_hits += wstats.case_cache_hits
            stats.case_cache_misses += wstats.case_cache_misses
            stats.presolve_seconds += wstats.presolve_seconds
            reg.merge_delta(delta)
            for system, doc in ev_docs:
                ev = evaluators.get(system)
                if ev is None:
                    evaluators[system] = ev = self._evaluator(system)
                ev.stats.merge(doc)
        stats.total_seconds = time.perf_counter() - t0
        return StudyResult(cast(List[CaseResult], results), stats,
                           evaluators)

    def _run_serial(self) -> StudyResult:
        t0 = time.perf_counter()
        stats = StudyStats(cases=len(self.cases))
        evaluators: Dict[System, Evaluator] = {}
        for case in self.cases:
            if case.system not in evaluators:
                evaluators[case.system] = self._evaluator(case.system)
        stats.systems = len(evaluators)
        stats.devices = len({s.device for s in evaluators})

        # ---- static verification pre-pass (ISSUE 7) ----------------------
        # plan + policy rules once per unique grid point, before any mapper
        # or memory work; cases sharing a point share one lint.
        reg = obs.metrics()
        if self.verify_mode != "off":
            with reg.phase("verify"):
                linted = set()
                for case in self.cases:
                    w = case.workload
                    point = (case.system, case.cfg, case.plan, case.policy,
                             w.batch, w.total_len)
                    if point in linted:
                        continue
                    linted.add(point)
                    verify_mod.verify_case(case, mode=self.verify_mode)

        # ---- memory-fit pre-pass (planner model; no evaluation cost) -----
        prelim = []
        for case in self.cases:
            w = case.workload
            mem = im.memory_per_device(case.cfg, case.plan, w.batch,
                                       w.total_len, case.policy)
            fits = mem <= case.system.device.memory_capacity
            prelim.append((case, mem, fits))

        # ---- persistent CaseResult layer: hits skip graph building, the
        # ---- mapper presolve AND evaluation (re-price only new cases) ----
        cached: Dict[int, CaseResult] = {}
        keys: Dict[int, str] = {}
        cc = self._case_cache
        if cc is not None and cc.enabled:
            for idx, (case, mem, fits) in enumerate(prelim):
                if case.stage == "serve":
                    continue        # sim replays carry full distributions
                if self.enforce_fits and not fits:
                    continue
                key = self._case_key(case)
                keys[idx] = key
                doc = cc.get(key)
                r = self._case_from_doc(doc, case, mem, fits) \
                    if doc is not None else None
                if r is not None:
                    cached[idx] = r
                    stats.case_cache_hits += 1
                    evaluators[case.system].stats.case_hits += 1
                    reg.inc("study.case_hits")
                else:
                    stats.case_cache_misses += 1
                    evaluators[case.system].stats.case_misses += 1
                    reg.inc("study.case_misses")

        # ---- grid-wide device-axis stacked mapper search -----------------
        t_pre = time.perf_counter()
        pairs, seen = [], set()
        for idx, (case, _, fits) in enumerate(prelim):
            if idx in cached:
                continue
            if self.enforce_fits and not fits:
                continue
            ev = evaluators[case.system]
            if ev.use_reference_mapper or not ev.batch_matmuls:
                continue    # seed-replica evaluators keep the eager path
            dev = case.system.device
            for g in self._graphs(case):
                for node in g:
                    s = node.spec
                    if isinstance(s, FusedMatmulSpec):
                        s = s.gemm     # presolve the fused kernel's GEMM
                    if not isinstance(s, MatmulSpec):
                        continue
                    pair = (dev, s.shape)
                    if pair not in seen and not is_memoized(*pair):
                        seen.add(pair)
                        pairs.append(pair)
        if pairs:
            with reg.phase("presolve"):
                matmul_perf_batch_multi(pairs)
        stats.matmul_pairs_presolved = len(pairs)
        stats.presolve_seconds = time.perf_counter() - t_pre

        # ---- per-case evaluation (all mapper work is now memo hits) ------
        results = []
        for idx, (case, mem, fits) in enumerate(prelim):
            if idx in cached:
                stats.evaluated += 1
                results.append(cached[idx])
                continue
            price_a, price_c = self._price(case.system)
            sys_cost = price_c * case.system.device_count
            if self.enforce_fits and not fits:
                stats.skipped_unfit += 1
                results.append(CaseResult(
                    case, math.inf, 0.0, mem, False, "n/a", "n/a",
                    0.0, 0.0, math.inf, math.inf,
                    price_a, price_c, sys_cost, 0.0))
                continue
            stats.evaluated += 1
            with reg.phase("evaluate"):
                r = self._evaluate(case, mem, fits, evaluators[case.system],
                                   price_a, price_c, sys_cost)
            if idx in keys:
                cc.put(keys[idx], self._case_to_doc(r))
            results.append(r)
        stats.total_seconds = time.perf_counter() - t0
        return StudyResult(results, stats, evaluators)

    def _evaluate(self, case: Case, mem: float, fits: bool, ev: Evaluator,
                  price_a: float, price_c: float,
                  sys_cost: float) -> CaseResult:
        w, cfg, plan, system = case.workload, case.cfg, case.plan, case.system
        pol, fus = case.policy, case.fusion
        dec_dom = "n/a"
        sim = None
        if case.stage == "serve":
            sim = sim_mod.simulate(system, cfg, plan, w, evaluator=ev,
                                   policy=pol, fusion=fus)
            latency = sim.e2e(50)           # median request e2e
            thr = sim.goodput
            pf, dc = sim.prefill_busy, sim.decode_busy
            dom, flops, bytes_ = sim.dominant, sim.flops, sim.bytes
        elif case.stage == "generate":
            rep = im.generate(system, cfg, plan, w.batch, w.in_len, w.out_len,
                              samples=w.samples, evaluator=ev, policy=pol,
                              fusion=fus)
            latency = rep.latency
            thr = im.throughput_from_generate(rep, plan, w.batch, w.out_len)
            pf, dc = rep.breakdown["prefill"], rep.breakdown["decode"]
            dom, flops, bytes_ = rep.dominant, rep.flops, rep.bytes
        elif case.stage == "prefill":
            rep = im.prefill(system, cfg, plan, w.batch, w.in_len,
                             evaluator=ev, policy=pol, fusion=fus)
            latency = pf = rep.latency
            dc = 0.0
            thr = w.tokens_in * plan.dp * plan.pp / latency
            dom, flops, bytes_ = rep.dominant, rep.flops, rep.bytes
        elif case.stage == "decode":
            rep = im.decode_step(system, cfg, plan, w.batch, w.total_len,
                                 evaluator=ev, policy=pol, fusion=fus)
            latency = dc = rep.latency
            pf = 0.0
            thr = w.batch * plan.dp * plan.pp / latency
            dom, flops, bytes_ = rep.dominant, rep.flops, rep.bytes
        else:   # layer microbenchmark: prefill + decode single-layer graphs
            pf_c, dc_c = ev.evaluate_many(self._graphs(case),
                                          overlap=fus.overlap)
            latency = pf = pf_c.latency
            dc = dc_c.latency
            thr = 0.0
            dom = max(pf_c.by_bound(), key=pf_c.by_bound().get)
            dec_dom = max(dc_c.by_bound(), key=dc_c.by_bound().get)
            flops = pf_c.flops + dc_c.flops
            bytes_ = pf_c.bytes + dc_c.bytes
        att, crit = self._attribution(case, ev)
        return CaseResult(case, latency, thr, mem, fits, dom, dec_dom,
                          flops, bytes_, pf, dc, price_a, price_c, sys_cost,
                          thr / sys_cost if sys_cost > 0 else 0.0, sim=sim,
                          attribution=att, critical=crit)

    def _attribution(self, case: Case, ev: Evaluator
                     ) -> Tuple[Optional[obs.Attribution],
                                Tuple[Tuple[str, float], ...]]:
        """Per-op attribution + critical-path breakdown of this case's
        primary graph(s). Every spec is already in the Evaluator's cache
        after _evaluate, so this re-prices nothing — it only re-assembles
        the per-op rows the stage helpers collapsed into scalars. Serve
        cases carry their SimResult instead."""
        if case.stage == "serve":
            return None, ()
        graphs = self._graphs(case)
        if case.stage in ("generate", "layer") and len(graphs) > 1:
            sections = [("prefill/", graphs[0]), ("decode/", graphs[1])]
        else:
            sections = [("", graphs[0])]
        costs = ev.evaluate_many([g for _, g in sections],
                                 overlap=case.fusion.overlap)
        atts = [obs.attribute(g, c, label=case.stage, prefix=pre)
                for (pre, g), c in zip(sections, costs)]
        att = atts[0] if len(atts) == 1 else obs.combine(case.stage, atts)
        crit = tuple(sorted(costs[0].critical_breakdown().items(),
                            key=lambda kv: (-kv[1], kv[0])))
        return att, crit


def _study_worker(payload: Tuple[Any, ...]) -> Tuple[Any, ...]:
    """Entry point of one `Study.run(workers=N)` shard process.

    The parent ships its resolved configuration explicitly (cache root +
    enabled flag, mapper backend and prune mode, verify mode, phase-span
    switch) rather than relying on inherited globals, so shards behave
    identically under fork and spawn start methods — runtime overrides like
    `result_cache.overridden(root=...)` are re-applied here. The shard runs
    as a plain serial Study (its own Evaluators, its own case-cache
    lookups) and returns its ordered CaseResults plus the stats and the
    registry counter delta the parent merges at join."""
    (cases, enforce_fits, use_cache, verify_mode, backend, prune,
     cache_root, cache_enabled, spans) = payload
    from . import mapper
    result_cache_mod.configure(root=cache_root, enabled=cache_enabled)
    try:
        mapper.set_mapper_backend(backend)
    except ImportError:                 # jax missing in the child: degrade
        mapper.set_mapper_backend("numpy")
    mapper.set_mapper_prune(prune)
    reg = obs.metrics()
    reg.set_enabled(spans)
    base = reg.snapshot()
    st = Study(cases=list(cases), enforce_fits=enforce_fits,
               result_cache=use_cache, verify=verify_mode)
    res = st._run_serial()
    snap = reg.snapshot()
    delta = {k: v - base.get(k, 0.0) for k, v in sorted(snap.items())
             if v != base.get(k, 0.0)}
    ev_docs = [(system, ev.stats.to_doc())
               for system, ev in res.evaluators.items()]
    return res.results, res.stats, ev_docs, delta
