"""Symbolic operator IR: describe the computation, evaluate it later.

The seed code called the operator performance models (operators.py) eagerly
while walking the model graph, so every planner candidate and every KV sample
point re-paid the full cost-model walk. This module splits "what computation
happens" from "how long it takes on a device": graph.py builds a Graph of
hashable OpSpec nodes, and evaluator.Evaluator turns a Graph (or many Graphs)
into latencies — deduplicating identical specs, memoizing results, and
batching the vectorized mapper search over unique matmul shapes.

Design rules (DESIGN.md §2):
  * every spec is a frozen, hashable dataclass — specs ARE cache keys;
  * specs carry no device/system state: the same Graph can be evaluated on
    any hardware description;
  * a Node pairs a spec with a display name (for breakdowns) and a repeat
    count — the n identical transformer layers of a stage become one node
    with repeat=n, exactly mirroring the seed's evaluate-once-multiply path.

Dataflow edges (ISSUE 5, DESIGN.md §9): a Graph is a DAG, not just an
ordered list. Each Node carries `deps`, the indices of its producers within
the Graph. `deps=None` means "the previous node" — so a graph built without
explicit edges is a pure chain whose scheduled latency equals the serial
sum bit-for-bit, recovering the pre-DAG behavior exactly. Every spec kind
occupies one of three device resources (`resource_of`): the systolic/MXU
datapath ("compute"), the vector/SIMD units + HBM streaming ("vector"), or
the interconnect ("link"); core/schedule.py places nodes on per-resource
timelines to price comm/compute overlap.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Iterator, List, Optional, Tuple, Union

from .units import Bytes, BytesPerElement, Elements, FlopsPerElement, Ratio


@dataclass(frozen=True)
class MatmulSpec:
    """C[M,N] = A[M,K] @ B[K,N], `batch` independent instances.

    Evaluated through the mapper's tiling/scheduling search (mapper.py);
    unique shapes across a whole sweep are solved in one batched search.

    Per-operand byte widths (ISSUE 4): A (activations), B (weights or KV
    cache), C (output activations), and the accumulator width the partials
    are staged at in on-chip buffers. `mac_scale` is the systolic issue rate
    relative to the fp16 datapath (precision.mac_scale; power of two).
    Widths may be fractional for sub-byte types (int4 -> 0.5).
    """
    m: int
    k: int
    n: int
    batch: int = 1
    bytes_a: BytesPerElement = 2
    bytes_b: BytesPerElement = 2
    bytes_out: BytesPerElement = 2
    bytes_acc: BytesPerElement = 2
    b_shared: bool = False
    mac_scale: Ratio = 1.0

    @property
    def shape(self) -> Tuple[int, int, int, int, float, float, float,
                             float, bool, float]:
        """The mapper's MatmulShape tuple for this spec."""
        return (self.m, self.k, self.n, self.batch, self.bytes_a,
                self.bytes_b, self.bytes_out, self.bytes_acc, self.b_shared,
                self.mac_scale)


@dataclass(frozen=True)
class SoftmaxSpec:
    """Row-wise online softmax over (rows, cols)."""
    rows: int
    cols: int
    bytes_in: BytesPerElement = 2
    bytes_out: BytesPerElement = 2


@dataclass(frozen=True)
class NormSpec:
    """layernorm (Welford mean/var) or rmsnorm (sum-of-squares) over rows."""
    kind: str                       # "layernorm" | "rmsnorm"
    rows: int
    cols: int
    bytes_in: BytesPerElement = 2
    bytes_out: BytesPerElement = 2


@dataclass(frozen=True)
class ElementwiseSpec:
    """Pointwise map. kind selects the specialised model:
    "gelu" (tanh approx), "silu_mul" (SwiGLU gate, 2 inputs), or "generic"
    (flops_per_elt flops, n_in operand streams)."""
    kind: str                       # "generic" | "gelu" | "silu_mul"
    n_elements: Elements
    flops_per_elt: FlopsPerElement = 1.0
    n_in: int = 1
    bytes_elt: BytesPerElement = 2


@dataclass(frozen=True)
class ScanSpec:
    """Linear-recurrence scan (RWKV6 WKV / RG-LRU) — extension op,
    DESIGN.md §5."""
    seq: int
    batch: int
    d_state: float
    flops_per_step: float
    bytes_io: Bytes
    chunk: int = 128


@dataclass(frozen=True)
class CollectiveSpec:
    """Device-device communication primitive under the LogGP link model.

    n_bytes follows each primitive's convention in interconnect.py (e.g. the
    full gathered size for all_gather). n_devices is the participating group
    size, NOT the system size — the evaluator supplies the link parameters.
    bytes_elt is the element width of the payload: all_reduce prices its
    reduction vector work at the collective's actual element count
    (n_bytes / bytes_elt adds) instead of assuming 2-byte elements.
    """
    kind: str     # "all_reduce" | "reduce_scatter" | "all_gather" | "all_to_all" | "p2p"
    n_bytes: Bytes
    n_devices: int = 0              # 0 -> whole system
    bytes_elt: BytesPerElement = 2


@dataclass(frozen=True)
class TrafficSpec:
    """Pure main-memory data movement (KV append, embedding gather)."""
    n_bytes: Bytes


@dataclass(frozen=True)
class FusedMatmulSpec:
    """A matmul with elementwise/norm/softmax consumers fused as epilogues
    (core/fusion.py): the intermediate tensor never round-trips HBM.

    `gemm` is the *effective* mapper shape — its bytes_out is already
    rescaled to the bytes the fused kernel actually writes (the final
    epilogue's output; 0 when `stream_out` hands the result straight to the
    next GEMM, flash-attention style). `epilogue` ops contribute only their
    vector-unit compute time: their input reads and intermediate writes are
    elided, exactly what kernels/flash_attention and kernels/matmul's fused
    dequant epilogues do on real hardware.

    `elided` records the HBM bytes this fusion removed relative to the
    serial graph (intermediate writes + epilogue re-reads, and for
    stream_out also the consumer GEMM's activation read), accumulated by
    the fusion rewrites per instance of this node. It is the single source
    of truth for fusion savings: both `fusion.elided_bytes` and the
    attribution reports (core/obs.py) sum it rather than re-deriving
    traffic deltas.
    """
    gemm: MatmulSpec
    epilogue: Tuple["OpSpec", ...]
    stream_out: bool = False
    elided: Bytes = 0.0


OpSpec = Union[MatmulSpec, SoftmaxSpec, NormSpec, ElementwiseSpec, ScanSpec,
               CollectiveSpec, TrafficSpec, FusedMatmulSpec]


def resource_of(spec: OpSpec) -> str:
    """The device resource a spec occupies while executing (DESIGN.md §9):
    "compute" (systolic/MXU datapath), "link" (interconnect), or "vector"
    (vector units + HBM streaming) for everything else."""
    if isinstance(spec, (MatmulSpec, FusedMatmulSpec)):
        return "compute"
    if isinstance(spec, CollectiveSpec):
        return "link"
    return "vector"


@dataclass(frozen=True)
class Node:
    """One IR node: a spec, a breakdown name, a repeat multiplier, and its
    producer edges.

    `deps` are indices of this node's producers within the owning Graph.
    `deps=None` (the default) means "the immediately preceding node" — the
    chain — so graphs built without explicit edges keep the exact serial
    semantics of the pre-DAG IR. `deps=()` marks a source node.
    """
    spec: OpSpec
    name: str
    repeat: int = 1
    deps: Optional[Tuple[int, ...]] = None

    @property
    def resource(self) -> str:
        return resource_of(self.spec)


def _shift(node: Node, offset: int) -> Node:
    if node.deps is None or offset == 0:
        return node
    return Node(node.spec, node.name, node.repeat,
                tuple(d + offset for d in node.deps))


@dataclass(frozen=True)
class Graph:
    """A dataflow computation: a tuple of Nodes with producer edges.

    Node order is a valid topological order (deps always point backwards) and
    fixes the float-summation order of serial totals — a pure chain evaluates
    bit-for-bit like the seed eager path. Concatenation (`+`, and
    GraphBuilder.extend) chains across the seam: the first node of the second
    graph depends on the last node of the first, matching both the serial
    semantics and the residual-stream dataflow of stacked layers.
    """
    nodes: Tuple[Node, ...] = ()

    def __iter__(self) -> Iterator[Node]:
        return iter(self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)

    def __add__(self, other: "Graph") -> "Graph":
        off = len(self.nodes)
        return Graph(self.nodes + tuple(_shift(n, off) for n in other.nodes))

    def scaled(self, repeat: int, prefix: str = "") -> "Graph":
        """Multiply every node's repeat (identical layers -> one node x n)."""
        return Graph(tuple(Node(n.spec, prefix + n.name, n.repeat * repeat,
                                n.deps)
                           for n in self.nodes))

    def specs(self) -> List[OpSpec]:
        return [n.spec for n in self.nodes]

    def edges(self) -> List[Tuple[int, ...]]:
        """Resolved producer edges per node: explicit `deps` where given,
        else the chain (previous node). Validates topological order."""
        out: List[Tuple[int, ...]] = []
        for i, n in enumerate(self.nodes):
            deps = ((i - 1,) if i else ()) if n.deps is None else n.deps
            if any(d >= i or d < 0 for d in deps):
                raise ValueError(
                    f"node {i} ({n.name!r}) has a forward/negative dep "
                    f"{deps}; deps must point at earlier nodes")
            out.append(deps)
        return out

    def consumers(self) -> List[List[int]]:
        """Inverse of edges(): for each node, who reads its output."""
        cons: List[List[int]] = [[] for _ in self.nodes]
        for i, deps in enumerate(self.edges()):
            for d in deps:
                cons[d].append(i)
        return cons


class GraphBuilder:
    """Mutable accumulator for Graph construction.

    `add` returns the new node's index so builders can wire explicit
    producer->consumer edges (`deps=`); omitting deps chains to the
    previous node.
    """

    def __init__(self) -> None:
        self._nodes: List[Node] = []

    def __len__(self) -> int:
        return len(self._nodes)

    def add(self, spec: OpSpec, name: str, repeat: int = 1,
            deps: Optional[Tuple[int, ...]] = None) -> int:
        self._nodes.append(Node(spec, name, repeat, deps))
        return len(self._nodes) - 1

    def extend(self, graph_or_nodes: Union[Graph, Iterable[Node]]
               ) -> "GraphBuilder":
        off = len(self._nodes)
        self._nodes.extend(_shift(n, off) for n in graph_or_nodes)
        return self

    def build(self) -> Graph:
        return Graph(tuple(self._nodes))
