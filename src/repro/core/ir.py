"""Symbolic operator IR: describe the computation, evaluate it later.

The seed code called the operator performance models (operators.py) eagerly
while walking the model graph, so every planner candidate and every KV sample
point re-paid the full cost-model walk. This module splits "what computation
happens" from "how long it takes on a device": graph.py builds a Graph of
hashable OpSpec nodes, and evaluator.Evaluator turns a Graph (or many Graphs)
into latencies — deduplicating identical specs, memoizing results, and
batching the vectorized mapper search over unique matmul shapes.

Design rules (DESIGN.md §2):
  * every spec is a frozen, hashable dataclass — specs ARE cache keys;
  * specs carry no device/system state: the same Graph can be evaluated on
    any hardware description;
  * a Node pairs a spec with a display name (for breakdowns) and a repeat
    count — the n identical transformer layers of a stage become one node
    with repeat=n, exactly mirroring the seed's evaluate-once-multiply path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Iterator, List, Tuple, Union


@dataclass(frozen=True)
class MatmulSpec:
    """C[M,N] = A[M,K] @ B[K,N], `batch` independent instances.

    Evaluated through the mapper's tiling/scheduling search (mapper.py);
    unique shapes across a whole sweep are solved in one batched search.

    Per-operand byte widths (ISSUE 4): A (activations), B (weights or KV
    cache), C (output activations), and the accumulator width the partials
    are staged at in on-chip buffers. `mac_scale` is the systolic issue rate
    relative to the fp16 datapath (precision.mac_scale; power of two).
    Widths may be fractional for sub-byte types (int4 -> 0.5).
    """
    m: int
    k: int
    n: int
    batch: int = 1
    bytes_a: Union[int, float] = 2
    bytes_b: Union[int, float] = 2
    bytes_out: Union[int, float] = 2
    bytes_acc: Union[int, float] = 2
    b_shared: bool = False
    mac_scale: float = 1.0

    @property
    def shape(self) -> tuple:
        """The mapper's MatmulShape tuple for this spec."""
        return (self.m, self.k, self.n, self.batch, self.bytes_a,
                self.bytes_b, self.bytes_out, self.bytes_acc, self.b_shared,
                self.mac_scale)


@dataclass(frozen=True)
class SoftmaxSpec:
    """Row-wise online softmax over (rows, cols)."""
    rows: int
    cols: int
    bytes_in: Union[int, float] = 2
    bytes_out: Union[int, float] = 2


@dataclass(frozen=True)
class NormSpec:
    """layernorm (Welford mean/var) or rmsnorm (sum-of-squares) over rows."""
    kind: str                       # "layernorm" | "rmsnorm"
    rows: int
    cols: int
    bytes_in: Union[int, float] = 2
    bytes_out: Union[int, float] = 2


@dataclass(frozen=True)
class ElementwiseSpec:
    """Pointwise map. kind selects the specialised model:
    "gelu" (tanh approx), "silu_mul" (SwiGLU gate, 2 inputs), or "generic"
    (flops_per_elt flops, n_in operand streams)."""
    kind: str                       # "generic" | "gelu" | "silu_mul"
    n_elements: int
    flops_per_elt: float = 1.0
    n_in: int = 1
    bytes_elt: Union[int, float] = 2


@dataclass(frozen=True)
class ScanSpec:
    """Linear-recurrence scan (RWKV6 WKV / RG-LRU) — extension op,
    DESIGN.md §5."""
    seq: int
    batch: int
    d_state: float
    flops_per_step: float
    bytes_io: float
    chunk: int = 128


@dataclass(frozen=True)
class CollectiveSpec:
    """Device-device communication primitive under the LogGP link model.

    n_bytes follows each primitive's convention in interconnect.py (e.g. the
    full gathered size for all_gather). n_devices is the participating group
    size, NOT the system size — the evaluator supplies the link parameters.
    """
    kind: str     # "all_reduce" | "reduce_scatter" | "all_gather" | "all_to_all" | "p2p"
    n_bytes: float
    n_devices: int = 0              # 0 -> whole system


@dataclass(frozen=True)
class TrafficSpec:
    """Pure main-memory data movement (KV append, embedding gather)."""
    n_bytes: float


OpSpec = Union[MatmulSpec, SoftmaxSpec, NormSpec, ElementwiseSpec, ScanSpec,
               CollectiveSpec, TrafficSpec]


@dataclass(frozen=True)
class Node:
    """One IR node: a spec, a breakdown name, and a repeat multiplier."""
    spec: OpSpec
    name: str
    repeat: int = 1


@dataclass(frozen=True)
class Graph:
    """An ordered computation: a tuple of Nodes.

    Ordering matters only for reproducibility of float summation — totals are
    accumulated in node order, matching the seed eager path bit-for-bit.
    """
    nodes: Tuple[Node, ...] = ()

    def __iter__(self) -> Iterator[Node]:
        return iter(self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)

    def __add__(self, other: "Graph") -> "Graph":
        return Graph(self.nodes + other.nodes)

    def scaled(self, repeat: int, prefix: str = "") -> "Graph":
        """Multiply every node's repeat (identical layers -> one node x n)."""
        return Graph(tuple(Node(n.spec, prefix + n.name, n.repeat * repeat)
                           for n in self.nodes))

    def specs(self) -> List[OpSpec]:
        return [n.spec for n in self.nodes]


class GraphBuilder:
    """Mutable accumulator for Graph construction."""

    def __init__(self) -> None:
        self._nodes: List[Node] = []

    def add(self, spec: OpSpec, name: str, repeat: int = 1) -> "GraphBuilder":
        self._nodes.append(Node(spec, name, repeat))
        return self

    def extend(self, graph_or_nodes: Union[Graph, Iterable[Node]]
               ) -> "GraphBuilder":
        self._nodes.extend(graph_or_nodes)
        return self

    def build(self) -> Graph:
        return Graph(tuple(self._nodes))
