"""Trace-driven continuous-batching serving simulator (ISSUE 3).

Answers request-level serving questions — p99 TTFT under Poisson arrivals,
goodput of continuous vs static batching, slot occupancy — analytically, per
hardware design, in seconds: the event loop replays the REAL engine's
scheduling policy (`core.scheduler.SlotScheduler`, the same object
`serving/engine.py` drives) but prices every prefill wave and decode round
with `inference_model`-built graphs evaluated through one shared Evaluator
instead of timing real kernels.

Cost model (mirrors the engine's static-shape execution):

  * a whole-batch admission wave (scheduler idle) prefills all `slots` rows
    right-padded to the wave's longest prompt: priced as one
    `build_model(batch=slots, seq=S)` graph;
  * a refill admission (scheduler busy) prefills each request alone and
    stalls decode while doing so: priced as batch-1 prefills at each
    request's prompt length;
  * a decode round advances ALL slots (dead ones masked): priced as
    `build_model(batch=slots, seq=1, kv_len=max live context)`.

To keep the mapper out of the event loop, the kv and prompt-length axes are
sampled (`kv_samples` / `seq_samples` points, the trick
`inference_model.generate` uses for its decode trapezoid) and every sampled
graph is evaluated in ONE `evaluate_many` call — all unique GEMM shapes of
the whole trace go through a single stacked mapper search; per-round costs
are linear interpolations between sample points. Following generate()'s
accounting, the first output token is priced as a decode round at
kv = in_len right after the prefill wave, so a constant-arrival uniform
trace reproduces `generate()`/`throughput()` within a fraction of a percent
(tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ModelConfig
from . import inference_model as im
from .evaluator import Evaluator
from .fusion import SERIAL, FusionPolicy, fuse
from .graph import Graph, LayerCost, Plan, build_model
from .hardware import System
from .precision import DEFAULT, PrecisionPolicy
from .scheduler import SlotScheduler
from .units import Bytes, Flops, PerSecond, Ratio, Seconds
from . import verify as verify_mod
from .workload import Trace, TrafficWorkload

__all__ = ["Trace", "TrafficWorkload", "SimResult", "RequestStats",
           "simulate", "trace_graphs"]


# ---------------------------------------------------------------------------
# sampled cost tables
# ---------------------------------------------------------------------------

@dataclass
class _RoundCost:
    """Price of one engine round: latency + accounting to aggregate."""
    latency: Seconds
    flops: Flops
    bytes: Bytes
    bound: Dict[str, float]

    @classmethod
    def of(cls, c: LayerCost) -> "_RoundCost":
        return cls(c.latency, c.flops, c.bytes, c.by_bound())


def _lerp(a: _RoundCost, b: _RoundCost, w: float) -> _RoundCost:
    if w <= 0.0:
        return a
    keys = set(a.bound) | set(b.bound)
    return _RoundCost(
        a.latency + (b.latency - a.latency) * w,
        a.flops + (b.flops - a.flops) * w,
        a.bytes + (b.bytes - a.bytes) * w,
        {k: a.bound.get(k, 0.0)
         + (b.bound.get(k, 0.0) - a.bound.get(k, 0.0)) * w for k in keys})


class _Interp:
    """Piecewise-linear interpolation of _RoundCost over an integer axis."""

    def __init__(self, xs: Sequence[int], costs: Sequence[LayerCost]):
        self.xs = list(xs)
        self.cs = [_RoundCost.of(c) for c in costs]

    def at(self, x: int) -> _RoundCost:
        xs = self.xs
        if x <= xs[0] or len(xs) == 1:
            return self.cs[0]
        if x >= xs[-1]:
            return self.cs[-1]
        j = int(np.searchsorted(xs, x, side="right"))
        lo, hi = xs[j - 1], xs[j]
        return _lerp(self.cs[j - 1], self.cs[j], (x - lo) / (hi - lo))


def _subsample(values, k: int) -> List[int]:
    """Up to k representative points from a set of values (endpoints kept,
    every point is a real member so exact shapes stay exact)."""
    values = sorted(set(values))
    if len(values) <= k or k < 2:
        return values[:max(k, 1)]
    idx = {round(i * (len(values) - 1) / (k - 1)) for i in range(k)}
    return [values[i] for i in sorted(idx)]


def _axis_points(lo: int, hi: int, k: int) -> List[int]:
    """generate()-style integer grid spanning [lo, hi]."""
    if hi <= lo or k < 2:
        return [lo]
    return sorted({lo + round(i * (hi - lo) / (k - 1)) for i in range(k)})


def _axes(traffic: TrafficWorkload) -> Tuple[List[int], List[int]]:
    trace = traffic.trace
    in_pts = _subsample([r.in_len for r in trace], traffic.seq_samples)
    kv_lo = min(r.in_len for r in trace)
    kv_hi = trace.max_total_len - 1
    kv_pts = _axis_points(kv_lo, kv_hi, traffic.kv_samples)
    return in_pts, kv_pts


def _graphs_and_axes(cfg: ModelConfig, plan: Plan, traffic: TrafficWorkload,
                     policy: PrecisionPolicy = DEFAULT,
                     fusion: FusionPolicy = SERIAL
                     ) -> Tuple[List[Graph], List[int], List[int]]:
    """(graphs, in_pts, kv_pts) — the graph list is laid out as
    [wave prefills at in_pts | refill prefills at in_pts | decodes at
    kv_pts], and returning the axes alongside keeps simulate()'s slicing
    structurally aligned with the build. Graphs are rewritten under
    `fusion`'s kernel-fusion rules before pricing."""
    if not len(traffic.trace):
        raise ValueError("traffic has an empty trace")
    in_pts, kv_pts = _axes(traffic)
    B = traffic.batch
    graphs = ([build_model(cfg, plan, B, S, kv_len=S, policy=policy)
               for S in in_pts]
              + [build_model(cfg, plan, 1, S, kv_len=S, policy=policy)
                 for S in in_pts]
              + [build_model(cfg, plan, B, seq=1, kv_len=kv, policy=policy)
                 for kv in kv_pts])
    return [fuse(g, fusion) for g in graphs], in_pts, kv_pts


def trace_graphs(cfg: ModelConfig, plan: Plan, traffic: TrafficWorkload,
                 policy: PrecisionPolicy = DEFAULT,
                 fusion: FusionPolicy = SERIAL) -> List[Graph]:
    """Every symbolic graph simulate() will price for this traffic — wave
    prefills (batch=slots) and refill prefills (batch=1) at the sampled
    prompt lengths, plus decode rounds at the sampled kv points. Exposed so
    study.Study can pre-collect the GEMM shapes of a whole serve-stage grid
    into one device-axis stacked mapper search."""
    return _graphs_and_axes(cfg, plan, traffic, policy, fusion)[0]


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclass
class RequestStats:
    """Per-request serving record (all times in seconds)."""
    index: int
    arrival: Seconds
    in_len: int
    out_len: int
    admitted: Seconds = 0.0     # end of the prefill wave that admitted it
    ttft: Seconds = 0.0         # arrival -> first output token
    e2e: Seconds = 0.0          # arrival -> last output token
    emitted: int = 0

    @property
    def tpot(self) -> Seconds:
        """Mean time per output token after the first."""
        return (self.e2e - self.ttft) / (self.out_len - 1) \
            if self.out_len > 1 else 0.0


@dataclass
class SimResult:
    """Request-level metrics of one simulated trace replay."""
    requests: List[RequestStats]
    slots: int
    policy: str
    makespan: Seconds           # clock at last completion (arrivals from t=0)
    tokens_out: int
    waves: int                  # admission waves priced
    rounds: int                 # decode rounds priced
    prefill_busy: Seconds
    decode_busy: Seconds
    idle: Seconds               # engine idle, waiting for arrivals
    occupancy: List[Tuple[float, int]]   # (time, live slots) after events
    slot_seconds: Seconds       # integral of live slots over time
    flops: Flops
    bytes: Bytes
    bound: Dict[str, float] = field(default_factory=dict)
    #: engine phase spans for the trace exporter (core/trace_export.py):
    #: ("wave" | "refill" | "decode" | "idle", start, end) in virtual seconds
    events: List[Tuple[str, Seconds, Seconds]] = field(default_factory=list)

    # -- percentiles -------------------------------------------------------
    def ttft(self, p: float = 50.0) -> Seconds:
        return float(np.percentile([r.ttft for r in self.requests], p))

    def tpot(self, p: float = 50.0) -> Seconds:
        vals = [r.tpot for r in self.requests if r.out_len > 1]
        return float(np.percentile(vals, p)) if vals else 0.0

    def e2e(self, p: float = 50.0) -> Seconds:
        return float(np.percentile([r.e2e for r in self.requests], p))

    # -- aggregates --------------------------------------------------------
    @property
    def goodput(self) -> PerSecond:
        """Output tokens per second over the whole replay."""
        return self.tokens_out / self.makespan if self.makespan > 0 else 0.0

    @property
    def request_rate(self) -> PerSecond:
        return len(self.requests) / self.makespan if self.makespan > 0 \
            else 0.0

    @property
    def mean_occupancy(self) -> Ratio:
        """Time-averaged fraction of slots holding a live request."""
        busy: Seconds = self.makespan - self.idle
        return self.slot_seconds / (busy * self.slots) if busy > 0 else 0.0

    @property
    def dominant(self) -> str:
        return max(self.bound, key=self.bound.get) if self.bound else "n/a"

    def goodput_slo(self, ttft_slo: Optional[Seconds] = None,
                    tpot_slo: Optional[Seconds] = None) -> PerSecond:
        """Goodput counting only requests meeting the given SLOs."""
        toks = sum(r.out_len for r in self.requests
                   if (ttft_slo is None or r.ttft <= ttft_slo)
                   and (tpot_slo is None or r.tpot <= tpot_slo))
        return toks / self.makespan if self.makespan > 0 else 0.0

    def summary(self) -> str:
        return (f"{self.policy}: {len(self.requests)} reqs "
                f"{self.tokens_out} toks in {self.makespan:.3f}s "
                f"goodput={self.goodput:.1f} tok/s "
                f"ttft p50/p99={self.ttft(50):.4f}/{self.ttft(99):.4f}s "
                f"tpot p50/p99={self.tpot(50):.5f}/{self.tpot(99):.5f}s "
                f"occ={self.mean_occupancy:.0%} waves={self.waves} "
                f"rounds={self.rounds}")


# ---------------------------------------------------------------------------
# the event loop
# ---------------------------------------------------------------------------

def simulate(system: System, cfg: ModelConfig, plan: Plan,
             traffic: TrafficWorkload,
             evaluator: Optional[Evaluator] = None,
             policy: PrecisionPolicy = DEFAULT,
             fusion: FusionPolicy = SERIAL,
             verify: Optional[str] = None) -> SimResult:
    """Replay `traffic.trace` through the engine's slot scheduler, pricing
    every wave/round analytically. See the module docstring for the model.

    `policy` prices every wave/round at a quantization point; `fusion`
    prices it at an execution-model point (fused kernels and/or
    overlap-scheduled rounds). The slot count stays `traffic.batch` — to let a quantized KV cache raise it,
    size the TrafficWorkload with
    `slots=inference_model.max_batch(..., policy=...)` (an int8-KV policy
    budgets roughly twice the fp16 slots at equal memory; the serve-stage
    Study memory gate checks that budget under the case's policy)."""
    if not isinstance(policy, PrecisionPolicy):
        raise TypeError(
            f"simulate()'s `policy` is a precision.PrecisionPolicy, got "
            f"{policy!r} — the scheduler policy string "
            f"('continuous'/'static') belongs on the TrafficWorkload")
    trace = traffic.trace
    n = len(trace)
    if n == 0:
        raise ValueError("traffic has an empty trace")
    if any(r.out_len < 1 for r in trace):
        raise ValueError("every trace request must generate >= 1 token")
    B = traffic.batch
    # static verification (ISSUE 7): plan + policy rules up front; the
    # sampled wave/round graphs are linted by the Evaluator below. Memory
    # capacity is the serve-stage Study gate's call, not re-proved here.
    mode = verify_mod.resolve_mode(verify)
    if mode != "off":
        diags = verify_mod.plan_diagnostics(
            system, cfg, plan, policy=policy, batch=B,
            max_len=traffic.total_len, check_memory=False)
        diags += verify_mod.policy_diagnostics(policy, system.device)
        verify_mod.apply_mode(diags, mode)
    ev = im._evaluator(system, evaluator, verify=mode)

    # ---- price all sampled graphs in ONE batched evaluation --------------
    graphs, in_pts, kv_pts = _graphs_and_axes(cfg, plan, traffic, policy,
                                              fusion)
    costs = ev.evaluate_many(graphs, overlap=fusion.overlap)
    k = len(in_pts)
    wave_tbl = _Interp(in_pts, costs[:k])            # batch=slots prefill
    one_tbl = _Interp(in_pts, costs[k:2 * k])        # batch=1 refill prefill
    dec_tbl = _Interp(kv_pts, costs[2 * k:])         # batch=slots decode
    dec_fill: Seconds = im.pp_fill(system, plan, B, cfg.d_model, policy)

    sched = SlotScheduler(B, policy=traffic.policy)
    recs = [RequestStats(i, r.arrival, r.in_len, r.out_len)
            for i, r in enumerate(trace)]

    t = 0.0
    i_next = 0                  # next not-yet-arrived trace index
    waiting: List[int] = []     # arrived, not yet admitted (record indices)
    done = 0
    tokens_out = waves = rounds = 0
    prefill_busy = decode_busy = idle = slot_seconds = 0.0
    flops = bytes_ = 0.0
    bound: Dict[str, float] = {}
    occupancy: List[Tuple[float, int]] = []
    events: List[Tuple[str, float, float]] = []

    def account(c: _RoundCost, fill: Seconds) -> Seconds:
        nonlocal flops, bytes_
        flops += c.flops
        bytes_ += c.bytes
        for key, v in c.bound.items():
            bound[key] = bound.get(key, 0.0) + v
        if fill > 0:
            bound["link"] = bound.get("link", 0.0) + fill
        return c.latency + fill

    while done < n:
        while i_next < n and trace.requests[i_next].arrival <= t:
            waiting.append(i_next)
            i_next += 1
        live = sched.live_slots()
        pairs = sched.plan_wave([recs[j] for j in waiting],
                                more_coming=i_next < n)
        if pairs:
            # ---- admission wave: price the prefill(s), then occupy -------
            wave = [r for _, r in pairs]
            if sched.idle:
                kind = "wave"
                S = max(r.in_len for r in wave)
                dt = account(wave_tbl.at(S),
                             im.pp_fill(system, plan, B * S, cfg.d_model,
                                        policy))
            else:
                kind = "refill"
                dt = 0.0
                for r in wave:
                    dt += account(one_tbl.at(r.in_len),
                                  im.pp_fill(system, plan, r.in_len,
                                             cfg.d_model, policy))
            slot_seconds += len(live) * dt
            events.append((kind, t, t + dt))
            t += dt
            prefill_busy += dt
            waves += 1
            admitted = set()
            for slot, rec in pairs:
                sched.admit(slot, rec, rec.out_len)
                rec.admitted = t
                admitted.add(rec.index)
            waiting = [j for j in waiting if j not in admitted]
            occupancy.append((t, len(sched.live_slots())))
        elif live:
            # ---- decode round: all slots advance, kv = max live context --
            kv = max(sched.slot_req[s].in_len + sched.slot_req[s].emitted
                     for s in live)
            dt = account(dec_tbl.at(kv), dec_fill)
            slot_seconds += len(live) * dt
            events.append(("decode", t, t + dt))
            t += dt
            decode_busy += dt
            rounds += 1
            for slot in live:
                rec = sched.slot_req[slot]
                rec.emitted += 1
                tokens_out += 1
                if rec.emitted == 1:
                    rec.ttft = t - rec.arrival
                if sched.step(slot):
                    rec.e2e = t - rec.arrival
                    done += 1
            occupancy.append((t, len(sched.live_slots())))
        else:
            # ---- nothing runnable: fast-forward to the next arrival ------
            if i_next >= n:
                raise RuntimeError(
                    "simulator deadlock: no live slots, no waiting "
                    "requests, no future arrivals")
            idle += trace.requests[i_next].arrival - t
            events.append(("idle", t, trace.requests[i_next].arrival))
            t = trace.requests[i_next].arrival

    return SimResult(requests=recs, slots=B, policy=traffic.policy,
                     makespan=t, tokens_out=tokens_out, waves=waves,
                     rounds=rounds, prefill_busy=prefill_busy,
                     decode_busy=decode_busy, idle=idle,
                     occupancy=occupancy, slot_seconds=slot_seconds,
                     flops=flops, bytes=bytes_, bound=bound, events=events)
