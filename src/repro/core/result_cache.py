"""Persistent content-hashed result cache (ISSUE 6).

Every mapper search and every Study case is a pure function of value-type
inputs (frozen dataclasses all the way down: Device, MatmulShape, ModelConfig,
Plan, Workload, PrecisionPolicy, FusionPolicy). That makes results durable by
construction: hash the canonical form of the inputs plus a model-version salt,
and the answer from a previous process is exactly the answer this process
would compute. This module is the storage layer both caches share:

  * `canonical()` turns any value-type input into a deterministic, JSON-safe
    structure (dataclasses carry their class name, floats round-trip exactly
    via repr, numpy scalars collapse to python numbers);
  * `content_key()` hashes that structure (sha256) together with a salt —
    `MODEL_VERSION` must be bumped whenever any analytical cost model changes
    meaning, which invalidates every prior on-disk entry at once;
  * `DiskCache` is a namespace directory of one-JSON-file-per-entry under a
    two-hex-character fanout. Writes are atomic (temp file + os.replace in
    the same directory); reads tolerate corruption (a torn/garbage file is
    deleted and treated as a miss); every IO error degrades to "cache off"
    rather than an exception, so a read-only or full disk never breaks an
    evaluation.

Storage root: $REPRO_CACHE_DIR, else ~/.cache/repro-hwe. The layer is on by
default; disable globally with REPRO_DISK_CACHE=0 or `configure(enabled=
False)` (cold-start benchmarking uses the `disabled()` context manager).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from contextlib import contextmanager
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Optional

from .obs import metrics

#: Bump on ANY semantic change to the analytical models (mapper cost model,
#: operator models, interconnect, precision, fusion/scheduling) — it salts
#: every content key, so old on-disk entries become unreachable instead of
#: silently stale.
MODEL_VERSION = "hwe-v7"

_ENV_DIR = "REPRO_CACHE_DIR"
_ENV_ENABLED = "REPRO_DISK_CACHE"

_FALSY = {"0", "false", "off", "no", ""}


def _env_enabled() -> bool:
    return os.environ.get(_ENV_ENABLED, "1").strip().lower() not in _FALSY


# module-level switches; None means "follow the environment"
_ENABLED_OVERRIDE: Optional[bool] = None
_ROOT_OVERRIDE: Optional[Path] = None


def cache_enabled() -> bool:
    """Is the persistent layer globally on?"""
    if _ENABLED_OVERRIDE is not None:
        return _ENABLED_OVERRIDE
    return _env_enabled()


def cache_root() -> Path:
    """Resolved storage root (not created until something is written)."""
    if _ROOT_OVERRIDE is not None:
        return _ROOT_OVERRIDE
    env = os.environ.get(_ENV_DIR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-hwe"


def configure(root: Optional[os.PathLike] = None,
              enabled: Optional[bool] = None) -> None:
    """Override storage root and/or the global on/off switch.

    Passing None leaves that setting untouched; `configure(root=...,
    enabled=...)` with explicit values wins over the REPRO_CACHE_DIR /
    REPRO_DISK_CACHE environment variables.
    """
    global _ROOT_OVERRIDE, _ENABLED_OVERRIDE
    if root is not None:
        _ROOT_OVERRIDE = Path(root)
    if enabled is not None:
        _ENABLED_OVERRIDE = bool(enabled)


@contextmanager
def disabled():
    """Temporarily force the persistent layer off (cold-start benchmarking)."""
    global _ENABLED_OVERRIDE
    prev = _ENABLED_OVERRIDE
    _ENABLED_OVERRIDE = False
    try:
        yield
    finally:
        _ENABLED_OVERRIDE = prev


@contextmanager
def overridden(root: Optional[os.PathLike] = None,
               enabled: Optional[bool] = None):
    """Temporarily override root and/or switch, restoring both on exit.

    Benchmarks use this to measure disk cold/warm behavior against a private
    temp directory without disturbing the user's real cache."""
    global _ROOT_OVERRIDE, _ENABLED_OVERRIDE
    prev = (_ROOT_OVERRIDE, _ENABLED_OVERRIDE)
    if root is not None:
        _ROOT_OVERRIDE = Path(root)
    if enabled is not None:
        _ENABLED_OVERRIDE = bool(enabled)
    try:
        yield
    finally:
        _ROOT_OVERRIDE, _ENABLED_OVERRIDE = prev


# ---------------------------------------------------------------------------
# canonical hashing
# ---------------------------------------------------------------------------

def canonical(obj: Any) -> Any:
    """Deterministic JSON-safe form of a value-type input.

    Dataclasses serialize as [classname, {field: canonical(value)}] so two
    different spec types with equal fields never collide; floats go through
    repr (exact round-trip); tuples/lists/dicts recurse. Raises TypeError on
    anything non-value-like (functions, arrays, open handles) — such inputs
    must not silently hash by id.
    """
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return [type(obj).__name__,
                {f.name: canonical(getattr(obj, f.name))
                 for f in dataclasses.fields(obj)}]
    if isinstance(obj, bool) or obj is None or isinstance(obj, (int, str)):
        return obj
    if isinstance(obj, float):
        # through float() first: np.float64 is a float subclass whose repr
        # is version-dependent ("np.float64(0.5)" under numpy 2)
        return repr(float(obj))
    if isinstance(obj, (tuple, list)):
        return [canonical(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): canonical(v) for k, v in sorted(obj.items())}
    # numpy scalars and other number-likes
    if hasattr(obj, "item") and not hasattr(obj, "__len__"):
        return canonical(obj.item())
    raise TypeError(f"cannot canonicalize {type(obj).__name__} for a "
                    f"content-hashed cache key: {obj!r}")


def content_key(*parts: Any, salt: str = MODEL_VERSION) -> str:
    """sha256 hex of the canonical form of `parts`, salted by the model
    version (stale-salt entries are simply unreachable keys)."""
    blob = json.dumps([salt, [canonical(p) for p in parts]],
                      sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


# ---------------------------------------------------------------------------
# the on-disk store
# ---------------------------------------------------------------------------

@dataclass
class DiskCacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    corrupt: int = 0        # unreadable entries dropped on read
    errors: int = 0         # IO failures silently degraded to miss/no-op

    def summary(self) -> str:
        return (f"disk_hits={self.hits} disk_misses={self.misses} "
                f"disk_puts={self.puts} corrupt={self.corrupt} "
                f"io_errors={self.errors}")


class DiskCache:
    """One namespace of the persistent store: content-key -> JSON document.

    Layout: <root>/<namespace>/<key[:2]>/<key>.json. All writes are atomic
    (same-directory temp + os.replace); all reads are corruption-tolerant.
    A DiskCache constructed while the global switch is off (or pointing at
    an unwritable root) behaves as an always-miss, swallow-writes cache.

    Concurrency contract (relied on by `Study.run(workers=N)`, ISSUE 10):
    keys are content hashes, so two processes can only ever race on a key
    by writing the SAME bytes; with each write staged in the destination
    directory and published by `os.replace`, readers see either a complete
    previous document or a complete identical one — never a torn file —
    and last-writer-wins is a no-op. No cross-process locking is needed.
    """

    def __init__(self, namespace: str, root: Optional[os.PathLike] = None,
                 enabled: Optional[bool] = None) -> None:
        self.namespace = namespace
        self._root = Path(root) if root is not None else None
        self._enabled = enabled
        self.stats = DiskCacheStats()

    def _bump(self, what: str) -> None:
        # local per-namespace stats stay the API; the process-wide registry
        # (core/obs.py) gets a mirrored monotone counter for reporting
        setattr(self.stats, what, getattr(self.stats, what) + 1)
        metrics().inc(f"cache.{self.namespace}.{what}")

    @property
    def enabled(self) -> bool:
        return cache_enabled() if self._enabled is None else self._enabled

    @property
    def directory(self) -> Path:
        root = self._root if self._root is not None else cache_root()
        return root / self.namespace

    def _path(self, key: str) -> Path:
        return self.directory / key[:2] / f"{key}.json"

    # ------------------------------------------------------------------
    def get(self, key: str) -> Optional[dict]:
        if not self.enabled:
            return None
        path = self._path(key)
        try:
            with open(path, "r") as f:
                doc = json.load(f)
        except FileNotFoundError:
            self._bump("misses")
            return None
        except (json.JSONDecodeError, UnicodeDecodeError, ValueError):
            # torn write or bit rot: drop the entry, miss
            self._bump("corrupt")
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        except OSError:
            self._bump("errors")
            return None
        if not isinstance(doc, dict):
            self._bump("corrupt")
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        self._bump("hits")
        return doc

    def put(self, key: str, doc: dict) -> None:
        if not self.enabled:
            return
        path = self._path(key)
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(doc, f, separators=(",", ":"))
                os.replace(tmp, path)       # atomic on POSIX
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            self._bump("puts")
        except OSError:
            self._bump("errors")          # read-only / full disk: degrade

    def clear(self) -> None:
        """Remove every entry of this namespace from disk."""
        try:
            shutil.rmtree(self.directory)
        except FileNotFoundError:
            pass
        except OSError:
            self._bump("errors")

    def __len__(self) -> int:
        try:
            return sum(1 for _ in self.directory.glob("??/*.json"))
        except OSError:
            return 0
