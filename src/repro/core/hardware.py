"""Hardware description template (LLMCompass paper, Sec. III-A, Fig. 3, Table I).

A *system* is devices + device-device interconnect.
A *device* is cores + global buffer + main memory.
A *core* is lanes + a shared local buffer.
A *lane* is an independent vector unit + systolic array + registers.

The template is deliberately agnostic between cache and scratchpad (the mapper
manages memory explicitly) and between HBM/DDR/CXL main memory (all are
bandwidth+capacity). TPUs are described with the same template following the
paper's own Table I convention for TPUv3.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from .units import (Bytes, BytesPerCycle, BytesPerSecond, FlopsPerCycle,
                    FlopsPerSecond, Hertz, Ratio, Seconds)

KB: Bytes = 1024
MB: Bytes = 1024 * KB
GB: Bytes = 1024 * MB


@dataclass(frozen=True)
class SystolicArray:
    rows: int
    cols: int
    # native PE datapath — prices die area (area.MAC_AREA) per dtype; the
    # timing model's narrow-datatype rate comes from the PrecisionPolicy
    # (precision.mac_scale), which is defined relative to this fp16 baseline
    dtype: str = "fp16"

    @property
    def macs(self) -> int:
        return self.rows * self.cols


@dataclass(frozen=True)
class VectorUnit:
    width: int                      # MACs (or ALU ops) per cycle per lane
    # fraction of peak usable for reductions / special functions (exp, rsqrt)
    special_ratio: Ratio = 1.0 / 4.0


@dataclass(frozen=True)
class Lane:
    vector_unit: VectorUnit
    systolic_array: SystolicArray
    register_file_bytes: Bytes = 256 * KB


@dataclass(frozen=True)
class Core:
    lanes: int
    lane: Lane
    local_buffer_bytes: Bytes       # shared among lanes (L1 / LDS / VMEM)
    # sustained local-buffer bandwidth in bytes/cycle (paper models buffers as
    # wide SRAM; per-core figure)
    local_buffer_bw_per_cycle: BytesPerCycle = 128


@dataclass(frozen=True)
class MainMemory:
    bandwidth_bytes: BytesPerSecond
    capacity_bytes: Bytes
    protocol: str = "HBM2e"


@dataclass(frozen=True)
class Device:
    name: str
    frequency_hz: Hertz
    core_count: int
    core: Core
    global_buffer_bytes: Bytes
    global_buffer_bw_per_cycle: BytesPerCycle  # bytes / clk (paper Table I)
    main_memory: Optional[MainMemory]
    # measured per-kernel launch + framework overhead (paper Sec. III-C:
    # "measured by running the operator with an input of size 1")
    kernel_launch_overhead_s: Seconds = 4.5e-6
    process_node_nm: int = 7

    # --- derived peak numbers -------------------------------------------------
    @property
    def total_lanes(self) -> int:
        return self.core_count * self.core.lanes

    @property
    def matmul_flops_per_cycle(self) -> FlopsPerCycle:
        """2 flops per MAC, all systolic arrays."""
        return 2 * self.total_lanes * self.core.lane.systolic_array.macs

    @property
    def vector_flops_per_cycle(self) -> FlopsPerCycle:
        return 2 * self.total_lanes * self.core.lane.vector_unit.width

    @property
    def peak_matmul_flops(self) -> FlopsPerSecond:
        return self.matmul_flops_per_cycle * self.frequency_hz

    @property
    def peak_vector_flops(self) -> FlopsPerSecond:
        return self.vector_flops_per_cycle * self.frequency_hz

    @property
    def memory_bandwidth(self) -> BytesPerSecond:
        """Bandwidth to the level that backs the global buffer.

        For GPU-style devices this is main-memory (HBM/DDR) bandwidth. For the
        paper's TPUv3 description the HBM *is* the global buffer, so its port
        bandwidth (bytes/clk x freq) is the figure.
        """
        if self.main_memory is not None:
            return self.main_memory.bandwidth_bytes
        return self.global_buffer_bw_per_cycle * self.frequency_hz

    @property
    def memory_capacity(self) -> Bytes:
        if self.main_memory is not None:
            return self.main_memory.capacity_bytes
        return float(self.global_buffer_bytes)

    @property
    def global_buffer_bandwidth(self) -> BytesPerSecond:
        return self.global_buffer_bw_per_cycle * self.frequency_hz


@dataclass(frozen=True)
class Link:
    """LogGP-style link (paper Sec. III-B2, Eq. 1-2)."""
    bandwidth_bytes: BytesPerSecond  # B
    latency_s: Seconds = 8.0e-6     # L
    overhead_s: Seconds = 1.0e-6    # O
    flit_bytes: Bytes = 16          # NVLink flit
    max_payload_bytes: Bytes = 256  # NVLink max payload


@dataclass(frozen=True)
class System:
    device: Device
    device_count: int
    link: Link
    topology: str = "ring"          # ring | fc (fully-connected) | torus2d

    def scaled(self, **kw) -> "System":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Presets (paper Table I, Table III, Table IV)
# ---------------------------------------------------------------------------

def make_core(lanes: int, vec_width: int, sa_rows: int,
              sa_cols: Optional[int] = None, local_kb: int = 192,
              register_file_kb: int = 256,
              local_buffer_bw_per_cycle: int = 128) -> Core:
    """Public constructor for custom core configurations (design what-ifs).

    Builds a Core of `lanes` lanes, each with a `vec_width`-wide vector unit
    and an `sa_rows` x `sa_cols` systolic array (square when sa_cols is
    omitted), sharing `local_kb` KiB of local buffer. Use with
    `dataclasses.replace(device, core=make_core(...))` to sweep compute
    organizations the way Sec. V does.
    """
    return Core(
        lanes=lanes,
        lane=Lane(VectorUnit(vec_width),
                  SystolicArray(sa_rows, sa_cols if sa_cols else sa_rows),
                  register_file_bytes=register_file_kb * KB),
        local_buffer_bytes=local_kb * KB,
        local_buffer_bw_per_cycle=local_buffer_bw_per_cycle,
    )


def _gpu_core(lanes: int, vec_width: int, sa: int, local_kb: int) -> Core:
    return make_core(lanes, vec_width, sa, local_kb=local_kb)


def with_mac_dtype(device: Device, dtype: str) -> Device:
    """Variant of `device` whose systolic PEs are built natively for `dtype`
    (same array geometry; smaller multipliers -> smaller die, area.MAC_AREA).
    Pair with the matching PrecisionPolicy when evaluating performance — the
    timing model does not stop you from running fp16 math on an int8 array.
    """
    lane = device.core.lane
    sa = replace(lane.systolic_array, dtype=dtype)
    return replace(
        device,
        name=f"{device.name}-{dtype}mac",
        core=replace(device.core, lane=replace(lane, systolic_array=sa)))


def nvidia_a100() -> Device:
    """NVIDIA A100 SXM4 80GB (Table I). 108 binned SMs."""
    return Device(
        name="nvidia-a100",
        frequency_hz=1410e6,
        core_count=108,
        core=_gpu_core(lanes=4, vec_width=32, sa=16, local_kb=192),
        global_buffer_bytes=40 * MB,
        global_buffer_bw_per_cycle=5120,
        main_memory=MainMemory(2.0e12, 80 * GB, "HBM2e"),
    )


def nvidia_ga100() -> Device:
    """Full GA100 die: 128 SMs (Table IV baseline)."""
    return replace(nvidia_a100(), name="nvidia-ga100", core_count=128,
                   global_buffer_bytes=48 * MB)


def amd_mi210() -> Device:
    return Device(
        name="amd-mi210",
        frequency_hz=1700e6,
        core_count=104,
        core=_gpu_core(lanes=4, vec_width=16, sa=16, local_kb=80),
        global_buffer_bytes=8 * MB,
        global_buffer_bw_per_cycle=4096,
        main_memory=MainMemory(1.6e12, 64 * GB, "HBM2e"),
    )


def google_tpu_v3() -> Device:
    """One TPUv3 chip, 2 cores (Table I convention: HBM backs global buffer)."""
    return Device(
        name="google-tpu-v3",
        frequency_hz=940e6,
        core_count=2,
        core=Core(
            lanes=1,
            lane=Lane(VectorUnit(4 * 128), SystolicArray(128, 128)),
            local_buffer_bytes=8192 * KB,
        ),
        global_buffer_bytes=16384 * MB,
        global_buffer_bw_per_cycle=490,
        main_memory=None,
        kernel_launch_overhead_s=20e-6,   # XLA dispatch, paper Sec. III-C
    )


def google_tpu_v5e() -> Device:
    """TPU v5e — our deployment target (197 TFLOP/s bf16, 819 GB/s HBM).

    One core per chip; 128x128 MXUs + 8x128 VPU; VMEM is the local buffer.
    197e12 / (2 MACs) / freq(940MHz v5e ~ 1.67GHz) -> 4 MXUs of 128x128 at
    ~1.74 GHz gives 2*4*16384*1.74e9 = 228 TF; clocking at 1.5GHz gives 196.6.
    """
    return Device(
        name="google-tpu-v5e",
        frequency_hz=1.5e9,
        core_count=1,
        core=Core(
            lanes=4,  # 4 MXUs
            lane=Lane(VectorUnit(8 * 128), SystolicArray(128, 128)),
            local_buffer_bytes=128 * MB,
        ),
        global_buffer_bytes=128 * MB,
        global_buffer_bw_per_cycle=546,   # 819 GB/s / 1.5 GHz
        main_memory=MainMemory(819e9, 16 * GB, "HBM2e"),
        kernel_launch_overhead_s=10e-6,
    )


# --- Table III compute-system designs A-E ----------------------------------

def compute_design(which: str) -> Device:
    spec = {
        #        cores lanes vec   sa   local_kb
        "A": (128, 4, 8, 8, 192),
        "B": (128, 4, 32, 16, 192),
        "C": (128, 1, 128, 32, 192),
        "D": (32, 1, 512, 64, 768),
        "E": (8, 1, 2048, 128, 3072),
    }[which]
    cores, lanes, vec, sa, local_kb = spec
    return replace(
        nvidia_ga100(),
        name=f"design-{which}",
        core_count=cores,
        core=_gpu_core(lanes=lanes, vec_width=vec, sa=sa, local_kb=local_kb),
    )


# --- Table IV proposed designs ----------------------------------------------

def latency_oriented() -> Device:
    """Half the compute + SRAM of GA100, same HBM memory system."""
    return replace(
        nvidia_ga100(),
        name="latency-oriented",
        core_count=64,
        global_buffer_bytes=24 * MB,
        global_buffer_bw_per_cycle=2560,
    )


def throughput_oriented() -> Device:
    """4x systolic/local-buffer per core, half the cores, 512GB DDR @ 1TB/s."""
    return replace(
        nvidia_ga100(),
        name="throughput-oriented",
        core_count=64,
        core=_gpu_core(lanes=4, vec_width=32, sa=32, local_kb=768),
        global_buffer_bytes=48 * MB,
        main_memory=MainMemory(1.0e12, 512 * GB, "PCIe 5.0/CXL DDR5"),
    )


# --- Systems -----------------------------------------------------------------

def dgx_a100(n: int = 4) -> System:
    return System(device=nvidia_a100(), device_count=n,
                  link=Link(bandwidth_bytes=600e9), topology="fc")


def tpu_v3_node(n_chips: int = 4) -> System:
    return System(device=google_tpu_v3(), device_count=n_chips,
                  link=Link(bandwidth_bytes=162.5e9, flit_bytes=16,
                            max_payload_bytes=256),
                  topology="torus2d")


def tpu_v5e_pod(n: int = 256) -> System:
    """16x16 v5e pod slice; ~50 GB/s per ICI link per direction."""
    return System(device=google_tpu_v5e(), device_count=n,
                  link=Link(bandwidth_bytes=50e9, latency_s=1e-6,
                            flit_bytes=16, max_payload_bytes=256),
                  topology="torus2d")


def make_system(device: Device, n: int, link_gbps: float = 600.0,
                topology: str = "fc") -> System:
    return System(device=device, device_count=n,
                  link=Link(bandwidth_bytes=link_gbps * 1e9), topology=topology)


PRESETS = {
    "a100": nvidia_a100,
    "ga100": nvidia_ga100,
    "mi210": amd_mi210,
    "tpuv3": google_tpu_v3,
    "tpuv5e": google_tpu_v5e,
    "latency-oriented": latency_oriented,
    "throughput-oriented": throughput_oriented,
    **{f"design-{w}": (lambda w=w: compute_design(w)) for w in "ABCDE"},
}


def get_device(name: str) -> Device:
    try:
        return PRESETS[name]()
    except KeyError:
        raise KeyError(f"unknown device preset '{name}'; have {sorted(PRESETS)}")
