"""Slot-level batching policy shared by the real serving engine and the
analytical simulator (ISSUE 3).

`serving/engine.py` (real kernels on a jax mesh) and `core/simulator.py`
(analytical costs from the Evaluator stack) must make the SAME scheduling
decisions: which requests are admitted into which slots, when a wave may
form, and when a slot is released. Extracting the policy here means a
simulated goodput claim is about the exact admission logic the engine runs,
not a re-implementation of it.

The scheduler is deliberately dumb and pure-Python: it owns `n_slots` slots,
each either free or holding an opaque request handle with a remaining token
budget. Policies:

  continuous — a finished slot is refilled as soon as a request is waiting
               (vLLM-style continuous batching; the engine's seed behavior);
  static     — a new wave is admitted only when every slot has drained, and
               (if more arrivals are expected) only once a full batch of
               requests is waiting — classic static batching, the baseline
               continuous batching is measured against.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

POLICIES = ("continuous", "static")


class SlotScheduler:
    """Continuous/static batching over a fixed set of slots."""

    def __init__(self, n_slots: int, policy: str = "continuous") -> None:
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; have {POLICIES}")
        self.n_slots = n_slots
        self.policy = policy
        self.slot_req: List[Optional[Any]] = [None] * n_slots
        self.slot_budget: List[int] = [0] * n_slots

    # -- state queries -----------------------------------------------------
    @property
    def idle(self) -> bool:
        return all(r is None for r in self.slot_req)

    def live_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- admission ---------------------------------------------------------
    def plan_wave(self, waiting: Sequence[Any],
                  more_coming: bool = False) -> List[Tuple[int, Any]]:
        """Pair waiting requests with the slots they may occupy NOW.

        `more_coming` tells a static-batching scheduler whether later
        arrivals could still top up a partial batch (it then holds the wave
        until the batch fills); continuous batching admits greedily.
        """
        if not waiting:
            return []
        if self.policy == "static":
            if not self.idle:
                return []
            if more_coming and len(waiting) < self.n_slots:
                return []
        free = self.free_slots()
        return list(zip(free, waiting))

    def admit(self, slot: int, req: Any, budget: int) -> bool:
        """Occupy `slot` with `req` for `budget` further tokens. A request
        whose budget is already exhausted (e.g. it finished at prefill)
        leaves the slot free; returns whether the slot was occupied."""
        if self.slot_req[slot] is not None:
            raise ValueError(f"slot {slot} is occupied")
        if budget <= 0:
            return False
        self.slot_req[slot] = req
        self.slot_budget[slot] = budget
        return True

    # -- per-token bookkeeping --------------------------------------------
    def step(self, slot: int, hit_eos: bool = False) -> bool:
        """Account one emitted token for `slot`; release it when its budget
        is spent or EOS was sampled. Returns whether the slot finished."""
        if self.slot_req[slot] is None:
            raise ValueError(f"slot {slot} is free")
        self.slot_budget[slot] -= 1
        if self.slot_budget[slot] <= 0 or hit_eos:
            self.slot_req[slot] = None
            self.slot_budget[slot] = 0
            return True
        return False

    def release(self, slot: int) -> None:
        self.slot_req[slot] = None
        self.slot_budget[slot] = 0
