"""Area model (paper Sec. III-D, Table II).

Bottom-up: lane = vector FPUs + systolic MACs + register file + per-lane
overhead; core = lanes + local-buffer SRAM + per-core overhead; device =
cores + global-buffer SRAM + memory PHY/controller + interconnect PHY.

Constants: Table II gives the 7nm areas for the FPU, ALU, per-lane overhead,
per-core overhead and HBM2e control/PHY. SRAM (CACTI scaled to 7nm) and
register-file (EMPIRE) curves are fitted so the model reproduces the paper's
own die-area validation (GA100 826 mm^2 within ~10%, Fig. 6a) and its
Table IV design areas (478 / 826 / 787 mm^2) — the fit is documented here
rather than hidden in a fudge factor.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .hardware import Device, MB
from .precision import DTYPES
from .units import Mm2

UM2: Mm2 = 1e-6   # mm^2 per um^2

# --- Table II constants (7nm) ----------------------------------------------
AREA_FP64_FPU: Mm2 = 7116 * UM2
AREA_FP32_FPU: Mm2 = AREA_FP64_FPU / 2     # half-width datapath

# Systolic PE area, per native datapath dtype (ISSUE 4). The fp16 MAC is
# THE calibrated constant: together with the fabric constant below it makes
# the model reproduce the paper's own Table IV triple exactly-ish — GA100
# 826 / latency 478 / throughput 787 mm^2 form a linear system in
# (MAC area, fabric, IO); solving it gives 1150 um^2/MAC, 1.45 mm^2/core
# fabric, 130 mm^2 mem+IO. (This note is the single home of that fit;
# lane_area and the device breakdown both read the table through
# _lane_parts, so the constant is applied in exactly one place.)
# Narrow datapaths scale by the registry's per-dtype multiplier-area ratios
# (precision.DTYPES.mac_area_rel: ~quadratic in operand width, fixed-point
# cheaper than floating) — derived, so a new registry dtype prices here
# automatically.
MAC_UM2_FP16 = 1150
MAC_AREA = {name: d.mac_area_rel * MAC_UM2_FP16 * UM2
            for name, d in DTYPES.items()}
AREA_FP16_MAC = MAC_AREA["fp16"]           # back-compat alias
AREA_INT32_ALU: Mm2 = 1838 * UM2
AREA_LANE_OVERHEAD: Mm2 = 10344 * UM2
AREA_CORE_OVERHEAD: Mm2 = 460000 * UM2     # Table II per-core overhead
AREA_CORE_FABRIC: Mm2 = 1450000 * UM2      # calibrated crossbar/uncore share
AREA_HBM2E_CTRL_1024: Mm2 = 5740000 * UM2  # per 1024-bit channel (scales w/ node)
AREA_HBM2E_PHY_1024: Mm2 = 10450000 * UM2  # per 1024-bit channel (analog, fixed)

# --- fitted memory-macro curves (documented calibration) -------------------
SRAM_LOCAL_MM2_PER_MB: Mm2 = 2.0   # high-port L1/LDS SRAM @ 7nm (CACTI-fit)
SRAM_GLOBAL_MM2_PER_MB: Mm2 = 1.2  # dense L2-class SRAM @ 7nm
REGFILE_MM2_PER_MB: Mm2 = 4.0      # multi-ported RF (EMPIRE-fit)
HBM_GBPS_PER_STACK = 400.0     # HBM2e per-1024b-stack bandwidth (~3.2 Gbps/pin)
DDR_PHY_MM2_PER_CH: Mm2 = 0.18     # PCIe5/DDR channel PHY+ctrl (perimeter IO)
DDR_GBPS_PER_CH = 4.0          # ~PCIe 5.0 x1 effective
LINK_PHY_MM2_PER_GBPS: Mm2 = 49.0 / 600.0  # NVLink SerDes (Table IV fit)


@dataclass
class AreaReport:
    lane_mm2: Mm2
    core_mm2: Mm2
    cores_total_mm2: Mm2
    global_buffer_mm2: Mm2
    memory_io_mm2: Mm2
    link_phy_mm2: Mm2
    breakdown: dict = field(default_factory=dict)

    @property
    def total_mm2(self) -> Mm2:
        return (self.cores_total_mm2 + self.global_buffer_mm2
                + self.memory_io_mm2 + self.link_phy_mm2)


def _lane_parts(device: Device) -> dict:
    """Per-lane area components — the one place the unit constants are
    applied (lane_area and the device breakdown both sum these)."""
    lane = device.core.lane
    sa = lane.systolic_array
    try:
        mac = MAC_AREA[sa.dtype]
    except KeyError:
        raise KeyError(f"no MAC area entry for systolic dtype {sa.dtype!r}; "
                       f"have {sorted(MAC_AREA)}")
    return {
        "vector_units": lane.vector_unit.width * AREA_FP32_FPU,
        "systolic_arrays": sa.macs * mac,
        "register_files": (lane.register_file_bytes / MB)
        / device.core.lanes * REGFILE_MM2_PER_MB,
        "lane_overhead": AREA_LANE_OVERHEAD,
    }


def lane_area(device: Device) -> Mm2:
    return sum(_lane_parts(device).values())


def core_area(device: Device) -> Mm2:
    lanes = device.core.lanes * lane_area(device)
    local = (device.core.local_buffer_bytes / MB) * SRAM_LOCAL_MM2_PER_MB
    return lanes + local + AREA_CORE_OVERHEAD + AREA_CORE_FABRIC


def device_area(device: Device,
                link_bandwidth_gbps: float = 600.0) -> AreaReport:
    la: Mm2 = lane_area(device)
    ca: Mm2 = core_area(device)
    cores: Mm2 = device.core_count * ca
    gb: Mm2 = (device.global_buffer_bytes / MB) * SRAM_GLOBAL_MM2_PER_MB

    mem_io: Mm2 = 0.0
    if device.main_memory is not None:
        bw_gbps = device.main_memory.bandwidth_bytes / 1e9
        if "HBM" in device.main_memory.protocol.upper():
            stacks = max(1, round(bw_gbps / HBM_GBPS_PER_STACK))
            mem_io = stacks * (AREA_HBM2E_CTRL_1024 + AREA_HBM2E_PHY_1024)
        else:
            channels = max(1, round(bw_gbps / DDR_GBPS_PER_CH))
            mem_io = channels * DDR_PHY_MM2_PER_CH

    link = link_bandwidth_gbps * LINK_PHY_MM2_PER_GBPS

    rep = AreaReport(
        lane_mm2=la, core_mm2=ca, cores_total_mm2=cores,
        global_buffer_mm2=gb, memory_io_mm2=mem_io, link_phy_mm2=link)
    parts = _lane_parts(device)
    rep.breakdown = {
        **{k: device.total_lanes * v for k, v in parts.items()},
        "local_buffers": device.core_count
        * (device.core.local_buffer_bytes / MB) * SRAM_LOCAL_MM2_PER_MB,
        "core_overhead": device.core_count
        * (AREA_CORE_OVERHEAD + AREA_CORE_FABRIC),
        "global_buffer": gb,
        "memory_io": mem_io,
        "link_phy": link,
    }
    return rep
