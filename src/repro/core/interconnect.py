"""Communication-primitive models (paper Sec. III-B2).

Link model (AHEAD / LogGP):   T = L + O + n_hat / B
with framing                  n_hat = ceil(n / MaxPayload) * Flit + n

On top: ring all-reduce (bandwidth-optimal, the paper's choice), plus
all-gather / reduce-scatter / all-to-all / p2p — the paper models only
all-reduce and p2p because Megatron-style TP needs nothing else; we add the
rest because sequence-parallel TP (RS+AG) and MoE expert-parallel (A2A)
plans need them. All reuse the same link equation.
"""
from __future__ import annotations

import math
from typing import Optional

from .hardware import Link, System
from .operators import OpResult
from .units import Bytes, BytesPerElement, Elements, Flops, \
    FlopsPerElement, Ratio, Seconds

#: one reduction add per payload element in a ring reduce step. The
#: pre-unitcheck code divided bytes by an element width and called the
#: quotient "flops" directly — dimensionally Elements, not Flops; this
#: constant carries the (value-preserving) elements -> flops conversion.
REDUCE_FLOPS_PER_ELEMENT: FlopsPerElement = 1.0


def link_time(link: Link, n_bytes: Bytes) -> Seconds:
    """Eq. 1-2: time to move n bytes across one link."""
    if n_bytes <= 0:
        return 0.0
    n_hat: Bytes = (math.ceil(n_bytes / link.max_payload_bytes)
                    * link.flit_bytes + n_bytes)
    return link.latency_s + link.overhead_s + n_hat / link.bandwidth_bytes


def p2p(system: System, n_bytes: Bytes, name: str = "p2p") -> OpResult:
    t: Seconds = link_time(system.link, n_bytes)
    return OpResult(name, t, 0.0, 0.0, "link")


def all_reduce(system: System, n_bytes: Bytes,
               n_devices: Optional[int] = None,
               name: str = "all_reduce",
               bytes_elt: BytesPerElement = 2.0) -> OpResult:
    """Ring all-reduce: 2(n-1) steps of n_bytes/n chunks (reduce-scatter then
    all-gather phase). Reduction adds vector work, usually negligible —
    priced at the collective's actual element width (`bytes_elt`): each of
    the (n-1) reduce-scatter steps adds chunk/bytes_elt elements, so an fp8
    payload does twice the adds per byte of an fp16 one."""
    n: Ratio = n_devices or system.device_count
    if n <= 1:
        return OpResult(name, 0.0, 0.0, 0.0, "link")
    chunk: Bytes = n_bytes / n
    t: Seconds = 2 * (n - 1) * link_time(system.link, chunk)
    red_elems: Elements = (n - 1) * chunk / bytes_elt
    red_flops: Flops = red_elems * REDUCE_FLOPS_PER_ELEMENT
    t += red_flops / system.device.peak_vector_flops
    return OpResult(name, t, red_flops, 2 * (n - 1) * chunk, "link")


def reduce_scatter(system: System, n_bytes: Bytes,
                   n_devices: Optional[int] = None,
                   name: str = "reduce_scatter",
                   bytes_elt: BytesPerElement = 2.0) -> OpResult:
    """Ring reduce-scatter: (n-1) steps, each reducing a chunk — the same
    per-element adds as all_reduce's first phase, priced at `bytes_elt` so
    SP (RS+AG) and AR plans compete on equal reduction accounting."""
    n: Ratio = n_devices or system.device_count
    if n <= 1:
        return OpResult(name, 0.0, 0.0, 0.0, "link")
    chunk: Bytes = n_bytes / n
    t: Seconds = (n - 1) * link_time(system.link, chunk)
    red_elems: Elements = (n - 1) * chunk / bytes_elt
    red_flops: Flops = red_elems * REDUCE_FLOPS_PER_ELEMENT
    t += red_flops / system.device.peak_vector_flops
    return OpResult(name, t, red_flops, (n - 1) * chunk, "link")


def all_gather(system: System, n_bytes: Bytes,
               n_devices: Optional[int] = None,
               name: str = "all_gather") -> OpResult:
    """n_bytes = full gathered size."""
    n: Ratio = n_devices or system.device_count
    if n <= 1:
        return OpResult(name, 0.0, 0.0, 0.0, "link")
    chunk: Bytes = n_bytes / n
    t: Seconds = (n - 1) * link_time(system.link, chunk)
    return OpResult(name, t, 0.0, (n - 1) * chunk, "link")


def all_to_all(system: System, n_bytes: Bytes,
               n_devices: Optional[int] = None,
               name: str = "all_to_all") -> OpResult:
    """Each device exchanges n_bytes/n with every peer. On a ring this is
    (n-1) steps with average hop distance n/4 worth of occupancy; on
    fully-connected, one step of the largest message per link."""
    n: Ratio = n_devices or system.device_count
    if n <= 1:
        return OpResult(name, 0.0, 0.0, 0.0, "link")
    per_pair: Bytes = n_bytes / n
    if system.topology == "fc":
        # dedicated pairwise links: serialize (n-1) sends on the NIC port
        t: Seconds = link_time(system.link, per_pair) \
            + (n - 2) * per_pair / system.link.bandwidth_bytes
    else:
        # ring/torus: bisection-limited; total relayed bytes per link ~ n/4 x
        t = link_time(system.link, per_pair * n / 4) * 2
    return OpResult(name, t, 0.0, per_pair * (n - 1), "link")
