"""Chrome/Perfetto ``trace_event`` export for Schedules and simulator
replays (ISSUE 9 tentpole, DESIGN.md §13).

The timestamps here are **virtual**: they come from the analytical model
(`schedule.Schedule` slot times, `simulator.SimResult` event times), never
from a wall clock, so exporting the same object twice — or the same
workload across the numpy and jax mapper backends — yields byte-identical
JSON that can be diffed in CI. `_ts` quantizes modeled seconds to
microseconds at picosecond resolution, which is the Chrome trace unit and
also collapses any 1-ulp float differences between vectorized backends.

Schedule traces use one process with one thread lane per resource
(compute / vector / link). Every op becomes a matched B/E pair on its
lane; because `schedule_graph` hands each resource's slots out from a
single `free[r]` cursor, same-lane slots are disjoint and emitted in
start order — the validator below checks exactly that. Pipelined
collectives whose consumer-visible `end` exceeds `start + duration` keep
their occupancy-sized B/E pair and get an extra instant marker at the
visible end, so `total_span_us(events) == _ts(makespan)` holds bit-for-bit
even when the last-finishing op is an overlapped collective.

Simulator traces use two processes: an engine process (wave / refill /
decode / idle spans plus a ``live_slots`` counter track) and a requests
process with one lane per request (queued span, generate span, TTFT
instant carrying the TPOT in its args).

All functions on the export path are covered by the purity lint
(tests/test_purity_lint.py): no clocks, no entropy, no env reads, no
bare dict-order iteration.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from .ir import FusedMatmulSpec, Graph
from .schedule import RESOURCES, Schedule
from .simulator import SimResult

__all__ = [
    "schedule_trace_events", "simulation_trace_events", "to_perfetto_json",
    "write_trace", "validate_trace_events", "total_span_us",
]

Event = Dict[str, Any]


def _ts(seconds: float) -> float:
    """Modeled seconds -> trace microseconds, quantized to picoseconds.

    round() is monotone, so max(_ts(end_i)) == _ts(makespan) exactly, and
    the ps quantum erases sub-ulp latency differences between mapper
    backends without losing any physically meaningful resolution."""
    return round(seconds * 1e6, 6)


# ---------------------------------------------------------------------------
# Schedule -> trace events
# ---------------------------------------------------------------------------

def schedule_trace_events(sch: Schedule, graph: Optional[Graph] = None,
                          pid: int = 0,
                          process_name: str = "schedule") -> List[Event]:
    """Per-resource timeline of one overlap Schedule.

    When the originating `graph` is passed, each span's args carry the op
    kind plus fusion facts (stream_out, elided bytes) so fused seams are
    inspectable in the Perfetto UI."""
    used = []
    for s in sch.slots:
        if s.resource not in used:
            used.append(s.resource)
    lanes = [r for r in RESOURCES if r in used] \
        + sorted(r for r in used if r not in RESOURCES)
    tid_of = {r: i for i, r in enumerate(lanes)}
    crit = frozenset(sch.critical_path())

    events: List[Event] = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0, "ts": 0,
         "args": {"name": process_name,
                  "makespan_us": _ts(sch.makespan),
                  "serial_us": _ts(sch.serial)}},
    ]
    for r in lanes:
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid_of[r], "ts": 0, "args": {"name": r}})

    for i, s in enumerate(sch.slots):
        tid = tid_of[s.resource]
        args: Dict[str, Any] = {"critical": i in crit,
                                "duration_us": _ts(s.duration),
                                "resource": s.resource}
        if graph is not None:
            spec = graph.nodes[i].spec
            args["kind"] = type(spec).__name__
            args["repeat"] = graph.nodes[i].repeat
            if isinstance(spec, FusedMatmulSpec):
                args["fused"] = len(spec.epilogue)
                args["stream_out"] = spec.stream_out
                args["elided_bytes"] = spec.elided
        pipelined = s.end > s.start + s.duration
        if pipelined:
            args["pipelined"] = True
            args["end_us"] = _ts(s.end)
        events.append({"name": s.name, "ph": "B", "pid": pid, "tid": tid,
                       "ts": _ts(s.start), "args": args})
        events.append({"name": s.name, "ph": "E", "pid": pid, "tid": tid,
                       "ts": _ts(s.start + s.duration)})
        if pipelined:
            # consumer-visible completion of an overlapped collective: the
            # link lane is already free, so mark it rather than extend B/E
            events.append({"name": f"{s.name}:done", "ph": "i", "pid": pid,
                           "tid": tid, "ts": _ts(s.end), "s": "t"})
    return events


# ---------------------------------------------------------------------------
# SimResult -> trace events
# ---------------------------------------------------------------------------

def simulation_trace_events(sim: SimResult, pid: int = 0) -> List[Event]:
    """Serving-replay timeline: engine phase spans + live-slot counter in
    one process, per-request lifecycle lanes in a second process."""
    events: List[Event] = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0, "ts": 0,
         "args": {"name": f"engine[{sim.policy}]",
                  "makespan_us": _ts(sim.makespan)}},
        {"name": "thread_name", "ph": "M", "pid": pid, "tid": 0, "ts": 0,
         "args": {"name": "engine"}},
        {"name": "process_name", "ph": "M", "pid": pid + 1, "tid": 0,
         "ts": 0, "args": {"name": "requests"}},
    ]
    for kind, t0, t1 in sim.events:
        events.append({"name": kind, "ph": "B", "pid": pid, "tid": 0,
                       "ts": _ts(t0)})
        events.append({"name": kind, "ph": "E", "pid": pid, "tid": 0,
                       "ts": _ts(t1)})
    for t, occ in sim.occupancy:
        events.append({"name": "live_slots", "ph": "C", "pid": pid,
                       "tid": 0, "ts": _ts(t), "args": {"slots": occ}})

    for i, r in enumerate(sim.requests):
        tid = i + 1
        events.append({"name": "thread_name", "ph": "M", "pid": pid + 1,
                       "tid": tid, "ts": 0,
                       "args": {"name": f"req{r.index}"}})
        events.append({"name": "queued", "ph": "B", "pid": pid + 1,
                       "tid": tid, "ts": _ts(r.arrival),
                       "args": {"in_len": r.in_len, "out_len": r.out_len}})
        events.append({"name": "queued", "ph": "E", "pid": pid + 1,
                       "tid": tid, "ts": _ts(r.admitted)})
        events.append({"name": "generate", "ph": "B", "pid": pid + 1,
                       "tid": tid, "ts": _ts(r.admitted),
                       "args": {"emitted": r.emitted}})
        events.append({"name": "first_token", "ph": "i", "pid": pid + 1,
                       "tid": tid, "ts": _ts(r.arrival + r.ttft), "s": "t",
                       "args": {"ttft_us": _ts(r.ttft),
                                "tpot_us": _ts(r.tpot)}})
        events.append({"name": "generate", "ph": "E", "pid": pid + 1,
                       "tid": tid, "ts": _ts(r.arrival + r.e2e)})
    return events


# ---------------------------------------------------------------------------
# serialization + validation
# ---------------------------------------------------------------------------

def to_perfetto_json(events: List[Event]) -> str:
    """Canonical (sorted-keys, no-whitespace) trace JSON — identical event
    lists serialize to identical bytes."""
    return json.dumps({"traceEvents": events, "displayTimeUnit": "ms"},
                      sort_keys=True, separators=(",", ":"))


def write_trace(path: str, events: List[Event]) -> str:
    text = to_perfetto_json(events)
    with open(path, "w") as f:
        f.write(text)
        f.write("\n")
    return text


def validate_trace_events(events: List[Event]) -> List[str]:
    """Chrome trace_event schema checks: required keys, known phases,
    non-negative timestamps, and per-(pid, tid) lane discipline — matched
    same-name B/E pairs with non-decreasing timestamps."""
    errors: List[str] = []
    stacks: Dict[Tuple[int, int], List[Tuple[str, float]]] = {}
    last_ts: Dict[Tuple[int, int], float] = {}
    for i, e in enumerate(events):
        missing = [k for k in ("name", "ph", "pid", "tid", "ts")
                   if k not in e]
        if missing:
            errors.append(f"event {i}: missing keys {missing}")
            continue
        ph = e["ph"]
        if ph not in ("B", "E", "M", "i", "C"):
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        ts = e["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == "M":
            continue
        lane = (e["pid"], e["tid"])
        if ph in ("B", "E"):
            if ts < last_ts.get(lane, 0.0):
                errors.append(f"event {i}: ts {ts} goes backwards on lane "
                              f"{lane}")
            last_ts[lane] = ts
            stack = stacks.setdefault(lane, [])
            if ph == "B":
                stack.append((e["name"], ts))
            else:
                if not stack:
                    errors.append(f"event {i}: E without B on lane {lane}")
                else:
                    bname, bts = stack.pop()
                    if bname != e["name"]:
                        errors.append(f"event {i}: E {e['name']!r} closes "
                                      f"B {bname!r} on lane {lane}")
                    if ts < bts:
                        errors.append(f"event {i}: E before its B on lane "
                                      f"{lane}")
    for lane, stack in sorted(stacks.items()):
        if stack:
            errors.append(f"lane {lane}: {len(stack)} unclosed B events")
    return errors


def total_span_us(events: List[Event]) -> float:
    """Last virtual timestamp in the trace (metadata excluded). For a
    Schedule export this equals `_ts(makespan)` bit-for-bit."""
    out = 0.0
    for e in events:
        if e.get("ph") != "M" and e.get("ts", 0) > out:
            out = e["ts"]
    return out
