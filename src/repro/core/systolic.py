"""Systolic-array timing model (paper Sec. III-B1, "from local buffer to lanes").

The paper drives SCALE-Sim [56,57] per (tile, array) shape and caches results
in a look-up table. We implement the closed-form cycle count that SCALE-Sim
produces for dense GEMM in output-stationary dataflow (its default for matmul
tiles) and cache it identically. The closed form is exact for dense tiles —
SCALE-Sim itself derives cycles = fill + stream + drain for each pass:

    per-pass cycles (OS dataflow, Sr x Sc array, reduction depth k):
        2 * Sr + Sc + k - 2
    passes = ceil(m / Sr) * ceil(n / Sc)

The last partial pass uses the partial fill/drain of the occupied rows/cols,
which matters for narrow decode-time GEMMs (paper Fig. 7 analysis: "large
systolic arrays are harder to fully utilize").
"""
from __future__ import annotations

import functools

import numpy as np

from .hardware import SystolicArray
from .units import Cycles, Ratio


@functools.lru_cache(maxsize=1 << 20)
def gemm_cycles(m: int, k: int, n: int, rows: int, cols: int) -> Cycles:
    """Cycles for one lane's systolic array to compute an (m,k)x(k,n) GEMM."""
    if m <= 0 or k <= 0 or n <= 0:
        return 0
    full_r, rem_r = divmod(m, rows)
    full_c, rem_c = divmod(n, cols)

    def pass_cycles(r_occ: int, c_occ: int) -> Cycles:
        # fill (weights/partials skew in over 2*r), stream k, drain c
        return 2 * r_occ + c_occ + k - 2

    total: Cycles = 0
    total += full_r * full_c * pass_cycles(rows, cols)
    if rem_r:
        total += full_c * pass_cycles(rem_r, cols)
    if rem_c:
        total += full_r * pass_cycles(rows, rem_c)
    if rem_r and rem_c:
        total += pass_cycles(rem_r, rem_c)
    return total


def gemm_cycles_array(m, k, n, rows, cols, xp=np):
    """Vectorized version used by the mapper's parameter search.

    m, k, n: broadcastable integer arrays; rows/cols may be scalars or
    per-row arrays (the mapper's device axis). Returns int64 array of cycles.
    This is the LUT-free fast path: the closed form is cheap enough to
    evaluate for ~1e5 candidates at once, which is what makes our mapper
    ~1000x faster than a per-candidate loop (paper: 26,400 rounds in ~15 min).

    `xp` selects the array module: numpy (default) or jax.numpy — the same
    closed form serves both mapper backends (core/mapper_jax.py traces it
    into the jitted candidate-table kernel; winners are backend-independent,
    tests/test_mapper_jax.py).
    """
    m = xp.asarray(m, dtype=xp.int64)
    k = xp.asarray(k, dtype=xp.int64)
    n = xp.asarray(n, dtype=xp.int64)
    full_r, rem_r = xp.divmod(m, rows)
    full_c, rem_c = xp.divmod(n, cols)

    def pc(r_occ, c_occ):
        return 2 * r_occ + c_occ + k - 2

    total = full_r * full_c * pc(rows, cols)
    total = total + xp.where(rem_r > 0, full_c * pc(rem_r, cols), 0)
    total = total + xp.where(rem_c > 0, full_r * pc(rows, rem_c), 0)
    total = total + xp.where((rem_r > 0) & (rem_c > 0), pc(rem_r, rem_c), 0)
    return total


def utilization(m: int, k: int, n: int, sa: SystolicArray) -> Ratio:
    """MAC utilization of the array for this tile (1.0 = every PE busy)."""
    cyc: Cycles = gemm_cycles(m, k, n, sa.rows, sa.cols)
    if cyc == 0:
        return 0.0
    ideal: Cycles = m * k * n / sa.macs
    return min(1.0, ideal / cyc)
