"""Deterministic list scheduler over per-resource timelines (DESIGN.md §9).

Replaces the serial latency sum with a resource-constrained schedule of the
dataflow DAG: every node occupies one device resource ("compute" — the
systolic datapath, "vector" — vector units + HBM streaming, "link" — the
interconnect) for `latency x repeat` seconds, starting once all of its
producers have finished AND its resource is free. Nodes are visited in graph
order (a topological order by construction), which makes the schedule
deterministic and — for a pure chain — reproduces the serial float-summation
order bit-for-bit: start_i = end_{i-1}, so the makespan is the exact
left-to-right sum the seed model computed.

Comm/compute overlap (`pipeline_collectives=True`) models the chunked
execution deployed TP inference actually uses (Megatron's tensor-parallel
communication overlap, ring-exchange RS/AG): a collective's ring steps
interleave with its producer's output tiles, so on the link timeline it may
start when its producer *starts* (not ends), while still never completing
before the producer has finished its last chunk:

    start  = max(link free, max over deps of START)
    finish = max(start + duration, max over deps of END)

Consumers wait for `finish`; the link stays busy for `duration`. This is the
ideal pipelined limit — per-chunk framing overheads are already inside the
LogGP link model, and the schedule's makespan is still bounded below by
every per-resource busy time (tested).

A node with repeat=n stands for n sequential instances (the folded identical
layers of build_model). Scheduling it once with duration n x latency equals
scheduling n copies whose intra-layer edges repeat per instance, because
list-schedule start times are positively homogeneous in the durations; the
one structure this folding cannot express is overlap *across* the layer
boundary, which keeps the model conservative.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from .ir import CollectiveSpec, Graph
from .units import Ratio, Seconds

RESOURCES = ("compute", "vector", "link")


@dataclass(frozen=True)
class OpSlot:
    """One scheduled node: where and when it ran."""
    name: str
    resource: str
    start: Seconds
    end: Seconds                    # completion seen by consumers
    duration: Seconds               # resource occupancy (latency x repeat)
    critical_pred: int = -1         # node index that set our start (-1: none)

    @property
    def slack_free(self) -> bool:
        return self.start == 0.0


@dataclass
class Schedule:
    """Per-op timeline + aggregate accounting for one scheduled Graph."""
    slots: List[OpSlot]
    makespan: Seconds
    serial: Seconds                 # left-to-right serial sum (seed metric)
    busy: Dict[str, Seconds]        # per-resource occupied time

    @property
    def overlap_speedup(self) -> Ratio:
        """Serial latency / scheduled latency (>= 1)."""
        return self.serial / self.makespan if self.makespan > 0 else 1.0

    def critical_path(self) -> List[int]:
        """Node indices on the critical path, source to sink. Follows the
        recorded `critical_pred` chain from the last-finishing node, so the
        attribution is exact for the schedule that was actually built."""
        if not self.slots:
            return []
        cur = max(range(len(self.slots)), key=lambda i: self.slots[i].end)
        path = [cur]
        while self.slots[cur].critical_pred >= 0:
            cur = self.slots[cur].critical_pred
            path.append(cur)
        path.reverse()
        return path

    def critical_breakdown(self) -> Dict[str, Seconds]:
        """Critical-path (not additive) attribution: seconds each named op
        contributes along the critical path, plus any scheduling stall."""
        out: Dict[str, Seconds] = {}
        prev_end: Seconds = 0.0
        for i in self.critical_path():
            s = self.slots[i]
            stall = s.start - prev_end
            if stall > 0:
                out["(stall)"] = out.get("(stall)", 0.0) + stall
            # clamp: a pipelined collective predecessor can extend past its
            # successor's own end in pathological hand-built graphs; its
            # contribution is then already attributed upstream
            out[s.name] = out.get(s.name, 0.0) \
                + max(0.0, s.end - max(s.start, prev_end))
            prev_end = max(prev_end, s.end)
        return out

    def summary(self) -> str:
        busy = " ".join(f"{r}={self.busy.get(r, 0.0) * 1e3:.2f}ms"
                        for r in RESOURCES)
        return (f"makespan={self.makespan * 1e3:.2f}ms "
                f"serial={self.serial * 1e3:.2f}ms "
                f"overlap_speedup={self.overlap_speedup:.3f}x {busy}")


def schedule_graph(graph: Graph, latencies: Sequence[float],
                   pipeline_collectives: bool = True,
                   resources: Optional[Sequence[str]] = None) -> Schedule:
    """List-schedule `graph` given per-node latencies (already x repeat).

    `latencies[i]` is node i's resource occupancy in seconds. `resources`
    optionally overrides `ir.resource_of` per node (tests use this to build
    synthetic contention). Returns the per-op timeline; the caller decides
    whether makespan (overlap) or the serial sum prices the graph.
    """
    n = len(graph.nodes)
    if len(latencies) != n:
        raise ValueError(f"got {len(latencies)} latencies for {n} nodes")
    edges = graph.edges()
    res = list(resources) if resources is not None else \
        [node.resource for node in graph.nodes]

    slots: List[OpSlot] = []
    ends: List[Seconds] = []
    starts: List[Seconds] = []
    free: Dict[str, Seconds] = {}
    free_by: Dict[str, int] = {}    # node currently holding each resource
    serial: Seconds = 0.0
    makespan: Seconds = 0.0
    busy: Dict[str, Seconds] = {}

    for i, node in enumerate(graph.nodes):
        dur: Seconds = latencies[i]
        r = res[i]
        deps = edges[i]
        pipelined = (pipeline_collectives and r == "link"
                     and isinstance(node.spec, CollectiveSpec) and deps)

        # -- when can we start? track WHO set the start for attribution ----
        start: Seconds = 0.0
        pred = -1
        for d in deps:
            ready = starts[d] if pipelined else ends[d]
            if ready > start:
                start, pred = ready, d
        if free.get(r, 0.0) > start:
            start, pred = free[r], free_by.get(r, -1)

        end: Seconds = start + dur
        if pipelined:
            # ring chunks interleave with the producer's tiles, but the last
            # chunk cannot complete before the producer does
            for d in deps:
                if ends[d] > end:
                    end, pred = ends[d], d
        free[r] = start + dur
        free_by[r] = i
        busy[r] = busy.get(r, 0.0) + dur
        serial = serial + dur               # left-to-right, seed order
        if end > makespan:
            makespan = end
        starts.append(start)
        ends.append(end)
        slots.append(OpSlot(node.name, r, start, end, dur, pred))

    return Schedule(slots=slots, makespan=makespan, serial=serial, busy=busy)
