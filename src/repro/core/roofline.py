"""Naive roofline baseline (paper Table V: fast but over-optimistic).

Used two ways:
  * as the lower-bound sanity check for the tile-level model (property test:
    mapper latency >= roofline latency, always);
  * in the dry-run analyzer, where the three-term roofline (compute, memory,
    collective) is derived from compiled-HLO statistics — see
    launch/analysis.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any

from .hardware import Device, System
from .operators import (GELU_FLOPS_PER_ELT, LAYERNORM_FLOPS_PER_ELT,
                        RMSNORM_FLOPS_PER_ELT, SILU_MUL_FLOPS_PER_ELT,
                        SOFTMAX_FLOPS_PER_ELT)
from .units import Bytes, BytesPerSecond, Elements, Flops, FlopsPerElement, \
    FlopsPerSecond, Ratio, Seconds

if TYPE_CHECKING:
    from .ir import Graph


@dataclass(frozen=True)
class RooflinePoint:
    compute_s: Seconds
    memory_s: Seconds
    collective_s: Seconds = 0.0

    @property
    def latency(self) -> Seconds:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms.items(), key=lambda kv: kv[1])[0]


def matmul_roofline(dev: Device, m: int, k: int, n: int, batch: int = 1,
                    bytes_a: float = 2, bytes_b: float = 2,
                    bytes_out: float = 2,
                    mac_scale: Ratio = 1.0) -> RooflinePoint:
    """Memory term = sum of per-operand widths (each tensor streamed once);
    compute term scaled by the narrow-datatype issue rate so it stays a
    lower bound for the mapper's scaled cycle counts (ISSUE 4)."""
    flops: Flops = 2.0 * batch * m * k * n
    bytes_: Bytes = batch * (m * k * bytes_a + k * n * bytes_b
                             + m * n * bytes_out)
    return RooflinePoint(flops / (dev.peak_matmul_flops * mac_scale),
                         bytes_ / dev.memory_bandwidth)


def op_roofline(dev: Device, flops: Flops, bytes_: Bytes,
                on_mxu: bool = False) -> RooflinePoint:
    peak: FlopsPerSecond = dev.peak_matmul_flops if on_mxu \
        else dev.peak_vector_flops
    return RooflinePoint(flops / peak, bytes_ / dev.memory_bandwidth)


# --- symbolic-IR entry points ----------------------------------------------

def spec_roofline(dev: Device, spec: object) -> RooflinePoint:
    """Optimistic roofline bound for one ir.OpSpec (no tiling effects).

    Property: the mapper/operator latency for the same spec is never below
    this bound (tested) — the paper's Table V criticism of rooflines.
    """
    from .ir import (CollectiveSpec, ElementwiseSpec, FusedMatmulSpec,
                     MatmulSpec, NormSpec, ScanSpec, SoftmaxSpec, TrafficSpec)
    if isinstance(spec, FusedMatmulSpec):
        # fused kernel: the GEMM's roofline at its rescaled (elided) output
        # traffic, plus the epilogues' vector flops on the compute term
        base = spec_roofline(dev, spec.gemm)
        extra: Seconds = sum(spec_roofline(dev, e).compute_s
                             for e in spec.epilogue)
        return RooflinePoint(base.compute_s + extra, base.memory_s)
    if isinstance(spec, MatmulSpec):
        return matmul_roofline(dev, spec.m, spec.k, spec.n, spec.batch,
                               spec.bytes_a, spec.bytes_b, spec.bytes_out,
                               spec.mac_scale)
    if isinstance(spec, SoftmaxSpec):
        n: Elements = spec.rows * spec.cols
        return op_roofline(dev, SOFTMAX_FLOPS_PER_ELT * n,
                           n * (spec.bytes_in + spec.bytes_out))
    if isinstance(spec, NormSpec):
        rate: FlopsPerElement = (LAYERNORM_FLOPS_PER_ELT
                                 if spec.kind == "layernorm"
                                 else RMSNORM_FLOPS_PER_ELT)
        nn: Elements = spec.rows * spec.cols
        return op_roofline(dev, rate * nn,
                           nn * (spec.bytes_in + spec.bytes_out))
    if isinstance(spec, ElementwiseSpec):
        per: FlopsPerElement = {
            "gelu": GELU_FLOPS_PER_ELT,
            "silu_mul": SILU_MUL_FLOPS_PER_ELT,
        }.get(spec.kind, spec.flops_per_elt)
        n_in = 2 if spec.kind == "silu_mul" else spec.n_in
        return op_roofline(dev, per * spec.n_elements,
                           spec.n_elements * (n_in + 1) * spec.bytes_elt)
    if isinstance(spec, ScanSpec):
        return op_roofline(dev, spec.flops_per_step * spec.seq * spec.batch,
                           spec.bytes_io)
    if isinstance(spec, TrafficSpec):
        return op_roofline(dev, 0.0, spec.n_bytes)
    if isinstance(spec, CollectiveSpec):
        return RooflinePoint(0.0, 0.0, 0.0)   # link-bound; see graph_roofline
    raise TypeError(f"no roofline for spec type {type(spec).__name__}")


def graph_roofline(system: System, graph: "Graph") -> RooflinePoint:
    """Three-term roofline for a whole ir.Graph: compute and memory terms sum
    each node's optimistic bound x repeat; collective bytes go through the
    link at its raw bandwidth (framing/latency ignored — optimistic, like the
    rest of the roofline)."""
    from .ir import CollectiveSpec
    dev = system.device
    compute: Seconds = 0.0
    memory: Seconds = 0.0
    coll_bytes: Bytes = 0.0
    for node in graph:
        if isinstance(node.spec, CollectiveSpec):
            n = node.spec.n_devices or system.device_count
            if n > 1:
                factor: Ratio = {"all_reduce": 2.0 * (n - 1) / n,
                                 "reduce_scatter": (n - 1) / n,
                                 "all_gather": (n - 1) / n,
                                 "all_to_all": (n - 1) / n,
                                 "p2p": 1.0}.get(node.spec.kind, 1.0)
                coll_bytes += node.spec.n_bytes * factor * node.repeat
            continue
        pt = spec_roofline(dev, node.spec)
        compute += pt.compute_s * node.repeat
        memory += pt.memory_s * node.repeat
    return RooflinePoint(compute, memory,
                         coll_bytes / system.link.bandwidth_bytes)


def schedule_roofline(cost: Any) -> RooflinePoint:
    """Three-term resource roofline of a scheduled LayerCost (DESIGN.md §9):
    per-resource busy times from the dataflow schedule — compute (MXU),
    memory (vector/HBM streaming), collective (link). The scheduled makespan
    is never below `.latency` of this point (max of the busy times), and the
    gap between them is exactly the critical-path serialization the list
    scheduler priced — the attribution a naive additive breakdown cannot
    give. Works on serially-priced costs too (busy times from spec resource
    tags)."""
    busy = cost.by_resource()
    return RooflinePoint(compute_s=busy.get("compute", 0.0),
                         memory_s=busy.get("vector", 0.0),
                         collective_s=busy.get("link", 0.0))


# --- TPU v5e constants used by the dry-run three-term roofline -------------
TPU_V5E_PEAK_BF16: FlopsPerSecond = 197e12    # per chip
TPU_V5E_HBM_BW: BytesPerSecond = 819e9        # per chip
TPU_V5E_ICI_BW: BytesPerSecond = 50e9         # per link (per direction)
TPU_V5E_ICI_LINKS = 4                         # 2D torus: +/-x, +/-y


def three_term(flops_per_chip: Flops, hbm_bytes_per_chip: Bytes,
               collective_bytes_per_chip: Bytes,
               peak: FlopsPerSecond = TPU_V5E_PEAK_BF16,
               hbm: BytesPerSecond = TPU_V5E_HBM_BW,
               ici: BytesPerSecond = TPU_V5E_ICI_BW) -> RooflinePoint:
    return RooflinePoint(
        compute_s=flops_per_chip / peak,
        memory_s=hbm_bytes_per_chip / hbm,
        collective_s=collective_bytes_per_chip / ici,
    )
