"""Naive roofline baseline (paper Table V: fast but over-optimistic).

Used two ways:
  * as the lower-bound sanity check for the tile-level model (property test:
    mapper latency >= roofline latency, always);
  * in the dry-run analyzer, where the three-term roofline (compute, memory,
    collective) is derived from compiled-HLO statistics — see
    launch/analysis.py.
"""
from __future__ import annotations

from dataclasses import dataclass

from .hardware import Device, System


@dataclass(frozen=True)
class RooflinePoint:
    compute_s: float
    memory_s: float
    collective_s: float = 0.0

    @property
    def latency(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def matmul_roofline(dev: Device, m: int, k: int, n: int, batch: int = 1,
                    bytes_elt: int = 2) -> RooflinePoint:
    flops = 2.0 * batch * m * k * n
    bytes_ = batch * (m * k + k * n + m * n) * bytes_elt
    return RooflinePoint(flops / dev.peak_matmul_flops,
                         bytes_ / dev.memory_bandwidth)


def op_roofline(dev: Device, flops: float, bytes_: float,
                on_mxu: bool = False) -> RooflinePoint:
    peak = dev.peak_matmul_flops if on_mxu else dev.peak_vector_flops
    return RooflinePoint(flops / peak, bytes_ / dev.memory_bandwidth)


# --- TPU v5e constants used by the dry-run three-term roofline -------------
TPU_V5E_PEAK_BF16 = 197e12          # FLOP/s per chip
TPU_V5E_HBM_BW = 819e9              # bytes/s per chip
TPU_V5E_ICI_BW = 50e9               # bytes/s per link (per direction)
TPU_V5E_ICI_LINKS = 4               # 2D torus: +/-x, +/-y


def three_term(flops_per_chip: float, hbm_bytes_per_chip: float,
               collective_bytes_per_chip: float,
               peak=TPU_V5E_PEAK_BF16, hbm=TPU_V5E_HBM_BW,
               ici=TPU_V5E_ICI_BW) -> RooflinePoint:
    return RooflinePoint(
        compute_s=flops_per_chip / peak,
        memory_s=hbm_bytes_per_chip / hbm,
        collective_s=collective_bytes_per_chip / ici,
    )
