"""AST dimensional-analysis pass over the pricing core (DESIGN.md §12).

PR 7's verifier validates runtime values; this pass makes *unit* errors
unrepresentable at lint time. It reads the ``Annotated[float, Unit(...)]``
aliases from core/units.py wherever they appear in source — function
signatures, dataclass fields, ``x: Seconds = ...`` locals, module constants
— and propagates dimension vectors through arithmetic, assignments, calls,
constructor keywords, attribute loads/stores and returns, emitting
``verify.Diagnostic`` records (rule id, severity, file:line, fix hint) when
two provably-different dimensions meet where they must agree.

The inference is *gradual*: every expression is one of

  * ``ANY``            — unit unknown (unannotated names, containers, numpy
                         internals). Absorbing under ``*``/``/``; optimistic
                         under ``+`` (the result takes the known side).
                         ANY never produces a diagnostic, so unannotated
                         code is silent by construction.
  * ``DIMENSIONLESS``  — numeric literals and ``Ratio``-typed values.
                         Coerces to any unit (this is how constants enter:
                         ``FP32_BYTES: BytesPerElement = 4.0``).
  * a known ``Unit``   — traced from an alias annotation through the
                         dimension algebra (``Bytes / BytesPerSecond`` is
                         ``Seconds``; ``Elements * BytesPerElement`` is
                         ``Bytes``).

Only when BOTH sides of an addition/comparison/assignment/field-store/
return carry known, different, non-dimensionless units does a rule fire —
the checker proves exactly what the annotations claim, nothing more.

Rules (all error severity):

  unit.add-mismatch      operands of + / - / += / max / min disagree
  unit.compare-mismatch  comparison operands disagree
  unit.assign-mismatch   value disagrees with an ``x: Unit`` declaration
  unit.field-mismatch    constructor kwarg / replace() kwarg / attribute
                         store disagrees with the declared field unit
  unit.return-mismatch   returned expression disagrees with ``-> Unit``
  unit.call-mismatch     argument disagrees with the declared param unit

Two passes: pass 1 over every target file builds global symbol tables
(class fields & properties, function signatures, module constants — merged
by bare name across the tree, matching the from-import style of the core);
pass 2 walks each function body linearly (both branches of ``if``, loop
bodies once) inferring an environment of name -> (unit, class) and checking
every rule site. ``check_source`` runs the same engine on a standalone
snippet, which is how the planted-mutant suite proves each rule fires.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .units import ALIASES, DIMENSIONLESS, Unit
from .verify import Diagnostic

__all__ = [
    "RULES", "Rule", "check_paths", "check_sources", "check_source",
    "registry_diagnostics", "registry_selfcheck", "DEFAULT_TARGETS",
]

#: the pricing core this pass was built to police (relative to src/repro)
DEFAULT_TARGETS = ("core",)


# ---------------------------------------------------------------------------
# rule registry (mirrors verify.RULES so CI modes / docs treat them alike)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Rule:
    rule_id: str
    summary: str


RULES: Dict[str, Rule] = {}


def _rule(rule_id: str, summary: str) -> str:
    if rule_id in RULES:
        raise ValueError(f"duplicate rule id {rule_id!r}")
    RULES[rule_id] = Rule(rule_id, summary)
    return rule_id


ADD_MISMATCH = _rule(
    "unit.add-mismatch",
    "operands of +, -, += or max/min carry different dimensions")
COMPARE_MISMATCH = _rule(
    "unit.compare-mismatch",
    "comparison operands carry different dimensions")
ASSIGN_MISMATCH = _rule(
    "unit.assign-mismatch",
    "assigned value disagrees with the local's declared unit")
FIELD_MISMATCH = _rule(
    "unit.field-mismatch",
    "value stored into a dataclass field disagrees with its declared unit")
RETURN_MISMATCH = _rule(
    "unit.return-mismatch",
    "returned expression disagrees with the declared return unit")
CALL_MISMATCH = _rule(
    "unit.call-mismatch",
    "argument disagrees with the declared parameter unit")


# ---------------------------------------------------------------------------
# inference values
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Val:
    """Inference result for one expression: dimension + (optional) class.

    ``unit is None`` means ANY. ``cls`` names a class from the symbol
    tables when the expression is an instance of it (used to resolve
    ``obj.field`` chains and ``replace(obj, ...)``).
    """
    unit: Optional[Unit] = None
    cls: Optional[str] = None
    elts: Optional[Tuple["Val", ...]] = None   # tuple literals, for returns


ANY = Val()
SCALAR = Val(unit=DIMENSIONLESS)


def _known(v: Val) -> bool:
    return v.unit is not None and not v.unit.dimensionless


# ---------------------------------------------------------------------------
# annotation resolution
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Ann:
    """A resolved source annotation: unit alias, class reference, or both
    unknown (ANY)."""
    unit: Optional[Unit] = None
    cls: Optional[str] = None
    elts: Optional[Tuple["Ann", ...]] = None   # Tuple[Seconds, Flops] returns


ANN_ANY = Ann()


@dataclass
class FuncInfo:
    name: str
    params: List[Tuple[str, Ann]]          # positional-or-keyword, in order
    kwonly: Dict[str, Ann]
    ret: Ann
    is_method: bool = False                # first param is self/cls


@dataclass
class ClassInfo:
    name: str
    fields: Dict[str, Ann]                 # AnnAssign fields + @property rets
    order: List[str]                       # declaration order (ctor mapping)
    methods: Dict[str, FuncInfo]


class SymbolTables:
    """Pass-1 product: bare-name-merged classes / functions / constants."""

    def __init__(self) -> None:
        self.classes: Dict[str, ClassInfo] = {}
        self.funcs: Dict[str, FuncInfo] = {}
        self.consts: Dict[str, Val] = {}
        # field name -> Ann agreed on by every class declaring it with a
        # known unit; None if two classes disagree ("duck" field lookup for
        # attribute loads whose base class is unknown)
        self.duck: Dict[str, Optional[Ann]] = {}

    def resolve(self, node: Optional[ast.expr]) -> Ann:
        """Resolve an annotation AST node to (unit, class)."""
        if node is None:
            return ANN_ANY
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            try:
                node = ast.parse(node.value, mode="eval").body
            except SyntaxError:
                return ANN_ANY
        if isinstance(node, ast.Name):
            return self._resolve_name(node.id)
        if isinstance(node, ast.Attribute):
            return self._resolve_name(node.attr)
        if isinstance(node, ast.Subscript):
            head = node.value
            head_name = (head.id if isinstance(head, ast.Name)
                         else head.attr if isinstance(head, ast.Attribute)
                         else "")
            if head_name == "Optional":
                return self.resolve(node.slice)
            if head_name == "Tuple" or head_name == "tuple":
                if isinstance(node.slice, ast.Tuple):
                    elts = tuple(self.resolve(e) for e in node.slice.elts)
                    if any(e.unit is not None or e.cls for e in elts):
                        return Ann(elts=elts)
            return ANN_ANY
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
            # X | None style optionals
            left = self.resolve(node.left)
            right = self.resolve(node.right)
            if left is not ANN_ANY and right is ANN_ANY:
                return left
            if right is not ANN_ANY and left is ANN_ANY:
                return right
            return ANN_ANY
        return ANN_ANY

    def _resolve_name(self, name: str) -> Ann:
        if name in ALIASES:
            return Ann(unit=ALIASES[name])
        if name in self.classes:
            return Ann(cls=name)
        return ANN_ANY

    def build_duck(self) -> None:
        seen: Dict[str, Optional[Ann]] = {}
        for ci in self.classes.values():
            for fname, ann in ci.fields.items():
                if ann.unit is None and ann.cls is None:
                    continue                      # ANY declarations ignored
                if fname not in seen:
                    seen[fname] = ann
                elif seen[fname] is not None and seen[fname] != ann:
                    seen[fname] = None            # conflict -> ambiguous
        self.duck = seen


def _decorator_names(fn: ast.FunctionDef) -> List[str]:
    out = []
    for d in fn.decorator_list:
        if isinstance(d, ast.Name):
            out.append(d.id)
        elif isinstance(d, ast.Attribute):
            out.append(d.attr)
        elif isinstance(d, ast.Call):
            f = d.func
            out.append(f.id if isinstance(f, ast.Name)
                       else f.attr if isinstance(f, ast.Attribute) else "")
    return out


def _func_info(tables: SymbolTables, fn: ast.FunctionDef,
               is_method: bool = False) -> FuncInfo:
    decs = _decorator_names(fn)
    method = is_method and "staticmethod" not in decs
    params: List[Tuple[str, Ann]] = []
    for a in list(fn.args.posonlyargs) + list(fn.args.args):
        params.append((a.arg, tables.resolve(a.annotation)))
    kwonly = {a.arg: tables.resolve(a.annotation)
              for a in fn.args.kwonlyargs}
    return FuncInfo(fn.name, params, kwonly, tables.resolve(fn.returns),
                    is_method=method)


def _build_tables(modules: Dict[str, ast.Module]) -> SymbolTables:
    tables = SymbolTables()
    # round 1: class names must exist before annotations resolve to them
    for mod in modules.values():
        for node in mod.body:
            if isinstance(node, ast.ClassDef):
                tables.classes[node.name] = ClassInfo(node.name, {}, [], {})
    # round 2: fields, methods, functions, constants
    for mod in modules.values():
        for node in mod.body:
            if isinstance(node, ast.ClassDef):
                ci = tables.classes[node.name]
                for item in node.body:
                    if (isinstance(item, ast.AnnAssign)
                            and isinstance(item.target, ast.Name)):
                        ci.fields[item.target.id] = tables.resolve(
                            item.annotation)
                        ci.order.append(item.target.id)
                    elif isinstance(item, ast.FunctionDef):
                        fi = _func_info(tables, item, is_method=True)
                        ci.methods[item.name] = fi
                        if "property" in _decorator_names(item):
                            ci.fields[item.name] = fi.ret
            elif isinstance(node, ast.FunctionDef):
                tables.funcs[node.name] = _func_info(tables, node)
            elif (isinstance(node, ast.AnnAssign)
                  and isinstance(node.target, ast.Name)):
                ann = tables.resolve(node.annotation)
                tables.consts[node.target.id] = Val(ann.unit, ann.cls)
    tables.build_duck()
    return tables


# ---------------------------------------------------------------------------
# per-function inference
# ---------------------------------------------------------------------------

#: calls that pass their first argument's unit through unchanged
_PASSTHROUGH = {"abs", "float", "int", "round", "ceil", "floor", "fabs",
                "trunc", "copy", "deepcopy", "asarray", "array", "sqrt0"}
#: calls whose arguments must share a unit and whose result is that unit
_UNIFYING = {"max", "min", "maximum", "minimum"}


class _Checker:
    def __init__(self, tables: SymbolTables, filename: str,
                 diags: List[Diagnostic]) -> None:
        self.tables = tables
        self.filename = filename
        self.diags = diags
        self.env: Dict[str, Val] = {}
        self.ret: Ann = ANN_ANY

    # ---- reporting -------------------------------------------------------
    def _diag(self, rule: str, node: ast.AST, message: str,
              hint: str = "") -> None:
        line = getattr(node, "lineno", 0)
        self.diags.append(Diagnostic(
            rule=rule, severity="error", message=message,
            location=f"{self.filename}:{line}", hint=hint))

    def _mismatch(self, rule: str, node: ast.AST, what: str,
                  left: Unit, right: Unit, hint: str = "") -> None:
        self._diag(rule, node,
                   f"{what}: {left.symbol} vs {right.symbol}",
                   hint or "annotate or convert one side so the "
                           "dimensions agree")

    # ---- entry points ----------------------------------------------------
    def check_function(self, fn: ast.FunctionDef,
                       cls: Optional[str] = None) -> None:
        info = (self.tables.classes[cls].methods[fn.name] if cls
                else self.tables.funcs.get(fn.name))
        if info is None:
            info = _func_info(self.tables, fn)
        self.env = {}
        self.ret = info.ret
        params = info.params
        if info.is_method and params:
            name, _ = params[0]
            self.env[name] = Val(cls=cls) if cls else ANY
            params = params[1:]
        for name, ann in params:
            self.env[name] = Val(ann.unit, ann.cls)
        for name, ann in info.kwonly.items():
            self.env[name] = Val(ann.unit, ann.cls)
        for stmt in fn.body:
            self._exec(stmt)

    def check_module_body(self, mod: ast.Module) -> None:
        """Module-level statements (constant declarations, init code)."""
        self.env = {}
        self.ret = ANN_ANY
        for stmt in mod.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Import, ast.ImportFrom)):
                continue
            self._exec(stmt)

    # ---- statements ------------------------------------------------------
    def _exec(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            val = self._infer(stmt.value)
            for tgt in stmt.targets:
                self._bind(tgt, val, stmt.value)
        elif isinstance(stmt, ast.AnnAssign):
            ann = self.tables.resolve(stmt.annotation)
            if stmt.value is not None:
                val = self._infer(stmt.value)
                if (ann.unit is not None and not ann.unit.dimensionless
                        and _known(val) and val.unit != ann.unit):
                    self._mismatch(
                        ASSIGN_MISMATCH, stmt,
                        "declared unit disagrees with assigned value",
                        ann.unit, val.unit,  # type: ignore[arg-type]
                        hint="fix the expression or the declaration")
            if isinstance(stmt.target, ast.Name):
                self.env[stmt.target.id] = Val(
                    ann.unit, ann.cls if ann.cls else None)
            elif isinstance(stmt.target, ast.Attribute):
                self._store_attr(stmt.target,
                                 Val(ann.unit, ann.cls), stmt)
        elif isinstance(stmt, ast.AugAssign):
            cur = self._infer(stmt.target)
            val = self._infer(stmt.value)
            if isinstance(stmt.op, (ast.Add, ast.Sub)):
                out = self._add(cur, val, stmt)
            elif isinstance(stmt.op, ast.Mult):
                out = self._mul(cur, val)
            elif isinstance(stmt.op, (ast.Div, ast.FloorDiv)):
                out = self._div(cur, val)
            else:
                out = ANY
            if isinstance(stmt.target, ast.Name):
                # an annotated local keeps its declared unit
                prev = self.env.get(stmt.target.id, ANY)
                self.env[stmt.target.id] = prev if _known(prev) else out
        elif isinstance(stmt, ast.Return):
            self._check_return(stmt)
        elif isinstance(stmt, ast.If):
            self._infer(stmt.test)
            for s in stmt.body:
                self._exec(s)
            for s in stmt.orelse:
                self._exec(s)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._infer(stmt.iter)
            self._bind(stmt.target, ANY, stmt.iter)
            for s in stmt.body:
                self._exec(s)
            for s in stmt.orelse:
                self._exec(s)
        elif isinstance(stmt, ast.While):
            self._infer(stmt.test)
            for s in stmt.body:
                self._exec(s)
            for s in stmt.orelse:
                self._exec(s)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._infer(item.context_expr)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, ANY, item.context_expr)
            for s in stmt.body:
                self._exec(s)
        elif isinstance(stmt, ast.Try):
            for s in stmt.body:
                self._exec(s)
            for h in stmt.handlers:
                for s in h.body:
                    self._exec(s)
            for s in stmt.orelse:
                self._exec(s)
            for s in stmt.finalbody:
                self._exec(s)
        elif isinstance(stmt, ast.Expr):
            self._infer(stmt.value)
        elif isinstance(stmt, ast.Assert):
            self._infer(stmt.test)
        elif isinstance(stmt, ast.FunctionDef):
            # nested function: check with its own (closure-free) env
            saved_env, saved_ret = self.env, self.ret
            info = _func_info(self.tables, stmt)
            self.env = {}
            for name, ann in info.params:
                self.env[name] = Val(ann.unit, ann.cls)
            for name, ann in info.kwonly.items():
                self.env[name] = Val(ann.unit, ann.cls)
            self.ret = info.ret
            for s in stmt.body:
                self._exec(s)
            self.env, self.ret = saved_env, saved_ret
        # Raise / Pass / Delete / Global / Import / ClassDef: nothing priced

    def _bind(self, tgt: ast.expr, val: Val, value_node: ast.expr) -> None:
        if isinstance(tgt, ast.Name):
            self.env[tgt.id] = val
        elif isinstance(tgt, ast.Attribute):
            self._store_attr(tgt, val, value_node)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            if (isinstance(value_node, (ast.Tuple, ast.List))
                    and len(value_node.elts) == len(tgt.elts)):
                for t, v in zip(tgt.elts, value_node.elts):
                    self._bind(t, self._infer(v), v)
            elif val.elts is not None and len(val.elts) == len(tgt.elts):
                for t, v in zip(tgt.elts, val.elts):
                    self._bind(t, v, value_node)
            else:
                for t in tgt.elts:
                    self._bind(t, ANY, value_node)
        # Subscript / Starred targets: not tracked

    def _store_attr(self, tgt: ast.Attribute, val: Val,
                    where: ast.AST) -> None:
        base = self._infer(tgt.value)
        if base.cls is None or base.cls not in self.tables.classes:
            return
        ann = self.tables.classes[base.cls].fields.get(tgt.attr)
        if ann is None:
            return
        if (ann.unit is not None and not ann.unit.dimensionless
                and _known(val) and val.unit != ann.unit):
            self._mismatch(
                FIELD_MISMATCH, where,
                f"store to {base.cls}.{tgt.attr} "
                f"(declared {ann.unit.symbol})",
                ann.unit, val.unit,  # type: ignore[arg-type]
                hint=f"convert the value to {ann.unit.symbol} or fix "
                     f"the field declaration")

    def _check_return(self, stmt: ast.Return) -> None:
        if stmt.value is None:
            return
        val = self._infer(stmt.value)
        ret = self.ret
        if ret.elts is not None and isinstance(stmt.value, ast.Tuple) \
                and len(stmt.value.elts) == len(ret.elts):
            for expr, ann in zip(stmt.value.elts, ret.elts):
                v = self._infer(expr)
                if (ann.unit is not None and not ann.unit.dimensionless
                        and _known(v) and v.unit != ann.unit):
                    self._mismatch(
                        RETURN_MISMATCH, expr,
                        "returned tuple element disagrees with the "
                        "declared return unit",
                        ann.unit, v.unit)  # type: ignore[arg-type]
            return
        if (ret.unit is not None and not ret.unit.dimensionless
                and _known(val) and val.unit != ret.unit):
            self._mismatch(
                RETURN_MISMATCH, stmt,
                "returned value disagrees with the declared return unit",
                ret.unit, val.unit,  # type: ignore[arg-type]
                hint=f"convert the result to {ret.unit.symbol} "
                     f"(e.g. divide a cycle count by a Hertz frequency "
                     f"for Seconds) or fix the -> annotation")

    # ---- expressions -----------------------------------------------------
    def _infer(self, node: ast.expr) -> Val:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool) or not isinstance(
                    node.value, (int, float)):
                return ANY
            return SCALAR
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            if node.id in self.tables.consts:
                return self.tables.consts[node.id]
            return ANY
        if isinstance(node, ast.Attribute):
            return self._infer_attr(node)
        if isinstance(node, ast.BinOp):
            return self._infer_binop(node)
        if isinstance(node, ast.UnaryOp):
            if isinstance(node.op, (ast.USub, ast.UAdd)):
                return self._infer(node.operand)
            self._infer(node.operand)
            return ANY
        if isinstance(node, ast.Compare):
            left = self._infer(node.left)
            for op, comp in zip(node.ops, node.comparators):
                right = self._infer(comp)
                if isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE,
                                   ast.Eq, ast.NotEq)):
                    if (_known(left) and _known(right)
                            and left.unit != right.unit):
                        self._mismatch(
                            COMPARE_MISMATCH, node,
                            "comparison across dimensions",
                            left.unit, right.unit)  # type: ignore[arg-type]
                left = right
            return SCALAR
        if isinstance(node, ast.BoolOp):
            vals = [self._infer(v) for v in node.values]
            return self._silent_unify(vals)
        if isinstance(node, ast.IfExp):
            self._infer(node.test)
            return self._silent_unify(
                [self._infer(node.body), self._infer(node.orelse)])
        if isinstance(node, ast.Call):
            return self._infer_call(node)
        if isinstance(node, ast.Tuple):
            return Val(elts=tuple(self._infer(e) for e in node.elts))
        if isinstance(node, ast.Subscript):
            base = self._infer(node.value)
            self._infer_slice(node.slice)
            return Val(unit=base.unit)
        if isinstance(node, (ast.List, ast.Set)):
            for e in node.elts:
                self._infer(e)
            return ANY
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if k is not None:
                    self._infer(k)
            for v in node.values:
                self._infer(v)
            return ANY
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            return ANY
        if isinstance(node, ast.Starred):
            self._infer(node.value)
            return ANY
        if isinstance(node, ast.JoinedStr):
            return ANY
        return ANY

    def _infer_slice(self, node: ast.expr) -> None:
        if isinstance(node, ast.Slice):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self._infer(part)
        else:
            self._infer(node)

    def _infer_attr(self, node: ast.Attribute) -> Val:
        base = self._infer(node.value)
        if base.cls is not None and base.cls in self.tables.classes:
            ci = self.tables.classes[base.cls]
            ann = ci.fields.get(node.attr)
            if ann is not None:
                return Val(ann.unit, ann.cls)
            return ANY
        # module-qualified constant (hw.MB) or duck field lookup: every
        # class declaring this field name agrees on its unit
        if node.attr in self.tables.consts:
            return self.tables.consts[node.attr]
        duck = self.tables.duck.get(node.attr)
        if duck is not None:
            return Val(duck.unit, duck.cls)
        return ANY

    def _infer_binop(self, node: ast.BinOp) -> Val:
        left = self._infer(node.left)
        right = self._infer(node.right)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            return self._add(left, right, node)
        if isinstance(node.op, ast.Mult):
            return self._mul(left, right)
        if isinstance(node.op, (ast.Div, ast.FloorDiv)):
            return self._div(left, right)
        if isinstance(node.op, ast.Mod):
            return Val(unit=left.unit)
        if isinstance(node.op, ast.Pow):
            if (left.unit is not None
                    and isinstance(node.right, ast.Constant)
                    and isinstance(node.right.value, int)):
                return Val(unit=left.unit ** node.right.value)
            return ANY
        return ANY

    def _add(self, left: Val, right: Val, node: ast.AST) -> Val:
        if left.unit is None:
            return Val(unit=right.unit)
        if right.unit is None:
            return Val(unit=left.unit)
        if left.unit == right.unit:
            return Val(unit=left.unit)
        if left.unit.dimensionless:
            return Val(unit=right.unit)
        if right.unit.dimensionless:
            return Val(unit=left.unit)
        self._mismatch(ADD_MISMATCH, node, "cannot add/subtract",
                       left.unit, right.unit,
                       hint="convert one operand (divide bytes by a "
                            "bandwidth, cycles by a frequency, ...) so "
                            "both sides share a dimension")
        return ANY

    def _mul(self, left: Val, right: Val) -> Val:
        if left.unit is None or right.unit is None:
            return ANY
        return Val(unit=left.unit * right.unit)

    def _div(self, left: Val, right: Val) -> Val:
        if left.unit is None or right.unit is None:
            return ANY
        return Val(unit=left.unit / right.unit)

    def _silent_unify(self, vals: Sequence[Val]) -> Val:
        known = [v for v in vals if _known(v)]
        if known and all(v.unit == known[0].unit for v in known):
            return Val(unit=known[0].unit)
        if known:
            return ANY
        if any(v.unit is not None for v in vals):
            return SCALAR
        return ANY

    def _unify_checked(self, vals: Sequence[Val], node: ast.AST) -> Val:
        known = [v for v in vals if _known(v)]
        for v in known[1:]:
            if v.unit != known[0].unit:
                self._mismatch(ADD_MISMATCH, node,
                               "max/min across dimensions",
                               known[0].unit, v.unit)  # type: ignore[arg-type]
                return ANY
        if known:
            return Val(unit=known[0].unit)
        if any(v.unit is not None for v in vals):
            return SCALAR
        return ANY

    # ---- calls -----------------------------------------------------------
    def _infer_call(self, node: ast.Call) -> Val:
        func = node.func
        name = (func.id if isinstance(func, ast.Name)
                else func.attr if isinstance(func, ast.Attribute)
                else "")

        args = [self._infer(a) for a in node.args]
        kwargs = {kw.arg: self._infer(kw.value)
                  for kw in node.keywords if kw.arg is not None}
        for kw in node.keywords:
            if kw.arg is None:
                self._infer(kw.value)

        if name in _UNIFYING:
            if len(node.args) >= 2 and not any(
                    isinstance(a, ast.Starred) for a in node.args):
                return self._unify_checked(args, node)
            return ANY
        if name in _PASSTHROUGH and len(args) >= 1:
            return Val(unit=args[0].unit)
        if name == "len":
            return SCALAR
        if name == "where" and len(args) == 3:
            return self._silent_unify(args[1:])
        if name == "replace" and node.args:
            # dataclasses.replace(obj, field=value)
            base = args[0]
            if base.cls is not None:
                self._check_ctor_kwargs(base.cls, node)
                return Val(cls=base.cls)
            return ANY

        # constructor?
        cls = None
        if isinstance(func, ast.Name) and func.id in self.tables.classes:
            cls = func.id
        elif isinstance(func, ast.Attribute) \
                and func.attr in self.tables.classes:
            cls = func.attr
        if cls is not None:
            self._check_ctor(cls, node, args)
            return Val(cls=cls)

        # known function (module-level, bare or attribute-qualified) or a
        # method on a known class
        info = None
        if isinstance(func, ast.Attribute):
            base = self._infer(func.value)
            if base.cls is not None and base.cls in self.tables.classes:
                info = self.tables.classes[base.cls].methods.get(func.attr)
            elif name in self.tables.funcs:
                info = self.tables.funcs[name]
        elif name in self.tables.funcs:
            info = self.tables.funcs[name]
        if info is not None:
            self._check_args(info, node, args, kwargs)
            return Val(info.ret.unit, info.ret.cls)
        return ANY

    def _check_args(self, info: FuncInfo, node: ast.Call,
                    args: Sequence[Val], kwargs: Dict[str, Val]) -> None:
        params = info.params[1:] if info.is_method else info.params
        by_name = dict(params)
        by_name.update(info.kwonly)
        for (pname, ann), val, anode in zip(params, args, node.args):
            self._check_one_arg(info.name, pname, ann, val, anode)
        for kname, val in kwargs.items():
            ann = by_name.get(kname)
            if ann is not None:
                self._check_one_arg(info.name, kname, ann, val, node)

    def _check_one_arg(self, fname: str, pname: str, ann: Ann, val: Val,
                       node: ast.AST) -> None:
        if (ann.unit is not None and not ann.unit.dimensionless
                and _known(val) and val.unit != ann.unit):
            self._mismatch(
                CALL_MISMATCH, node,
                f"argument {pname!r} of {fname}() "
                f"(declared {ann.unit.symbol})",
                ann.unit, val.unit,  # type: ignore[arg-type]
                hint=f"pass a {ann.unit.symbol} value or change the "
                     f"parameter annotation")

    def _check_ctor(self, cls: str, node: ast.Call,
                    args: Sequence[Val]) -> None:
        ci = self.tables.classes[cls]
        init = ci.methods.get("__init__")
        if init is not None:
            kwargs = {kw.arg: self._infer(kw.value)
                      for kw in node.keywords if kw.arg is not None}
            self._check_args(init, node, args, kwargs)
            return
        # dataclass-style: positional args follow field declaration order
        for fname, val, anode in zip(ci.order, args, node.args):
            ann = ci.fields.get(fname, ANN_ANY)
            self._check_field(cls, fname, ann, val, anode)
        self._check_ctor_kwargs(cls, node)

    def _check_ctor_kwargs(self, cls: str, node: ast.Call) -> None:
        ci = self.tables.classes[cls]
        for kw in node.keywords:
            if kw.arg is None:
                continue
            ann = ci.fields.get(kw.arg)
            if ann is None:
                continue
            self._check_field(cls, kw.arg, ann, self._infer(kw.value),
                              kw.value)

    def _check_field(self, cls: str, fname: str, ann: Ann, val: Val,
                     node: ast.AST) -> None:
        if (ann.unit is not None and not ann.unit.dimensionless
                and _known(val) and val.unit != ann.unit):
            self._mismatch(
                FIELD_MISMATCH, node,
                f"field {cls}.{fname} (declared {ann.unit.symbol})",
                ann.unit, val.unit,  # type: ignore[arg-type]
                hint=f"convert the value to {ann.unit.symbol} or fix "
                     f"the field declaration")


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def check_sources(named_sources: Dict[str, str]) -> List[Diagnostic]:
    """Run the full two-pass analysis over {filename: source}."""
    modules: Dict[str, ast.Module] = {}
    diags: List[Diagnostic] = []
    for fname, src in named_sources.items():
        try:
            modules[fname] = ast.parse(src, filename=fname)
        except SyntaxError as exc:
            diags.append(Diagnostic(
                rule="unit.parse-error", severity="error",
                message=str(exc), location=f"{fname}:{exc.lineno or 0}"))
    tables = _build_tables(modules)
    for fname, mod in modules.items():
        checker = _Checker(tables, fname, diags)
        checker.check_module_body(mod)
        for node in mod.body:
            if isinstance(node, ast.FunctionDef):
                checker.check_function(node)
            elif isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, ast.FunctionDef):
                        checker.check_function(item, cls=node.name)
    diags.sort(key=lambda d: (d.location, d.rule))
    return diags


def check_source(src: str, filename: str = "<snippet>") -> List[Diagnostic]:
    """Analyse a standalone snippet (the mutant suite's entry point)."""
    return check_sources({filename: src})


def _expand(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        else:
            out.append(path)
    return out


def check_paths(paths: Iterable[str]) -> List[Diagnostic]:
    """Analyse files / directories together (one merged symbol table)."""
    sources: Dict[str, str] = {}
    for path in _expand(paths):
        sources[str(path)] = path.read_text()
    return check_sources(sources)


# ---------------------------------------------------------------------------
# registry self-check: one minimal mutant per rule, proving each fires
# ---------------------------------------------------------------------------

_SAMPLE_MUTANTS: Dict[str, str] = {
    ADD_MISMATCH: (
        "from repro.core.units import Bytes, Seconds\n"
        "def f(n: Bytes, t: Seconds) -> float:\n"
        "    return n + t\n"),
    COMPARE_MISMATCH: (
        "from repro.core.units import Bytes, Seconds\n"
        "def f(n: Bytes, t: Seconds) -> bool:\n"
        "    return n < t\n"),
    ASSIGN_MISMATCH: (
        "from repro.core.units import Bytes, Seconds\n"
        "def f(n: Bytes) -> None:\n"
        "    t: Seconds = n\n"),
    FIELD_MISMATCH: (
        "from dataclasses import dataclass\n"
        "from repro.core.units import Bytes, Elements\n"
        "@dataclass\n"
        "class Spec:\n"
        "    n_bytes: Bytes\n"
        "def f(n: Elements) -> Spec:\n"
        "    return Spec(n_bytes=n)\n"),
    RETURN_MISMATCH: (
        "from repro.core.units import Cycles, Seconds\n"
        "def f(c: Cycles) -> Seconds:\n"
        "    return c\n"),
    CALL_MISMATCH: (
        "from repro.core.units import Bytes, Seconds\n"
        "def g(t: Seconds) -> Seconds:\n"
        "    return t\n"
        "def f(n: Bytes) -> Seconds:\n"
        "    return g(n)\n"),
}


def registry_diagnostics() -> Dict[str, List[Diagnostic]]:
    """Per-rule diagnostics from each rule's built-in sample mutant."""
    return {rule_id: [d for d in check_source(src) if d.rule == rule_id]
            for rule_id, src in _SAMPLE_MUTANTS.items()}


def registry_selfcheck() -> None:
    """Raise unless every registered rule fires on its sample mutant."""
    missing_sample = set(RULES) - set(_SAMPLE_MUTANTS)
    if missing_sample:
        raise AssertionError(
            f"rules without a sample mutant: {sorted(missing_sample)}")
    for rule_id, diags in registry_diagnostics().items():
        if not diags:
            raise AssertionError(
                f"rule {rule_id} did not fire on its sample mutant")
