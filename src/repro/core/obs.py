"""Observability core: process-wide metrics registry, wall-clock phase
spans, and per-op attribution records (ISSUE 9, DESIGN.md §13).

Three concerns live here, all deliberately decoupled from the pricing
models they observe:

  * `MetricsRegistry` — process-wide counters/gauges/histograms. The
    scattered cache statistics (mapper memo/disk hits, result-cache
    hits/puts, verifier diagnostics, chunk-backend selections) all feed
    this one registry; the legacy per-module stats objects
    (`mapper.MapperCacheStats`, `result_cache.DiskCacheStats`,
    `evaluator.EvalStats`) remain as compatibility views/mirrors over it.
    Counters are plain dict increments — always on, same cost as the
    attribute adds they replaced.

  * phase spans — `with metrics().phase("presolve"): ...` records
    wall-clock seconds per named framework phase (presolve / search /
    schedule / verify), so `benchmarks/run.py --json` can report where the
    framework's OWN time goes. Spans are the only wall-clock reads in the
    subsystem and are **zero-overhead when off**: with spans disabled
    (the default) `phase()` returns a shared no-op context manager and
    never touches the clock.

  * `Attribution` — the structured per-op report for one evaluated graph:
    latency/flops/bytes per op and per layer group, bound classification,
    fusion savings (elided HBM bytes, from `FusedMatmulSpec.elided` — the
    single source of truth shared with `fusion.elided_bytes`), and
    collective exposure (critical-path seconds) vs hidden (overlapped)
    time. Wired into `study.CaseResult` so a finished Study can answer
    "why did case A beat case B" without re-running anything.

Everything here uses *modeled* quantities (virtual time, analytic bytes);
only phase spans read the wall clock. The trace exporters live in
core/trace_export.py and consume Schedules/SimResults directly.
"""
from __future__ import annotations

import csv
import io
import json
import time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from .ir import FusedMatmulSpec, resource_of

__all__ = [
    "MetricsRegistry", "metrics", "AttrRow", "Attribution", "attribute",
    "combine", "layer_group",
]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared no-op context manager returned by phase() when spans are off
    (no allocation, no clock read)."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live wall-clock phase measurement."""
    __slots__ = ("_reg", "_name", "_t0")

    def __init__(self, reg: "MetricsRegistry", name: str) -> None:
        self._reg = reg
        self._name = name
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: object) -> bool:
        dt = time.perf_counter() - self._t0
        ph = self._reg._phases.get(self._name)
        if ph is None:
            self._reg._phases[self._name] = [1, dt]
        else:
            ph[0] += 1
            ph[1] += dt
        return False


@dataclass
class _Hist:
    """Streaming summary of an observed distribution (no sample storage)."""
    count: int = 0
    total: float = 0.0
    min: float = 0.0
    max: float = 0.0

    def observe(self, v: float) -> None:
        if self.count == 0:
            self.min = self.max = v
        else:
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
        self.count += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Process-wide named counters, gauges, histograms and phase spans.

    Counters are monotone and always on (`inc`); consumers that want a
    window (e.g. `mapper.MapperCacheStats`) snapshot a baseline and report
    deltas, so the registry itself is never reset mid-process. Phase spans
    (`phase`) are wall-clock and gated by `enabled` — off by default.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Hist] = {}
        self._phases: Dict[str, List[float]] = {}   # name -> [count, secs]
        self.enabled = False        # gates phase spans (wall-clock) only

    # -- counters ----------------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        self._counters[name] = self._counters.get(name, 0.0) + value

    def counter(self, name: str) -> float:
        return self._counters.get(name, 0.0)

    def counters(self, prefix: str = "") -> Dict[str, float]:
        return {k: v for k, v in sorted(self._counters.items())
                if k.startswith(prefix)}

    # -- gauges ------------------------------------------------------------
    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = value

    def gauge(self, name: str) -> float:
        return self._gauges.get(name, 0.0)

    # -- histograms --------------------------------------------------------
    def observe(self, name: str, value: float) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = _Hist()
        h.observe(value)

    def histogram(self, name: str) -> _Hist:
        return self._hists.get(name, _Hist())

    # -- phase spans (wall-clock; the only clock reads in the subsystem) ---
    def set_enabled(self, flag: bool) -> bool:
        """Turn phase spans on/off; returns the previous setting."""
        prev = self.enabled
        self.enabled = bool(flag)
        return prev

    def phase(self, name: str):
        """Context manager timing one framework phase. A shared no-op when
        spans are disabled — zero clock reads, zero allocation."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name)

    def phase_seconds(self) -> Dict[str, float]:
        return {k: v[1] for k, v in sorted(self._phases.items())}

    def phase_counts(self) -> Dict[str, int]:
        return {k: int(v[0]) for k, v in sorted(self._phases.items())}

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Flat {name: value} view of every counter, gauge and phase total
        (phases as `phase.<name>.seconds` / `.count`), for delta-taking."""
        out: Dict[str, float] = {}
        for k, v in sorted(self._counters.items()):
            out[k] = v
        for k, v in sorted(self._gauges.items()):
            out[f"gauge.{k}"] = v
        for k, cv in sorted(self._phases.items()):
            out[f"phase.{k}.count"] = cv[0]
            out[f"phase.{k}.seconds"] = cv[1]
        return out

    def merge_delta(self, delta: Dict[str, float]) -> None:
        """Fold a worker process's snapshot delta (`snapshot()` minus a
        baseline taken at worker start) into this registry: counters and
        phase spans ADD, gauges overwrite (last writer wins — gauges are
        point-in-time readings, not totals).

        This is what makes baseline-window views (`mapper.MapperCacheStats`)
        merge-safe across `Study.run(workers=N)` joins: worker activity is
        invisible to the parent's counters while the shard runs, then lands
        exactly once at join — a window constructed before the run reports
        the summed cross-process activity, never a torn intermediate state.
        """
        for k in sorted(delta):
            v = delta[k]
            if k.startswith("gauge."):
                self._gauges[k[len("gauge."):]] = v
            elif k.startswith("phase.") and k.endswith(".count"):
                ph = self._phases.setdefault(
                    k[len("phase."):-len(".count")], [0, 0.0])
                ph[0] += v
            elif k.startswith("phase.") and k.endswith(".seconds"):
                ph = self._phases.setdefault(
                    k[len("phase."):-len(".seconds")], [0, 0.0])
                ph[1] += v
            else:
                self.inc(k, v)

    def summary(self) -> str:
        parts = [f"{k}={v:g}" for k, v in sorted(self._counters.items())]
        parts += [f"phase.{k}={v[1]:.4f}s/{int(v[0])}"
                  for k, v in sorted(self._phases.items())]
        return " ".join(parts) if parts else "(empty)"


_REGISTRY = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-wide registry every subsystem reports into."""
    return _REGISTRY


# ---------------------------------------------------------------------------
# layer-group classification (attribution reports)
# ---------------------------------------------------------------------------

def layer_group(name: str) -> str:
    """Coarse layer-group bucket for an op name from graph.py's builder
    vocabulary: "attn" (token mixers: attention, recurrence, conv),
    "mlp" (channel mixers: FFN, MoE experts), "comm" (collectives and
    expert dispatch), "head" (embedding / final norm / lm head), "other".
    Attribution prefixes ("prefill/") and fused names ("qk_t+softmax")
    classify by their leading op."""
    base = name.rsplit("/", 1)[-1].split("+", 1)[0]
    for p in ("x_", "enc_"):
        if base.startswith(p):
            base = base[len(p):]
    if ("allreduce" in base or base.endswith(("_rs", "_ag"))
            or base in ("moe_dispatch", "moe_combine", "p2p")):
        return "comm"
    if base in ("embed", "ln_final") or base.startswith("lm_"):
        return "head"
    if base.startswith(("ln_mlp", "router", "expert", "moe", "w1", "w2",
                        "act", "gelu", "cmix")):
        return "mlp"
    if base.startswith(("ln_attn", "qkv", "qk", "rope", "kv", "softmax",
                        "a_mul_v", "o_proj", "tmix", "wkv", "rec", "rg_lru",
                        "conv1d", "gate", "attn")):
        return "attn"
    return "other"


# ---------------------------------------------------------------------------
# attribution records
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttrRow:
    """One op of an attributed graph (all times modeled/virtual seconds)."""
    name: str
    group: str              # layer_group bucket
    resource: str           # compute | vector | link
    bound: str              # compute | memory | overhead | link
    latency: float          # resource occupancy, x repeat
    flops: float
    bytes: float            # main-memory traffic, x repeat
    elided: float           # HBM bytes fusion removed (x repeat)
    repeat: int
    critical: bool          # on the schedule's critical path
    start: float            # schedule start (serial: running prefix sum)
    end: float              # consumer-visible end
    exposed: float          # critical-path seconds (serial: == latency)


@dataclass(frozen=True)
class Attribution:
    """Per-op/per-group attribution of one evaluated graph (or a labeled
    bundle of graphs, e.g. a generate case's prefill + decode sections)."""
    label: str
    total: float            # priced latency (makespan when scheduled)
    serial: float           # serial (no-overlap) sum
    rows: Tuple[AttrRow, ...]

    # -- aggregates --------------------------------------------------------
    @property
    def elided(self) -> float:
        """Total HBM bytes the fusion rewrites removed (fusion savings)."""
        return sum(r.elided for r in self.rows)

    @property
    def link_exposed(self) -> float:
        """Collective seconds the makespan actually waits on."""
        return sum(r.exposed for r in self.rows if r.resource == "link")

    @property
    def link_hidden(self) -> float:
        """Collective seconds overlapped behind compute/vector work."""
        return sum(max(0.0, r.latency - r.exposed) for r in self.rows
                   if r.resource == "link")

    def by_group(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for r in self.rows:
            g = out.setdefault(r.group, {"latency": 0.0, "flops": 0.0,
                                         "bytes": 0.0, "elided": 0.0})
            g["latency"] += r.latency
            g["flops"] += r.flops
            g["bytes"] += r.bytes
            g["elided"] += r.elided
        return out

    def by_bound(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for r in self.rows:
            out[r.bound] = out.get(r.bound, 0.0) + r.latency
        return out

    # -- structured output -------------------------------------------------
    def to_rows(self) -> List[dict]:
        return [{"name": r.name, "group": r.group, "resource": r.resource,
                 "bound": r.bound, "latency_s": r.latency, "flops": r.flops,
                 "bytes": r.bytes, "elided_bytes": r.elided,
                 "repeat": r.repeat, "critical": r.critical,
                 "start_s": r.start, "end_s": r.end, "exposed_s": r.exposed}
                for r in self.rows]

    def to_csv(self, path: Optional[str] = None) -> str:
        rows = self.to_rows()
        buf = io.StringIO()
        if rows:
            w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_json(self) -> str:
        return json.dumps(self.to_doc(), sort_keys=True,
                          separators=(",", ":"))

    # -- cache-doc round trip (study.py CaseResult layer) ------------------
    def to_doc(self) -> dict:
        return {"label": self.label, "total": self.total,
                "serial": self.serial,
                "rows": [[r.name, r.group, r.resource, r.bound, r.latency,
                          r.flops, r.bytes, r.elided, r.repeat,
                          int(r.critical), r.start, r.end, r.exposed]
                         for r in self.rows]}

    @classmethod
    def from_doc(cls, doc: dict) -> Optional["Attribution"]:
        try:
            rows = tuple(
                AttrRow(str(n), str(g), str(res), str(b), float(lat),
                        float(fl), float(by), float(el), int(rep),
                        bool(cr), float(st), float(en), float(ex))
                for n, g, res, b, lat, fl, by, el, rep, cr, st, en, ex
                in doc["rows"])
            return cls(str(doc["label"]), float(doc["total"]),
                       float(doc["serial"]), rows)
        except (KeyError, TypeError, ValueError):
            return None                 # malformed/older entry


def attribute(graph, cost, label: str = "", prefix: str = "") -> Attribution:
    """Build the Attribution for one evaluated graph.

    `cost` is the graph's `graph.LayerCost` (ops aligned 1:1 with
    graph.nodes, latencies already x repeat). When the cost carries an
    overlap schedule, start/end come from the per-resource timeline and
    `exposed` is each op's critical-path contribution; for a serially
    priced graph every op is "critical" and fully exposed, with start/end
    the left-to-right prefix sums. Elided bytes come from
    `FusedMatmulSpec.elided` — the same per-spec accounting
    `fusion.elided_bytes` sums, so the two surfaces cannot diverge."""
    sch = cost.schedule
    crit_idx = frozenset(sch.critical_path()) if sch is not None \
        else frozenset()
    crit_secs = sch.critical_breakdown() if sch is not None else {}
    rows = []
    t = 0.0
    for i, (node, op) in enumerate(zip(graph.nodes, cost.ops)):
        if sch is not None:
            slot = sch.slots[i]
            start, end = slot.start, slot.end
        else:
            start = t
            t = t + op.latency
            end = t
        spec = node.spec
        elided = node.repeat * spec.elided \
            if isinstance(spec, FusedMatmulSpec) else 0.0
        if sch is None:
            critical, exposed = True, op.latency
        else:
            critical = i in crit_idx
            exposed = min(op.latency, crit_secs.get(node.name, 0.0)) \
                if critical else 0.0
        rows.append(AttrRow(
            prefix + node.name, layer_group(node.name), resource_of(spec),
            op.bound, op.latency, op.flops, op.main_memory_bytes, elided,
            node.repeat, critical, start, end, exposed))
    return Attribution(label, cost.latency, cost.serial_latency, tuple(rows))


def combine(label: str, atts: Iterable[Attribution]) -> Attribution:
    """Concatenate several section Attributions (e.g. prefill + decode)
    into one labeled record; totals add across sections."""
    atts = list(atts)
    rows: Tuple[AttrRow, ...] = ()
    for a in atts:
        rows = rows + a.rows
    return Attribution(label, sum(a.total for a in atts),
                       sum(a.serial for a in atts), rows)
