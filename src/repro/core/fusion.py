"""Kernel-fusion pass over the dataflow IR (DESIGN.md §9).

Pattern-rewrite rules fold elementwise / norm / softmax consumers into their
producing matmul as fused epilogues — the analytical counterpart of what
kernels/matmul's fused dequant epilogue and kernels/flash_attention actually
emit. A fused epilogue's input read and the producer's output write are
elided (the tile stays in on-chip buffers); the epilogue contributes only
its vector-unit compute time, which the fused kernel pays after the GEMM
mainloop tile-by-tile. The flash rule goes one step further: a fused-softmax
result whose sole consumer is another matmul is streamed on-chip into that
GEMM's A operand (`bytes_a=0`), so the attention-score matrix never touches
HBM at all — flash-attention's defining property.

The pass is a pure Graph -> Graph rewrite: it never looks at a Device, so
fused graphs memoize exactly like built ones, and the evaluator's spec-level
cache dedups fused kernels across plans and KV depths. `fuse()` iterates the
rules to a fixpoint, so it is idempotent: fuse(fuse(g)) == fuse(g) (tested).

Honesty line: the flash rule's `bytes_a=0` removes the A stream from BOTH
the mapper's HBM-traffic terms (correct — the scores never leave the chip)
and its on-chip buffer-residency masks (optimistic — a real flash kernel
still stages one score subtile in SRAM while it streams). The error is one
subtile of residency, second-order next to the elided traffic; a dedicated
residency-only width on MatmulShape would remove it at the cost of an 11th
mapper axis.

`FusionPolicy` is the execution-model knob threaded through
inference_model / planner / simulator / study (a Study grid axis): which
fusion rules run, and whether evaluation prices the dataflow schedule
(comm/compute overlap, core/schedule.py) or the seed's serial sum. The
default SERIAL policy is the identity — bit-for-bit the seed numbers.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import List, Optional, Tuple

from .ir import (ElementwiseSpec, FusedMatmulSpec, Graph, MatmulSpec, Node,
                 NormSpec, OpSpec, SoftmaxSpec)
from .units import Bytes, BytesPerElement, Elements


@dataclass(frozen=True)
class FusionPolicy:
    """Execution-model point: fusion rules + schedule mode.

    fuse_epilogues — fold elementwise/norm/softmax consumers into their
        producing matmul (HBM round-trip of the intermediate elided);
    flash_stream  — stream a fused-softmax output straight into its consumer
        GEMM's A operand (flash-attention; requires fuse_epilogues);
    overlap       — price graphs with the resource-timeline list scheduler
        (comm/compute overlap) instead of the serial sum.
    """
    fuse_epilogues: bool = False
    flash_stream: bool = False
    overlap: bool = False

    def __post_init__(self):
        if self.flash_stream and not self.fuse_epilogues:
            raise ValueError("flash_stream streams a *fused* softmax into "
                             "the consumer GEMM; enable fuse_epilogues too")

    @property
    def fuses(self) -> bool:
        return self.fuse_epilogues or self.flash_stream


SERIAL = FusionPolicy()                                   # seed-exact
FUSED = FusionPolicy(fuse_epilogues=True, flash_stream=True)
OVERLAP = FusionPolicy(overlap=True)
FULL = FusionPolicy(fuse_epilogues=True, flash_stream=True, overlap=True)

_PRESET_TAGS = {SERIAL: "serial", FUSED: "fused", OVERLAP: "overlap",
                FULL: "fused+overlap"}


def fusion_tag(policy: FusionPolicy) -> str:
    """Row label for a policy: preset name or a structural tag."""
    tag = _PRESET_TAGS.get(policy)
    if tag is not None:
        return tag
    parts = [p for p, on in [("epi", policy.fuse_epilogues),
                             ("flash", policy.flash_stream),
                             ("overlap", policy.overlap)] if on]
    return "+".join(parts) if parts else "serial"


# ---------------------------------------------------------------------------
# pattern matching helpers
# ---------------------------------------------------------------------------

def _out_elems(spec: OpSpec) -> Optional[Elements]:
    """Elements the node's output tensor holds (None: not fusible over)."""
    if isinstance(spec, MatmulSpec):
        return float(spec.batch * spec.m * spec.n)
    if isinstance(spec, FusedMatmulSpec):
        return _out_elems(spec.epilogue[-1])
    if isinstance(spec, (SoftmaxSpec, NormSpec)):
        return float(spec.rows * spec.cols)
    if isinstance(spec, ElementwiseSpec):
        return float(spec.n_elements)
    return None


def _in_elems(spec: OpSpec) -> Optional[Elements]:
    """Elements the node reads from its (sole) producer tensor."""
    if isinstance(spec, (SoftmaxSpec, NormSpec)):
        return float(spec.rows * spec.cols)
    if isinstance(spec, ElementwiseSpec):
        n_in = 2 if spec.kind == "silu_mul" else spec.n_in
        return float(spec.n_elements * n_in)
    return None


def _out_write_bytes(spec: OpSpec) -> Bytes:
    """Bytes the epilogue's output tensor writes to main memory."""
    if isinstance(spec, (SoftmaxSpec, NormSpec)):
        return spec.rows * spec.cols * spec.bytes_out
    if isinstance(spec, ElementwiseSpec):
        return spec.n_elements * spec.bytes_elt
    raise TypeError(f"not an epilogue spec: {type(spec).__name__}")


def _epilogue_ok(spec: OpSpec) -> bool:
    return isinstance(spec, (SoftmaxSpec, NormSpec, ElementwiseSpec))


def _rescaled(gemm: MatmulSpec, out_bytes: Bytes) -> MatmulSpec:
    """The effective mapper shape once the kernel writes `out_bytes` instead
    of its own C tensor (byte widths are per-element multipliers, so the
    rescale is exact even for fractional widths)."""
    c_elems: Elements = gemm.batch * gemm.m * gemm.n
    width: BytesPerElement = out_bytes / c_elems if c_elems else 0.0
    return replace(gemm, bytes_out=width)


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------

def _fuse_once(nodes: List[Node], edges: List[Tuple[int, ...]],
               policy: FusionPolicy) -> bool:
    """Apply the first matching rewrite in graph order. Mutates `nodes` and
    `edges` in place (removed nodes become None); returns True if rewritten.
    """
    n = len(nodes)
    consumers: List[List[int]] = [[] for _ in range(n)]
    for j in range(n):
        if nodes[j] is None:
            continue
        for d in edges[j]:
            consumers[d].append(j)

    for i in range(n):
        node = nodes[i]
        if node is None:
            continue
        spec = node.spec

        # -- rule 1: matmul (+existing epilogue) absorbs its sole consumer --
        if isinstance(spec, (MatmulSpec, FusedMatmulSpec)) \
                and not (isinstance(spec, FusedMatmulSpec) and spec.stream_out):
            cons = consumers[i]
            if len(cons) == 1:
                j = cons[0]
                nj = nodes[j]
                if _epilogue_ok(nj.spec) and edges[j] == (i,) \
                        and nj.repeat == node.repeat \
                        and _in_elems(nj.spec) == _out_elems(spec):
                    gemm = spec.gemm if isinstance(spec, FusedMatmulSpec) \
                        else spec
                    epi = (spec.epilogue if isinstance(spec, FusedMatmulSpec)
                           else ()) + (nj.spec,)
                    # HBM bytes this absorption removes, per instance: the
                    # producer's current effective output write plus the
                    # epilogue's serial input read (its own output write
                    # becomes the fused kernel's write, so it cancels)
                    prev: Bytes = spec.elided \
                        if isinstance(spec, FusedMatmulSpec) else 0.0
                    saved: Bytes = (gemm.batch * gemm.m * gemm.n
                                    * gemm.bytes_out
                                    + _in_read_bytes(nj.spec))
                    fused = FusedMatmulSpec(
                        _rescaled(gemm, _out_write_bytes(nj.spec)), epi,
                        elided=prev + saved)
                    nodes[i] = Node(fused, f"{node.name}+{nj.name}",
                                    node.repeat, node.deps)
                    # rewire: j's consumers now read the fused node
                    for k in range(j + 1, n):
                        if nodes[k] is None:
                            continue
                        edges[k] = tuple(i if d == j else d
                                         for d in edges[k])
                    nodes[j] = None
                    return True

        # -- rule 2 (flash): fused softmax streamed into the consumer GEMM --
        if policy.flash_stream and isinstance(spec, FusedMatmulSpec) \
                and not spec.stream_out \
                and isinstance(spec.epilogue[-1], SoftmaxSpec):
            cons = consumers[i]
            if len(cons) == 1:
                j = cons[0]
                nj = nodes[j]
                mj = nj.spec
                if isinstance(mj, MatmulSpec) and nj.repeat == node.repeat \
                        and float(mj.batch * mj.m * mj.k) == _out_elems(spec):
                    # streaming removes the producer's remaining effective
                    # write AND the consumer GEMM's activation read
                    g0 = spec.gemm
                    streamed: Bytes = (g0.batch * g0.m * g0.n * g0.bytes_out
                                       + mj.batch * mj.m * mj.k * mj.bytes_a)
                    nodes[i] = Node(
                        FusedMatmulSpec(_rescaled(spec.gemm, 0.0),
                                        spec.epilogue, stream_out=True,
                                        elided=spec.elided + streamed),
                        node.name, node.repeat, node.deps)
                    nodes[j] = Node(replace(mj, bytes_a=0), nj.name,
                                    nj.repeat, nj.deps)
                    return True
    return False


@functools.lru_cache(maxsize=4096)
def fuse(graph: Graph, policy: FusionPolicy = SERIAL) -> Graph:
    """Rewrite `graph` under `policy`'s fusion rules (identity for SERIAL /
    OVERLAP). Deterministic, cached, idempotent: re-running on its own
    output finds no new patterns."""
    if not policy.fuses:
        return graph
    nodes: List[Optional[Node]] = list(graph.nodes)
    edges = graph.edges()
    while _fuse_once(nodes, edges, policy):
        pass
    # compact: drop removed nodes, remap all (now explicit) edges
    remap, kept = {}, []
    for i, nd in enumerate(nodes):
        if nd is not None:
            remap[i] = len(kept)
            kept.append((nd, edges[i]))
    return Graph(tuple(Node(nd.spec, nd.name, nd.repeat,
                            tuple(remap[d] for d in deps))
                       for nd, deps in kept))


def _in_read_bytes(spec: OpSpec) -> Bytes:
    """Bytes the epilogue op would read from main memory when not fused."""
    if isinstance(spec, (SoftmaxSpec, NormSpec)):
        return spec.rows * spec.cols * spec.bytes_in
    if isinstance(spec, ElementwiseSpec):
        n_in = 2 if spec.kind == "silu_mul" else spec.n_in
        return spec.n_elements * n_in * spec.bytes_elt
    raise TypeError(f"not an epilogue spec: {type(spec).__name__}")


def elided_bytes(graph: Graph, fused: Graph) -> Bytes:
    """Main-memory traffic the fusion rewrite removed, by spec accounting
    (producer output writes + epilogue input reads + streamed outputs).

    Each rewrite in `_fuse_once` now records its per-instance savings in
    `FusedMatmulSpec.elided`, so this is a straight sum over the fused
    graph — the identical numbers the attribution reports (core/obs.py)
    surface per op, with no second derivation that could drift. `graph` is
    kept in the signature for call-site symmetry (and so a non-fusing
    policy trivially reports 0). The evaluator's per-kernel totals remain
    the ground truth (the mapper may also re-tile the cheaper fused
    shape)."""
    del graph  # savings live on the fused specs themselves
    total: Bytes = 0.0
    for node in fused:
        s = node.spec
        if isinstance(s, FusedMatmulSpec):
            total += node.repeat * s.elided
    return total
