"""Precision subsystem: datatype-aware compute, memory, and area modeling.

The paper evaluates everything at fp16 (its Sec. III compute model prices a
"16-bit MAC" systolic array and all traffic at 2 bytes/element). Deployed
LLM serving leans hard on narrower datatypes — int8/fp8 weights, quantized
KV caches, int8 systolic datapaths — and the surveys this repo tracks
(2410.04466 Sec. IV, 2411.00136) show precision is the single biggest
lever after parallelism. This module makes precision a first-class axis:

  * ``DType`` — a frozen registry entry: byte width, MAC throughput relative
    to the fp16 datapath (paper Sec. III-B1: an int8 PE issues 2 MACs per
    fp16-MAC slot on the same array), and PE area relative to an fp16 MAC
    (area.py prices narrow datapaths with it).
  * ``PrecisionPolicy`` — a frozen value type assigning one DType per tensor
    class (weights / activations / KV cache / accumulator). Policies ride
    Study grids exactly like Plans and Workloads: hashable, taggable,
    cheap to enumerate.

Threading (DESIGN.md §8): graph.py builders stamp per-operand byte widths
and the compute-rate scale onto every OpSpec; the mapper prices A/B/C/
partial traffic at those widths and scales systolic cycles by ``mac_scale``;
inference_model's memory model and the planner/simulator capacity gates read
the policy instead of a hardwired ``bytes_per=2``.

The DEFAULT policy is fp16 everywhere — including the accumulator, because
the seed mapper staged C tiles and k-split partials at the 2-byte element
width. DEFAULT must reproduce the frozen seed numbers bit-for-bit
(tests/test_precision.py); honest int8/fp8 presets carry fp32 accumulators.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Union


@dataclass(frozen=True)
class DType:
    """One numeric format as the analytical stack sees it.

    mac_throughput: MACs per cycle relative to the fp16 datapath *on the
        same systolic array* (paper Sec. III-B1 compute model). Powers of
        two only — the mapper divides cycle counts by this exactly.
    mac_area_rel: area of one PE built natively for this format, relative
        to the calibrated fp16 MAC (area.MAC_AREA); multiplier area shrinks
        roughly quadratically with operand width.
    """
    name: str
    bits: int
    mac_throughput: float
    mac_area_rel: float

    @property
    def bytes(self) -> Union[int, float]:
        """Byte width; int when whole so the default mapper path stays on
        exact int64 arithmetic (int4 -> 0.5)."""
        return self.bits // 8 if self.bits % 8 == 0 else self.bits / 8

    def __str__(self) -> str:
        return self.name


FP32 = DType("fp32", 32, 0.5, 4.0)
BF16 = DType("bf16", 16, 1.0, 1.0)
FP16 = DType("fp16", 16, 1.0, 1.0)
FP8 = DType("fp8", 8, 2.0, 0.5)      # e4m3 storage; e5m2 prices the same
INT8 = DType("int8", 8, 2.0, 0.3)
INT4 = DType("int4", 4, 4.0, 0.1)

DTYPES: Dict[str, DType] = {d.name: d for d in
                            (FP32, BF16, FP16, FP8, INT8, INT4)}


def get_dtype(name: str) -> DType:
    try:
        return DTYPES[name]
    except KeyError:
        raise KeyError(f"unknown dtype '{name}'; have {sorted(DTYPES)}")


def mac_scale(a: DType, b: DType) -> float:
    """Compute-rate scale of a GEMM whose operands are a x b, relative to
    the fp16 datapath. Mixed-width GEMMs run at the slower operand's rate:
    int8 weights against fp16 activations dequantize into fp16 MACs (1.0);
    only an all-int8 (or all-fp8) GEMM earns the 2x issue rate."""
    return min(a.mac_throughput, b.mac_throughput)


@dataclass(frozen=True)
class PrecisionPolicy:
    """Datatype assignment per tensor class — one point on the precision axis.

    weights:     every parameter matrix (QKV/O, MLP, experts, embedding)
    activations: layer inputs/outputs, attention probabilities, collectives
    kv_cache:    the resident K/V tensors (attention B operands + capacity)
    accumulator: matmul partial sums staged in on-chip buffers (C tiles and
                 the scheme-2 k-split partials in the mapper)
    """
    weights: DType = FP16
    activations: DType = FP16
    kv_cache: DType = FP16
    accumulator: DType = FP16

    @property
    def tag(self) -> str:
        return (f"w{self.weights.name}_a{self.activations.name}"
                f"_kv{self.kv_cache.name}_acc{self.accumulator.name}")

    # -- spec kwargs for graph builders ------------------------------------
    def weight_gemm(self) -> Dict[str, Union[int, float]]:
        """MatmulSpec width kwargs for activation x weight GEMMs."""
        return dict(bytes_a=self.activations.bytes,
                    bytes_b=self.weights.bytes,
                    bytes_out=self.activations.bytes,
                    bytes_acc=self.accumulator.bytes,
                    mac_scale=mac_scale(self.activations, self.weights))

    def attn_gemm(self) -> Dict[str, Union[int, float]]:
        """MatmulSpec width kwargs for attention score/value GEMMs, whose B
        operand streams from the KV cache."""
        return dict(bytes_a=self.activations.bytes,
                    bytes_b=self.kv_cache.bytes,
                    bytes_out=self.activations.bytes,
                    bytes_acc=self.accumulator.bytes,
                    mac_scale=mac_scale(self.activations, self.kv_cache))

    def with_(self, **kw: DType) -> "PrecisionPolicy":
        """Named-field variant (`DEFAULT.with_(weights=INT8)`)."""
        return replace(self, **kw)


#: the seed model's implicit policy: 2 bytes everywhere, fp16 MAC rate.
DEFAULT = PrecisionPolicy()

#: named presets for Study grids / benchmarks (quantization design space).
#: Quantized presets accumulate in fp32 — matching the Pallas kernels, which
#: never accumulate narrower than fp32 (kernels/matmul).
POLICIES: Dict[str, PrecisionPolicy] = {
    "fp16": DEFAULT,
    "bf16": PrecisionPolicy(BF16, BF16, BF16, BF16),
    "int8-weights": PrecisionPolicy(weights=INT8, accumulator=FP32),
    "int8-kv": PrecisionPolicy(kv_cache=INT8, accumulator=FP32),
    "w8kv8": PrecisionPolicy(weights=INT8, kv_cache=INT8, accumulator=FP32),
    "w8a8": PrecisionPolicy(weights=INT8, activations=INT8, kv_cache=INT8,
                            accumulator=FP32),
    "fp8": PrecisionPolicy(FP8, FP8, FP8, FP32),
    "int4-weights": PrecisionPolicy(weights=INT4, accumulator=FP32),
}


def get_policy(name: str) -> PrecisionPolicy:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown precision policy '{name}'; "
                       f"have {sorted(POLICIES)}")


def policy_tag(policy: PrecisionPolicy) -> str:
    """Preset name when the policy is a registered preset, else the
    structural tag — the Study's `policy` result column."""
    for name, p in POLICIES.items():
        if p == policy:
            return name
    return policy.tag
