"""Evaluator: turn symbolic Graphs (ir.py) into latencies on a System.

One Evaluator owns one System and one result cache keyed by OpSpec. Because
specs are hashable values, any spec — a matmul shape, a softmax extent, a
collective volume — is evaluated at most once per Evaluator lifetime, no
matter how many plans, KV depths, or repeated layers reference it. Share one
Evaluator across a whole planner sweep and plan #2 onward pays only for
shapes it has not seen (DESIGN.md §3).

Matmuls additionally batch: `evaluate_many` first collects every unique
un-cached MatmulSpec across all requested graphs and solves them in one
stacked mapper search (mapper.matmul_perf_batch) before assembling per-graph
results. The decode-KV trapezoid sweep and a multi-plan ranking both become
a single batched search this way.

Numbers are bit-for-bit identical to the seed eager path: each spec kind
dispatches to the same operators.py / interconnect.py model the eager code
called, and node repeat counts multiply results exactly the way the seed
model_ops multiplied per-op costs by the layer count.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Dict, List, Mapping, Sequence

from .hardware import Device, Link, System
from . import operators as ops
from . import interconnect as net
from .ir import (CollectiveSpec, ElementwiseSpec, FusedMatmulSpec, Graph,
                 MatmulSpec, NormSpec, OpSpec, ScanSpec, SoftmaxSpec,
                 TrafficSpec, resource_of)
from .mapper import matmul_cache_stats, matmul_perf_batch
from .obs import metrics
from .schedule import schedule_graph
from . import verify as verify_mod


@dataclass
class EvalStats:
    """Cache / search statistics for one Evaluator (reported by benchmarks)."""
    graphs: int = 0
    nodes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    matmul_searches: int = 0         # unique GEMM shapes actually searched
    batched_searches: int = 0        # matmul_perf_batch invocations
    candidates_searched: int = 0     # dense-equivalent candidate count
    serial_seconds: float = 0.0      # serial sum of overlap-scheduled graphs
    scheduled_seconds: float = 0.0   # their resource-timeline makespans
    # mapper memo deltas attributable to this evaluator (ISSUE 6): shapes
    # served by the in-memory LRU / the persistent disk layer instead of a
    # search, and LRU entries evicted while it ran
    mapper_memo_hits: int = 0
    mapper_disk_hits: int = 0
    mapper_evictions: int = 0
    # Study result-cache outcomes attributed to this evaluator: cases whose
    # CaseResult was served from the persistent case cache vs re-evaluated
    # (study.Study.run fills these in)
    case_hits: int = 0
    case_misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @property
    def schedule_ratio(self) -> float:
        """Scheduled-vs-serial latency ratio across all overlap-mode graphs
        (< 1 means overlap hid work; 1.0 when nothing was scheduled). A
        regression in overlap modeling shows up here in bench logs."""
        return self.scheduled_seconds / self.serial_seconds \
            if self.serial_seconds > 0 else 1.0

    def to_doc(self) -> Dict[str, float]:
        """Plain-dict snapshot of every field — the pickle-friendly form a
        Study worker ships its shard's stats home in."""
        return {f.name: getattr(self, f.name) for f in fields(self)}

    def merge(self, doc: Mapping[str, float]) -> None:
        """Accumulate a worker shard's `to_doc()` snapshot into this
        instance (field-wise addition; unknown keys are ignored so docs
        from newer/older workers degrade gracefully)."""
        for f in fields(self):
            v = doc.get(f.name)
            if v:
                setattr(self, f.name, getattr(self, f.name) + v)

    def summary(self) -> str:
        return (f"graphs={self.graphs} nodes={self.nodes} "
                f"hits={self.cache_hits} misses={self.cache_misses} "
                f"hit_rate={self.hit_rate:.1%} "
                f"matmul_searches={self.matmul_searches} "
                f"batched_calls={self.batched_searches} "
                f"candidates={self.candidates_searched} "
                f"mapper_memo_hits={self.mapper_memo_hits} "
                f"mapper_disk_hits={self.mapper_disk_hits} "
                f"mapper_evictions={self.mapper_evictions} "
                f"case_hits={self.case_hits} "
                f"case_misses={self.case_misses} "
                f"sched_vs_serial={self.schedule_ratio:.3f}")


def _single_device_system(device: Device) -> System:
    return System(device=device, device_count=1, link=Link(1e9))


class Evaluator:
    """Evaluate IR graphs on one System, deduplicating and batching work."""

    def __init__(self, system: System, batch_matmuls: bool = True,
                 use_reference_mapper: bool = False,
                 verify: str | None = None) -> None:
        self._device_only = isinstance(system, Device)
        if self._device_only:   # device-only use: no real link parameters
            system = _single_device_system(system)
        self.system = system
        self.device = system.device
        self.batch_matmuls = batch_matmuls
        # seed-replica mode for before/after benchmarking: per-shape dense
        # search (mapper.matmul_perf_reference), no batching, no global memo
        self.use_reference_mapper = use_reference_mapper
        if use_reference_mapper:
            self.batch_matmuls = False
        # static verification mode (ISSUE 7): "error" | "warn" | "off",
        # defaulting to $REPRO_VERIFY else "warn". Graphs are linted once
        # each (they are frozen/hashable) before any mapper work; overlap
        # schedules are certificate-checked after scheduling.
        self.verify_mode = verify_mod.resolve_mode(verify)
        self._verified: set[Graph] = set()
        self._cache: Dict[OpSpec, ops.OpResult] = {}
        self.stats = EvalStats()

    # ------------------------------------------------------------------
    def _mapper_call(self, shapes):
        """matmul_perf_batch with the global memo's hit/eviction deltas
        attributed to this evaluator's stats (ISSUE 6)."""
        ms = matmul_cache_stats()
        memo0, disk0, evict0 = ms.memo_hits, ms.disk_hits, ms.evictions
        results = matmul_perf_batch(self.device, shapes)
        self.stats.mapper_memo_hits += ms.memo_hits - memo0
        self.stats.mapper_disk_hits += ms.disk_hits - disk0
        self.stats.mapper_evictions += ms.evictions - evict0
        return results

    def _eval_spec(self, spec: OpSpec) -> ops.OpResult:
        """Evaluate one spec eagerly through the operator models."""
        dev = self.device
        if isinstance(spec, MatmulSpec):
            self.stats.matmul_searches += 1
            if self.use_reference_mapper:
                from .mapper import matmul_perf_reference
                r = matmul_perf_reference(dev, spec.m, spec.k, spec.n,
                                          spec.batch, spec.bytes_a,
                                          spec.bytes_b, spec.bytes_out,
                                          spec.bytes_acc, spec.b_shared,
                                          spec.mac_scale)
            else:
                self.stats.batched_searches += 1
                r = self._mapper_call([spec.shape])[0]
            self.stats.candidates_searched += r.candidates_searched
            return ops.OpResult("matmul", r.latency
                                + dev.kernel_launch_overhead_s, r.flops,
                                r.main_memory_bytes, r.mapping.bound,
                                r.mapping)
        if isinstance(spec, FusedMatmulSpec):
            # one kernel: the GEMM (mapper-priced at its rescaled output
            # traffic) plus tile-local vector epilogues — no per-epilogue
            # launch overhead, no intermediate HBM round trip
            r_mm = self._lookup(spec.gemm)
            lat, flops = r_mm.latency, r_mm.flops
            for e in spec.epilogue:
                t, f = ops.fused_epilogue(dev, e)
                lat += t
                flops += f
            return ops.OpResult("fused_matmul", lat, flops,
                                r_mm.main_memory_bytes, r_mm.bound,
                                r_mm.mapping)
        if isinstance(spec, SoftmaxSpec):
            return ops.softmax(dev, spec.rows, spec.cols, spec.bytes_in,
                               spec.bytes_out)
        if isinstance(spec, NormSpec):
            fn = ops.layernorm if spec.kind == "layernorm" else ops.rmsnorm
            return fn(dev, spec.rows, spec.cols, spec.bytes_in, spec.bytes_out)
        if isinstance(spec, ElementwiseSpec):
            if spec.kind == "gelu":
                return ops.gelu(dev, spec.n_elements, spec.bytes_elt,
                                spec.bytes_elt)
            if spec.kind == "silu_mul":
                return ops.silu_mul(dev, spec.n_elements, spec.bytes_elt,
                                    spec.bytes_elt)
            return ops.elementwise(dev, spec.n_elements, spec.flops_per_elt,
                                   spec.n_in, spec.bytes_elt)
        if isinstance(spec, ScanSpec):
            return ops.recurrent_scan(dev, spec.seq, spec.batch, spec.d_state,
                                      spec.flops_per_step, spec.bytes_io,
                                      spec.chunk)
        if isinstance(spec, CollectiveSpec):
            if self._device_only:
                raise ValueError(
                    "this Evaluator was built from a bare Device and has no "
                    "link model; construct it with a System to price "
                    f"collectives (got {spec.kind})")
            n = spec.n_devices or self.system.device_count
            if spec.kind == "all_reduce":
                # reduction vector work priced at the payload's element width
                return net.all_reduce(self.system, spec.n_bytes, n,
                                      bytes_elt=spec.bytes_elt)
            if spec.kind == "reduce_scatter":
                return net.reduce_scatter(self.system, spec.n_bytes, n,
                                          bytes_elt=spec.bytes_elt)
            fn = {"all_gather": net.all_gather,
                  "all_to_all": net.all_to_all}.get(spec.kind)
            if fn is not None:
                return fn(self.system, spec.n_bytes, n)
            if spec.kind == "p2p":
                return net.p2p(self.system, spec.n_bytes)
            raise ValueError(f"unknown collective kind {spec.kind!r}")
        if isinstance(spec, TrafficSpec):
            return ops.memory_traffic(dev, spec.n_bytes)
        raise TypeError(f"cannot evaluate spec of type {type(spec).__name__}")

    def _lookup(self, spec: OpSpec) -> ops.OpResult:
        r = self._cache.get(spec)
        if r is None:
            self.stats.cache_misses += 1
            r = self._eval_spec(spec)
            self._cache[spec] = r
        else:
            self.stats.cache_hits += 1
        return r

    def _prefetch_matmuls(self, graphs: Sequence[Graph]) -> set:
        """Solve every un-cached unique MatmulSpec in one stacked search.
        Returns the set of specs filled in (already counted as misses)."""
        pending: List[MatmulSpec] = []
        seen = set()
        for g in graphs:
            for node in g:
                s = node.spec
                if isinstance(s, FusedMatmulSpec):
                    s = s.gemm            # the stacked search solves the base
                if isinstance(s, MatmulSpec) and s not in self._cache \
                        and s not in seen:
                    seen.add(s)
                    pending.append(s)
        if not pending:
            return seen
        dev = self.device
        results = self._mapper_call([s.shape for s in pending])
        self.stats.batched_searches += 1
        for s, r in zip(pending, results):
            self.stats.matmul_searches += 1
            self.stats.candidates_searched += r.candidates_searched
            self.stats.cache_misses += 1
            self._cache[s] = ops.OpResult(
                "matmul", r.latency + dev.kernel_launch_overhead_s, r.flops,
                r.main_memory_bytes, r.mapping.bound, r.mapping)
        return seen

    # ------------------------------------------------------------------
    def evaluate(self, graph: Graph, overlap: bool = False) -> "LayerCost":
        return self.evaluate_many([graph], overlap=overlap)[0]

    def evaluate_many(self, graphs: Sequence[Graph],
                      overlap: bool = False) -> List["LayerCost"]:
        """Evaluate several graphs; unique matmuls across ALL of them are
        solved in one batched mapper search first.

        With `overlap=True` each graph is additionally list-scheduled over
        per-resource timelines (core/schedule.py): the returned LayerCost's
        `latency` is the dataflow makespan (collectives pipelined with their
        producers) instead of the serial sum, and carries the per-op
        start/end schedule."""
        from .graph import LayerCost      # late import: graph builds on ir
        reg = metrics()
        if self.verify_mode != "off":
            with reg.phase("verify"):
                for g in graphs:
                    if g not in self._verified:
                        verify_mod.verify_graph(g, self.device,
                                                mode=self.verify_mode)
                        self._verified.add(g)
        with reg.phase("search"):
            prefetched = self._prefetch_matmuls(graphs) \
                if self.batch_matmuls else set()
        out = []
        for g in graphs:
            self.stats.graphs += 1
            cost = LayerCost()
            for node in g:
                self.stats.nodes += 1
                if node.spec in prefetched:
                    prefetched.discard(node.spec)   # first use = the miss
                    r = self._cache[node.spec]
                else:
                    r = self._lookup(node.spec)
                cost.add(ops.OpResult(
                    node.name, r.latency * node.repeat,
                    r.flops * node.repeat,
                    r.main_memory_bytes * node.repeat, r.bound, r.mapping))
            cost._resources = tuple(resource_of(n.spec) for n in g)
            if overlap:
                lats = [o.latency for o in cost.ops]
                with reg.phase("schedule"):
                    sch = schedule_graph(g, lats)
                if self.verify_mode != "off":
                    # certificate check: the schedule really is a feasible
                    # witness of its claimed makespan (ISSUE 7)
                    with reg.phase("verify"):
                        verify_mod.verify_schedule(g, lats, sch,
                                                   mode=self.verify_mode)
                cost.schedule = sch
                self.stats.serial_seconds += sch.serial
                self.stats.scheduled_seconds += sch.makespan
            out.append(cost)
        reg.inc("evaluator.graphs", len(graphs))
        return out
