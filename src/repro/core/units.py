"""Unit vocabulary for the pricing core (ISSUE 8, DESIGN.md §12).

Every quantity the cost model prices — seconds, cycles, bytes, elements,
flops, die mm², dollars, watts — gets a zero-runtime-cost type alias:

    Seconds = Annotated[float, Unit(s=1)]

The ``Unit`` metadata is a dimension vector over the base dimensions below,
with the obvious algebra (exponents add under ``*``, subtract under ``/``):

    Bytes / BytesPerSecond  -> Seconds
    Cycles / Hertz          -> Seconds          (Hertz is cycles/second)
    Elements * BytesPerElement -> Bytes
    Seconds + Bytes         -> dimension error  (caught by core/unitcheck.py)

Annotations are erased at runtime (``Annotated[float, ...]`` IS ``float`` to
the interpreter and to dataclasses), so annotating the pricing core changes
no numbers — the fp16 default path stays bit-for-bit against
``tests/data/seed_reference.json``. The static pass in core/unitcheck.py
reads these aliases from signatures, dataclass fields and ``x: Unit`` local
declarations and propagates them through arithmetic; anything unannotated is
``ANY`` and never produces a diagnostic (gradual typing: the checker proves
exactly what is annotated).

Conventions (how to annotate new pricing code):
  * totals are ``Bytes`` / ``Flops`` / ``Elements``; *per-element* widths and
    rates are ``BytesPerElement`` / ``FlopsPerElement`` (so ``n * bytes_elt``
    is provably ``Bytes`` only when ``n`` is ``Elements``);
  * tensor extents (m, k, n, rows, cols, batch) stay plain ``int`` — their
    products become ``Elements`` at an annotated local, e.g.
    ``n: Elements = rows * cols``;
  * frequencies are ``Hertz`` (cycles/second): dividing a cycle count by a
    frequency, or a byte count by a bandwidth, provably yields Seconds.
"""
from __future__ import annotations

from typing import Annotated, Dict, Tuple

#: base dimensions, canonical order (time, clock ticks, information,
#: tensor elements, float operations, die area, money, power)
DIMENSIONS = ("s", "cycle", "byte", "elt", "flop", "mm2", "usd", "watt")


class Unit:
    """An immutable dimension vector: ``Unit(byte=1, s=-1)`` is bytes/second.

    Supports ``*``, ``/`` and integer ``**`` (exponents add / subtract /
    scale). Equality and hashing are structural, so Units are usable as dict
    keys and inside ``Annotated`` metadata.
    """

    __slots__ = ("dims",)

    dims: Tuple[Tuple[str, int], ...]

    def __init__(self, **exponents: int) -> None:
        bad = set(exponents) - set(DIMENSIONS)
        if bad:
            raise ValueError(f"unknown dimension(s) {sorted(bad)}; "
                             f"have {DIMENSIONS}")
        object.__setattr__(self, "dims", tuple(
            (d, int(e)) for d, e in sorted(exponents.items()) if e))

    @classmethod
    def _from_dims(cls, dims: Dict[str, int]) -> "Unit":
        u = object.__new__(cls)
        object.__setattr__(u, "dims", tuple(
            (d, e) for d, e in sorted(dims.items()) if e))
        return u

    def __setattr__(self, name: str, value: object) -> None:
        raise AttributeError("Unit is immutable")

    def exponent(self, dim: str) -> int:
        return dict(self.dims).get(dim, 0)

    # ---- algebra ---------------------------------------------------------
    def __mul__(self, other: "Unit") -> "Unit":
        if not isinstance(other, Unit):
            raise TypeError(f"cannot multiply Unit by {type(other).__name__}")
        out = dict(self.dims)
        for d, e in other.dims:
            out[d] = out.get(d, 0) + e
        return Unit._from_dims(out)

    def __truediv__(self, other: "Unit") -> "Unit":
        if not isinstance(other, Unit):
            raise TypeError(f"cannot divide Unit by {type(other).__name__}")
        out = dict(self.dims)
        for d, e in other.dims:
            out[d] = out.get(d, 0) - e
        return Unit._from_dims(out)

    def __pow__(self, k: int) -> "Unit":
        if not isinstance(k, int):
            raise TypeError("Unit exponents are integers")
        return Unit._from_dims({d: e * k for d, e in self.dims})

    # ---- identity --------------------------------------------------------
    def __eq__(self, other: object) -> bool:
        return isinstance(other, Unit) and self.dims == other.dims

    def __hash__(self) -> int:
        return hash(self.dims)

    @property
    def dimensionless(self) -> bool:
        return not self.dims

    @property
    def symbol(self) -> str:
        """Human-readable form: ``B·s^-1``, ``1`` for dimensionless."""
        if not self.dims:
            return "1"
        sym = {"s": "s", "cycle": "cyc", "byte": "B", "elt": "elt",
               "flop": "flop", "mm2": "mm2", "usd": "$", "watt": "W"}
        return "·".join(f"{sym[d]}" + (f"^{e}" if e != 1 else "")
                        for d, e in self.dims)

    def __repr__(self) -> str:
        return f"Unit({self.symbol})"


DIMENSIONLESS = Unit()

# ---------------------------------------------------------------------------
# the vocabulary: zero-runtime-cost aliases (Annotated[float, Unit(...)])
# ---------------------------------------------------------------------------

Ratio = Annotated[float, Unit()]        # provably-dimensionless fractions
Seconds = Annotated[float, Unit(s=1)]
Cycles = Annotated[float, Unit(cycle=1)]
Bytes = Annotated[float, Unit(byte=1)]
Elements = Annotated[float, Unit(elt=1)]
Flops = Annotated[float, Unit(flop=1)]
Mm2 = Annotated[float, Unit(mm2=1)]
Dollars = Annotated[float, Unit(usd=1)]
Watts = Annotated[float, Unit(watt=1)]

Hertz = Annotated[float, Unit(cycle=1, s=-1)]           # cycles / second
PerSecond = Annotated[float, Unit(s=-1)]                # rates (tokens/s)
BytesPerSecond = Annotated[float, Unit(byte=1, s=-1)]
FlopsPerSecond = Annotated[float, Unit(flop=1, s=-1)]
BytesPerCycle = Annotated[float, Unit(byte=1, cycle=-1)]
FlopsPerCycle = Annotated[float, Unit(flop=1, cycle=-1)]
BytesPerElement = Annotated[float, Unit(byte=1, elt=-1)]
FlopsPerElement = Annotated[float, Unit(flop=1, elt=-1)]

#: alias-name -> Unit registry read by the static checker to resolve
#: annotations in source (``x: Seconds``, ``def f() -> Bytes``, field decls)
ALIASES: Dict[str, Unit] = {
    "Ratio": Unit(),
    "Seconds": Unit(s=1),
    "Cycles": Unit(cycle=1),
    "Bytes": Unit(byte=1),
    "Elements": Unit(elt=1),
    "Flops": Unit(flop=1),
    "Mm2": Unit(mm2=1),
    "Dollars": Unit(usd=1),
    "Watts": Unit(watt=1),
    "Hertz": Unit(cycle=1, s=-1),
    "PerSecond": Unit(s=-1),
    "BytesPerSecond": Unit(byte=1, s=-1),
    "FlopsPerSecond": Unit(flop=1, s=-1),
    "BytesPerCycle": Unit(byte=1, cycle=-1),
    "FlopsPerCycle": Unit(flop=1, cycle=-1),
    "BytesPerElement": Unit(byte=1, elt=-1),
    "FlopsPerElement": Unit(flop=1, elt=-1),
}


def unit_of(alias: object) -> Unit:
    """The Unit metadata of an ``Annotated[float, Unit(...)]`` alias."""
    meta = getattr(alias, "__metadata__", ())
    for m in meta:
        if isinstance(m, Unit):
            return m
    raise TypeError(f"{alias!r} carries no Unit metadata")
