"""Workload value type: the "what are we serving" axis of a Study (ISSUE 2).

A Workload is a frozen, hashable description of one inference traffic shape:
`batch` concurrent requests of `in_len` prompt tokens generating `out_len`
output tokens, with the decode-KV trapezoid integrated over `samples` points
(inference_model.generate). Because it is a value type it can key dicts,
deduplicate across grids, and live inside a frozen study.Case.

Presets cover the paper's six in/out evaluation shapes (Table IV / Fig. 10:
256/256, 512/1024, 1024/1024, 2048/256, 256/2048, 2048/2048 at batch 16)
and our serving shapes (DESIGN.md §5 assignment table analogues).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Tuple


@dataclass(frozen=True)
class Workload:
    """One inference traffic shape: batch x (in_len -> out_len)."""
    batch: int
    in_len: int
    out_len: int
    samples: int = 8        # decode-KV trapezoid sample points in generate()

    @property
    def total_len(self) -> int:
        """Maximum resident context: prompt + every generated token."""
        return self.in_len + self.out_len

    @property
    def tokens_in(self) -> int:
        return self.batch * self.in_len

    @property
    def tokens_out(self) -> int:
        return self.batch * self.out_len

    @property
    def tag(self) -> str:
        return f"b{self.batch}_in{self.in_len}_out{self.out_len}"

    def with_batch(self, batch: int) -> "Workload":
        return replace(self, batch=batch)


# The paper's six (in_len, out_len) evaluation shapes, in Fig. 10 order.
PAPER_SHAPES: Tuple[Tuple[int, int], ...] = (
    (256, 256), (512, 1024), (1024, 1024),
    (2048, 256), (256, 2048), (2048, 2048))


def paper_workloads(batch: int = 16, samples: int = 8) -> Dict[str, Workload]:
    """The paper's six in/out shapes as named Workloads (Fig. 10: batch 16)."""
    return {f"in{i}_out{o}": Workload(batch, i, o, samples)
            for i, o in PAPER_SHAPES}


# Our serving shapes: the traffic classes the launch/ stack plans for.
SERVING_WORKLOADS: Dict[str, Workload] = {
    "serve-chat": Workload(8, 2048, 256),          # planner probe workload
    "serve-chat-batch64": Workload(64, 2048, 256),  # throughput-heavy chat
    "serve-prefill-32k": Workload(32, 32768, 1),    # prefill_32k shape
    "serve-decode-32k": Workload(16, 32768, 1024),  # decode_32k shape
}

WORKLOADS: Dict[str, Workload] = {
    **{f"paper-{k}": v for k, v in paper_workloads().items()},
    **SERVING_WORKLOADS,
}


def get_workload(name: str) -> Workload:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise KeyError(
            f"unknown workload preset '{name}'; have {sorted(WORKLOADS)}")
