"""Workload value types: the "what are we serving" axis of a Study.

A Workload is a frozen, hashable description of one inference traffic shape:
`batch` concurrent requests of `in_len` prompt tokens generating `out_len`
output tokens, with the decode-KV trapezoid integrated over `samples` points
(inference_model.generate). Because it is a value type it can key dicts,
deduplicate across grids, and live inside a frozen study.Case.

ISSUE 3 adds request-level traffic: a `Trace` is a fixed sequence of timed
requests (Poisson/gamma arrivals or an explicit list, each with its own
in/out lengths), and a `TrafficWorkload` wraps a Trace plus an engine shape
(slot count, batching policy) so a Study grid can sweep systems x schedulers
x traces through `core/simulator.py` (stage="serve").

Presets cover the paper's six in/out evaluation shapes (Table IV / Fig. 10:
256/256, 512/1024, 1024/1024, 2048/256, 256/2048, 2048/2048 at batch 16)
and our serving shapes (DESIGN.md §5 assignment table analogues).

ISSUE 4 adds the precision axis: `PRECISION_POLICIES` re-exports the named
quantization points (core/precision.py) so a grid declares
``Study(..., workloads=WORKLOADS, policies=PRECISION_POLICIES)`` and one
stacked mapper search prices systems x plans x workloads x policies.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Sequence, Tuple, Union

import numpy as np

from .precision import POLICIES as PRECISION_POLICIES  # noqa: F401  (axis
#   preset re-export: workload.py is the "grid axes" module users import)


@dataclass(frozen=True)
class Workload:
    """One inference traffic shape: batch x (in_len -> out_len)."""
    batch: int
    in_len: int
    out_len: int
    samples: int = 8        # decode-KV trapezoid sample points in generate()

    @property
    def total_len(self) -> int:
        """Maximum resident context: prompt + every generated token."""
        return self.in_len + self.out_len

    @property
    def tokens_in(self) -> int:
        return self.batch * self.in_len

    @property
    def tokens_out(self) -> int:
        return self.batch * self.out_len

    @property
    def tag(self) -> str:
        return f"b{self.batch}_in{self.in_len}_out{self.out_len}"

    def with_batch(self, batch: int) -> "Workload":
        return replace(self, batch=batch)


# ---------------------------------------------------------------------------
# request-level traffic (ISSUE 3): traces + the serve-stage Study axis
# ---------------------------------------------------------------------------

#: length spec for synthetic traces: a fixed int or an inclusive (lo, hi)
#: range sampled uniformly per request
LenSpec = Union[int, Tuple[int, int]]


def _sample_len(spec: LenSpec, rng: np.random.Generator) -> int:
    if isinstance(spec, tuple):
        lo, hi = spec
        return int(rng.integers(lo, hi + 1))
    return int(spec)


@dataclass(frozen=True)
class TraceRequest:
    """One timed request: arrives at `arrival` seconds, brings `in_len`
    prompt tokens, and generates exactly `out_len` output tokens."""
    arrival: float
    in_len: int
    out_len: int


@dataclass(frozen=True)
class Trace:
    """A fixed, replayable request sequence (sorted by arrival time)."""
    requests: Tuple[TraceRequest, ...]
    tag: str = ""

    def __post_init__(self):
        arr = [r.arrival for r in self.requests]
        if arr != sorted(arr):
            object.__setattr__(
                self, "requests",
                tuple(sorted(self.requests, key=lambda r: r.arrival)))

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    @property
    def max_in_len(self) -> int:
        return max((r.in_len for r in self.requests), default=1)

    @property
    def max_total_len(self) -> int:
        return max((r.in_len + r.out_len for r in self.requests), default=1)

    @property
    def tokens_out(self) -> int:
        return sum(r.out_len for r in self.requests)

    # -- constructors ------------------------------------------------------
    @classmethod
    def explicit(cls, requests: Sequence[Tuple[float, int, int]],
                 tag: str = "explicit") -> "Trace":
        return cls(tuple(TraceRequest(*r) for r in requests), tag=tag)

    @classmethod
    def constant(cls, n: int, interval: float, in_len: LenSpec,
                 out_len: LenSpec, seed: int = 0) -> "Trace":
        """Deterministic arrivals every `interval` seconds (interval=0:
        one batch at t=0). Lengths may still be sampled ranges."""
        rng = np.random.default_rng(seed)
        reqs = tuple(TraceRequest(i * interval, _sample_len(in_len, rng),
                                  _sample_len(out_len, rng))
                     for i in range(n))
        return cls(reqs, tag=f"const_n{n}_iv{interval:g}")

    @classmethod
    def poisson(cls, n: int, rate: float, in_len: LenSpec, out_len: LenSpec,
                seed: int = 0) -> "Trace":
        """Poisson arrivals at `rate` requests/second."""
        if rate <= 0:
            raise ValueError("arrival rate must be > 0")
        rng = np.random.default_rng(seed)
        t = np.cumsum(rng.exponential(1.0 / rate, size=n))
        reqs = tuple(TraceRequest(float(t[i]), _sample_len(in_len, rng),
                                  _sample_len(out_len, rng))
                     for i in range(n))
        return cls(reqs, tag=f"poisson_n{n}_r{rate:g}")

    @classmethod
    def gamma(cls, n: int, rate: float, cv: float, in_len: LenSpec,
              out_len: LenSpec, seed: int = 0) -> "Trace":
        """Gamma inter-arrivals: mean 1/rate, coefficient of variation `cv`
        (cv=1 reduces to Poisson; cv>1 is burstier than Poisson; for
        deterministic cv=0 arrivals use Trace.constant)."""
        if rate <= 0:
            raise ValueError("arrival rate must be > 0")
        if cv <= 0:
            raise ValueError("cv must be > 0 (use Trace.constant for "
                             "deterministic arrivals)")
        rng = np.random.default_rng(seed)
        shape = 1.0 / (cv * cv)
        scale = cv * cv / rate
        t = np.cumsum(rng.gamma(shape, scale, size=n))
        reqs = tuple(TraceRequest(float(t[i]), _sample_len(in_len, rng),
                                  _sample_len(out_len, rng))
                     for i in range(n))
        return cls(reqs, tag=f"gamma_n{n}_r{rate:g}_cv{cv:g}")


@dataclass(frozen=True)
class TrafficWorkload(Workload):
    """A Trace served by an engine of `batch` slots under `policy`.

    Subclasses Workload so it slots into the existing Study axes: `batch` is
    the engine slot count and `in_len`/`out_len` are the trace maxima, which
    makes the planner memory-fit pre-pass (`total_len` = worst resident
    context) work unchanged. Use with stage="serve".
    """
    trace: Trace = field(default_factory=lambda: Trace(()))
    policy: str = "continuous"          # scheduler.POLICIES
    kv_samples: int = 8                 # decode-KV interpolation points
    seq_samples: int = 8                # prefill-length interpolation points

    def __post_init__(self):
        from .scheduler import POLICIES     # leaf module, no cycle
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"have {POLICIES}")
        if self.kv_samples < 2 or self.seq_samples < 2:
            # a single sample point would price every round at the axis
            # minimum — grossly wrong whenever the trace spans a range
            raise ValueError("kv_samples and seq_samples must be >= 2")

    @classmethod
    def from_trace(cls, trace: Trace, slots: int,
                   policy: str = "continuous", kv_samples: int = 8,
                   seq_samples: int = 8) -> "TrafficWorkload":
        if not len(trace):
            raise ValueError("trace has no requests")
        return cls(batch=slots, in_len=trace.max_in_len,
                   out_len=max(r.out_len for r in trace),
                   trace=trace, policy=policy, kv_samples=kv_samples,
                   seq_samples=seq_samples)

    @property
    def total_len(self) -> int:
        """Worst-case resident context of any single request."""
        return self.trace.max_total_len if len(self.trace) \
            else super().total_len

    @property
    def tag(self) -> str:
        return f"b{self.batch}_{self.policy}_{self.trace.tag}"


# The paper's six (in_len, out_len) evaluation shapes, in Fig. 10 order.
PAPER_SHAPES: Tuple[Tuple[int, int], ...] = (
    (256, 256), (512, 1024), (1024, 1024),
    (2048, 256), (256, 2048), (2048, 2048))


def paper_workloads(batch: int = 16, samples: int = 8) -> Dict[str, Workload]:
    """The paper's six in/out shapes as named Workloads (Fig. 10: batch 16)."""
    return {f"in{i}_out{o}": Workload(batch, i, o, samples)
            for i, o in PAPER_SHAPES}


# Our serving shapes: the traffic classes the launch/ stack plans for.
SERVING_WORKLOADS: Dict[str, Workload] = {
    "serve-chat": Workload(8, 2048, 256),          # planner probe workload
    "serve-chat-batch64": Workload(64, 2048, 256),  # throughput-heavy chat
    "serve-prefill-32k": Workload(32, 32768, 1),    # prefill_32k shape
    "serve-decode-32k": Workload(16, 32768, 1024),  # decode_32k shape
}

WORKLOADS: Dict[str, Workload] = {
    **{f"paper-{k}": v for k, v in paper_workloads().items()},
    **SERVING_WORKLOADS,
}


def get_workload(name: str) -> Workload:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise KeyError(
            f"unknown workload preset '{name}'; have {sorted(WORKLOADS)}")
