"""Static model verifier: invariant linter over the analytical IR (ISSUE 7).

The stack's credibility rests on internal consistency — a Graph that drops
bytes at a fusion seam, a Plan whose tp doesn't divide the head count, or a
Schedule that double-books the link timeline would silently corrupt every
number downstream. This module turns those implicit modeling assumptions
into machine-checked contracts: a registry of small pure rules, each
examining one artifact kind (Graph, Plan, PrecisionPolicy, Schedule) and
emitting structured `Diagnostic` records instead of asserting.

Severity model (DESIGN.md §11):

  error — the artifact is inconsistent with the cost model's assumptions;
          numbers computed from it are wrong, not just approximate.
  warn  — suspicious but conceivably intended; evaluation proceeds.
  info  — a modeling note (deliberate approximations, known replication).

Mode plumbing — `Evaluator`, `Study`, and `simulator.simulate` accept
``verify="error"|"warn"|"off"`` (default: the REPRO_VERIFY environment
variable, else "warn"):

  off   — skip verification entirely;
  warn  — every diagnostic becomes a `VerificationWarning`; never raises;
  error — error-severity diagnostics raise ONE `VerificationError` listing
          every diagnostic found (CI runs this mode); warn/info still warn.

The schedule rules are a *certificate validator*: scheduler output is
re-checked against the DAG (deps respected, no resource double-booking,
makespan within [max-resource-busy, serial] bounds, pipelined-collective
completion), so a scheduler bug cannot silently ship an impossible timeline.

Adding a rule: write a generator taking the kind's context dataclass and
yielding Diagnostics, decorate with ``@rule("kind.name", kind, summary)``.
The CLI (`python -m repro.verify`) and the mutation suite
(tests/test_verify.py) pick it up from the registry automatically; every
rule must ship with at least one deliberately-broken artifact it catches.
"""
from __future__ import annotations

import math
import os
import warnings
from dataclasses import dataclass
from typing import (TYPE_CHECKING, Any, Callable, Dict, Iterable, Iterator,
                    List, Optional, Sequence, Tuple, TypeVar, get_args)

from .ir import (CollectiveSpec, ElementwiseSpec, FusedMatmulSpec, Graph,
                 MatmulSpec, Node, NormSpec, OpSpec, ScanSpec, SoftmaxSpec,
                 TrafficSpec, resource_of)
from .fusion import (_epilogue_ok, _in_elems, _out_elems, _out_write_bytes)
from .hardware import Device, System
from .precision import DEFAULT, PrecisionPolicy, get_dtype, mac_scale
from .obs import metrics
from .schedule import RESOURCES, Schedule

if TYPE_CHECKING:                                   # annotation-only imports
    from ..configs.base import ModelConfig
    from .graph import Plan

__all__ = [
    "Diagnostic", "VerificationError", "VerificationWarning", "Rule",
    "MODES", "RULES", "rule", "resolve_mode", "apply_mode",
    "graph_diagnostics", "plan_diagnostics", "policy_diagnostics",
    "schedule_diagnostics", "registry_diagnostics",
    "verify_graph", "verify_plan", "verify_policy", "verify_schedule",
    "verify_case",
]

# ---------------------------------------------------------------------------
# diagnostics, errors, modes
# ---------------------------------------------------------------------------

SEVERITIES: Tuple[str, ...] = ("error", "warn", "info")


@dataclass(frozen=True)
class Diagnostic:
    """One finding: which rule fired, how bad, where, and how to fix it."""
    rule: str                       # registry id, e.g. "graph.acyclic"
    severity: str                   # "error" | "warn" | "info"
    message: str
    location: str = ""              # "node 3 ('softmax')", "plan tp=4", ...
    hint: str = ""                  # how to fix it

    def __str__(self) -> str:
        where = f" @ {self.location}" if self.location else ""
        tail = f" (hint: {self.hint})" if self.hint else ""
        return f"{self.severity}[{self.rule}]{where}: {self.message}{tail}"


class VerificationWarning(UserWarning):
    """A diagnostic surfaced in ``verify="warn"`` mode."""


class VerificationError(ValueError):
    """Verification failed: one clean exception listing ALL diagnostics.

    Raised in ``verify="error"`` mode when any error-severity diagnostic is
    present — malformed inputs fail here with every finding attached instead
    of a deep stack trace from the mapper.
    """

    def __init__(self, diagnostics: Sequence[Diagnostic]) -> None:
        ordered = sorted(diagnostics,
                         key=lambda d: SEVERITIES.index(d.severity))
        self.diagnostics: Tuple[Diagnostic, ...] = tuple(ordered)
        counts = {s: sum(1 for d in ordered if d.severity == s)
                  for s in SEVERITIES}
        head = ", ".join(f"{n} {s}{'s' if n != 1 else ''}"
                         for s, n in counts.items() if n)
        body = "\n".join(f"  {d}" for d in ordered)
        super().__init__(f"verification failed: {head}\n{body}")


MODES: Tuple[str, ...] = ("error", "warn", "off")
_ENV_MODE = "REPRO_VERIFY"


def resolve_mode(mode: Optional[str]) -> str:
    """Explicit mode, else $REPRO_VERIFY, else the "warn" default."""
    if mode is None:
        mode = os.environ.get(_ENV_MODE, "warn").strip().lower() or "warn"
    if mode not in MODES:
        raise ValueError(f"verify mode must be one of {MODES}, got {mode!r}")
    return mode


def apply_mode(diagnostics: Sequence[Diagnostic], mode: str,
               stacklevel: int = 3) -> List[Diagnostic]:
    """Enforce `mode` over collected diagnostics (see module docstring)."""
    diags = list(diagnostics)
    for d in diags:     # counted even when mode silences them (core/obs.py)
        metrics().inc(f"verify.diagnostics.{d.severity}")
    if mode == "off" or not diags:
        return diags
    if mode == "error" and any(d.severity == "error" for d in diags):
        raise VerificationError(diags)
    for d in diags:
        warnings.warn(str(d), VerificationWarning, stacklevel=stacklevel)
    return diags


# ---------------------------------------------------------------------------
# the rule registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GraphContext:
    """Inputs to graph rules. `device` enables datapath-aware checks."""
    graph: Graph
    device: Optional[Device] = None


@dataclass(frozen=True)
class PlanContext:
    """Inputs to plan-legality rules."""
    system: System
    cfg: "ModelConfig"
    plan: "Plan"
    policy: PrecisionPolicy
    batch: int = 1
    max_len: int = 1
    check_memory: bool = True


@dataclass(frozen=True)
class PolicyContext:
    """Inputs to precision-policy rules."""
    policy: PrecisionPolicy
    device: Optional[Device] = None


@dataclass(frozen=True)
class ScheduleContext:
    """A scheduler run to certify: the DAG, its inputs, and the output."""
    graph: Graph
    latencies: Tuple[float, ...]
    schedule: Schedule
    pipeline_collectives: bool = True


@dataclass(frozen=True)
class Rule:
    """Registry entry: a pure checker over one artifact kind."""
    id: str
    kind: str                       # "graph" | "plan" | "policy" | "schedule"
    summary: str
    check: Callable[[Any], Iterable[Diagnostic]]


RULES: Dict[str, Rule] = {}

_F = TypeVar("_F", bound=Callable[[Any], Iterable[Diagnostic]])

KINDS: Tuple[str, ...] = ("graph", "plan", "policy", "schedule", "registry")


def rule(rule_id: str, kind: str, summary: str) -> Callable[[_F], _F]:
    """Register a checker under `rule_id` (see module docstring)."""
    if kind not in KINDS:
        raise ValueError(f"rule kind must be one of {KINDS}, got {kind!r}")

    def deco(fn: _F) -> _F:
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(rule_id, kind, summary, fn)
        return fn
    return deco


def _run_rules(kind: str, ctx: object) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    for r in RULES.values():
        if r.kind == kind:
            out.extend(r.check(ctx))
    return out


# ---------------------------------------------------------------------------
# graph rules
# ---------------------------------------------------------------------------

#: every member of the OpSpec union (isinstance target + coverage contract)
_SPEC_KINDS: Tuple[type, ...] = get_args(OpSpec)

#: one minimal instance per spec kind — the resource-coverage contract:
#: adding a kind to OpSpec without a sample here is itself a diagnostic.
_SAMPLE_SPECS: Tuple[OpSpec, ...] = (
    MatmulSpec(1, 1, 1),
    SoftmaxSpec(1, 1),
    NormSpec("rmsnorm", 1, 1),
    ElementwiseSpec("generic", 1),
    ScanSpec(1, 1, 1.0, 1.0, 2.0),
    CollectiveSpec("all_reduce", 2.0),
    TrafficSpec(2.0),
    FusedMatmulSpec(MatmulSpec(1, 1, 1), (SoftmaxSpec(1, 1),)),
)

_NORM_KINDS = ("layernorm", "rmsnorm")
_ELEMENTWISE_KINDS = ("generic", "gelu", "silu_mul")
_COLLECTIVE_KINDS = ("all_reduce", "reduce_scatter", "all_gather",
                     "all_to_all", "p2p")

_REL_TOL = 1e-9


def _loc(i: int, node: Node) -> str:
    return f"node {i} ({node.name!r})"


def _raw_edges(graph: Graph) -> List[Tuple[int, ...]]:
    """Resolved producer edges WITHOUT Graph.edges()'s ValueError — the
    verifier must survive malformed graphs to report them."""
    out: List[Tuple[int, ...]] = []
    for i, n in enumerate(graph.nodes):
        out.append((((i - 1,) if i else ()) if n.deps is None else n.deps))
    return out


def _valid_edges(graph: Graph) -> List[Tuple[int, ...]]:
    """Raw edges restricted to in-range producers (for derived checks)."""
    n = len(graph.nodes)
    return [tuple(d for d in deps if 0 <= d < n and d != i)
            for i, deps in enumerate(_raw_edges(graph))]


def _gemm_of(spec: OpSpec) -> Optional[MatmulSpec]:
    if isinstance(spec, MatmulSpec):
        return spec
    if isinstance(spec, FusedMatmulSpec):
        return spec.gemm
    return None


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL_TOL, abs_tol=0.0)


@rule("graph.producers", "graph",
      "every dep points at an existing, distinct node")
def _check_producers(ctx: GraphContext) -> Iterator[Diagnostic]:
    n = len(ctx.graph.nodes)
    for i, deps in enumerate(_raw_edges(ctx.graph)):
        node = ctx.graph.nodes[i]
        for d in deps:
            if d < 0 or d >= n:
                yield Diagnostic(
                    "graph.producers", "error",
                    f"dep {d} is out of range for a {n}-node graph "
                    f"(dangling producer)", _loc(i, node),
                    "deps must index nodes of the same Graph; check "
                    "GraphBuilder offsets when concatenating")
            elif d == i:
                yield Diagnostic(
                    "graph.producers", "error",
                    "node depends on itself", _loc(i, node),
                    "a node cannot be its own producer")


@rule("graph.acyclic", "graph", "the dataflow graph is a DAG")
def _check_acyclic(ctx: GraphContext) -> Iterator[Diagnostic]:
    nodes = ctx.graph.nodes
    edges = _valid_edges(ctx.graph)
    indeg = [len(deps) for deps in edges]
    consumers: List[List[int]] = [[] for _ in nodes]
    for i, deps in enumerate(edges):
        for d in deps:
            consumers[d].append(i)
    ready = [i for i, k in enumerate(indeg) if k == 0]
    done = 0
    while ready:
        i = ready.pop()
        done += 1
        for c in consumers[i]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
    if done < len(nodes):
        cyc = [i for i, k in enumerate(indeg) if k > 0]
        names = ", ".join(_loc(i, nodes[i]) for i in cyc[:6])
        yield Diagnostic(
            "graph.acyclic", "error",
            f"dependency cycle through {len(cyc)} nodes: {names}",
            hint="dataflow graphs must be DAGs; break the cycle or drop "
                 "the back edge")


@rule("graph.topo-order", "graph",
      "node order is a topological order (deps point backwards)")
def _check_topo(ctx: GraphContext) -> Iterator[Diagnostic]:
    n = len(ctx.graph.nodes)
    for i, deps in enumerate(_raw_edges(ctx.graph)):
        for d in deps:
            if i < d < n:
                yield Diagnostic(
                    "graph.topo-order", "error",
                    f"dep {d} points forward", _loc(i, ctx.graph.nodes[i]),
                    "Graph node order must be topological: producers before "
                    "consumers (Graph.edges() and the scheduler require it)")


@rule("graph.unconsumed", "graph",
      "every non-terminal node's output is consumed")
def _check_unconsumed(ctx: GraphContext) -> Iterator[Diagnostic]:
    nodes = ctx.graph.nodes
    if len(nodes) < 2:
        return
    consumed = set()
    for deps in _valid_edges(ctx.graph):
        consumed.update(deps)
    for i, node in enumerate(nodes[:-1]):
        if i not in consumed:
            yield Diagnostic(
                "graph.unconsumed", "info",
                "output is never consumed (dead node, or a missing edge)",
                _loc(i, node),
                "wire the consumer's deps, or drop the node")


@rule("graph.resource", "graph",
      "every spec is a known OpSpec kind with a valid resource tag")
def _check_resource(ctx: GraphContext) -> Iterator[Diagnostic]:
    for i, node in enumerate(ctx.graph.nodes):
        if not isinstance(node.spec, _SPEC_KINDS):
            yield Diagnostic(
                "graph.resource", "error",
                f"spec type {type(node.spec).__name__} is not a member of "
                f"ir.OpSpec", _loc(i, node),
                "add the kind to ir.OpSpec, ir.resource_of, the evaluator "
                "dispatch, and verify._SAMPLE_SPECS")
            continue
        res = resource_of(node.spec)
        if res not in RESOURCES:
            yield Diagnostic(
                "graph.resource", "error",
                f"resource_of returned {res!r}, not one of {RESOURCES}",
                _loc(i, node),
                "fix ir.resource_of for this spec kind")


@rule("graph.values", "graph",
      "spec fields are in-range and kind strings are known")
def _check_values(ctx: GraphContext) -> Iterator[Diagnostic]:
    for i, node in enumerate(ctx.graph.nodes):
        loc = _loc(i, node)
        if node.repeat < 1:
            yield Diagnostic(
                "graph.values", "error",
                f"repeat={node.repeat} silently zeroes or negates this "
                f"node's cost", loc, "repeat must be >= 1")
        for spec in _flat_specs(node.spec):
            yield from _spec_value_diags(spec, loc)


def _flat_specs(spec: OpSpec) -> Iterator[OpSpec]:
    if isinstance(spec, FusedMatmulSpec):
        yield spec.gemm
        yield from spec.epilogue
    else:
        yield spec


def _spec_value_diags(spec: OpSpec, loc: str) -> Iterator[Diagnostic]:
    if isinstance(spec, MatmulSpec):
        if min(spec.m, spec.k, spec.n, spec.batch) < 1:
            yield Diagnostic("graph.values", "error",
                             f"non-positive GEMM dims m={spec.m} k={spec.k} "
                             f"n={spec.n} batch={spec.batch}", loc)
        if min(spec.bytes_a, spec.bytes_b, spec.bytes_out,
               spec.bytes_acc) < 0:
            yield Diagnostic("graph.values", "error",
                             "negative operand byte width", loc)
    elif isinstance(spec, (SoftmaxSpec, NormSpec)):
        if min(spec.rows, spec.cols) < 1:
            yield Diagnostic("graph.values", "error",
                             f"non-positive rows={spec.rows} "
                             f"cols={spec.cols}", loc)
        if min(spec.bytes_in, spec.bytes_out) <= 0:
            yield Diagnostic("graph.values", "error",
                             "non-positive element byte width", loc)
        if isinstance(spec, NormSpec) and spec.kind not in _NORM_KINDS:
            yield Diagnostic(
                "graph.values", "error",
                f"unknown norm kind {spec.kind!r} would silently be priced "
                f"as rmsnorm", loc, f"use one of {_NORM_KINDS}")
    elif isinstance(spec, ElementwiseSpec):
        if spec.kind not in _ELEMENTWISE_KINDS:
            yield Diagnostic(
                "graph.values", "error",
                f"unknown elementwise kind {spec.kind!r} would silently be "
                f"priced as generic", loc, f"use one of {_ELEMENTWISE_KINDS}")
        if spec.n_elements < 1 or spec.n_in < 1 or spec.bytes_elt <= 0 \
                or spec.flops_per_elt < 0:
            yield Diagnostic("graph.values", "error",
                             "non-positive elementwise field", loc)
    elif isinstance(spec, CollectiveSpec):
        if spec.kind not in _COLLECTIVE_KINDS:
            yield Diagnostic(
                "graph.values", "error",
                f"unknown collective kind {spec.kind!r} (the evaluator "
                f"raises deep inside interconnect.py)", loc,
                f"use one of {_COLLECTIVE_KINDS}")
        if spec.n_bytes < 0 or spec.n_devices < 0 or spec.bytes_elt <= 0:
            yield Diagnostic("graph.values", "error",
                             "non-positive collective field", loc)
    elif isinstance(spec, TrafficSpec):
        if spec.n_bytes < 0:
            yield Diagnostic("graph.values", "error",
                             f"negative traffic bytes {spec.n_bytes}", loc)
    elif isinstance(spec, ScanSpec):
        if min(spec.seq, spec.batch, spec.chunk) < 1 or spec.d_state <= 0 \
                or spec.flops_per_step < 0 or spec.bytes_io < 0:
            yield Diagnostic("graph.values", "error",
                             "non-positive scan field", loc)


@rule("graph.accumulator", "graph",
      "GEMM accumulators are at least as wide as their operands")
def _check_accumulator(ctx: GraphContext) -> Iterator[Diagnostic]:
    for i, node in enumerate(ctx.graph.nodes):
        gemm = _gemm_of(node.spec)
        if gemm is None:
            continue
        widest = max(gemm.bytes_a, gemm.bytes_b)
        if gemm.bytes_acc < widest:
            yield Diagnostic(
                "graph.accumulator", "error",
                f"accumulator width {gemm.bytes_acc}B is narrower than the "
                f"widest operand ({widest}B): partial sums would lose "
                f"precision the cost model doesn't charge for",
                _loc(i, node),
                "stage partials at >= the operand width (quantized "
                "policies accumulate fp32)")


@rule("graph.mac-scale", "graph",
      "systolic issue-rate scales are positive powers of two")
def _check_mac_scale(ctx: GraphContext) -> Iterator[Diagnostic]:
    for i, node in enumerate(ctx.graph.nodes):
        gemm = _gemm_of(node.spec)
        if gemm is None:
            continue
        s = gemm.mac_scale
        if s <= 0 or not math.log2(s).is_integer():
            yield Diagnostic(
                "graph.mac-scale", "error",
                f"mac_scale={s} is not a positive power of two (the mapper "
                f"divides cycle counts by it exactly)", _loc(i, node),
                "derive it from precision.mac_scale()")


@rule("graph.dataflow", "graph",
      "bytes/elements are conserved across edges and fusion seams")
def _check_dataflow(ctx: GraphContext) -> Iterator[Diagnostic]:
    nodes = ctx.graph.nodes
    edges = _valid_edges(ctx.graph)
    consumers: List[List[int]] = [[] for _ in nodes]
    for i, deps in enumerate(edges):
        for d in deps:
            consumers[d].append(i)

    for i, node in enumerate(nodes):
        spec = node.spec
        loc = _loc(i, node)

        # ---- fused-kernel seams: exact rescale invariants ----------------
        if isinstance(spec, FusedMatmulSpec):
            yield from _fused_diags(spec, loc)
            if spec.stream_out:
                yield from _stream_diags(spec, i, loc, nodes, consumers)

        # ---- a GEMM reading its A operand "for free" needs a streamer ----
        gemm = _gemm_of(spec)
        if gemm is not None and gemm.bytes_a == 0:
            streamers = [d for d in edges[i]
                         if isinstance(nodes[d].spec, FusedMatmulSpec)
                         and nodes[d].spec.stream_out]
            if not streamers:
                yield Diagnostic(
                    "graph.dataflow", "error",
                    "GEMM reads its A operand for free (bytes_a=0) but no "
                    "producer streams it on-chip", loc,
                    "only the flash rule's consumer may set bytes_a=0 "
                    "(paired with a stream_out producer)")

        # ---- general single-producer conservation ------------------------
        # A softmax/elementwise consumer is mid-stream: reading more
        # elements than its sole producer emits means bytes appeared from
        # nowhere (the fusion-seam bug class). A NORM consumer may open a
        # new stream — block-boundary re-normalization (the whisper encoder
        # stack chains after the decoder as an ordering seam; SP shards
        # re-enter at 1/tp the tokens) — so a norm mismatch is only a note.
        if isinstance(spec, (SoftmaxSpec, NormSpec, ElementwiseSpec)) \
                and len(edges[i]) == 1:
            d = edges[i][0]
            prod = nodes[d]
            if prod.repeat != node.repeat:
                continue
            out = _out_elems(prod.spec)
            inn = _in_elems(spec)
            if out is None or inn is None or out <= 0:
                continue
            if inn > out * (1 + _REL_TOL):
                if isinstance(spec, NormSpec):
                    yield Diagnostic(
                        "graph.dataflow", "info",
                        f"norm reads {inn:g} elements but its producer "
                        f"{_loc(d, prod)} outputs {out:g} (block-boundary "
                        f"norms may open a new stream)", loc)
                else:
                    yield Diagnostic(
                        "graph.dataflow", "warn",
                        f"reads {inn:g} elements but its sole producer "
                        f"{_loc(d, prod)} outputs {out:g}", loc,
                        "bytes are not conserved across this edge; check "
                        "the builder's shapes")


def _fused_diags(spec: FusedMatmulSpec, loc: str) -> Iterator[Diagnostic]:
    gemm = spec.gemm
    if not spec.epilogue:
        yield Diagnostic("graph.dataflow", "error",
                         "FusedMatmulSpec with an empty epilogue", loc,
                         "use a plain MatmulSpec instead")
        return
    bad = [type(e).__name__ for e in spec.epilogue if not _epilogue_ok(e)]
    if bad:
        yield Diagnostic(
            "graph.dataflow", "error",
            f"epilogue contains non-epilogue specs: {', '.join(bad)}", loc,
            "only softmax/norm/elementwise ops fuse as epilogues")
        return
    prev_out = float(gemm.batch * gemm.m * gemm.n)
    for k, epi in enumerate(spec.epilogue):
        inn = _in_elems(epi)
        if inn is not None and not _close(inn, prev_out):
            yield Diagnostic(
                "graph.dataflow", "error",
                f"epilogue stage {k} ({type(epi).__name__}) reads {inn:g} "
                f"elements but the previous stage produces {prev_out:g}",
                loc, "fusion requires exact element-count matches "
                     "(fusion._fuse_once checks _in_elems == _out_elems)")
        nxt = _out_elems(epi)
        prev_out = nxt if nxt is not None else prev_out
    c_elems = float(gemm.batch * gemm.m * gemm.n)
    expected = 0.0 if spec.stream_out else _out_write_bytes(spec.epilogue[-1])
    actual = gemm.bytes_out * c_elems
    if not (_close(actual, expected) or actual == expected):
        yield Diagnostic(
            "graph.dataflow", "error",
            f"fused kernel writes {actual:g} bytes but the final epilogue's "
            f"output is {expected:g} bytes (bytes_out rescale broken)", loc,
            "rebuild the effective shape with fusion._rescaled")


def _stream_diags(spec: FusedMatmulSpec, i: int, loc: str,
                  nodes: Tuple[Node, ...],
                  consumers: List[List[int]]) -> Iterator[Diagnostic]:
    cons = consumers[i]
    if not cons:
        yield Diagnostic(
            "graph.dataflow", "error",
            "streams its output on-chip (stream_out) but has no consumer",
            loc, "flash streaming requires the consumer GEMM edge")
        return
    out = _out_elems(spec)
    for c in cons:
        cg = _gemm_of(nodes[c].spec)
        if cg is None:
            yield Diagnostic(
                "graph.dataflow", "error",
                f"streamed output is consumed by non-GEMM "
                f"{_loc(c, nodes[c])}", loc,
                "flash streaming hands the tile to a matmul A operand")
            continue
        if cg.bytes_a != 0:
            yield Diagnostic(
                "graph.dataflow", "error",
                f"consumer {_loc(c, nodes[c])} re-reads the streamed "
                f"operand from HBM (bytes_a={cg.bytes_a})", loc,
                "the flash consumer must set bytes_a=0")
        a_elems = float(cg.batch * cg.m * cg.k)
        if out is not None and not _close(a_elems, out):
            yield Diagnostic(
                "graph.dataflow", "error",
                f"consumer {_loc(c, nodes[c])} A operand holds {a_elems:g} "
                f"elements but the streamed tensor has {out:g}", loc)


@rule("graph.datapath", "graph",
      "operand widths fit the device's native systolic datapath")
def _check_datapath(ctx: GraphContext) -> Iterator[Diagnostic]:
    if ctx.device is None:
        return
    sa = ctx.device.core.lane.systolic_array
    try:
        sa_bits = get_dtype(sa.dtype).bits
    except KeyError:
        yield Diagnostic(
            "graph.datapath", "error",
            f"device {ctx.device.name!r} has an unknown systolic datapath "
            f"dtype {sa.dtype!r}", hint="register it in precision.DTYPES")
        return
    for i, node in enumerate(ctx.graph.nodes):
        gemm = _gemm_of(node.spec)
        if gemm is None:
            continue
        op_bits = max(gemm.bytes_a, gemm.bytes_b) * 8
        if op_bits > sa_bits:
            yield Diagnostic(
                "graph.datapath", "error",
                f"{op_bits:g}-bit GEMM operands on device "
                f"{ctx.device.name!r}'s {sa_bits}-bit {sa.dtype!r} systolic "
                f"datapath: the timing model would silently price it at "
                f"full rate", _loc(i, node),
                "narrow the policy operands or widen the datapath "
                "(hardware.with_mac_dtype)")


# ---------------------------------------------------------------------------
# plan rules
# ---------------------------------------------------------------------------

def _plan_loc(plan: "Plan") -> str:
    sp = ",sp" if plan.sequence_parallel else ""
    return (f"plan tp={plan.tp},pp={plan.pp},dp={plan.dp},"
            f"ep={plan.ep}{sp}")


@rule("plan.devices", "plan",
      "the plan's device grid fits the system")
def _check_devices(ctx: PlanContext) -> Iterator[Diagnostic]:
    used = ctx.plan.devices
    have = ctx.system.device_count
    if used > have:
        yield Diagnostic(
            "plan.devices", "error",
            f"plan needs tp*pp*dp={used} devices but the system has {have}",
            _plan_loc(ctx.plan), "shrink the plan or grow the system")
    elif used < have:
        yield Diagnostic(
            "plan.devices", "info",
            f"plan uses {used} of {have} devices", _plan_loc(ctx.plan))


@rule("plan.tp-heads", "plan",
      "tensor parallelism divides the attention head count")
def _check_tp_heads(ctx: PlanContext) -> Iterator[Diagnostic]:
    cfg, tp = ctx.cfg, ctx.plan.tp
    if tp <= 1 or cfg.n_heads <= 0:
        return
    if cfg.n_heads % tp:
        modeled = max(1, cfg.n_heads // tp) * tp
        yield Diagnostic(
            "plan.tp-heads", "error",
            f"tp={tp} does not divide n_heads={cfg.n_heads}: the graph "
            f"builder would model {modeled} heads and silently drop the "
            f"rest of the attention work", _plan_loc(ctx.plan),
            "choose tp dividing the head count "
            "(planner.enumerate_plans only emits such plans)")


@rule("plan.tp-kv-heads", "plan",
      "tp beyond the KV head count replicates KV (modeled, but noted)")
def _check_tp_kv_heads(ctx: PlanContext) -> Iterator[Diagnostic]:
    cfg, tp = ctx.cfg, ctx.plan.tp
    if 0 < cfg.n_kv_heads < tp:
        yield Diagnostic(
            "plan.tp-kv-heads", "info",
            f"tp={tp} exceeds n_kv_heads={cfg.n_kv_heads}: KV heads "
            f"replicate across tp ranks (compute and per-device KV memory "
            f"are modeled replicated)", _plan_loc(ctx.plan))


@rule("plan.pp-layers", "plan",
      "pipeline stages do not outnumber the layers")
def _check_pp_layers(ctx: PlanContext) -> Iterator[Diagnostic]:
    cfg, pp = ctx.cfg, ctx.plan.pp
    if pp <= 1:
        return
    if pp > cfg.n_layers:
        yield Diagnostic(
            "plan.pp-layers", "error",
            f"pp={pp} exceeds n_layers={cfg.n_layers}: some pipeline "
            f"stages would hold zero layers while the model prices "
            f"ceil-sized stages", _plan_loc(ctx.plan),
            "cap pp at the layer count "
            "(planner.enumerate_plans only emits such plans)")
    elif cfg.n_layers % pp:
        yield Diagnostic(
            "plan.pp-layers", "info",
            f"pp={pp} does not divide n_layers={cfg.n_layers}: stages are "
            f"ceil-sized and the slowest stage is priced",
            _plan_loc(ctx.plan))


@rule("plan.ep-experts", "plan",
      "expert parallelism divides the expert count")
def _check_ep(ctx: PlanContext) -> Iterator[Diagnostic]:
    cfg, plan = ctx.cfg, ctx.plan
    if plan.ep <= 1:
        return
    if cfg.n_experts <= 0:
        yield Diagnostic(
            "plan.ep-experts", "error",
            f"ep={plan.ep} on a dense model (n_experts=0)",
            _plan_loc(plan), "expert parallelism needs experts to shard")
        return
    if cfg.n_experts % plan.ep:
        yield Diagnostic(
            "plan.ep-experts", "error",
            f"ep={plan.ep} does not divide n_experts={cfg.n_experts}: the "
            f"builder would model {max(1, cfg.n_experts // plan.ep) * plan.ep} "
            f"experts and drop the rest", _plan_loc(plan),
            "use a divisor of the expert count (planner uses gcd)")
    if plan.ep > plan.dp:
        yield Diagnostic(
            "plan.ep-experts", "warn",
            f"ep={plan.ep} exceeds dp={plan.dp}: experts would shard over "
            f"more ranks than the data-parallel group has",
            _plan_loc(plan))


@rule("plan.memory", "plan",
      "the model + KV + activations fit per-device memory under the policy")
def _check_memory(ctx: PlanContext) -> Iterator[Diagnostic]:
    if not ctx.check_memory:
        return
    from .inference_model import memory_per_device   # lazy: import cycle
    need = memory_per_device(ctx.cfg, ctx.plan, ctx.batch, ctx.max_len,
                             ctx.policy)
    cap = ctx.system.device.memory_capacity
    if need > cap:
        yield Diagnostic(
            "plan.memory", "error",
            f"needs {need / 2 ** 30:.2f} GiB per device but "
            f"{ctx.system.device.name!r} has {cap / 2 ** 30:.2f} GiB "
            f"(batch={ctx.batch}, max_len={ctx.max_len}, "
            f"policy={ctx.policy.tag})", _plan_loc(ctx.plan),
            "raise tp/pp, shrink the batch/context, or quantize "
            "(weights/kv_cache dtypes)")


# ---------------------------------------------------------------------------
# policy rules
# ---------------------------------------------------------------------------

@rule("policy.accumulator", "policy",
      "the accumulator is at least as wide as every operand class")
def _check_policy_acc(ctx: PolicyContext) -> Iterator[Diagnostic]:
    p = ctx.policy
    widest = max(p.weights.bits, p.activations.bits, p.kv_cache.bits)
    if p.accumulator.bits < widest:
        yield Diagnostic(
            "policy.accumulator", "error",
            f"accumulator {p.accumulator.name} ({p.accumulator.bits}b) is "
            f"narrower than the widest operand class ({widest}b)",
            f"policy {p.tag}",
            "accumulate at >= operand width (quantized presets use fp32)")


@rule("policy.mac-scale", "policy",
      "derived GEMM issue rates are positive powers of two")
def _check_policy_mac(ctx: PolicyContext) -> Iterator[Diagnostic]:
    p = ctx.policy
    for label, a, b in (("activations x weights", p.activations, p.weights),
                        ("activations x kv", p.activations, p.kv_cache)):
        s = mac_scale(a, b)
        if s <= 0 or not math.log2(s).is_integer():
            yield Diagnostic(
                "policy.mac-scale", "error",
                f"mac_scale({label}) = {s} is not a positive power of two",
                f"policy {p.tag}",
                "DType.mac_throughput must be a power of two")


@rule("policy.datapath", "policy",
      "policy operand widths fit the device's native datapath")
def _check_policy_datapath(ctx: PolicyContext) -> Iterator[Diagnostic]:
    if ctx.device is None:
        return
    p = ctx.policy
    sa = ctx.device.core.lane.systolic_array
    try:
        sa_bits = get_dtype(sa.dtype).bits
    except KeyError:
        yield Diagnostic(
            "policy.datapath", "error",
            f"device {ctx.device.name!r} has an unknown systolic datapath "
            f"dtype {sa.dtype!r}", f"policy {p.tag}",
            "register it in precision.DTYPES")
        return
    widest = max(p.weights.bits, p.activations.bits, p.kv_cache.bits)
    if widest > sa_bits:
        yield Diagnostic(
            "policy.datapath", "error",
            f"{widest}-bit operands on device {ctx.device.name!r}'s "
            f"{sa_bits}-bit {sa.dtype!r} systolic datapath: the timing "
            f"model would not stop you, but the numbers would be wrong",
            f"policy {p.tag}",
            "quantize the policy to the datapath width, or price an "
            "fp16-native design (hardware.with_mac_dtype)")


# ---------------------------------------------------------------------------
# schedule certificate rules
# ---------------------------------------------------------------------------

def _sched_eps(ctx: ScheduleContext) -> float:
    return _REL_TOL * max(abs(ctx.schedule.serial), 1e-30)


def _pipelined(ctx: ScheduleContext, i: int,
               deps: Tuple[int, ...]) -> bool:
    return (ctx.pipeline_collectives
            and ctx.schedule.slots[i].resource == "link"
            and isinstance(ctx.graph.nodes[i].spec, CollectiveSpec)
            and bool(deps))


@rule("schedule.deps", "schedule",
      "no slot starts before its producers allow")
def _check_sched_deps(ctx: ScheduleContext) -> Iterator[Diagnostic]:
    slots = ctx.schedule.slots
    eps = _sched_eps(ctx)
    for i, deps in enumerate(_valid_edges(ctx.graph)):
        s = slots[i]
        pipelined = _pipelined(ctx, i, deps)
        for d in deps:
            ready = slots[d].start if pipelined else slots[d].end
            if s.start + eps < ready:
                kind = "starts" if pipelined else "finishes"
                yield Diagnostic(
                    "schedule.deps", "error",
                    f"slot starts at {s.start:g} but its producer "
                    f"{_loc(d, ctx.graph.nodes[d])} only {kind} at "
                    f"{ready:g}", _loc(i, ctx.graph.nodes[i]),
                    "the certificate re-checks scheduler output; this "
                    "schedule violates its own DAG")


@rule("schedule.exclusive", "schedule",
      "no resource timeline is double-booked")
def _check_sched_exclusive(ctx: ScheduleContext) -> Iterator[Diagnostic]:
    eps = _sched_eps(ctx)
    by_res: Dict[str, List[int]] = {}
    for i, s in enumerate(ctx.schedule.slots):
        by_res.setdefault(s.resource, []).append(i)
    for r, idxs in sorted(by_res.items()):
        idxs.sort(key=lambda i: (ctx.schedule.slots[i].start, i))
        for a, b in zip(idxs, idxs[1:]):
            sa, sb = ctx.schedule.slots[a], ctx.schedule.slots[b]
            if sb.start + eps < sa.start + sa.duration:
                yield Diagnostic(
                    "schedule.exclusive", "error",
                    f"{r!r} is double-booked: "
                    f"{_loc(a, ctx.graph.nodes[a])} occupies "
                    f"[{sa.start:g}, {sa.start + sa.duration:g}) but "
                    f"{_loc(b, ctx.graph.nodes[b])} starts at {sb.start:g}",
                    hint="one resource runs one op at a time; occupancy is "
                         "`duration`, not the pipelined `end`")


@rule("schedule.makespan", "schedule",
      "makespan lies in [max resource busy, serial sum]")
def _check_sched_makespan(ctx: ScheduleContext) -> Iterator[Diagnostic]:
    sch = ctx.schedule
    eps = _sched_eps(ctx)
    if sch.slots:
        last = max(s.end for s in sch.slots)
        if abs(sch.makespan - last) > eps:
            yield Diagnostic(
                "schedule.makespan", "error",
                f"recorded makespan {sch.makespan:g} != last completion "
                f"{last:g}")
    max_busy = max(sch.busy.values(), default=0.0)
    if sch.makespan + eps < max_busy:
        yield Diagnostic(
            "schedule.makespan", "error",
            f"makespan {sch.makespan:g} is below the busiest resource's "
            f"occupancy {max_busy:g} — faster than the roofline allows")
    if sch.makespan > sch.serial + eps:
        yield Diagnostic(
            "schedule.makespan", "error",
            f"makespan {sch.makespan:g} exceeds the serial sum "
            f"{sch.serial:g} — the schedule lost time a chain wouldn't")


@rule("schedule.pipelining", "schedule",
      "slot completion matches the (pipelined-)collective model")
def _check_sched_pipelining(ctx: ScheduleContext) -> Iterator[Diagnostic]:
    slots = ctx.schedule.slots
    eps = _sched_eps(ctx)
    for i, deps in enumerate(_valid_edges(ctx.graph)):
        s = slots[i]
        if _pipelined(ctx, i, deps):
            expect = max([s.start + s.duration]
                         + [slots[d].end for d in deps])
        else:
            expect = s.start + s.duration
        if abs(s.end - expect) > eps:
            yield Diagnostic(
                "schedule.pipelining", "error",
                f"slot ends at {s.end:g} but the execution model says "
                f"{expect:g} (pipelined collectives end at "
                f"max(start+duration, producer ends); everything else at "
                f"start+duration)", _loc(i, ctx.graph.nodes[i]))


@rule("schedule.busy", "schedule",
      "per-resource busy accounting matches slot durations")
def _check_sched_busy(ctx: ScheduleContext) -> Iterator[Diagnostic]:
    sch = ctx.schedule
    eps = _sched_eps(ctx)
    totals: Dict[str, float] = {}
    for s in sch.slots:                 # node order = scheduler's sum order
        totals[s.resource] = totals.get(s.resource, 0.0) + s.duration
    for r in sorted(set(totals) | set(sch.busy)):
        a, b = totals.get(r, 0.0), sch.busy.get(r, 0.0)
        if abs(a - b) > eps:
            yield Diagnostic(
                "schedule.busy", "error",
                f"busy[{r!r}] records {b:g}s but slot durations sum to "
                f"{a:g}s")
    serial = 0.0
    for s in sch.slots:
        serial += s.duration
    if abs(serial - sch.serial) > eps:
        yield Diagnostic(
            "schedule.busy", "error",
            f"serial records {sch.serial:g}s but durations sum to "
            f"{serial:g}s")
    if len(sch.slots) != len(ctx.graph.nodes) \
            or len(ctx.latencies) != len(ctx.graph.nodes):
        yield Diagnostic(
            "schedule.busy", "error",
            f"{len(sch.slots)} slots / {len(ctx.latencies)} latencies for "
            f"a {len(ctx.graph.nodes)}-node graph")


# ---------------------------------------------------------------------------
# registry self-checks (the resource-tag coverage contract)
# ---------------------------------------------------------------------------

def registry_diagnostics() -> List[Diagnostic]:
    """`ir.resource_of` must be total over every OpSpec kind: each union
    member needs a sample here, and each sample must map to a known
    resource. Run by the CLI and the test suite."""
    out: List[Diagnostic] = []
    sampled = {type(s) for s in _SAMPLE_SPECS}
    for kind in _SPEC_KINDS:
        if kind not in sampled:
            out.append(Diagnostic(
                "ir.resource-coverage", "error",
                f"OpSpec kind {kind.__name__} has no sample in "
                f"verify._SAMPLE_SPECS: resource coverage is unproven",
                hint="add a minimal instance so the contract stays total"))
    for s in _SAMPLE_SPECS:
        if type(s) not in _SPEC_KINDS:
            out.append(Diagnostic(
                "ir.resource-coverage", "error",
                f"sample {type(s).__name__} is not a member of ir.OpSpec"))
        res = resource_of(s)
        if res not in RESOURCES:
            out.append(Diagnostic(
                "ir.resource-coverage", "error",
                f"resource_of({type(s).__name__}) = {res!r}, not one of "
                f"{RESOURCES}", hint="fix ir.resource_of"))
    return out


# ---------------------------------------------------------------------------
# collectors + public entry points
# ---------------------------------------------------------------------------

def graph_diagnostics(graph: Graph,
                      device: Optional[Device] = None) -> List[Diagnostic]:
    """All graph-rule diagnostics (no mode applied)."""
    return _run_rules("graph", GraphContext(graph, device))


def plan_diagnostics(system: System, cfg: "ModelConfig", plan: "Plan", *,
                     policy: Optional[PrecisionPolicy] = None,
                     batch: int = 1, max_len: int = 1,
                     check_memory: bool = True) -> List[Diagnostic]:
    """All plan-rule diagnostics (no mode applied)."""
    ctx = PlanContext(system, cfg, plan, policy or DEFAULT,
                      batch, max_len, check_memory)
    return _run_rules("plan", ctx)


def policy_diagnostics(policy: PrecisionPolicy,
                       device: Optional[Device] = None) -> List[Diagnostic]:
    """All policy-rule diagnostics (no mode applied)."""
    return _run_rules("policy", PolicyContext(policy, device))


def schedule_diagnostics(graph: Graph, latencies: Sequence[float],
                         schedule: Schedule,
                         pipeline_collectives: bool = True
                         ) -> List[Diagnostic]:
    """All schedule-certificate diagnostics (no mode applied)."""
    ctx = ScheduleContext(graph, tuple(latencies), schedule,
                          pipeline_collectives)
    return _run_rules("schedule", ctx)


def verify_graph(graph: Graph, device: Optional[Device] = None,
                 mode: Optional[str] = None) -> List[Diagnostic]:
    """Lint one Graph; enforce the resolved mode. Returns the diagnostics."""
    m = resolve_mode(mode)
    if m == "off":
        return []
    return apply_mode(graph_diagnostics(graph, device), m)


def verify_plan(system: System, cfg: "ModelConfig", plan: "Plan", *,
                policy: Optional[PrecisionPolicy] = None,
                batch: int = 1, max_len: int = 1, check_memory: bool = True,
                mode: Optional[str] = None) -> List[Diagnostic]:
    """Lint one (system, config, plan) point; enforce the resolved mode."""
    m = resolve_mode(mode)
    if m == "off":
        return []
    diags = plan_diagnostics(system, cfg, plan, policy=policy, batch=batch,
                             max_len=max_len, check_memory=check_memory)
    return apply_mode(diags, m)


def verify_policy(policy: PrecisionPolicy, device: Optional[Device] = None,
                  mode: Optional[str] = None) -> List[Diagnostic]:
    """Lint one PrecisionPolicy (against a device's datapath if given)."""
    m = resolve_mode(mode)
    if m == "off":
        return []
    return apply_mode(policy_diagnostics(policy, device), m)


def verify_schedule(graph: Graph, latencies: Sequence[float],
                    schedule: Schedule, pipeline_collectives: bool = True,
                    mode: Optional[str] = None) -> List[Diagnostic]:
    """Validate a scheduler-output certificate; enforce the resolved mode."""
    m = resolve_mode(mode)
    if m == "off":
        return []
    diags = schedule_diagnostics(graph, latencies, schedule,
                                 pipeline_collectives)
    return apply_mode(diags, m)


def verify_case(case: Any, mode: Optional[str] = None,
                check_memory: bool = False) -> List[Diagnostic]:
    """Lint one study.Case (plan + policy rules; its graphs are linted by
    the Evaluator when the case prices). Memory is off by default: the
    Study's enforce_fits gate owns that decision per-case."""
    m = resolve_mode(mode)
    if m == "off":
        return []
    w = case.workload
    diags = plan_diagnostics(case.system, case.cfg, case.plan,
                             policy=case.policy, batch=w.batch,
                             max_len=w.total_len, check_memory=check_memory)
    diags += policy_diagnostics(case.policy, case.system.device)
    return apply_mode(diags, m)
