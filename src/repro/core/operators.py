"""Operator-level performance models (paper Sec. III-B1/B3).

Matmul delegates to the mapper search. Softmax / LayerNorm / GELU follow the
same tile-by-tile methodology minus the systolic array: fewer dimensions, no
MXU, vector-unit compute, special-function throughput for exp/tanh/rsqrt.
Softmax uses the online algorithm [Milakov & Gimelshein], GELU the tanh
approximation [Hendrycks & Gimpel] — as in the paper.

Every model returns an OpResult carrying latency, flops, bytes and the
binding resource, so graph-level accounting (and the roofline comparison)
stays interpretable — the paper's "no fudge factors" principle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .hardware import Device
from .mapper import Mapping, matmul_perf


@dataclass(frozen=True)
class OpResult:
    name: str
    latency: float                  # seconds, incl. launch overhead
    flops: float
    main_memory_bytes: float
    bound: str                      # compute | memory | overhead | link
    mapping: Optional[Mapping] = None

    def __add__(self, other: "OpResult") -> "OpResult":
        # the dominant (slower) operand decides the bound and contributes its
        # mapping, so combined results keep their Pallas BlockSpec hints
        dom, sub = (self, other) if self.latency >= other.latency \
            else (other, self)
        return OpResult(
            name=f"{self.name}+{other.name}",
            latency=self.latency + other.latency,
            flops=self.flops + other.flops,
            main_memory_bytes=self.main_memory_bytes + other.main_memory_bytes,
            bound=dom.bound,
            mapping=dom.mapping if dom.mapping is not None else sub.mapping,
        )


ZERO = OpResult("zero", 0.0, 0, 0, "overhead")


def _finish(name: str, dev: Device, compute_t: float, mem_t: float,
            flops: float, bytes_: float, mapping=None) -> OpResult:
    body = max(compute_t, mem_t)   # vector ops pipeline load with compute
    lat = body + dev.kernel_launch_overhead_s
    if dev.kernel_launch_overhead_s > body:
        bound = "overhead"
    elif compute_t >= mem_t:
        bound = "compute"
    else:
        bound = "memory"
    return OpResult(name, lat, flops, bytes_, bound, mapping)


def matmul(dev: Device, m: int, k: int, n: int, batch: int = 1,
           bytes_a: float = 2, bytes_b: float = 2, bytes_out: float = 2,
           bytes_acc: float = 2, b_shared: bool = False,
           mac_scale: float = 1.0, name: str = "matmul") -> OpResult:
    r = matmul_perf(dev, m, k, n, batch=batch, bytes_a=bytes_a,
                    bytes_b=bytes_b, bytes_out=bytes_out, bytes_acc=bytes_acc,
                    b_shared=b_shared, mac_scale=mac_scale)
    return OpResult(name, r.latency + dev.kernel_launch_overhead_s, r.flops,
                    r.main_memory_bytes, r.mapping.bound, r.mapping)


def _vector_time(dev: Device, flops: float, special_frac: float = 0.0) -> float:
    """Time for elementwise/reduction work on the vector units.

    special_frac: fraction of operations that are special functions
    (exp/tanh/rsqrt), which run at VectorUnit.special_ratio of peak.
    """
    peak = dev.peak_vector_flops
    sp = dev.core.lane.vector_unit.special_ratio
    return flops * ((1 - special_frac) + special_frac / sp) / peak


def _row_parallel_util(dev: Device, rows: int) -> float:
    """Row-parallel ops (softmax/norms) assign rows to cores: with fewer
    rows than cores, the idle cores cannot help — the paper's Fig. 5d trend
    (throughput drops at extreme reduction dims) comes from exactly this."""
    return min(1.0, rows / dev.core_count)


def softmax(dev: Device, rows: int, cols: int, bytes_in: int = 2,
            bytes_out: int = 2, name: str = "softmax") -> OpResult:
    """Row-wise softmax on (rows, cols), online algorithm (one read pass for
    running max+sum, one read+write pass to normalize). If a row's working set
    exceeds the global buffer, the second pass re-reads from main memory."""
    n = rows * cols
    row_bytes = cols * bytes_in
    fits = rows * row_bytes <= dev.global_buffer_bytes
    reads = 1 if fits else 2
    bytes_ = n * (reads * bytes_in + bytes_out)
    mem_t = bytes_ / dev.memory_bandwidth
    # per element: 1 exp + ~3 flops (max, scale-accum, divide amortized)
    flops = 4.0 * n
    cmp_t = _vector_time(dev, flops, special_frac=0.25) \
        / _row_parallel_util(dev, rows)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def layernorm(dev: Device, rows: int, cols: int, bytes_in: int = 2,
              bytes_out: int = 2, name: str = "layernorm") -> OpResult:
    """Welford-style mean/var + normalize; reduction cost grows with cols.

    When one row exceeds the per-core local buffer, partial stats make extra
    trips through the global buffer — this is what makes throughput *drop* at
    extreme reduction dims (paper Fig. 5d) where a roofline model stays flat.
    """
    n = rows * cols
    bytes_ = n * (bytes_in + bytes_out)
    mem_t = bytes_ / dev.memory_bandwidth
    flops = 8.0 * n   # mean/var accumulation + (x-mu)*rsqrt(var)*g + b
    cmp_t = _vector_time(dev, flops, special_frac=0.05) \
        / _row_parallel_util(dev, rows)
    # cross-tile reduction penalty: rows are strip-mined into col-chunks that
    # fit a core's local buffer; partial (mean, M2) pairs traverse the GB
    chunk = max(1, dev.core.local_buffer_bytes // (2 * bytes_in))
    n_chunks = -(-cols // chunk)
    if n_chunks > 1:
        part_bytes = rows * n_chunks * 8 * 2     # fp32 (mean, M2) per chunk
        mem_t += 2 * part_bytes / dev.global_buffer_bandwidth
        cmp_t += _vector_time(dev, rows * n_chunks * 8.0) \
            / _row_parallel_util(dev, rows)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def rmsnorm(dev: Device, rows: int, cols: int, bytes_in: int = 2,
            bytes_out: int = 2, name: str = "rmsnorm") -> OpResult:
    """RMSNorm: sum-of-squares reduction + x * rsqrt(ms) * g.

    First-class model (no layernorm fudge factors): one fused read pass
    accumulates the sum of squares and normalizes, ~4 flops/element (square-
    accumulate, scale, one rsqrt per row amortized). The chunked-reduction
    penalty is the same mechanism as layernorm's — rows strip-mined into
    col-chunks that fit a core's local buffer — but each chunk carries a
    single fp32 partial (sum of squares) instead of a (mean, M2) pair.
    """
    n = rows * cols
    bytes_ = n * (bytes_in + bytes_out)
    mem_t = bytes_ / dev.memory_bandwidth
    flops = 4.0 * n   # x*x accumulate + x * rsqrt(ms) * g
    cmp_t = _vector_time(dev, flops, special_frac=0.05) \
        / _row_parallel_util(dev, rows)
    chunk = max(1, dev.core.local_buffer_bytes // (2 * bytes_in))
    n_chunks = -(-cols // chunk)
    if n_chunks > 1:
        part_bytes = rows * n_chunks * 8         # fp32 sum-of-squares partial
        mem_t += 2 * part_bytes / dev.global_buffer_bandwidth
        cmp_t += _vector_time(dev, rows * n_chunks * 4.0) \
            / _row_parallel_util(dev, rows)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def gelu(dev: Device, n_elements: int, bytes_in: int = 2,
         bytes_out: int = 2, name: str = "gelu") -> OpResult:
    """tanh-approximated GELU: ~10 flops/element, half special."""
    bytes_ = n_elements * (bytes_in + bytes_out)
    mem_t = bytes_ / dev.memory_bandwidth
    flops = 10.0 * n_elements
    cmp_t = _vector_time(dev, flops, special_frac=0.5)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def silu_mul(dev: Device, n_elements: int, bytes_in: int = 2,
             bytes_out: int = 2, name: str = "silu_mul") -> OpResult:
    """SwiGLU gate: silu(a) * b — reads two operands."""
    bytes_ = n_elements * (2 * bytes_in + bytes_out)
    mem_t = bytes_ / dev.memory_bandwidth
    flops = 6.0 * n_elements
    cmp_t = _vector_time(dev, flops, special_frac=0.4)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def elementwise(dev: Device, n_elements: int, flops_per_elt: float = 1.0,
                n_in: int = 1, bytes_elt: int = 2,
                name: str = "elementwise") -> OpResult:
    bytes_ = n_elements * (n_in + 1) * bytes_elt
    mem_t = bytes_ / dev.memory_bandwidth
    flops = flops_per_elt * n_elements
    cmp_t = _vector_time(dev, flops)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def fused_epilogue(dev: Device, spec) -> tuple:
    """(seconds, flops) an op adds when fused into a producing matmul's
    epilogue (DESIGN.md §9).

    The epilogue runs tile-by-tile on the vector units after the GEMM
    mainloop: its input arrives in on-chip buffers (no HBM read), its
    launch overhead is amortized into the GEMM's, and — by the fusion
    pass's construction — its output write replaces the GEMM's C write
    (already repriced via the fused spec's bytes_out). What remains is the
    vector-unit compute, with the same special-function ratios and
    row-parallel utilization as the standalone models; softmax runs its
    online single-pass form by construction (the flash-attention trick), so
    the spill second-read never happens.
    """
    from .ir import ElementwiseSpec, NormSpec, SoftmaxSpec
    if isinstance(spec, SoftmaxSpec):
        n = spec.rows * spec.cols
        flops = 4.0 * n
        return (_vector_time(dev, flops, special_frac=0.25)
                / _row_parallel_util(dev, spec.rows), flops)
    if isinstance(spec, NormSpec):
        n = spec.rows * spec.cols
        flops = (8.0 if spec.kind == "layernorm" else 4.0) * n
        return (_vector_time(dev, flops, special_frac=0.05)
                / _row_parallel_util(dev, spec.rows), flops)
    if isinstance(spec, ElementwiseSpec):
        if spec.kind == "gelu":
            flops = 10.0 * spec.n_elements
            return _vector_time(dev, flops, special_frac=0.5), flops
        if spec.kind == "silu_mul":
            flops = 6.0 * spec.n_elements
            return _vector_time(dev, flops, special_frac=0.4), flops
        flops = spec.flops_per_elt * spec.n_elements
        return _vector_time(dev, flops), flops
    raise TypeError(f"cannot fuse {type(spec).__name__} as an epilogue")


def memory_traffic(dev: Device, bytes_: float, name: str = "io") -> OpResult:
    """Pure data movement (e.g. KV-cache append, embedding gather)."""
    mem_t = bytes_ / dev.memory_bandwidth
    return _finish(name, dev, 0.0, mem_t, 0.0, bytes_)


def recurrent_scan(dev: Device, seq: int, batch: int, d_state: float,
                   flops_per_step: float, bytes_io: float,
                   chunk: int = 128, name: str = "scan") -> OpResult:
    """Linear-recurrence scan (RWKV6 WKV / RG-LRU) — paper-model extension.

    Modeled as a chunked scan: inside a chunk the state stays in the local
    buffer (vector compute); between chunks the carry is tiny. IO = stream the
    inputs/outputs once. Not in the paper's operator set (it models dense
    transformer ops); flagged in DESIGN.md Sec. 5.
    """
    mem_t = bytes_io / dev.memory_bandwidth
    cmp_t = _vector_time(dev, flops_per_step * seq * batch, special_frac=0.2)
    # sequential dependency floor: chunks pipeline across batch*heads, but a
    # single (batch, head) chain is seq/chunk sequential carries deep
    chain = (seq / chunk) * (d_state / max(dev.core.lane.vector_unit.width, 1)
                             ) / dev.frequency_hz
    cmp_t = max(cmp_t, chain)
    return _finish(name, dev, cmp_t, mem_t, flops_per_step * seq * batch,
                   bytes_io)
