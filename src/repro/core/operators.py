"""Operator-level performance models (paper Sec. III-B1/B3).

Matmul delegates to the mapper search. Softmax / LayerNorm / GELU follow the
same tile-by-tile methodology minus the systolic array: fewer dimensions, no
MXU, vector-unit compute, special-function throughput for exp/tanh/rsqrt.
Softmax uses the online algorithm [Milakov & Gimelshein], GELU the tanh
approximation [Hendrycks & Gimpel] — as in the paper.

Every model returns an OpResult carrying latency, flops, bytes and the
binding resource, so graph-level accounting (and the roofline comparison)
stays interpretable — the paper's "no fudge factors" principle.

Quantities are unit-annotated (core/units.py, DESIGN.md §12): per-element
flop rates are module constants typed ``FlopsPerElement`` so ``rate * n``
is provably ``Flops``, and every ``_finish`` argument is dimension-checked
by ``python -m repro.unitcheck``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from .hardware import Device
from .mapper import Mapping, matmul_perf
from .units import Bytes, BytesPerElement, Cycles, Elements, Flops, \
    FlopsPerElement, FlopsPerSecond, Ratio, Seconds

#: per-element flop counts of the vector-op models (paper Sec. III-B3)
SOFTMAX_FLOPS_PER_ELT: FlopsPerElement = 4.0    # exp + max/accum/divide
LAYERNORM_FLOPS_PER_ELT: FlopsPerElement = 8.0  # Welford + (x-mu)*rsqrt*g+b
RMSNORM_FLOPS_PER_ELT: FlopsPerElement = 4.0    # x*x accum + x*rsqrt(ms)*g
GELU_FLOPS_PER_ELT: FlopsPerElement = 10.0      # tanh approximation
SILU_MUL_FLOPS_PER_ELT: FlopsPerElement = 6.0   # silu(a) * b

#: cross-chunk norm partials are staged in fp32 (4 bytes per value). The
#: pre-unitcheck code charged 8 bytes per fp32 value here — a units bug the
#: dimensional-analysis annotation surfaced; fixing it halves the chunked-
#: reduction byte penalty (only visible when a row exceeds the local buffer).
FP32_BYTES: BytesPerElement = 4.0


@dataclass(frozen=True)
class OpResult:
    name: str
    latency: Seconds                # incl. launch overhead
    flops: Flops
    main_memory_bytes: Bytes
    bound: str                      # compute | memory | overhead | link
    mapping: Optional[Mapping] = None

    def __add__(self, other: "OpResult") -> "OpResult":
        # the dominant (slower) operand decides the bound and contributes its
        # mapping, so combined results keep their Pallas BlockSpec hints
        dom, sub = (self, other) if self.latency >= other.latency \
            else (other, self)
        return OpResult(
            name=f"{self.name}+{other.name}",
            latency=self.latency + other.latency,
            flops=self.flops + other.flops,
            main_memory_bytes=self.main_memory_bytes + other.main_memory_bytes,
            bound=dom.bound,
            mapping=dom.mapping if dom.mapping is not None else sub.mapping,
        )


ZERO = OpResult("zero", 0.0, 0, 0, "overhead")


def _finish(name: str, dev: Device, compute_t: Seconds, mem_t: Seconds,
            flops: Flops, bytes_: Bytes,
            mapping: Optional[Mapping] = None) -> OpResult:
    body: Seconds = max(compute_t, mem_t)  # vector ops pipeline with compute
    lat: Seconds = body + dev.kernel_launch_overhead_s
    if dev.kernel_launch_overhead_s > body:
        bound = "overhead"
    elif compute_t >= mem_t:
        bound = "compute"
    else:
        bound = "memory"
    return OpResult(name, lat, flops, bytes_, bound, mapping)


def matmul(dev: Device, m: int, k: int, n: int, batch: int = 1,
           bytes_a: BytesPerElement = 2, bytes_b: BytesPerElement = 2,
           bytes_out: BytesPerElement = 2, bytes_acc: BytesPerElement = 2,
           b_shared: bool = False, mac_scale: Ratio = 1.0,
           name: str = "matmul") -> OpResult:
    r = matmul_perf(dev, m, k, n, batch=batch, bytes_a=bytes_a,
                    bytes_b=bytes_b, bytes_out=bytes_out, bytes_acc=bytes_acc,
                    b_shared=b_shared, mac_scale=mac_scale)
    return OpResult(name, r.latency + dev.kernel_launch_overhead_s, r.flops,
                    r.main_memory_bytes, r.mapping.bound, r.mapping)


def _vector_time(dev: Device, flops: Flops,
                 special_frac: Ratio = 0.0) -> Seconds:
    """Time for elementwise/reduction work on the vector units.

    special_frac: fraction of operations that are special functions
    (exp/tanh/rsqrt), which run at VectorUnit.special_ratio of peak.
    """
    peak: FlopsPerSecond = dev.peak_vector_flops
    sp: Ratio = dev.core.lane.vector_unit.special_ratio
    return flops * ((1 - special_frac) + special_frac / sp) / peak


def _row_parallel_util(dev: Device, rows: int) -> Ratio:
    """Row-parallel ops (softmax/norms) assign rows to cores: with fewer
    rows than cores, the idle cores cannot help — the paper's Fig. 5d trend
    (throughput drops at extreme reduction dims) comes from exactly this."""
    return min(1.0, rows / dev.core_count)


def softmax(dev: Device, rows: int, cols: int, bytes_in: BytesPerElement = 2,
            bytes_out: BytesPerElement = 2,
            name: str = "softmax") -> OpResult:
    """Row-wise softmax on (rows, cols), online algorithm (one read pass for
    running max+sum, one read+write pass to normalize). If a row's working set
    exceeds the global buffer, the second pass re-reads from main memory."""
    n: Elements = rows * cols
    row_bytes: Bytes = cols * bytes_in
    fits = rows * row_bytes <= dev.global_buffer_bytes
    reads = 1 if fits else 2
    bytes_: Bytes = n * (reads * bytes_in + bytes_out)
    mem_t: Seconds = bytes_ / dev.memory_bandwidth
    flops: Flops = SOFTMAX_FLOPS_PER_ELT * n
    cmp_t: Seconds = _vector_time(dev, flops, special_frac=0.25) \
        / _row_parallel_util(dev, rows)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def layernorm(dev: Device, rows: int, cols: int,
              bytes_in: BytesPerElement = 2, bytes_out: BytesPerElement = 2,
              name: str = "layernorm") -> OpResult:
    """Welford-style mean/var + normalize; reduction cost grows with cols.

    When one row exceeds the per-core local buffer, partial stats make extra
    trips through the global buffer — this is what makes throughput *drop* at
    extreme reduction dims (paper Fig. 5d) where a roofline model stays flat.
    """
    n: Elements = rows * cols
    bytes_: Bytes = n * (bytes_in + bytes_out)
    mem_t: Seconds = bytes_ / dev.memory_bandwidth
    flops: Flops = LAYERNORM_FLOPS_PER_ELT * n
    cmp_t: Seconds = _vector_time(dev, flops, special_frac=0.05) \
        / _row_parallel_util(dev, rows)
    # cross-tile reduction penalty: rows are strip-mined into col-chunks that
    # fit a core's local buffer; partial (mean, M2) fp32 pairs traverse the GB
    chunk = max(1, dev.core.local_buffer_bytes // (2 * bytes_in))
    n_chunks = -(-cols // chunk)
    if n_chunks > 1:
        part_elems: Elements = rows * n_chunks * 2   # (mean, M2) per chunk
        part_bytes: Bytes = part_elems * FP32_BYTES
        mem_t += 2 * part_bytes / dev.global_buffer_bandwidth
        combine_flops: Flops = rows * n_chunks * 8.0
        cmp_t += _vector_time(dev, combine_flops) \
            / _row_parallel_util(dev, rows)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def rmsnorm(dev: Device, rows: int, cols: int,
            bytes_in: BytesPerElement = 2, bytes_out: BytesPerElement = 2,
            name: str = "rmsnorm") -> OpResult:
    """RMSNorm: sum-of-squares reduction + x * rsqrt(ms) * g.

    First-class model (no layernorm fudge factors): one fused read pass
    accumulates the sum of squares and normalizes, ~4 flops/element (square-
    accumulate, scale, one rsqrt per row amortized). The chunked-reduction
    penalty is the same mechanism as layernorm's — rows strip-mined into
    col-chunks that fit a core's local buffer — but each chunk carries a
    single fp32 partial (sum of squares) instead of a (mean, M2) pair.
    """
    n: Elements = rows * cols
    bytes_: Bytes = n * (bytes_in + bytes_out)
    mem_t: Seconds = bytes_ / dev.memory_bandwidth
    flops: Flops = RMSNORM_FLOPS_PER_ELT * n
    cmp_t: Seconds = _vector_time(dev, flops, special_frac=0.05) \
        / _row_parallel_util(dev, rows)
    chunk = max(1, dev.core.local_buffer_bytes // (2 * bytes_in))
    n_chunks = -(-cols // chunk)
    if n_chunks > 1:
        part_elems: Elements = rows * n_chunks     # one fp32 partial / chunk
        part_bytes: Bytes = part_elems * FP32_BYTES
        mem_t += 2 * part_bytes / dev.global_buffer_bandwidth
        combine_flops: Flops = rows * n_chunks * 4.0
        cmp_t += _vector_time(dev, combine_flops) \
            / _row_parallel_util(dev, rows)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def gelu(dev: Device, n_elements: Elements, bytes_in: BytesPerElement = 2,
         bytes_out: BytesPerElement = 2, name: str = "gelu") -> OpResult:
    """tanh-approximated GELU: ~10 flops/element, half special."""
    bytes_: Bytes = n_elements * (bytes_in + bytes_out)
    mem_t: Seconds = bytes_ / dev.memory_bandwidth
    flops: Flops = GELU_FLOPS_PER_ELT * n_elements
    cmp_t: Seconds = _vector_time(dev, flops, special_frac=0.5)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def silu_mul(dev: Device, n_elements: Elements,
             bytes_in: BytesPerElement = 2, bytes_out: BytesPerElement = 2,
             name: str = "silu_mul") -> OpResult:
    """SwiGLU gate: silu(a) * b — reads two operands."""
    bytes_: Bytes = n_elements * (2 * bytes_in + bytes_out)
    mem_t: Seconds = bytes_ / dev.memory_bandwidth
    flops: Flops = SILU_MUL_FLOPS_PER_ELT * n_elements
    cmp_t: Seconds = _vector_time(dev, flops, special_frac=0.4)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def elementwise(dev: Device, n_elements: Elements,
                flops_per_elt: FlopsPerElement = 1.0, n_in: int = 1,
                bytes_elt: BytesPerElement = 2,
                name: str = "elementwise") -> OpResult:
    bytes_: Bytes = n_elements * (n_in + 1) * bytes_elt
    mem_t: Seconds = bytes_ / dev.memory_bandwidth
    flops: Flops = flops_per_elt * n_elements
    cmp_t: Seconds = _vector_time(dev, flops)
    return _finish(name, dev, cmp_t, mem_t, flops, bytes_)


def fused_epilogue(dev: Device, spec: object) -> Tuple[Seconds, Flops]:
    """(seconds, flops) an op adds when fused into a producing matmul's
    epilogue (DESIGN.md §9).

    The epilogue runs tile-by-tile on the vector units after the GEMM
    mainloop: its input arrives in on-chip buffers (no HBM read), its
    launch overhead is amortized into the GEMM's, and — by the fusion
    pass's construction — its output write replaces the GEMM's C write
    (already repriced via the fused spec's bytes_out). What remains is the
    vector-unit compute, with the same special-function ratios and
    row-parallel utilization as the standalone models; softmax runs its
    online single-pass form by construction (the flash-attention trick), so
    the spill second-read never happens.
    """
    from .ir import ElementwiseSpec, NormSpec, SoftmaxSpec
    if isinstance(spec, SoftmaxSpec):
        n: Elements = spec.rows * spec.cols
        flops: Flops = SOFTMAX_FLOPS_PER_ELT * n
        return (_vector_time(dev, flops, special_frac=0.25)
                / _row_parallel_util(dev, spec.rows), flops)
    if isinstance(spec, NormSpec):
        rate: FlopsPerElement = (LAYERNORM_FLOPS_PER_ELT
                                 if spec.kind == "layernorm"
                                 else RMSNORM_FLOPS_PER_ELT)
        nn: Elements = spec.rows * spec.cols
        nflops: Flops = rate * nn
        return (_vector_time(dev, nflops, special_frac=0.05)
                / _row_parallel_util(dev, spec.rows), nflops)
    if isinstance(spec, ElementwiseSpec):
        if spec.kind == "gelu":
            gflops: Flops = GELU_FLOPS_PER_ELT * spec.n_elements
            return _vector_time(dev, gflops, special_frac=0.5), gflops
        if spec.kind == "silu_mul":
            sflops: Flops = SILU_MUL_FLOPS_PER_ELT * spec.n_elements
            return _vector_time(dev, sflops, special_frac=0.4), sflops
        eflops: Flops = spec.flops_per_elt * spec.n_elements
        return _vector_time(dev, eflops), eflops
    raise TypeError(f"cannot fuse {type(spec).__name__} as an epilogue")


def memory_traffic(dev: Device, bytes_: Bytes, name: str = "io") -> OpResult:
    """Pure data movement (e.g. KV-cache append, embedding gather)."""
    mem_t: Seconds = bytes_ / dev.memory_bandwidth
    return _finish(name, dev, 0.0, mem_t, 0.0, bytes_)


def recurrent_scan(dev: Device, seq: int, batch: int, d_state: float,
                   flops_per_step: float, bytes_io: Bytes,
                   chunk: int = 128, name: str = "scan") -> OpResult:
    """Linear-recurrence scan (RWKV6 WKV / RG-LRU) — paper-model extension.

    Modeled as a chunked scan: inside a chunk the state stays in the local
    buffer (vector compute); between chunks the carry is tiny. IO = stream the
    inputs/outputs once. Not in the paper's operator set (it models dense
    transformer ops); flagged in DESIGN.md Sec. 5.
    """
    mem_t: Seconds = bytes_io / dev.memory_bandwidth
    total_flops: Flops = flops_per_step * seq * batch
    cmp_t: Seconds = _vector_time(dev, total_flops, special_frac=0.2)
    # sequential dependency floor: chunks pipeline across batch*heads, but a
    # single (batch, head) chain is seq/chunk sequential carries deep, one
    # vector-width slice of state per clock
    chain_cycles: Cycles = (seq / chunk) * (
        d_state / max(dev.core.lane.vector_unit.width, 1))
    chain: Seconds = chain_cycles / dev.frequency_hz
    cmp_t = max(cmp_t, chain)
    return _finish(name, dev, cmp_t, mem_t, total_flops, bytes_io)
