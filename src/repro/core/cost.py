"""Cost model (paper Sec. III-D, Table IV).

Per-die cost from supply-chain wafer modeling [Ning et al., ISCA'23]:
dies-per-wafer geometry + defect-limited yield, with a salvage factor for
designs that bin/disable faulty units (A100 ships 108/128 SMs). Memory cost
from spot pricing: the paper's own Table IV implies ~$7/GB HBM2e and
~$0.30/GB DDR5 — we use exactly those.

No IP/mask/packaging costs, matching the paper's scope.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .hardware import Device, GB
from .units import Dollars, Mm2, Ratio

WAFER_COST_7NM_USD: Dollars = 9346.0  # TSMC N7, public supply-chain estimate
WAFER_DIAMETER_MM = 300.0
DEFECT_DENSITY_PER_MM2 = 0.001       # ~0.1 defects/cm^2 (mature N7)
SALVAGE_YIELD: Ratio = 0.90          # binning recovers most defective dies
HBM_USD_PER_GB: Dollars = 7.0        # per GB of HBM2e
DDR_USD_PER_GB: Dollars = 0.30       # per GB of DDR5


def dies_per_wafer(die_area_mm2: Mm2) -> int:
    """Standard DPW geometry: area term minus edge-loss term."""
    d = WAFER_DIAMETER_MM
    return int(math.pi * (d / 2) ** 2 / die_area_mm2
               - math.pi * d / math.sqrt(2.0 * die_area_mm2))


def die_yield(die_area_mm2: Mm2, salvage: bool = True) -> Ratio:
    """Poisson defect yield; salvage floors it for redundancy-binned designs."""
    y = math.exp(-DEFECT_DENSITY_PER_MM2 * die_area_mm2)
    if salvage:
        y = max(y, SALVAGE_YIELD)
    return y


def die_cost(die_area_mm2: Mm2, salvage: bool = True) -> Dollars:
    dpw = dies_per_wafer(die_area_mm2)
    return WAFER_COST_7NM_USD / (dpw * die_yield(die_area_mm2, salvage))


def memory_cost(device: Device) -> Dollars:
    if device.main_memory is None:
        return 0.0
    gb = device.main_memory.capacity_bytes / GB
    if "HBM" in device.main_memory.protocol.upper():
        return gb * HBM_USD_PER_GB
    return gb * DDR_USD_PER_GB


@dataclass
class CostReport:
    die_area_mm2: Mm2
    die_cost_usd: Dollars
    memory_cost_usd: Dollars

    @property
    def total_usd(self) -> Dollars:
        return self.die_cost_usd + self.memory_cost_usd


def device_cost(device: Device, die_area_mm2: Mm2) -> CostReport:
    return CostReport(die_area_mm2=die_area_mm2,
                      die_cost_usd=die_cost(die_area_mm2),
                      memory_cost_usd=memory_cost(device))
