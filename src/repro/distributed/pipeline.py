"""Pipeline parallelism over the `pod` axis (GPipe schedule via shard_map).

The stacked-units parameter layout (models/lm.py) makes PP natural: the
unit axis shards across `pod` — each pod holds n_units/P consecutive units
— and activations travel pod->pod with collective_permute. The microbatch
loop keeps all stages busy after the fill phase (paper Sec. II-C:
"pipeline parallelism ... increasing throughput at the expense of
latency").

This is the optional PP path (launch/train.py --pp); the default dry-run
plan uses the pod axis for data parallelism (DESIGN.md Sec. 6).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_fn: Callable, params_stacked, x,
                   n_microbatches: int):
    """Run x through all pipeline stages.

    stage_fn(stage_params, x) -> x  applies this pod's units.
    params_stacked: pytree with leading unit axis, sharded P("pod", ...).
    x: (B, ...) activations, B % n_microbatches == 0.
    """
    n_stages = mesh.shape["pod"]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("pod"), P(None)),
        out_specs=P(None),
        check_rep=False)
    def run(local_params, x_full):
        stage = lax.axis_index("pod")
        B = x_full.shape[0]
        mb = B // n_microbatches
        xs = x_full.reshape(n_microbatches, mb, *x_full.shape[1:])
        n_ticks = n_microbatches + n_stages - 1
        out = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, out = carry            # buf: activation entering this stage
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < n_microbatches)
            # stage 0 feeds from the input stream
            inject = xs[jnp.clip(mb_idx, 0, n_microbatches - 1)]
            cur = jnp.where(stage == 0, inject, buf)
            y = stage_fn(local_params, cur)
            y = jnp.where(active, y, buf)
            # last stage writes the result
            out = jnp.where(
                (stage == n_stages - 1) & active,
                out.at[jnp.clip(mb_idx, 0, n_microbatches - 1)].set(y), out)
            # pass activations to the next stage
            nxt = lax.ppermute(y, "pod",
                               [(i, (i + 1) % n_stages)
                                for i in range(n_stages)])
            return (nxt, out), None

        buf0 = jnp.zeros(xs.shape[1:], xs.dtype)
        (buf, out), _ = lax.scan(tick, (buf0, out), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via psum over pod
        out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
        out = lax.psum(out, "pod")
        return out.reshape(B, *x_full.shape[1:])

    return run(params_stacked, x)
