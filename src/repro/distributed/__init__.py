from . import sharding, fault_tolerance, pipeline
from .sharding import (param_shardings, opt_state_shardings, data_shardings,
                       cache_shardings, param_spec, batch_spec)
from .fault_tolerance import (RestartManifest, remesh, StepMonitor,
                              FailureInjector)

__all__ = ["sharding", "fault_tolerance", "pipeline", "param_shardings",
           "opt_state_shardings", "data_shardings", "cache_shardings",
           "param_spec", "batch_spec", "RestartManifest", "remesh",
           "StepMonitor", "FailureInjector"]
