"""Fault tolerance: restart manifests, elastic re-meshing, straggler
mitigation hooks.

At 1000+ nodes the failure model is: a host (or its chips) disappears
mid-run. Recovery path here:
  1. every K steps the trainer commits (checkpoint, RestartManifest);
  2. on failure the launcher restarts on the surviving slice, calls
     remesh() — a fresh mesh from whatever devices exist now — and
     restores the checkpoint re-sharded onto it (Checkpointer.restore
     takes the new shardings);
  3. the data pipeline is a pure function of step, so skipping to
     manifest.step is exact — no data loss or duplication;
  4. straggler mitigation: StepMonitor tracks a rolling step-time
     distribution; steps beyond `threshold_sigma` trigger the
     on_straggler callback (re-batch away from the slow host / alert).
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import asdict, dataclass
from typing import Callable, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh


@dataclass
class RestartManifest:
    step: int
    data_step: int
    mesh_shape: dict
    rng_seed: int
    wall_time: float = 0.0

    def save(self, path: str):
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(asdict(self), f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "RestartManifest":
        with open(path) as f:
            return cls(**json.load(f))


def remesh(devices=None, model_parallel: int = 1,
           pods: int = 1) -> Mesh:
    """Build the largest (pod, data, model) mesh from surviving devices.

    Drops devices that no longer divide evenly — elastic down-scaling."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    model = math.gcd(model_parallel, n)
    per_pod = n // pods
    usable_per_pod = (per_pod // model) * model
    usable = usable_per_pod * pods
    devices = devices[:usable]
    data = usable_per_pod // model
    arr = np.array(devices).reshape(pods, data, model)
    return Mesh(arr, ("pod", "data", "model"))


class StepMonitor:
    """Rolling step-time stats + straggler detection."""

    def __init__(self, window: int = 50, threshold_sigma: float = 3.0,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.window = window
        self.sigma = threshold_sigma
        self.times: List[float] = []
        self.on_straggler = on_straggler
        self.straggler_steps: List[int] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        hist = self.times[-self.window:]
        if len(hist) >= 10:
            mu = float(np.mean(hist))
            sd = float(np.std(hist)) + 1e-9
            if dt > mu + self.sigma * sd:
                self.straggler_steps.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt)
        self.times.append(dt)
        return dt


class FailureInjector:
    """Test hook: raise at a chosen step to exercise restart-recovery."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def check(self, step: int):
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.fired):
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")
