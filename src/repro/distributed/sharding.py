"""Sharding rules: DP / TP / SP / EP / (PP via pipeline.py) on the
(pod, data, model) mesh.

The layout is Megatron-style TP (paper Fig. 2: column-parallel up
projections, row-parallel down projections, two all-reduces per layer) with
these extensions beyond the paper (recorded for EXPERIMENTS.md §Perf):
  * sequence-parallel activations (reduce-scatter + all-gather instead of
    all-reduce) — `mode="sp"`;
  * expert parallelism: MoE expert tensors shard (E, d, f) ->
    ("data", None, "model"), so dispatch lowers to an all-to-all over the
    data axis;
  * ZeRO-style optimizer-state sharding over the data axis.

Rules match on parameter path names and apply to the *trailing* dims —
stacked-unit leading axes (models/lm.py) are skipped automatically.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP_AXES = ("pod", "data")     # batch / expert / ZeRO axes
TP_AXIS = "model"

# (regex on path, candidate trailing-dim specs — first fully-valid wins)
_PARAM_RULES = [
    (r"embed$", [("model", None)]),               # vocab-parallel
    (r"head$", [(None, "model")]),
    (r"(wq|wk|wv)$", [(None, "model")]),          # column-parallel
    (r"(bq|bk|bv)$", [("model",)]),
    (r"wo$", [("model", None)]),                  # row-parallel
    (r"moe/router$", [(None, None)]),
    # EP x TP; when n_experts doesn't divide the data axis (grok: 8 experts
    # on 16-wide data) fall back to sharding the d_model dim over (pod,)data
    (r"moe/w_(up|gate)$", [("data", None, "model"),
                           (None, ("pod", "data"), "model"),
                           (None, "data", "model"),
                           (None, None, "model")]),
    (r"moe/w_down$", [("data", "model", None),
                      (None, "model", ("pod", "data")),
                      (None, "model", "data"),
                      (None, "model", None)]),
    (r"mlp/w_(up|gate)$", [(None, "model")]),
    (r"mlp/w_down$", [("model", None)]),
    (r"(wr|wg)$", [(None, "model")]),             # rwkv head-parallel
    (r"tmix/wo$", [("model", None)]),
    (r"cmix/w_up$", [(None, "model")]),
    (r"cmix/w_down$", [("model", None)]),
    (r"(w_gate|w_in)$", [(None, "model")]),       # rglru channel-parallel
    (r"(w_a|w_x)$", [(None, "model")]),
    (r"rec/w_out$", [("model", None)]),
    (r"conv_w$", [(None, "model")]),
    (r"conv_b$", [("model",)]),
    (r"lam$", [("model",)]),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def _axis_ok(mesh: Mesh, axis, dim: int) -> bool:
    if axis is None:
        return True
    size = int(np.prod([mesh.shape[a] for a in
                        (axis if isinstance(axis, tuple) else (axis,))]))
    return dim % size == 0 and dim >= size


def _filter_axes(mesh: Mesh, axis):
    """Drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh)."""
    if axis is None:
        return None
    axes = axis if isinstance(axis, tuple) else (axis,)
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


SMALL_EXPERT_BYTES = 1 << 30    # <1 GiB: TP-only sharding is plenty

# set per-arch by the launcher (set_model_config): kv-head divisibility
# decides whether k/v projections shard or replicate under TP
_ACTIVE_CFG = None


def set_model_config(cfg):
    global _ACTIVE_CFG
    _ACTIVE_CFG = cfg


def param_spec(mesh: Mesh, path, leaf) -> P:
    """PartitionSpec for one parameter leaf."""
    s = _path_str(path)
    shape = leaf.shape
    # GQA: when n_kv_heads doesn't divide the TP axis, sharding wk/wv cuts
    # across head boundaries and every attention all-gathers K/V — cheaper
    # to replicate the (small) kv projections and compute them redundantly
    if _ACTIVE_CFG is not None and re.search(r"attn/(wk|wv|bk|bv)$", s):
        tp = mesh.shape.get(TP_AXIS, 1)
        if _ACTIVE_CFG.n_kv_heads and _ACTIVE_CFG.n_kv_heads % tp != 0:
            return P()
    # small MoE expert tensors (granite: 40 x 1536 x 512) stay TP-only —
    # d-sharding them conflicts with the capacity-sharded dispatch buffers
    # and forces resharding of every expert block
    if re.search(r"moe/w_(up|down|gate)$", s):
        import numpy as _np
        if int(_np.prod(shape)) * 2 < SMALL_EXPERT_BYTES:
            tp_dim = len(shape) - 2 if s.endswith("w_down") else len(shape) - 1
            spec = [None] * len(shape)
            if _axis_ok(mesh, TP_AXIS, shape[tp_dim]):
                spec[tp_dim] = TP_AXIS
            if _axis_ok(mesh, "data", shape[-3]) and shape[-3] > 1:
                spec[-3] = "data"       # E over data when it divides
            return P(*spec)
    for pat, candidates in _PARAM_RULES:
        if not re.search(pat, s):
            continue
        for trailing in candidates:
            nlead = len(shape) - len(trailing)
            if nlead < 0:
                continue
            spec = [None] * nlead + [_filter_axes(mesh, a) for a in trailing]
            if all(_axis_ok(mesh, a, shape[i]) for i, a in enumerate(spec)):
                return P(*spec)
        # last resort: the first candidate with invalid axes dropped
        trailing = candidates[0]
        nlead = len(shape) - len(trailing)
        spec = [None] * max(nlead, 0) + [_filter_axes(mesh, a)
                                         for a in trailing][:len(shape)]
        spec = [a if _axis_ok(mesh, a, shape[i]) else None
                for i, a in enumerate(spec)]
        return P(*spec)
    return P()     # replicate (norms, small vectors)


def param_shardings(mesh: Mesh, abstract_params):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_spec(mesh, p, l)),
        abstract_params)


def zero_spec(mesh: Mesh, path, leaf) -> P:
    """ZeRO: additionally shard a replicated dim of the optimizer state
    over the (pod,)data axes — fp32 master/moment tensors dominate training
    memory, and unlike params they are touched once per step."""
    base = param_spec(mesh, path, leaf)
    spec = list(base) + [None] * (len(leaf.shape) - len(base))
    used = set()
    for a in spec:
        for ax in (a if isinstance(a, tuple) else (a,)):
            if ax:
                used.add(ax)
    free = tuple(a for a in ("pod", "data") if a in mesh.shape
                 and a not in used)
    if free:
        for i, a in enumerate(spec):
            if a is None and _axis_ok(mesh, free, leaf.shape[i]):
                spec[i] = free if len(free) > 1 else free[0]
                break
        else:
            for i, a in enumerate(spec):
                if a is None and _axis_ok(mesh, free[0], leaf.shape[i]):
                    spec[i] = free[0]
                    break
    return P(*spec)


def opt_state_shardings(mesh: Mesh, abstract_opt):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, zero_spec(mesh, p, l)),
        abstract_opt)


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Shard batch over (pod, data) when divisible; fall back gracefully."""
    axes = [a for a in DP_AXES if a in mesh.shape]
    full = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % full == 0:
        return P(tuple(axes))
    if "data" in mesh.shape and batch % mesh.shape["data"] == 0:
        return P("data")
    return P()


def _batch_axes(mesh: Mesh, batch: int):
    """Mesh axes (tuple) to shard a batch dim over, or None."""
    axes = tuple(a for a in DP_AXES if a in mesh.shape)
    full = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % full == 0 and batch >= full:
        return axes
    if "data" in mesh.shape and batch % mesh.shape["data"] == 0 \
            and batch >= mesh.shape["data"]:
        return ("data",)
    return None


def data_shardings(mesh: Mesh, specs: dict):
    """Shardings for input_specs dicts (tokens/targets/mask/frontend)."""
    out = {}
    for name, sds in specs.items():
        ba = _batch_axes(mesh, sds.shape[0])
        spec = [ba] + [None] * (len(sds.shape) - 1)
        out[name] = NamedSharding(mesh, P(*spec))
    return out


def cache_spec(mesh: Mesh, path, leaf, batch: int,
               kv_mode: str = "channel") -> P:
    """KV caches / recurrent state: batch over DP axes + one TP dim.

    kv_mode="channel" (paper-faithful Megatron layout): the fused H*dh
    channel dim shards over model — decode attention all-reduces partial
    scores over the channel shards.
    kv_mode="sequence" (beyond-paper, FlashDecoding-style split-KV): the
    TIME dim shards over model — each shard computes online-softmax partials
    over its positions and the combine is a tiny (B, H) all-reduce.
    """
    s = _path_str(path)
    shape = leaf.shape
    if s.endswith("pos") or s.endswith("enc_out"):
        ba = _batch_axes(mesh, shape[0]) if shape else None
        return P(*([ba] + [None] * (len(shape) - 1))) if shape else P()
    spec = [None] * len(shape)
    n = len(shape)
    is_kv = s.endswith("/k") or s.endswith("/v") or s.endswith("xk") \
        or s.endswith("xv")
    if s.endswith("state"):
        tp_try = [n - 3, n - 2]           # rwkv state (.., H, N, N)
    elif is_kv and kv_mode == "sequence" and n >= 3:
        tp_try = [n - 2]                  # time axis of (.., T, H*dh)
    else:
        tp_try = [n - 1]                  # fused kv channels / (.., d)
    for i, d in enumerate(shape):
        if d == batch:
            spec[i] = _batch_axes(mesh, batch)
            for j in tp_try:
                if j > i and _axis_ok(mesh, TP_AXIS, shape[j]) and shape[j] > 1:
                    spec[j] = TP_AXIS
                    break
            break
    return P(*spec)


def cache_shardings(mesh: Mesh, abstract_cache, batch: int,
                    kv_mode: str = "channel"):
    if kv_mode == "auto":
        # measured policy (EXPERIMENTS.md §Perf): channel sharding is free
        # when kv-heads divide TP (the per-head layout never crosses
        # shards); otherwise sequence sharding (FlashDecoding split-KV)
        # cuts decode collectives 16-883x
        tp = mesh.shape.get(TP_AXIS, 1)
        kvh = getattr(_ACTIVE_CFG, "n_kv_heads", 0) if _ACTIVE_CFG else 0
        kv_mode = "channel" if (kvh and kvh % tp == 0) else "sequence"
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_spec(mesh, p, l, batch,
                                                    kv_mode)),
        abstract_cache)
