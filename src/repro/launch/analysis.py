"""Compiled-HLO analysis for the roofline report.

cost_analysis() provides FLOPs and HBM bytes. Collective bytes are NOT in
cost_analysis — we parse the optimized (post-SPMD) HLO text and sum operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, scaling instructions inside while-loop bodies by the
loop trip count (the scan-over-units puts the per-layer collectives inside
a while body executed n_units times).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS_RE = re.compile(r"(?:body|condition|to_apply|branch_computations)="
                       r"[{]?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _instr_collective_bytes(line: str, kind: str) -> int:
    """Bytes moved by one collective instruction (per device).

    Optimized HLO shows operands as bare names, so we read the RESULT type
    (the segment between '=' and the op name). For all-reduce / all-gather /
    all-to-all / collective-permute the result size is the data volume; for
    reduce-scatter the input volume is result x group_size.
    """
    m = re.search(rf"=\s+(.*?)\s+{kind}(?:-start)?\(", line)
    if not m:
        return 0
    result_seg = m.group(1)
    total = 0
    for dm in _SHAPE_RE.finditer(result_seg):
        total += _shape_bytes(dm.group(1), dm.group(2))
    if kind == "reduce-scatter":
        gm = _GROUPS_RE.search(line)
        if gm:
            total *= int(gm.group(2))
    return total


@dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_kind: Dict[str, float] = field(default_factory=dict)
    count: int = 0


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective operand bytes, loop-trip-count aware."""
    # split into computations
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$",
                     line)
        if m:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)

    # direct collective bytes per computation
    direct: Dict[str, CollectiveStats] = {}
    calls: Dict[str, list] = {}
    trip: Dict[str, int] = {}
    for name, lines in comps.items():
        st = CollectiveStats()
        calls[name] = []
        for line in lines:
            low = line.strip()
            if any(c in low for c in _COLLECTIVES) and "(" in low \
                    and "-done" not in low:
                for kind in _COLLECTIVES:
                    if re.search(rf"\b{kind}(?:-start)?\(", low):
                        b = _instr_collective_bytes(low, kind)
                        st.total_bytes += b
                        st.by_kind[kind] = st.by_kind.get(kind, 0.0) + b
                        st.count += 1
                        break
            if " while(" in low or low.startswith("while("):
                tm = _TRIP_RE.search(low)
                t = int(tm.group(1)) if tm else 1
                for cm in _CALLS_RE.finditer(low):
                    callee = cm.group(1)
                    calls[name].append((callee, t))
                    trip[callee] = max(trip.get(callee, 1), t)
            else:
                for cm in _CALLS_RE.finditer(low):
                    calls[name].append((cm.group(1), 1))
        direct[name] = st

    # propagate bottom-up from ENTRY (assume DAG of computations)
    import functools

    @functools.lru_cache(maxsize=None)
    def total(name: str) -> tuple:
        st = direct.get(name, CollectiveStats())
        tb = st.total_bytes
        kinds = dict(st.by_kind)
        cnt = st.count
        for callee, t in calls.get(name, []):
            if callee == name or callee not in comps:
                continue
            ctb, ckinds, ccnt = total(callee)
            tb += t * ctb
            cnt += t * ccnt
            for k, v in ckinds:
                kinds[k] = kinds.get(k, 0.0) + t * v
        return tb, tuple(sorted(kinds.items())), cnt

    entry = None
    for name in comps:
        if "main" in name or entry is None:
            pass
    # ENTRY computation: the one marked ENTRY in the original text
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else next(iter(comps), None)
    if entry is None:
        return CollectiveStats()
    tb, kinds, cnt = total(entry)
    return CollectiveStats(total_bytes=tb, by_kind=dict(kinds), count=cnt)


_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "copy", "copy-start", "copy-done", "after-all", "partition-id",
             "replica-id", "iota", "broadcast", "reshape"}

_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def hlo_costs(hlo_text: str) -> dict:
    """Trip-count-aware FLOPs and HBM-traffic estimate from optimized HLO.

    compiled.cost_analysis() counts while-loop bodies ONCE — our layer stack
    is a scan, so it undercounts by ~n_layers. This pass multiplies each
    computation's costs by its loop trip count (known_trip_count backend
    config) instead.

    flops: dot instructions only (2 * prod(result) * prod(contract dims));
    elementwise flops are <1% for transformer workloads and are ignored.
    bytes: sum of (operand + result) sizes at instruction/fusion boundaries
    — fusion boundaries are materialization points, i.e. an HBM-traffic
    model in the paper's own spirit (tile-level, not cycle-level).
    """
    shapes: Dict[str, tuple] = {}      # name -> (dtype, dims list) of result
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$",
                     line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        im = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$", line)
        if im:
            name, rest = im.group(1), im.group(2)
            tshapes = []
            # result type: everything before the op name token
            head = rest.split("(")[0]
            for sm in _SHAPE_RE.finditer(head):
                tshapes.append((sm.group(1), sm.group(2)))
            if tshapes:
                shapes[name] = tshapes

    def result_bytes(name):
        return sum(_shape_bytes(dt, dm) for dt, dm in shapes.get(name, []))

    def line_cost(line):
        im = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$", line)
        if not im:
            return 0.0, 0.0
        name, rest = im.group(1), im.group(2)
        op_m = re.search(r"\)\s*|\]\}?\s*", rest)
        tokens = rest.split("(")[0].strip().split()
        op = tokens[-1] if tokens else ""
        if op in _SKIP_OPS or not op:
            return 0.0, 0.0
        flops = 0.0
        if op == "dot":
            res = shapes.get(name, [])
            n_res = 0
            for dt, dm in res:
                n = 1
                for d in dm.split(","):
                    if d:
                        n *= int(d)
                n_res += n
            cm = _DOT_CONTRACT_RE.search(rest)
            k = 1
            if cm:
                # lhs operand shape
                args = rest[rest.index("("):]
                om = _OPND_RE.search(args)
                if om and om.group(1) in shapes:
                    dims = shapes[om.group(1)][0][1].split(",")
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(dims) and dims[int(ci)]:
                            k *= int(dims[int(ci)])
            flops = 2.0 * n_res * k
        # bytes: operands + result, with slicing ops counted at the size
        # they actually touch (a dynamic-slice of the stacked layer params
        # reads one layer, not the whole stack)
        args = rest[rest.index("("):] if "(" in rest else ""
        opnds = [om.group(1) for om in _OPND_RE.finditer(args)]
        if op in ("dynamic-slice", "gather", "slice"):
            b = 2.0 * result_bytes(name)
        elif op == "dynamic-update-slice":
            upd = result_bytes(opnds[1]) if len(opnds) > 1 else 0
            b = 2.0 * upd
        elif op == "scatter":
            upd = result_bytes(opnds[2]) if len(opnds) > 2 else 0
            b = 2.0 * upd
        elif op == "while":
            b = 0.0          # body costs propagate via trip counts
        else:
            b = result_bytes(name)
            for o in opnds:
                b += result_bytes(o)
        return flops, float(b)

    direct: Dict[str, tuple] = {}
    calls: Dict[str, list] = {}
    for cname, lines in comps.items():
        f = b = 0.0
        calls[cname] = []
        for line in lines:
            lf, lb = line_cost(line)
            f += lf
            b += lb
            if " while(" in line or line.strip().startswith("while("):
                tm = _TRIP_RE.search(line)
                t = int(tm.group(1)) if tm else 1
                for cm2 in _CALLS_RE.finditer(line):
                    calls[cname].append((cm2.group(1), t))
            else:
                for cm2 in _CALLS_RE.finditer(line):
                    calls[cname].append((cm2.group(1), 1))
        direct[cname] = (f, b)

    import functools

    @functools.lru_cache(maxsize=None)
    def total(cname):
        f, b = direct.get(cname, (0.0, 0.0))
        for callee, t in calls.get(cname, []):
            if callee == cname or callee not in comps:
                continue
            cf, cb = total(callee)
            f += t * cf
            b += t * cb
        return f, b

    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else next(iter(comps), None)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0}
    f, b = total(entry)
    return {"flops": f, "bytes": b}


# --- bridge to the symbolic IR (core/ir.py) --------------------------------

_KIND_TO_SPEC = {"all-reduce": "all_reduce", "all-gather": "all_gather",
                 "reduce-scatter": "reduce_scatter",
                 "all-to-all": "all_to_all", "collective-permute": "p2p"}


def collectives_to_graph(stats: CollectiveStats, n_devices: int):
    """Lower measured per-kind collective bytes into a CollectiveSpec graph.

    The HLO pass counts *data volume per device*; the analytic link model
    (interconnect.py) prices that volume under LogGP + ring/fc topology, so
    the same Evaluator that prices a planner sweep can also price a compiled
    program's communication. One node per kind, bytes summed.
    """
    from ..core.ir import CollectiveSpec, Graph, Node
    nodes = []
    for kind, bytes_ in sorted(stats.by_kind.items()):
        spec_kind = _KIND_TO_SPEC.get(kind)
        if spec_kind is None or bytes_ <= 0:
            continue
        nodes.append(Node(CollectiveSpec(spec_kind, bytes_, n_devices),
                          f"hlo_{kind.replace('-', '_')}"))
    return Graph(tuple(nodes))


def predicted_collective_time(system, stats: CollectiveStats,
                              n_devices: int = 0) -> float:
    """Seconds the analytic interconnect model predicts for the measured
    collective traffic of one compiled program execution."""
    from ..core.evaluator import Evaluator
    n = n_devices or system.device_count
    graph = collectives_to_graph(stats, n)
    if not len(graph):
        return 0.0
    return Evaluator(system).evaluate(graph).latency


def cost_summary(compiled) -> dict:
    """Extract flops/bytes from compiled.cost_analysis() (per-device)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes_accessed": bytes_,
            "optimal_seconds": float(ca.get("optimal_seconds", 0.0))}


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0))
    out["total_bytes"] = (out["argument_size_in_bytes"]
                          + out["output_size_in_bytes"]
                          + out["temp_size_in_bytes"]
                          - out["alias_size_in_bytes"])
    return out
