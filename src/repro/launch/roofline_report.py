"""§Roofline generator: three-term roofline per (arch x shape x mesh) from
the dry-run artifacts.

    compute   = HLO_FLOPs_per_chip / 197 TFLOP/s      (trip-count-aware)
    memory    = HBM_bytes_per_chip / 819 GB/s         (LLMCompass model —
                the paper's tile-level traffic accounting; the CPU-HLO
                boundary count is reported alongside as an upper bound)
    collective= collective_bytes_per_chip / 50 GB/s   (per-ICI-link)

MODEL_FLOPS: train = 6*N_active*tokens, prefill = 2*N_active*tokens,
decode = 2*N_active*batch (+ attention KV terms are in HLO, not MODEL —
the ratio shows remat/attention/dispatch overhead).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

from ..configs import SHAPES, get_config
from ..core import hardware as hw
from ..core.evaluator import Evaluator
from ..core.graph import Plan, build_model
from ..core.roofline import (TPU_V5E_PEAK_BF16, TPU_V5E_HBM_BW,
                             TPU_V5E_ICI_BW)

DRYRUN_DIR = "experiments/dryrun"


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq


_SIM = {}
_EVALUATOR = None     # one shared evaluator: specs dedup across the grid


def simulated_hbm_bytes(arch: str, shape) -> float:
    """Per-chip HBM traffic from the LLMCompass model (paper Sec. III-B)."""
    global _EVALUATOR
    key = (arch, shape.name)
    if key in _SIM:
        return _SIM[key]
    cfg = get_config(arch)
    if _EVALUATOR is None:
        _EVALUATOR = Evaluator(hw.tpu_v5e_pod(256))
    plan = Plan(tp=16, dp=16)
    if shape.kind == "decode":
        g = build_model(cfg, plan, batch=max(shape.global_batch // 16, 1),
                        seq=1, kv_len=shape.seq_len)
        bytes_ = _EVALUATOR.evaluate(g).bytes
    else:
        g = build_model(cfg, plan, batch=max(shape.global_batch // 16, 1),
                        seq=shape.seq_len, kv_len=shape.seq_len)
        bytes_ = _EVALUATOR.evaluate(g).bytes
        if shape.kind == "train":
            bytes_ *= 3.5       # bwd + remat re-reads (documented factor)
    _SIM[key] = bytes_
    return bytes_


@dataclass
class Row:
    arch: str
    shape: str
    mesh: str
    fits: bool
    mem_gib: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_ratio: float
    dominant: str
    note: str


NOTES = {
    "compute": "more chips / lower precision / cut remat recompute",
    "memory": "wider batch per chip or KV/weight quantization to raise "
              "arithmetic intensity",
    "collective": "shard KV along sequence / overlap TP collectives (SP) / "
                  "larger per-chip shards",
}


def build_rows(dryrun_dir: str = DRYRUN_DIR):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("skipped") or not rec.get("ok"):
            continue
        arch, shape_name, mesh = rec["arch"], rec["shape"], rec["mesh"]
        shape = SHAPES[shape_name]
        cfg = get_config(arch)
        n_dev = rec["devices"]
        flops_dev = rec["hlo_cost"]["flops"]
        coll_dev = rec["collectives"]["bytes"]
        hbm_dev = simulated_hbm_bytes(arch, shape) \
            * (256 / n_dev if shape.kind != "decode" else 1.0)
        ct = flops_dev / TPU_V5E_PEAK_BF16
        mt = hbm_dev / TPU_V5E_HBM_BW
        lt = coll_dev / TPU_V5E_ICI_BW
        terms = {"compute": ct, "memory": mt, "collective": lt}
        dom = max(terms, key=terms.get)
        ratio = model_flops(cfg, shape) / max(flops_dev * n_dev, 1.0)
        rows.append(Row(
            arch=arch, shape=shape_name, mesh=mesh,
            fits=rec["memory"]["total_bytes"] <= 16 * 2 ** 30,
            mem_gib=rec["memory"]["total_bytes"] / 2 ** 30,
            compute_s=ct, memory_s=mt, collective_s=lt,
            model_flops_ratio=ratio, dominant=dom, note=NOTES[dom]))
    return rows


def markdown_table(rows, mesh: str = "single") -> str:
    out = ["| arch | shape | fits<=16GiB | mem/chip | compute s | memory s |"
           " collective s | dominant | MODEL/HLO | next lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.mesh != mesh:
            continue
        out.append(
            f"| {r.arch} | {r.shape} | {'Y' if r.fits else 'N'} "
            f"| {r.mem_gib:.1f} | {r.compute_s:.3e} | {r.memory_s:.3e} "
            f"| {r.collective_s:.3e} | **{r.dominant}** "
            f"| {r.model_flops_ratio:.2f} | {r.note} |")
    return "\n".join(out)


def main():
    rows = build_rows()
    print(f"{len(rows)} cells analyzed")
    print(markdown_table(rows, "single"))
    print()
    print(markdown_table(rows, "multi"))


if __name__ == "__main__":
    main()
