"""Production mesh construction.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Mesh axes:
  pod    — across-pod (DCN) axis: data parallel by default, pipeline
           parallel with --pp (distributed/pipeline.py)
  data   — within-pod batch/expert/ZeRO axis
  model  — tensor parallel axis (Megatron layout, paper Fig. 2)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over host devices for tests/examples."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
