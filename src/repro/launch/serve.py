"""Serving launcher: LLMCompass-planned parallelism + continuous-batching
engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --preset tiny --requests 8 --max-new 16

The planner (the paper's performance model) is consulted first: it prints
the predicted-latency-optimal (tp, pp, dp) plan and predicted throughput
for the target system before the engine starts — Sec. IV of the paper used
as a deployment tool.
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config
from .. import models
from ..core import hardware as hw
from ..core import planner
from ..serving import Engine, Request, SamplingParams
from .train import preset_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["tiny", "m100", "full"],
                    default="tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan-chips", type=int, default=16,
                    help="v5e chips for the planning report")
    args = ap.parse_args()

    full_cfg = get_config(args.arch)
    # 1) plan on the real config with the paper's model
    node = hw.tpu_v5e_pod(args.plan_chips)
    try:
        best = planner.best_plan(node, full_cfg, batch=args.batch,
                                 in_len=512, out_len=args.max_new)
        p = best.plan
        print(f"[planner] {full_cfg.name} on {args.plan_chips}x v5e: "
              f"tp={p.tp} pp={p.pp} dp={p.dp} ep={p.ep}  "
              f"pred latency={best.latency * 1e3:.1f}ms  "
              f"pred throughput={best.throughput:.0f} tok/s  "
              f"mem/chip={best.memory_per_device / 2 ** 30:.2f}GiB")
    except ValueError as e:
        print(f"[planner] {e}")

    # 2) serve the (preset) model locally
    cfg = preset_config(full_cfg, args.preset)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_size=args.batch, max_len=args.max_len)
    sampling = SamplingParams(temperature=args.temperature, top_k=40)
    reqs = [Request(uid=i, prompt=[(7 * i + j) % cfg.vocab_size
                                   for j in range(5 + i % 7)],
                    max_new_tokens=args.max_new, sampling=sampling)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    for r in done[: min(4, len(done))]:
        print(f"req {r.uid}: prompt={r.prompt} -> {r.output}")
    print(f"served {len(done)} requests, {eng.stats['tokens_out']} tokens "
          f"in {dt:.2f}s ({eng.throughput():.1f} tok/s decode-side)")


if __name__ == "__main__":
    main()
