"""Training launcher: mesh + sharding + checkpoint/restart + monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --preset tiny --steps 20 --batch 8 --seq 128

Production posture: restart manifests + deterministic data skiping make
``--resume`` exact; StepMonitor flags stragglers; checkpoints are async.
On a real TPU slice run under `jax.distributed.initialize()` with
--data/--model sized to the slice; on CPU it runs the same code on a 1x1
mesh.
"""
from __future__ import annotations

import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_config
from .. import models
from ..checkpoint import Checkpointer
from ..data import DataConfig, TokenPipeline
from ..distributed import sharding as shd
from ..distributed.fault_tolerance import RestartManifest, StepMonitor
from ..training import AdamW, cosine_schedule, init_state, make_train_step
from .mesh import make_host_mesh


def preset_config(cfg, preset: str):
    if preset == "full":
        return cfg
    if preset == "m100":      # ~100M-param config of the same family
        return replace(cfg, name=cfg.name + "-m100", n_layers=12,
                       d_model=768, n_heads=12 if cfg.n_heads else 0,
                       n_kv_heads=4 if cfg.n_kv_heads else 0,
                       d_head=64 if cfg.n_heads else 0, d_ff=2048,
                       vocab_size=32000,
                       n_experts=min(cfg.n_experts, 8),
                       top_k=min(cfg.top_k, 2))
    return smoke_config(cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["tiny", "m100", "full"],
                    default="tiny")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--token-file", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = preset_config(get_config(args.arch), args.preset)
    mesh = make_host_mesh(data=args.data, model=args.model)
    shd.set_model_config(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=max(args.steps // 20, 1),
                                   total=args.steps))
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                    seed=args.seed,
                                    token_file=args.token_file))
    ck = Checkpointer(args.ckpt_dir)
    man_path = f"{args.ckpt_dir}/manifest.json"
    mon = StepMonitor(on_straggler=lambda s, dt: print(
        f"[straggler] step {s} took {dt:.2f}s"))

    with jax.sharding.set_mesh(mesh):
        state = init_state(cfg, opt, jax.random.PRNGKey(args.seed))
        start = 0
        if args.resume and ck.latest_step() is not None:
            man = RestartManifest.load(man_path)
            state, _ = ck.restore(state)
            start = man.step + 1
            print(f"resumed from step {man.step}")
        step_fn = jax.jit(make_train_step(
            cfg, opt, microbatches=args.microbatches,
            has_frontend=models.needs_frontend(cfg)))

        n_params = models.param_count(state.params)
        print(f"training {cfg.name}: {n_params / 1e6:.1f}M params, "
              f"mesh={dict(mesh.shape)}, batch={args.batch}x{args.seq}")
        for s in range(start, args.steps):
            mon.start()
            raw = pipe.batch_at(s)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            if models.needs_frontend(cfg):
                batch["frontend"] = jnp.zeros(
                    (args.batch, max(cfg.n_frontend_tokens, 1), cfg.d_model),
                    jnp.bfloat16)
            state, metrics = step_fn(state, batch)
            dt = mon.stop(s)
            if s % max(args.steps // 20, 1) == 0 or s == args.steps - 1:
                print(f"step {s:5d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}  "
                      f"{args.batch * args.seq / dt:.0f} tok/s")
            if s % args.ckpt_every == 0 or s == args.steps - 1:
                ck.save(s, state, extra={"data_step": s}, async_=True)
                RestartManifest(step=s, data_step=s,
                                mesh_shape=dict(mesh.shape),
                                rng_seed=args.seed).save(man_path)
        ck.wait()
    print("done")


if __name__ == "__main__":
    main()
