import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and record memory / FLOPs / collective bytes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape decode_32k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Results append to experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline table (EXPERIMENTS.md §Roofline) is generated from these files by
launch/roofline_report.py. Cells already on disk are skipped unless
--force.

The FIRST TWO LINES of this file must stay first: jax locks the device
count at first init, and the dry-run (and only the dry-run) needs 512
placeholder CPU devices.
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from ..configs.base import ModelConfig, ShapeConfig
from .. import models
from ..distributed import sharding as shd
from ..training import AdamW, constant_schedule
from ..training.train_step import TrainState
from . import analysis
from .mesh import make_production_mesh

OUT_DIR = "experiments/dryrun"


def _decode_max_len(shape: ShapeConfig) -> int:
    return shape.seq_len


def build_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    microbatches: int = 8, kv_mode: str = "channel"):
    """Returns (jitted fn, example args as ShapeDtypeStructs).

    Training uses microbatched gradient accumulation (microbatches=8 ->
    32-sequence microbatches at global batch 256): activation memory scales
    with the microbatch, gradients accumulate in fp32 at parameter
    sharding. §Perf iteration 3."""
    specs = models.input_specs(cfg, shape)
    shd.set_model_config(cfg)
    params_abs = models.abstract_params(cfg)
    p_shard = shd.param_shardings(mesh, params_abs)
    d_shard = shd.data_shardings(mesh, specs)

    if shape.kind == "train":
        opt = AdamW(lr=constant_schedule(1e-4))
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_shard = shd.opt_state_shardings(mesh, opt_abs)
        from ..training.train_step import make_train_step
        step = make_train_step(cfg, opt, microbatches=microbatches,
                               has_frontend=models.needs_frontend(cfg))
        state_abs = TrainState(params_abs, opt_abs)
        state_shard = TrainState(p_shard, o_shard)
        fn = jax.jit(step,
                     in_shardings=(state_shard, d_shard),
                     donate_argnums=(0,))
        return fn, (state_abs, specs)

    cache_len = _decode_max_len(shape) if shape.kind == "decode" \
        else shape.seq_len + 128
    cache_abs = models.abstract_cache(cfg, shape.global_batch, cache_len)
    c_shard = shd.cache_shardings(mesh, cache_abs, shape.global_batch,
                                  kv_mode=kv_mode)

    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            return models.prefill(cfg, params, batch["tokens"], cache,
                                  frontend=batch.get("frontend"))
        fn = jax.jit(prefill_step,
                     in_shardings=(p_shard, d_shard, c_shard),
                     donate_argnums=(2,))
        return fn, (params_abs, specs, cache_abs)

    # decode: one new token against a seq_len-deep cache
    def serve_step(params, batch, cache):
        return models.decode_step(cfg, params, batch["token"], cache)

    fn = jax.jit(serve_step,
                 in_shardings=(p_shard, d_shard, c_shard),
                 donate_argnums=(2,))
    return fn, (params_abs, specs, cache_abs)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = OUT_DIR, force: bool = False,
             verbose: bool = True, microbatches: int = 8,
             kv_mode: str = "channel") -> dict:
    import os as _os
    _os.makedirs(out_dir, exist_ok=True)
    suffix = "" if kv_mode == "channel" else f"__kv-{kv_mode}"
    path = _os.path.join(out_dir,
                         f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if _os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "skipped": True,
               "reason": "long_500k needs sub-quadratic attention "
                         "(DESIGN.md Sec. 5)"}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": int(n_dev), "kind": shape.kind}
    rec["microbatches"] = microbatches if shape.kind == "train" else 1
    rec["kv_mode"] = kv_mode
    try:
        # NOTE: the legacy `with mesh:` context is deliberate. Under
        # set_mesh the in-model with_sharding_constraint helpers activate,
        # and measured cells REGRESSED (granite prefill: 22.8 -> 102.6 GiB,
        # collectives 682 -> 2187 GiB): GSPMD's own propagation from the
        # parameter/input shardings beats our hand constraints. Recorded as
        # a refuted hypothesis in EXPERIMENTS.md §Perf.
        with mesh:
            fn, args = build_lowerable(cfg, shape, mesh,
                                       microbatches=microbatches,
                                       kv_mode=kv_mode)
            lowered = fn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            mem = analysis.memory_summary(compiled)
            cost = analysis.cost_summary(compiled)
            hlo_text = compiled.as_text()
            coll = analysis.collective_bytes(hlo_text)
            hcost = analysis.hlo_costs(hlo_text)
            # keep the HLO for later re-analysis (gzip, ~100KB each)
            import gzip
            _os.makedirs(_os.path.join(out_dir, "hlo"), exist_ok=True)
            with gzip.open(_os.path.join(
                    out_dir, "hlo",
                    f"{arch}__{shape_name}__{mesh_kind}.txt.gz"), "wt") as zf:
                zf.write(hlo_text)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "memory": mem,
            "cost": cost,
            "hlo_cost": hcost,          # trip-count-aware flops/bytes
            "collectives": {"bytes": coll.total_bytes,
                            "count": coll.count,
                            "by_kind": coll.by_kind},
            "bytes_per_device": mem["total_bytes"],
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "tokens": shape.tokens if shape.kind != "decode"
            else shape.global_batch,
        })
        if verbose:
            print(f"[{arch} | {shape_name} | {mesh_kind}] OK  "
                  f"compile={rec['compile_s']}s  "
                  f"mem/dev={mem['total_bytes']/2**30:.2f}GiB  "
                  f"flops={cost['flops']:.3e}  "
                  f"coll={coll.total_bytes/2**20:.1f}MiB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[{arch} | {shape_name} | {mesh_kind}] FAIL {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--kv-shard", choices=["channel", "sequence", "auto"],
                    default="channel")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                for m in meshes:
                    cells.append((arch, shape, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    n_ok = n_fail = n_skip = 0
    for arch, shape, m in cells:
        rec = run_cell(arch, shape, m, out_dir=args.out, force=args.force,
                       microbatches=args.microbatches,
                       kv_mode=args.kv_shard)
        if rec.get("skipped"):
            n_skip += 1
        elif rec.get("ok"):
            n_ok += 1
        else:
            n_fail += 1
    print(f"dry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"(inapplicable cells)")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
