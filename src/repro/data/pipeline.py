"""Deterministic token data pipeline.

Two sources: a seeded synthetic stream (always available — CI / smoke) and
a memmapped token file (production path: one uint32 file per corpus shard).
Per-host sharding: host h of H reads batch rows [h*B/H, (h+1)*B/H) — the
global order is a pure function of (seed, step), so elastic restarts and
host failures resume exactly (fault_tolerance.RestartManifest records the
step; the pipeline skips to it in O(1)).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: Optional[str] = None     # memmap uint32; None -> synthetic
    host_index: int = 0
    host_count: int = 1


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=np.uint32, mode="r")

    # ------------------------------------------------------------------
    def batch_at(self, step: int) -> dict:
        """Pure function of step -> {tokens, targets, mask} (local shard)."""
        cfg = self.cfg
        lo = cfg.host_index * self.local_batch
        rows = np.arange(lo, lo + self.local_batch, dtype=np.int64)
        if self._mm is not None:
            n_tok = self._mm.shape[0]
            n_seq = max((n_tok - 1) // cfg.seq_len, 1)
            rng = np.random.RandomState(
                (cfg.seed * 1_000_003 + step) % (2 ** 31 - 1))
            seq_idx = rng.randint(0, n_seq, size=cfg.global_batch)[
                lo:lo + self.local_batch]
            starts = seq_idx * cfg.seq_len
            tok = np.stack([self._mm[s:s + cfg.seq_len + 1]
                            for s in starts]).astype(np.int32)
        else:
            # synthetic: seeded per (step, row) — deterministic & cheap
            rng = np.random.RandomState(
                (cfg.seed * 1_000_003 + step) % (2 ** 31 - 1))
            tok = rng.randint(0, cfg.vocab_size,
                              size=(cfg.global_batch, cfg.seq_len + 1),
                              ).astype(np.int32)[lo:lo + self.local_batch]
        tokens = tok[:, :-1]
        targets = tok[:, 1:]
        mask = np.ones_like(targets, np.float32)
        return {"tokens": tokens, "targets": targets, "mask": mask}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
