"""Export a Perfetto timeline + per-op attribution for one grid point.

    python -m repro.trace --config gpt3_175b --stage prefill
    python -m repro.trace --config llama2-13b --stage serve --requests 24
    python -m repro.trace --config gpt3_175b --fusion full --csv ops.csv

Builds the requested config x plan x policy x fusion x stage point, prices
it through the analytical models, and writes a Chrome trace_event JSON
(`--out`, default <config>_<stage>.perfetto.json) that opens directly in
https://ui.perfetto.dev or chrome://tracing. Timestamps are the model's
*virtual* times (core/trace_export.py), so the file is deterministic and
diffable; the tool validates the trace schema and asserts the exported
span equals the Schedule makespan bit-for-bit before reporting success.

Non-serve stages export per-resource Schedule lanes (compute/vector/link,
critical ops flagged, fused kernels carrying their elided bytes) and print
the per-op attribution table (core/obs.py) — `--csv` dumps the full table.
The serve stage replays the Poisson trace through the continuous-batching
simulator and exports engine phases, slot occupancy and per-request lanes.
"""
from __future__ import annotations

import argparse
import sys

from .configs import get_config
from .core import fusion as fu
from .core import hardware as hw
from .core import obs
from .core.evaluator import Evaluator
from .core.graph import Plan
from .core.precision import POLICIES, get_policy
from .core.schedule import schedule_graph
from .core.simulator import simulate
from .core.study import Case, Study
from .core.trace_export import (schedule_trace_events,
                                simulation_trace_events, total_span_us,
                                validate_trace_events, write_trace, _ts)
from .core.workload import Trace, TrafficWorkload, Workload

_FUSIONS = {"serial": fu.SERIAL, "fused": fu.FUSED, "overlap": fu.OVERLAP,
            "full": fu.FULL}


def _attribution_table(att: obs.Attribution, top: int = 20) -> str:
    rows = sorted(att.rows, key=lambda r: -r.latency)[:top]
    lines = [f"{'op':<28} {'group':<6} {'bound':<9} {'latency_s':>12} "
             f"{'bytes':>12} {'elided':>12} {'crit':>5}"]
    for r in rows:
        lines.append(f"{r.name:<28} {r.group:<6} {r.bound:<9} "
                     f"{r.latency:>12.6f} {r.bytes:>12.4g} "
                     f"{r.elided:>12.4g} {str(r.critical):>5}")
    lines.append(f"total={att.total:.6f}s serial={att.serial:.6f}s "
                 f"elided={att.elided:.4g}B "
                 f"link_exposed={att.link_exposed:.6f}s "
                 f"link_hidden={att.link_hidden:.6f}s")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--config", required=True,
                    help="model config name (gpt3_175b, llama2-13b, ...)")
    ap.add_argument("--stage", default="prefill",
                    choices=("generate", "prefill", "decode", "layer",
                             "serve"))
    ap.add_argument("--device", default="a100",
                    help=f"device preset ({', '.join(sorted(hw.PRESETS))})")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--link-gbps", type=float, default=600.0)
    ap.add_argument("--topology", default="fc")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor parallel (default: all devices)")
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--ep", type=int, default=1)
    ap.add_argument("--sp", action="store_true", help="sequence parallelism")
    ap.add_argument("--policy", default="fp16",
                    help=f"precision preset ({', '.join(sorted(POLICIES))})")
    ap.add_argument("--fusion", default="full",
                    choices=tuple(sorted(_FUSIONS)))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--in-len", type=int, default=512)
    ap.add_argument("--out-len", type=int, default=64)
    ap.add_argument("--samples", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16,
                    help="serve stage: trace length")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="serve stage: Poisson arrivals per second")
    ap.add_argument("--out", default=None,
                    help="trace path (default <config>_<stage>"
                         ".perfetto.json)")
    ap.add_argument("--csv", default=None,
                    help="also dump the full attribution table as CSV")
    args = ap.parse_args(argv)

    cfg = get_config(args.config.strip().lower().replace("_", "-"))
    system = hw.make_system(hw.get_device(args.device), args.devices,
                            args.link_gbps, args.topology)
    plan = Plan(tp=args.tp or args.devices, pp=args.pp, dp=args.dp,
                ep=args.ep, sequence_parallel=args.sp)
    policy = get_policy(args.policy)
    fus = _FUSIONS[args.fusion]
    out = args.out or f"{cfg.name}_{args.stage}.perfetto.json"

    ev = Evaluator(system)
    att = None
    if args.stage == "serve":
        trace = Trace.poisson(args.requests, args.rate, args.in_len,
                              args.out_len, seed=0)
        traffic = TrafficWorkload.from_trace(trace, slots=args.batch)
        sim = simulate(system, cfg, plan, traffic, evaluator=ev,
                       policy=policy, fusion=fus)
        events = simulation_trace_events(sim)
        expect = _ts(sim.makespan)
        print(sim.summary())
    else:
        w = Workload(args.batch, args.in_len, args.out_len,
                     samples=args.samples)
        case = Case(system, cfg, plan, w, stage=args.stage, policy=policy,
                    fusion=fus)
        graphs = Study._graphs(case)
        if args.stage in ("generate", "layer") and len(graphs) > 1:
            sections = [("prefill/", graphs[0]), ("decode/", graphs[1])]
        else:
            sections = [("", graphs[0])]
        costs = ev.evaluate_many([g for _, g in sections],
                                 overlap=fus.overlap)
        events, expect, atts = [], 0.0, []
        for i, ((pre, g), cost) in enumerate(zip(sections, costs)):
            sch = cost.schedule
            if sch is None:
                # serial pricing: a dependency-ordered timeline for display
                sch = schedule_graph(g, [o.latency for o in cost.ops],
                                     pipeline_collectives=False)
            name = pre.rstrip("/") or args.stage
            events += schedule_trace_events(sch, g, pid=i,
                                            process_name=name)
            expect = max(expect, _ts(sch.makespan))
            atts.append(obs.attribute(g, cost, label=args.stage,
                                      prefix=pre))
        att = atts[0] if len(atts) == 1 else obs.combine(args.stage, atts)
        print(_attribution_table(att))

    errors = validate_trace_events(events)
    span = total_span_us(events)
    write_trace(out, events)
    if errors:
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        return 1
    if span != expect:
        print(f"SPAN MISMATCH: trace span {span} us != makespan {expect} us",
              file=sys.stderr)
        return 1
    if args.csv and att is not None:
        att.to_csv(args.csv)
        print(f"wrote {args.csv}")
    print(f"wrote {out} ({len(events)} events, span {span:.3f} us == "
          f"modeled makespan; open in https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
