"""Chunked, manifest-driven checkpointing (tensorstore-free).

Layout:  <dir>/step_<N>/
             manifest.json      {step, leaf paths, shapes, dtypes, data step}
             shard_<i>.npz      leaf arrays (host-local shard in multi-host)

Guarantees:
  * atomic commit — written to step_<N>.tmp, fsynced, renamed;
  * async mode — the array->host copy happens on the caller thread, the
    file write on a background thread (training continues);
  * elastic restore — arrays are re-sharded onto whatever mesh the restore
    call runs under (jax.device_put with the new sharding), so a restart on
    a smaller/larger healthy slice works (fault_tolerance.remesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _path_key(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(out)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             async_: bool = False):
        flat, _ = _flatten(tree)
        host = {}
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for path, leaf in flat:
            key = _path_key(path)
            arr = np.asarray(leaf)
            host[key] = arr
            manifest["leaves"].append(
                {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)})

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{k.replace("/", "__"): v for k, v in host.items()})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if async_:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """template: pytree with the target structure (values ignored).
        shardings: optional matching pytree of NamedSharding for re-shard."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        dtypes = {le["key"]: le["dtype"] for le in manifest["leaves"]}
        flat, treedef = _flatten(template)
        leaves = []
        for path, leaf in flat:
            key = _path_key(path).replace("/", "__")
            arr = data[key]
            want = dtypes.get(_path_key(path))
            if want and arr.dtype.kind == "V":
                # npz stores ml_dtypes (bfloat16) as raw void: reinterpret
                arr = arr.view(np.dtype(want))
            leaves.append(arr)
        if shardings is not None:
            sflat = jax.tree.leaves(shardings)
            leaves = [jax.device_put(a, s) for a, s in zip(leaves, sflat)]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        return tree, manifest

    def _gc(self):
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        names = sorted(
            (int(n.split("_")[1]) for n in os.listdir(self.dir)
             if n.startswith("step_") and not n.endswith(".tmp")))
        for s in names[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
