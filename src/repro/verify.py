"""Lint every shipped config/plan/policy combination (ISSUE 7).

    python -m repro.verify                 # default matrix, human summary
    python -m repro.verify --all-configs   # include EXTRA_ARCHS (gpt3-175b)
    python -m repro.verify --json out.json # machine-readable report

Exit status is 1 if any error-severity diagnostic is found, else 0 — CI
runs `--all-configs` as the error-mode gate the Evaluator/Study default
(warn) deliberately does not enforce at runtime.

The matrix mirrors what the benchmarks actually evaluate: every registered
arch on a 4x A100 node and a 16x TPU v5e slice, every plan the planner
would enumerate, every precision preset against each device's datapath,
graphs built per fusion preset at a prefill and a decode point, and a
schedule certificate for each overlap-scheduled graph (unit latencies —
certificate rules are latency-scale-free).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from .configs import ARCHS, EXTRA_ARCHS
from .core import hardware as hw
from .core import planner
from .core.fusion import FULL, FUSED, SERIAL, FusionPolicy, fuse
from .core.graph import Plan, build_model
from .core.ir import Graph
from .core.precision import POLICIES
from .core.schedule import schedule_graph
from .core.verify import (Diagnostic, graph_diagnostics, plan_diagnostics,
                          policy_diagnostics, registry_diagnostics,
                          schedule_diagnostics)

#: fusion presets to build graphs under (overlap presets also get their
#: schedule certificate validated)
_FUSIONS: Tuple[Tuple[str, FusionPolicy], ...] = (
    ("serial", SERIAL), ("fused", FUSED), ("full", FULL))

#: (stage, seq, kv_len) graph points — one prefill, one deep decode step
_STAGES: Tuple[Tuple[str, int, int], ...] = (
    ("prefill", 512, 512), ("decode", 1, 2048))


def _systems() -> Dict[str, hw.System]:
    return {"dgx-a100-4": hw.dgx_a100(4), "tpu-v5e-16": hw.tpu_v5e_pod(16)}


def _record(report: List[dict], where: str, diags: List[Diagnostic]) -> None:
    for d in diags:
        report.append({"where": where, "rule": d.rule,
                       "severity": d.severity, "location": d.location,
                       "message": d.message, "hint": d.hint})


def lint_all(all_configs: bool = False,
             progress: bool = False) -> List[dict]:
    """Run every rule family over the shipped matrix; return diagnostic
    rows (dicts) for reporting. Pure collection — no mode enforcement."""
    report: List[dict] = []
    archs = dict(ARCHS)
    if all_configs:
        archs.update(EXTRA_ARCHS)

    _record(report, "registry", registry_diagnostics())

    for sname, system in _systems().items():
        dev = system.device
        for pname, pol in POLICIES.items():
            _record(report, f"{sname}/policy:{pname}",
                    policy_diagnostics(pol, dev))
        for arch, cfg in archs.items():
            plans = planner.enumerate_plans(system, cfg)
            for plan in plans:
                _record(report, f"{sname}/{arch}/{_ptag(plan)}",
                        plan_diagnostics(system, cfg, plan,
                                         check_memory=False))
            # graphs: lint the densest-TP plan plus the single-device plan
            # under each fusion preset — builder seams do not depend on the
            # policy sweep, so DEFAULT precision keeps the matrix tractable
            lint_plans = {plans[0], max(plans, key=lambda p: p.tp)}
            for plan in lint_plans:
                for fname, fus in _FUSIONS:
                    for stage, seq, kv in _STAGES:
                        g = fuse(build_model(cfg, plan, 1, seq, kv_len=kv),
                                 fus)
                        where = (f"{sname}/{arch}/{_ptag(plan)}/"
                                 f"{fname}/{stage}")
                        _record(report, where, graph_diagnostics(g, dev))
                        if fus.overlap:
                            _record(report, where + "/schedule",
                                    _certificate(g))
            if progress:
                print(f"  {sname}/{arch}: "
                      f"{len(plans)} plans linted", file=sys.stderr)
    return report


def _ptag(plan: Plan) -> str:
    sp = "+sp" if plan.sequence_parallel else ""
    return f"tp{plan.tp}pp{plan.pp}dp{plan.dp}ep{plan.ep}{sp}"


def _certificate(g: Graph) -> List[Diagnostic]:
    """Schedule the graph at unit latencies and validate the certificate
    (the rules check structure, not absolute time, so 1.0s per node is as
    strong a witness as priced latencies)."""
    lats = [1.0] * len(g)
    return schedule_diagnostics(g, lats, schedule_graph(g, lats))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="lint every shipped config/plan/policy combination")
    ap.add_argument("--all-configs", action="store_true",
                    help="include EXTRA_ARCHS (gpt3-175b) in the matrix")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full diagnostic report as JSON")
    ap.add_argument("--progress", action="store_true",
                    help="per-arch progress on stderr")
    args = ap.parse_args(argv)

    report = lint_all(all_configs=args.all_configs, progress=args.progress)
    counts = {"error": 0, "warn": 0, "info": 0}
    for row in report:
        counts[row["severity"]] += 1

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"counts": counts, "diagnostics": report}, f, indent=2)

    for row in report:
        if row["severity"] != "info":
            print(f"{row['severity']}[{row['rule']}] {row['where']} "
                  f"{row['location']}: {row['message']}")
    print(f"verify: {counts['error']} errors, {counts['warn']} warns, "
          f"{counts['info']} infos across the shipped matrix")
    return 1 if counts["error"] else 0


if __name__ == "__main__":
    sys.exit(main())
