"""Public matmul op: Pallas on TPU, interpret mode elsewhere.

mapper_blocks() asks the LLMCompass mapper (the paper's contribution) for
the performance-optimal VMEM tiling of a given GEMM on the TPU preset and
returns it as Pallas block sizes — the mapper doubles as a block autotuner.

ISSUE 4 adds the quantized paths the precision subsystem prices:
`matmul_int8` (per-row/per-column symmetric scales, integer MACs, fp32
accumulation, fused dequantize) and `matmul_fp8` (e4m3 cast-through into
the standard kernel) — so the numeric tree stays honest about the int8/fp8
GEMMs the analytical model claims 2x MAC rate and 1-byte traffic for.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import matmul_int8_pallas, matmul_pallas  # noqa: E402
from .ref import (matmul_fp8_ref, matmul_int8_ref, matmul_ref, quantize_fp8,
                  quantize_int8)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mapper_blocks(m: int, k: int, n: int):
    from ...core.hardware import google_tpu_v5e
    from ...core.mapper import matmul_perf
    r = matmul_perf(google_tpu_v5e(), m, k, n)
    f = lambda x: max(128, min(x // 128 * 128, 1024)) if x >= 128 else x
    return (f(r.mapping.subtile_m), f(r.mapping.subtile_k),
            f(r.mapping.subtile_n))


def _pad_to(x, mults):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(a, b, *, bm: int = 256, bk: int = 512, bn: int = 256,
           interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    m, n = a.shape[0], b.shape[1]
    bm_, bk_, bn_ = min(bm, m), min(bk, a.shape[1]), min(bn, n)
    # zero-pad to block multiples: out-of-bounds block reads are undefined
    # on TPU (NaN in interpret mode) and k-padding would pollute the sum
    ap = _pad_to(a, (bm_, bk_))
    bp = _pad_to(b, (bk_, bn_))
    out = matmul_pallas(ap, bp, bm=bm_, bk=bk_, bn=bn_, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul_int8(a, b, *, bm: int = 256, bk: int = 512, bn: int = 256,
                interpret: bool | None = None):
    """Quantized GEMM: int8-quantize A per row and B per column (symmetric,
    scale = amax/127), multiply with integer MACs + fp32 accumulation, and
    dequantize in the epilogue. Input/output are float arrays; the float
    result approximates `matmul(a, b)` to quantization error (~1%), and
    matches `matmul_int8_ref` (quantize-dequantize oracle) to fp32
    association error."""
    if interpret is None:
        interpret = not _on_tpu()
    m, n = a.shape[0], b.shape[1]
    qa, sa = quantize_int8(a, axis=1)
    qb, sb = quantize_int8(b, axis=0)
    bm_, bk_, bn_ = min(bm, m), min(bk, a.shape[1]), min(bn, n)
    # zero-pad: padded int8 entries are 0, so they add nothing to the sums;
    # scale pads are 1 so padded rows/cols dequantize to finite (sliced) junk
    qa = _pad_to(qa, (bm_, bk_))
    qb = _pad_to(qb, (bk_, bn_))
    sa = jnp.pad(sa, [(0, qa.shape[0] - m), (0, 0)], constant_values=1.0)
    sb = jnp.pad(sb, [(0, 0), (0, qb.shape[1] - n)], constant_values=1.0)
    out = matmul_int8_pallas(qa, qb, sa, sb, bm=bm_, bk=bk_, bn=bn_,
                             interpret=interpret)
    return out[:m, :n].astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul_fp8(a, b, *, bm: int = 256, bk: int = 512, bn: int = 256,
               interpret: bool | None = None):
    """fp8 (e4m3) GEMM: operands rounded to e4m3 storage, multiplied through
    the standard fp32-accumulating kernel — the 1-byte-traffic path the
    precision subsystem prices for fp8 policies."""
    af = quantize_fp8(a).astype(jnp.float32)
    bf = quantize_fp8(b).astype(jnp.float32)
    return matmul(af, bf, bm=bm, bk=bk, bn=bn,
                  interpret=interpret).astype(a.dtype)


reference = matmul_ref
reference_int8 = matmul_int8_ref
reference_fp8 = matmul_fp8_ref
