"""Public matmul op: Pallas on TPU, interpret mode elsewhere.

mapper_blocks() asks the LLMCompass mapper (the paper's contribution) for
the performance-optimal VMEM tiling of a given GEMM on the TPU preset and
returns it as Pallas block sizes — the mapper doubles as a block autotuner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import matmul_pallas  # noqa: E402
from .ref import matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mapper_blocks(m: int, k: int, n: int):
    from ...core.hardware import google_tpu_v5e
    from ...core.mapper import matmul_perf
    r = matmul_perf(google_tpu_v5e(), m, k, n)
    f = lambda x: max(128, min(x // 128 * 128, 1024)) if x >= 128 else x
    return (f(r.mapping.subtile_m), f(r.mapping.subtile_k),
            f(r.mapping.subtile_n))


def _pad_to(x, mults):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(a, b, *, bm: int = 256, bk: int = 512, bn: int = 256,
           interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    m, n = a.shape[0], b.shape[1]
    bm_, bk_, bn_ = min(bm, m), min(bk, a.shape[1]), min(bn, n)
    # zero-pad to block multiples: out-of-bounds block reads are undefined
    # on TPU (NaN in interpret mode) and k-padding would pollute the sum
    ap = _pad_to(a, (bm_, bk_))
    bp = _pad_to(b, (bk_, bn_))
    out = matmul_pallas(ap, bp, bm=bm_, bk=bk_, bn=bn_, interpret=interpret)
    return out[:m, :n]


reference = matmul_ref
