"""Pure-jnp oracles for the tiled matmul kernels, including the quantized
paths (ISSUE 4): symmetric int8 quantize/dequantize and fp8 (e4m3)
cast-through references the Pallas kernels are tested against."""
import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def quantize_int8(x, axis: int):
    """Symmetric per-vector int8 quantization along `axis` (the reduction
    axis of the GEMM): scale = amax/127 per kept vector. Returns (q, scale)
    with scale shaped to broadcast against x."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def matmul_int8_ref(a, b, out_dtype=jnp.float32):
    """Quantize-dequantize oracle: per-row(A)/per-column(B) int8 symmetric
    quantization, fp32 GEMM on the dequantized values. The kernel computes
    the same quantized products with integer MACs — they must agree to fp32
    association error."""
    qa, sa = quantize_int8(a, axis=1)
    qb, sb = quantize_int8(b, axis=0)
    return jnp.dot(dequantize_int8(qa, sa), dequantize_int8(qb, sb),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def quantize_fp8(x):
    """fp8 (e4m3) cast-through: the storage format the analytical model
    prices at 1 byte / 2x MAC rate. No per-vector scales — e4m3's dynamic
    range covers normalized activations/weights."""
    return x.astype(jnp.float8_e4m3fn)


def matmul_fp8_ref(a, b, out_dtype=jnp.float32):
    """fp8 quantize-dequantize oracle: fp32 GEMM on e4m3-rounded values."""
    af = quantize_fp8(a).astype(jnp.float32)
    bf = quantize_fp8(b).astype(jnp.float32)
    return jnp.dot(af, bf,
                   preferred_element_type=jnp.float32).astype(out_dtype)
