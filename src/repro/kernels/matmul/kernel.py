"""Tiled matmul Pallas kernel — the paper's central operator (Sec. III-B1).

Mapping onto the paper's hierarchy (TPU adaptation, DESIGN.md Sec. 3):
  main memory -> global buffer  tile   == HBM -> VMEM BlockSpec block
  schedule scheme 1 (output-parallel)  == (i, j) grid axes
  schedule scheme 2 (k-split + reduce) == k grid axis revisiting the same
                                          output block with a VMEM accumulator
  double buffering                     == Pallas pipelining (automatic)

Accumulation is fp32 in a VMEM scratch regardless of input dtype; the MXU
dims (bm, bk, bn) must be multiples of 128 for full utilization (paper
implication (5): buffers sized to keep the systolic array busy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 256,
                  bk: int = 512, bn: int = 256,
                  out_dtype=None, interpret: bool = False) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N], tiled (bm, bk, bn)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    out_dtype = out_dtype or a.dtype
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# int8 path (ISSUE 4): integer MACs, fp32 accumulation, fused dequantize
# ---------------------------------------------------------------------------

def _matmul_int8_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *,
                        n_k: int):
    """Per k-block: an exact int8 x int8 -> int32 dot (the narrow-datapath
    MAC array the analytical model prices at 2x fp16 rate), accumulated
    across blocks in an fp32 VMEM scratch; the store fuses the per-row /
    per-column dequantization scales."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    part = jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_ref[...] += part.astype(jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * sa_ref[...] * sb_ref[...]
                      ).astype(o_ref.dtype)


def matmul_int8_pallas(a: jax.Array, b: jax.Array, a_scale: jax.Array,
                       b_scale: jax.Array, *, bm: int = 256, bk: int = 512,
                       bn: int = 256, out_dtype=jnp.float32,
                       interpret: bool = False) -> jax.Array:
    """C[M,N] = (A_q[M,K] @ B_q[K,N]) * a_scale[M,1] * b_scale[1,N] for
    symmetric per-row(A)/per-column(B) int8 quantization."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert a_scale.shape == (m, 1) and b_scale.shape == (1, n), \
        (a_scale.shape, b_scale.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_matmul_int8_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, a_scale, b_scale)
