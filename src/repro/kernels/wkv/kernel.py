"""RWKV6 WKV linear-recurrence Pallas kernel (DESIGN.md Sec. 5 extension).

TPU adaptation of the chunked-recurrence idea: the (N, N) matrix state
lives in VMEM scratch and persists across sequential grid steps along the
time-chunk axis (TPU grids iterate sequentially per core — the innermost
grid dimension is the recurrence carrier). Each grid step streams one
(L, N) chunk of r/k/v/w through VMEM; the inner L-step recurrence runs on
registers via fori_loop.

Layouts: r,k,v,w (BH, T, N) fp32; u (1, N); out (BH, T, N) + final state
(BH, N, N). Grid (BH, T/L), time innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref,
                state_ref, *, L: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[...].astype(jnp.float32)       # (1, N)

    def step(t, state):
        r = r_ref[0, t, :].astype(jnp.float32)[None, :]    # (1, N)
        k = k_ref[0, t, :].astype(jnp.float32)[None, :]
        v = v_ref[0, t, :].astype(jnp.float32)[None, :]
        w = w_ref[0, t, :].astype(jnp.float32)[None, :]
        kv = k.T @ v                                        # (N, N)
        out = r @ (state + u.T * kv)                        # (1, N)
        o_ref[0, t, :] = out[0].astype(o_ref.dtype)
        return state * w.T + kv

    state = jax.lax.fori_loop(0, L, step, state_ref[...])
    state_ref[...] = state

    @pl.when(ci == n_chunks - 1)
    def _store():
        s_out_ref[0] = state


def wkv_pallas(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w: (BH, T, N); u: (N,). Returns out (BH, T, N), state (BH, N, N)."""
    BH, T, N = r.shape
    L = min(chunk, T)
    n_chunks = pl.cdiv(T, L)
    kern = functools.partial(_wkv_kernel, L=L, n_chunks=n_chunks)
    out, state = pl.pallas_call(
        kern,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N), lambda b, c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, n_chunks * L, N), r.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u.reshape(1, N))
    return out[:, :T], state
