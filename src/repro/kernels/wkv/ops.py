"""Public WKV op."""
from __future__ import annotations

import functools

import jax

from .kernel import wkv_pallas
from .ref import wkv_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, w, u, *, chunk: int = 64, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T = r.shape[1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        # pad r/k/v with zeros and w with ONES (identity decay): the padded
        # steps leave the carried state untouched and their outputs are
        # sliced off — undefined tail-block reads would poison the state
        import jax.numpy as jnp
        z = ((0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, z)
        k = jnp.pad(k, z)
        v = jnp.pad(v, z)
        w = jnp.pad(w, z, constant_values=1.0)
    out, state = wkv_pallas(r, k, v, w, u, chunk=L, interpret=interpret)
    return out[:, :T], state


reference = wkv_ref
