"""Oracle: sequential WKV recurrence in pure jnp."""
import jax.numpy as jnp
from jax import lax


def wkv_ref(r, k, v, w, u):
    """r,k,v,w: (BH, T, N); u: (N,). Matches models/recurrent._wkv_step."""
    BH, T, N = r.shape

    def step(state, xs):
        rt, kt, vt, wt = xs                      # (BH, N) each
        kv = kt[:, :, None] * vt[:, None, :]     # (BH, N, N)
        out = jnp.einsum("bn,bnm->bm", rt, state + u[None, :, None] * kv)
        state = state * wt[:, :, None] + kv
        return state, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state0 = jnp.zeros((BH, N, N), jnp.float32)
    state, outs = lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state
