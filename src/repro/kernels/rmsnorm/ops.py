"""Public fused-norm ops."""
from __future__ import annotations

import functools

import jax

from .kernel import layernorm_pallas, rmsnorm_pallas
from .ref import layernorm_ref, rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm(x, g, *, eps: float = 1e-6, br: int = 256,
            interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rmsnorm_pallas(x, g, eps=eps, br=br, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def layernorm(x, g, b, *, eps: float = 1e-5, br: int = 256,
              interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return layernorm_pallas(x, g, b, eps=eps, br=br, interpret=interpret)


reference = rmsnorm_ref
reference_layernorm = layernorm_ref
