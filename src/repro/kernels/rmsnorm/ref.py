"""Oracles for the fused norm kernels."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x, g, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)


def layernorm_ref(x, g, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)
