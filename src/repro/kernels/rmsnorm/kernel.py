"""Fused RMSNorm / LayerNorm Pallas kernel (paper Sec. III-B3).

Row-block tiling: each grid step normalizes a (br, C) block entirely in
VMEM — one HBM read + one write per element (the fusion the paper's model
assumes for norm ops). fp32 statistics regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = ((x - mu) * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, g, *, eps: float = 1e-6, br: int = 256,
                   interpret: bool = False):
    """x: (R, C); g: (C,)."""
    R, C = x.shape
    br = min(br, R)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(R, br),),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, C), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, g.reshape(1, C))


def layernorm_pallas(x, g, b, *, eps: float = 1e-5, br: int = 256,
                     interpret: bool = False):
    R, C = x.shape
    br = min(br, R)
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(pl.cdiv(R, br),),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, C), lambda i: (0, 0)),
                  pl.BlockSpec((1, C), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, g.reshape(1, C), b.reshape(1, C))
