"""Oracle for decode attention."""
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths, softcap: float = 0.0):
    """q: (B, Hkv, G, D); k, v: (B, T, Hkv, D); lengths: (B,)."""
    B, Hkv, G, D = q.shape
    s = jnp.einsum("bhgd,bthd->bhgt", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    t = jnp.arange(k.shape[1])
    mask = t[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgt,bthd->bhgd", p.astype(v.dtype), v).astype(q.dtype)
