"""Decode-time attention Pallas kernel — the narrow-M, IO-bound case the
paper highlights (Sec. IV-B: "matrix multiplications during decoding are
narrow (e.g. 16x12288)" and Sec. V-A: decode is bound by reading KV).

One query token per sequence; the kernel streams KV blocks from HBM through
VMEM exactly once per kv-head (GQA: the G query heads of a group ride the
same KV stream). q lives in VMEM for the whole sweep.

Layouts: q (B, Hkv, G, D); k/v (B, T, Hkv, D); lengths (B,) valid KV
lengths (ring-buffer caches pass full T). Grid (b, h, ki), ki innermost;
running (m, l, acc) in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, n_k: int, bk: int, softcap: float, scale: float):
    ki = pl.program_id(2)
    b = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new) * (s > NEG_INF / 2)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0, :, 0].astype(jnp.float32)           # (bk, D)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, softcap: float = 0.0,
                            bk: int = 512, interpret: bool = False):
    """q: (B, Hkv, G, D); k, v: (B, T, Hkv, D); lengths: (B,) int32."""
    B, Hkv, G, D = q.shape
    _, T, _, _ = k.shape
    bk = min(bk, T)
    grid = (B, Hkv, pl.cdiv(T, bk))
    kern = functools.partial(_decode_kernel, n_k=grid[2], bk=bk,
                             softcap=softcap, scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths, whole array
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
