"""Public decode-attention op."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("softcap", "bk", "interpret"))
def decode_attention(q, k, v, lengths, *, softcap: float = 0.0,
                     bk: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T = k.shape[1]
    bk_ = min(bk, T)
    pad = (-T) % bk_
    if pad:   # zero-pad the KV axis; in-kernel length mask covers the rest
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return decode_attention_pallas(q, k, v, lengths, softcap=softcap,
                                   bk=bk_, interpret=interpret)


reference = decode_attention_ref
