"""Pallas TPU kernels for the operators the paper's performance model
covers (Sec. III-B): tiled matmul, fused attention (online softmax [37]),
norms, GELU — plus the WKV/linear-recurrence scan our RWKV/Griffin archs
need (DESIGN.md Sec. 5 extension).

Layout per kernel: <name>/kernel.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jit'd public wrapper; interpret=True off-TPU),
<name>/ref.py (pure-jnp oracle used by the allclose test sweeps).

The BlockSpec tile sizes can be chosen by the LLMCompass mapper
(core/mapper.py) — the mapper's (subtile_m, subtile_k, subtile_n) for the
TPU preset IS the VMEM block shape (DESIGN.md Sec. 3: the mapper doubles as
a Pallas block autotuner); see matmul.ops.mapper_blocks().
"""
from .matmul import ops as matmul
from .flash_attention import ops as flash_attention
from .rmsnorm import ops as rmsnorm
from .gelu import ops as gelu
from .decode_attention import ops as decode_attention
from .wkv import ops as wkv

__all__ = ["matmul", "flash_attention", "rmsnorm", "gelu",
           "decode_attention", "wkv"]
