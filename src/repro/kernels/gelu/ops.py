"""Public activation ops."""
from __future__ import annotations

import functools

import jax

from .kernel import gelu_pallas, silu_mul_pallas
from .ref import gelu_ref, silu_mul_ref


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def gelu(x, *, br: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gelu_pallas(x, br=br, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def silu_mul(g, u, *, br: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return silu_mul_pallas(g, u, br=br, interpret=interpret)


reference = gelu_ref
reference_silu_mul = silu_mul_ref
