"""GELU (tanh approximation [26], as the paper models it) + fused
SwiGLU gate — elementwise Pallas kernels."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(_SQRT_2_OVER_PI * (x + 0.044715 * x ** 3)))


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = _gelu(x).astype(o_ref.dtype)


def _silu_mul_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.nn.sigmoid(g) * u).astype(o_ref.dtype)


def gelu_pallas(x, *, br: int = 256, interpret: bool = False):
    """x: (R, C) (callers flatten)."""
    R, C = x.shape
    br = min(br, R)
    return pl.pallas_call(
        _gelu_kernel,
        grid=(pl.cdiv(R, br),),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x)


def silu_mul_pallas(g, u, *, br: int = 256, interpret: bool = False):
    R, C = g.shape
    br = min(br, R)
    return pl.pallas_call(
        _silu_mul_kernel,
        grid=(pl.cdiv(R, br),),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), g.dtype),
        interpret=interpret,
    )(g, u)
