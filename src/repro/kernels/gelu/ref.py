"""Oracles for the activation kernels."""
import jax
import jax.numpy as jnp


def gelu_ref(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def silu_mul_ref(g, u):
    gf = g.astype(jnp.float32)
    return (jax.nn.silu(gf) * u.astype(jnp.float32)).astype(g.dtype)
