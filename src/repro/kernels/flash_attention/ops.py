"""Public fused-attention op (Pallas on TPU, interpret elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


def _pad_seq(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 512, bk: int = 512,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Sq, Sk = q.shape[2], k.shape[2]
    bq_, bk_ = min(bq, Sq), min(bk, Sk)
    qp = _pad_seq(q, 2, bq_)      # zero-pad: padded KV is masked in-kernel
    kp = _pad_seq(k, 2, bk_)      # (valid_k below), and 0 * NaN from
    vp = _pad_seq(v, 2, bk_)      # undefined reads never hits the accum
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 softcap=softcap, bq=bq_, bk=bk_,
                                 valid_k=Sk, interpret=interpret)
    return out[:, :, :Sq]


reference = attention_ref
