"""Fused attention Pallas kernel (online softmax [Milakov & Gimelshein],
the algorithm the paper uses for its Softmax operator, fused into attention).

Layouts: q (B, Hq, Sq, D), k/v (B, Hkv, Sk, D); GQA handled in the index
maps (kv block index = h // G) so KV is read once per kv-head, matching the
paper's GQA traffic accounting.

Grid (b, h, qi, ki), ki innermost: running (m, l, acc) live in VMEM scratch
across the ki sweep; output written on the last ki step. Causal masking via
block-local iota; fully-masked blocks short-circuit via pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 n_k: int, bq: int, bk: int, sk: int, causal: bool,
                 window: int, softcap: float, scale: float):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = True
    if causal:
        live = ki * bk <= qi * bq + bq - 1   # block reaches the diagonal

    @pl.when(jnp.asarray(live))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < sk
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new) * (s > NEG_INF / 2)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, bq: int = 512,
                           bk: int = 512, valid_k: int | None = None,
                           interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D).
    valid_k: true KV length when callers pre-padded the KV axis."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    grid = (B, Hq, pl.cdiv(Sq, bq), pl.cdiv(Sk, bk))
    kern = functools.partial(
        _attn_kernel, n_k=grid[3], bq=bq, bk=bk,
        sk=valid_k if valid_k is not None else Sk, causal=causal,
        window=window, softcap=softcap, scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
