"""Oracle: naive attention in (B, H, S, D) layout."""
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return o.reshape(B, Hq, Sq, D)
