"""Token samplers (greedy / temperature / top-k / top-p), pure JAX."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # 0 -> greedy
    top_k: int = 0                 # 0 -> off
    top_p: float = 1.0             # 1 -> off


def sample(logits, key, params: SamplingParams):
    """logits: (B, V) -> (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_ = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_per_request(logits, key, params: Sequence[SamplingParams]):
    """Per-row sampling: logits (B, V) with one SamplingParams PER ROW.

    Rows sharing identical params are sampled together through `sample`
    (greedy rows stay a pure argmax and never consume randomness, so a
    greedy request's stream is deterministic regardless of its batch
    neighbors — the ISSUE 3 engine regression). Each non-greedy group draws
    a subkey `fold_in`ed with the group's first row index so distinct groups
    in one call never share a draw; non-greedy streams are reproducible for
    a fixed seed and schedule, but (like any batched sampler) the concrete
    draws do shift when batch composition changes. Returns (B,) int32.
    """
    if len(params) != logits.shape[0]:
        raise ValueError(f"{len(params)} params for {logits.shape[0]} rows")
    groups: dict = {}
    for i, p in enumerate(params):
        groups.setdefault(p, []).append(i)
    if len(groups) == 1:
        (p, _), = groups.items()
        return sample(logits, key, p)
    out = np.zeros(logits.shape[0], np.int32)
    for p, rows in groups.items():
        sub = key if p.temperature <= 0.0 else jax.random.fold_in(key,
                                                                  rows[0])
        out[np.asarray(rows)] = np.asarray(
            sample(logits[jnp.asarray(rows)], sub, p), np.int32)
    return jnp.asarray(out)
