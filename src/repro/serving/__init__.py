from .engine import Engine, Request
from .sampler import SamplingParams, sample, sample_per_request

__all__ = ["Engine", "Request", "SamplingParams", "sample",
           "sample_per_request"]
