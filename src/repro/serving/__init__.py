from .engine import Engine, Request
from .sampler import SamplingParams, sample

__all__ = ["Engine", "Request", "SamplingParams", "sample"]
