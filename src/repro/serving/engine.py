"""Serving engine: batched prefill + continuous-batching decode.

Slot model (vLLM-style, static shapes for XLA):
  * the engine owns `batch_size` slots and one cache pytree;
  * prefill runs per admission wave (right-padded prompts, per-sequence
    prompt_lens); finished slots are refilled by single-prompt prefill into
    a fresh batch-1 cache that is scattered into the slot (jitted);
  * decode advances all live slots every step (dead slots masked).

Recurrent/hybrid archs (state pollution from right pads) are admitted in
equal-length buckets — the scheduler handles that transparently.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .. import models
from .sampler import SamplingParams, sample


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: int = -1
    sampling: SamplingParams = field(default_factory=SamplingParams)
    output: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.cache = models.init_cache(cfg, batch_size, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_size
        self.slot_budget = np.zeros(batch_size, np.int32)
        self._prefill = jax.jit(
            lambda p, t, c, l, f: models.prefill(cfg, p, t, c, frontend=f,
                                                 prompt_lens=l))
        self._decode = jax.jit(
            lambda p, t, c: models.decode_step(cfg, p, t, c))
        self._insert = jax.jit(self._insert_impl, static_argnames=("slot",))
        self.stats = {"tokens_out": 0, "prefill_s": 0.0, "decode_s": 0.0,
                      "steps": 0}

    # ------------------------------------------------------------------
    def _insert_impl(self, cache, one_cache, slot: int):
        """Scatter a batch-1 cache into `slot` of the engine cache."""
        def put(big, small):
            if big.ndim == 0:
                return big
            # find the batch axis: the dim where shapes differ (B vs 1)
            for ax in range(big.ndim):
                if big.shape[ax] != small.shape[ax] and small.shape[ax] == 1:
                    idx = [slice(None)] * big.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return big.at[tuple(idx)].set(small)
            return big
        return jax.tree.map(put, cache, one_cache)

    # ------------------------------------------------------------------
    def admit_wave(self, requests: List[Request]):
        """Prefill a wave of requests into free slots (right-padded)."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        wave = requests[:len(free)]
        if not wave:
            return []
        t0 = time.perf_counter()
        if all(r is None for r in self.slot_req):
            # whole-batch prefill path
            S = max(max(len(r.prompt) for r in wave), 1)
            toks = np.zeros((self.B, S), np.int32)
            lens = np.zeros((self.B,), np.int32)
            for i, r in enumerate(wave):
                toks[i, :len(r.prompt)] = r.prompt
                lens[i] = len(r.prompt)
            lens = np.maximum(lens, 1)
            logits, self.cache = self._prefill(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(lens), None)
            first = np.asarray(jnp.argmax(logits, -1), np.int32)
            for i, r in enumerate(wave):
                self._admit_slot(i, r, int(first[i]))
        else:
            # per-slot insertion
            for slot, r in zip(free, wave):
                one = models.init_cache(self.cfg, 1, self.max_len)
                toks = jnp.asarray([r.prompt], jnp.int32)
                lens = jnp.asarray([len(r.prompt)], jnp.int32)
                logits, one = self._prefill(self.params, toks, one, lens,
                                            None)
                self.cache = self._insert(self.cache, one, slot=slot)
                self._admit_slot(slot, r, int(np.asarray(jnp.argmax(logits[0]))))
        self.stats["prefill_s"] += time.perf_counter() - t0
        return wave

    # ------------------------------------------------------------------
    def _admit_slot(self, slot: int, r: Request, first_token: int):
        """The prefill's first sampled token counts against the budget."""
        r.output.append(first_token)
        self.stats["tokens_out"] += 1
        if (r.max_new_tokens <= 1
                or (r.eos_id >= 0 and first_token == r.eos_id)):
            r.done = True
            self.slot_req[slot] = None
            return
        self.slot_req[slot] = r
        self.slot_budget[slot] = r.max_new_tokens - 1

    # ------------------------------------------------------------------
    def decode_round(self):
        """One decode step for all live slots."""
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return
        t0 = time.perf_counter()
        tok = np.zeros((self.B,), np.int32)
        for i in live:
            tok[i] = self.slot_req[i].output[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(tok),
                                          self.cache)
        self.key, sub = jax.random.split(self.key)
        nxt = np.asarray(sample(logits, sub,
                                self.slot_req[live[0]].sampling), np.int32)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["steps"] += 1
        for i in live:
            r = self.slot_req[i]
            r.output.append(int(nxt[i]))
            self.stats["tokens_out"] += 1
            self.slot_budget[i] -= 1
            if (self.slot_budget[i] <= 0
                    or (r.eos_id >= 0 and r.output[-1] == r.eos_id)):
                r.done = True
                self.slot_req[i] = None

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Request]:
        """Offline serve: continuous batching until all requests finish."""
        pending = list(requests)
        submitted: List[Request] = []
        while pending or any(r is not None for r in self.slot_req):
            if pending:
                wave = self.admit_wave(pending)
                submitted += wave
                pending = pending[len(wave):]
            self.decode_round()
        return submitted

    def throughput(self) -> float:
        tot = self.stats["prefill_s"] + self.stats["decode_s"]
        return self.stats["tokens_out"] / tot if tot > 0 else 0.0
