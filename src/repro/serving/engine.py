"""Serving engine: batched prefill + continuous-batching decode.

Slot model (vLLM-style, static shapes for XLA):
  * the engine owns `batch_size` slots and one cache pytree; slot admission,
    budgets and refill-on-completion live in `core.scheduler.SlotScheduler`
    — the SAME policy object the analytical simulator (core/simulator.py)
    replays, so simulated schedules are about this exact code;
  * prefill runs per admission wave (right-padded prompts, per-sequence
    prompt_lens); finished slots are refilled by single-prompt prefill into
    a fresh batch-1 cache that is scattered into the slot (jitted);
  * decode advances all live slots every step (dead slots masked), sampling
    every slot with its own request's SamplingParams.

Recurrent/hybrid archs (state pollution from right pads) are admitted in
equal-length buckets — the scheduler handles that transparently.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.scheduler import SlotScheduler
from .. import models
from .sampler import SamplingParams, sample_per_request


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: int = -1
    sampling: SamplingParams = field(default_factory=SamplingParams)
    output: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, seed: int = 0, policy: str = "continuous"):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.cache = models.init_cache(cfg, batch_size, max_len)
        self.sched = SlotScheduler(batch_size, policy=policy)
        self._prefill = jax.jit(
            lambda p, t, c, l, f: models.prefill(cfg, p, t, c, frontend=f,
                                                 prompt_lens=l))
        self._decode = jax.jit(
            lambda p, t, c: models.decode_step(cfg, p, t, c))
        self._insert = jax.jit(self._insert_impl, static_argnames=("slot",))
        self.stats = {"tokens_out": 0, "prefill_s": 0.0, "decode_s": 0.0,
                      "steps": 0}

    @property
    def slot_req(self) -> List[Optional[Request]]:
        return self.sched.slot_req

    @property
    def slot_budget(self) -> List[int]:
        return self.sched.slot_budget

    # ------------------------------------------------------------------
    def _insert_impl(self, cache, one_cache, slot: int):
        """Scatter a batch-1 cache into `slot` of the engine cache."""
        def put(big, small):
            if big.ndim == 0:
                return big
            # find the batch axis: the dim where shapes differ (B vs 1)
            for ax in range(big.ndim):
                if big.shape[ax] != small.shape[ax] and small.shape[ax] == 1:
                    idx = [slice(None)] * big.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return big.at[tuple(idx)].set(small)
            return big
        return jax.tree.map(put, cache, one_cache)

    # ------------------------------------------------------------------
    def admit_wave(self, requests: List[Request]):
        """Prefill a wave of requests into free slots (right-padded)."""
        pairs = self.sched.plan_wave(requests)
        if not pairs:
            return []
        wave = [r for _, r in pairs]
        t0 = time.perf_counter()
        if self.sched.idle:
            # whole-batch prefill path
            S = max(max(len(r.prompt) for r in wave), 1)
            toks = np.zeros((self.B, S), np.int32)
            lens = np.zeros((self.B,), np.int32)
            for i, r in enumerate(wave):
                toks[i, :len(r.prompt)] = r.prompt
                lens[i] = len(r.prompt)
            lens = np.maximum(lens, 1)
            logits, self.cache = self._prefill(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(lens), None)
            self.key, sub = jax.random.split(self.key)
            first = np.asarray(sample_per_request(
                logits[:len(wave)], sub, [r.sampling for r in wave]),
                np.int32)
            for i, r in enumerate(wave):
                self._admit_slot(i, r, int(first[i]))
        else:
            # per-slot insertion
            for slot, r in pairs:
                one = models.init_cache(self.cfg, 1, self.max_len)
                toks = jnp.asarray([r.prompt], jnp.int32)
                lens = jnp.asarray([len(r.prompt)], jnp.int32)
                logits, one = self._prefill(self.params, toks, one, lens,
                                            None)
                self.cache = self._insert(self.cache, one, slot=slot)
                self.key, sub = jax.random.split(self.key)
                first = sample_per_request(logits[:1], sub, [r.sampling])
                self._admit_slot(slot, r, int(np.asarray(first[0])))
        self.stats["prefill_s"] += time.perf_counter() - t0
        return wave

    # ------------------------------------------------------------------
    def _admit_slot(self, slot: int, r: Request, first_token: int):
        """The prefill's first sampled token counts against the budget."""
        r.output.append(first_token)
        self.stats["tokens_out"] += 1
        if (r.max_new_tokens <= 1
                or (r.eos_id >= 0 and first_token == r.eos_id)):
            r.done = True
            return
        self.sched.admit(slot, r, r.max_new_tokens - 1)

    # ------------------------------------------------------------------
    def decode_round(self):
        """One decode step for all live slots (dead slots stay masked;
        each live slot samples with its own request's SamplingParams)."""
        live = self.sched.live_slots()
        if not live:
            return
        t0 = time.perf_counter()
        tok = np.zeros((self.B,), np.int32)
        for i in live:
            tok[i] = self.sched.slot_req[i].output[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(tok),
                                          self.cache)
        self.key, sub = jax.random.split(self.key)
        nxt = np.asarray(sample_per_request(
            logits[jnp.asarray(live)], sub,
            [self.sched.slot_req[i].sampling for i in live]), np.int32)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["steps"] += 1
        for j, i in enumerate(live):
            r = self.sched.slot_req[i]
            r.output.append(int(nxt[j]))
            self.stats["tokens_out"] += 1
            hit_eos = r.eos_id >= 0 and r.output[-1] == r.eos_id
            if self.sched.step(i, hit_eos=hit_eos):
                r.done = True

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Request]:
        """Offline serve: continuous batching until all requests finish."""
        pending = list(requests)
        submitted: List[Request] = []
        while pending or not self.sched.idle:
            if pending:
                wave = self.admit_wave(pending)
                submitted += wave
                pending = pending[len(wave):]
            self.decode_round()
        return submitted

    def throughput(self) -> float:
        tot = self.stats["prefill_s"] + self.stats["decode_s"]
        return self.stats["tokens_out"] / tot if tot > 0 else 0.0
