"""ISSUE 3: trace-driven serving simulator + shared scheduler + traces.

Guarantees, by layer:
  1. SlotScheduler policy unit behavior (the engine and the simulator run
     THIS code — its admission/budget rules are the contract);
  2. Trace constructors: reproducible, sorted, length specs respected;
  3. simulator conservation (tokens emitted == sum of trace out_lens, all
     requests finish, occupancy bounded by slots) and consistency: a
     constant-arrival uniform trace reproduces inference_model.generate /
     throughput within 1% from one stacked mapper search;
  4. the Study serve stage: TrafficWorkload axis, SimResult plumbing;
  5. the generate() bound-aggregation bugfix (decode-bound generations must
     not report the prefill's compute bound).
"""
import pytest

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan
from repro.core.mapper import clear_matmul_cache
from repro.core.scheduler import SlotScheduler
from repro.core.simulator import simulate, trace_graphs
from repro.core.study import Case, Study
from repro.core.workload import Trace, TrafficWorkload, Workload
from repro.configs import get_config

A100 = hw.make_system(hw.nvidia_a100(), 1)
CFG = get_config("qwen2-0.5b")
PLAN = Plan()


# ---------------------------------------------------------------------------
# 1. SlotScheduler
# ---------------------------------------------------------------------------

def test_scheduler_continuous_admits_greedily():
    s = SlotScheduler(2, policy="continuous")
    assert s.plan_wave(["a", "b", "c"]) == [(0, "a"), (1, "b")]
    s.admit(0, "a", 2)
    assert s.plan_wave(["b"], more_coming=True) == [(1, "b")]
    s.admit(1, "b", 1)
    assert s.plan_wave(["c"]) == []           # no free slots
    assert s.step(1) and s.slot_req[1] is None  # budget 1 -> done
    assert not s.step(0)                        # budget 2 -> one left
    assert s.plan_wave(["c"]) == [(1, "c")]     # refill the freed slot


def test_scheduler_static_waits_for_drain_and_full_batch():
    s = SlotScheduler(2, policy="static")
    # partial batch is held while more arrivals may come, admitted otherwise
    assert s.plan_wave(["a"], more_coming=True) == []
    assert s.plan_wave(["a"], more_coming=False) == [(0, "a")]
    s.admit(0, "a", 2)
    # busy scheduler never admits, even a full waiting batch
    assert s.plan_wave(["b", "c"], more_coming=False) == []
    s.step(0)
    s.step(0)
    assert s.idle
    assert s.plan_wave(["b", "c"]) == [(0, "b"), (1, "c")]


def test_scheduler_admit_and_step_validate():
    s = SlotScheduler(1)
    assert not s.admit(0, "a", 0)     # exhausted budget leaves slot free
    assert s.slot_req[0] is None
    s.admit(0, "a", 5)
    with pytest.raises(ValueError):
        s.admit(0, "b", 3)
    assert s.step(0, hit_eos=True)    # eos releases regardless of budget
    with pytest.raises(ValueError):
        s.step(0)
    with pytest.raises(ValueError):
        SlotScheduler(2, policy="warp")


# ---------------------------------------------------------------------------
# 2. traces
# ---------------------------------------------------------------------------

def test_trace_constructors_reproducible_and_sorted():
    a = Trace.poisson(20, rate=5.0, in_len=(32, 64), out_len=8, seed=3)
    b = Trace.poisson(20, rate=5.0, in_len=(32, 64), out_len=8, seed=3)
    assert a == b and len(a) == 20
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] > 0
    assert all(32 <= r.in_len <= 64 and r.out_len == 8 for r in a)
    g = Trace.gamma(10, rate=5.0, cv=2.0, in_len=16, out_len=(4, 8), seed=1)
    assert all(4 <= r.out_len <= 8 for r in g)
    c = Trace.constant(4, 0.5, 16, 4)
    assert [r.arrival for r in c] == [0.0, 0.5, 1.0, 1.5]
    e = Trace.explicit([(0.2, 8, 2), (0.1, 4, 1)])
    assert [r.arrival for r in e] == [0.1, 0.2]   # re-sorted
    assert e.max_total_len == 10 and e.tokens_out == 3


def test_traffic_workload_axis():
    tr = Trace.constant(6, 0.1, (16, 32), (4, 8), seed=0)
    w = TrafficWorkload.from_trace(tr, slots=4, policy="static")
    assert w.batch == 4 and w.in_len == tr.max_in_len
    assert w.total_len == tr.max_total_len
    assert hash(w) == hash(TrafficWorkload.from_trace(tr, slots=4,
                                                      policy="static"))
    assert "static" in w.tag
    with pytest.raises(ValueError):
        TrafficWorkload.from_trace(Trace(()), slots=4)


# ---------------------------------------------------------------------------
# 3. simulator conservation + consistency
# ---------------------------------------------------------------------------

def test_simulator_conserves_tokens_mixed_traffic():
    trace = Trace.poisson(24, rate=30.0, in_len=(16, 96), out_len=(4, 24),
                          seed=11)
    for policy in ("continuous", "static"):
        w = TrafficWorkload.from_trace(trace, slots=4, policy=policy,
                                       kv_samples=4, seq_samples=4)
        r = simulate(A100, CFG, PLAN, w)
        assert r.tokens_out == trace.tokens_out, policy
        assert all(q.emitted == q.out_len for q in r.requests), policy
        assert all(q.e2e >= q.ttft > 0 for q in r.requests), policy
        assert all(0 <= live <= 4 for _, live in r.occupancy), policy
        assert r.makespan >= trace.requests[-1].arrival
        assert r.prefill_busy + r.decode_busy + r.idle <= r.makespan + 1e-9


def test_simulator_matches_generate_and_throughput():
    """One uniform admission wave == the closed-form generate()/throughput()
    numbers within 1% (acceptance criterion), from ONE stacked search."""
    B, I, O = 4, 128, 32
    clear_matmul_cache()
    ev = Evaluator(A100)
    w = TrafficWorkload.from_trace(Trace.constant(B, 0.0, I, O), slots=B)
    r = simulate(A100, CFG, PLAN, w, evaluator=ev)
    assert ev.stats.batched_searches == 1     # no per-step re-search
    g = im.generate(A100, CFG, PLAN, B, I, O, evaluator=ev)
    thr = im.throughput(A100, CFG, PLAN, B, I, O, evaluator=ev)
    clear_matmul_cache()
    assert abs(r.e2e(50) - g.latency) / g.latency < 0.01
    assert abs(r.e2e(99) - g.latency) / g.latency < 0.01
    assert abs(r.goodput - thr) / thr < 0.01
    # TTFT analog: prefill + first decode round
    assert r.ttft(50) < g.breakdown["prefill"] * 1.5


def test_simulator_continuous_beats_static_ttft():
    trace = Trace.poisson(16, rate=20.0, in_len=64, out_len=16, seed=5)
    res = {}
    for policy in ("continuous", "static"):
        w = TrafficWorkload.from_trace(trace, slots=4, policy=policy,
                                       kv_samples=4)
        res[policy] = simulate(A100, CFG, PLAN, w)
    assert res["continuous"].ttft(99) < res["static"].ttft(99)
    assert res["continuous"].waves >= res["static"].waves


def test_simulator_validates_trace():
    with pytest.raises(ValueError):
        simulate(A100, CFG, PLAN,
                 TrafficWorkload(batch=2, in_len=8, out_len=1))
    bad = TrafficWorkload(batch=1, in_len=8, out_len=1,
                          trace=Trace.explicit([(0.0, 8, 0)]))
    with pytest.raises(ValueError):
        simulate(A100, CFG, PLAN, bad)


# ---------------------------------------------------------------------------
# 4. Study serve stage
# ---------------------------------------------------------------------------

def test_study_serve_stage():
    trace = Trace.poisson(8, rate=20.0, in_len=(16, 64), out_len=8, seed=2)
    wls = [TrafficWorkload.from_trace(trace, slots=2, policy=p,
                                      kv_samples=4, seq_samples=4)
           for p in ("continuous", "static")]
    res = Study(systems=[A100], configs=[CFG], plans=[PLAN],
                workloads=wls, stage="serve").run()
    assert len(res) == 2
    assert res.stats.matmul_pairs_presolved > 0
    for r in res:
        assert r.sim is not None
        assert r.throughput == r.sim.goodput
        assert r.latency == r.sim.e2e(50)
        assert r.sim.tokens_out == trace.tokens_out
        row = r.to_row()
        assert row["goodput_tok_s"] == r.sim.goodput
        assert row["ttft_p99_s"] == r.sim.ttft(99)
    # non-serve rows keep the columns, empty
    assert Study(systems=[A100], configs=[CFG], plans=[PLAN],
                 workloads=[Workload(1, 32, 4, samples=4)]
                 ).run()[0].to_row()["goodput_tok_s"] == ""


def test_study_serve_stage_requires_traffic_workload():
    with pytest.raises(ValueError):
        Case(A100, CFG, PLAN, Workload(4, 128, 16), stage="serve")


def test_trace_graphs_cover_axes():
    trace = Trace.poisson(6, rate=10.0, in_len=(16, 48), out_len=8, seed=0)
    w = TrafficWorkload.from_trace(trace, slots=4, kv_samples=4,
                                   seq_samples=3)
    graphs = trace_graphs(CFG, PLAN, w)
    assert len(graphs) >= 3           # wave prefills + refills + decodes
    assert all(len(g) > 0 for g in graphs)


# ---------------------------------------------------------------------------
# 5. generate() bound aggregation (satellite bugfix)
# ---------------------------------------------------------------------------

def test_generate_bound_aggregates_decode():
    """A decode-heavy generation must be memory-bound end-to-end even though
    its prefill pass alone is compute-bound (the seed reported the latter)."""
    gpt3 = get_config("gpt3-175b")
    node = hw.dgx_a100(4)
    plan = Plan(tp=4)
    g = im.generate(node, gpt3, plan, 8, 512, 512)
    pf = im.prefill(node, gpt3, plan, 8, 512)
    assert pf.dominant == "compute"
    assert g.breakdown["decode"] > g.breakdown["prefill"]
    assert g.dominant == "memory"
    # bound buckets must account for (almost all of) the total latency
    assert sum(g.bound.values()) == pytest.approx(g.latency, rel=0.05)
    # flops/bytes now cover prefill + decode, not prefill alone
    assert g.flops > pf.flops and g.bytes > pf.bytes
