"""Behavioral regression tests for the real unit bugs the dimensional-
analysis pass surfaced (tentpole satellite). One test class per fix:

  a) interconnect: ring-reduce "flops" were computed as bytes/width —
     dimensionally Elements. The fix routes them through
     REDUCE_FLOPS_PER_ELEMENT (x1.0, value-preserving); these tests pin the
     reduction accounting to the LogGP hand-formula so the conversion can
     never silently pick up a non-unity factor.
  b) operators: chunked-norm fp32 partials were charged 8 bytes per value
     (a bytes-vs-elements slip); fp32 is 4 bytes. This changes numbers in
     the chunked regime only — MODEL_VERSION was bumped for it.
  c) operators.recurrent_scan: the sequential-chain floor is a cycle count
     and must cross to seconds through the device frequency (value-
     preserving rewrite; pinned here against the hand formula).
"""
import math

import pytest

from repro.core import hardware as hw
from repro.core import interconnect as net
from repro.core import operators, result_cache


# ---------------------------------------------------------------------------
# (a) collective reduction accounting
# ---------------------------------------------------------------------------

class TestReduceFlops:
    def test_conversion_factor_is_unity(self):
        # the fix is value-preserving by construction: one add per element
        assert net.REDUCE_FLOPS_PER_ELEMENT == 1.0

    def test_all_reduce_matches_hand_formula(self):
        system = hw.dgx_a100(4)
        n_bytes = 1 << 22
        r = net.all_reduce(system, n_bytes)
        n = system.device_count
        chunk = n_bytes / n
        red_flops = (n - 1) * chunk / 2.0       # fp16 payload: 2 B/element
        assert r.flops == red_flops
        expected = (2 * (n - 1) * net.link_time(system.link, chunk)
                    + red_flops / system.device.peak_vector_flops)
        assert r.latency == pytest.approx(expected, rel=1e-15)
        assert r.main_memory_bytes == 2 * (n - 1) * chunk

    def test_reduce_scatter_matches_hand_formula(self):
        system = hw.dgx_a100(8)
        n_bytes = 3 << 20
        r = net.reduce_scatter(system, n_bytes)
        n = system.device_count
        chunk = n_bytes / n
        red_flops = (n - 1) * chunk / 2.0
        assert r.flops == red_flops
        expected = ((n - 1) * net.link_time(system.link, chunk)
                    + red_flops / system.device.peak_vector_flops)
        assert r.latency == pytest.approx(expected, rel=1e-15)

    def test_narrow_payload_doubles_adds_per_byte(self):
        system = hw.dgx_a100(4)
        fp16 = net.all_reduce(system, 1 << 20, bytes_elt=2.0)
        fp8 = net.all_reduce(system, 1 << 20, bytes_elt=1.0)
        assert fp8.flops == 2 * fp16.flops


# ---------------------------------------------------------------------------
# (b) chunked-norm partials are fp32 = 4 bytes
# ---------------------------------------------------------------------------

def _chunked_shape(dev, bytes_in=2):
    """(rows, cols) that force a multi-chunk row reduction on `dev`."""
    chunk = max(1, dev.core.local_buffer_bytes // (2 * bytes_in))
    cols = 4 * chunk
    return 64, cols, 4    # rows, cols, n_chunks == ceil(cols/chunk)


class TestNormPartialWidth:
    def test_fp32_is_four_bytes(self):
        assert operators.FP32_BYTES == 4.0

    def test_layernorm_penalty_scales_with_partial_width(self, monkeypatch):
        """Doubling FP32_BYTES back to the buggy 8 must raise latency by
        exactly the extra partial traffic through the global buffer —
        proving the penalty term is wired through the constant."""
        dev = hw.nvidia_a100()
        rows, cols, n_chunks = _chunked_shape(dev)
        r4 = operators.layernorm(dev, rows, cols)
        monkeypatch.setattr(operators, "FP32_BYTES", 8.0)
        r8 = operators.layernorm(dev, rows, cols)
        extra = 2 * (rows * n_chunks * 2) * 4.0 / dev.global_buffer_bandwidth
        assert r8.latency - r4.latency == pytest.approx(extra, rel=1e-9)
        # streamed bytes are unaffected: partials move GB<->cores, not HBM
        assert r8.main_memory_bytes == r4.main_memory_bytes

    def test_rmsnorm_penalty_scales_with_partial_width(self, monkeypatch):
        dev = hw.nvidia_a100()
        rows, cols, n_chunks = _chunked_shape(dev)
        r4 = operators.rmsnorm(dev, rows, cols)
        monkeypatch.setattr(operators, "FP32_BYTES", 8.0)
        r8 = operators.rmsnorm(dev, rows, cols)
        extra = 2 * (rows * n_chunks) * 4.0 / dev.global_buffer_bandwidth
        assert r8.latency - r4.latency == pytest.approx(extra, rel=1e-9)

    def test_layernorm_chunked_matches_hand_formula(self):
        dev = hw.nvidia_a100()
        rows, cols, n_chunks = _chunked_shape(dev)
        r = operators.layernorm(dev, rows, cols)
        n = rows * cols
        mem_t = (n * 4 / dev.memory_bandwidth
                 + 2 * (rows * n_chunks * 2 * 4.0)
                 / dev.global_buffer_bandwidth)
        assert r.bound == "memory"          # this regime is memory-bound
        assert r.latency == pytest.approx(
            mem_t + dev.kernel_launch_overhead_s, rel=1e-12)

    def test_unchunked_norms_unchanged_by_the_constant(self, monkeypatch):
        """d_model-sized rows (the frozen seed path) never chunk on A100, so
        the fix provably cannot move the fp16 reference numbers."""
        dev = hw.nvidia_a100()
        chunk = max(1, dev.core.local_buffer_bytes // 4)
        cols = 12288                        # GPT-3 d_model
        assert cols <= chunk
        before = operators.layernorm(dev, 2048, cols)
        monkeypatch.setattr(operators, "FP32_BYTES", 8.0)
        after = operators.layernorm(dev, 2048, cols)
        assert before == after

    def test_model_version_bumped_for_the_numeric_change(self):
        # the fp32 fix moves chunked-regime numbers -> cache salt must move
        assert result_cache.MODEL_VERSION == "hwe-v7"


# ---------------------------------------------------------------------------
# (c) scan chain floor crosses cycles -> seconds through the frequency
# ---------------------------------------------------------------------------

class TestScanChainFloor:
    def test_chain_floor_matches_hand_formula(self):
        dev = hw.nvidia_a100()
        seq, batch, d_state, chunk = 8192, 1, 65536, 128
        # negligible flops/io so the sequential chain dominates
        r = operators.recurrent_scan(dev, seq, batch, d_state,
                                     flops_per_step=1.0, bytes_io=1.0,
                                     chunk=chunk)
        width = max(dev.core.lane.vector_unit.width, 1)
        chain_cycles = (seq / chunk) * (d_state / width)
        expected = chain_cycles / dev.frequency_hz \
            + dev.kernel_launch_overhead_s
        assert r.latency == pytest.approx(expected, rel=1e-12)

    def test_chain_floor_scales_inverse_with_frequency(self):
        import dataclasses
        dev = hw.nvidia_a100()
        slow = dataclasses.replace(dev, frequency_hz=dev.frequency_hz / 2)
        seq, batch, d_state = 8192, 1, 65536
        fast_r = operators.recurrent_scan(dev, seq, batch, d_state,
                                          flops_per_step=1.0, bytes_io=1.0)
        slow_r = operators.recurrent_scan(slow, seq, batch, d_state,
                                          flops_per_step=1.0, bytes_io=1.0)
        fast_chain = fast_r.latency - dev.kernel_launch_overhead_s
        slow_chain = slow_r.latency - slow.kernel_launch_overhead_s
        assert slow_chain == pytest.approx(2 * fast_chain, rel=1e-9)
