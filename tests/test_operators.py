"""Operator performance models (paper Sec. III-B3) + interconnect (III-B2)."""

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import hardware as hw
from repro.core import operators as ops
from repro.core import interconnect as net

A100 = hw.nvidia_a100()


def test_softmax_memory_bound_large():
    r = ops.softmax(A100, 32768, 4096)
    assert r.bound == "memory"
    # bytes: online algorithm = 1 read (fits GB) + 1 write at minimum
    assert r.main_memory_bytes >= 32768 * 4096 * 4


def test_layernorm_extreme_reduction_slows():
    """Fig. 5d: throughput drops at extreme reduction dims."""
    per_byte_fast = ops.layernorm(A100, 8192, 4096).latency / (8192 * 4096)
    per_byte_slow = ops.layernorm(A100, 8, 4 << 20).latency / (8 * (4 << 20))
    assert per_byte_slow > per_byte_fast * 1.2


def test_tiny_op_is_overhead_bound():
    r = ops.gelu(A100, 128)
    assert r.bound == "overhead"
    assert r.latency >= A100.kernel_launch_overhead_s


def test_op_add_combines():
    a = ops.gelu(A100, 1 << 20)
    b = ops.softmax(A100, 1024, 1024)
    c = a + b
    assert c.latency == pytest.approx(a.latency + b.latency)
    assert c.flops == a.flops + b.flops


def test_op_add_keeps_dominant_mapping():
    """Combined results carry the dominant operand's Pallas BlockSpec hint."""
    mm = ops.matmul(A100, 4096, 4096, 4096)
    small = ops.gelu(A100, 128)
    assert mm.latency > small.latency
    assert (mm + small).mapping == mm.mapping
    assert (small + mm).mapping == mm.mapping      # dominant wins either way
    # dominant without a mapping falls back to the other operand's
    assert (mm + ops.matmul(A100, 64, 64, 64)).mapping == mm.mapping


def test_rmsnorm_first_class_model():
    """No fudge factors: ~4 flops/element, one fused read+write pass, same
    chunked-reduction penalty mechanism as layernorm."""
    r = ops.rmsnorm(A100, 8192, 4096)
    ln = ops.layernorm(A100, 8192, 4096)
    assert r.flops == 4.0 * 8192 * 4096
    assert r.main_memory_bytes == 8192 * 4096 * 4      # 1 read + 1 write, bf16
    assert 0 < r.latency <= ln.latency                 # cheaper than layernorm
    # extreme reduction dims lose row parallelism and pay the cross-chunk
    # penalty (paper Fig. 5d trend)
    per_elt_fast = ops.rmsnorm(A100, 8192, 4096).latency / (8192 * 4096)
    per_elt_slow = ops.rmsnorm(A100, 2, 4 << 20).latency / (2 * (4 << 20))
    assert per_elt_slow > per_elt_fast * 1.2


@given(n=st.integers(1, 1 << 28))
@settings(max_examples=30, deadline=None)
def test_latency_positive_and_finite(n):
    r = ops.gelu(A100, n)
    assert 0 < r.latency < 10.0


# ---------------- interconnect ----------------

def test_link_framing_overhead():
    """Eq. 2: n_hat > n by the flit-per-payload framing factor."""
    link = hw.Link(bandwidth_bytes=600e9)
    t_raw = 1e6 / 600e9
    t = net.link_time(link, 1e6)
    assert t > t_raw
    # framing: 16B flit per 256B payload = 6.25% overhead
    assert t - link.latency_s - link.overhead_s == pytest.approx(
        t_raw * (1 + 16 / 256), rel=0.01)


def test_ring_allreduce_busbw_optimal():
    """Large-message ring all-reduce approaches 2(n-1)/n algorithmic bw."""
    sys4 = hw.dgx_a100(4)
    n_bytes = 1 << 30
    r = net.all_reduce(sys4, n_bytes)
    algo_bytes = 2 * (4 - 1) / 4 * n_bytes
    busbw = algo_bytes / r.latency
    assert busbw == pytest.approx(600e9 / (1 + 16 / 256), rel=0.1)


def test_allreduce_zero_on_one_device():
    sys1 = hw.dgx_a100(1)
    assert net.all_reduce(sys1, 1 << 20).latency == 0.0


@given(n=st.sampled_from([2, 4, 8, 16]), mb=st.integers(1, 512))
@settings(max_examples=20, deadline=None)
def test_reduce_scatter_plus_allgather_close_to_allreduce(n, mb):
    sys_ = hw.make_system(hw.nvidia_a100(), n)
    bytes_ = mb * (1 << 20)
    ar = net.all_reduce(sys_, bytes_).latency
    rs = net.reduce_scatter(sys_, bytes_).latency
    ag = net.all_gather(sys_, bytes_).latency
    assert rs + ag == pytest.approx(ar, rel=0.25)


def test_latency_term_dominates_small_messages():
    sys4 = hw.dgx_a100(4)
    r = net.all_reduce(sys4, 64)
    assert r.latency >= 2 * 3 * sys4.link.latency_s
