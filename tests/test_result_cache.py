"""ISSUE 6: the persistent content-hashed result layer + bounded LRU memo.

Covers the storage module itself (canonical hashing, atomic writes,
corruption tolerance, stale-salt invalidation), the mapper's two memo layers
(bounded LRU with eviction accounting — the seed's dict silently stopped
inserting at capacity — and the disk-backed warm path), EvalStats
attribution, and the Study-level CaseResult cache (warm reruns bit-identical
to the uncached path, malformed entries re-priced).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import hardware as hw
from repro.core import mapper
from repro.core import result_cache
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan, build_model
from repro.core.mapper import (clear_matmul_cache, matmul_cache_stats,
                               matmul_perf_batch, reset_matmul_cache_stats)
from repro.core.result_cache import (DiskCache, canonical, content_key,
                                     cache_enabled, cache_root)
from repro.core.study import Case, Study
from repro.core.workload import Workload
from repro.configs import get_config

A100 = hw.nvidia_a100()

# cheap-to-search distinct shapes (full 10-tuples)
def _shape(m, k=256, n=256):
    return (m, k, n, 1, 2, 2, 2, 2, False, 1.0)


@pytest.fixture(autouse=True)
def _cold_memo():
    clear_matmul_cache()
    reset_matmul_cache_stats()
    yield
    clear_matmul_cache()
    reset_matmul_cache_stats()


# ---------------------------------------------------------------------------
# canonical hashing
# ---------------------------------------------------------------------------

def test_canonical_float_repr_roundtrip():
    assert canonical(0.1) == repr(0.1)
    assert float(canonical(1 / 3)) == 1 / 3
    assert content_key(0.1) != content_key(0.1 + 2 ** -55)


def test_canonical_distinguishes_dataclass_types():
    @dataclasses.dataclass(frozen=True)
    class P:
        x: int = 1

    @dataclasses.dataclass(frozen=True)
    class Q:
        x: int = 1

    assert content_key(P()) != content_key(Q())


def test_canonical_numpy_scalars_collapse():
    assert canonical(np.int64(5)) == 5
    assert content_key(np.float64(0.5)) == content_key(0.5)


def test_canonical_rejects_non_value_types():
    with pytest.raises(TypeError):
        canonical(lambda: 0)
    with pytest.raises(TypeError):
        content_key(np.zeros(3))


def test_content_key_salt_invalidates():
    dev = A100
    assert content_key(dev, salt="hwe-v6") != content_key(dev, salt="hwe-v7")


# ---------------------------------------------------------------------------
# DiskCache
# ---------------------------------------------------------------------------

def test_disk_roundtrip_stats_and_clear(tmp_path):
    dc = DiskCache("t", root=tmp_path, enabled=True)
    key = content_key("hello")
    assert dc.get(key) is None and dc.stats.misses == 1
    dc.put(key, {"v": [1, 2.5, "x"]})
    assert dc.stats.puts == 1 and len(dc) == 1
    assert dc.get(key) == {"v": [1, 2.5, "x"]} and dc.stats.hits == 1
    dc.clear()
    assert len(dc) == 0 and dc.get(key) is None


def test_disk_corrupt_entry_dropped(tmp_path):
    dc = DiskCache("t", root=tmp_path, enabled=True)
    key = content_key("x")
    dc.put(key, {"v": 1})
    path = dc._path(key)
    path.write_text("{torn wri")
    assert dc.get(key) is None
    assert dc.stats.corrupt == 1
    assert not path.exists()            # dropped, not re-read forever
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps([1, 2]))  # valid JSON, wrong shape
    assert dc.get(key) is None and dc.stats.corrupt == 2


def test_disk_disabled_is_inert(tmp_path):
    dc = DiskCache("t", root=tmp_path, enabled=False)
    dc.put(content_key("x"), {"v": 1})
    assert len(dc) == 0 and dc.get(content_key("x")) is None
    # enabled=None follows the global switch
    follow = DiskCache("t2", root=tmp_path)
    with result_cache.disabled():
        assert not follow.enabled
        follow.put(content_key("x"), {"v": 1})
    assert len(follow) == 0


def test_overridden_restores_root_and_switch(tmp_path):
    root0, on0 = cache_root(), cache_enabled()
    with result_cache.overridden(root=tmp_path / "a", enabled=True):
        assert cache_root() == tmp_path / "a" and cache_enabled()
        with result_cache.disabled():
            assert not cache_enabled()
        assert cache_enabled()
    assert cache_root() == root0 and cache_enabled() == on0


# ---------------------------------------------------------------------------
# mapper: bounded LRU memo
# ---------------------------------------------------------------------------

def test_lru_eviction_bounds_and_counts(monkeypatch):
    monkeypatch.setattr(mapper, "_MM_CACHE_MAX", 3)
    with result_cache.disabled():
        shapes = [_shape(8 * (i + 1)) for i in range(5)]
        matmul_perf_batch(A100, shapes)
        st = matmul_cache_stats()
        assert len(mapper._MM_CACHE) <= 3
        assert st.evictions >= 2 and st.misses == 5
        # the two oldest shapes were evicted — searching them again misses
        matmul_perf_batch(A100, shapes[:1])
        assert matmul_cache_stats().misses == 6


def test_lru_hit_refreshes_recency(monkeypatch):
    monkeypatch.setattr(mapper, "_MM_CACHE_MAX", 3)
    with result_cache.disabled():
        a, b, c, d = [_shape(8 * (i + 1)) for i in range(4)]
        matmul_perf_batch(A100, [a, b, c])
        matmul_perf_batch(A100, [a])        # touch a: now LRU order b, c, a
        matmul_perf_batch(A100, [d])        # evicts b, not a
        assert mapper.is_memoized(A100, a)
        assert not mapper.is_memoized(A100, b)
        assert matmul_cache_stats().memo_hits == 1


# ---------------------------------------------------------------------------
# mapper: persistent layer
# ---------------------------------------------------------------------------

def test_mapper_disk_warm_restart_bit_identical(tmp_path):
    shapes = [_shape(16), _shape(48, 128, 512)]
    puts0 = mapper._disk_cache().stats.puts    # session-cumulative counter
    with result_cache.overridden(root=tmp_path, enabled=True):
        cold = matmul_perf_batch(A100, shapes)
        st = matmul_cache_stats()
        assert st.misses == 2
        assert mapper._disk_cache().stats.puts == puts0 + 2
        clear_matmul_cache()                # "new process": memo gone
        warm = matmul_perf_batch(A100, shapes)
        assert matmul_cache_stats().disk_hits == 2
        for c, w in zip(cold, warm):
            assert c == w                   # frozen dataclasses: bit-exact
        clear_matmul_cache(disk=True)
        assert len(mapper._disk_cache()) == 0


def test_mapper_stale_salt_unreachable(tmp_path, monkeypatch):
    shape = [_shape(24)]
    with result_cache.overridden(root=tmp_path, enabled=True):
        matmul_perf_batch(A100, shape)
        clear_matmul_cache()
        monkeypatch.setattr(mapper, "MODEL_VERSION", "hwe-vNEXT")
        matmul_perf_batch(A100, shape)
        st = matmul_cache_stats()
        assert st.disk_hits == 0 and st.misses == 2   # old entry unreachable


def test_mapper_disk_key_includes_backend(tmp_path, monkeypatch):
    with result_cache.overridden(root=tmp_path, enabled=True):
        k_np = mapper._pair_key(A100, _shape(16))
        monkeypatch.setattr(mapper, "_BACKEND", "jax")
        assert mapper._pair_key(A100, _shape(16)) != k_np


def test_mapper_malformed_disk_doc_is_missed(tmp_path):
    shape = [_shape(32)]
    with result_cache.overridden(root=tmp_path, enabled=True):
        cold = matmul_perf_batch(A100, shape)
        key = mapper._pair_key(A100, shape[0])
        mapper._disk_cache().put(key, {"latency": 1.0})   # truncated doc
        clear_matmul_cache()
        again = matmul_perf_batch(A100, shape)
        assert again[0] == cold[0]          # re-searched, not garbage
        assert matmul_cache_stats().misses == 2


# ---------------------------------------------------------------------------
# EvalStats attribution
# ---------------------------------------------------------------------------

def _graph():
    return build_model(get_config("qwen2-0.5b"), Plan(tp=1), batch=4,
                       seq=128, kv_len=128)


def test_evalstats_memo_and_disk_hits(tmp_path):
    sys1 = hw.make_system(A100, 1, 600, "fc")
    with result_cache.overridden(root=tmp_path, enabled=True):
        ev1 = Evaluator(sys1)
        ev1.evaluate(_graph())
        assert ev1.stats.mapper_memo_hits == 0
        assert ev1.stats.mapper_disk_hits == 0
        # same process: the global LRU serves a fresh Evaluator
        ev2 = Evaluator(sys1)
        ev2.evaluate(_graph())
        assert ev2.stats.mapper_memo_hits > 0
        # "new process": memo dropped, the disk layer serves instead
        clear_matmul_cache()
        ev3 = Evaluator(sys1)
        ev3.evaluate(_graph())
        assert ev3.stats.mapper_disk_hits > 0
        assert ev3.stats.mapper_memo_hits == 0


def test_evalstats_eviction_attribution(tmp_path, monkeypatch):
    monkeypatch.setattr(mapper, "_MM_CACHE_MAX", 2)
    with result_cache.disabled():
        ev = Evaluator(hw.make_system(A100, 1, 600, "fc"))
        ev.evaluate(_graph())
        assert ev.stats.mapper_evictions > 0


# ---------------------------------------------------------------------------
# Study CaseResult cache
# ---------------------------------------------------------------------------

def _cases():
    sysA = hw.make_system(hw.compute_design("A"), 4, 600, "fc")
    cfg = get_config("qwen2-0.5b")
    return [Case(sysA, cfg, Plan(tp=1, dp=4), w, label=n)
            for n, w in (("a", Workload(4, 256, 64)),
                         ("b", Workload(8, 128, 128)))]


def test_study_warm_rerun_bit_identical(tmp_path):
    with result_cache.overridden(root=tmp_path, enabled=True):
        cold = Study(cases=_cases(), enforce_fits=False).run()
        assert cold.stats.case_cache_misses == 2
        assert cold.stats.case_cache_hits == 0
        clear_matmul_cache()
        warm = Study(cases=_cases(), enforce_fits=False).run()
        assert warm.stats.case_cache_hits == 2
        assert warm.stats.matmul_pairs_presolved == 0   # nothing re-priced
        for c, w in zip(cold, warm):
            assert c.latency == w.latency
            assert c.throughput == w.throughput
            assert c.prefill_latency == w.prefill_latency
            assert c.decode_latency == w.decode_latency
            assert c.dominant == w.dominant


def test_study_overlapping_grid_reprices_only_new(tmp_path):
    with result_cache.overridden(root=tmp_path, enabled=True):
        Study(cases=_cases()[:1], enforce_fits=False).run()
        both = Study(cases=_cases(), enforce_fits=False).run()
        assert both.stats.case_cache_hits == 1
        assert both.stats.case_cache_misses == 1


def test_study_result_cache_opt_out(tmp_path):
    with result_cache.overridden(root=tmp_path, enabled=True):
        Study(cases=_cases(), enforce_fits=False, result_cache=False).run()
        again = Study(cases=_cases(), enforce_fits=False,
                      result_cache=False).run()
        assert again.stats.case_cache_hits == 0
        assert again.stats.case_cache_misses == 0


def test_study_stale_salt_reprices(tmp_path, monkeypatch):
    import repro.core.study as study_mod
    with result_cache.overridden(root=tmp_path, enabled=True):
        Study(cases=_cases(), enforce_fits=False).run()
        monkeypatch.setattr(study_mod, "MODEL_VERSION", "hwe-vNEXT")
        rerun = Study(cases=_cases(), enforce_fits=False).run()
        assert rerun.stats.case_cache_hits == 0
        assert rerun.stats.case_cache_misses == 2


def test_study_malformed_case_doc_reprices(tmp_path):
    with result_cache.overridden(root=tmp_path, enabled=True):
        cold = Study(cases=_cases(), enforce_fits=False).run()
        s = Study(cases=_cases(), enforce_fits=False)
        key = s._case_key(s.cases[0])
        s._case_cache.put(key, {"latency": 1.0})        # truncated doc
        rerun = s.run()
        assert rerun.stats.case_cache_hits == 1         # the intact one
        assert rerun[0].latency == cold[0].latency      # re-priced correctly
