"""Trace export (ISSUE 9): Perfetto schema validity, span == makespan
bit-for-bit, byte-level determinism (including numpy-vs-jax mapper
backends), and the fused-epilogue elided-bytes single source of truth."""
import json

import pytest

from repro.configs import get_config
from repro.core import fusion as fu
from repro.core import hardware as hw
from repro.core import obs, result_cache
from repro.core.evaluator import Evaluator
from repro.core.fusion import (_epilogue_ok, _in_read_bytes,
                               _out_write_bytes, elided_bytes, fuse)
from repro.core.graph import Plan, build_layer, build_model
from repro.core.ir import FusedMatmulSpec, MatmulSpec
from repro.core.mapper import clear_matmul_cache, set_mapper_backend
from repro.core.schedule import schedule_graph
from repro.core.simulator import simulate
from repro.core.trace_export import (_ts, schedule_trace_events,
                                     simulation_trace_events,
                                     to_perfetto_json, total_span_us,
                                     validate_trace_events, write_trace)
from repro.core.workload import Trace, TrafficWorkload


# ---------------------------------------------------------------------------
# schedule export
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prefill():
    """GPT-3 175B prefill on 4x A100, FULL fusion, overlap schedule."""
    cfg = get_config("gpt3-175b")
    ev = Evaluator(hw.dgx_a100(4), verify="off")
    g = fuse(build_model(cfg, Plan(tp=4), 2, 256, kv_len=256), fu.FULL)
    cost = ev.evaluate(g, overlap=True)
    return ev, g, cost


def test_schedule_trace_schema_and_span(prefill):
    _, g, cost = prefill
    sch = cost.schedule
    events = schedule_trace_events(sch, g)
    assert validate_trace_events(events) == []
    # acceptance criterion: exported span equals the makespan bit-for-bit
    assert total_span_us(events) == _ts(sch.makespan)
    b = [e for e in events if e["ph"] == "B"]
    e = [e for e in events if e["ph"] == "E"]
    assert len(b) == len(e) == len(sch.slots)
    # lane metadata names every used resource
    lanes = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert lanes == {s.resource for s in sch.slots}
    # graph-enriched args: fused kernels carry their elided bytes
    fused_b = [ev for ev in b if ev["args"].get("kind") == "FusedMatmulSpec"]
    assert fused_b
    assert sum(ev["args"]["elided_bytes"] for ev in fused_b) > 0
    # at least one op sits on the critical path
    assert any(ev["args"]["critical"] for ev in b)


def test_schedule_trace_deterministic(prefill):
    ev, g, _ = prefill
    a = to_perfetto_json(
        schedule_trace_events(ev.evaluate(g, overlap=True).schedule, g))
    b = to_perfetto_json(
        schedule_trace_events(ev.evaluate(g, overlap=True).schedule, g))
    assert a == b


def test_serial_schedule_trace(prefill):
    """The CLI's no-overlap display path: a dependency-ordered timeline."""
    ev, g, _ = prefill
    cost = ev.evaluate(g, overlap=False)
    sch = schedule_graph(g, [o.latency for o in cost.ops],
                         pipeline_collectives=False)
    events = schedule_trace_events(sch, g)
    assert validate_trace_events(events) == []
    assert total_span_us(events) == _ts(sch.makespan)


def test_pipelined_collectives_keep_span_exact():
    """When the last-finishing op is an overlapped collective, the instant
    marker at its consumer-visible end must still close the span."""
    cfg = get_config("gpt3-175b")
    g = fuse(build_model(cfg, Plan(tp=4), 2, 256, kv_len=256), fu.FULL)
    ev = Evaluator(hw.dgx_a100(4), verify="off")
    cost = ev.evaluate(g, overlap=True)
    sch = cost.schedule
    events = schedule_trace_events(sch, g)
    pipelined = [s for s in sch.slots if s.end > s.start + s.duration]
    instants = [e for e in events if e["ph"] == "i"]
    assert len(instants) == len(pipelined)
    for e in instants:
        assert e["name"].endswith(":done") and e["s"] == "t"
    assert total_span_us(events) == _ts(sch.makespan)


def test_write_trace_and_json_shape(prefill, tmp_path):
    _, g, cost = prefill
    events = schedule_trace_events(cost.schedule, g)
    path = tmp_path / "t.perfetto.json"
    text = write_trace(str(path), events)
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == len(events)
    assert path.read_text() == text + "\n"


def test_validator_catches_planted_errors():
    base = {"pid": 0, "tid": 0}
    assert validate_trace_events([{"ph": "B", "ts": 0, **base}])  # no name
    assert validate_trace_events(
        [{"name": "x", "ph": "Z", "ts": 0, **base}])              # bad phase
    assert validate_trace_events(
        [{"name": "x", "ph": "B", "ts": -1.0, **base}])           # ts < 0
    assert validate_trace_events(
        [{"name": "x", "ph": "E", "ts": 0, **base}])              # E sans B
    assert validate_trace_events(
        [{"name": "x", "ph": "B", "ts": 0, **base},
         {"name": "y", "ph": "E", "ts": 1, **base}])              # mismatch
    assert validate_trace_events(
        [{"name": "x", "ph": "B", "ts": 5, **base},
         {"name": "x", "ph": "E", "ts": 9, **base},
         {"name": "y", "ph": "B", "ts": 4, **base},
         {"name": "y", "ph": "E", "ts": 9, **base}])              # backwards
    assert validate_trace_events(
        [{"name": "x", "ph": "B", "ts": 0, **base}])              # unclosed
    ok = [{"name": "x", "ph": "B", "ts": 0, **base},
          {"name": "x", "ph": "E", "ts": 2.5, **base}]
    assert validate_trace_events(ok) == []


def test_ts_quantizer():
    assert _ts(0.0) == 0.0
    assert _ts(1.0) == 1_000_000.0
    # picosecond quantum collapses sub-ulp backend noise...
    assert _ts(1.0 + 1e-15) == _ts(1.0)
    # ...but keeps physically meaningful resolution apart
    assert _ts(1.0 + 1e-11) != _ts(1.0)
    # monotone: max over ends == _ts(max) always
    xs = [0.1, 0.2, 0.30000000001]
    assert max(_ts(x) for x in xs) == _ts(max(xs))


# ---------------------------------------------------------------------------
# backend determinism: numpy vs jax traces are byte-identical
# ---------------------------------------------------------------------------

def _layer_trace_bytes() -> str:
    cfg = get_config("qwen2-0.5b")
    g = fuse(build_layer(cfg, Plan(tp=2), 0, 2, 128, 128), fu.FULL)
    ev = Evaluator(hw.dgx_a100(2), verify="off")
    cost = ev.evaluate(g, overlap=True)
    return to_perfetto_json(schedule_trace_events(cost.schedule, g))


def test_numpy_vs_jax_trace_byte_identical():
    pytest.importorskip("jax")
    with result_cache.disabled():
        prev = set_mapper_backend("numpy")
        try:
            clear_matmul_cache()
            via_numpy = _layer_trace_bytes()
            set_mapper_backend("jax")
            clear_matmul_cache()
            via_jax = _layer_trace_bytes()
        finally:
            set_mapper_backend(prev)
            clear_matmul_cache()
    assert via_numpy == via_jax


# ---------------------------------------------------------------------------
# simulator export
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sim_result():
    cfg = get_config("qwen2-0.5b")
    system = hw.dgx_a100(2)
    traffic = TrafficWorkload.from_trace(
        Trace.poisson(8, 16.0, 128, 8, seed=0), slots=4)
    return simulate(system, cfg, Plan(tp=2), traffic,
                    evaluator=Evaluator(system, verify="off"))


def test_simulation_trace_schema_and_span(sim_result):
    events = simulation_trace_events(sim_result)
    assert validate_trace_events(events) == []
    assert total_span_us(events) == _ts(sim_result.makespan)
    # slot-occupancy counter track is present
    counters = [e for e in events if e["ph"] == "C"]
    assert counters and all(e["name"] == "live_slots" for e in counters)
    # per-request lifecycle: queued + generate B/E pairs and a TTFT instant
    n = len(sim_result.requests)
    req_b = [e for e in events if e["ph"] == "B" and e["pid"] == 1]
    assert sum(e["name"] == "queued" for e in req_b) == n
    assert sum(e["name"] == "generate" for e in req_b) == n
    firsts = [e for e in events if e["name"] == "first_token"]
    assert len(firsts) == n
    for e in firsts:
        assert e["ph"] == "i" and e["args"]["ttft_us"] >= 0


def test_simulation_trace_deterministic(sim_result):
    a = to_perfetto_json(simulation_trace_events(sim_result))
    b = to_perfetto_json(simulation_trace_events(sim_result))
    assert a == b


def test_sim_events_tile_the_makespan(sim_result):
    """Engine spans (wave/refill/decode/idle) are contiguous from 0 to the
    makespan — the trace's engine lane has no holes."""
    t = 0.0
    for kind, t0, t1 in sim_result.events:
        assert kind in ("wave", "refill", "decode", "idle")
        assert t0 == pytest.approx(t)
        assert t1 >= t0
        t = t1
    assert t == pytest.approx(sim_result.makespan)


# ---------------------------------------------------------------------------
# elided bytes: single source of truth + pinned GPT-3 4xA100 savings
# ---------------------------------------------------------------------------

def _graph_io_accounting(g, gf):
    """The pre-ISSUE-9 derivation: fusion savings as the difference in
    spec-level graph IO. Kept here as an independent cross-check of the
    per-spec `FusedMatmulSpec.elided` ledger."""
    def graph_io(gr):
        total = 0.0
        for node in gr:
            s = node.spec
            if isinstance(s, FusedMatmulSpec):
                g0 = s.gemm
                total += node.repeat * g0.batch * (
                    g0.m * g0.n * g0.bytes_out + g0.m * g0.k * g0.bytes_a)
            elif isinstance(s, MatmulSpec):
                total += node.repeat * s.batch * (
                    s.m * s.n * s.bytes_out + s.m * s.k * s.bytes_a)
            elif _epilogue_ok(s):
                total += node.repeat * (_in_read_bytes(s)
                                        + _out_write_bytes(s))
        return total
    return graph_io(g) - graph_io(gf)


# savings of GPT-3 175B on 4x A100 (tp=4), pinned: regression values for
# the fused-epilogue ledger (ISSUE 9 satellite). Both FUSED and FULL elide
# the same HBM traffic at these points (FULL additionally overlaps).
_PINS = [(8, 2048, 695784701952.0), (4, 1024, 96636764160.0)]


@pytest.mark.parametrize("batch,seq,pinned", _PINS)
@pytest.mark.parametrize("policy", [fu.FUSED, fu.FULL],
                         ids=["fused", "full"])
def test_gpt3_fusion_savings_pinned(batch, seq, pinned, policy):
    cfg = get_config("gpt3-175b")
    g = build_model(cfg, Plan(tp=4), batch, seq, kv_len=seq)
    gf = fuse(g, policy)
    got = elided_bytes(g, gf)
    assert got == pinned
    # the three surfaces agree exactly: fusion.elided_bytes, the per-spec
    # ledger the attribution rows read, and the legacy graph-IO difference
    ledger = sum(n.repeat * n.spec.elided for n in gf
                 if isinstance(n.spec, FusedMatmulSpec))
    assert ledger == pinned
    assert _graph_io_accounting(g, gf) == pinned


def test_attribution_elided_matches_fusion_accounting(prefill):
    _, g, cost = prefill
    att = obs.attribute(g, cost)
    assert att.elided == elided_bytes(g, g)  # signature symmetry: fused in
    assert att.elided == sum(
        n.repeat * n.spec.elided for n in g
        if isinstance(n.spec, FusedMatmulSpec))
    assert att.elided > 0


def test_serial_policy_elides_nothing():
    cfg = get_config("qwen2-0.5b")
    g = build_model(cfg, Plan(tp=2), 2, 128, kv_len=128)
    gf = fuse(g, fu.SERIAL)
    assert elided_bytes(g, gf) == 0.0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_trace_cli_smoke(tmp_path, capsys):
    from repro.trace import main
    out = tmp_path / "layer.perfetto.json"
    csv_path = tmp_path / "ops.csv"
    rc = main(["--config", "qwen2_0.5b", "--stage", "prefill",
               "--devices", "2", "--tp", "2", "--batch", "2",
               "--in-len", "128", "--out", str(out),
               "--csv", str(csv_path)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert validate_trace_events(doc["traceEvents"]) == []
    text = capsys.readouterr().out
    assert "open in https://ui.perfetto.dev" in text
    assert "total=" in text                  # attribution table printed
    assert csv_path.read_text().startswith("name,group,resource")
