"""ISSUE 2: Study API + device-axis mapper batching tests.

Three layers of guarantees, mirroring test_ir_evaluator.py:
  1. the device-axis stacked search (matmul_perf_batch_multi) reproduces
     matmul_perf_reference per device, bit-for-bit (fixed grid + property);
  2. a systems x configs x workloads Study grid reproduces the single-case
     seed path (im.generate with a cold Evaluator), bit-for-bit, and matches
     frozen seed-commit numbers (tests/data/seed_reference.json "study_grid",
     captured from the single-case path before the Study refactor);
  3. the Study API surface: stages, fits gating, rows/csv/best, per-device
     pricing, and the MoE expert-parallel memory fix.
"""
import json
import os

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan, layer_ops
from repro.core.mapper import (clear_matmul_cache, matmul_perf_batch_multi,
                               matmul_perf_reference)
from repro.core.study import Case, Study
from repro.core.workload import (PAPER_SHAPES, Workload, get_workload,
                                 paper_workloads)
from repro.configs import get_config

REL = 1e-9
_REF_PATH = os.path.join(os.path.dirname(__file__), "data",
                         "seed_reference.json")


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-30)


# ---------------------------------------------------------------------------
# 1. device-axis stacked search vs per-device dense reference
# ---------------------------------------------------------------------------

DEVICES = [hw.nvidia_a100(), hw.amd_mi210(), hw.google_tpu_v5e(),
           hw.compute_design("C")]

SHAPES = [(1, 128, 128, 1, 2, 2, 2, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 2, 2, 2, False, 1.0),
          (2048, 128, 2048, 8, 2, 2, 2, 2, True, 1.0),
          (333, 777, 129, 3, 2, 2, 4, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 1, 2, 4, False, 1.0),    # int8 weights
          (512, 4096, 4096, 1, 1, 1, 1, 4, False, 2.0)]     # w8a8


def test_device_axis_batch_matches_reference_mixed_grid():
    """All (device, shape) pairs in ONE stacked call == per-device dense."""
    pairs = [(dev, sh) for dev in DEVICES for sh in SHAPES]
    clear_matmul_cache()
    out = matmul_perf_batch_multi(pairs)
    clear_matmul_cache()
    for (dev, sh), rb in zip(pairs, out):
        rr = matmul_perf_reference(dev, sh[0], sh[1], sh[2], batch=sh[3],
                                   bytes_a=sh[4], bytes_b=sh[5],
                                   bytes_out=sh[6], bytes_acc=sh[7],
                                   b_shared=sh[8], mac_scale=sh[9])
        assert rb.latency == rr.latency, (dev.name, sh)
        assert rb.flops == rr.flops, (dev.name, sh)
        assert rb.main_memory_bytes == rr.main_memory_bytes, (dev.name, sh)
        assert rb.candidates_searched == rr.candidates_searched, (dev.name, sh)
        assert rb.mapping == rr.mapping, (dev.name, sh)


@given(m=st.sampled_from([1, 16, 77, 512, 4096]),
       k=st.sampled_from([64, 500, 12288]),
       n=st.sampled_from([1, 128, 3072]),
       batch=st.sampled_from([1, 3, 8]),
       wbytes=st.sampled_from([2, 1, 0.5]))
@settings(max_examples=15, deadline=None)
def test_device_axis_batch_property(m, k, n, batch, wbytes):
    shape = (m, k, n, batch, 2, wbytes, 2, 2, False, 1.0)
    clear_matmul_cache()
    out = matmul_perf_batch_multi([(d, shape) for d in DEVICES])
    clear_matmul_cache()
    for d, rb in zip(DEVICES, out):
        rr = matmul_perf_reference(d, m, k, n, batch=batch, bytes_b=wbytes)
        assert rb.latency == rr.latency, d.name
        assert rb.mapping == rr.mapping, d.name


# ---------------------------------------------------------------------------
# 2. Study grid vs single-case seed path + frozen numbers
# ---------------------------------------------------------------------------

def _grid_axes():
    systems = [hw.dgx_a100(4), hw.tpu_v5e_pod(16)]
    configs = [get_config("stablelm-1.6b"), get_config("qwen2-0.5b")]
    wls = {"w512": Workload(4, 512, 64, samples=8),
           "w256": Workload(2, 256, 32, samples=4)}
    return systems, configs, Plan(tp=2, dp=2), wls


def test_study_grid_matches_single_case_seed_path():
    systems, configs, plan, wls = _grid_axes()
    clear_matmul_cache()
    res = Study(systems=systems, configs=configs, plans=[plan],
                workloads=wls, enforce_fits=False).run()
    assert len(res) == 8
    assert res.stats.matmul_pairs_presolved > 0
    for r in res:
        clear_matmul_cache()          # cold single-case call, seed conditions
        w = r.case.workload
        g = im.generate(r.case.system, r.case.cfg, r.case.plan, w.batch,
                        w.in_len, w.out_len, samples=w.samples)
        assert r.latency == g.latency, r.case.label
        assert r.throughput == im.throughput_from_generate(
            g, r.case.plan, w.batch, w.out_len), r.case.label
        assert r.flops == g.flops and r.bytes == g.bytes
    clear_matmul_cache()


def test_study_grid_matches_frozen_seed_commit_numbers():
    ref = json.load(open(_REF_PATH))["study_grid"]
    systems, configs, plan, wls = _grid_axes()
    clear_matmul_cache()
    res = Study(systems=systems, configs=configs, plans=[plan],
                workloads=wls, enforce_fits=False).run()
    clear_matmul_cache()
    assert len(res) == len(ref)
    for r in res:
        sys_tag = f"{r.case.system.device.name}_x{r.case.system.device_count}"
        lat, thr = ref[f"{r.case.cfg.name}/{sys_tag}/{r.case.label}"]
        assert _rel(r.latency, lat) < REL, r.case.label
        assert _rel(r.throughput, thr) < REL, r.case.label


def test_study_layer_stage_matches_layer_ops():
    """The layer stage reproduces the paper-microbenchmark convention."""
    node = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    plan = Plan(tp=4)
    r = Study(cases=[Case(node, cfg, plan, Workload(8, 2048, 1024),
                          stage="layer")], enforce_fits=False).run()[0]
    pf = layer_ops(cfg, node, plan, 0, batch=8, seq=2048, kv_len=2048)
    dc = layer_ops(cfg, node, plan, 0, batch=8, seq=1, kv_len=3072)
    assert _rel(r.prefill_latency, pf.latency) < REL
    assert _rel(r.decode_latency, dc.latency) < REL
    assert r.dominant == max(pf.by_bound(), key=pf.by_bound().get)
    assert r.decode_dominant == max(dc.by_bound(), key=dc.by_bound().get)


def test_study_prefill_decode_stages_match_inference_model():
    node = hw.dgx_a100(4)
    cfg = get_config("qwen2-0.5b")
    plan = Plan(tp=2, dp=2)
    w = Workload(4, 256, 128)
    res = Study(cases=[Case(node, cfg, plan, w, stage="prefill"),
                       Case(node, cfg, plan, w, stage="decode")]).run()
    pf = im.prefill(node, cfg, plan, w.batch, w.in_len)
    dc = im.decode_step(node, cfg, plan, w.batch, w.total_len)
    assert _rel(res[0].latency, pf.latency) < REL
    assert _rel(res[1].latency, dc.latency) < REL


# ---------------------------------------------------------------------------
# 3. API surface
# ---------------------------------------------------------------------------

def test_study_rows_csv_best():
    node = hw.dgx_a100(4)
    cfg = get_config("qwen2-0.5b")
    res = Study(systems=[node], configs=[cfg],
                plans=[Plan(tp=1, dp=4), Plan(tp=4)],
                workloads={"w": Workload(2, 128, 16, samples=4)}).run()
    rows = res.to_rows()
    assert len(rows) == 2
    assert {"latency_s", "throughput_tok_s", "fits", "perf_per_usd",
            "dominant_bound", "area_mm2"} <= set(rows[0])
    csv_text = res.to_csv()
    assert csv_text.splitlines()[0].startswith("label,stage,device")
    assert len(csv_text.splitlines()) == 3
    assert res.best("latency").latency == min(r.latency for r in res)
    assert res.best("throughput").throughput == \
        max(r.throughput for r in res)
    assert res.best("perf_per_dollar").perf_per_dollar == \
        max(r.perf_per_dollar for r in res)
    with pytest.raises(ValueError):
        res.best("nonsense")


def test_study_enforce_fits_skips_evaluation():
    """GPT-3 on one A100 cannot fit: no evaluation cost, inf latency."""
    node = hw.make_system(hw.nvidia_a100(), 1)
    cfg = get_config("gpt3-175b")
    res = Study(systems=[node], configs=[cfg], plans=[Plan()],
                workloads=[Workload(1, 128, 16)]).run()
    r = res[0]
    assert not r.fits
    assert r.latency == float("inf") and r.throughput == 0.0
    assert res.stats.skipped_unfit == 1 and res.stats.evaluated == 0
    with pytest.raises(ValueError):
        res.best("latency")


def test_study_prices_each_device_once():
    """Same device in two systems -> identical per-device pricing columns."""
    from repro.core import area, cost
    dev = hw.nvidia_a100()
    res = Study(systems=[hw.make_system(dev, 1), hw.make_system(dev, 4)],
                configs=[get_config("qwen2-0.5b")], plans=[Plan()],
                workloads=[Workload(1, 128, 8, samples=4)]).run()
    a = area.device_area(dev, 600.0).total_mm2
    c = cost.device_cost(dev, a).total_usd
    for r in res:
        assert r.area_mm2 == a
        assert r.device_cost_usd == c
        assert r.system_cost_usd == c * r.case.system.device_count


def test_study_auto_plans_and_validation():
    node = hw.tpu_v5e_pod(4)
    cfg = get_config("qwen2-0.5b")
    res = Study(systems=[node], configs=[cfg], plans="auto",
                workloads=[Workload(2, 128, 16, samples=4)]).run()
    from repro.core.planner import enumerate_plans
    assert len(res) == len(enumerate_plans(node, cfg))
    with pytest.raises(ValueError):
        Study(systems=[node], configs=[cfg], workloads=[Workload(1, 8, 8)],
              cases=[])
    with pytest.raises(ValueError):
        Case(node, cfg, Plan(), Workload(1, 8, 8), stage="warp")
    with pytest.raises(ValueError):
        Study(systems=[node], configs=[cfg], workloads=None)


def test_study_rejects_mismatched_evaluator():
    node = hw.dgx_a100(4)
    other = hw.tpu_v5e_pod(16)
    with pytest.raises(ValueError):
        Study(cases=[Case(node, get_config("qwen2-0.5b"), Plan(),
                          Workload(1, 64, 8))],
              evaluators={node: Evaluator(other)}).run()


def test_workload_presets():
    assert len(PAPER_SHAPES) == 6
    wls = paper_workloads(batch=16)
    assert all(w.batch == 16 for w in wls.values())
    assert [(w.in_len, w.out_len) for w in wls.values()] == list(PAPER_SHAPES)
    w = get_workload("serve-chat")
    assert (w.batch, w.in_len, w.out_len) == (8, 2048, 256)
    assert w.total_len == 2304 and w.tag == "b8_in2048_out256"
    assert w.with_batch(32).batch == 32
    with pytest.raises(KeyError):
        get_workload("nope")


# ---------------------------------------------------------------------------
# satellite: MoE expert-parallel memory sharding
# ---------------------------------------------------------------------------

def test_memory_per_device_shards_experts_by_ep():
    cfg = get_config("granite-moe-3b-a800m")
    assert cfg.n_experts > 1
    base = im.memory_per_device(cfg, Plan(tp=1, dp=4, ep=1), 4, 2048)
    ep4 = im.memory_per_device(cfg, Plan(tp=1, dp=4, ep=4), 4, 2048)
    expert_bytes = cfg.n_layers * cfg.n_experts * cfg.mlp_params() * 2
    # ep=4 drops exactly 3/4 of the expert FFN weight bytes
    assert _rel(base - ep4, expert_bytes * 3 / 4) < REL
    # dense models are unaffected by ep
    dense = get_config("qwen2-0.5b")
    assert im.memory_per_device(dense, Plan(ep=4), 4, 2048) == \
        im.memory_per_device(dense, Plan(ep=1), 4, 2048)


def test_moe_plan_fits_check_uses_sharded_experts():
    """A system sized so granite-moe only fits when experts are sharded:
    the planner must keep the ep>1 plan instead of wrongly rejecting it."""
    cfg = get_config("granite-moe-3b-a800m")
    plan = Plan(tp=1, dp=cfg.n_experts, ep=cfg.n_experts)
    unsharded = im.memory_per_device(cfg, Plan(tp=1, dp=cfg.n_experts), 4,
                                     2048)
    sharded = im.memory_per_device(cfg, plan, 4, 2048)
    assert sharded < unsharded
