"""Checkpoint/restart, elastic re-mesh, straggler monitor, failure
injection — the 1000+-node survivability story (DESIGN.md Sec. 6)."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config
from repro.checkpoint import Checkpointer
from repro.distributed import (RestartManifest, remesh, StepMonitor,
                               FailureInjector)
from repro.training import AdamW, constant_schedule, init_state, \
    make_train_step
from repro.data import DataConfig, TokenPipeline

KEY = jax.random.PRNGKey(0)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck = Checkpointer(str(tmp_path))
    ck.save(7, tree, extra={"data_step": 7})
    out, manifest = ck.restore(tree)
    assert manifest["step"] == 7
    assert np.array_equal(out["a"], tree["a"])
    assert out["b"]["c"].dtype == np.dtype("bfloat16") or \
        out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, async_=True)
    ck.wait()
    assert ck.latest_step() == 4
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) <= 2


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones(2)})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_restart_manifest_roundtrip(tmp_path):
    m = RestartManifest(step=42, data_step=42, mesh_shape={"data": 4},
                        rng_seed=7)
    p = str(tmp_path / "manifest.json")
    m.save(p)
    m2 = RestartManifest.load(p)
    assert m2.step == 42 and m2.mesh_shape == {"data": 4}


def test_remesh_single_device():
    mesh = remesh(model_parallel=1, pods=1)
    assert mesh.devices.size >= 1
    assert set(mesh.axis_names) == {"pod", "data", "model"}


def test_step_monitor_flags_straggler():
    import time
    hits = []
    mon = StepMonitor(window=20, threshold_sigma=3.0,
                      on_straggler=lambda s, dt: hits.append(s))
    for i in range(15):
        mon.start()
        mon.stop(i)
    mon.times = [0.01] * 15          # deterministic history
    mon.start()
    time.sleep(0.2)                  # inject a straggler step
    mon.stop(99)
    assert 99 in mon.straggler_steps and hits == [99]


def test_failure_injection_and_recovery(tmp_path):
    """Full loop: train, fail at step 3, restart from checkpoint + manifest,
    continue — final state must equal an uninterrupted run."""
    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    opt = AdamW(lr=constant_schedule(1e-3))
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, 16, 4, seed=5))
    step_fn = jax.jit(make_train_step(cfg, opt))
    # the production run's checkpoint dir — the reference run must NOT
    # share it, or restore() would pick up the reference's later steps
    ck = Checkpointer(str(tmp_path / "prod"))
    ref_ck = Checkpointer(str(tmp_path / "ref"))
    man_path = str(tmp_path / "manifest.json")

    def run(n_steps, state, start, injector=None, ckpt=None):
        ckpt = ckpt or ck
        for s in range(start, n_steps):
            if injector:
                injector.check(s)
            b = pipe.batch_at(s)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            state, _ = step_fn(state, batch)
            ckpt.save(s, state, extra={"data_step": s})
            if ckpt is ck:
                RestartManifest(step=s, data_step=s, mesh_shape={},
                                rng_seed=0).save(man_path)
        return state

    # uninterrupted reference
    ref = run(5, init_state(cfg, opt, KEY), 0, ckpt=ref_ck)

    # interrupted run
    inj = FailureInjector(fail_at_step=3)
    state = init_state(cfg, opt, KEY)
    with pytest.raises(RuntimeError, match="injected node failure"):
        state = run(5, state, 0, injector=inj)
    # recover: load manifest + checkpoint, resume from the next step
    man = RestartManifest.load(man_path)
    template = init_state(cfg, opt, KEY)
    state, _ = ck.restore(template)
    state = run(5, state, man.step + 1)

    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
