"""Per-arch smoke tests: reduced same-family configs, one forward / train
step on CPU, shapes + finiteness + serving equivalence (assignment
deliverable f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config
from repro import models
from repro.models.lm import padded_vocab

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    fe = None
    if models.needs_frontend(cfg):
        fe = jax.random.normal(KEY, (B, 8, cfg.d_model), jnp.bfloat16)
    return toks, fe


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(ARCHS[arch])
    params = models.init_params(cfg, KEY)
    toks, fe = _inputs(cfg)
    logits, aux = jax.jit(
        lambda p, t, f: models.forward(cfg, p, t, frontend=f))(params, toks, fe)
    assert logits.shape == (2, 16, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_decreases_loss_direction(arch):
    """One SGD-ish step on the same batch must not blow up and the grads
    must be finite and non-zero."""
    cfg = smoke_config(ARCHS[arch])
    params = models.init_params(cfg, KEY)
    toks, fe = _inputs(cfg)
    tg = jnp.roll(toks, -1, 1)
    (lv, met), g = jax.jit(jax.value_and_grad(
        lambda p: models.loss_fn(cfg, p, toks, tg, frontend=fe),
        has_aux=True))(params, )
    assert np.isfinite(float(lv))
    gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_config(ARCHS[arch])
    params = models.init_params(cfg, KEY)
    toks, fe = _inputs(cfg, S=16)
    logits, _ = jax.jit(
        lambda p, t, f: models.forward(cfg, p, t, frontend=f))(params, toks, fe)
    cache = models.init_cache(cfg, 2, 32)
    lg1, cache = jax.jit(
        lambda p, t, c, f: models.prefill(cfg, p, t, c, frontend=f))(
        params, toks[:, :-1], cache, fe)

    def check(ref, got, tol):
        if cfg.n_experts:
            # MoE routing is a discrete boundary: the serving path's
            # different accumulation order can flip near-tied top-k picks
            # at random init, so compare decisions, not elementwise logits
            agree = (ref.argmax(-1) == got.argmax(-1)).mean()
            assert agree >= 0.99, f"argmax agreement {agree}"
        else:
            err = np.max(np.abs(ref - got)) / (np.max(np.abs(ref)) + 1e-9)
            assert err < tol, err

    check(np.asarray(logits[:, -2, :cfg.vocab_size], np.float32),
          np.asarray(lg1[:, :cfg.vocab_size], np.float32), 0.05)
    lg2, cache = jax.jit(
        lambda p, t, c: models.decode_step(cfg, p, t, c))(
        params, toks[:, -1], cache)
    check(np.asarray(logits[:, -1, :cfg.vocab_size], np.float32),
          np.asarray(lg2[:, :cfg.vocab_size], np.float32), 0.07)


def test_unit_structure_covers_all_layers():
    for arch, cfg0 in ARCHS.items():
        cfg = ARCHS[arch]
        unit, n_units, rem = models.unit_structure(cfg)
        assert len(unit) * n_units + len(rem) == cfg.n_layers, arch


def test_recurrentgemma_pattern():
    cfg = ARCHS["recurrentgemma-2b"]
    kinds = models.layer_kinds(cfg)
    assert kinds[:3] == ["rglru", "rglru", "attn"]
    unit, n_units, rem = models.unit_structure(cfg)
    assert unit == ("rglru", "rglru", "attn") and n_units == 8
    assert rem == ("rglru", "rglru")


def test_vision_pattern():
    cfg = ARCHS["llama-3.2-vision-11b"]
    kinds = models.layer_kinds(cfg)
    assert kinds[3] == "xattn" and kinds[8] == "xattn"
    unit, n_units, rem = models.unit_structure(cfg)
    assert n_units * len(unit) == 40 and not rem


def test_param_counts_match_simulator():
    """Simulator (configs.base) parameter accounting must match the
    instantiated JAX trees (abstract, no allocation) within 2%."""
    for arch, cfg in ARCHS.items():
        abs_p = models.abstract_params(cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_p))
        predicted = cfg.param_count()
        # account for vocab padding in the actual tree
        pad = padded_vocab(cfg) - cfg.vocab_size
        actual -= pad * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        err = abs(actual - predicted) / predicted
        assert err < 0.02, f"{arch}: sim {predicted} vs jax {actual}"


def test_flash_attention_static_vs_streaming():
    """Both drivers of the chunked attention agree."""
    from repro.models.layers import flash_attention, attention_reference
    q = jax.random.normal(KEY, (2, 70, 4, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 70, 2, 32), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 70, 2, 32), jnp.float32)
    ref = attention_reference(q, k, v, causal=True)
    for static in (True, False):
        out = flash_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32,
                              static=static)
        err = np.max(np.abs(np.asarray(out - ref, np.float32)))
        assert err < 1e-4, f"static={static}"


def test_flash_attention_window():
    from repro.models.layers import flash_attention, attention_reference
    q = jax.random.normal(KEY, (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 16), jnp.float32)
    ref = attention_reference(q, k, v, causal=True, window=16)
    out = flash_attention(q, k, v, causal=True, window=16, chunk_q=16,
                          chunk_k=16, static=True)
    assert np.max(np.abs(np.asarray(out - ref, np.float32))) < 1e-4
