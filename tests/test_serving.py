"""Serving engine: batched generation, continuous batching slot refill,
sampler behavior."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config
from repro import models
from repro.serving import (Engine, Request, SamplingParams, sample,
                           sample_per_request)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    params = models.init_params(cfg, KEY)
    return cfg, params


def test_engine_offline_batch(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params, batch_size=4, max_len=64)
    reqs = [Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=5)
            for i in range(4)]
    done = eng.run(reqs)
    assert len(done) == 4
    for r in done:
        assert r.done and len(r.output) == 5
        assert all(0 <= t < models.lm.padded_vocab(cfg) for t in r.output)
    assert eng.stats["tokens_out"] >= 16


def test_engine_continuous_batching_refill(dense_setup):
    """More requests than slots: finished slots must be refilled."""
    cfg, params = dense_setup
    eng = Engine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(uid=i, prompt=[i + 1, 5], max_new_tokens=3)
            for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5 and all(r.done for r in done)


def test_engine_greedy_matches_step_by_step(dense_setup):
    """Engine generation for one request == manual prefill+decode loop."""
    cfg, params = dense_setup
    prompt = [3, 1, 4, 1, 5]
    eng = Engine(cfg, params, batch_size=1, max_len=64)
    [req] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])

    cache = models.init_cache(cfg, 1, 64)
    lg, cache = models.prefill(cfg, params, jnp.asarray([prompt]), cache)
    toks = [int(jnp.argmax(lg[0]))]
    for _ in range(3):
        lg, cache = models.decode_step(cfg, params,
                                       jnp.asarray([toks[-1]]), cache)
        toks.append(int(jnp.argmax(lg[0])))
    assert req.output == toks


def test_engine_eos_stops(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params, batch_size=1, max_len=64)
    # every token is "eos": generation must stop after the first one
    cache = models.init_cache(cfg, 1, 64)
    lg, _ = models.prefill(cfg, params, jnp.asarray([[1, 2]]), cache)
    eos = int(jnp.argmax(lg[0]))
    [req] = eng.run([Request(uid=0, prompt=[1, 2], max_new_tokens=10,
                             eos_id=eos)])
    assert req.done and len(req.output) == 1


def test_engine_recurrent_arch():
    cfg = smoke_config(ARCHS["recurrentgemma-2b"])
    params = models.init_params(cfg, KEY)
    eng = Engine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(uid=i, prompt=[1, 2, 3], max_new_tokens=4)
            for i in range(2)]
    done = eng.run(reqs)
    assert all(r.done and len(r.output) == 4 for r in done)


# -------- per-request sampling regressions (ISSUE 3 bugfixes) ----------

def _greedy_solo(cfg, params, prompt, n):
    eng = Engine(cfg, params, batch_size=1, max_len=64)
    [r] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=n)])
    return r.output


@pytest.mark.parametrize("greedy_slot", [0, 1])
def test_engine_mixed_sampling_keeps_greedy_deterministic(dense_setup,
                                                          greedy_slot):
    """A greedy request must produce its solo-run output even when batched
    next to a temperature>0 request, in either slot order (the seed engine
    applied the FIRST live slot's SamplingParams to every slot)."""
    cfg, params = dense_setup
    prompt = [3, 1, 4]
    solo = _greedy_solo(cfg, params, prompt, 6)
    hot = SamplingParams(temperature=1.5, top_k=8)
    reqs = [Request(uid=0, prompt=[9, 8, 7], max_new_tokens=6, sampling=hot),
            Request(uid=1, prompt=prompt, max_new_tokens=6)]
    if greedy_slot == 0:
        reqs.reverse()
    eng = Engine(cfg, params, batch_size=2, max_len=64)
    done = eng.run(reqs)
    greedy = next(r for r in done if r.sampling.temperature == 0.0)
    assert greedy.output == solo


def test_engine_first_token_respects_sampling(dense_setup):
    """admit_wave must route prefill logits through the sampler: with
    temperature > 0 the first token is a seeded draw (reproducible per
    seed, not a hardwired argmax), while greedy stays argmax."""
    cfg, params = dense_setup
    prompt = [2, 7, 1]
    hot = SamplingParams(temperature=5.0)

    def first_token(seed):
        eng = Engine(cfg, params, batch_size=1, max_len=64, seed=seed)
        [r] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=1,
                               sampling=hot)])
        return r.output[0]

    assert first_token(0) == first_token(0)     # reproducible
    greedy_first = _greedy_solo(cfg, params, prompt, 1)[0]
    # at temperature 5 over the whole vocab, some seed must deviate from
    # the argmax the seed engine hardwired
    assert any(first_token(s) != greedy_first for s in range(5))


def test_engine_refill_wave_uses_own_sampling(dense_setup):
    """Per-slot insertion path (engine busy) also samples per-request."""
    cfg, params = dense_setup
    solo = _greedy_solo(cfg, params, [5, 5, 5], 3)
    eng = Engine(cfg, params, batch_size=2, max_len=64)
    hot = SamplingParams(temperature=2.0, top_k=4)
    reqs = [Request(uid=0, prompt=[1, 2], max_new_tokens=8, sampling=hot),
            Request(uid=1, prompt=[3, 4], max_new_tokens=2, sampling=hot),
            Request(uid=2, prompt=[5, 5, 5], max_new_tokens=3)]  # refilled
    done = eng.run(reqs)
    assert all(r.done for r in done)
    assert done[2].output == solo


def test_sample_per_request_groups():
    logits = jnp.array([[0.0, 5.0, 1.0],
                        [10.0, 9.0, -50.0],
                        [0.0, 5.0, 1.0]])
    params = [SamplingParams(),
              SamplingParams(temperature=1.0, top_k=2),
              SamplingParams()]
    toks = sample_per_request(logits, KEY, params)
    assert int(toks[0]) == 1 and int(toks[2]) == 1   # greedy rows: argmax
    assert int(toks[1]) in (0, 1)                     # top-2 restricted
    with pytest.raises(ValueError):
        sample_per_request(logits, KEY, params[:2])


# ---------------- sampler ----------------

def test_sampler_greedy():
    logits = jnp.array([[0.0, 5.0, 1.0]])
    assert int(sample(logits, KEY, SamplingParams())[0]) == 1


def test_sampler_topk_restricts():
    logits = jnp.array([[10.0, 9.0, -50.0, -50.0]])
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed),
                       SamplingParams(temperature=1.0, top_k=2))[0])
        assert t in (0, 1)


def test_sampler_topp_restricts():
    logits = jnp.array([[10.0, 1.0, 0.5, 0.1]])
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed),
                       SamplingParams(temperature=1.0, top_p=0.5))[0])
        assert t == 0
