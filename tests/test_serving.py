"""Serving engine: batched generation, continuous batching slot refill,
sampler behavior."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config
from repro import models
from repro.serving import Engine, Request, SamplingParams, sample

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    params = models.init_params(cfg, KEY)
    return cfg, params


def test_engine_offline_batch(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params, batch_size=4, max_len=64)
    reqs = [Request(uid=i, prompt=[1 + i, 2, 3], max_new_tokens=5)
            for i in range(4)]
    done = eng.run(reqs)
    assert len(done) == 4
    for r in done:
        assert r.done and len(r.output) == 5
        assert all(0 <= t < models.lm.padded_vocab(cfg) for t in r.output)
    assert eng.stats["tokens_out"] >= 16


def test_engine_continuous_batching_refill(dense_setup):
    """More requests than slots: finished slots must be refilled."""
    cfg, params = dense_setup
    eng = Engine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(uid=i, prompt=[i + 1, 5], max_new_tokens=3)
            for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5 and all(r.done for r in done)


def test_engine_greedy_matches_step_by_step(dense_setup):
    """Engine generation for one request == manual prefill+decode loop."""
    cfg, params = dense_setup
    prompt = [3, 1, 4, 1, 5]
    eng = Engine(cfg, params, batch_size=1, max_len=64)
    [req] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])

    cache = models.init_cache(cfg, 1, 64)
    lg, cache = models.prefill(cfg, params, jnp.asarray([prompt]), cache)
    toks = [int(jnp.argmax(lg[0]))]
    for _ in range(3):
        lg, cache = models.decode_step(cfg, params,
                                       jnp.asarray([toks[-1]]), cache)
        toks.append(int(jnp.argmax(lg[0])))
    assert req.output == toks


def test_engine_eos_stops(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params, batch_size=1, max_len=64)
    # every token is "eos": generation must stop after the first one
    cache = models.init_cache(cfg, 1, 64)
    lg, _ = models.prefill(cfg, params, jnp.asarray([[1, 2]]), cache)
    eos = int(jnp.argmax(lg[0]))
    [req] = eng.run([Request(uid=0, prompt=[1, 2], max_new_tokens=10,
                             eos_id=eos)])
    assert req.done and len(req.output) == 1


def test_engine_recurrent_arch():
    cfg = smoke_config(ARCHS["recurrentgemma-2b"])
    params = models.init_params(cfg, KEY)
    eng = Engine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(uid=i, prompt=[1, 2, 3], max_new_tokens=4)
            for i in range(2)]
    done = eng.run(reqs)
    assert all(r.done and len(r.output) == 4 for r in done)


# ---------------- sampler ----------------

def test_sampler_greedy():
    logits = jnp.array([[0.0, 5.0, 1.0]])
    assert int(sample(logits, KEY, SamplingParams())[0]) == 1


def test_sampler_topk_restricts():
    logits = jnp.array([[10.0, 9.0, -50.0, -50.0]])
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed),
                       SamplingParams(temperature=1.0, top_k=2))[0])
        assert t in (0, 1)


def test_sampler_topp_restricts():
    logits = jnp.array([[10.0, 1.0, 0.5, 0.1]])
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed),
                       SamplingParams(temperature=1.0, top_p=0.5))[0])
        assert t == 0
