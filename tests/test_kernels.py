"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis
randomized shapes (interpret mode on CPU). Property tests skip without
hypothesis; the fixed sweeps always run (_hypothesis_compat shim)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro import kernels as K


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------- matmul ----------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (100, 200, 50), (1, 300, 77),
                                   (513, 129, 257)])
def test_matmul_sweep(m, k, n, dtype):
    key = jax.random.PRNGKey(m * 1000 + k + n)
    a = jax.random.normal(key, (m, k), dtype)
    b = jax.random.normal(key, (k, n), dtype)
    out = K.matmul.matmul(a, b, bm=128, bk=128, bn=128)
    assert out.shape == (m, n) and out.dtype == dtype
    assert rel_err(out, K.matmul.reference(a, b)) < tol(dtype)


@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300))
@settings(max_examples=10, deadline=None)
def test_matmul_property(m, k, n):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(key, (k, n), jnp.float32)
    out = K.matmul.matmul(a, b, bm=64, bk=64, bn=64)
    assert rel_err(out, K.matmul.reference(a, b)) < 2e-5


# ---------------- quantized matmul (ISSUE 4) ----------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (100, 200, 50), (1, 300, 77),
                                   (513, 129, 257)])
def test_matmul_int8_kernel_vs_ref(m, k, n):
    """Integer-MAC kernel == quantize-dequantize oracle (same quantized
    products; only fp32 association order differs)."""
    key = jax.random.PRNGKey(m * 1000 + k + n)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(3), (k, n), jnp.float32)
    out = K.matmul.matmul_int8(a, b, bm=128, bk=128, bn=128)
    assert out.shape == (m, n) and out.dtype == jnp.float32
    assert rel_err(out, K.matmul.reference_int8(a, b)) < 1e-4


def test_matmul_int8_approximates_exact():
    """Per-row/per-column symmetric int8 keeps the GEMM within ~1-2% of the
    exact fp32 result on normal data — the accuracy the analytical model's
    int8 pricing implicitly assumes."""
    key = jax.random.PRNGKey(42)
    a = jax.random.normal(key, (192, 384), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(43), (384, 160), jnp.float32)
    out = K.matmul.matmul_int8(a, b, bm=64, bk=128, bn=64)
    assert rel_err(out, K.matmul.reference(a, b)) < 5e-2


def test_matmul_int8_scale_invariance():
    """Symmetric per-vector scales make the quantized GEMM invariant to
    per-row input scaling up to quantization error."""
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (64, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(8), (256, 96), jnp.float32)
    rows = jnp.linspace(0.01, 100.0, 64)[:, None]
    out = K.matmul.matmul_int8(a * rows, b, bm=64, bk=64, bn=64)
    ref = K.matmul.matmul_int8(a, b, bm=64, bk=64, bn=64) * rows
    assert rel_err(out, ref) < 5e-2


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (100, 200, 50),
                                   (513, 129, 257)])
def test_matmul_fp8_kernel_vs_ref(m, k, n):
    key = jax.random.PRNGKey(m + k + n)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(9), (k, n), jnp.float32)
    out = K.matmul.matmul_fp8(a, b, bm=128, bk=128, bn=128)
    assert rel_err(out, K.matmul.reference_fp8(a, b)) < 2e-5
    # e4m3 has a 3-bit mantissa: within ~5% of exact on normal data
    assert rel_err(out, K.matmul.reference(a, b)) < 8e-2


# ---------------- flash attention ----------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv,sq,sk,causal,window,cap", [
    (4, 4, 128, 128, True, 0, 0.0),      # MHA causal
    (8, 2, 130, 130, True, 0, 0.0),      # GQA, non-divisible seq
    (4, 1, 64, 200, False, 0, 0.0),      # MQA cross-attn
    (4, 2, 128, 128, True, 32, 0.0),     # local window
    (4, 2, 96, 96, True, 0, 30.0),       # logit softcap (grok)
])
def test_flash_attention_sweep(hq, hkv, sq, sk, causal, window, cap, dtype):
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (2, hq, sq, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, sk, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (2, hkv, sk, 64), dtype)
    out = K.flash_attention.flash_attention(
        q, k, v, causal=causal, window=window, softcap=cap, bq=64, bk=64)
    ref = K.flash_attention.reference(q, k, v, causal=causal, window=window,
                                      softcap=cap)
    assert rel_err(out, ref) < tol(dtype)


# ---------------- decode attention ----------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hkv,g,t", [(2, 4, 128), (1, 8, 200), (4, 1, 64)])
def test_decode_attention_sweep(hkv, g, t, dtype):
    key = jax.random.PRNGKey(5)
    B = 3
    q = jax.random.normal(key, (B, hkv, g, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(6), (B, t, hkv, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(7), (B, t, hkv, 64), dtype)
    lens = jnp.array([t, max(1, t // 2), max(1, t // 3)], jnp.int32)
    out = K.decode_attention.decode_attention(q, k, v, lens, bk=64)
    ref = K.decode_attention.reference(q, k, v, lens)
    assert rel_err(out, ref) < tol(dtype)


# ---------------- norms + activations ----------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,c", [(64, 256), (100, 512), (7, 1024)])
def test_rmsnorm_sweep(r, c, dtype):
    key = jax.random.PRNGKey(8)
    x = jax.random.normal(key, (r, c), dtype)
    g = jax.random.normal(jax.random.PRNGKey(9), (c,), jnp.float32)
    assert rel_err(K.rmsnorm.rmsnorm(x, g, br=32),
                   K.rmsnorm.reference(x, g)) < tol(dtype)


def test_layernorm_kernel():
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (90, 384), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(11), (384,))
    b = jax.random.normal(jax.random.PRNGKey(12), (384,))
    assert rel_err(K.rmsnorm.layernorm(x, g, b, br=32),
                   K.rmsnorm.reference_layernorm(x, g, b)) < 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gelu_silu_kernels(dtype):
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (100, 256), dtype)
    u = jax.random.normal(jax.random.PRNGKey(14), (100, 256), dtype)
    assert rel_err(K.gelu.gelu(x, br=32), K.gelu.reference(x)) < tol(dtype)
    assert rel_err(K.gelu.silu_mul(x, u, br=32),
                   K.gelu.reference_silu_mul(x, u)) < tol(dtype)


# ---------------- wkv ----------------

@pytest.mark.parametrize("t,chunk", [(96, 32), (64, 64), (100, 32)])
def test_wkv_kernel(t, chunk):
    key = jax.random.PRNGKey(15)
    BH, N = 4, 32
    r = jax.random.normal(key, (BH, t, N), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(16), (BH, t, N), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(17), (BH, t, N), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(18),
                                         (BH, t, N))) * 0.5 + 0.45
    u = jax.random.normal(jax.random.PRNGKey(19), (N,), jnp.float32)
    out, state = K.wkv.wkv(r, k, v, w, u, chunk=chunk)
    ref_out, ref_state = K.wkv.reference(r, k, v, w, u)
    assert rel_err(out, ref_out) < 1e-4
    assert rel_err(state, ref_state) < 1e-4


def test_wkv_matches_model_scan():
    """Kernel agrees with the model-zoo chunked scan (models/recurrent)."""
    from repro.models.recurrent import wkv_scan
    key = jax.random.PRNGKey(20)
    B, T, H, N = 2, 64, 2, 16
    shp = (B, T, H, N)
    r = jax.random.normal(key, shp, jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(21), shp, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(22), shp, jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(23), shp)) * 0.5 + 0.45
    u = jax.random.normal(jax.random.PRNGKey(24), (H, N), jnp.float32)
    st0 = jnp.zeros((B, H, N, N), jnp.float32)
    out_model, state_model = wkv_scan(r, k, v, w, u, st0, chunk=16)
    # kernel layout (BH, T, N)
    tr = lambda a: jnp.moveaxis(a, 1, 2).reshape(B * H, T, N)
    out_k, state_k = K.wkv.wkv(tr(r), tr(k), tr(v), tr(w),
                               u.reshape(-1)[:N] * 0 + u[0], chunk=16)
    # compare only head 0 (kernel u is per-head-slice here)
    got = out_k.reshape(B, H, T, N)[:, 0]
    want = jnp.moveaxis(out_model, 1, 2)[:, 0]
    assert rel_err(got, want) < 1e-4
