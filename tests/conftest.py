"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests spawn subprocesses (test_multidevice.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
