"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests spawn subprocesses (test_multidevice.py)."""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Point the persistent result cache (core/result_cache.py) at a per-session
# temp dir BEFORE repro imports: tests must start cold and never read or
# pollute the developer's ~/.cache across runs. Within one session the layer
# stays live — cross-test disk hits return values bit-identical to what the
# same code would compute, and test_result_cache.py exercises it explicitly.
os.environ.setdefault(
    "REPRO_CACHE_DIR", tempfile.mkdtemp(prefix="repro-test-cache-"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
