"""Observability subsystem (ISSUE 9): metrics registry, phase spans,
compatibility shims, attribution records, and their Study/CaseResult
wiring."""
import warnings

import pytest

from repro.configs import get_config
from repro.core import fusion as fu
from repro.core import hardware as hw
from repro.core import obs
from repro.core import result_cache
from repro.core import verify as verify_mod
from repro.core.evaluator import EvalStats, Evaluator
from repro.core.fusion import fuse
from repro.core.graph import Plan, build_model
from repro.core.mapper import (MapperCacheStats, matmul_cache_stats,
                               reset_matmul_cache_stats)
from repro.core.study import Case, Study
from repro.core.workload import Workload


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = obs.MetricsRegistry()
    assert reg.counter("x") == 0.0
    reg.inc("x")
    reg.inc("x", 2.5)
    assert reg.counter("x") == 3.5
    reg.set_gauge("g", 7.0)
    assert reg.gauge("g") == 7.0
    reg.observe("h", 1.0)
    reg.observe("h", 3.0)
    h = reg.histogram("h")
    assert (h.count, h.total, h.min, h.max, h.mean) == (2, 4.0, 1.0, 3.0, 2.0)
    snap = reg.snapshot()
    assert snap["x"] == 3.5 and snap["gauge.g"] == 7.0
    assert "x=3.5" in reg.summary()


def test_registry_counters_prefix_filter():
    reg = obs.MetricsRegistry()
    reg.inc("a.one")
    reg.inc("a.two")
    reg.inc("b.one")
    assert set(reg.counters("a.")) == {"a.one", "a.two"}


def test_phase_spans_gated_by_enabled():
    reg = obs.MetricsRegistry()
    # off (default): shared no-op context manager, nothing recorded
    cm1 = reg.phase("p")
    cm2 = reg.phase("q")
    assert cm1 is cm2          # the shared null span — zero allocation
    with cm1:
        pass
    assert reg.phase_seconds() == {}
    # on: wall-clock recorded per name, with entry counts
    assert reg.set_enabled(True) is False
    with reg.phase("p"):
        pass
    with reg.phase("p"):
        pass
    assert reg.phase_counts() == {"p": 2}
    assert reg.phase_seconds()["p"] >= 0.0
    snap = reg.snapshot()
    assert snap["phase.p.count"] == 2
    assert reg.set_enabled(False) is True


def test_global_registry_is_shared():
    assert obs.metrics() is obs.metrics()


# ---------------------------------------------------------------------------
# compatibility shims over the registry
# ---------------------------------------------------------------------------

def test_mapper_stats_shim_windows_the_registry():
    reg = obs.metrics()
    st = MapperCacheStats()          # fresh window: all zeros
    assert (st.memo_hits, st.disk_hits, st.misses, st.evictions) \
        == (0, 0, 0, 0)
    reg.inc("mapper.memo_hits")
    reg.inc("mapper.misses", 3)
    assert st.memo_hits == 1 and st.misses == 3
    assert "memo_hits=1" in st.summary() and "misses=3" in st.summary()
    # a new window re-baselines without touching the monotone registry
    before = reg.counter("mapper.misses")
    st2 = MapperCacheStats()
    assert st2.misses == 0
    assert reg.counter("mapper.misses") == before


def test_reset_matmul_cache_stats_rebaselines():
    obs.metrics().inc("mapper.disk_hits", 5)
    reset_matmul_cache_stats()
    assert matmul_cache_stats().disk_hits == 0
    obs.metrics().inc("mapper.disk_hits")
    assert matmul_cache_stats().disk_hits == 1
    reset_matmul_cache_stats()
    assert matmul_cache_stats().disk_hits == 0


def test_disk_cache_mirrors_into_registry(tmp_path):
    reg = obs.metrics()
    dc = result_cache.DiskCache("obs-test", root=tmp_path, enabled=True)
    m0 = reg.counter("cache.obs-test.misses")
    p0 = reg.counter("cache.obs-test.puts")
    h0 = reg.counter("cache.obs-test.hits")
    assert dc.get("0" * 64) is None
    dc.put("0" * 64, {"v": 1})
    assert dc.get("0" * 64) == {"v": 1}
    assert reg.counter("cache.obs-test.misses") == m0 + 1
    assert reg.counter("cache.obs-test.puts") == p0 + 1
    assert reg.counter("cache.obs-test.hits") == h0 + 1
    assert dc.stats.misses == 1 and dc.stats.puts == 1 and dc.stats.hits == 1


def test_verify_diagnostics_counted_even_when_off():
    reg = obs.metrics()
    d = verify_mod.Diagnostic("test.rule", "warn", "synthetic")
    w0 = reg.counter("verify.diagnostics.warn")
    verify_mod.apply_mode([d], "off")
    assert reg.counter("verify.diagnostics.warn") == w0 + 1
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        verify_mod.apply_mode([d, d], "warn")
    assert reg.counter("verify.diagnostics.warn") == w0 + 3


# ---------------------------------------------------------------------------
# layer-group classification
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,group", [
    ("qkv_proj", "attn"), ("qk_t+softmax", "attn"), ("a_mul_v", "attn"),
    ("o_proj", "attn"), ("ln_attn", "attn"), ("rope", "attn"),
    ("w1_proj+gelu", "mlp"), ("w2_proj", "mlp"), ("ln_mlp", "mlp"),
    ("router", "mlp"), ("expert_w1", "mlp"),
    ("allreduce_mlp", "comm"), ("moe_dispatch", "comm"), ("p2p", "comm"),
    ("grad_ag", "comm"), ("act_rs", "comm"),
    ("embed", "head"), ("lm_head", "head"), ("ln_final", "head"),
    ("prefill/qkv_proj", "attn"), ("decode/w2_proj", "mlp"),
    ("mystery_op", "other"),
])
def test_layer_group(name, group):
    assert obs.layer_group(name) == group


# ---------------------------------------------------------------------------
# attribution records
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gpt3_attr():
    cfg = get_config("gpt3-175b")
    system = hw.dgx_a100(4)
    ev = Evaluator(system, verify="off")
    g = fuse(build_model(cfg, Plan(tp=4), 2, 256, kv_len=256), fu.FULL)
    cost = ev.evaluate(g, overlap=True)
    return g, cost, obs.attribute(g, cost, label="prefill")


def test_attribute_rows_align_with_graph(gpt3_attr):
    g, cost, att = gpt3_attr
    assert len(att.rows) == len(g.nodes)
    assert att.total == cost.latency
    assert att.serial == cost.serial_latency
    assert att.total <= att.serial          # overlap can only hide work
    # per-row latency reconciles with the priced ops
    for row, op in zip(att.rows, cost.ops):
        assert row.latency == op.latency
        assert row.bound == op.bound
    # link exposure: hidden + exposed == total link occupancy
    link_total = sum(r.latency for r in att.rows if r.resource == "link")
    assert att.link_exposed + att.link_hidden == pytest.approx(link_total)


def test_attribute_serial_graph_prefix_sums(gpt3_attr):
    g, _, _ = gpt3_attr
    ev = Evaluator(hw.dgx_a100(4), verify="off")
    cost = ev.evaluate(g, overlap=False)
    att = obs.attribute(g, cost)
    assert att.total == att.serial
    t = 0.0
    for r in att.rows:
        assert r.start == t and r.critical and r.exposed == r.latency
        t = r.end
    assert t == pytest.approx(att.serial)


def test_attribution_outputs(gpt3_attr):
    _, _, att = gpt3_attr
    rows = att.to_rows()
    assert rows[0]["name"] and "latency_s" in rows[0]
    csv_text = att.to_csv()
    assert csv_text.splitlines()[0].startswith("name,group,resource")
    assert len(csv_text.splitlines()) == len(att.rows) + 1
    groups = att.by_group()
    assert {"attn", "mlp", "comm", "head"} <= set(groups)
    assert sum(g["latency"] for g in groups.values()) \
        == pytest.approx(sum(r.latency for r in att.rows))
    assert att.to_json().startswith("{")


def test_attribution_doc_round_trip(gpt3_attr):
    _, _, att = gpt3_attr
    doc = att.to_doc()
    back = obs.Attribution.from_doc(doc)
    assert back == att
    assert obs.Attribution.from_doc({"label": "x"}) is None
    assert obs.Attribution.from_doc({"label": "x", "total": 1.0,
                                     "serial": 1.0, "rows": [["bad"]]}) \
        is None


def test_combine_concatenates_sections(gpt3_attr):
    _, _, att = gpt3_attr
    both = obs.combine("generate", [att, att])
    assert both.label == "generate"
    assert len(both.rows) == 2 * len(att.rows)
    assert both.total == pytest.approx(2 * att.total)


# ---------------------------------------------------------------------------
# Study / CaseResult / EvalStats wiring
# ---------------------------------------------------------------------------

def test_evalstats_summary_includes_case_hits():
    st = EvalStats(case_hits=3, case_misses=1)
    assert "case_hits=3" in st.summary()
    assert "case_misses=1" in st.summary()


@pytest.fixture(scope="module")
def small_study_run(tmp_path_factory):
    cfg = get_config("qwen2-0.5b")
    system = hw.dgx_a100(2)
    case = Case(system, cfg, Plan(tp=2), Workload(2, 64, 8, samples=2),
                stage="layer", fusion=fu.FULL)
    root = tmp_path_factory.mktemp("case-cache")
    with result_cache.overridden(root=root, enabled=True):
        cold = Study(cases=[case], verify="off").run()
        warm = Study(cases=[case], verify="off").run()
    return case, cold, warm


def test_case_result_carries_attribution(small_study_run):
    case, cold, _ = small_study_run
    r = cold[0]
    assert r.attribution is not None
    assert r.attribution.label == "layer"
    # prefill + decode sections, prefixed
    names = [row.name for row in r.attribution.rows]
    assert any(n.startswith("prefill/") for n in names)
    assert any(n.startswith("decode/") for n in names)
    assert r.critical and r.critical[0][1] > 0.0
    # sorted largest-first
    vals = [v for _, v in r.critical]
    assert vals == sorted(vals, reverse=True)


def test_case_result_row_exposes_critical_breakdown(small_study_run):
    _, cold, _ = small_study_run
    row = cold[0].to_row()
    assert "critical_breakdown" in row
    assert "=" in row["critical_breakdown"]
    assert row["elided_bytes"] == cold[0].attribution.elided


def test_warm_rerun_serves_attribution_from_cache(small_study_run):
    case, cold, warm = small_study_run
    assert warm.stats.case_cache_hits == 1
    assert warm[0].attribution == cold[0].attribution
    assert warm[0].critical == cold[0].critical
    assert warm[0].latency == cold[0].latency
    ev = warm.evaluators[case.system]
    assert ev.stats.case_hits == 1
    assert "case_hits=1" in ev.stats.summary()


def test_serve_cases_have_no_attribution():
    import repro.core.simulator as sim_mod
    from repro.core.workload import Trace, TrafficWorkload
    cfg = get_config("qwen2-0.5b")
    system = hw.dgx_a100(2)
    traffic = TrafficWorkload.from_trace(Trace.constant(4, 0.0, 64, 4),
                                         slots=4)
    case = Case(system, cfg, Plan(tp=2), traffic, stage="serve")
    res = Study(cases=[case], verify="off", result_cache=False).run()
    assert res[0].attribution is None
    assert res[0].critical == ()
    assert isinstance(res[0].sim, sim_mod.SimResult)
