"""Purity lint (ISSUE 7, satellite): the persistent result cache is only
sound if everything on a cache-keyed path is deterministic — same inputs,
same bytes, across processes and sessions. This AST lint walks the
functions that either compute cache keys or produce the values stored
under them and forbids the classic nondeterminism sources:

  * wall clocks and entropy: `time`, `random`, `datetime`, `uuid`,
    `secrets`, `np.random`, `os.urandom`
  * environment reads: `os.environ`, `os.getenv` (configuration must flow
    through arguments, not ambient state)
  * process-local identity: `id()`, `hash()` (PYTHONHASHSEED-salted for
    str/bytes), `globals()`, `locals()`, `vars()`
  * dict-order-dependent iteration: bare `.items()` / `.keys()` /
    `.values()` in a `for` or comprehension, unless wrapped in `sorted()`.
    (Python dicts preserve insertion order, but insertion order is exactly
    what a refactor silently changes — canonical() sorts for a reason.)

The linted set is the cache-keyed core: mapper's batched-search stages
(their MatmulResults go to the persistent matmul cache), result_cache's
canonicalization/keying, and Study's CaseResult keying/serialization.
"""
import ast
import inspect
import pathlib
import textwrap

import pytest

from repro.core import mapper, obs, result_cache, trace_export
from repro.core.study import Study

#: functions on result_cache-keyed paths: keys must be stable AND the
#: values stored under them must be reproducible. The trace-export path
#: (ISSUE 9) is held to the same rules: virtual-timestamp traces must be
#: byte-identical across runs, so no wall clocks, entropy, env reads or
#: dict-order iteration anywhere between a Schedule/SimResult and its JSON.
LINTED = [
    mapper._gather_chunk,
    mapper._chunk_tables,
    mapper._chunk_tables_numpy,
    mapper._pick_winners,
    mapper._solve_chunk,
    mapper._pair_key,
    mapper._pair_sig,
    mapper._result_to_doc,
    mapper._row_lower_bounds,
    mapper._seed_rows,
    mapper._prune_pairs,
    result_cache.canonical,
    result_cache.content_key,
    Study._case_key,                # staticmethod resolves to the function
    Study._case_to_doc,
    trace_export._ts,
    trace_export.schedule_trace_events,
    trace_export.simulation_trace_events,
    trace_export.to_perfetto_json,
    trace_export.validate_trace_events,
    obs.attribute,
    obs.Attribution.to_doc,         # feeds Study._case_to_doc
]

_BANNED_NAMES = {"time", "random", "datetime", "uuid", "secrets"}
_BANNED_CALLS = {"id", "hash", "globals", "locals", "vars", "getenv",
                 "urandom"}
_DICT_ITERS = {"items", "keys", "values"}


def _lint(tree, label):
    """Purity violations in an AST (a parsed function or any wrapper)."""
    out = []

    class V(ast.NodeVisitor):
        def visit_Name(self, node):
            if node.id in _BANNED_NAMES:
                out.append(f"{label}:{node.lineno}: "
                           f"references {node.id!r}")
            self.generic_visit(node)

        def visit_Attribute(self, node):
            # os.environ, np.random, os.urandom, os.getenv
            base = node.value.id if isinstance(node.value, ast.Name) else ""
            if (base, node.attr) in {("os", "environ"), ("os", "getenv"),
                                     ("os", "urandom"), ("np", "random"),
                                     ("numpy", "random")}:
                out.append(f"{label}:{node.lineno}: "
                           f"reads {base}.{node.attr}")
            self.generic_visit(node)

        def visit_Call(self, node):
            f = node.func
            if isinstance(f, ast.Name) and f.id in _BANNED_CALLS:
                out.append(f"{label}:{node.lineno}: calls {f.id}()")
            self.generic_visit(node)

        # ---- dict-order-dependent iteration ------------------------------
        def _iter_is_impure(self, it):
            """True for a bare d.items()/keys()/values() iterator."""
            return (isinstance(it, ast.Call)
                    and isinstance(it.func, ast.Attribute)
                    and it.func.attr in _DICT_ITERS)

        def _check_iter(self, it, what):
            if self._iter_is_impure(it):
                out.append(f"{label}:{it.lineno}: {what} over bare "
                           f".{it.func.attr}() — wrap in sorted()")

        def visit_For(self, node):
            self._check_iter(node.iter, "for-loop")
            self.generic_visit(node)

        def visit_comprehension(self, node):
            self._check_iter(node.iter, "comprehension")
            for child in ast.iter_child_nodes(node):
                self.visit(child)

    V().visit(tree)
    return out


def _violations(fn):
    src = textwrap.dedent(inspect.getsource(fn))
    return _lint(ast.parse(src), fn.__name__)


@pytest.mark.parametrize("fn", LINTED, ids=lambda f: f.__qualname__)
def test_cache_keyed_paths_are_pure(fn):
    assert _violations(fn) == []


# ---------------------------------------------------------------------------
# the lint itself must catch what it claims to catch
# ---------------------------------------------------------------------------

def _planted_time():
    import time
    return time.time()


def _planted_env():
    import os
    return os.environ.get("HOME")


def _planted_hash(x):
    return hash(x)


def _planted_dict_iter(d):
    return [k for k, v in d.items()]


def _planted_sorted_ok(d):
    # sorted() pins the order — this is canonical()'s own idiom
    return [k for k, v in sorted(d.items())]


def test_lint_self_check():
    assert _violations(_planted_time)
    assert _violations(_planted_env)
    assert _violations(_planted_hash)
    assert _violations(_planted_dict_iter)
    assert _violations(_planted_sorted_ok) == []


# ---------------------------------------------------------------------------
# benchmarks/ and examples/ case builders (unitcheck PR satellite): whatever
# builds a Study case grid feeds the content-hashed cache keys, so the same
# purity rules apply. Discovered from source paths — entry scripts are
# linted without being imported (so examples never execute under pytest).
# ---------------------------------------------------------------------------

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _source_case_builders():
    found = []
    for sub in ("benchmarks", "examples"):
        for path in sorted((_ROOT / sub).glob("*.py")):
            tree = ast.parse(path.read_text())
            for node in tree.body:
                if isinstance(node, ast.FunctionDef) and (
                        node.name in ("cases", "build_cases")
                        or node.name.endswith("_cases")):
                    found.append((f"{sub}/{path.name}:{node.name}", node))
    return found


_BUILDERS = _source_case_builders()


def test_case_builder_discovery():
    names = [label for label, _ in _BUILDERS]
    assert any(n.endswith("study_speed.py:_cases") for n in names)
    assert any(n.endswith("mega_sweep.py:build_cases") for n in names)


@pytest.mark.parametrize("item", _BUILDERS, ids=lambda it: it[0])
def test_benchmark_case_builders_are_pure(item):
    label, node = item
    assert _lint(ast.Module(body=[node], type_ignores=[]), label) == []


def test_source_lint_catches_planted_violation(tmp_path):
    bad = tmp_path / "bad_bench.py"
    bad.write_text(textwrap.dedent("""
        def build_cases():
            import time
            seed = time.time()
            return [k for k, v in {"a": 1}.items()]
    """))
    tree = ast.parse(bad.read_text())
    node = next(n for n in tree.body if isinstance(n, ast.FunctionDef))
    v = _lint(ast.Module(body=[node], type_ignores=[]), "bad_bench")
    assert any("time" in x for x in v)
    assert any(".items()" in x for x in v)


def test_canonical_sorts_dicts():
    """Behavioral twin of the AST rule: two dicts with different insertion
    orders must canonicalize (and key) identically."""
    a = {"x": 1, "y": [2, 3], "z": {"k": 4}}
    b = {"z": {"k": 4}, "y": [2, 3], "x": 1}
    assert result_cache.canonical(a) == result_cache.canonical(b)
    assert result_cache.content_key(a) == result_cache.content_key(b)
