"""ISSUE 10: mapper candidate pruning — bit-for-bit winner preservation.

The prune path (lower-bound cutoff + cross-pair row dedupe) must be
invisible in the results: winners, latencies, flops, traffic and
`candidates_searched` identical to the exhaustive search, in every mode.
The "oracle" mode re-solves the full row set inside `flush()` and raises
on any divergence, so simply running a grid under it is itself the proof.
On top of that this file pins the `_tile_candidates` coverage invariants
the pruning soundness argument leans on (the full-dimension tile and the
hardware-native tile within the doubling budget), and the counter surface
(`mapper.rows_evaluated` / `rows_pruned` / `rows_deduped`).
"""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import hardware as hw
from repro.core import result_cache
from repro.core.mapper import (_tile_candidates, clear_matmul_cache,
                               get_mapper_prune, matmul_perf_batch_multi,
                               set_mapper_prune)
from repro.core.obs import metrics

DEVICES = [hw.nvidia_a100(), hw.google_tpu_v5e(), hw.amd_mi210(),
           hw.compute_design("C")]

# (m, k, n, batch, bytes_a, bytes_b, bytes_out, bytes_acc, b_shared,
#  mac_scale) — same coverage axes as tests/test_mapper_jax.py
SHAPES = [(1, 128, 128, 1, 2, 2, 2, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 2, 2, 2, False, 1.0),
          (4096, 12288, 3072, 1, 2, 2, 2, 2, False, 1.0),
          (2048, 128, 2048, 8, 2, 2, 2, 2, True, 1.0),
          (7, 64, 2048, 112, 2, 2, 2, 2, False, 1.0),
          (333, 777, 129, 3, 2, 2, 4, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 1, 2, 4, False, 1.0),
          (512, 4096, 4096, 1, 1, 1, 1, 4, False, 2.0),
          (64, 8192, 8192, 1, 2, 0.5, 2, 4, False, 1.0)]

PAIRS = [(d, s) for d in DEVICES for s in SHAPES]


@pytest.fixture(autouse=True)
def _cold_and_restored():
    """Cold memo, no persistent layer, prune mode restored afterwards."""
    prev = get_mapper_prune()
    clear_matmul_cache()
    with result_cache.disabled():
        yield
    set_mapper_prune(prev)
    clear_matmul_cache()


def _solve(mode, pairs):
    set_mapper_prune(mode)
    clear_matmul_cache()        # memo keys carry no prune mode: clear between
    return matmul_perf_batch_multi(pairs)


def test_prune_modes_bitwise_identical():
    """off / on / oracle agree exactly — winner, latency bits and all."""
    off = _solve("off", PAIRS)
    on = _solve("on", PAIRS)
    oracle = _solve("oracle", PAIRS)    # raises internally on any mismatch
    for (dev, shape), a, b, c in zip(PAIRS, off, on, oracle):
        what = f"{dev.name} {shape}"
        for r in (b, c):
            assert r.mapping == a.mapping, what
            assert r.latency == a.latency, what           # bit-for-bit
            assert r.flops == a.flops, what
            assert r.main_memory_bytes == a.main_memory_bytes, what
            assert r.candidates_searched == a.candidates_searched, what


def test_prune_reduces_rows_evaluated():
    """The cutoff must actually cut: strictly fewer rows priced, and the
    pruned-row counter accounts exactly for the difference."""
    reg = metrics()

    def rows_evaluated(mode):
        base = reg.snapshot()
        _solve(mode, PAIRS)
        snap = reg.snapshot()
        return {k: snap.get(k, 0.0) - base.get(k, 0.0)
                for k in ("mapper.rows_feasible", "mapper.rows_evaluated",
                          "mapper.rows_pruned")}

    d_off = rows_evaluated("off")
    d_on = rows_evaluated("on")
    assert d_off["mapper.rows_feasible"] == d_on["mapper.rows_feasible"]
    assert d_off["mapper.rows_evaluated"] >= d_off["mapper.rows_feasible"]
    assert d_on["mapper.rows_evaluated"] < d_off["mapper.rows_evaluated"]
    assert d_on["mapper.rows_pruned"] > 0
    assert d_off["mapper.rows_pruned"] == 0


def test_prune_mode_api():
    prev = set_mapper_prune("off")
    assert get_mapper_prune() == "off"
    assert set_mapper_prune("oracle") == "off"
    assert set_mapper_prune(prev) == "oracle"
    with pytest.raises(ValueError):
        set_mapper_prune("fast")
    assert get_mapper_prune() == prev   # rejected mode leaves state alone


def test_pair_dedupe_reuses_identical_devices():
    """Two devices that differ only in name have identical candidate rows
    and tables — the dedupe must solve once and reuse, with identical
    winners and the reuse visible on the `mapper.rows_deduped` counter.
    Pairs are interleaved so each duplicate shares a chunk with its
    original (dedupe is per evaluation chunk, not global)."""
    reg = metrics()
    a100 = hw.nvidia_a100()
    clone = dataclasses.replace(a100, name="a100-clone")
    pairs = [(d, s) for s in SHAPES for d in (a100, clone)]
    set_mapper_prune("on")
    base = reg.counter("mapper.rows_deduped")
    res = matmul_perf_batch_multi(pairs)
    deduped = reg.counter("mapper.rows_deduped") - base
    assert deduped > 0
    for s, r_a, r_b in zip(SHAPES, res[0::2], res[1::2]):
        assert r_a.mapping == r_b.mapping, s
        assert r_a.latency == r_b.latency, s
    # dedupe must not change anything vs the exhaustive per-pair solve
    off = _solve("off", pairs)
    for r, o in zip(res, off):
        assert r.mapping == o.mapping
        assert r.latency == o.latency


# -- _tile_candidates coverage (satellite) ----------------------------------

@pytest.mark.parametrize("dim", [1, 7, 16, 128, 129, 2048, 12288, 50176])
@pytest.mark.parametrize("align", [8, 16, 64, 128])
def test_tile_candidates_cover_full_dim(dim, align):
    """The full-dimension tile (max reuse) is always a candidate."""
    cands = _tile_candidates(dim, min(align, dim))
    assert dim in cands.tolist()


@pytest.mark.parametrize("dim", [16, 128, 129, 2048, 12288])
@pytest.mark.parametrize("align", [8, 16, 64, 128])
def test_tile_candidates_cover_native_tile(dim, align):
    """Within the max_tiles doubling budget (every GEMM dimension the
    framework's model graphs generate below ~50k-token LM heads) the
    hardware-native alignment tile is a candidate."""
    align = min(align, dim)
    cands = _tile_candidates(dim, align)
    assert align in cands.tolist()


def test_tile_candidates_documented_truncation():
    """Beyond the doubling budget the LARGEST tiles are kept and the native
    tile drops out — pinned behaviour (frozen fp16 seed references); see
    the _tile_candidates docstring before "fixing" this."""
    cands = _tile_candidates(50176, 16)     # ratio 3136 > 2^11 budget
    assert len(cands) == 12
    assert 16 not in cands.tolist()
    assert 50176 in cands.tolist()
    assert np.all(np.diff(cands) > 0)


# -- randomized sweep: pruning never removes the winner ---------------------

@given(m=st.integers(1, 4096), k=st.integers(1, 12288),
       n=st.integers(1, 12288), batch=st.sampled_from([1, 4, 96]),
       b_shared=st.booleans(),
       dev=st.sampled_from(range(len(DEVICES))))
@settings(max_examples=25, deadline=None)
def test_prune_never_removes_winner_random_shapes(m, k, n, batch, b_shared,
                                                  dev):
    shape = (m, k, n, batch, 2, 2, 2, 2, b_shared, 1.0)
    with result_cache.disabled():
        prev = get_mapper_prune()
        try:
            set_mapper_prune("oracle")      # raises on any winner mismatch
            clear_matmul_cache()
            matmul_perf_batch_multi([(DEVICES[dev], shape)])
        finally:
            set_mapper_prune(prev)
            clear_matmul_cache()
