"""ISSUE 6: JAX chunk backend equivalence against numpy and the dense oracle.

The JAX backend pads feasible candidate rows into power-of-two buckets and
prices them with one jitted XLA kernel. Nothing in the table computation
reduces across rows, so the ONLY numeric freedom XLA has is FMA contraction
of `a*b + c`, worth at most one float64 ulp. The gate therefore is:

  * the winning Mapping must be IDENTICAL to numpy's on every pair;
  * latencies agree to 1e-12 relative (bit-equal in almost every case);
  * flops / traffic / candidate counts are integers and must be bit-equal;
  * numpy stays bit-for-bit with the dense oracle (matmul_perf_reference),
    anchoring both backends to the frozen seed semantics.

The sweep below is a fixed grid (devices x shapes incl. mixed per-operand
widths, sub-byte weights, batched/b_shared and mac_scale), so it runs in
full without hypothesis; the property test on top re-draws random shapes
when hypothesis is installed.
"""
import os
import subprocess
import sys

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import hardware as hw
from repro.core import result_cache
from repro.core.mapper import (clear_matmul_cache, get_mapper_backend,
                               matmul_perf_batch, matmul_perf_reference,
                               set_mapper_backend)

jax = pytest.importorskip("jax")

REL = 1e-12

DEVICES = [hw.nvidia_a100(), hw.google_tpu_v5e(), hw.amd_mi210(),
           hw.compute_design("C")]

# (m, k, n, batch, bytes_a, bytes_b, bytes_out, bytes_acc, b_shared,
#  mac_scale) — spans prefill/decode aspect ratios, batched + shared-B,
# mixed and sub-byte operand widths, and narrow-datatype MAC rates
SHAPES = [(1, 128, 128, 1, 2, 2, 2, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 2, 2, 2, False, 1.0),
          (4096, 12288, 3072, 1, 2, 2, 2, 2, False, 1.0),
          (2048, 128, 2048, 8, 2, 2, 2, 2, True, 1.0),
          (7, 64, 2048, 112, 2, 2, 2, 2, False, 1.0),
          (333, 777, 129, 3, 2, 2, 4, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 1, 2, 4, False, 1.0),   # int8 weights
          (512, 4096, 4096, 1, 1, 1, 1, 4, False, 2.0),    # w8a8
          (64, 8192, 8192, 1, 2, 0.5, 2, 4, False, 1.0)]   # int4 weights


@pytest.fixture(autouse=True)
def _numpy_backend_cold_cache():
    """Every test starts on the default backend with cold memos and no
    persistent layer, and restores the backend afterwards."""
    prev = get_mapper_backend()
    set_mapper_backend("numpy")
    clear_matmul_cache()
    with result_cache.disabled():
        yield
    set_mapper_backend(prev)
    clear_matmul_cache()


def _solve_with(backend, device, shapes):
    set_mapper_backend(backend)
    clear_matmul_cache()        # the memo key has no backend: clear between
    try:
        return matmul_perf_batch(device, shapes)
    finally:
        set_mapper_backend("numpy")


def _assert_equivalent(a, b, what):
    assert a.mapping == b.mapping, what          # the winner: exact
    assert a.flops == b.flops, what
    assert a.main_memory_bytes == b.main_memory_bytes, what
    assert a.candidates_searched == b.candidates_searched, what
    assert abs(a.latency - b.latency) <= REL * abs(b.latency), what


@pytest.mark.parametrize("device", DEVICES, ids=lambda d: d.name)
def test_jax_backend_matches_numpy(device):
    np_res = _solve_with("numpy", device, SHAPES)
    jx_res = _solve_with("jax", device, SHAPES)
    for s, a, b in zip(SHAPES, jx_res, np_res):
        _assert_equivalent(a, b, f"{device.name} {s}")


def test_numpy_backend_is_bitwise_the_dense_oracle():
    """Anchors the whole equivalence chain: the default backend IS the seed
    reference, so the JAX gate above transitively gates against it too."""
    dev = DEVICES[0]
    for s, r in zip(SHAPES, _solve_with("numpy", dev, SHAPES)):
        ref = matmul_perf_reference(dev, *s)
        assert r.mapping == ref.mapping
        assert r.latency == ref.latency          # bit-for-bit
        assert r.flops == ref.flops
        assert r.main_memory_bytes == ref.main_memory_bytes


def test_jax_single_vs_batched_chunking_identical():
    """Bucket padding must not leak filler rows into real segments: solving
    shapes one-by-one (small buckets) equals solving them stacked (large
    buckets spanning several pairs)."""
    dev = DEVICES[1]
    stacked = _solve_with("jax", dev, SHAPES)
    for s, r_stacked in zip(SHAPES, stacked):
        r_single = _solve_with("jax", dev, [s])[0]
        _assert_equivalent(r_single, r_stacked, s)


@given(m=st.integers(1, 4096), k=st.integers(1, 16384),
       n=st.integers(1, 4096), batch=st.sampled_from([1, 3, 8]),
       wa=st.sampled_from([0.5, 1, 2, 4]), wb=st.sampled_from([0.5, 1, 2]),
       b_shared=st.booleans(), mac=st.sampled_from([1.0, 2.0, 4.0]))
@settings(max_examples=40, deadline=None)
def test_jax_backend_matches_numpy_property(m, k, n, batch, wa, wb,
                                            b_shared, mac):
    shape = (m, k, n, batch, wa, wb, 2, 4, b_shared, mac)
    for dev in DEVICES[:2]:
        a = _solve_with("jax", dev, [shape])[0]
        b = _solve_with("numpy", dev, [shape])[0]
        _assert_equivalent(a, b, f"{dev.name} {shape}")


# ---------------------------------------------------------------------------
# backend selection API
# ---------------------------------------------------------------------------

def test_backend_switch_roundtrip():
    assert get_mapper_backend() == "numpy"
    prev = set_mapper_backend("jax")
    assert prev == "numpy"
    assert get_mapper_backend() == "jax"
    assert set_mapper_backend("numpy") == "jax"


def test_backend_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown mapper backend"):
        set_mapper_backend("cuda")
    assert get_mapper_backend() == "numpy"       # unchanged on error


def test_backend_env_var_selects_jax():
    env = dict(os.environ, REPRO_MAPPER_BACKEND="jax")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.core.mapper import get_mapper_backend;"
         "print(get_mapper_backend())"],
        env=env, capture_output=True, text=True, check=True)
    assert out.stdout.strip() == "jax"


def test_backend_env_var_unknown_falls_back_to_numpy():
    env = dict(os.environ, REPRO_MAPPER_BACKEND="fortran")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.core.mapper import get_mapper_backend;"
         "print(get_mapper_backend())"],
        env=env, capture_output=True, text=True, check=True)
    assert out.stdout.strip() == "numpy"


# ---------------------------------------------------------------------------
# padding buckets
# ---------------------------------------------------------------------------

def test_bucket_sizes_are_bounded_powers_of_two():
    from repro.core.mapper_jax import _MIN_BUCKET, _bucket
    assert _bucket(0) == _MIN_BUCKET
    assert _bucket(1) == _MIN_BUCKET
    assert _bucket(_MIN_BUCKET) == _MIN_BUCKET
    assert _bucket(_MIN_BUCKET + 1) == _MIN_BUCKET * 2
    for n in (5000, 70000, 130000):
        b = _bucket(n)
        assert b >= n and b & (b - 1) == 0
        assert b < 2 * max(n, _MIN_BUCKET)       # never over-pads 2x


def test_trace_reuse_across_chunk_sizes():
    """Different row counts inside one bucket reuse one jit trace — the
    whole point of padding (a trace per exact shape would recompile
    constantly)."""
    from repro.core import mapper_jax
    # warm one trace, then vary row counts within the same bucket
    _solve_with("jax", DEVICES[0], [SHAPES[0]])
    sizes = mapper_jax._tables_kernel._cache_size()
    _solve_with("jax", DEVICES[0], SHAPES[:3])
    _solve_with("jax", DEVICES[0], SHAPES[:5])
    assert mapper_jax._tables_kernel._cache_size() <= sizes + 2
