"""End-to-end inference model + planner (paper Sec. IV/V machinery)."""

import pytest

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core import planner
from repro.core.graph import Plan, model_ops
from repro.configs import get_config, ARCHS

GPT3 = get_config("gpt3-175b")
NODE = hw.dgx_a100(4)


def test_prefill_compute_bound_decode_memory_bound():
    """Paper implications (1)/(3) must hold in the full-model report."""
    plan = Plan(tp=4)
    pf = im.prefill(NODE, GPT3, plan, batch=8, seq=2048)
    dc = im.decode_step(NODE, GPT3, plan, batch=8, kv_len=3072)
    assert pf.bound["compute"] > pf.bound.get("memory", 0)
    assert dc.bound["memory"] > dc.bound.get("compute", 0)


def test_generate_latency_grows_with_output():
    plan = Plan(tp=4)
    g1 = im.generate(NODE, GPT3, plan, 8, 512, 64)
    g2 = im.generate(NODE, GPT3, plan, 8, 512, 512)
    assert g2.latency > g1.latency * 3


def test_memory_accounting_gpt3():
    """GPT-3 fp16 params = 350GB: needs >= 5 x 80GB A100s (paper Sec. I)."""
    plan1 = Plan(tp=1)
    assert im.memory_per_device(GPT3, plan1, 1, 2048) > 350e9
    n = 1
    while im.memory_per_device(GPT3, Plan(tp=n), 1, 2048) > 80e9:
        n *= 2
    assert n >= 8   # tp rounds to powers of 2


def test_max_batch_monotone_in_memory():
    small = hw.make_system(hw.nvidia_a100(), 8)
    big_dev = hw.throughput_oriented()
    big = hw.make_system(big_dev, 8)
    plan = Plan(tp=1, pp=8)
    assert im.max_batch(big, GPT3, plan, 4096) > \
        im.max_batch(small, GPT3, plan, 4096)


def test_kv_cache_memory_windowed():
    """recurrentgemma local attention caps resident KV at the window."""
    cfg = ARCHS["recurrentgemma-2b"]
    plan = Plan()
    m_short = im.memory_per_device(cfg, plan, 1, 4096)
    m_long = im.memory_per_device(cfg, plan, 1, 524288)
    # long context costs almost nothing extra (activations only)
    assert m_long < m_short * 3


def test_kv_cache_memory_dense_grows():
    cfg = ARCHS["qwen3-1.7b"]
    plan = Plan()
    assert im.memory_per_device(cfg, plan, 1, 262144) > \
        2 * im.memory_per_device(cfg, plan, 1, 4096)


def test_planner_grok_needs_many_devices():
    node16 = hw.tpu_v5e_pod(16)
    with pytest.raises(ValueError):
        planner.best_plan(node16, ARCHS["grok-1-314b"], 8, 2048, 256)


def test_planner_finds_plan_for_small_models():
    node = hw.tpu_v5e_pod(16)
    for arch in ("qwen1.5-0.5b", "rwkv6-7b", "recurrentgemma-2b"):
        best = planner.best_plan(node, ARCHS[arch], 8, 2048, 128)
        assert best.fits
        assert best.plan.devices == 16


def test_all_archs_layer_ops_build():
    """The simulator graph covers every assigned architecture."""
    node = hw.tpu_v5e_pod(16)
    plan = Plan(tp=4, dp=4)
    for arch, cfg in ARCHS.items():
        cost = model_ops(cfg, node, plan, batch=4, seq=256, kv_len=256)
        assert cost.latency > 0 and cost.flops > 0, arch


def test_tp_reduces_latency_adds_collectives():
    pf1 = im.prefill(NODE, GPT3, Plan(tp=1), 1, 512)
    pf4 = im.prefill(NODE, GPT3, Plan(tp=4), 1, 512)
    assert pf4.latency < pf1.latency
    assert pf4.bound.get("link", 0) > 0
