"""ISSUE 1 equivalence + evaluator unit tests.

Three layers of bit-for-bit guarantees:
  1. the compressed/batched mapper engine == the seed dense broadcast search
     (matmul_perf_reference, kept verbatim);
  2. the IR/evaluator pipeline (dedup + memo + stacked search) == the eager
     per-node walk (seed-replica evaluator) for prefill / decode / generate /
     rank_plans across dense, MoE, and GQA configs and tp/pp/dp plans;
  3. layernorm-only configs == frozen seed-commit numbers
     (tests/data/seed_reference.json, captured from the seed eager path
     before this refactor; the rmsnorm model change can't affect them).
"""
import json
import os

import pytest

from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core import planner
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan, build_model
from repro.core.ir import MatmulSpec, NormSpec
from repro.core.mapper import (clear_matmul_cache, matmul_perf,
                               matmul_perf_batch, matmul_perf_reference)
from repro.configs import get_config

REL = 1e-9

CONFIGS = ["gpt3-175b", "qwen2-0.5b", "granite-moe-3b-a800m"]
PLANS = [Plan(tp=4), Plan(tp=2, pp=2), Plan(tp=1, pp=2, dp=2),
         Plan(tp=2, dp=2, sequence_parallel=True)]


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-30)


# ---------------------------------------------------------------------------
# 1. mapper engine vs dense reference
# ---------------------------------------------------------------------------

# (m, k, n, batch, bytes_a, bytes_b, bytes_out, bytes_acc, b_shared,
#  mac_scale) — incl. mixed per-operand widths and narrow-datatype rates
SHAPES = [(1, 128, 128, 1, 2, 2, 2, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 2, 2, 2, False, 1.0),
          (16384, 896, 1152, 1, 2, 2, 2, 2, False, 1.0),
          (2048, 128, 2048, 8, 2, 2, 2, 2, False, 1.0),
          (2048, 128, 2048, 8, 2, 2, 2, 2, True, 1.0),
          (7, 64, 2048, 112, 2, 2, 2, 2, False, 1.0),
          (333, 777, 129, 3, 2, 2, 4, 2, False, 1.0),
          (16, 12288, 12288, 1, 2, 1, 2, 4, False, 1.0),   # int8 weights
          (512, 4096, 4096, 1, 1, 1, 1, 4, False, 2.0),    # w8a8
          (64, 8192, 8192, 1, 2, 0.5, 2, 4, False, 1.0)]   # int4 weights


@pytest.mark.parametrize("dev_fn", [hw.nvidia_a100, hw.google_tpu_v5e,
                                    hw.amd_mi210])
def test_batched_mapper_matches_dense_reference(dev_fn):
    dev = dev_fn()
    clear_matmul_cache()
    batched = matmul_perf_batch(dev, SHAPES)
    for sh, rb in zip(SHAPES, batched):
        rr = matmul_perf_reference(dev, sh[0], sh[1], sh[2], batch=sh[3],
                                   bytes_a=sh[4], bytes_b=sh[5],
                                   bytes_out=sh[6], bytes_acc=sh[7],
                                   b_shared=sh[8], mac_scale=sh[9])
        assert rb.latency == rr.latency, sh
        assert rb.flops == rr.flops, sh
        assert rb.main_memory_bytes == rr.main_memory_bytes, sh
        assert rb.candidates_searched == rr.candidates_searched, sh
        assert rb.mapping.bound == rr.mapping.bound, sh


def test_single_shape_wrapper_matches_batch():
    dev = hw.nvidia_a100()
    r1 = matmul_perf(dev, 512, 4096, 1024)
    r2 = matmul_perf_batch(dev, [(512, 4096, 1024, 1, 2, 2, 2, 2, False,
                                  1.0)])[0]
    assert r1.latency == r2.latency
    assert r1.mapping == r2.mapping


# ---------------------------------------------------------------------------
# 2. IR/evaluator pipeline vs eager seed-replica walk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", CONFIGS)
@pytest.mark.parametrize("plan", PLANS, ids=lambda p: f"tp{p.tp}pp{p.pp}dp{p.dp}"
                         + ("sp" if p.sequence_parallel else ""))
def test_equivalence_prefill_decode_generate(arch, plan):
    cfg = get_config(arch)
    system = hw.dgx_a100(4)
    clear_matmul_cache()
    new_ev = Evaluator(system)                            # dedup + batched
    seed_ev = Evaluator(system, use_reference_mapper=True)  # eager dense

    for fn, args in [(im.prefill, (4, 256)),
                     (im.decode_step, (4, 384))]:
        new = fn(system, cfg, plan, *args, evaluator=new_ev)
        old = fn(system, cfg, plan, *args, evaluator=seed_ev)
        assert _rel(new.latency, old.latency) < REL, (arch, plan, fn.__name__)
        assert _rel(new.flops, old.flops) < REL
        assert _rel(new.bytes, old.bytes) < REL
        assert new.bound.keys() == old.bound.keys()

    g_new = im.generate(system, cfg, plan, 4, 256, 32, evaluator=new_ev)
    g_old = im.generate(system, cfg, plan, 4, 256, 32, evaluator=seed_ev)
    assert _rel(g_new.latency, g_old.latency) < REL
    clear_matmul_cache()


@pytest.mark.parametrize("arch", CONFIGS)
def test_equivalence_rank_plans(arch):
    cfg = get_config(arch)
    system = hw.tpu_v5e_pod(16)
    clear_matmul_cache()
    new = planner.rank_plans(system, cfg, 8, 512, 32,
                             evaluator=Evaluator(system))
    old = planner.rank_plans(
        system, cfg, 8, 512, 32,
        evaluator=Evaluator(system, use_reference_mapper=True))
    assert len(new) == len(old)
    for a, b in zip(new, old):
        assert a.plan == b.plan
        assert a.fits == b.fits
        if a.fits:
            assert _rel(a.latency, b.latency) < REL, a.plan
            assert _rel(a.throughput, b.throughput) < REL, a.plan
    clear_matmul_cache()


# ---------------------------------------------------------------------------
# 3. frozen seed-commit numbers (layernorm-only configs)
# ---------------------------------------------------------------------------

_REF_PATH = os.path.join(os.path.dirname(__file__), "data",
                         "seed_reference.json")


def _seed_cases():
    return {
        "gpt3-175b": [("dgx_a100_4", hw.dgx_a100(4), Plan(tp=4)),
                      ("dgx_a100_4_pp", hw.dgx_a100(4), Plan(tp=2, pp=2)),
                      ("tpu_v5e_16", hw.tpu_v5e_pod(16), Plan(tp=4, pp=4))],
        "stablelm-1.6b": [("tpu_v5e_16", hw.tpu_v5e_pod(16),
                           Plan(tp=2, dp=8)),
                          ("dgx_a100_4", hw.dgx_a100(4), Plan(tp=1, dp=4))],
        "whisper-tiny": [("tpu_v5e_16", hw.tpu_v5e_pod(16),
                          Plan(tp=2, pp=2, dp=4))],
        "rwkv6-7b": [("tpu_v5e_16", hw.tpu_v5e_pod(16), Plan(tp=4, dp=4))],
    }


def test_matches_frozen_seed_commit_numbers():
    ref = json.load(open(_REF_PATH))
    for arch, sysplans in _seed_cases().items():
        cfg = get_config(arch)
        for tag, system, plan in sysplans:
            r = ref[f"{arch}/{tag}"]
            pf = im.prefill(system, cfg, plan, batch=4, seq=512)
            dc = im.decode_step(system, cfg, plan, batch=4, kv_len=768)
            g = im.generate(system, cfg, plan, 4, 512, 64)
            assert _rel(pf.latency, r["prefill"]) < REL, (arch, tag)
            assert _rel(pf.flops, r["prefill_flops"]) < REL, (arch, tag)
            assert _rel(pf.bytes, r["prefill_bytes"]) < REL, (arch, tag)
            assert _rel(dc.latency, r["decode"]) < REL, (arch, tag)
            assert _rel(g.latency, r["generate"]) < REL, (arch, tag)


def test_rank_plans_matches_frozen_seed_commit():
    ref = json.load(open(_REF_PATH))["rank_plans/stablelm-1.6b/tpu_v5e_16"]
    got = planner.rank_plans(hw.tpu_v5e_pod(16), get_config("stablelm-1.6b"),
                             8, 1024, 128)
    checked = 0
    for r in got:
        if not r.fits or r.plan.sequence_parallel:
            # SP siblings postdate the frozen reference (ISSUE 5); their
            # non-SP twins must still match it bit-for-bit
            continue
        lat, tp_ = ref[f"tp{r.plan.tp}_pp{r.plan.pp}_dp{r.plan.dp}"]
        assert _rel(r.latency, lat) < REL, r.plan
        assert _rel(r.throughput, tp_) < REL, r.plan
        checked += 1
    assert checked > 0


# ---------------------------------------------------------------------------
# evaluator unit tests: dedup, batching, stats
# ---------------------------------------------------------------------------

def test_evaluator_dedups_same_spec():
    system = hw.dgx_a100(4)
    ev = Evaluator(system)
    spec = MatmulSpec(256, 1024, 512)
    from repro.core.ir import Graph, Node
    g = Graph((Node(spec, "a"), Node(spec, "b"), Node(spec, "c", repeat=3)))
    cost = ev.evaluate(g)
    assert ev.stats.cache_misses == 1          # one search for three nodes
    assert ev.stats.cache_hits == 2
    assert cost.ops[0].latency == cost.ops[1].latency
    assert cost.ops[2].latency == cost.ops[0].latency * 3
    # same spec again, new graph: pure hit
    ev.evaluate(Graph((Node(spec, "d"),)))
    assert ev.stats.cache_misses == 1
    assert ev.stats.cache_hits == 3


def test_evaluator_dedups_across_plans():
    """Plan #2 with the same tp shares every spec with plan #1 -> 100% hits."""
    system = hw.tpu_v5e_pod(16)
    cfg = get_config("qwen2-0.5b")
    ev = Evaluator(system)
    im.prefill(system, cfg, Plan(tp=2, dp=8), 4, 256, evaluator=ev)
    misses = ev.stats.cache_misses
    im.prefill(system, cfg, Plan(tp=2, pp=8), 4, 256, evaluator=ev)
    assert ev.stats.cache_misses == misses     # no new unique specs
    assert ev.stats.hit_rate > 0.4


def test_evaluator_batches_matmuls_in_one_search():
    system = hw.dgx_a100(4)
    cfg = get_config("qwen2-0.5b")
    clear_matmul_cache()
    ev = Evaluator(system)
    graphs = [build_model(cfg, Plan(tp=1), 2, 1, kv)
              for kv in (128, 256, 384, 512)]
    ev.evaluate_many(graphs)
    assert ev.stats.batched_searches == 1      # one stacked search for all
    assert ev.stats.matmul_searches > 4
    clear_matmul_cache()


def test_repeat_counts_match_layer_multiplication():
    """One node x repeat == the seed's evaluate-once-multiply layer path."""
    system = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    g = build_model(cfg, Plan(tp=4), 2, 128, 128, include_head=False)
    n_unique = len(g)
    assert n_unique < 2 * cfg.n_layers         # layers collapsed into repeats
    assert sum(n.repeat for n in g) >= cfg.n_layers


def test_norm_spec_kind_follows_config():
    g = build_model(get_config("gpt3-175b"), Plan(), 1, 64, 64)
    kinds = {n.spec.kind for n in g if isinstance(n.spec, NormSpec)}
    assert kinds == {"layernorm"}
    g = build_model(get_config("qwen2-0.5b"), Plan(), 1, 64, 64)
    kinds = {n.spec.kind for n in g if isinstance(n.spec, NormSpec)}
    assert "rmsnorm" in kinds


def test_spec_roofline_never_beats_model():
    """rooflines are optimistic (paper Table V) — also true per-spec."""
    from repro.core.roofline import spec_roofline
    dev = hw.nvidia_a100()
    ev = Evaluator(hw.dgx_a100(1))
    from repro.core.ir import Graph, Node, SoftmaxSpec
    for spec in [MatmulSpec(512, 4096, 1024), SoftmaxSpec(4096, 2048),
                 NormSpec("rmsnorm", 4096, 4096),
                 NormSpec("layernorm", 4096, 4096)]:
        cost = ev.evaluate(Graph((Node(spec, "x"),)))
        rf = spec_roofline(dev, spec)
        assert cost.latency >= rf.compute_s * 0.999
