"""ISSUE 5: dataflow-DAG scheduling + kernel-fusion semantics.

Four layers of guarantees:
  1. scheduler semantics on synthetic DAGs — a pure chain reproduces the
     serial float sum bit-for-bit, a diamond is priced at its critical path,
     resource contention serializes, and overlap never beats the
     per-resource busy-time bound;
  2. collective pipelining — an overlappable collective hides behind its
     producer GEMM but can never complete before it;
  3. the fusion pass — idempotent, serial-policy identity, correct traffic
     elision, flash-attention streaming;
  4. end-to-end — serial/unfused evaluation stays bit-for-bit on the frozen
     seed numbers while FULL fusion+overlap strictly improves, SP plan
     siblings are enumerated and ranked, and the Study fusion axis works.
"""
import json
import os

from repro.core import fusion as fu
from repro.core import hardware as hw
from repro.core import inference_model as im
from repro.core import interconnect as net
from repro.core import planner
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan, build_model
from repro.core.ir import (CollectiveSpec, FusedMatmulSpec, Graph,
                           MatmulSpec, Node, SoftmaxSpec, resource_of)
from repro.core.schedule import schedule_graph
from repro.core.study import Study
from repro.core.workload import Workload
from repro.configs import get_config

_REF_PATH = os.path.join(os.path.dirname(__file__), "data",
                         "seed_reference.json")

MM = MatmulSpec(8, 8, 8)                    # "compute" stand-in
VEC = SoftmaxSpec(8, 8)                     # "vector" stand-in
AR = CollectiveSpec("all_reduce", 1024, 4)  # "link" stand-in


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-30)


# ---------------------------------------------------------------------------
# 1. scheduler semantics on synthetic DAGs
# ---------------------------------------------------------------------------

def test_chain_equals_serial_sum_bitforbit():
    """A pure chain's makespan is the exact left-to-right float sum."""
    lats = [0.1, 0.07, 1e-9, 0.3, 0.0411, 7e-5]
    g = Graph(tuple(Node(MM, f"n{i}") for i in range(len(lats))))
    sch = schedule_graph(g, lats)
    acc = 0.0
    for x in lats:
        acc = acc + x
    assert sch.makespan == acc              # bit-for-bit, same assoc. order
    assert sch.serial == acc
    assert sch.overlap_speedup == 1.0


def test_chain_mixed_resources_still_serial():
    """Dependencies serialize a chain even across different resources."""
    lats = [0.2, 0.05, 0.1]
    g = Graph((Node(MM, "a"), Node(VEC, "b"), Node(MM, "c")))
    sch = schedule_graph(g, lats)
    assert sch.makespan == (0.2 + 0.05) + 0.1


def test_diamond_critical_path():
    """a -> {b, c} -> d prices max(b, c), and the critical path names the
    slower branch."""
    #      b (compute, 0.5)
    # a <                  > d
    #      c (vector, 0.2)
    g = Graph((Node(MM, "a"),
               Node(MM, "b", deps=(0,)),
               Node(VEC, "c", deps=(0,)),
               Node(MM, "d", deps=(1, 2))))
    sch = schedule_graph(g, [0.1, 0.5, 0.2, 0.05])
    assert _rel(sch.makespan, 0.1 + 0.5 + 0.05) < 1e-12
    names = [sch.slots[i].name for i in sch.critical_path()]
    assert names == ["a", "b", "d"]
    cb = sch.critical_breakdown()
    assert "c" not in cb                    # off the critical path
    assert _rel(sum(cb.values()), sch.makespan) < 1e-12


def test_same_resource_contention_serializes():
    """Two dependence-free GEMMs still share the one systolic datapath."""
    g = Graph((Node(MM, "a", deps=()), Node(MM, "b", deps=())))
    sch = schedule_graph(g, [0.3, 0.4])
    assert _rel(sch.makespan, 0.7) < 1e-12
    # on different resources they genuinely overlap
    g2 = Graph((Node(MM, "a", deps=()), Node(VEC, "b", deps=())))
    sch2 = schedule_graph(g2, [0.3, 0.4])
    assert _rel(sch2.makespan, 0.4) < 1e-12


def test_overlap_bounded_by_resource_busy_times():
    """makespan is always within [max(per-resource busy), serial sum]."""
    cfg = get_config("gpt3-175b")
    system = hw.dgx_a100(4)
    ev = Evaluator(system)
    for fusion in (fu.OVERLAP, fu.FULL):
        for seq, kv in ((512, 512), (1, 768)):
            g = fu.fuse(build_model(cfg, Plan(tp=4), 4, seq, kv_len=kv),
                        fusion)
            cost = ev.evaluate(g, overlap=True)
            sch = cost.schedule
            assert sch.makespan >= max(sch.busy.values()) - 1e-15
            assert sch.makespan <= sch.serial + 1e-15
            assert cost.latency == sch.makespan
            assert cost.serial_latency == sch.serial


# ---------------------------------------------------------------------------
# 2. collective pipelining
# ---------------------------------------------------------------------------

def test_collective_hides_behind_producer():
    """gemm -> AR -> gemm2: with pipelining the AR rides the link while the
    producer still owns compute; without it, strict serialization."""
    g = Graph((Node(MM, "gemm"), Node(AR, "ar"), Node(MM, "gemm2")))
    lats = [0.5, 0.2, 0.4]
    on = schedule_graph(g, lats, pipeline_collectives=True)
    off = schedule_graph(g, lats, pipeline_collectives=False)
    assert _rel(off.makespan, 0.5 + 0.2 + 0.4) < 1e-12
    assert _rel(on.makespan, 0.5 + 0.4) < 1e-12      # AR fully hidden
    # link busy time is still priced
    assert _rel(on.busy["link"], 0.2) < 1e-12


def test_collective_cannot_finish_before_producer():
    """The last ring chunk needs the producer's last tile: a long producer
    floors the collective's completion even when the wire is fast."""
    g = Graph((Node(MM, "gemm"), Node(AR, "ar"), Node(MM, "gemm2")))
    sch = schedule_graph(g, [1.0, 0.1, 0.2], pipeline_collectives=True)
    slot = sch.slots[1]
    assert slot.end >= 1.0                   # >= producer end
    assert _rel(sch.makespan, 1.0 + 0.2) < 1e-12


def test_collective_longer_than_producer_sets_the_path():
    g = Graph((Node(MM, "gemm"), Node(AR, "ar"), Node(MM, "gemm2")))
    sch = schedule_graph(g, [0.2, 1.0, 0.3], pipeline_collectives=True)
    # AR starts with the producer, runs 1.0 on the link, then gemm2
    assert _rel(sch.makespan, 1.0 + 0.3) < 1e-12


# ---------------------------------------------------------------------------
# 3. fusion pass
# ---------------------------------------------------------------------------

def test_fusion_serial_policy_is_identity():
    g = build_model(get_config("gpt3-175b"), Plan(tp=4), 4, 512, 512)
    assert fu.fuse(g, fu.SERIAL) == g
    assert fu.fuse(g, fu.OVERLAP) == g       # overlap alone rewrites nothing


def test_fusion_idempotent_and_structure():
    for arch, plan in [("gpt3-175b", Plan(tp=4)), ("qwen2-0.5b", Plan()),
                       ("granite-moe-3b-a800m", Plan(tp=2, dp=2, ep=2))]:
        cfg = get_config(arch)
        for seq, kv in ((256, 256), (1, 384)):
            g = build_model(cfg, plan, 2, seq, kv_len=kv)
            f1 = fu.fuse(g, fu.FUSED)
            assert fu.fuse(f1, fu.FUSED) == f1          # idempotent
            assert len(f1) < len(g)                     # something fused
            # every edge still points backwards; graph remains a DAG
            f1.edges()


def test_flash_rule_streams_scores():
    """qk_t+softmax is streamed into a_mul_v: the score matrix never touches
    HBM (bytes_out=0 / bytes_a=0), flash-attention's defining property."""
    g = fu.fuse(build_model(get_config("gpt3-175b"), Plan(tp=4), 4, 512,
                            512), fu.FUSED)
    fused = {n.name: n.spec for n in g}
    qk = fused["qk_t+softmax"]
    assert isinstance(qk, FusedMatmulSpec) and qk.stream_out
    assert qk.gemm.bytes_out == 0.0
    assert fused["a_mul_v"].bytes_a == 0


def test_fusion_traffic_elision_accounting():
    """Fused evaluation removes at least the spec-accounted intermediate
    traffic (producer C writes + epilogue reads/writes + streamed scores);
    the mapper may elide a little more by re-tiling the cheaper shape."""
    cfg = get_config("gpt3-175b")
    system = hw.dgx_a100(4)
    ev = Evaluator(system)
    g = build_model(cfg, Plan(tp=4), 4, 512, kv_len=512)
    f = fu.fuse(g, fu.FUSED)
    est = fu.elided_bytes(g, f)
    assert est > 0
    serial, fused = ev.evaluate_many([g, f])
    actual = serial.bytes - fused.bytes
    assert actual >= est * 0.999
    assert fused.latency < serial.latency    # fewer launches + less traffic
    assert fused.flops == serial.flops       # fusion moves work, not math


def test_fused_epilogue_latency_decomposition():
    """A fused node's cost = effective GEMM + tile-local epilogue compute."""
    from repro.core import operators as ops
    system = hw.dgx_a100(4)
    dev = system.device
    base = MatmulSpec(512, 512, 512)
    sm = SoftmaxSpec(512, 512)
    fspec = FusedMatmulSpec(base, (sm,))
    ev = Evaluator(system)
    r_f = ev.evaluate(Graph((Node(fspec, "x"),))).ops[0]
    r_mm = ev.evaluate(Graph((Node(base, "m"),))).ops[0]
    t_epi, f_epi = ops.fused_epilogue(dev, sm)
    assert _rel(r_f.latency, r_mm.latency + t_epi) < 1e-12
    assert _rel(r_f.flops, r_mm.flops + f_epi) < 1e-12
    assert r_f.main_memory_bytes == r_mm.main_memory_bytes


# ---------------------------------------------------------------------------
# 4. end-to-end: seed-exact serial, strict wins, SP plans, Study axis
# ---------------------------------------------------------------------------

def test_serial_unfused_stays_on_frozen_seed_numbers():
    ref = json.load(open(_REF_PATH))["gpt3-175b/dgx_a100_4"]
    system = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    pf = im.prefill(system, cfg, Plan(tp=4), 4, 512, fusion=fu.SERIAL)
    assert _rel(pf.latency, ref["prefill"]) < 1e-9
    assert _rel(pf.bytes, ref["prefill_bytes"]) < 1e-9


def test_full_fusion_overlap_strictly_faster():
    system = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    ev = Evaluator(system)
    pf_s = im.prefill(system, cfg, Plan(tp=4), 4, 512, evaluator=ev)
    pf_f = im.prefill(system, cfg, Plan(tp=4), 4, 512, evaluator=ev,
                      fusion=fu.FULL)
    assert pf_f.latency < pf_s.latency
    assert pf_f.schedule is not None         # per-op start/end exposed
    assert pf_s.schedule is None
    # scheduled-vs-serial ratio surfaces in the evaluator stats summary
    assert ev.stats.schedule_ratio < 1.0
    assert "sched_vs_serial" in ev.stats.summary()


def test_generate_monotone_under_execution_models():
    system = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    ev = Evaluator(system)
    lat = {f: im.generate(system, cfg, Plan(tp=4), 4, 256, 32, evaluator=ev,
                          fusion=f).latency
           for f in (fu.SERIAL, fu.FUSED, fu.OVERLAP, fu.FULL)}
    assert lat[fu.FUSED] < lat[fu.SERIAL]
    assert lat[fu.OVERLAP] < lat[fu.SERIAL]
    assert lat[fu.FULL] <= min(lat[fu.FUSED], lat[fu.OVERLAP])


def test_schedule_roofline_bounds_makespan():
    from repro.core.roofline import schedule_roofline
    system = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    g = fu.fuse(build_model(cfg, Plan(tp=4), 4, 512, 512), fu.FULL)
    cost = Evaluator(system).evaluate(g, overlap=True)
    pt = schedule_roofline(cost)
    assert cost.latency >= pt.latency - 1e-15   # max busy <= makespan
    assert pt.bound in ("compute", "memory", "collective")


def test_sp_siblings_enumerated_and_ranked():
    system = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    plans = planner.enumerate_plans(system, cfg)
    sp = [p for p in plans if p.sequence_parallel]
    assert sp and all(p.tp > 1 for p in sp)
    for p in sp:                            # every SP plan has its AR twin
        import dataclasses
        assert dataclasses.replace(p, sequence_parallel=False) in plans
    # and the ranking prices them like any candidate
    cfg_s = get_config("stablelm-1.6b")
    ranked = planner.rank_plans(system, cfg_s, 8, 256, 16)
    assert any(r.plan.sequence_parallel for r in ranked if r.fits)
    # rwkv blocks hardcode their all-reduce: no mislabeled SP duplicates
    rwkv_plans = planner.enumerate_plans(system, get_config("rwkv6-7b"))
    assert not any(p.sequence_parallel for p in rwkv_plans)


def test_sp_overlap_hides_rs_ag():
    """Under FULL, the SP plan's RS+AG hide behind the adjacent GEMMs: the
    scheduled SP prefill beats its own serial pricing."""
    system = hw.dgx_a100(4)
    cfg = get_config("gpt3-175b")
    ev = Evaluator(system)
    sp = Plan(tp=4, sequence_parallel=True)
    rep_serial = im.prefill(system, cfg, sp, 4, 512, evaluator=ev)
    rep_full = im.prefill(system, cfg, sp, 4, 512, evaluator=ev,
                          fusion=fu.FULL)
    assert rep_full.latency < rep_serial.latency
    busy = rep_full.schedule.busy
    assert busy.get("link", 0.0) > 0.0      # RS+AG priced, not dropped


def test_all_reduce_prices_element_width():
    """Satellite: reduction flops follow the payload's element width."""
    system = hw.dgx_a100(4)
    fp16 = net.all_reduce(system, 2 ** 20, 4)
    fp8 = net.all_reduce(system, 2 ** 20, 4, bytes_elt=1)
    assert _rel(fp8.flops, 2 * fp16.flops) < 1e-12
    assert fp8.latency > fp16.latency
    # default matches the seed formula: (n-1) * chunk / 2
    assert _rel(fp16.flops, 3 * (2 ** 20 / 4) / 2) < 1e-12


def test_study_fusion_axis():
    system = hw.dgx_a100(4)
    cfg = get_config("qwen2-0.5b")
    res = Study(systems=[system], configs=[cfg], plans=[Plan(tp=4)],
                workloads=[Workload(4, 128, 16, samples=4)],
                fusions={"serial": fu.SERIAL, "full": fu.FULL}).run()
    assert len(res) == 2
    rows = {r["fusion"]: r for r in res.to_rows()}
    assert set(rows) == {"serial", "full"}
    assert rows["full"]["latency_s"] < rows["serial"]["latency_s"]
    assert res.filter(fusion="full")[0].case.fusion == fu.FULL
    # simulator path: fused+overlapped serving beats serial goodput
    from repro.core.simulator import simulate
    from repro.core.workload import Trace, TrafficWorkload
    traffic = TrafficWorkload.from_trace(Trace.constant(8, 0.0, 128, 16),
                                         slots=4)
    ev = res.evaluators[system]
    s_serial = simulate(system, cfg, Plan(tp=4), traffic, evaluator=ev)
    s_full = simulate(system, cfg, Plan(tp=4), traffic, evaluator=ev,
                      fusion=fu.FULL)
    assert s_full.goodput > s_serial.goodput


def test_graph_concat_shifts_explicit_deps():
    a = Graph((Node(MM, "a0"), Node(VEC, "a1", deps=(0,))))
    b = Graph((Node(MM, "b0"), Node(VEC, "b1", deps=(0,))))
    c = a + b
    assert c.edges() == [(), (0,), (1,), (2,)]
    assert resource_of(c.nodes[2].spec) == "compute"


def test_scaled_schedule_is_homogeneous():
    """Folded repeat counts scale the schedule linearly: scaling every
    duration by n scales the makespan by n (the layer-folding premise)."""
    g = Graph((Node(MM, "a"),
               Node(MM, "b", deps=(0,)),
               Node(AR, "c", deps=(1,)),
               Node(VEC, "d", deps=(0,)),
               Node(MM, "e", deps=(2, 3))))
    lats = [0.1, 0.25, 0.2, 0.4, 0.05]
    one = schedule_graph(g, lats)
    ten = schedule_graph(g, [10 * x for x in lats])
    assert _rel(ten.makespan, 10 * one.makespan) < 1e-12
