"""ISSUE 4: precision subsystem tests.

Four layers of guarantees:
  1. the DEFAULT (fp16-everywhere) policy is a no-op: identical graphs,
     frozen seed-commit numbers bit-for-bit (tests/data/seed_reference.json);
  2. spec stamping is the policy, exactly: every operand width in a built
     graph equals the policy's per-class width, and the matmul roofline's
     byte count is the sum of per-operand widths (the mapper never goes
     below it);
  3. quantization moves the model the right way: int8 weights strictly
     speed up memory-bound decode, w8a8 speeds up compute-bound prefill,
     int8 KV doubles the slot budget, int8 MACs shrink the die;
  4. the precision axis composes: Study grids sweep policies, the planner
     memory gate admits quantized plans fp16 rejects, the serving simulator
     prices policies.
"""
import json
import os

import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import area, hardware as hw
from repro.core import inference_model as im
from repro.core import planner
from repro.core.evaluator import Evaluator
from repro.core.graph import Plan, build_model
from repro.core.ir import (ElementwiseSpec, MatmulSpec, NormSpec,
                           SoftmaxSpec, TrafficSpec)
from repro.core.mapper import clear_matmul_cache, matmul_perf
from repro.core.precision import (DEFAULT, DTYPES, FP16, FP32, INT8,
                                  PrecisionPolicy, get_dtype, get_policy,
                                  mac_scale, POLICIES, policy_tag)
from repro.core.roofline import spec_roofline
from repro.core.study import Case, Study
from repro.core.workload import (PRECISION_POLICIES, Trace, TrafficWorkload,
                                 Workload)

REL = 1e-9
_REF_PATH = os.path.join(os.path.dirname(__file__), "data",
                         "seed_reference.json")


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-30)


# ---------------------------------------------------------------------------
# 1. registry + policy surface
# ---------------------------------------------------------------------------

def test_dtype_registry():
    assert DTYPES["fp16"].bytes == 2 and isinstance(DTYPES["fp16"].bytes, int)
    assert DTYPES["int8"].bytes == 1
    assert DTYPES["fp32"].bytes == 4
    assert DTYPES["int4"].bytes == 0.5
    assert get_dtype("bf16").mac_throughput == 1.0
    with pytest.raises(KeyError):
        get_dtype("fp12")


def test_mac_scale_promotes_to_slower_operand():
    assert mac_scale(FP16, FP16) == 1.0
    assert mac_scale(FP16, INT8) == 1.0      # dequantize-into-fp16 MACs
    assert mac_scale(INT8, INT8) == 2.0
    assert mac_scale(FP32, INT8) == 0.5
    assert mac_scale(DTYPES["int4"], DTYPES["int4"]) == 4.0


def test_policy_presets_and_tags():
    assert POLICIES["fp16"] == DEFAULT == PrecisionPolicy()
    assert PRECISION_POLICIES is POLICIES      # workload.py grid-axis export
    w8 = get_policy("int8-weights")
    assert w8.weights == INT8 and w8.activations == FP16
    assert w8.accumulator == FP32              # honest fp32 acc off-default
    assert policy_tag(w8) == "int8-weights"
    assert policy_tag(DEFAULT) == "fp16"
    custom = DEFAULT.with_(kv_cache=get_dtype("int4"))
    assert policy_tag(custom) == custom.tag    # unregistered -> structural
    with pytest.raises(KeyError):
        get_policy("int7")


def test_weight_and_attn_gemm_kwargs():
    w8 = get_policy("int8-weights")
    wg, ag = w8.weight_gemm(), w8.attn_gemm()
    assert wg["bytes_b"] == 1 and wg["bytes_a"] == 2
    assert wg["bytes_acc"] == 4 and wg["mac_scale"] == 1.0
    assert ag["bytes_b"] == 2                  # KV stays fp16 in this preset
    a8 = get_policy("w8a8")
    assert a8.weight_gemm()["mac_scale"] == 2.0


# ---------------------------------------------------------------------------
# 2. fp16 default is a bit-for-bit no-op
# ---------------------------------------------------------------------------

def test_default_policy_builds_identical_graphs():
    cfg = get_config("qwen2-0.5b")
    g_imp = build_model(cfg, Plan(tp=2), 4, 128, kv_len=128)
    g_exp = build_model(cfg, Plan(tp=2), 4, 128, kv_len=128, policy=DEFAULT)
    assert g_imp == g_exp


def test_fp16_policy_matches_frozen_seed_commit_numbers():
    """The acceptance gate: explicit fp16-everywhere PrecisionPolicy
    reproduces the frozen seed latencies/flops/bytes bit-for-bit."""
    ref = json.load(open(_REF_PATH))
    fp16 = get_policy("fp16")
    for arch, tag, system, plan in [
            ("gpt3-175b", "dgx_a100_4", hw.dgx_a100(4), Plan(tp=4)),
            ("stablelm-1.6b", "tpu_v5e_16", hw.tpu_v5e_pod(16),
             Plan(tp=2, dp=8))]:
        cfg = get_config(arch)
        r = ref[f"{arch}/{tag}"]
        pf = im.prefill(system, cfg, plan, batch=4, seq=512, policy=fp16)
        dc = im.decode_step(system, cfg, plan, batch=4, kv_len=768,
                            policy=fp16)
        g = im.generate(system, cfg, plan, 4, 512, 64, policy=fp16)
        assert _rel(pf.latency, r["prefill"]) < REL, (arch, tag)
        assert _rel(pf.flops, r["prefill_flops"]) < REL, (arch, tag)
        assert _rel(pf.bytes, r["prefill_bytes"]) < REL, (arch, tag)
        assert _rel(dc.latency, r["decode"]) < REL, (arch, tag)
        assert _rel(g.latency, r["generate"]) < REL, (arch, tag)


def test_fp16_policy_area_unchanged():
    for dev in (hw.nvidia_ga100(), hw.latency_oriented()):
        assert dev.core.lane.systolic_array.dtype == "fp16"
    assert area.MAC_AREA["fp16"] == area.AREA_FP16_MAC
    assert area.device_area(hw.nvidia_ga100(), 600).total_mm2 == \
        pytest.approx(826, rel=0.05)


# ---------------------------------------------------------------------------
# 3. spec stamping == the policy (the per-operand-width property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(POLICIES))
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "granite-moe-3b-a800m"])
def test_policy_widths_stamp_every_spec(name, arch):
    """Every operand width in a built graph is the policy's class width:
    matmul A/out at activations, B at weights or kv_cache, acc at
    accumulator; softmax/norm/elementwise at activations."""
    p = get_policy(name)
    cfg = get_config(arch)
    g = build_model(cfg, Plan(tp=2), 2, 64, kv_len=64, policy=p)
    saw_weight = saw_kv = False
    for node in g:
        s = node.spec
        if isinstance(s, MatmulSpec):
            assert s.bytes_a == p.activations.bytes, node.name
            assert s.bytes_out == p.activations.bytes, node.name
            assert s.bytes_acc == p.accumulator.bytes, node.name
            assert s.bytes_b in (p.weights.bytes, p.kv_cache.bytes), node.name
            saw_weight |= s.bytes_b == p.weights.bytes
            saw_kv |= node.name in ("qk_t", "a_mul_v") \
                and s.bytes_b == p.kv_cache.bytes
        elif isinstance(s, (SoftmaxSpec, NormSpec)):
            assert s.bytes_in == s.bytes_out == p.activations.bytes, node.name
        elif isinstance(s, ElementwiseSpec):
            assert s.bytes_elt == p.activations.bytes, node.name
    assert saw_weight and saw_kv


def test_decode_kv_append_priced_at_kv_width():
    cfg = get_config("qwen2-0.5b")
    kv8 = get_policy("int8-kv")
    g16 = build_model(cfg, Plan(), 2, 1, kv_len=128)
    g8 = build_model(cfg, Plan(), 2, 1, kv_len=128, policy=kv8)
    t16 = [n.spec.n_bytes for n in g16
           if isinstance(n.spec, TrafficSpec) and n.name == "kv_append"]
    t8 = [n.spec.n_bytes for n in g8
          if isinstance(n.spec, TrafficSpec) and n.name == "kv_append"]
    assert t16 and t8 and t8[0] == t16[0] / 2


@given(ba=st.sampled_from([0.5, 1, 2, 4]), bb=st.sampled_from([0.5, 1, 2, 4]),
       bo=st.sampled_from([1, 2, 4]), scale=st.sampled_from([0.5, 1.0, 2.0]))
@settings(max_examples=12, deadline=None)
def test_matmul_bytes_are_per_operand_sums(ba, bb, bo, scale):
    """The roofline byte count of a MatmulSpec is exactly the sum of
    per-operand widths, and the mapper's chosen mapping never streams less
    (nor runs faster than the width-scaled roofline)."""
    dev = hw.nvidia_a100()
    m, k, n = 256, 4096, 1024
    spec = MatmulSpec(m, k, n, bytes_a=ba, bytes_b=bb, bytes_out=bo,
                      mac_scale=scale)
    rf = spec_roofline(dev, spec)
    expected = m * k * ba + k * n * bb + m * n * bo
    assert _rel(rf.memory_s * dev.memory_bandwidth, expected) < REL
    clear_matmul_cache()
    r = matmul_perf(dev, m, k, n, bytes_a=ba, bytes_b=bb, bytes_out=bo,
                    mac_scale=scale)
    clear_matmul_cache()
    assert r.main_memory_bytes >= expected * (1 - 1e-12)
    assert r.latency >= rf.latency * 0.999


# ---------------------------------------------------------------------------
# 4. quantization moves the model the right way
# ---------------------------------------------------------------------------

GPT3 = get_config("gpt3-175b")
NODE = hw.dgx_a100(4)


def test_int8_weights_speed_up_memory_bound_decode():
    """Decode streams weights: halving bytes_b must strictly cut latency
    AND total traffic, with flops unchanged (the acceptance criterion)."""
    plan = Plan(tp=4)
    dc16 = im.decode_step(NODE, GPT3, plan, batch=8, kv_len=3072)
    assert dc16.bound["memory"] > dc16.bound.get("compute", 0)  # mem-bound
    dc8 = im.decode_step(NODE, GPT3, plan, batch=8, kv_len=3072,
                         policy=get_policy("int8-weights"))
    assert dc8.latency < dc16.latency
    assert dc8.bytes < dc16.bytes
    assert dc8.flops == dc16.flops
    # weight streaming dominates decode: the cut is substantial, not epsilon
    assert dc8.latency < 0.75 * dc16.latency


def test_w8a8_speeds_up_compute_bound_prefill():
    """Prefill is compute-bound: the 2x int8 issue rate must show up."""
    plan = Plan(tp=4)
    pf16 = im.prefill(NODE, GPT3, plan, batch=8, seq=2048)
    assert pf16.bound["compute"] > pf16.bound.get("memory", 0)
    pf8 = im.prefill(NODE, GPT3, plan, batch=8, seq=2048,
                     policy=get_policy("w8a8"))
    assert pf8.latency < 0.75 * pf16.latency


def test_int8_kv_doubles_slot_budget():
    cfg = get_config("qwen3-1.7b")
    sys1 = hw.make_system(hw.nvidia_a100(), 1)
    plan = Plan()
    kv8 = get_policy("int8-kv")
    m16 = im.memory_per_device(cfg, plan, 16, 8192)
    m8 = im.memory_per_device(cfg, plan, 16, 8192, kv8)
    # the saving is exactly half the fp16 KV bytes
    kv_bytes = 16 * 8192 * cfg.kv_bytes_per_token(2)
    assert _rel(m16 - m8, kv_bytes / 2) < REL
    b16 = im.max_batch(sys1, cfg, plan, 16384)
    b8 = im.max_batch(sys1, cfg, plan, 16384, kv8)
    assert b8 > 1.5 * b16       # KV dominates at 16k context: ~2x slots


def test_int4_weights_quarter_weight_memory():
    cfg = get_config("qwen2-0.5b")
    w4 = get_policy("int4-weights")
    m16 = im.memory_per_device(cfg, Plan(), 1, 1)
    m4 = im.memory_per_device(cfg, Plan(), 1, 1, w4)
    saved = cfg.param_count() * (2 - 0.5)
    assert _rel(m16 - m4, saved) < REL


def test_narrow_mac_shrinks_die():
    assert area.MAC_AREA["int4"] < area.MAC_AREA["int8"] \
        < area.MAC_AREA["fp8"] < area.MAC_AREA["fp16"] < area.MAC_AREA["fp32"]
    a100 = hw.nvidia_a100()
    i8 = hw.with_mac_dtype(a100, "int8")
    r16 = area.device_area(a100, 600)
    r8 = area.device_area(i8, 600)
    assert r8.total_mm2 < r16.total_mm2
    assert _rel(r8.breakdown["systolic_arrays"],
                0.3 * r16.breakdown["systolic_arrays"]) < REL
    with pytest.raises(KeyError):
        area.device_area(hw.with_mac_dtype(a100, "fp12"), 600)


# ---------------------------------------------------------------------------
# 5. the axis composes: Study grids, planner gate, serving simulator
# ---------------------------------------------------------------------------

def test_study_policies_axis():
    cfg = get_config("qwen2-0.5b")
    node = hw.dgx_a100(4)
    w = Workload(2, 128, 16, samples=4)
    pols = {"fp16": get_policy("fp16"), "int8-weights":
            get_policy("int8-weights")}
    res = Study(systems=[node], configs=[cfg], plans=[Plan(tp=2, dp=2)],
                workloads={"w": w}, policies=pols).run()
    assert len(res) == 2
    assert {r["policy"] for r in res.to_rows()} == set(pols)
    # the fp16 row is bit-for-bit the row of a Study without the axis
    base = Study(systems=[node], configs=[cfg], plans=[Plan(tp=2, dp=2)],
                 workloads={"w": w}).run()[0]
    r16 = res.filter(policy="fp16")[0]
    assert r16.latency == base.latency
    assert r16.throughput == base.throughput
    r8 = res.filter(policy="int8-weights")[0]
    assert r8.latency < r16.latency


def test_study_policy_mapping_keys_name_rows():
    """User-supplied axis keys label the rows and round-trip filter()."""
    cfg = get_config("qwen2-0.5b")
    node = hw.dgx_a100(4)
    custom = PrecisionPolicy(weights=INT8, kv_cache=INT8, accumulator=FP32)
    res = Study(systems=[node], configs=[cfg], plans=[Plan(tp=2, dp=2)],
                workloads={"w": Workload(2, 64, 8, samples=4)},
                policies={"my-quant": custom}).run()
    assert res.to_rows()[0]["policy"] == "my-quant"
    assert res.filter(policy="my-quant") == res.results
    assert res.filter(policy="w8kv8") == res.results   # preset tag matches
    assert res.filter(policy=custom) == res.results
    assert res.filter(policy="fp16") == []


def test_policy_kwarg_rejects_scheduler_string():
    """The PrecisionPolicy kwarg fails fast when handed the scheduler
    policy string ('continuous'/'static') by mistake."""
    cfg = get_config("qwen3-1.7b")
    sys1 = hw.make_system(hw.nvidia_a100(), 1)
    traffic = TrafficWorkload.from_trace(
        Trace.constant(2, 0.0, 32, 4), slots=2)
    from repro.core.simulator import simulate
    with pytest.raises(TypeError):
        simulate(sys1, cfg, Plan(), traffic, policy="static")
    with pytest.raises(TypeError):
        Case(sys1, cfg, Plan(), traffic, stage="serve", policy="static")


def test_planner_gate_admits_quantized_plans():
    """GPT-3 fp16 on 4xA100 fits under NO plan (87.5 GB/device of weights
    alone); int8 weights bring it under 80 GB — best_plan must find it."""
    with pytest.raises(ValueError):
        planner.best_plan(NODE, GPT3, 1, 128, 16)
    best = planner.best_plan(NODE, GPT3, 1, 128, 16,
                             policy=get_policy("w8kv8"))
    assert best.fits
    assert best.memory_per_device < NODE.device.memory_capacity


def test_simulator_prices_policies():
    """Uniform-trace replay under int8-KV: decode rounds stream half the
    cache, so goodput must improve on the fp16 replay."""
    cfg = get_config("qwen3-1.7b")
    sys1 = hw.make_system(hw.nvidia_a100(), 1)
    from repro.core.simulator import simulate
    traffic = TrafficWorkload.from_trace(
        Trace.constant(4, 0.0, 512, 128), slots=4)
    ev = Evaluator(sys1)
    r16 = simulate(sys1, cfg, Plan(), traffic, evaluator=ev)
    r8 = simulate(sys1, cfg, Plan(), traffic, evaluator=ev,
                  policy=get_policy("w8kv8"))
    assert r8.tokens_out == r16.tokens_out
    assert r8.goodput > r16.goodput


def test_serve_stage_case_carries_policy():
    cfg = get_config("qwen3-1.7b")
    sys1 = hw.make_system(hw.nvidia_a100(), 1)
    traffic = TrafficWorkload.from_trace(
        Trace.constant(4, 0.0, 128, 16), slots=4)
    res = Study(cases=[
        Case(sys1, cfg, Plan(), traffic, stage="serve"),
        Case(sys1, cfg, Plan(), traffic, stage="serve",
             policy=get_policy("w8kv8"))]).run()
    assert res[1].sim.goodput > res[0].sim.goodput
    assert res.to_rows()[1]["policy"] == "w8kv8"
