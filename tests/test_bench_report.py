"""ISSUE 10 satellite: benchmarks/run.py cold-vs-warm report deltas.

`--json` reports diff against the previous BENCH_*.json artifact (the
`bench_cold_vs_warm` section). The helpers are pure, so they are unit
tested here without running the benchmark suite itself.
"""
import json

from benchmarks.run import _load_baseline, delta_vs_previous

PREV = {
    "suite": "quick",
    "git_sha": "abc123",
    "benchmarks": {
        "study_speed": {"seconds": 10.0, "checks": {}},
        "fig6_area": {"seconds": 2.0, "checks": {}},
        "retired_bench": {"seconds": 1.0, "checks": {}},
        "broken": "not-a-dict",
    },
}


def test_delta_vs_previous_speedups():
    d = delta_vs_previous(PREV, {"study_speed": 2.5, "fig6_area": 4.0,
                                 "new_bench": 1.0})
    assert d["previous_git_sha"] == "abc123"
    assert d["previous_suite"] == "quick"
    b = d["benchmarks"]
    # only benchmarks present (and well-formed) on both sides are diffed
    assert sorted(b) == ["fig6_area", "study_speed"]
    assert b["study_speed"] == {"seconds_prev": 10.0, "seconds": 2.5,
                                "speedup": 4.0}
    assert b["fig6_area"]["speedup"] == 0.5      # regression: < 1


def test_delta_vs_previous_zero_seconds():
    d = delta_vs_previous(PREV, {"fig6_area": 0.0})
    assert d["benchmarks"]["fig6_area"]["speedup"] == 0.0


def test_load_baseline(tmp_path):
    p = tmp_path / "BENCH_quick.json"
    assert _load_baseline(None) is None
    assert _load_baseline(str(p)) is None                 # absent
    p.write_text("{not json")
    assert _load_baseline(str(p)) is None                 # corrupt
    p.write_text(json.dumps({"no_benchmarks": 1}))
    assert _load_baseline(str(p)) is None                 # wrong shape
    p.write_text(json.dumps(PREV))
    assert _load_baseline(str(p)) == PREV
