"""Training substrate: optimizer, train loop convergence, grad compression,
microbatching equivalence, data pipeline determinism."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config
from repro.training import (AdamW, cosine_schedule, constant_schedule,
                            make_train_step, init_state, compress_grads,
                            compress_int8, decompress_int8)
from repro.data import DataConfig, TokenPipeline

KEY = jax.random.PRNGKey(0)


def test_adamw_reduces_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    st = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * st.master["w"]}
        params, st, _ = opt.update(g, st, params)
    assert float(jnp.abs(st.master["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


def test_grad_clipping():
    opt = AdamW(lr=constant_schedule(1e-3), clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    st = opt.init(params)
    _, _, stats = opt.update({"w": jnp.full(4, 100.0)}, st, params)
    assert float(stats["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_train_loop_loss_decreases():
    """~100k-param model, repeated batch: loss must drop significantly."""
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    opt = AdamW(lr=constant_schedule(3e-3), weight_decay=0.0)
    state = init_state(cfg, opt, KEY)
    step = jax.jit(make_train_step(cfg, opt))
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::10]


def test_microbatched_step_matches_full_batch():
    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    opt = AdamW(lr=constant_schedule(1e-3))
    toks = jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    s1 = init_state(cfg, opt, KEY)
    s2 = init_state(cfg, opt, KEY)
    st1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(s1, batch)
    st2, m2 = jax.jit(make_train_step(cfg, opt, microbatches=4))(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    w1 = jax.tree.leaves(st1.params)[0].astype(jnp.float32)
    w2 = jax.tree.leaves(st2.params)[0].astype(jnp.float32)
    assert float(jnp.max(jnp.abs(w1 - w2))) < 0.05


def test_int8_compression_error_feedback():
    g = {"w": jax.random.normal(KEY, (256,)) * 0.01}
    deq, res = compress_grads(g)
    # error feedback: residual + dequantized == original
    err = g["w"] - (deq["w"] + res["w"])
    assert float(jnp.max(jnp.abs(err))) < 1e-6
    # relative quantization error bounded by int8 resolution
    rel = float(jnp.max(jnp.abs(g["w"] - deq["w"])) / jnp.max(jnp.abs(g["w"])))
    assert rel < 1.0 / 100


def test_int8_roundtrip():
    x = jax.random.normal(KEY, (1000,)) * 3.0
    q, s = compress_int8(x)
    back = decompress_int8(q, s)
    assert float(jnp.max(jnp.abs(x - back))) <= float(s) * 0.5 + 1e-6


# ---------------- data pipeline ----------------

def test_pipeline_deterministic_and_pure():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b1 = p1.batch_at(123)
    b2 = p2.batch_at(123)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], p1.batch_at(124)["tokens"])


def test_pipeline_host_sharding_partitions():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=1)
    full = TokenPipeline(cfg).batch_at(5)["tokens"]
    h0 = TokenPipeline(
        DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=1,
                   host_index=0, host_count=2)).batch_at(5)["tokens"]
    h1 = TokenPipeline(
        DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=1,
                   host_index=1, host_count=2)).batch_at(5)["tokens"]
    assert np.array_equal(np.concatenate([h0, h1]), full)


def test_pipeline_memmap_source(tmp_path):
    toks = np.arange(10_000, dtype=np.uint32) % 777
    f = tmp_path / "tokens.bin"
    toks.tofile(f)
    cfg = DataConfig(vocab_size=777, seq_len=64, global_batch=4, seed=3,
                     token_file=str(f))
    b = TokenPipeline(cfg).batch_at(0)
    assert b["tokens"].shape == (4, 64)
    # targets are tokens shifted by one
    assert np.array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
