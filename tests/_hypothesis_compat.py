"""Optional-hypothesis shim: property tests skip (instead of killing the
whole module at collection) when hypothesis isn't installed, while plain
tests in the same file still run. `pip install -e .[test]` gets the real
thing."""
try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Accepts any strategy constructor call; values are never drawn
        because @given skips the test."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
