"""Area + cost model (paper Sec. III-D, Table II/IV)."""
import pytest

from repro.core import area, cost, hardware as hw


def test_ga100_area_calibration():
    """Paper Table IV: GA100 = 826 mm^2 (model output)."""
    rep = area.device_area(hw.nvidia_ga100(), 600)
    assert rep.total_mm2 == pytest.approx(826, rel=0.05)


def test_table4_design_areas():
    lat = area.device_area(hw.latency_oriented(), 600).total_mm2
    thr = area.device_area(hw.throughput_oriented(), 600).total_mm2
    assert lat == pytest.approx(478, rel=0.05)
    assert thr == pytest.approx(787, rel=0.08)


def test_area_reduction_claim():
    """Paper: latency design reduces die area by 42.1%."""
    ga = area.device_area(hw.nvidia_ga100(), 600).total_mm2
    lat = area.device_area(hw.latency_oriented(), 600).total_mm2
    assert 1 - lat / ga == pytest.approx(0.421, abs=0.03)


def test_breakdown_sums_to_total():
    rep = area.device_area(hw.nvidia_a100(), 600)
    assert sum(rep.breakdown.values()) == pytest.approx(rep.total_mm2,
                                                        rel=0.01)


def test_bigger_systolic_bigger_lane():
    a = area.lane_area(hw.compute_design("B"))
    e = area.lane_area(hw.compute_design("E"))
    assert e > 10 * a


def test_cost_table4():
    """Paper Table IV: $640 / $711 / $296 total device cost."""
    for dev, paper in ((hw.latency_oriented(), 640),
                       (hw.nvidia_ga100(), 711),
                       (hw.throughput_oriented(), 296)):
        rep = area.device_area(dev, 600)
        c = cost.device_cost(dev, rep.total_mm2)
        assert c.total_usd == pytest.approx(paper, rel=0.08)


def test_dies_per_wafer_monotone():
    assert cost.dies_per_wafer(100) > cost.dies_per_wafer(400) > \
        cost.dies_per_wafer(800) > 0


def test_hbm_vs_ddr_cost():
    assert cost.memory_cost(hw.nvidia_ga100()) == pytest.approx(560, rel=0.01)
    assert cost.memory_cost(hw.throughput_oriented()) == pytest.approx(
        154, rel=0.01)
