"""ISSUE 10: parallel sharded Study execution — determinism and merging.

`Study.run(workers=N)` must be invisible in the results: `to_rows()` and
CSV output byte-identical to the serial path, identical persistent-cache
contents, and stats / EvalStats / MetricsRegistry counters that merge to
the serial totals (modulo wall-clock fields). Workers are real processes
(ProcessPoolExecutor), so these tests also pin the config plumbing: cache
root + enabled flag, mapper backend/prune mode and verify mode all travel
in the worker payload, never through inherited globals.
"""
import copy
import os
import tempfile

import pytest

from repro.core import hardware as hw
from repro.core import result_cache
from repro.core.evaluator import EvalStats
from repro.core.graph import Plan
from repro.core.mapper import MapperCacheStats, clear_matmul_cache
from repro.core.obs import MetricsRegistry, metrics
from repro.core.study import Study
from repro.core.workload import Trace, TrafficWorkload, Workload
from repro.configs import get_config

WORKLOADS = {"w256": Workload(2, 256, 32, samples=4),
             "w128": Workload(1, 128, 16, samples=2)}


def _grid_study(**kw):
    return Study(systems=[hw.dgx_a100(4)],
                 configs=[get_config("stablelm-1.6b"),
                          get_config("qwen2-0.5b")],
                 plans=[Plan(tp=2, dp=2)],
                 workloads=WORKLOADS, **kw)


def _run(workers, **kw):
    clear_matmul_cache()        # workers fork: don't inherit a warm memo
    return _grid_study(**kw).run(workers=workers)


def test_parallel_rows_and_csv_byte_identical():
    with result_cache.disabled():
        serial = _run(None)
        two = _run(2)
        eight = _run(8)         # clamps to len(cases)
    assert two.to_rows() == serial.to_rows()
    assert eight.to_rows() == serial.to_rows()
    assert two.to_csv() == serial.to_csv()
    assert eight.to_csv() == serial.to_csv()
    # merged sweep counters match the serial ones (wall-clock aside)
    assert two.stats.cases == serial.stats.cases
    assert two.stats.evaluated == serial.stats.evaluated
    assert two.stats.skipped_unfit == serial.stats.skipped_unfit
    assert two.stats.matmul_pairs_presolved \
        == serial.stats.matmul_pairs_presolved


def _tree(root):
    out = {}
    for dirpath, _, files in os.walk(root):
        for f in sorted(files):
            p = os.path.join(dirpath, f)
            with open(p, "rb") as fh:
                out[os.path.relpath(p, root)] = fh.read()
    return out


def test_parallel_disk_cache_contents_identical():
    """Cold serial and cold parallel runs persist the SAME entries, byte
    for byte — content-hashed keys + atomic writes make cross-process
    dedup safe, and merging changes nothing about what lands on disk."""
    with tempfile.TemporaryDirectory() as a, tempfile.TemporaryDirectory() as b:
        with result_cache.overridden(root=a, enabled=True):
            r_serial = _run(None)
        with result_cache.overridden(root=b, enabled=True):
            r_par = _run(2)
        assert r_par.to_rows() == r_serial.to_rows()
        ta, tb = _tree(a), _tree(b)
        assert sorted(ta) == sorted(tb)
        assert ta == tb


def test_parallel_warm_rerun_hits_case_cache():
    with tempfile.TemporaryDirectory() as root:
        with result_cache.overridden(root=root, enabled=True):
            cold = _run(2)
            warm = _run(2)
        assert warm.to_rows() == cold.to_rows()
        assert cold.stats.case_cache_hits == 0
        assert cold.stats.case_cache_misses == len(cold)
        assert warm.stats.case_cache_hits == len(warm)
        assert warm.stats.case_cache_misses == 0


def test_workers_zero_and_one_are_serial():
    with result_cache.disabled():
        assert _run(0).to_rows() == _run(1).to_rows() == _run(None).to_rows()


def test_negative_workers_raises():
    with pytest.raises(ValueError):
        _grid_study().run(workers=-1)


def test_serve_stage_through_workers():
    trace = Trace.poisson(8, rate=20.0, in_len=(16, 64), out_len=8, seed=2)
    wls = [TrafficWorkload.from_trace(trace, slots=2, policy=p,
                                      kv_samples=4, seq_samples=4)
           for p in ("continuous", "static")]

    def study():
        clear_matmul_cache()
        return Study(systems=[hw.make_system(hw.nvidia_a100(), 1)],
                     configs=[get_config("qwen2-0.5b")], plans=[Plan()],
                     workloads=wls, stage="serve")

    with result_cache.disabled():
        serial = study().run()
        par = study().run(workers=2)
    assert len(par) == 2
    assert par.to_rows() == serial.to_rows()
    for r_s, r_p in zip(serial, par):
        assert r_p.sim is not None
        assert r_p.sim.goodput == r_s.sim.goodput
        assert r_p.sim.ttft(99) == r_s.sim.ttft(99)


# -- counter merging (satellite: merge-safe MapperCacheStats windows) -------

def test_merge_delta_counters_phases_gauges():
    reg = MetricsRegistry()
    reg.inc("mapper.misses", 3)
    reg.set_gauge("workers", 1.0)
    reg.merge_delta({"mapper.misses": 2.0, "mapper.rows_pruned": 7.0,
                     "gauge.workers": 4.0,
                     "phase.presolve.count": 2, "phase.presolve.seconds": 0.5})
    assert reg.counter("mapper.misses") == 5
    assert reg.counter("mapper.rows_pruned") == 7
    assert reg.gauge("workers") == 4.0            # gauges overwrite
    assert reg.phase_counts() == {"presolve": 2}  # phases add
    assert reg.phase_seconds() == {"presolve": 0.5}
    reg.merge_delta({"phase.presolve.count": 1,
                     "phase.presolve.seconds": 0.25})
    assert reg.phase_counts() == {"presolve": 3}
    assert reg.phase_seconds() == {"presolve": 0.75}


def test_mapper_cache_stats_window_sees_worker_activity():
    """Regression (ISSUE 10): a MapperCacheStats window constructed before
    a parallel run must report the workers' mapper activity after the
    join — per-worker registry deltas are summed into the parent registry,
    the single source of truth the window reads."""
    with result_cache.disabled():
        window = MapperCacheStats()
        before = window.misses
        _run(2)
        assert window.misses > before


def test_eval_stats_doc_roundtrip_and_merge():
    a = EvalStats(graphs=2, nodes=10, cache_hits=3, matmul_searches=4,
                  serial_seconds=0.5)
    doc = a.to_doc()
    assert doc["graphs"] == 2 and doc["serial_seconds"] == 0.5
    b = copy.deepcopy(a)
    b.merge(doc)
    assert b.graphs == 4 and b.nodes == 20 and b.cache_hits == 6
    assert b.serial_seconds == 1.0
    b.merge({"graphs": 0, "unknown_field": 9})    # zeros and strays ignored
    assert b.graphs == 4
    assert not hasattr(b, "unknown_field")


def test_parallel_merges_eval_stats():
    with result_cache.disabled():
        serial = _run(None)
        par = _run(2)
    s_ev = list(serial.evaluators.values())
    p_ev = list(par.evaluators.values())
    assert len(s_ev) == len(p_ev) == 1
    assert p_ev[0].stats.graphs == s_ev[0].stats.graphs
    assert p_ev[0].stats.matmul_searches == s_ev[0].stats.matmul_searches
    assert p_ev[0].stats.candidates_searched \
        == s_ev[0].stats.candidates_searched
