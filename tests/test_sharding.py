"""Sharding rules (single-process checks) + multi-device pjit smoke via a
subprocess with 8 forced host devices (XLA device count must stay 1 in the
main test process)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed import sharding as shd
from repro import models


def _fake_mesh(shape, axes):
    """Mesh over a single device repeated is illegal; build an abstract-ish
    mesh via np object array of the one device — only mesh.shape is used by
    the rules."""
    n = int(np.prod(shape))
    dev = jax.devices()[0]
    arr = np.array([dev] * n).reshape(shape)
    return Mesh(arr, axes)


MESH = _fake_mesh((4, 2), ("data", "model"))


def test_param_rules_dense():
    shd.set_model_config(ARCHS["qwen3-1.7b"])
    abs_p = models.abstract_params(ARCHS["qwen3-1.7b"])
    import jax.tree_util as jtu
    flat = jtu.tree_flatten_with_path(abs_p)[0]
    specs = {shd._path_str(p): shd.param_spec(MESH, p, l) for p, l in flat}
    assert specs["embed"] == P("model", None)
    wq = [v for k, v in specs.items() if k.endswith("attn/wq")][0]
    assert wq == P(None, None, "model")          # stacked leading unit axis
    wo = [v for k, v in specs.items() if k.endswith("attn/wo")][0]
    assert wo == P(None, "model", None)
    wd = [v for k, v in specs.items() if k.endswith("mlp/w_down")][0]
    assert wd == P(None, "model", None)


def test_gqa_kv_replication_rule():
    """qwen2 has 2 kv heads: on tp=16 the kv projections replicate."""
    mesh16 = _fake_mesh((2, 16), ("data", "model"))
    shd.set_model_config(ARCHS["qwen2-0.5b"])
    abs_p = models.abstract_params(ARCHS["qwen2-0.5b"])
    import jax.tree_util as jtu
    flat = jtu.tree_flatten_with_path(abs_p)[0]
    wk = [(p, l) for p, l in flat if shd._path_str(p).endswith("attn/wk")][0]
    assert shd.param_spec(mesh16, *wk) == P()
    # but q still shards
    wq = [(p, l) for p, l in flat if shd._path_str(p).endswith("attn/wq")][0]
    assert "model" in str(shd.param_spec(mesh16, *wq))
    shd.set_model_config(None)


def test_moe_expert_rules():
    shd.set_model_config(ARCHS["grok-1-314b"])
    abs_p = models.abstract_params(ARCHS["grok-1-314b"])
    import jax.tree_util as jtu
    flat = jtu.tree_flatten_with_path(abs_p)[0]
    wup = [(p, l) for p, l in flat
           if shd._path_str(p).endswith("moe/w_up")][0]
    spec = shd.param_spec(MESH, *wup)
    # grok: 8 experts don't divide nothing here (8%4==0 -> EP over data)
    assert spec[1] == "data" or spec[2] == "data" or "data" in str(spec)
    shd.set_model_config(None)


def test_zero_spec_adds_data_axis():
    shd.set_model_config(None)
    leaf = jax.ShapeDtypeStruct((1024, 512), jax.numpy.float32)
    path = (jax.tree_util.DictKey("m"), jax.tree_util.DictKey("final_norm"),
            jax.tree_util.DictKey("scale"))
    spec = shd.zero_spec(MESH, path, leaf)
    assert "data" in str(spec)


def test_batch_spec():
    assert shd.batch_spec(MESH, 8) == P(("data",))
    assert shd.batch_spec(MESH, 3) == P()


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS, smoke_config
    from repro import models
    from repro.distributed import sharding as shd
    from repro.training import AdamW, constant_schedule, init_state, make_train_step
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config(ARCHS["qwen3-1.7b"])
    mesh = make_host_mesh(data=4, model=2)
    shd.set_model_config(cfg)
    key = jax.random.PRNGKey(0)
    opt = AdamW(lr=constant_schedule(1e-3))
    # jax<0.6 has no jax.sharding.set_mesh; Mesh itself is a context manager
    _set_mesh = getattr(jax.sharding, "set_mesh", None) \
        or getattr(jax.sharding, "use_mesh", None) or (lambda m: m)
    with _set_mesh(mesh):
        state = init_state(cfg, opt, key)
        abs_p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.params)
        p_shard = shd.param_shardings(mesh, abs_p)
        state = state._replace(params=jax.device_put(state.params, p_shard))
        step = jax.jit(make_train_step(cfg, opt, microbatches=2))
        toks = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
        state, m1 = step(state, batch)
        state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5
    # single-device reference for numerical agreement
    print("MULTIDEV_OK", float(m1["loss"]))
""")


@pytest.mark.slow
def test_pjit_train_step_8_devices(tmp_path):
    """End-to-end pjit train step on a 4x2 host-device mesh (subprocess so
    the main process keeps 1 device)."""
    script = tmp_path / "multidev.py"
    script.write_text(MULTIDEV_SCRIPT)
    res = subprocess.run([sys.executable, str(script)], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "MULTIDEV_OK" in res.stdout, res.stdout + res.stderr
