"""Unit-algebra property tests (tentpole satellite): the dimension vectors
behind core/units.py form an abelian group under * and /, and the alias
vocabulary encodes the pricing identities the checker relies on
(Bytes / BytesPerSecond = Seconds, Cycles / Hertz = Seconds, ...).

Property tests draw random exponent vectors when hypothesis is installed
and skip cleanly otherwise (tests/_hypothesis_compat.py); the algebraic
identity tests and the shipped-tree gate below always run.
"""
import pathlib

import pytest

from repro.core import unitcheck
from repro.core import units
from repro.core.units import ALIASES, DIMENSIONLESS, DIMENSIONS, Unit, unit_of

from _hypothesis_compat import given, settings, st

_ROOT = pathlib.Path(__file__).resolve().parents[1]

units_st = st.builds(
    lambda d: Unit(**d),
    st.dictionaries(st.sampled_from(DIMENSIONS), st.integers(-4, 4),
                    max_size=len(DIMENSIONS)))


# ---------------------------------------------------------------------------
# group laws
# ---------------------------------------------------------------------------

@given(units_st, units_st, units_st)
@settings(max_examples=200, deadline=None)
def test_mul_associative(a, b, c):
    assert (a * b) * c == a * (b * c)


@given(units_st, units_st)
@settings(max_examples=200, deadline=None)
def test_mul_commutative(a, b):
    assert a * b == b * a


@given(units_st)
@settings(max_examples=100, deadline=None)
def test_identity_and_inverse(a):
    assert a * DIMENSIONLESS == a
    assert a / DIMENSIONLESS == a
    assert (a / a).dimensionless
    assert (DIMENSIONLESS / a) * a == DIMENSIONLESS


@given(units_st, units_st)
@settings(max_examples=200, deadline=None)
def test_cancellation(a, b):
    assert (a * b) / b == a
    assert (a / b) * b == a


@given(units_st)
@settings(max_examples=100, deadline=None)
def test_integer_powers(a):
    assert a ** 0 == DIMENSIONLESS
    assert a ** 1 == a
    assert a ** 2 == a * a
    assert a ** -1 == DIMENSIONLESS / a


@given(units_st, units_st)
@settings(max_examples=200, deadline=None)
def test_eq_hash_consistent(a, b):
    if a == b:
        assert hash(a) == hash(b)


# ---------------------------------------------------------------------------
# the pricing identities (always run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num, den, out", [
    ("Bytes", "BytesPerSecond", "Seconds"),
    ("Flops", "FlopsPerSecond", "Seconds"),
    ("Cycles", "Hertz", "Seconds"),
    ("Bytes", "BytesPerElement", "Elements"),
    ("Flops", "FlopsPerElement", "Elements"),
    ("Bytes", "BytesPerCycle", "Cycles"),
    ("Bytes", "Seconds", "BytesPerSecond"),
    ("Flops", "Seconds", "FlopsPerSecond"),
])
def test_division_identities(num, den, out):
    assert ALIASES[num] / ALIASES[den] == ALIASES[out]


def test_multiplication_identities():
    assert ALIASES["Elements"] * ALIASES["BytesPerElement"] == ALIASES["Bytes"]
    assert ALIASES["Elements"] * ALIASES["FlopsPerElement"] == ALIASES["Flops"]
    assert ALIASES["Hertz"] * ALIASES["Seconds"] == ALIASES["Cycles"]
    assert ALIASES["Ratio"] == DIMENSIONLESS


def test_unit_of_agrees_with_registry():
    """The Annotated metadata on each alias IS its registry entry."""
    for name, u in ALIASES.items():
        assert unit_of(getattr(units, name)) == u
    with pytest.raises(TypeError):
        unit_of(float)


def test_distinct_dimensions_differ():
    base = [ALIASES[a] for a in ("Seconds", "Cycles", "Bytes", "Elements",
                                 "Flops", "Mm2", "Dollars", "Watts")]
    assert len(set(base)) == len(base)
    for u in base:
        assert not u.dimensionless


def test_non_unit_operands_raise():
    with pytest.raises(TypeError):
        ALIASES["Seconds"] * 3          # type: ignore[operator]
    with pytest.raises(TypeError):
        ALIASES["Seconds"] / "x"        # type: ignore[operator]
    with pytest.raises(TypeError):
        ALIASES["Seconds"] ** 1.5       # type: ignore[operator]


def test_aliases_cover_every_dimension():
    dims_named = set()
    for u in ALIASES.values():
        dims_named |= {d for d, _ in u.dims}
    assert dims_named == set(DIMENSIONS)


# ---------------------------------------------------------------------------
# the shipped tree is clean (the CI gate, run in-process)
# ---------------------------------------------------------------------------

def test_shipped_core_has_zero_unit_errors():
    diags = unitcheck.check_paths([str(_ROOT / "src" / "repro" / "core")])
    errors = [d for d in diags if d.severity == "error"]
    assert errors == [], "\n".join(
        f"{d.rule} {d.location}: {d.message}" for d in errors)
