"""Planted-mutant suite for the static unit checker (the tentpole's proof
of work): every rule must catch a representative unit bug — including the
four acceptance mutants (bytes+seconds add, cycles returned as seconds,
elements stored into a bytes field, a forgotten bandwidth divide) — at the
right rule id AND source line, and the corrected twin of each mutant must
come back clean. Mirrors tests/test_verify.py's registry-coverage pattern.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.core import unitcheck

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _diags(src):
    return unitcheck.check_source(textwrap.dedent(src), filename="<m>")


def _assert_caught(src, rule, line):
    hits = [d for d in _diags(src) if d.rule == rule]
    assert hits, f"{rule} missed its mutant entirely"
    locs = [d.location for d in hits]
    assert f"<m>:{line}" in locs, \
        f"{rule} fired at {locs}, expected <m>:{line}"
    assert all(d.severity == "error" for d in hits)


def _assert_clean(src):
    assert _diags(src) == []


# ---------------------------------------------------------------------------
# the four acceptance mutants
# ---------------------------------------------------------------------------

def test_mutant_bytes_plus_seconds():
    _assert_caught("""\
        from repro.core.units import Bytes, Seconds
        def f(n: Bytes, t: Seconds) -> float:
            return n + t
        """, "unit.add-mismatch", 3)
    _assert_clean("""\
        from repro.core.units import Bytes, BytesPerSecond, Seconds
        def f(n: Bytes, bw: BytesPerSecond, t: Seconds) -> Seconds:
            return n / bw + t
        """)


def test_mutant_cycles_returned_as_seconds():
    _assert_caught("""\
        from repro.core.units import Cycles, Seconds
        def f(c: Cycles) -> Seconds:
            return c
        """, "unit.return-mismatch", 3)
    _assert_clean("""\
        from repro.core.units import Cycles, Hertz, Seconds
        def f(c: Cycles, freq: Hertz) -> Seconds:
            return c / freq
        """)


def test_mutant_elements_stored_into_bytes_field():
    _assert_caught("""\
        from dataclasses import dataclass
        from repro.core.units import Bytes, Elements

        @dataclass
        class Spec:
            n_bytes: Bytes = 0.0

        def f(n: Elements) -> Spec:
            s = Spec()
            s.n_bytes = n
            return s
        """, "unit.field-mismatch", 10)
    _assert_clean("""\
        from dataclasses import dataclass
        from repro.core.units import Bytes, BytesPerElement, Elements

        @dataclass
        class Spec:
            n_bytes: Bytes = 0.0

        def f(n: Elements, width: BytesPerElement) -> Spec:
            s = Spec()
            s.n_bytes = n * width
            return s
        """)


def test_mutant_missing_bandwidth_divide():
    _assert_caught("""\
        from repro.core.units import Bytes, BytesPerSecond, Seconds
        def f(n: Bytes, bw: BytesPerSecond) -> Seconds:
            t: Seconds = n
            return t
        """, "unit.assign-mismatch", 3)
    _assert_clean("""\
        from repro.core.units import Bytes, BytesPerSecond, Seconds
        def f(n: Bytes, bw: BytesPerSecond) -> Seconds:
            t: Seconds = n / bw
            return t
        """)


# ---------------------------------------------------------------------------
# the remaining rules
# ---------------------------------------------------------------------------

def test_mutant_compare_mismatch():
    _assert_caught("""\
        from repro.core.units import Flops, Seconds
        def f(x: Flops, t: Seconds) -> bool:
            return x > t
        """, "unit.compare-mismatch", 3)
    _assert_clean("""\
        from repro.core.units import Seconds
        def f(a: Seconds, b: Seconds) -> bool:
            return a > b
        """)


def test_mutant_call_mismatch():
    _assert_caught("""\
        from repro.core.units import Bytes, Seconds
        def launch(t: Seconds) -> Seconds:
            return t
        def f(n: Bytes) -> Seconds:
            return launch(n)
        """, "unit.call-mismatch", 5)
    _assert_clean("""\
        from repro.core.units import Seconds
        def launch(t: Seconds) -> Seconds:
            return t
        def f(t: Seconds) -> Seconds:
            return launch(t)
        """)


def test_mutant_constructor_field_mismatch():
    """Dataclass constructors check keyword args against field units (a
    constructor argument is a field store, so it carries the field rule)."""
    _assert_caught("""\
        from dataclasses import dataclass
        from repro.core.units import Cycles, Seconds

        @dataclass
        class Slot:
            start: Seconds = 0.0

        def f(c: Cycles) -> Slot:
            return Slot(start=c)
        """, "unit.field-mismatch", 9)


def test_mutant_augassign_keeps_declared_unit():
    _assert_caught("""\
        from repro.core.units import Bytes, Seconds
        def f(n: Bytes) -> Seconds:
            t: Seconds = 0.0
            t += n
            return t
        """, "unit.add-mismatch", 4)


def test_dimensionless_and_any_do_not_fire():
    """Gradual typing: literals, unannotated values and Ratio scaling are
    never diagnosed — only contradictions between known units are."""
    _assert_clean("""\
        from repro.core.units import Ratio, Seconds
        def f(t: Seconds, util: Ratio, k: int) -> Seconds:
            body = t * util * 2.0 + t
            mystery = helper(k)
            return body + mystery * 1.0
        def helper(k):
            return k
        """)


# ---------------------------------------------------------------------------
# registry coverage (every rule has a caught sample; no orphans either way)
# ---------------------------------------------------------------------------

def test_every_rule_has_a_sample_mutant():
    assert set(unitcheck.RULES) == set(unitcheck._SAMPLE_MUTANTS)


@pytest.mark.parametrize("rule_id", sorted(unitcheck.RULES))
def test_registry_sample_fires(rule_id):
    diags = unitcheck.registry_diagnostics()[rule_id]
    assert diags, f"{rule_id}'s sample mutant produced no diagnostic"
    assert all(d.rule == rule_id for d in diags)


def test_registry_selfcheck_passes():
    unitcheck.registry_selfcheck()      # raises on any uncaught sample


def test_parse_error_is_reported_not_raised():
    diags = unitcheck.check_source("def broken(:\n", filename="<bad>")
    assert any(d.rule == "unit.parse-error" for d in diags)


# ---------------------------------------------------------------------------
# CLI gate: python -m repro.unitcheck
# ---------------------------------------------------------------------------

def _run_cli(*args, cwd=_ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "repro.unitcheck", *args],
                          capture_output=True, text=True, cwd=cwd, env=env)


def test_cli_clean_tree_exits_zero():
    p = _run_cli(str(_ROOT / "src" / "repro" / "core"))
    assert p.returncode == 0, p.stdout + p.stderr
    assert "0 diagnostic(s)" in p.stdout


def test_cli_error_mode_gates(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""\
        from repro.core.units import Bytes, Seconds
        def f(n: Bytes, t: Seconds) -> float:
            return n + t
        """))
    p = _run_cli(str(bad))
    assert p.returncode == 1
    assert "unit.add-mismatch" in p.stdout
    assert f"{bad}:3" in p.stdout

    p = _run_cli("--mode", "warn", str(bad))
    assert p.returncode == 0
    assert "unit.add-mismatch" in p.stdout

    p = _run_cli("--mode", "off", str(bad))
    assert p.returncode == 0
    assert "nothing checked" in p.stdout


def test_cli_json_report(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""\
        from repro.core.units import Cycles, Seconds
        def f(c: Cycles) -> Seconds:
            return c
        """))
    out = tmp_path / "report.json"
    p = _run_cli("--json", str(out), str(bad))
    assert p.returncode == 1
    doc = json.loads(out.read_text())
    assert doc["count"] == len(doc["diagnostics"]) >= 1
    assert doc["diagnostics"][0]["rule"] == "unit.return-mismatch"
    assert sorted(doc["rules"]) == sorted(unitcheck.RULES)


def test_cli_selfcheck_flag():
    p = _run_cli("--selfcheck", str(_ROOT / "src" / "repro" / "core"))
    assert p.returncode == 0, p.stdout + p.stderr
