"""Mapper + systolic model: unit and property tests (paper Sec. III-B1)."""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import hardware as hw
from repro.core.mapper import matmul_perf, _tile_candidates
from repro.core.systolic import gemm_cycles, gemm_cycles_array, utilization
from repro.core.roofline import matmul_roofline

A100 = hw.nvidia_a100()
TPU = hw.google_tpu_v5e()


def brute_force_cycles(m, k, n, rows, cols):
    """Reference: explicit pass enumeration."""
    total = 0
    for r0 in range(0, m, rows):
        for c0 in range(0, n, cols):
            r_occ = min(rows, m - r0)
            c_occ = min(cols, n - c0)
            total += 2 * r_occ + c_occ + k - 2
    return total


@given(m=st.integers(1, 400), k=st.integers(1, 300), n=st.integers(1, 400))
@settings(max_examples=60, deadline=None)
def test_systolic_closed_form_matches_bruteforce(m, k, n):
    assert gemm_cycles(m, k, n, 16, 16) == brute_force_cycles(m, k, n, 16, 16)


def test_systolic_vectorized_matches_scalar():
    ms = np.array([1, 16, 33, 128, 200])
    ks = np.array([1, 7, 64, 128, 500])
    ns = np.array([1, 16, 31, 256, 129])
    vec = gemm_cycles_array(ms, ks, ns, 16, 16)
    for i in range(len(ms)):
        assert vec[i] == gemm_cycles(int(ms[i]), int(ks[i]), int(ns[i]),
                                     16, 16)


def test_systolic_utilization_bounds():
    sa = A100.core.lane.systolic_array
    # deep-k amortizes fill/drain; short-k pays it (paper Fig. 7 analysis)
    assert 0.95 < utilization(128, 4096, 128, sa) <= 1.0
    assert 0.7 < utilization(128, 128, 128, sa) < 0.8
    assert utilization(1, 128, 128, sa) < 0.2


@given(m=st.sampled_from([1, 16, 64, 512, 4096]),
       k=st.sampled_from([64, 512, 12288]),
       n=st.sampled_from([128, 3072, 12288]))
@settings(max_examples=20, deadline=None)
def test_mapper_never_beats_roofline(m, k, n):
    """The paper's key criticism of rooflines: they're optimistic. Our
    tile-level latency must never be below the roofline bound."""
    r = matmul_perf(A100, m, k, n)
    rf = matmul_roofline(A100, m, k, n)
    assert r.latency >= rf.compute_s * 0.999
    assert r.latency >= rf.memory_s * 0.35  # C-tile write-back may overlap


def test_mapper_tiles_fit_buffers():
    r = matmul_perf(A100, 4096, 12288, 3072)
    mp = r.mapping
    gb = (mp.tile_m * mp.tile_k + mp.tile_k * mp.tile_n
          + mp.tile_m * mp.tile_n) * 2
    if mp.double_buffer_l2:
        gb *= 2
    assert gb <= A100.global_buffer_bytes
    lb = (mp.subtile_m * mp.subtile_k + mp.subtile_k * mp.subtile_n
          + mp.subtile_m * mp.subtile_n) * 2
    if mp.double_buffer_l1:
        lb *= 2
    assert lb <= A100.core.local_buffer_bytes
    assert mp.subtile_m <= mp.tile_m
    assert mp.subtile_n <= mp.tile_n


def test_mapper_compute_bound_large_matmul():
    r = matmul_perf(A100, 16384, 12288, 12288)
    assert r.mapping.bound == "compute"
    eff = r.flops / r.latency / A100.peak_matmul_flops
    assert 0.5 < eff <= 1.0, f"MXU efficiency {eff}"


def test_mapper_memory_bound_narrow_matmul():
    """Decode-shape GEMM (paper: 16 x 12288) must be IO-bound."""
    r = matmul_perf(A100, 16, 12288, 12288)
    assert r.mapping.bound == "memory"


def test_mapper_monotone_in_m():
    lats = [matmul_perf(A100, m, 12288, 12288).latency
            for m in (64, 256, 1024, 4096)]
    assert all(b > a * 0.98 for a, b in zip(lats, lats[1:]))


def test_mapper_batched_gqa_traffic():
    """Batched (per-head) matmul reads the B operand once per batch."""
    single = matmul_perf(A100, 2048, 128, 2048)
    batched = matmul_perf(A100, 2048, 128, 2048, batch=8)
    assert batched.main_memory_bytes > 7 * single.main_memory_bytes * 0.8
    assert batched.latency > 4 * single.latency


def test_tile_candidates_cover_dim():
    c = _tile_candidates(1000, 16)
    assert 1000 in c
    assert all(x > 0 for x in c)


def test_mapper_tpu_blocks_are_mxu_aligned():
    from repro.kernels.matmul.ops import mapper_blocks
    bm, bk, bn = mapper_blocks(4096, 4096, 4096)
    assert bm % 128 == 0 and bk % 128 == 0 and bn % 128 == 0
